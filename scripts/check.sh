#!/usr/bin/env bash
# One-entry-point build check: tier-1 test suite, a fast interpret-mode
# smoke of the sorted_probe Pallas kernel (stage B runs through the Pallas
# interpreter, so kernel regressions surface even on CPU-only machines),
# a sharded-store round trip (build → save_sharded → reopen → lookup_batch),
# a pipelined-extraction smoke (parallel engine vs serial loop parity on a
# collision-seeded corpus), a query-service smoke (concurrent clients
# through the micro-batching scheduler: byte parity vs the serial
# reference + a nonzero coalesced-batch count), a similarity smoke (the
# Tanimoto Pallas kernel in interpret mode vs the NumPy oracle on a
# collision-seeded plane, byte-exact top-k), an LM-serving smoke (the
# paged-KV continuous-batching engine token-for-token identical to the
# static engine on uniform AND ragged request mixes), and a smoke-scale
# pass of the full benchmark harness — which must also produce the
# BENCH_extract.json / BENCH_service.json / BENCH_similarity.json /
# BENCH_serve.json metrics files — so the bench modules can't silently
# rot.  Smoke runs
# park their metrics at temp paths; the committed BENCH_*.json files
# only change via `python -m benchmarks.run --update-metrics`.
#
# Usage: scripts/check.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Opt into tcmalloc when the box has it: the span engine's multi-threaded
# carve/decode path hits glibc malloc's arena locks otherwise.  Opt out
# with REPRO_NO_TCMALLOC=1.
if [[ -z "${REPRO_NO_TCMALLOC:-}" && "${LD_PRELOAD:-}" != *tcmalloc* ]]; then
  for so in /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
            /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
            /usr/lib/aarch64-linux-gnu/libtcmalloc_minimal.so.4 \
            /usr/lib/libtcmalloc_minimal.so.4 \
            /usr/lib/libtcmalloc.so.4; do
    if [[ -e "$so" ]]; then
      export LD_PRELOAD="${LD_PRELOAD:+$LD_PRELOAD:}$so"
      echo "== tcmalloc preloaded: $so =="
      break
    fi
  done
fi

echo "== tier-1: pytest =="
python -m pytest -x -q "$@"

echo "== kernel smoke: sorted_probe (interpret mode) =="
python - <<'PY'
import numpy as np
import jax.numpy as jnp
from repro.kernels.sorted_probe.ops import sorted_probe_pallas
from repro.kernels.sorted_probe.ref import sorted_probe_ref

rng = np.random.default_rng(0)
table = np.unique(
    rng.integers(0, 2**32 - 1, size=(512, 2), dtype=np.uint32), axis=0
)
hits = table[rng.integers(0, len(table), size=64)]
misses = rng.integers(0, 2**32 - 1, size=(64, 2), dtype=np.uint32)
queries = jnp.asarray(np.concatenate([hits, misses]))
table = jnp.asarray(table)

found_k, pos_k = sorted_probe_pallas(queries, table, interpret=True)
found_r, pos_r = sorted_probe_ref(queries, table)
assert bool(jnp.all(found_k == found_r)), "found mask mismatch vs reference"
assert bool(jnp.all(jnp.where(found_k, pos_k, 0) == jnp.where(found_r, pos_r, 0)))
assert int(found_k[:64].sum()) == 64, "planted hits not all found"
print(f"sorted_probe interpret OK: {int(found_k.sum())}/{len(queries)} hits")
PY

echo "== store smoke: build -> save_sharded -> reopen -> lookup_batch =="
python - <<'PY'
import tempfile
from pathlib import Path
from repro.core import ByteOffsetIndex, IndexStore

idx = ByteOffsetIndex(key_mode="full_id")
for i in range(2000):
    idx.add(f"InChI=1S/check/{i}", f"f_{i % 5:02d}.sdf", i * 64)
with tempfile.TemporaryDirectory() as td:
    summary = idx.save_sharded(Path(td) / "store", n_shards=4)
    assert summary["written"] == 4, summary
    qs = IndexStore.open(Path(td) / "store")
    present = [f"InChI=1S/check/{i}" for i in range(0, 2000, 13)]
    absent = [f"InChI=1S/nope/{i}" for i in range(50)]
    fid, off, hit = qs.lookup_batch(present + absent)
    assert hit[: len(present)].all() and not hit[len(present):].any()
    for k, loc in zip(present, qs.locate_batch(present)):
        assert loc == idx.lookup(k), (k, loc)
    # re-publish is incremental: nothing changed -> nothing rewritten
    assert idx.save_sharded(Path(td) / "store", n_shards=4)["written"] == 0
print(f"index store OK: {len(present)} hits, {len(absent)} misses, "
      f"{qs.stats.bloom_rejects} bloom rejects")
PY

echo "== extraction engine smoke: pipelined vs serial parity =="
python - <<'PY'
import tempfile
from pathlib import Path
from repro.core import RecordCache, RecordStore, build_index, extract, intersect_host
from repro.core.sdfgen import CorpusSpec, db_id_list, generate_corpus

# 1500 records into a 16-bit key space: hashed collisions land in the
# target set, so the mismatch path is part of the parity check
spec = CorpusSpec(n_files=3, records_per_file=500, key_bits=16)
root = Path(tempfile.mkdtemp()) / "c"
generate_corpus(root, spec)
store = RecordStore(root)
targets = intersect_host(
    db_id_list(spec, "chembl", extra_outside=10),
    db_id_list(spec, "emolecules", extra_outside=10),
).ids
idx = build_index(store, key_mode="hashed_key", key_bits=16)
serial = extract(store, idx, targets, key_bits=16, workers=0)
cache = RecordCache(capacity=1024)
piped = extract(store, idx, targets, key_bits=16, workers=4, cache=cache)
warm = extract(store, idx, targets, key_bits=16, workers=4, cache=cache)
for other in (piped, warm):
    assert list(other.records.items()) == list(serial.records.items())
    assert other.missing == serial.missing
    assert other.mismatches == serial.mismatches
assert warm.cache_hits == warm.seeks and warm.spans_read == 0
assert serial.mismatches, "smoke corpus no longer seeds collisions"
# every span backend must reproduce the serial loop byte-for-byte,
# mismatches included
from repro.core.iobackend import uring_available
backends = ["thread", "mmap"] + (["uring"] if uring_available() else [])
for be in backends:
    r = extract(store, idx, targets, key_bits=16, workers=4, backend=be)
    assert list(r.records.items()) == list(serial.records.items()), be
    assert r.missing == serial.missing and r.mismatches == serial.mismatches, be
    assert r.read_backend == be, (be, r.read_backend)
print(f"extraction engine OK: {serial.found} records, "
      f"{len(serial.missing)} missing, {len(serial.mismatches)} mismatches "
      f"identical on serial/pipelined/warm + backends {backends}; "
      f"{piped.spans_read} spans cold, {warm.cache_hits} cache hits warm")
PY

echo "== service smoke: concurrent clients vs serial parity =="
python - <<'PY'
import tempfile, threading
from pathlib import Path
from repro.core import RecordStore, build_index, extract, intersect_host
from repro.core.sdfgen import CorpusSpec, db_id_list, generate_corpus
from repro.service import QueryService, ServiceConfig

# collision-seeded corpus: the service must reproduce the serial loop's
# records AND its mismatches byte-for-byte
spec = CorpusSpec(n_files=3, records_per_file=500, key_bits=16)
root = Path(tempfile.mkdtemp()) / "c"
generate_corpus(root, spec)
store = RecordStore(root)
targets = intersect_host(
    db_id_list(spec, "chembl", extra_outside=10),
    db_id_list(spec, "emolecules", extra_outside=10),
).ids
idx = build_index(store, key_mode="hashed_key", key_bits=16)
sdir = root.parent / "istore"
idx.save_sharded(sdir, n_shards=8)
serial = extract(store, idx, targets, key_bits=16, workers=0)
assert serial.mismatches, "smoke corpus no longer seeds collisions"

with QueryService(store, sdir, ServiceConfig(replicas=2)) as svc:
    outs = {}
    def client(i):
        outs[i] = svc.fetch(targets, key_bits=16)
    ths = [threading.Thread(target=client, args=(i,)) for i in range(6)]
    for t in ths: t.start()
    for t in ths: t.join()
    for res in outs.values():
        assert list(res.records.items()) == list(serial.records.items())
        assert res.missing == serial.missing
        assert res.mismatches == serial.mismatches
    # concurrent single-key lookups must coalesce into shared probes
    lk = [k for k in idx.entries][:400]
    def looker(i):
        for j in range(i, len(lk), 6):
            svc.lookup_batch(lk[j:j+2])
    ths = [threading.Thread(target=looker, args=(i,)) for i in range(6)]
    for t in ths: t.start()
    for t in ths: t.join()
    sch = svc.stats()["scheduler"]
    assert sch["coalesced_batches"] > 0, "no request coalescing happened"
    print(f"query service OK: {len(outs)} concurrent fetches byte-identical "
          f"({len(serial.mismatches)} collision mismatches reproduced), "
          f"{sch['coalesced_batches']} coalesced batches "
          f"(mean {sch['mean_batch_keys']:.1f} keys)")
PY

echo "== chaos smoke: closed-loop load with a shard killed mid-run =="
python - <<'PY'
import tempfile, threading, time
import numpy as np
from pathlib import Path
from repro.core import RecordStore, build_index
from repro.core.sdfgen import CorpusSpec, generate_corpus
from repro.core.store import IndexStore, digest_u64, shard_of
from repro.runtime.fault import BackoffPolicy
from repro.service import (
    FaultInjectingTransport, LocalTransport, QueryService, ServiceConfig,
    ShardRouter, run_closed_loop,
)

spec = CorpusSpec(n_files=3, records_per_file=500)
root = Path(tempfile.mkdtemp()) / "c"
generate_corpus(root, spec)
store = RecordStore(root)
idx = build_index(store, key_mode="full_id")
sdir = root.parent / "istore"
idx.save_sharded(sdir, n_shards=8)

injectors = []
def factory(st, i):
    tr = FaultInjectingTransport(LocalTransport(st, name=f"r{i}"), seed=42 + i)
    injectors.append(tr)
    return tr

router = ShardRouter(
    sdir, replicas=2, min_scatter_keys=1, transport_factory=factory,
    probe_timeout_ms=250.0, fail_threshold=2,
    health_backoff=BackoffPolicy(base_s=0.1, cap_s=0.5),
)
keys = sorted(IndexStore.open(sdir).iter_keys())
dead_shard = 3
with QueryService(store, router, ServiceConfig(replicas=2)) as svc:
    svc.lookup_batch(keys[:500])  # warm

    def chaos():  # kill one shard range mid-run, revive before the end
        time.sleep(0.2)
        for tr in injectors:
            tr.kill(shard=dead_shard)
        time.sleep(0.4)
        for tr in injectors:
            tr.revive(shard=dead_shard)
    driver = threading.Thread(target=chaos)
    driver.start()
    rep = run_closed_loop(
        lambda ks: svc.lookup_batch(ks), keys, clients=6, duration_s=1.0,
        keys_per_request=8,
        classify=lambda r: bool(r.degraded.any()),
        counters_fn=lambda: {
            k: float(v) for k, v in svc.stats()["fault"].items()
            if isinstance(v, (int, float))
        },
    )
    driver.join()
    # 1) the outage never surfaced as a client error — only degraded masks
    assert rep.errors == 0, f"{rep.errors} client errors during chaos"
    assert rep.degraded > 0, "kill window produced no degraded responses"
    # 2) the degraded mask is exactly the killed shard's key range
    sid = shard_of(digest_u64(keys), router.n_shards, router.digest_bits)
    for tr in injectors:
        tr.kill(shard=dead_shard)
    res = svc.lookup_batch(keys)
    assert np.array_equal(res.degraded, sid == dead_shard), "bad miss mask"
    assert res.hit[sid != dead_shard].all(), "healthy shards lost keys"
    for tr in injectors:
        tr.revive(shard=dead_shard)
    # 3) parity restored within the recovery budget after revival
    ref = IndexStore.open(sdir).lookup_batch(keys)
    deadline = time.monotonic() + 10.0
    res = svc.lookup_batch(keys)
    while res.degraded.any() and time.monotonic() < deadline:
        time.sleep(0.1)
        res = svc.lookup_batch(keys)
    assert not res.degraded.any(), "shard still degraded 10s after revival"
    for got, want in zip((res.file_ids, res.offsets, res.hit), ref):
        assert np.array_equal(got, want), "post-revival parity broken"
    snap = svc.stats()["health"]
    print(f"chaos smoke OK: {rep.requests} requests, 0 failed, "
          f"{rep.degraded} degraded during the kill window, "
          f"{int(rep.counters.get('retries', 0))} retries, "
          f"{snap['revivals']} revivals "
          f"(last recovery {snap['last_recovery_s']:.2f}s), "
          f"post-revival parity on {len(keys)} keys")
router.close()
PY

echo "== serve smoke: continuous batching vs static engine parity =="
python - <<'PY'
import dataclasses
import jax
from repro.configs import get_config
from repro.models.registry import build_model
from repro.serve.engine import Engine, ServeConfig
from repro.serve.kvcache import PagedCacheSpec
from repro.serve.scheduler import ContinuousEngine

cfg = dataclasses.replace(
    get_config("yi-6b"), n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
    head_dim=32, d_ff=128, vocab_size=300)
params, _ = build_model(cfg).init(jax.random.PRNGKey(0))
scfg = ServeConfig(max_new_tokens=10, max_len=64, greedy=True)
spec = PagedCacheSpec(n_blocks=33, block_size=8, max_slots=3,
                      max_blocks_per_seq=8)
static = Engine(cfg, params, scfg)
cont = ContinuousEngine(cfg, params, spec, scfg)
# uniform batch: token-for-token identical to the static engine
texts = ["InChI=1S/C8H9NO2/", "C6H12O6/c1-", "smiles:CCO"]
want = [r.token_ids for r in static.generate(texts)]
got = [r.token_ids for r in cont.generate(texts)]
assert got == want, "continuous engine diverged from static on uniform batch"
# ragged budgets across more requests than slots: per-prompt serial parity
ragged = [("ab", 3), ("InChI=1S/C4H10/c1-3-4-2", 10), ("xy", 5), ("C1=CC", 7)]
futs = [cont.submit(t, b, lead=False) for t, b in ragged]
cont._maybe_lead()
for (t, b), f in zip(ragged, futs):
    assert f.result(timeout=300).token_ids == \
        static.generate([t])[0].token_ids[:b], f"ragged diverged on {t!r}"
cont.check()   # exact: slot holds + prefix-index holds == every refcount
st = cont._mgr.stats()
# after the last release only the prefix index pins blocks; dropping it
# must return the pool to empty with allocs balancing frees
held = len(cont._index.block_refs()) if cont._index is not None else 0
assert st["in_use"] == held, (st, held)
if cont._index is not None:
    cont._index.clear()
st = cont._mgr.stats()
assert st["in_use"] == 0 and st["allocs"] == st["frees"], st
slo = cont.slo_ms()
assert slo["ttft_p50_ms"] > 0 and slo["itl_p50_ms"] > 0, slo
cont.close()
# prefix-cache sharing must save prefill work without changing a byte
shared = ["InChI=1S/C8H9NO2/c1-6(10)9-7-2-4-8(11)5-3-7;" + t
          for t in ("a", "bb", "a")]
on = ContinuousEngine(cfg, params, spec,
                      ServeConfig(max_new_tokens=8, max_len=64, greedy=True),
                      prefix_cache=True)
off = ContinuousEngine(cfg, params, spec,
                       ServeConfig(max_new_tokens=8, max_len=64, greedy=True),
                       prefix_cache=False)
want = [r.token_ids for r in off.generate(shared)]
got = [r.token_ids for r in on.generate(shared)]
assert got == want, "prefix sharing changed emitted bytes"
assert on.stats.prefix_hits >= 2 and on.stats.prefill_tokens_saved > 0, \
    on.counters()
on.check()
saved = on.stats.prefill_tokens_saved
on.close(); off.close()
print(f"serve smoke OK: {len(texts)} uniform + {len(ragged)} ragged requests "
      f"byte-identical to the static engine; {st['allocs']} block allocs "
      f"all returned, itl p50 {slo['itl_p50_ms']:.2f} ms; prefix cache "
      f"saved {saved} prefill tokens with byte parity")
PY

echo "== similarity smoke: Tanimoto kernel (interpret) vs oracle =="
python - <<'PY'
import numpy as np
from repro.core.fingerprint import fingerprint_batch
from repro.kernels.tanimoto.ops import tanimoto_topk, tanimoto_topk_host
from repro.kernels.tanimoto.ref import tanimoto_topk_ref

# collision-seeded plane: repetitions of "ABC" share one trigram set, so
# the corpus carries byte-identical fingerprints and the top-k tie
# discipline (score desc, row asc) is load-bearing, not incidental
texts = ["ABC" * r for r in range(2, 10)] + [f"CID/{i:05d}" for i in range(120)]
db, dc = fingerprint_batch(texts)
q, _ = fingerprint_batch(["ABCABC", "CID/00042", "ZZZ"])
ref = tanimoto_topk_ref(q, db, 8)
kern = tanimoto_topk(q, db, 8, interpret=True)
host = tanimoto_topk_host(q, db, 8)
for tag, got in (("pallas-interpret", kern), ("host-blocked", host)):
    assert np.array_equal(ref[0], got[0]), f"{tag}: top-k scores diverge"
    assert np.array_equal(ref[1], got[1]), f"{tag}: top-k indices diverge"
assert kern[1][0].tolist() == list(range(8)), "tie flood must rank row-asc"
assert float(kern[0][0, 0]) == 1.0, "self-hit must score 1.0"
print(f"tanimoto parity OK: {len(texts)} rows, 8 seeded fingerprint "
      f"collisions, kernel == host == oracle byte-for-byte")
PY

echo "== bench smoke: full harness at smoke scale =="
BENCH_OUT=$(mktemp)
BENCH_JSON=$(mktemp -u)
BENCH_SVC_JSON=$(mktemp -u)
BENCH_SIM_JSON=$(mktemp -u)
BENCH_SRV_JSON=$(mktemp -u)
if ! REPRO_BENCH_FILES=2 REPRO_BENCH_RPF=250 \
     REPRO_BENCH_CACHE="${TMPDIR:-/tmp}/repro_bench_smoke" \
     REPRO_BENCH_EXTRACT_OUT="$BENCH_JSON" \
     REPRO_BENCH_SERVICE_OUT="$BENCH_SVC_JSON" \
     REPRO_BENCH_SIMILARITY_OUT="$BENCH_SIM_JSON" \
     REPRO_BENCH_SERVE_OUT="$BENCH_SRV_JSON" \
     REPRO_BENCH_SERVICE_SECONDS=0.4 \
     REPRO_BENCH_SIM_SECONDS=0.4 \
     REPRO_BENCH_SERVE_SECONDS=0.4 \
     python -m benchmarks.run > "$BENCH_OUT"; then
  echo "benchmark harness failed:"
  grep '\.ERROR,' "$BENCH_OUT" || tail -5 "$BENCH_OUT"
  rm -f "$BENCH_OUT" "$BENCH_JSON" "$BENCH_SVC_JSON" "$BENCH_SIM_JSON" \
        "$BENCH_SRV_JSON"
  exit 1
fi
echo "bench harness OK: $(wc -l < "$BENCH_OUT") CSV rows"
test -s "$BENCH_JSON" || { echo "BENCH_extract.json not produced"; exit 1; }
python - "$BENCH_JSON" <<'PY'
import json, sys
m = json.load(open(sys.argv[1]))
for key in ("serial", "pipelined_cold", "pipelined_warm",
            "speedup_warm", "parity"):
    assert key in m, f"BENCH_extract.json missing {key!r}"
assert m["parity"] is True, "serial vs pipelined output diverged"
print(f"BENCH_extract.json OK: warm speedup {m['speedup_warm']:.1f}x, "
      f"cache hit rate {m['pipelined_warm']['cache_hit_rate']:.0%}")
PY
test -s "$BENCH_SVC_JSON" || { echo "BENCH_service.json not produced"; exit 1; }
python - "$BENCH_SVC_JSON" <<'PY'
import json, sys
m = json.load(open(sys.argv[1]))
for key in ("naive", "service", "speedup_vs_naive", "mean_coalesced_batch",
            "coalesced_batches", "cache_hit_rate", "parity"):
    assert key in m, f"BENCH_service.json missing {key!r}"
assert m["parity"] is True, "service fetch diverged from serial extract"
assert m["coalesced_batches"] > 0, "no coalesced batches at smoke scale"
print(f"BENCH_service.json OK: {m['service']['lookups_per_sec']:.0f} "
      f"lookups/s ({m['speedup_vs_naive']:.1f}x naive), mean batch "
      f"{m['mean_coalesced_batch']:.1f} keys")
PY
test -s "$BENCH_SIM_JSON" || { echo "BENCH_similarity.json not produced"; exit 1; }
python - "$BENCH_SIM_JSON" <<'PY'
import json, sys
m = json.load(open(sys.argv[1]))
for key in ("qps", "speedup_kernel_vs_naive", "service", "parity_flags",
            "parity"):
    assert key in m, f"BENCH_similarity.json missing {key!r}"
assert m["parity"] is True, "a similarity backend diverged from the oracle"
print(f"BENCH_similarity.json OK: {m['qps']['kernel']:.0f} q/s "
      f"({m['speedup_kernel_vs_naive']:.1f}x naive loop), parity true")
PY
test -s "$BENCH_SRV_JSON" || { echo "BENCH_serve.json not produced"; exit 1; }
python - "$BENCH_SRV_JSON" <<'PY'
import json, sys
m = json.load(open(sys.argv[1]))
for key in ("ragged", "uniform", "shared_prefix", "slo", "scheduler",
            "allocator", "parity"):
    assert key in m, f"BENCH_serve.json missing {key!r}"
assert m["parity"] is True, "continuous engine diverged from static"
assert m["shared_prefix"]["parity"] is True, \
    "prefix sharing changed bytes"
assert m["shared_prefix"]["prefix_hit_rate"] > 0, \
    "shared-prefix mix never hit the prefix cache"
assert m["slo"]["ttft_p50_ms"] > 0 and m["slo"]["itl_p50_ms"] > 0, m["slo"]
print(f"BENCH_serve.json OK: continuous "
      f"{m['ragged']['continuous']['tokens_per_s']:.0f} tok/s "
      f"({m['ragged']['speedup']:.1f}x static on the ragged mix), "
      f"prefix hit rate {m['shared_prefix']['prefix_hit_rate']:.2f}, "
      f"itl p50 {m['slo']['itl_p50_ms']:.2f} ms")
PY
rm -f "$BENCH_OUT" "$BENCH_JSON" "$BENCH_SVC_JSON" "$BENCH_SIM_JSON" \
      "$BENCH_SRV_JSON"

echo "== bench-regression gate: committed BENCH_extract.json =="
python - BENCH_extract.json <<'PY'
import json, sys
m = json.load(open(sys.argv[1]))
cold, warm, parity = m["speedup_cold"], m["speedup_warm"], m["parity"]
errs = []
if parity is not True:
    errs.append("parity flag is not true (serial vs engine diverged)")
if warm < 5.0:
    errs.append(f"speedup_warm {warm:.2f}x < 5x floor")
if cold < 2.0:
    errs.append(f"speedup_cold {cold:.2f}x < 2x floor")
if errs:
    print("BENCH REGRESSION in committed BENCH_extract.json:")
    for e in errs:
        print(f"  - {e}")
    print("re-run `python -m benchmarks.run --scale 10 --update-metrics` "
          "on a quiet box and commit the refreshed metrics, or fix the "
          "read path.")
    sys.exit(1)
print(f"bench gate OK: cold {cold:.1f}x, warm {warm:.1f}x, parity true "
      f"(backend {m['pipelined_cold'].get('read_backend', '?')})")
PY

echo "== bench-regression gate: committed BENCH_similarity.json =="
python - BENCH_similarity.json <<'PY'
import json, sys
m = json.load(open(sys.argv[1]))
speedup, parity = m["speedup_kernel_vs_naive"], m["parity"]
errs = []
if parity is not True:
    errs.append("parity flag is not true (a backend diverged from the "
                "oracle or the service path)")
if speedup < 3.0:
    errs.append(f"speedup_kernel_vs_naive {speedup:.2f}x < 3x floor")
if errs:
    print("BENCH REGRESSION in committed BENCH_similarity.json:")
    for e in errs:
        print(f"  - {e}")
    print("re-run `python -m benchmarks.run --update-metrics` on a quiet "
          "box and commit the refreshed metrics, or fix the scoring path.")
    sys.exit(1)
print(f"similarity gate OK: {m['qps']['kernel']:.0f} q/s via "
      f"{m['config']['backend']} ({speedup:.1f}x naive loop), parity true")
PY

echo "== bench-regression gate: committed BENCH_serve.json =="
python - BENCH_serve.json <<'PY'
import json, sys
m = json.load(open(sys.argv[1]))
speedup, parity, slo = m["ragged"]["speedup"], m["parity"], m["slo"]
pfx = m["shared_prefix"]
errs = []
if parity is not True:
    errs.append("parity flag is not true (continuous vs static diverged)")
if speedup < 2.0:
    errs.append(f"ragged speedup {speedup:.2f}x < 2x floor")
if pfx["parity"] is not True:
    errs.append("shared_prefix parity is not true (sharing changed bytes)")
if pfx["speedup"] < 1.5:
    errs.append(f"shared_prefix speedup {pfx['speedup']:.2f}x < 1.5x floor")
if not pfx["prefix_hit_rate"] > 0:
    errs.append("shared_prefix hit rate is zero (index never matched)")
if not (slo["ttft_p50_ms"] > 0 and slo["itl_p50_ms"] > 0
        and slo["itl_p99_ms"] >= slo["itl_p50_ms"]):
    errs.append(f"SLO percentiles unpopulated or inconsistent: {slo}")
if errs:
    print("BENCH REGRESSION in committed BENCH_serve.json:")
    for e in errs:
        print(f"  - {e}")
    print("re-run `python -m benchmarks.run --update-metrics` on a quiet "
          "box and commit the refreshed metrics, or fix the decode loop.")
    sys.exit(1)
print(f"serve gate OK: {m['ragged']['continuous']['tokens_per_s']:.0f} tok/s "
      f"continuous ({speedup:.1f}x static ragged), shared-prefix "
      f"{pfx['speedup']:.1f}x at hit rate {pfx['prefix_hit_rate']:.2f}, "
      f"ttft p50 {slo['ttft_p50_ms']:.1f} ms, itl p50 "
      f"{slo['itl_p50_ms']:.2f} ms, parity true")
PY

echo "== all checks passed =="
