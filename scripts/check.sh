#!/usr/bin/env bash
# One-entry-point build check: tier-1 test suite + a fast interpret-mode
# smoke of the sorted_probe Pallas kernel (stage B runs through the Pallas
# interpreter, so kernel regressions surface even on CPU-only machines).
#
# Usage: scripts/check.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q "$@"

echo "== kernel smoke: sorted_probe (interpret mode) =="
python - <<'PY'
import numpy as np
import jax.numpy as jnp
from repro.kernels.sorted_probe.ops import sorted_probe_pallas
from repro.kernels.sorted_probe.ref import sorted_probe_ref

rng = np.random.default_rng(0)
table = np.unique(
    rng.integers(0, 2**32 - 1, size=(512, 2), dtype=np.uint32), axis=0
)
hits = table[rng.integers(0, len(table), size=64)]
misses = rng.integers(0, 2**32 - 1, size=(64, 2), dtype=np.uint32)
queries = jnp.asarray(np.concatenate([hits, misses]))
table = jnp.asarray(table)

found_k, pos_k = sorted_probe_pallas(queries, table, interpret=True)
found_r, pos_r = sorted_probe_ref(queries, table)
assert bool(jnp.all(found_k == found_r)), "found mask mismatch vs reference"
assert bool(jnp.all(jnp.where(found_k, pos_k, 0) == jnp.where(found_r, pos_r, 0)))
assert int(found_k[:64].sum()) == 64, "planted hits not all found"
print(f"sorted_probe interpret OK: {int(found_k.sum())}/{len(queries)} hits")
PY

echo "== all checks passed =="
