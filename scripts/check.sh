#!/usr/bin/env bash
# One-entry-point build check: tier-1 test suite, a fast interpret-mode
# smoke of the sorted_probe Pallas kernel (stage B runs through the Pallas
# interpreter, so kernel regressions surface even on CPU-only machines),
# a sharded-store round trip (build → save_sharded → reopen → lookup_batch),
# and a smoke-scale pass of the full benchmark harness so the bench modules
# can't silently rot.
#
# Usage: scripts/check.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q "$@"

echo "== kernel smoke: sorted_probe (interpret mode) =="
python - <<'PY'
import numpy as np
import jax.numpy as jnp
from repro.kernels.sorted_probe.ops import sorted_probe_pallas
from repro.kernels.sorted_probe.ref import sorted_probe_ref

rng = np.random.default_rng(0)
table = np.unique(
    rng.integers(0, 2**32 - 1, size=(512, 2), dtype=np.uint32), axis=0
)
hits = table[rng.integers(0, len(table), size=64)]
misses = rng.integers(0, 2**32 - 1, size=(64, 2), dtype=np.uint32)
queries = jnp.asarray(np.concatenate([hits, misses]))
table = jnp.asarray(table)

found_k, pos_k = sorted_probe_pallas(queries, table, interpret=True)
found_r, pos_r = sorted_probe_ref(queries, table)
assert bool(jnp.all(found_k == found_r)), "found mask mismatch vs reference"
assert bool(jnp.all(jnp.where(found_k, pos_k, 0) == jnp.where(found_r, pos_r, 0)))
assert int(found_k[:64].sum()) == 64, "planted hits not all found"
print(f"sorted_probe interpret OK: {int(found_k.sum())}/{len(queries)} hits")
PY

echo "== store smoke: build -> save_sharded -> reopen -> lookup_batch =="
python - <<'PY'
import tempfile
from pathlib import Path
from repro.core import ByteOffsetIndex, IndexStore

idx = ByteOffsetIndex(key_mode="full_id")
for i in range(2000):
    idx.add(f"InChI=1S/check/{i}", f"f_{i % 5:02d}.sdf", i * 64)
with tempfile.TemporaryDirectory() as td:
    summary = idx.save_sharded(Path(td) / "store", n_shards=4)
    assert summary["written"] == 4, summary
    qs = IndexStore.open(Path(td) / "store")
    present = [f"InChI=1S/check/{i}" for i in range(0, 2000, 13)]
    absent = [f"InChI=1S/nope/{i}" for i in range(50)]
    fid, off, hit = qs.lookup_batch(present + absent)
    assert hit[: len(present)].all() and not hit[len(present):].any()
    for k, loc in zip(present, qs.locate_batch(present)):
        assert loc == idx.lookup(k), (k, loc)
    # re-publish is incremental: nothing changed -> nothing rewritten
    assert idx.save_sharded(Path(td) / "store", n_shards=4)["written"] == 0
print(f"index store OK: {len(present)} hits, {len(absent)} misses, "
      f"{qs.stats.bloom_rejects} bloom rejects")
PY

echo "== bench smoke: full harness at smoke scale =="
BENCH_OUT=$(mktemp)
if ! REPRO_BENCH_FILES=2 REPRO_BENCH_RPF=250 \
     REPRO_BENCH_CACHE="${TMPDIR:-/tmp}/repro_bench_smoke" \
     python -m benchmarks.run > "$BENCH_OUT"; then
  echo "benchmark harness failed:"
  grep '\.ERROR,' "$BENCH_OUT" || tail -5 "$BENCH_OUT"
  rm -f "$BENCH_OUT"
  exit 1
fi
echo "bench harness OK: $(wc -l < "$BENCH_OUT") CSV rows"
rm -f "$BENCH_OUT"

echo "== all checks passed =="
