"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness convention) covering:

  Table I   — baseline scan throughput + Eq. 2/3 projection
  Table II  — baseline vs indexed speedup (740× headline)
  Table III — storage / RAM / disk-I/O-volume trade-offs
  Table IV  — hashed-key vs full-id identifier strategies
  Eq. 4/5   — collision counts vs birthday bound + §VI discovery/migration
  Fig. 2    — runtime scaling and baseline/index crossover
  kernels   — TPU-adapted hot-loop throughput (hash_mix, sorted_probe)

Corpus scale via REPRO_BENCH_FILES / REPRO_BENCH_RPF env vars.
Roofline numbers come from the dry-run (results/dryrun.jsonl), not here.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (
        collisions_eq45,
        fig2_scaling,
        kernels_tpu,
        table1_scan,
        table2_speedup,
        table3_resources,
        table4_identifiers,
    )

    modules = [
        ("table1", table1_scan),
        ("table2", table2_speedup),
        ("table3", table3_resources),
        ("table4", table4_identifiers),
        ("eq45", collisions_eq45),
        ("fig2", fig2_scaling),
        ("kernels", kernels_tpu),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.perf_counter()
        try:
            for line in mod.run():
                print(line, flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name}.ERROR,0,{type(e).__name__}: {e}", flush=True)
        print(
            f"{name}.total,{(time.perf_counter()-t0)*1e6:.0f},",
            flush=True,
        )
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
