"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness convention) covering:

  Table I   — baseline scan throughput + Eq. 2/3 projection
  Table II  — baseline vs indexed speedup (740× headline)
  Table III — storage / RAM / disk-I/O-volume trade-offs
  Table IV  — hashed-key vs full-id identifier strategies
  Eq. 4/5   — collision counts vs birthday bound + §VI discovery/migration
  Fig. 2    — runtime scaling and baseline/index crossover
  extract   — serial vs pipelined extraction engine (+ record cache)
  service   — continuous-batching query service vs per-key probing
  serve     — decode-token continuous batching vs static LM batches
  kernels   — TPU-adapted hot-loop throughput (hash_mix, sorted_probe)

Corpus scale via REPRO_BENCH_FILES / REPRO_BENCH_RPF env vars, or
``--scale N`` (→ REPRO_BENCH_SCALE) to multiply records-per-file 10-100x
so span-backend and depth effects separate from fixed overheads.
Roofline numbers come from the dry-run (results/dryrun.jsonl), not here.

The extraction-engine, service, similarity, and LM-serving modules
additionally emit machine-readable metrics (``BENCH_extract.json`` /
``BENCH_service.json`` / ``BENCH_similarity.json`` /
``BENCH_serve.json``) so records/sec, cache hit rate, sustained
lookups/sec, tokens/sec, p50/p99 latency, and the batching speedups are
tracked across PRs.  The committed copies at the repo root are only
rewritten with ``--update-metrics`` (run it on a quiet box when
regenerating the tracked numbers); plain runs park their metrics in the
bench cache so a smoke pass never churns the committed files.
``REPRO_BENCH_EXTRACT_OUT`` / ``REPRO_BENCH_SERVICE_OUT`` /
``REPRO_BENCH_SIMILARITY_OUT`` / ``REPRO_BENCH_SERVE_OUT`` override the
destination outright.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path


def _write_metrics(
    metrics, env_var: str, default_name: str, tag: str, update: bool
) -> None:
    if not metrics:
        return
    out = os.environ.get(env_var)
    if out:
        path = Path(out)
    elif update:
        path = Path(__file__).resolve().parents[1] / default_name
    else:
        from .common import CACHE

        CACHE.mkdir(parents=True, exist_ok=True)
        path = CACHE / default_name
    path.write_text(json.dumps(metrics, indent=1, sort_keys=True) + "\n")
    print(f"{tag}.metrics_written,0,{path}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--scale", type=int, default=None, metavar="N",
        help="multiply records-per-file by N (10-100x separates backend "
             "and depth effects; exported as REPRO_BENCH_SCALE)")
    ap.add_argument(
        "--update-metrics", action="store_true",
        help="rewrite the committed BENCH_*.json files at the repo root; "
             "without it metrics land in the bench cache (env overrides "
             "such as REPRO_BENCH_EXTRACT_OUT always win)")
    args = ap.parse_args()
    if args.scale is not None:
        # must land in the env before the bench modules import common.py
        os.environ["REPRO_BENCH_SCALE"] = str(args.scale)
    from . import (
        collisions_eq45,
        extract_engine,
        fig2_scaling,
        kernels_tpu,
        serve_tokens,
        service_load,
        similarity,
        table1_scan,
        table2_speedup,
        table3_resources,
        table4_identifiers,
    )

    modules = [
        ("table1", table1_scan),
        ("table2", table2_speedup),
        ("table3", table3_resources),
        ("table4", table4_identifiers),
        ("eq45", collisions_eq45),
        ("fig2", fig2_scaling),
        ("extract", extract_engine),
        ("service", service_load),
        ("serve", serve_tokens),
        ("similarity", similarity),
        ("kernels", kernels_tpu),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.perf_counter()
        try:
            for line in mod.run():
                print(line, flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name}.ERROR,0,{type(e).__name__}: {e}", flush=True)
        print(
            f"{name}.total,{(time.perf_counter()-t0)*1e6:.0f},",
            flush=True,
        )
    _write_metrics(extract_engine.last_metrics(),
                   "REPRO_BENCH_EXTRACT_OUT", "BENCH_extract.json",
                   "extract", args.update_metrics)
    _write_metrics(service_load.last_metrics(),
                   "REPRO_BENCH_SERVICE_OUT", "BENCH_service.json",
                   "service", args.update_metrics)
    _write_metrics(similarity.last_metrics(),
                   "REPRO_BENCH_SIMILARITY_OUT", "BENCH_similarity.json",
                   "similarity", args.update_metrics)
    _write_metrics(serve_tokens.last_metrics(),
                   "REPRO_BENCH_SERVE_OUT", "BENCH_serve.json",
                   "serve", args.update_metrics)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
