"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness convention) covering:

  Table I   — baseline scan throughput + Eq. 2/3 projection
  Table II  — baseline vs indexed speedup (740× headline)
  Table III — storage / RAM / disk-I/O-volume trade-offs
  Table IV  — hashed-key vs full-id identifier strategies
  Eq. 4/5   — collision counts vs birthday bound + §VI discovery/migration
  Fig. 2    — runtime scaling and baseline/index crossover
  extract   — serial vs pipelined extraction engine (+ record cache)
  kernels   — TPU-adapted hot-loop throughput (hash_mix, sorted_probe)

Corpus scale via REPRO_BENCH_FILES / REPRO_BENCH_RPF env vars.
Roofline numbers come from the dry-run (results/dryrun.jsonl), not here.

The extraction-engine module additionally emits machine-readable metrics
to ``BENCH_extract.json`` at the repo root (override the path with
``REPRO_BENCH_EXTRACT_OUT``) so records/sec, spans/record, cache hit rate
and the serial→pipelined speedup are tracked across PRs.
"""

from __future__ import annotations

import json
import os
import sys
import time
from pathlib import Path


def _write_extract_metrics(metrics) -> None:
    if not metrics:
        return
    out = os.environ.get("REPRO_BENCH_EXTRACT_OUT")
    path = Path(out) if out else Path(__file__).resolve().parents[1] / "BENCH_extract.json"
    path.write_text(json.dumps(metrics, indent=1, sort_keys=True) + "\n")
    print(f"extract.metrics_written,0,{path}", flush=True)


def main() -> None:
    from . import (
        collisions_eq45,
        extract_engine,
        fig2_scaling,
        kernels_tpu,
        table1_scan,
        table2_speedup,
        table3_resources,
        table4_identifiers,
    )

    modules = [
        ("table1", table1_scan),
        ("table2", table2_speedup),
        ("table3", table3_resources),
        ("table4", table4_identifiers),
        ("eq45", collisions_eq45),
        ("fig2", fig2_scaling),
        ("extract", extract_engine),
        ("kernels", kernels_tpu),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.perf_counter()
        try:
            for line in mod.run():
                print(line, flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name}.ERROR,0,{type(e).__name__}: {e}", flush=True)
        print(
            f"{name}.total,{(time.perf_counter()-t0)*1e6:.0f},",
            flush=True,
        )
    _write_extract_metrics(extract_engine.last_metrics())
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
