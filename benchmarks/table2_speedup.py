"""Table II — baseline vs index-based extraction (the 740× headline).

Measured end-to-end at benchmark scale: naïve scan (Algorithm 1, both the
paper's list-membership variant and the set fix), index construction
(Algorithm 2), initial extraction and re-extraction (Algorithm 3, no
rebuild).  Paper-scale speedup is then projected through the validated
complexity model (the paper's own Eq. 2/3 methodology): at N=477,123
targets the projected naïve runtime is months while index+extract stays
at hours — the 740× figure falls out of the same arithmetic.
"""

from __future__ import annotations

import tempfile
from pathlib import Path
from typing import List

from repro.core.baseline import estimate_runtime, naive_scan
from repro.core.extract import extract
from repro.core.index import build_index
from repro.core.sdfgen import db_id_list
from repro.core.intersect import intersect_host
from repro.core.store import IndexStore

from .common import (
    PAPER_N_FILES,
    PAPER_N_TARGETS,
    PAPER_RECORDS_PER_FILE,
    bench_store,
    row,
    timeit,
)


def run() -> List[str]:
    store, spec = bench_store()
    out = []

    # targets = ChEMBL∩eMolecules role (with ids absent from "pubchem")
    b = db_id_list(spec, "chembl", extra_outside=25)
    c = db_id_list(spec, "emolecules", extra_outside=25)
    inter = intersect_host(b, c)
    targets = inter.ids
    out.append(row("table2.chembl_x_emolecules", inter.seconds,
                   f"{inter.count} targets (paper: 477,123 in 2.5 h)"))

    t_list, res_list = timeit(lambda: naive_scan(store, targets, "list"))
    out.append(row("table2.baseline_list_scan", t_list,
                   f"found {len(res_list.records)}; {res_list.comparisons:.2e} cmps"))
    t_set, res_set = timeit(lambda: naive_scan(store, targets, "set"))
    out.append(row("table2.baseline_set_scan", t_set,
                   f"found {len(res_set.records)}"))

    t_idx, idx = timeit(lambda: build_index(store, key_mode="full_id"))
    out.append(row("table2.index_construction", t_idx,
                   f"{len(idx)} entries (paper: 11.7 h once)"))

    t_ex1, res1 = timeit(lambda: extract(store, idx, targets))
    out.append(row("table2.initial_extraction", t_ex1,
                   f"found {res1.found}, missing {len(res1.missing)} "
                   f"(paper: 3.2 h, 435,413 found; pipelined engine, "
                   f"{res1.spans_read} spans, plan/read "
                   f"{res1.plan_seconds*1e3:.1f}/{res1.read_seconds*1e3:.1f} ms)"))

    # read-path ablation: the same plan through the serial reference loop
    t_ser, res_ser = timeit(lambda: extract(store, idx, targets, workers=0))
    parity = (list(res_ser.records.items()) == list(res1.records.items())
              and res_ser.missing == res1.missing)
    out.append(row("table2.serial_read_ablation", t_ser,
                   f"workers=0 per-line loop; pipelined is "
                   f"{t_ser/max(t_ex1, 1e-9):.1f}x faster, parity="
                   f"{'ok' if parity else 'BROKEN'}"))

    # re-extraction with modified criteria — no index rebuild
    targets2 = targets[: max(1, len(targets) * 9 // 10)]
    t_ex2, res2 = timeit(lambda: extract(store, idx, targets2))
    out.append(row("table2.re_extraction", t_ex2,
                   f"found {res2.found} (paper: 2.8 h, no rebuild)"))

    # sharded-store variant: same Algorithm 3, batched lookups through the
    # mmap-backed IndexStore instead of the resident dict
    with tempfile.TemporaryDirectory() as td:
        t_pub, _ = timeit(lambda: idx.save_sharded(Path(td) / "store", n_shards=8))
        qs = IndexStore.open(Path(td) / "store")
        t_ex3, res3 = timeit(lambda: extract(store, qs, targets))
        out.append(row(
            "table2.sharded_store_extraction", t_ex3,
            f"found {res3.found} via lookup_batch over {qs.n_shards} shards "
            f"(publish {t_pub:.2f}s; dict extraction {t_ex1:.2f}s)"))

    sp1 = t_list / t_ex1 if t_ex1 > 0 else float("inf")
    out.append(row("table2.measured_speedup", 0.0,
                   f"{sp1:.0f}x at N={len(targets)} (list-baseline / extraction)"))

    # paper-scale projection through the complexity model.  Naive time uses
    # the measured *comparison* rate (see table1 note); extraction time uses
    # the paper's own per-target seek cost (3.2 h / 477k ≈ 24 ms on cold
    # HDD) alongside our measured per-target cost (page-cached SSD).
    cmp_rate = res_list.comparisons / max(t_list, 1e-9)
    ops, _ = estimate_runtime(
        PAPER_N_TARGETS, PAPER_N_FILES, PAPER_RECORDS_PER_FILE, cmp_rate, "list"
    )
    t_naive_paper = ops / cmp_rate
    per_target = t_ex1 / max(res1.found, 1)
    t_extract_paper = per_target * PAPER_N_TARGETS
    out.append(row(
        "table2.paper_scale_projection", 0.0,
        f"naive {t_naive_paper/86400:.0f} d vs extract "
        f"{t_extract_paper/3600:.2f} h (our per-target {per_target*1e3:.2f} ms, "
        f"page-cached; paper 24 ms cold-HDD → 3.2 h) → "
        f"{t_naive_paper/max(t_extract_paper,1e-9):.0f}x vs paper 740x",
    ))
    return out
