"""Table IV — identifier strategy comparison: hashed key vs full id.

The §VI.C migration quantified: average key length, collision guarantee,
index size (CSV on disk), in-memory size, and lookup latency for the
27-char hashed key (InChIKey role) vs the full canonical id (full-InChI
role).  The paper accepted +27 % storage and +50 % lookup latency for
deterministic uniqueness; we measure the same columns.
"""

from __future__ import annotations

import statistics
import sys
import tempfile
import time
from pathlib import Path
from typing import List

from repro.core.identifiers import hashed_key
from repro.core.index import build_index
from repro.core.sdfgen import db_id_list

from .common import bench_store, row, timeit


def _ram(idx) -> int:
    total = sys.getsizeof(idx.entries)
    for k, v in idx.entries.items():
        total += sys.getsizeof(k) + sys.getsizeof(v[0]) + sys.getsizeof(v[1]) + 64
    return total


def _lookup_latency(idx, keys, repeats: int = 5) -> float:
    t0 = time.perf_counter()
    n = 0
    for _ in range(repeats):
        for k in keys:
            # fresh string objects: defeat CPython's per-object hash cache so
            # the measured cost includes hashing the key (the paper's 0.8 vs
            # 1.2 µs difference is exactly the key-length hashing cost)
            idx.lookup(str(bytes(k, "ascii"), "ascii"))
            n += 1
    return (time.perf_counter() - t0) / n


def run() -> List[str]:
    store, spec = bench_store()
    out = []
    ids = db_id_list(spec, "chembl")
    sample = ids[:2000]

    results = {}
    for mode in ("hashed_key", "full_id"):
        t_build, idx = timeit(lambda m=mode: build_index(store, key_mode=m))
        with tempfile.TemporaryDirectory() as td:
            size = idx.save_csv(Path(td) / "ix.csv")
        keys = (
            [hashed_key(i, spec.key_bits) for i in sample]
            if mode == "hashed_key"
            else sample
        )
        lat = _lookup_latency(idx, keys)
        keylen = statistics.mean(
            len(k) for k in list(idx.entries.keys())[:1000]
        )
        results[mode] = (size, _ram(idx), lat, keylen)
        out.append(row(
            f"table4.{mode}", lat,
            f"keylen {keylen:.0f} ch; index {size/1e6:.2f} MB; "
            f"ram {_ram(idx)/1e6:.1f} MB; build {t_build:.2f} s",
        ))

    hs, hr, hl, hk = results["hashed_key"]
    fs, fr, fl, fk = results["full_id"]
    out.append(row(
        "table4.overhead_full_vs_hashed", 0.0,
        f"index +{(fs/hs-1)*100:.0f}% (paper +27%); "
        f"ram +{(fr/hr-1)*100:.0f}%; lookup {fl/hl:.2f}x "
        f"(paper 1.5x: 1.2 vs 0.8 µs); "
        f"guarantee: deterministic vs probabilistic",
    ))
    return out
