"""Device-path throughput: hash_mix digesting and sorted_probe membership.

These are the TPU adaptations of the paper's hot loops (DESIGN.md §2),
measured here on the XLA reference path (CPU container; on TPU the Pallas
kernels take over).  Derived column reports ids/s so the number is
directly comparable to the paper's host-side rates (3,243 mol/s naïve
scan; ~1e6/s dict lookups).
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.identifiers import canonical_id, molecule_from_cid
from repro.core.packing import pack_ids
from repro.kernels.hash_mix.ops import hash_mix
from repro.kernels.sorted_probe.ops import sorted_probe
from repro.kernels.sorted_probe.ref import sort_pairs

from .common import row, timeit


def run() -> List[str]:
    out = []
    n = 20_000
    ids = [canonical_id(molecule_from_cid(c)) for c in range(n)]
    packed = jnp.asarray(pack_ids(ids))

    d = hash_mix(packed)  # compile
    t, _ = timeit(lambda: hash_mix(packed).block_until_ready(), repeats=3)
    out.append(row("kernels.hash_mix", t, f"{n/t:.0f} ids/s (XLA path)"))

    table = jnp.asarray(np.asarray(d[:, :2]))
    table_sorted, _ = sort_pairs(table)
    queries = table[: n // 2]
    f, p = sorted_probe(queries, table_sorted)  # compile
    t, _ = timeit(
        lambda: sorted_probe(queries, table_sorted)[0].block_until_ready(),
        repeats=3,
    )
    out.append(row(
        "kernels.sorted_probe", t,
        f"{queries.shape[0]/t:.0f} lookups/s over {n}-entry table "
        f"(paper dict: ~1.2 µs/lookup)",
    ))
    return out
