"""Table III — system resource requirements: storage, RAM, disk I/O volume.

The paper's deepest point: the index wins primarily on **I/O volume**
(168.9 TB of repeated scans → one 177 MB targeted read pass; −99.7%), at
the cost of RAM (index resident: 2× raw CSV size from dict overhead) and
+0.44% persistent storage.  All three are measured here at benchmark scale
and compared against the paper's figures.
"""

from __future__ import annotations

import sys
import tempfile
from pathlib import Path
from typing import List

from repro.core.baseline import naive_scan
from repro.core.extract import extract
from repro.core.index import build_index
from repro.core.intersect import intersect_host
from repro.core.sdfgen import db_id_list

from .common import bench_store, row, timeit


def _index_ram_bytes(idx) -> int:
    """Approximate resident size of the in-memory index dict."""
    total = sys.getsizeof(idx.entries)
    for k, (f, o) in idx.entries.items():
        total += sys.getsizeof(k) + sys.getsizeof(f) + sys.getsizeof(o) + 64
    return total


def run() -> List[str]:
    store, spec = bench_store()
    out = []
    corpus_bytes = store.total_bytes()

    b = db_id_list(spec, "chembl", extra_outside=25)
    c = db_id_list(spec, "emolecules", extra_outside=25)
    targets = intersect_host(b, c).ids

    # baseline I/O volume: bytes scanned by the naive pass
    _, res_list = timeit(lambda: naive_scan(store, targets, "set"))
    baseline_io = res_list.bytes_scanned

    idx = build_index(store, key_mode="full_id")
    with tempfile.TemporaryDirectory() as td:
        csv_path = Path(td) / "index.csv"
        csv_bytes = idx.save_csv(csv_path)
    ram_bytes = _index_ram_bytes(idx)

    _, res = timeit(lambda: extract(store, idx, targets))
    indexed_io = res.bytes_read

    avg_rec = corpus_bytes / max(len(idx), 1)
    out.append(row("table3.persistent_storage", 0.0,
                   f"corpus {corpus_bytes/1e6:.1f} MB + index "
                   f"{csv_bytes/1e6:.2f} MB = +{csv_bytes/corpus_bytes*100:.2f}% "
                   f"(paper: +0.44%; ratio scales as id_len/record_len — "
                   f"our records avg {avg_rec:.0f} B vs paper ~18 kB)"))
    out.append(row("table3.peak_ram", 0.0,
                   f"index resident {ram_bytes/1e6:.1f} MB "
                   f"= {ram_bytes/max(csv_bytes,1):.1f}x raw CSV "
                   f"(paper: 28.3 GB ≈ 2x 14 GB)"))
    out.append(row("table3.disk_io_volume", 0.0,
                   f"baseline {baseline_io/1e6:.1f} MB scanned vs indexed "
                   f"{indexed_io/1e6:.3f} MB read "
                   f"= -{(1 - indexed_io/max(baseline_io,1))*100:.2f}% "
                   f"(paper: -99.7%); note baseline here is ONE set-scan — "
                   f"the paper's figure multiplies by re-extraction count"))
    return out
