"""Table III — system resource requirements: storage, RAM, disk I/O volume.

The paper's deepest point: the index wins primarily on **I/O volume**
(168.9 TB of repeated scans → one 177 MB targeted read pass; −99.7%), at
the cost of RAM (index resident: 2× raw CSV size from dict overhead) and
+0.44% persistent storage.  All three are measured here at benchmark scale
and compared against the paper's figures.

Beyond-paper rows measure the same trade-off for the two packed serving
formats: the monolithic binary sidecar (``BinaryIndex``) and the sharded
mmap-backed ``IndexStore`` — storage (including Bloom sidecars), resident
RAM after serving a query batch, and lookup throughput — so the cost of
sharding + Bloom prefiltering is measured, not asserted.
"""

from __future__ import annotations

import sys
import tempfile
from pathlib import Path
from typing import List

from repro.core.baseline import naive_scan
from repro.core.extract import extract
from repro.core.index import BinaryIndex, build_index
from repro.core.intersect import intersect_host
from repro.core.sdfgen import db_id_list
from repro.core.store import IndexStore

from .common import bench_store, row, timeit


def _index_ram_bytes(idx) -> int:
    """Approximate resident size of the in-memory index dict."""
    total = sys.getsizeof(idx.entries)
    for k, (f, o) in idx.entries.items():
        total += sys.getsizeof(k) + sys.getsizeof(f) + sys.getsizeof(o) + 64
    return total


def run() -> List[str]:
    store, spec = bench_store()
    out = []
    corpus_bytes = store.total_bytes()

    b = db_id_list(spec, "chembl", extra_outside=25)
    c = db_id_list(spec, "emolecules", extra_outside=25)
    targets = intersect_host(b, c).ids

    # baseline I/O volume: bytes scanned by the naive pass
    _, res_list = timeit(lambda: naive_scan(store, targets, "set"))
    baseline_io = res_list.bytes_scanned

    idx = build_index(store, key_mode="full_id")
    with tempfile.TemporaryDirectory() as td:
        csv_path = Path(td) / "index.csv"
        csv_bytes = idx.save_csv(csv_path)
    ram_bytes = _index_ram_bytes(idx)

    # workers=0: the serial path's bytes_read counts exactly the record
    # text fetched (the paper's targeted-read volume); the engine's count
    # includes coalescing overshoot, reported separately below
    _, res = timeit(lambda: extract(store, idx, targets, workers=0))
    indexed_io = res.bytes_read

    avg_rec = corpus_bytes / max(len(idx), 1)
    out.append(row("table3.persistent_storage", 0.0,
                   f"corpus {corpus_bytes/1e6:.1f} MB + index "
                   f"{csv_bytes/1e6:.2f} MB = +{csv_bytes/corpus_bytes*100:.2f}% "
                   f"(paper: +0.44%; ratio scales as id_len/record_len — "
                   f"our records avg {avg_rec:.0f} B vs paper ~18 kB)"))
    out.append(row("table3.peak_ram", 0.0,
                   f"index resident {ram_bytes/1e6:.1f} MB "
                   f"= {ram_bytes/max(csv_bytes,1):.1f}x raw CSV "
                   f"(paper: 28.3 GB ≈ 2x 14 GB)"))
    out.append(row("table3.disk_io_volume", 0.0,
                   f"baseline {baseline_io/1e6:.1f} MB scanned vs indexed "
                   f"{indexed_io/1e6:.3f} MB read "
                   f"= -{(1 - indexed_io/max(baseline_io,1))*100:.2f}% "
                   f"(paper: -99.7%); note baseline here is ONE set-scan — "
                   f"the paper's figure multiplies by re-extraction count"))

    # the pipelined engine trades bounded read amplification (span guess +
    # gap bridging) for far fewer syscalls — measure the trade, don't
    # assert it
    _, res_eng = timeit(lambda: extract(store, idx, targets))
    out.append(row("table3.engine_read_amplification", 0.0,
                   f"engine pread {res_eng.bytes_read/1e6:.3f} MB over "
                   f"{res_eng.spans_read} spans for {res_eng.seeks} records "
                   f"({res_eng.bytes_read/max(indexed_io,1):.1f}x record "
                   f"bytes, {res_eng.seeks/max(res_eng.spans_read,1):.1f} "
                   f"records/span)"))

    # ---- packed serving formats: monolithic binary vs sharded store --------
    # query batch = every target, plus misses (the common case in serving)
    queries = targets + [t + "/absent" for t in targets[:max(1, len(targets) // 4)]]
    with tempfile.TemporaryDirectory() as td:
        bin_path, bin_bytes = idx.save_binary(Path(td) / "index.npz")
        bx = BinaryIndex(bin_path)
        bin_ram = sum(a.nbytes for a in (bx.digests, bx.file_ids, bx.offsets))
        bin_ram += sum(sys.getsizeof(k) for k in bx.keys)
        t_bin, _ = timeit(lambda: [bx.lookup(k) for k in queries])
        out.append(row(
            "table3.binary_sidecar", t_bin,
            f"storage {bin_bytes/1e6:.2f} MB, resident {bin_ram/1e6:.2f} MB "
            f"(all columns), {len(queries)/max(t_bin, 1e-9):.0f} lookups/s "
            f"per-key"))

        idx.save_sharded(Path(td) / "store", n_shards=8)
        qs = IndexStore.open(Path(td) / "store")
        qs.lookup_batch(queries)  # warm: fault shards in (open cost, not serving)
        rejects0 = qs.stats.bloom_rejects
        t_shard, _ = timeit(lambda: qs.lookup_batch(queries))
        out.append(row(
            "table3.sharded_store", t_shard,
            f"storage {qs.total_bytes()/1e6:.2f} MB (+bloom sidecars), "
            f"resident {qs.resident_bytes()/1e6:.2f} MB after the batch "
            f"({qs.shards_loaded}/{qs.n_shards} shards mmap'd), "
            f"{len(queries)/max(t_shard, 1e-9):.0f} lookups/s batched, "
            f"{qs.stats.bloom_rejects - rejects0}/{len(queries)} bloom-rejected"))
    return out
