"""Table I — baseline scan throughput on representative files.

The paper scanned 3 representative PubChem files (smallest/median/largest)
and found throughput constant across sizes (CoV 4.7%), validating that the
naïve algorithm's cost is linear in bytes and the bottleneck algorithmic.
We reproduce the measurement and the CoV check, then project Eq. 2/3.
"""

from __future__ import annotations

import statistics
from typing import List

from repro.core.baseline import estimate_runtime, measure_scan_throughput

from .common import (
    PAPER_N_FILES,
    PAPER_N_TARGETS,
    PAPER_RECORDS_PER_FILE,
    bench_store,
    row,
)


def run() -> List[str]:
    store, spec = bench_store()
    samples = measure_scan_throughput(store, n_files=3)
    out = []
    rates = []
    for s in samples:
        rates.append(s.records_per_second)
        out.append(
            row(
                f"table1.scan[{s.file}]",
                s.seconds,
                f"{s.records_per_second:.0f} mol/s; {s.file_bytes/1e6:.1f} MB",
            )
        )
    mean_rate = statistics.mean(rates)
    cov = statistics.pstdev(rates) / mean_rate if mean_rate else 0.0
    out.append(
        row("table1.mean", statistics.mean(s.seconds for s in samples),
            f"{mean_rate:.0f} mol/s mean; CoV {cov*100:.1f}% (paper: 4.7%)")
    )
    # Eq. 2/3: project paper-scale brute force.  The paper's op count is
    # N×M×S *comparisons*; dividing it by the measured *comparison* rate
    # (list-membership tests/s from a short Algorithm-1 run) reproduces the
    # 100-day order.  (Reproduction note, EXPERIMENTS.md: Eq. 3 as printed
    # divides 8.4e13 by 3,200·3,600 which yields 7.3e6 hours, not 7,291 —
    # the comparison-rate reading is the self-consistent one.)
    from repro.core.baseline import naive_scan
    from repro.core.sdfgen import db_id_list

    targets = db_id_list(spec, "chembl")[:300]
    res = naive_scan(store, targets, "list", max_files=1)
    cmp_rate = res.comparisons / max(res.seconds, 1e-9)
    ops, _ = estimate_runtime(
        PAPER_N_TARGETS, PAPER_N_FILES, PAPER_RECORDS_PER_FILE, cmp_rate, "list"
    )
    secs = ops / cmp_rate
    out.append(
        row(
            "table1.eq2_eq3_projection",
            secs,
            f"{ops:.3e} cmps at {cmp_rate:.2e} cmp/s → {secs/86400:.0f} days "
            f"(paper: 8.4e13 ops, 100+ days / 4–6 months practical)",
        )
    )
    return out
