"""§VI / Eq. 4–5 — hashed-key collision discovery vs the birthday bound.

The paper found 163 colliding InChIKeys among 176.9 M entries (~10× the
n²/2h ≈ 15.7 expectation) and migrated to full InChI.  At benchmark scale
(n ≈ 3.2e4) the paper's 50-bit key space yields E ≈ 0 collisions — as
theory demands — so we sweep the effective key width downward and verify
measured collision counts track the birthday bound, which is the same
validation the paper ran at fixed h with 5,500× our n.  The sweep also
exercises the *discovery machinery* end-to-end: Algorithm 3's defensive
verification catches the collisions as extraction mismatches.
"""

from __future__ import annotations

from typing import List

from repro.core.baseline import naive_scan
from repro.core.collisions import birthday_expectation, scan_corpus
from repro.core.extract import extract
from repro.core.index import build_index
from repro.core.sdfgen import db_id_list

from .common import bench_store, row, timeit

KEY_BITS_SWEEP = (16, 20, 24, 28, 50)


def run() -> List[str]:
    store, spec = bench_store()
    out = []
    for bits in KEY_BITS_SWEEP:
        t, rep = timeit(lambda b=bits: scan_corpus(store, key_bits=b))
        e = birthday_expectation(rep.n_records, bits)
        out.append(row(
            f"eq45.scan[{bits}b]", t,
            f"{rep.n_colliding_keys} colliding keys / "
            f"{rep.n_affected_records} records; E[n²/2h]={e:.2f}; "
            f"rate {rep.empirical_rate:.2e}",
        ))

    # end-to-end discovery: hashed-key pipeline at a collision-prone width
    bits = 24
    store24, spec24 = bench_store(key_bits=bits)
    idx = build_index(store24, key_mode="hashed_key", key_bits=bits)
    targets = db_id_list(spec24, "chembl")
    t_ex, res = timeit(lambda: extract(store24, idx, targets, key_bits=bits))
    out.append(row(
        "eq45.verification_catches", t_ex,
        f"extract found {res.found}, verification mismatches "
        f"{len(res.mismatches)} (the paper's §VI.A discovery path); "
        f"index shadowed keys {idx.stats.n_duplicate_keys}",
    ))

    # migration: full-id pipeline has zero mismatches by construction
    idx_full = build_index(store24, key_mode="full_id")
    t_fx, res_full = timeit(lambda: extract(store24, idx_full, targets))
    out.append(row(
        "eq45.migration_full_id", t_fx,
        f"found {res_full.found}, mismatches {len(res_full.mismatches)} "
        f"(deterministic uniqueness — paper §VI.C)",
    ))
    return out
