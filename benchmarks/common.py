"""Shared benchmark infrastructure: cached corpus + timing helpers.

The benchmark corpus is a scale model of the paper's (354 files × 500k
records, 3.2 TB): N_FILES × RECORDS_PER_FILE synthetic SDF records
(~tens of MB).  Every benchmark reports its measured value AND, where the
paper's complexity model applies, the projection to paper scale —
reproducing how the paper itself extrapolated (Eq. 2/3 project the
100-day baseline from 3 scanned files).

Output convention (benchmarks/run.py): ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import os
import time
from pathlib import Path
from typing import Callable, Optional, Tuple

from repro.core.records import RecordStore
from repro.core.sdfgen import CorpusSpec, generate_corpus

# paper-scale constants (§III)
PAPER_N_FILES = 354
PAPER_RECORDS_PER_FILE = 500_000
PAPER_N_RECORDS = 176_929_690
PAPER_N_TARGETS = 477_123
PAPER_FOUND = 435_413
PAPER_FINAL = 426_850

# REPRO_BENCH_SCALE multiplies records-per-file (``run.py --scale``): the
# stock corpus fits in one coalesce window per file, so backend and depth
# effects only separate once the corpus is 10-100x deeper.
BENCH_SCALE = int(os.environ.get("REPRO_BENCH_SCALE", "1"))
BENCH_FILES = int(os.environ.get("REPRO_BENCH_FILES", "8"))
BENCH_RPF = int(os.environ.get("REPRO_BENCH_RPF", "4000")) * BENCH_SCALE
CACHE = Path(os.environ.get("REPRO_BENCH_CACHE", "/root/repo/.bench_cache"))


def bench_spec(key_bits: int = 64) -> CorpusSpec:
    return CorpusSpec(
        n_files=BENCH_FILES, records_per_file=BENCH_RPF, key_bits=key_bits
    )


def bench_store(key_bits: int = 64) -> Tuple[RecordStore, CorpusSpec]:
    spec = bench_spec(key_bits)
    root = CACHE / f"corpus_{spec.n_files}x{spec.records_per_file}_{key_bits}"
    generate_corpus(root, spec)
    return RecordStore(root), spec


def timeit(fn: Callable, repeats: int = 1) -> Tuple[float, object]:
    """(seconds_per_call, last_result)."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn()
    return (time.perf_counter() - t0) / repeats, out


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
