"""Fig. 2 — runtime scaling vs target count; baseline/index crossover.

Measures naïve (list) scan and index-based extraction at a sweep of target
counts, fits the complexity model, and solves for the crossover (the paper
puts it at ~400k targets single-shot, ~200k with two extractions at their
scale — ours lands where the model says it should for our corpus size).
"""

from __future__ import annotations

from typing import List

from repro.core.baseline import naive_scan
from repro.core.extract import extract
from repro.core.index import build_index
from repro.core.sdfgen import db_id_list

from .common import bench_store, row, timeit


TARGET_SWEEP = (5, 20, 80, 320)


def run() -> List[str]:
    store, spec = bench_store()
    out = []
    pool = db_id_list(spec, "chembl")
    idx = None
    t_build = 0.0

    naive_pts = []
    indexed_pts = []
    for n in TARGET_SWEEP:
        targets = pool[:n]
        t_naive, _ = timeit(lambda: naive_scan(store, targets, "list"))
        if idx is None:
            t_build, idx = timeit(lambda: build_index(store, key_mode="full_id"))
        t_ex, _ = timeit(lambda: extract(store, idx, targets))
        naive_pts.append((n, t_naive))
        indexed_pts.append((n, t_ex))
        out.append(row(
            f"fig2.naive[N={n}]", t_naive, f"{t_naive:.3f} s"
        ))
        out.append(row(
            f"fig2.indexed[N={n}]", t_ex,
            f"{t_ex:.3f} s (+ one-time build {t_build:.2f} s)"
        ))

    # linear fits: naive t ≈ a + b·N (list membership grows with N);
    # indexed t ≈ c + d·N.  Crossover where build + c + dN = a + bN.
    def fit(pts):
        n_ = [p[0] for p in pts]
        t_ = [p[1] for p in pts]
        nbar = sum(n_) / len(n_)
        tbar = sum(t_) / len(t_)
        b = sum((x - nbar) * (y - tbar) for x, y in pts) / max(
            sum((x - nbar) ** 2 for x in n_), 1e-12
        )
        return tbar - b * nbar, b

    a0, b0 = fit(naive_pts)
    c0, d0 = fit(indexed_pts)
    if b0 > d0:
        crossover = (t_build + c0 - a0) / (b0 - d0)
        msg = (
            f"crossover N* ≈ {crossover:.0f} targets at this corpus size "
            f"(single extraction; paper: ~400k at 177M records); "
            f"two extractions halve it (paper: ~200k)"
        )
    else:
        msg = "no crossover in range (indexed dominated)"
    out.append(row("fig2.crossover", 0.0, msg))
    return out
