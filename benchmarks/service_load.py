"""Query-service load benchmark — continuous batching vs per-key probing.

Closed-loop clients (>= 8, each one outstanding request at a time) drive
two architectures over the same published sharded store:

* ``service.naive``   — each client serves its request with per-request
  ``lookup_batch`` calls at batch size 1 (the pre-service per-key
  contract: one probe per key, no cross-caller coalescing);
* ``service.batched`` — each client submits to the ``QueryService``,
  whose continuous micro-batching scheduler re-coalesces the concurrent
  cohort into the big batched probes the ``IndexStore`` is built for.

Both paths are measured with multi-key requests (the shape of a real
integration query — a handful of related records per request) AND with
single-key requests (``single_key`` rows), where every coalescing gain
must come from cross-client batching alone.

Byte-identical record parity of the service's ``fetch`` against the
direct serial ``extract`` reference is asserted before any throughput is
reported, and a warm second fetch measures the shared scan-resistant
record cache.  ``benchmarks/run.py`` writes :func:`last_metrics` to
``BENCH_service.json``.
"""

from __future__ import annotations

import os
from pathlib import Path
from typing import Dict, List, Optional

from repro.core import IndexStore, build_index, extract
from repro.core.index import ByteOffsetIndex
from repro.core.intersect import intersect_host
from repro.core.sdfgen import db_id_list
from repro.service import QueryService, ServiceConfig, run_closed_loop

from .common import CACHE, bench_store, row

CLIENTS = int(os.environ.get("REPRO_BENCH_SERVICE_CLIENTS", "8"))
KEYS_PER_REQUEST = 4
DURATION_S = float(os.environ.get("REPRO_BENCH_SERVICE_SECONDS", "1.2"))
N_SHARDS = 16
REPLICAS = 2

_LAST: Optional[Dict[str, object]] = None


def last_metrics() -> Optional[Dict[str, object]]:
    """Metrics of the most recent :func:`run` (for BENCH_service.json)."""
    return _LAST


def _report(rep) -> Dict[str, float]:
    return {
        "clients": rep.clients,
        "requests": rep.requests,
        "lookups_per_sec": rep.lookups_per_sec,
        "p50_ms": rep.p50_ms,
        "p99_ms": rep.p99_ms,
        # failed = raised to the client; degraded = served partial results
        # (any nonzero here on a fault-free run means the gate is broken)
        "failed": rep.failed,
        "degraded": rep.degraded,
        "errors": rep.errors,
    }


def run() -> List[str]:
    global _LAST
    store, spec = bench_store()
    out = []

    idx = build_index(store, key_mode="full_id")
    store_dir = CACHE / (
        f"store_{spec.n_files}x{spec.records_per_file}_{N_SHARDS}"
    )
    idx.save_sharded(store_dir, n_shards=N_SHARDS)
    keys = sorted(idx.entries.keys())

    targets = intersect_host(
        db_id_list(spec, "chembl", extra_outside=25),
        db_id_list(spec, "emolecules", extra_outside=25),
    ).ids

    svc = QueryService(
        store, store_dir, ServiceConfig(replicas=REPLICAS, max_batch=512)
    )

    # -- parity gate: fetch through the whole service stack vs serial ------
    serial = extract(store, idx, targets, workers=0)
    res = svc.fetch(targets)
    parity = (
        list(res.records.items()) == list(serial.records.items())
        and res.missing == serial.missing
        and res.mismatches == serial.mismatches
    )
    out.append(row(
        "service.fetch_parity", 0.0,
        f"{res.found} records byte-identical={'ok' if parity else 'BROKEN'}"))
    warm = svc.fetch(targets)
    cache_hit_rate = warm.cache_hits / max(warm.seeks, 1)
    parity = parity and list(warm.records.items()) == list(serial.records.items())

    # -- naive baseline: per-request lookup_batch at batch size 1 ----------
    naive_store = IndexStore.open(store_dir)
    naive_store.lookup_batch(keys[: min(2000, len(keys))])  # warm mmaps

    def naive(ks):
        for k in ks:
            naive_store.lookup_batch([k])

    rep_naive = run_closed_loop(
        naive, keys, clients=CLIENTS, duration_s=DURATION_S,
        keys_per_request=KEYS_PER_REQUEST,
    )
    out.append(row(
        "service.naive", rep_naive.seconds,
        f"{rep_naive.lookups_per_sec:.0f} lookups/s, {CLIENTS} clients x "
        f"{KEYS_PER_REQUEST} keys/req, p50 {rep_naive.p50_ms:.2f} ms, "
        f"p99 {rep_naive.p99_ms:.2f} ms"))

    # -- service path: continuous micro-batching ---------------------------
    svc.lookup_batch(keys[: min(2000, len(keys))])  # warm the scheduler
    rep_svc = run_closed_loop(
        lambda ks: svc.lookup_batch(ks), keys, clients=CLIENTS,
        duration_s=DURATION_S, keys_per_request=KEYS_PER_REQUEST,
    )
    speedup = rep_svc.lookups_per_sec / max(rep_naive.lookups_per_sec, 1e-9)
    sch = svc.stats()["scheduler"]
    out.append(row(
        "service.batched", rep_svc.seconds,
        f"{rep_svc.lookups_per_sec:.0f} lookups/s ({speedup:.1f}x naive), "
        f"mean batch {sch['mean_batch_keys']:.1f} keys, "
        f"{sch['coalesced_batches']} coalesced batches, "
        f"p50 {rep_svc.p50_ms:.2f} ms, p99 {rep_svc.p99_ms:.2f} ms"))

    # -- single-key ablation: coalescing across clients only ---------------
    rep_naive1 = run_closed_loop(
        naive, keys, clients=CLIENTS, duration_s=DURATION_S / 2,
        keys_per_request=1,
    )
    rep_svc1 = run_closed_loop(
        lambda ks: svc.lookup_batch(ks), keys, clients=CLIENTS,
        duration_s=DURATION_S / 2, keys_per_request=1,
    )
    speedup1 = rep_svc1.lookups_per_sec / max(rep_naive1.lookups_per_sec, 1e-9)
    out.append(row(
        "service.single_key", rep_svc1.seconds,
        f"svc {rep_svc1.lookups_per_sec:.0f} vs naive "
        f"{rep_naive1.lookups_per_sec:.0f} lookups/s ({speedup1:.1f}x) at "
        f"1 key/request"))

    stats = svc.stats()
    sch = stats["scheduler"]
    _LAST = {
        "corpus": {
            "files": spec.n_files,
            "records_per_file": spec.records_per_file,
            "entries": len(keys),
            "n_shards": N_SHARDS,
        },
        "config": {
            "clients": CLIENTS,
            "keys_per_request": KEYS_PER_REQUEST,
            "replicas": REPLICAS,
            "max_batch": 512,
            "max_wait_ms": ServiceConfig().max_wait_ms,
            "duration_s": DURATION_S,
        },
        "naive": _report(rep_naive),
        "service": _report(rep_svc),
        "speedup_vs_naive": speedup,
        "single_key": {
            "naive": _report(rep_naive1),
            "service": _report(rep_svc1),
            "speedup_vs_naive": speedup1,
        },
        "mean_coalesced_batch": sch["mean_batch_keys"],
        "coalesced_batches": sch["coalesced_batches"],
        "flushes": {
            k: sch[k]
            for k in ("full_flushes", "cohort_flushes", "deadline_flushes",
                      "immediate_flushes")
        },
        "cache_hit_rate": cache_hit_rate,
        "bloom_rejects": stats["store"]["bloom_rejects"],
        "parity": bool(parity),
    }
    svc.close()
    return out
