"""Similarity-search benchmark — batched Tanimoto top-k vs per-query loop.

Three scorers over the same fingerprint plane (library level):

* ``similarity.naive_loop`` — :func:`tanimoto_topk_naive`, the
  pre-batching serving contract: one independent scoring pass per query,
  database popcounts recomputed on every call;
* ``similarity.reference``  — the chunked vectorized NumPy oracle
  (:func:`tanimoto_topk_ref`) with precomputed count sidecars;
* ``similarity.kernel``     — the :func:`tanimoto_topk` dispatcher's
  resolved backend: the Pallas popcount/top-k kernel on TPU, the
  L2-tiled uint64 host path elsewhere.

All three must produce byte-identical ``(scores, indices)`` — the
``parity`` flags gate the throughput numbers, and an interpret-mode
Pallas pass on a subsample keeps the kernel itself honest on CPU-only
boxes.  Then the full service path is driven by closed-loop clients:
per-query host probes (one fingerprint per ``similar_batch`` call)
against ``QueryService.similar`` riding the micro-batching scheduler.
``benchmarks/run.py`` writes :func:`last_metrics` to
``BENCH_similarity.json``.
"""

from __future__ import annotations

import os
from typing import Dict, List, Optional

import numpy as np

from repro.core import build_index
from repro.core.fingerprint import fingerprint_batch, popcount_u32
from repro.core.store import IndexStore
from repro.kernels.tanimoto.ops import tanimoto_topk
from repro.kernels.tanimoto.ref import tanimoto_topk_naive, tanimoto_topk_ref
from repro.service import QueryService, ServiceConfig, run_closed_loop

from .common import CACHE, bench_store, row, timeit

CLIENTS = int(os.environ.get("REPRO_BENCH_SIM_CLIENTS", "8"))
QUERIES_PER_REQUEST = 4
DURATION_S = float(os.environ.get("REPRO_BENCH_SIM_SECONDS", "1.2"))
N_QUERIES = int(os.environ.get("REPRO_BENCH_SIM_QUERIES", "64"))
N_SHARDS = 16
REPLICAS = 2
K = 8

_LAST: Optional[Dict[str, object]] = None


def last_metrics() -> Optional[Dict[str, object]]:
    """Metrics of the most recent :func:`run` (for BENCH_similarity.json)."""
    return _LAST


def _report(rep) -> Dict[str, float]:
    return {
        "clients": rep.clients,
        "requests": rep.requests,
        "queries_per_sec": rep.lookups_per_sec,
        "p50_ms": rep.p50_ms,
        "p99_ms": rep.p99_ms,
        "errors": rep.errors,
    }


def _equal(a, b) -> bool:
    return np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])


def run() -> List[str]:
    global _LAST
    store, spec = bench_store()
    out = []

    idx = build_index(store, key_mode="full_id")
    store_dir = CACHE / (
        f"store_{spec.n_files}x{spec.records_per_file}_{N_SHARDS}"
    )
    idx.save_sharded(store_dir, n_shards=N_SHARDS)
    keys = sorted(idx.entries.keys())

    # the same folding the published sidecars carry — one flat plane for
    # the library-level rows, the sharded store for the service rows
    db, dc = fingerprint_batch(keys)
    step = max(1, len(keys) // N_QUERIES)
    qf = np.ascontiguousarray(db[::step][:N_QUERIES])
    qc = popcount_u32(qf).sum(axis=1, dtype=np.int32)
    qn = qf.shape[0]

    import jax

    backend = (
        "pallas-tpu" if jax.default_backend() == "tpu" else "host-blocked"
    )

    # warm every path (allocators, and the jit cache when a TPU is there)
    tanimoto_topk_naive(qf[:2], db, K)
    tanimoto_topk_ref(qf[:8], db, K, db_counts=dc)
    tanimoto_topk(qf[:8], db, K, db_counts=dc)

    t_naive, res_naive = timeit(lambda: tanimoto_topk_naive(qf, db, K))
    t_ref, res_ref = timeit(
        lambda: tanimoto_topk_ref(qf, db, K, q_counts=qc, db_counts=dc)
    )
    t_kern, res_kern = timeit(
        lambda: tanimoto_topk(qf, db, K, q_counts=qc, db_counts=dc)
    )
    qps_naive = qn / t_naive
    qps_ref = qn / t_ref
    qps_kern = qn / t_kern
    speedup_kern = qps_kern / max(qps_naive, 1e-9)
    speedup_ref = qps_ref / max(qps_naive, 1e-9)

    parity_kernel = _equal(res_kern, res_ref) and _equal(res_naive, res_ref)
    # the Pallas kernel itself, interpreted on a subsample (full-scale
    # interpret mode would dominate the bench on CPU-only boxes)
    sub_q, sub_n = min(qn, 16), min(len(keys), 512)
    parity_interpret = _equal(
        tanimoto_topk(qf[:sub_q], db[:sub_n], K, interpret=True),
        tanimoto_topk_ref(qf[:sub_q], db[:sub_n], K),
    )

    out.append(row(
        "similarity.naive_loop", t_naive,
        f"{qps_naive:.0f} q/s — {qn} queries x {len(keys)} rows, "
        f"one scoring pass per query"))
    out.append(row(
        "similarity.reference", t_ref,
        f"{qps_ref:.0f} q/s ({speedup_ref:.1f}x naive), chunked oracle"))
    out.append(row(
        "similarity.kernel", t_kern,
        f"{qps_kern:.0f} q/s ({speedup_kern:.1f}x naive) via {backend}, "
        f"top-{K} byte-identical={'ok' if parity_kernel else 'BROKEN'}, "
        f"interpret={'ok' if parity_interpret else 'BROKEN'}"))

    # -- service path: per-query probes vs the micro-batched scheduler -----
    svc = QueryService(
        store, store_dir, ServiceConfig(replicas=REPLICAS, max_batch=512)
    )
    naive_store = IndexStore.open(store_dir)

    sample = qf[: min(qn, 16)]
    got = svc.similar(sample, K)
    want_parts = [
        naive_store.similar_batch(sample[i : i + 1], K, probe="host")
        for i in range(sample.shape[0])
    ]
    want = tuple(
        np.concatenate([p[j] for p in want_parts], axis=0) for j in range(3)
    )
    parity_service = all(np.array_equal(got[j], want[j]) for j in range(3))
    out.append(row(
        "similarity.service_parity", 0.0,
        f"service vs per-query probes byte-identical="
        f"{'ok' if parity_service else 'BROKEN'}"))

    pool_step = max(1, len(keys) // 2048)
    pool = [db[i] for i in range(0, len(keys), pool_step)]

    def naive_sim(rows_):
        for fp in rows_:
            naive_store.similar_batch(fp[None, :], K, probe="host")

    rep_naive = run_closed_loop(
        naive_sim, pool, clients=CLIENTS, duration_s=DURATION_S,
        keys_per_request=QUERIES_PER_REQUEST,
    )
    out.append(row(
        "similarity.service_naive", rep_naive.seconds,
        f"{rep_naive.lookups_per_sec:.0f} q/s, {CLIENTS} clients x "
        f"{QUERIES_PER_REQUEST} queries/req, p50 {rep_naive.p50_ms:.2f} ms, "
        f"p99 {rep_naive.p99_ms:.2f} ms"))

    rep_svc = run_closed_loop(
        lambda rows_: svc.similar(np.stack(rows_), K), pool,
        clients=CLIENTS, duration_s=DURATION_S,
        keys_per_request=QUERIES_PER_REQUEST,
    )
    speedup_svc = rep_svc.lookups_per_sec / max(
        rep_naive.lookups_per_sec, 1e-9
    )
    sim_stats = svc.stats()["similarity"]
    sch = sim_stats["scheduler"]
    out.append(row(
        "similarity.service_batched", rep_svc.seconds,
        f"{rep_svc.lookups_per_sec:.0f} q/s ({speedup_svc:.1f}x naive), "
        f"mean batch {sch['mean_batch_keys']:.1f} queries, "
        f"p50 {rep_svc.p50_ms:.2f} ms, p99 {rep_svc.p99_ms:.2f} ms"))

    parity = bool(parity_kernel and parity_interpret and parity_service)
    _LAST = {
        "corpus": {
            "files": spec.n_files,
            "records_per_file": spec.records_per_file,
            "entries": len(keys),
            "n_shards": N_SHARDS,
            "fingerprint_bits": int(naive_store.fingerprint_bits or 0),
        },
        "config": {
            "n_queries": qn,
            "k": K,
            "backend": backend,
            "clients": CLIENTS,
            "queries_per_request": QUERIES_PER_REQUEST,
            "duration_s": DURATION_S,
            "replicas": REPLICAS,
        },
        "qps": {
            "naive_loop": qps_naive,
            "reference": qps_ref,
            "kernel": qps_kern,
        },
        "speedup_kernel_vs_naive": speedup_kern,
        "speedup_reference_vs_naive": speedup_ref,
        "service": {
            "naive": _report(rep_naive),
            "service": _report(rep_svc),
            "speedup_vs_naive": speedup_svc,
            "mean_coalesced_batch": sch["mean_batch_keys"],
            "fp_rows_scanned": sim_stats["fp_rows_scanned"],
        },
        "parity_flags": {
            "kernel_vs_reference": bool(parity_kernel),
            "interpret_kernel_vs_reference": bool(parity_interpret),
            "service_vs_per_query": bool(parity_service),
        },
        "parity": parity,
    }
    svc.close()
    return out
