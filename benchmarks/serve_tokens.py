"""Decode-token serving benchmark — continuous batching vs static batches.

Two LM serving architectures over the SAME tiny transformer and weights:

* ``serve.static`` — the pre-paged architecture: concurrent requests
  coalesce (via the query service's :class:`MicroBatcher`) into fixed
  static batches that a :class:`~repro.serve.engine.Engine` pads together
  and decodes until the LONGEST request's budget; each caller keeps only
  its own budget's worth of tokens.  This is honest static serving — a
  batch is pinned by its slowest member, and short requests ride along
  burning lanes they don't use.
* ``serve.continuous`` — the :class:`~repro.serve.scheduler
  .ContinuousEngine`: slot admission per decode step over the paged KV
  cache, EOS/budget eviction returning blocks, so a finished short
  request's lane is re-admitted immediately instead of idling until the
  batch drains.

Both arms are driven by the same closed-loop generator the query-service
benchmarks use (:func:`repro.service.loadgen.run_closed_loop` — one
outstanding request per client, 2x more clients than slots), so
tokens/sec measures *sustained* load, not a single drag race.  Delivered
tokens (what callers keep) count for both arms; the static arm's
overshoot past a request's budget is exactly the waste being measured.

A third arm measures **prefix-cache sharing**: a workload whose prompts
all extend one long stem (the shared-system-prompt / shared-document
serving shape) is served by two :class:`ContinuousEngine`\\ s over the
same weights — prefix cache on vs off.  The on-engine adopts the stem's
resident KV blocks at admission and prefills only the per-request tail,
so its throughput advantage is pure prefill compute saved; outputs are
byte-identical between the arms (suffix prefill is bit-exact).

Byte parity is asserted before any throughput is reported: a uniform
batch must match the static engine token-for-token, a ragged mix must
match per-prompt serial generation, and the shared-prefix mix must be
byte-identical with sharing on vs off.  ``benchmarks/run.py`` writes
:func:`last_metrics` to ``BENCH_serve.json``; the headline gates are
``ragged.speedup >= 2`` and ``shared_prefix.speedup >= 1.5`` with every
parity flag true.

Env knobs: ``REPRO_BENCH_SERVE_SECONDS`` (per-arm window),
``REPRO_BENCH_SERVE_SLOTS`` (decode batch width / slot count).
"""

from __future__ import annotations

import dataclasses
import os
import random
import threading
from typing import Dict, List, Optional, Tuple

from .common import row

MAX_SLOTS = int(os.environ.get("REPRO_BENCH_SERVE_SLOTS", "8"))
DURATION_S = float(os.environ.get("REPRO_BENCH_SERVE_SECONDS", "2.5"))
BLOCK_SIZE = 16
MAX_BLOCKS_PER_SEQ = 6          # 96-row view = longest prompt + budget
N_BLOCKS = MAX_SLOTS * MAX_BLOCKS_PER_SEQ + 8   # slots + trash + headroom
CLIENTS = 2 * MAX_SLOTS
SHORT_BUDGETS = (2, 3, 4, 5, 6)
LONG_BUDGET = 48
LONG_FRACTION = 0.2
UNIFORM_BUDGET = 12
# shared-prefix arm: prompts = one long stem + a short unique tail, so
# almost all prefill FLOPs are in the (shareable) stem
STEM_BLOCKS = 32                # stem spans exactly this many full blocks
PREFIX_TAILS = 16               # distinct request tails over the stem
PREFIX_BUDGETS = (2, 3, 4)
PREFIX_BLOCKS_PER_SEQ = 36      # table width of the shared-prefix spec

_LAST: Optional[Dict[str, object]] = None


def last_metrics() -> Optional[Dict[str, object]]:
    """Metrics of the most recent :func:`run` (for BENCH_serve.json)."""
    return _LAST


def _tiny_cfg():
    from repro.configs import get_config

    return dataclasses.replace(
        get_config("yi-6b"),
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=300,
    )


def _prompts() -> List[str]:
    """Deterministic InChI-flavored prompts, 8-24 chars (1-2 buckets)."""
    rng = random.Random(11)
    stem = "InChI=1S/C8H10N4O2/c1-10-4"
    return [stem[: rng.randrange(8, 25)] for _ in range(48)]


def _prefix_pool() -> List[Tuple[str, int]]:
    """Shared-prefix workload: every prompt extends the same long stem.

    The stem is sized so BOS + stem fills exactly ``STEM_BLOCKS`` full
    blocks — the whole stem is block-aligned and adoptable; only the
    2-char tail (plus the budget) ever needs fresh blocks.
    """
    base = (
        "InChI=1S/C27H46O/c1-18(2)7-6-8-19(3)23-11-12-24-22-10-9-20-17-"
        "21(28)13-15-26(20,4)25(22)14-16-27(23,24)5/h17-19,21-25,28H;"
    )
    stem = (base * 4)[: STEM_BLOCKS * BLOCK_SIZE - 1]   # -1: BOS token
    rng = random.Random(37)
    pool = []
    for i in range(64):
        tail = f"{i % PREFIX_TAILS:02d}"
        pool.append((stem + tail, rng.choice(PREFIX_BUDGETS)))
    return pool


def _ragged_pool(prompts: List[str]) -> List[Tuple[str, int]]:
    rng = random.Random(23)
    pool = []
    for i in range(64):
        budget = (
            LONG_BUDGET
            if rng.random() < LONG_FRACTION
            else rng.choice(SHORT_BUDGETS)
        )
        pool.append((prompts[i % len(prompts)], budget))
    return pool


class _StaticServer:
    """Static-batch serving arm: MicroBatcher -> fixed-width Engine batches.

    Requests coalesce into batches of up to ``MAX_SLOTS``; the probe pads
    the batch to exactly ``MAX_SLOTS`` lanes (with the longest pool
    prompt, so both the batch AND prefill dims are constant — one trace
    per engine) and decodes on the smallest engine whose token cap covers
    the batch's largest budget.  Callers get their budget's prefix.
    """

    def __init__(self, cfg, params, filler: str, max_len: int, caps):
        from repro.serve.engine import Engine, ServeConfig
        from repro.service.scheduler import MicroBatcher

        self.filler = filler
        self.engines = [
            (cap, Engine(cfg, params, ServeConfig(
                max_new_tokens=cap, max_len=max_len, greedy=True)))
            for cap in sorted(caps)
        ]
        self.tokens = 0
        self._lock = threading.Lock()
        self.mb = MicroBatcher(self._probe, max_batch=MAX_SLOTS,
                               max_wait_ms=4.0)

    def _engine_for(self, cap: int):
        for c, eng in self.engines:
            if cap <= c:
                return eng
        raise ValueError(f"budget {cap} exceeds every engine cap")

    def _probe(self, items: List[Tuple[str, int]]):
        budgets = [b for _, b in items]
        texts = [t for t, _ in items]
        texts += [self.filler] * (MAX_SLOTS - len(texts))
        rs = self._engine_for(max(budgets)).generate(texts)
        outs = [rs[i].token_ids[: budgets[i]] for i in range(len(items))]
        with self._lock:
            self.tokens += sum(len(o) for o in outs)
        return (outs,)

    def request(self, item: Tuple[str, int]) -> List[int]:
        return self.mb.submit([item]).result()[0][0]

    def counters(self) -> Dict[str, float]:
        with self._lock:
            return {"tokens_out": float(self.tokens)}

    def close(self):
        self.mb.close()


def _arm_report(rep, tokens: float) -> Dict[str, float]:
    return {
        "tokens_per_s": tokens / rep.seconds if rep.seconds > 0 else 0.0,
        "requests": rep.requests,
        "requests_per_s": rep.requests_per_sec,
        "p50_ms": rep.p50_ms,
        "p99_ms": rep.p99_ms,
        "errors": rep.errors,
        "seconds": rep.seconds,
    }


def run() -> List[str]:
    global _LAST
    import jax

    from repro.models.registry import build_model
    from repro.serve.engine import ServeConfig
    from repro.serve.kvcache import PagedCacheSpec
    from repro.serve.scheduler import ContinuousEngine
    from repro.service.loadgen import run_closed_loop

    out: List[str] = []
    cfg = _tiny_cfg()
    params, _ = build_model(cfg).init(jax.random.PRNGKey(0))
    spec = PagedCacheSpec(
        n_blocks=N_BLOCKS, block_size=BLOCK_SIZE, max_slots=MAX_SLOTS,
        max_blocks_per_seq=MAX_BLOCKS_PER_SEQ,
    )
    prompts = _prompts()
    filler = max(prompts, key=len)
    static = _StaticServer(
        cfg, params, filler, spec.max_len,
        caps=(max(SHORT_BUDGETS), UNIFORM_BUDGET, LONG_BUDGET),
    )
    cont = ContinuousEngine(
        cfg, params, spec,
        ServeConfig(max_new_tokens=LONG_BUDGET, max_len=spec.max_len,
                    greedy=True),
    )

    # -- parity gate (doubles as trace warmup for both arms) ---------------
    uni_items = [(p, UNIFORM_BUDGET) for p in prompts[:MAX_SLOTS]]
    want_uni = static._probe(uni_items)[0]
    got_uni = [
        r.token_ids
        for r in cont.generate([p for p, _ in uni_items], UNIFORM_BUDGET)
    ]
    parity = got_uni == want_uni

    ragged_items = [
        (prompts[0], 3), (prompts[1], LONG_BUDGET), (prompts[2], 6),
        (prompts[3], 20), (prompts[4], max(SHORT_BUDGETS)),
    ]
    futs = [cont.submit(t, b, lead=False) for t, b in ragged_items]
    cont._maybe_lead()
    got_ragged = [f.result(timeout=300).token_ids for f in futs]
    for (t, b), got in zip(ragged_items, got_ragged):
        # reference from a single-request static batch (padded probe —
        # also exercises the static arm's batch-composition invariance)
        want = static._probe([(t, b)])[0][0]
        parity = parity and got == want
    out.append(row(
        "serve.parity", 0.0,
        f"uniform+ragged token parity vs static engine: "
        f"{'ok' if parity else 'BROKEN'}"))
    cont.reset_slo()

    # -- ragged sustained load (the continuous-batching case) --------------
    pool = _ragged_pool(prompts)
    c0 = static.counters()["tokens_out"]
    rep_s = run_closed_loop(
        lambda ks: static.request(ks[0]), pool, clients=CLIENTS,
        duration_s=DURATION_S, keys_per_request=1,
        counters_fn=static.counters,
    )
    tok_s = rep_s.counters.get("tokens_out", static.counters()["tokens_out"] - c0)
    rep_c = run_closed_loop(
        lambda ks: cont.submit(ks[0][0], max_new_tokens=ks[0][1]).result(),
        pool, clients=CLIENTS, duration_s=DURATION_S, keys_per_request=1,
        counters_fn=cont.counters,
    )
    tok_c = rep_c.counters["tokens_out"]
    ragged = {
        "static": _arm_report(rep_s, tok_s),
        "continuous": _arm_report(rep_c, tok_c),
    }
    ragged["speedup"] = (
        ragged["continuous"]["tokens_per_s"]
        / max(ragged["static"]["tokens_per_s"], 1e-9)
    )
    slo = cont.slo_ms()
    out.append(row(
        "serve.static_ragged", rep_s.seconds,
        f"{ragged['static']['tokens_per_s']:.0f} tok/s, "
        f"{rep_s.requests} requests, {CLIENTS} clients"))
    out.append(row(
        "serve.continuous_ragged", rep_c.seconds,
        f"{ragged['continuous']['tokens_per_s']:.0f} tok/s "
        f"({ragged['speedup']:.1f}x static), ttft p50 "
        f"{slo['ttft_p50_ms']:.1f} ms, itl p50 {slo['itl_p50_ms']:.2f} ms "
        f"/ p99 {slo['itl_p99_ms']:.2f} ms"))

    # -- uniform control: no raggedness, static batching is near-optimal ---
    pool_u = [(p, UNIFORM_BUDGET) for p in prompts]
    c0 = static.counters()["tokens_out"]
    rep_su = run_closed_loop(
        lambda ks: static.request(ks[0]), pool_u, clients=CLIENTS,
        duration_s=DURATION_S / 2, keys_per_request=1,
        counters_fn=static.counters,
    )
    tok_su = rep_su.counters.get(
        "tokens_out", static.counters()["tokens_out"] - c0
    )
    rep_cu = run_closed_loop(
        lambda ks: cont.submit(ks[0][0], max_new_tokens=ks[0][1]).result(),
        pool_u, clients=CLIENTS, duration_s=DURATION_S / 2,
        keys_per_request=1, counters_fn=cont.counters,
    )
    tok_cu = rep_cu.counters["tokens_out"]
    uniform = {
        "static": _arm_report(rep_su, tok_su),
        "continuous": _arm_report(rep_cu, tok_cu),
    }
    uniform["speedup"] = (
        uniform["continuous"]["tokens_per_s"]
        / max(uniform["static"]["tokens_per_s"], 1e-9)
    )
    out.append(row(
        "serve.uniform_control", rep_cu.seconds,
        f"continuous {uniform['continuous']['tokens_per_s']:.0f} vs static "
        f"{uniform['static']['tokens_per_s']:.0f} tok/s "
        f"({uniform['speedup']:.2f}x) at uniform budget {UNIFORM_BUDGET}"))

    # -- shared-prefix mix: prefix cache on vs off -------------------------
    spec_p = PagedCacheSpec(
        n_blocks=MAX_SLOTS * PREFIX_BLOCKS_PER_SEQ + PREFIX_BLOCKS_PER_SEQ + 8,
        block_size=BLOCK_SIZE, max_slots=MAX_SLOTS,
        max_blocks_per_seq=PREFIX_BLOCKS_PER_SEQ,
    )
    scfg_p = ServeConfig(
        max_new_tokens=max(PREFIX_BUDGETS), max_len=spec_p.max_len,
        greedy=True,
    )
    pfx_on = ContinuousEngine(cfg, params, spec_p, scfg_p, prefix_cache=True)
    pfx_off = ContinuousEngine(cfg, params, spec_p, scfg_p, prefix_cache=False)
    ppool = _prefix_pool()
    ptexts = [t for t, _ in ppool[:PREFIX_TAILS]]

    # parity gate first (doubles as trace warmup for both arms): sharing
    # must never change a byte
    want_p = [r.token_ids for r in pfx_off.generate(ptexts)]
    got_p = [r.token_ids for r in pfx_on.generate(ptexts)]
    pparity = got_p == want_p
    out.append(row(
        "serve.prefix_parity", 0.0,
        f"shared-prefix bytes, cache on vs off: "
        f"{'ok' if pparity else 'BROKEN'}"))
    pfx_on.reset_slo()

    rep_off = run_closed_loop(
        lambda ks: pfx_off.submit(ks[0][0], max_new_tokens=ks[0][1]).result(),
        ppool, clients=CLIENTS, duration_s=DURATION_S / 2,
        keys_per_request=1, counters_fn=pfx_off.counters,
    )
    rep_on = run_closed_loop(
        lambda ks: pfx_on.submit(ks[0][0], max_new_tokens=ks[0][1]).result(),
        ppool, clients=CLIENTS, duration_s=DURATION_S / 2,
        keys_per_request=1, counters_fn=pfx_on.counters,
    )
    on_c = pfx_on.counters()
    shared_prefix = {
        "off": _arm_report(rep_off, rep_off.counters["tokens_out"]),
        "on": _arm_report(rep_on, rep_on.counters["tokens_out"]),
        "parity": bool(pparity),
        "prefix_hit_rate": on_c["prefix_hit_rate"],
        "prefix_hits": on_c["prefix_hits"],
        "prefill_tokens_saved": on_c["prefill_tokens_saved"],
        "index_entries": on_c["pfx_entries"],
        "index_evictions": on_c["pfx_evictions"],
        "stem_tokens": STEM_BLOCKS * BLOCK_SIZE,
    }
    shared_prefix["speedup"] = (
        shared_prefix["on"]["tokens_per_s"]
        / max(shared_prefix["off"]["tokens_per_s"], 1e-9)
    )
    out.append(row(
        "serve.prefix_shared", rep_on.seconds,
        f"{shared_prefix['on']['tokens_per_s']:.0f} tok/s cache-on vs "
        f"{shared_prefix['off']['tokens_per_s']:.0f} off "
        f"({shared_prefix['speedup']:.1f}x), hit rate "
        f"{on_c['prefix_hit_rate']:.2f}, "
        f"{on_c['prefill_tokens_saved']:.0f} prefill tokens saved"))
    pfx_on.close()
    pfx_off.close()

    sched = cont.counters()
    _LAST = {
        "config": {
            "max_slots": MAX_SLOTS,
            "block_size": BLOCK_SIZE,
            "n_blocks": N_BLOCKS,
            "max_blocks_per_seq": MAX_BLOCKS_PER_SEQ,
            "clients": CLIENTS,
            "duration_s": DURATION_S,
            "short_budgets": list(SHORT_BUDGETS),
            "long_budget": LONG_BUDGET,
            "long_fraction": LONG_FRACTION,
            "uniform_budget": UNIFORM_BUDGET,
            "stem_blocks": STEM_BLOCKS,
            "prefix_tails": PREFIX_TAILS,
            "prefix_budgets": list(PREFIX_BUDGETS),
            "prefix_blocks_per_seq": PREFIX_BLOCKS_PER_SEQ,
            "model": {
                "n_layers": cfg.n_layers, "d_model": cfg.d_model,
                "n_heads": cfg.n_heads, "vocab_size": cfg.vocab_size,
            },
        },
        "ragged": ragged,
        "uniform": uniform,
        "shared_prefix": shared_prefix,
        "slo": slo,
        "scheduler": {
            k: sched[k]
            for k in ("requests", "completed", "steps", "tokens_out",
                      "decode_tokens", "prefills", "admission_stalls",
                      "peak_active", "tokens_per_step", "prefix_hits",
                      "prefix_misses", "prefix_hit_rate",
                      "prefill_tokens_saved")
        },
        "allocator": {
            k: sched[f"blk_{k}"]
            for k in ("allocs", "frees", "alloc_failures", "peak_in_use")
        },
        "parity": bool(parity),
    }
    cont.close()
    static.close()
    return out
