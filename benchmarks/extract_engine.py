"""Extraction-engine benchmark — serial vs pipelined read phase.

Measures Algorithm 3's read phase through the three engine stages the
tentpole adds on top of the paper's forward-seek loop:

* ``extract.serial``         — ``workers=0`` reference: one seek + per-line
  Python scan + per-record verify (the paper's own loop, the ablation row);
* ``extract.pipelined_cold`` — coalesced preads + bulk ``$$$$`` splitting +
  parallel file workers + batched verify, empty cache;
* ``extract.pipelined_warm`` — same engine with the record cache warm, so
  repeat extraction (the paper's "re-extraction, no rebuild" scenario)
  skips both the I/O and the structural re-parse;
* ``extract.dense_*``        — a dense target set (every 7th record), where
  inter-target gaps actually fall inside the coalesce threshold and many
  records ride one pread span (the sparse intersection set sits ~150 KB
  apart at bench scale, past any sane gap, so its spans stay 1/record);
* ``extract.cold_<backend>`` — the same cold extraction forced through
  each span I/O backend (thread preadv / mmap / uring when the kernel
  has it), parity asserted per backend.

Besides CSV rows, the module records a machine-readable metrics dict
(:func:`last_metrics`) which ``benchmarks/run.py`` writes to
``BENCH_extract.json`` so the extraction perf trajectory is tracked
across PRs.  Output parity between the serial and pipelined paths is
asserted, not assumed.
"""

from __future__ import annotations

import os
from typing import Dict, List, Optional

from repro.core.cache import RecordCache
from repro.core.extract import extract
from repro.core.index import build_index
from repro.core.intersect import intersect_host
from repro.core.iobackend import uring_available
from repro.core.sdfgen import db_id_list

from .common import bench_store, row, timeit

# Coalescing tuned for the bench target density (every 77th record): a
# 64 KiB gap bridges the typical inter-target distance so spans merge.
ENGINE_WORKERS = 4
ENGINE_GAP = 64 * 1024

_LAST: Optional[Dict[str, object]] = None


def last_metrics() -> Optional[Dict[str, object]]:
    """Metrics of the most recent :func:`run` (for BENCH_extract.json)."""
    return _LAST


def _drop_page_cache(store) -> bool:
    """Evict the corpus from the OS page cache (fadvise DONTNEED).

    The paper's corpora are terabytes — extraction NEVER runs against a
    warm page cache there, so every ``cold`` row below evicts first.
    Without this the whole corpus sits cached after index construction
    and the serial loop's per-record read is a ~1 µs memcpy instead of a
    ~40 µs device read, hiding exactly the latency the async span window
    exists to overlap.  Returns False where fadvise is unavailable.
    """
    if not hasattr(os, "posix_fadvise"):  # pragma: no cover - non-posix
        return False
    os.sync()  # dirty pages survive DONTNEED; flush them first
    for fname in store.file_names():
        fd = os.open(store.path_of(fname), os.O_RDONLY)
        try:
            os.posix_fadvise(fd, 0, 0, os.POSIX_FADV_DONTNEED)
        finally:
            os.close(fd)
    return True


def _identical(a, b) -> bool:
    return (
        list(a.records.items()) == list(b.records.items())
        and a.missing == b.missing
        and a.mismatches == b.mismatches
    )


def run() -> List[str]:
    global _LAST
    store, spec = bench_store()
    out = []

    targets = intersect_host(
        db_id_list(spec, "chembl", extra_outside=25),
        db_id_list(spec, "emolecules", extra_outside=25),
    ).ids
    idx = build_index(store, key_mode="full_id")

    # warm the machinery, not the data: first engine call pays one-time
    # pool spin-up + verify-kernel first-touch (~15 ms) that would
    # otherwise land entirely on the cold row
    warm_t = targets[:64]
    extract(store, idx, warm_t, workers=0)
    extract(store, idx, warm_t, workers=ENGINE_WORKERS, coalesce_gap=ENGINE_GAP)

    cold = _drop_page_cache(store)
    t_serial, res_serial = timeit(lambda: extract(store, idx, targets, workers=0))
    n = max(res_serial.found, 1)
    out.append(row(
        "extract.serial", t_serial,
        f"found {res_serial.found}; {n / max(t_serial, 1e-9):.0f} rec/s "
        f"(workers=0: per-record seek + per-line scan, "
        f"page cache {'cold' if cold else 'WARM'})"))

    cache = RecordCache(capacity=2 * len(targets) + 16)
    _drop_page_cache(store)
    t_cold, res_cold = timeit(lambda: extract(
        store, idx, targets,
        workers=ENGINE_WORKERS, coalesce_gap=ENGINE_GAP, cache=cache))
    spans_per_rec = res_cold.spans_read / n
    out.append(row(
        "extract.pipelined_cold", t_cold,
        f"{n / max(t_cold, 1e-9):.0f} rec/s; {res_cold.spans_read} spans "
        f"({spans_per_rec:.3f}/rec), {res_cold.bytes_read / 1e6:.2f} MB pread, "
        f"workers={ENGINE_WORKERS}"))

    t_warm, res_warm = timeit(lambda: extract(
        store, idx, targets,
        workers=ENGINE_WORKERS, coalesce_gap=ENGINE_GAP, cache=cache))
    hit_rate = res_warm.cache_hits / max(res_warm.seeks, 1)
    out.append(row(
        "extract.pipelined_warm", t_warm,
        f"{n / max(t_warm, 1e-9):.0f} rec/s; cache {res_warm.cache_hits}/"
        f"{res_warm.seeks} hits ({hit_rate:.0%}), {res_warm.spans_read} spans"))

    parity = _identical(res_serial, res_cold) and _identical(res_serial, res_warm)
    speedup_cold = t_serial / max(t_cold, 1e-9)
    speedup_warm = t_serial / max(t_warm, 1e-9)

    # per-backend cold ablation: same targets, no cache, each span backend
    # forced explicitly (auto picks uring when available, thread otherwise)
    backend_metrics: Dict[str, Dict[str, object]] = {}
    for be in ["thread", "mmap"] + (["uring"] if uring_available() else []):
        _drop_page_cache(store)
        t_be, res_be = timeit(lambda: extract(
            store, idx, targets,
            workers=ENGINE_WORKERS, coalesce_gap=ENGINE_GAP, backend=be))
        be_parity = _identical(res_serial, res_be)
        parity = parity and be_parity
        out.append(row(
            f"extract.cold_{be}", t_be,
            f"{n / max(t_be, 1e-9):.0f} rec/s, depth peak "
            f"{res_be.inflight_peak}, {res_be.spans_read} spans, "
            f"{t_serial / max(t_be, 1e-9):.1f}x vs serial, "
            f"parity={'ok' if be_parity else 'BROKEN'}"))
        backend_metrics[be] = {
            "seconds": t_be,
            "records_per_sec": n / max(t_be, 1e-9),
            "speedup_vs_serial": t_serial / max(t_be, 1e-9),
            "inflight_peak": res_be.inflight_peak,
            "spans_read": res_be.spans_read,
            "parity": be_parity,
        }
    out.append(row(
        "extract.speedup", 0.0,
        f"cold {speedup_cold:.1f}x, warm {speedup_warm:.1f}x vs serial; "
        f"parity={'ok' if parity else 'BROKEN'}; plan/read split "
        f"{res_cold.plan_seconds * 1e3:.1f}/{res_cold.read_seconds * 1e3:.1f} ms"))

    # dense extraction: every-7th-record targets keep inter-target gaps
    # inside the coalesce threshold, so span merging actually engages
    dense = db_id_list(spec, "chembl")
    _drop_page_cache(store)
    t_dser, res_dser = timeit(lambda: extract(store, idx, dense, workers=0))
    _drop_page_cache(store)
    t_deng, res_deng = timeit(lambda: extract(
        store, idx, dense, workers=ENGINE_WORKERS, coalesce_gap=ENGINE_GAP))
    nd = max(res_dser.found, 1)
    dense_spans_per_rec = res_deng.spans_read / nd
    dense_parity = _identical(res_dser, res_deng)
    out.append(row(
        "extract.dense_coalesced", t_deng,
        f"{nd} records via {res_deng.spans_read} spans "
        f"({dense_spans_per_rec:.3f}/rec, {nd / max(res_deng.spans_read, 1):.0f} "
        f"rec/span); {t_dser / max(t_deng, 1e-9):.1f}x vs serial "
        f"{t_dser * 1e3:.0f} ms, parity={'ok' if dense_parity else 'BROKEN'}"))
    parity = parity and dense_parity

    _LAST = {
        "corpus": {
            "files": spec.n_files,
            "records_per_file": spec.records_per_file,
            "targets": len(targets),
            "records_extracted": res_serial.found,
        },
        "engine": {
            "workers": ENGINE_WORKERS,
            "coalesce_gap": ENGINE_GAP,
            "cache_capacity": cache.capacity,
        },
        "serial": {
            "seconds": t_serial,
            "records_per_sec": n / max(t_serial, 1e-9),
            "plan_seconds": res_serial.plan_seconds,
            "read_seconds": res_serial.read_seconds,
        },
        "pipelined_cold": {
            "seconds": t_cold,
            "records_per_sec": n / max(t_cold, 1e-9),
            "plan_seconds": res_cold.plan_seconds,
            "read_seconds": res_cold.read_seconds,
            "spans_read": res_cold.spans_read,
            "spans_per_record": spans_per_rec,
            "bytes_read": res_cold.bytes_read,
            "read_backend": res_cold.read_backend,
            "inflight_peak": res_cold.inflight_peak,
            "verify_batches": res_cold.verify_batches,
            "verify_records": res_cold.verify_records,
            "verify_batch_max": res_cold.verify_batch_max,
        },
        "backends": backend_metrics,
        "pipelined_warm": {
            "seconds": t_warm,
            "records_per_sec": n / max(t_warm, 1e-9),
            "cache_hits": res_warm.cache_hits,
            "cache_hit_rate": hit_rate,
        },
        "dense": {
            "targets": len(dense),
            "records_extracted": res_dser.found,
            "serial_seconds": t_dser,
            "engine_seconds": t_deng,
            "records_per_sec": nd / max(t_deng, 1e-9),
            "spans_read": res_deng.spans_read,
            "spans_per_record": dense_spans_per_rec,
            "speedup": t_dser / max(t_deng, 1e-9),
        },
        "page_cache_cold": cold,
        "speedup_cold": speedup_cold,
        "speedup_warm": speedup_warm,
        "parity": parity,
    }
    return out
