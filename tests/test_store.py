"""Sharded mmap-backed IndexStore + Bloom prefilter tests.

Covers the query-service layer's contract: shard-boundary routing, the
digest-collision verify path (narrow-digest seeding), mmap reopen after
``save_sharded``, Bloom false-positive handling, incremental re-publish,
device-probe parity, and ``lookup_batch`` parity with per-key
``ByteOffsetIndex.lookup`` — including a ≥100k-key corpus with seeded
digest collisions.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    BloomFilter,
    ByteOffsetIndex,
    IndexStore,
    RecordStore,
    build_index,
    candidate_runs,
    digest_u64,
    extract,
    intersect_host,
    intersect_sorted,
    save_sharded,
    shard_of,
)
from repro.core.sdfgen import CorpusSpec, db_id_list, generate_corpus


def synth_index(n: int, n_files: int = 7) -> ByteOffsetIndex:
    idx = ByteOffsetIndex(key_mode="full_id")
    for i in range(n):
        idx.add(f"InChI=1S/synthetic/{i}", f"f_{i % n_files:02d}.sdf", i * 100)
    return idx


# ---------------------------------------------------------------------------
# Bloom filter
# ---------------------------------------------------------------------------

def test_bloom_no_false_negatives_and_bounded_fpr():
    rng = np.random.default_rng(0)
    present = rng.integers(0, 2**63, size=4096, dtype=np.uint64)
    absent = rng.integers(0, 2**63, size=4096, dtype=np.uint64)
    absent = np.setdiff1d(absent, present)
    bf = BloomFilter.build(present, bits_per_key=12)
    assert bf.contains(present).all()  # never a false negative
    fpr = bf.contains(absent).mean()
    # 12 bits/key ≈ 0.5% theoretical; allow generous slack
    assert fpr < 0.05, fpr
    assert bf.expected_fpp(len(present)) < 0.02


def test_bloom_empty_and_tiny():
    bf = BloomFilter.build(np.array([], dtype=np.uint64))
    assert bf.contains(np.array([1, 2, 3], dtype=np.uint64)).sum() == 0
    one = np.array([42], dtype=np.uint64)
    bf = BloomFilter.build(one)
    assert bf.contains(one).all()


# ---------------------------------------------------------------------------
# save_sharded / IndexStore round trip
# ---------------------------------------------------------------------------

def test_save_sharded_reopen_parity_and_mmap(tmp_path):
    idx = synth_index(3000)
    summary = idx.save_sharded(tmp_path / "store", n_shards=8)
    assert summary == {
        "written": 8, "skipped": 0, "n_entries": 3000,
        "path": str(tmp_path / "store"),
    }
    qs = IndexStore.open(tmp_path / "store")
    assert len(qs) == 3000 and qs.key_mode == "full_id"

    keys = [f"InChI=1S/synthetic/{i}" for i in range(0, 3000, 11)]
    misses = [f"InChI=1S/absent/{i}" for i in range(40)]
    fid, off, hit = qs.lookup_batch(keys + misses)
    assert hit[: len(keys)].all() and not hit[len(keys):].any()
    assert (fid[len(keys):] == -1).all() and (off[len(keys):] == -1).all()
    for k, loc in zip(keys + misses, qs.locate_batch(keys + misses)):
        assert loc == idx.lookup(k)
    # columns of a touched shard are memory-mapped, not copied
    touched = next(iter(qs.stats.shards_touched))
    assert isinstance(qs._shard(touched).digests, np.memmap)
    # single-key compatibility surface
    assert qs.lookup(keys[0]) == idx.lookup(keys[0])
    assert keys[0] in qs and misses[0] not in qs


def test_shards_load_lazily(tmp_path):
    idx = synth_index(2000)
    idx.save_sharded(tmp_path / "s", n_shards=16)
    qs = IndexStore.open(tmp_path / "s")
    assert qs.shards_loaded == 0  # open() touches only the manifest
    # find a key and query it: exactly one shard may fault in
    key = "InChI=1S/synthetic/123"
    assert qs.lookup(key) == idx.lookup(key)
    assert qs.shards_loaded == 1
    d = digest_u64([key], bits=qs.digest_bits)
    assert set(qs.stats.shards_touched) == {
        int(shard_of(d, qs.n_shards, qs.digest_bits)[0])
    }
    # a bloom-rejected miss loads no further shard columns
    before = qs.shards_loaded
    rejected = None
    for i in range(200):
        probe = f"InChI=1S/absent/{i}"
        r0 = qs.stats.bloom_rejects
        qs.lookup(probe)
        if qs.stats.bloom_rejects > r0:
            rejected = probe
            break
    assert rejected is not None
    assert qs.shards_loaded == before


def test_shard_boundary_keys(tmp_path):
    """Keys whose digests sit at the edges of a shard's range route and
    resolve correctly (an off-by-one in `shard_of` or the per-shard search
    would lose exactly these)."""
    digest_bits, n_shards = 12, 4
    span = np.uint64(1 << (digest_bits - 2))  # digest range per shard
    idx = ByteOffsetIndex(key_mode="full_id")
    # hunt keys landing on the first/last digest value of a shard range
    cand = [f"InChI=1S/boundary/{i}" for i in range(20_000)]
    d = digest_u64(cand, bits=digest_bits)
    rem = d % span
    picks = np.nonzero((rem == 0) | (rem == span - np.uint64(1)))[0][:6]
    assert len(picks) == 6, "boundary-key hunt came up short"
    boundary_keys = [cand[int(i)] for i in picks]
    for i in picks:
        idx.add(cand[int(i)], "b.sdf", int(i))
    for j in range(500):  # filler spread across shards
        idx.add(f"InChI=1S/fill/{j}", "f.sdf", j)
    idx.save_sharded(tmp_path / "s", n_shards=n_shards, digest_bits=digest_bits)
    qs = IndexStore.open(tmp_path / "s")
    assert qs.locate_batch(boundary_keys) == [idx.lookup(k) for k in boundary_keys]


def test_digest_collision_verify_path(tmp_path):
    """At 8 effective digest bits nearly every digest collides; the
    equal-run scan + full-key verify must still resolve every key to ITS
    location and reject absent keys that alias a present digest."""
    idx = synth_index(600)
    idx.save_sharded(tmp_path / "s", n_shards=4, digest_bits=8)
    qs = IndexStore.open(tmp_path / "s")
    keys = [f"InChI=1S/synthetic/{i}" for i in range(600)]
    assert qs.locate_batch(keys) == [idx.lookup(k) for k in keys]
    assert qs.stats.verify_collisions > 0  # the run scan actually ran
    # absent keys: with 256 digest values every miss aliases some present
    # digest — verification must turn them all into clean misses
    absent = [f"InChI=1S/absent/{i}" for i in range(200)]
    _, _, hit = qs.lookup_batch(absent)
    assert not hit.any()


def test_bloom_false_positive_handling(tmp_path):
    """A 1-bit-per-key Bloom filter false-positives heavily; every false
    positive must degrade to a probed miss, never a wrong record."""
    idx = synth_index(2000)
    save_sharded(idx, tmp_path / "s", n_shards=4, bloom_bits_per_key=1)
    qs = IndexStore.open(tmp_path / "s")
    absent = [f"InChI=1S/absent/{i}" for i in range(2000)]
    _, _, hit = qs.lookup_batch(absent)
    assert not hit.any()
    assert qs.stats.bloom_false_positives > 0  # filter lied, probe caught it
    assert qs.stats.bloom_rejects > 0          # and it still rejects some
    # presents still all resolve (no false negatives by construction)
    keys = [f"InChI=1S/synthetic/{i}" for i in range(0, 2000, 17)]
    _, _, hit = qs.lookup_batch(keys)
    assert hit.all()


def test_incremental_save_rewrites_only_changed_shards(tmp_path):
    idx = synth_index(4000)
    root = tmp_path / "s"
    assert idx.save_sharded(root, n_shards=8)["written"] == 8
    # no change -> no rewrite
    again = idx.save_sharded(root, n_shards=8)
    assert again["written"] == 0 and again["skipped"] == 8
    # one new key -> exactly the shard owning its digest is rewritten
    new_key = "InChI=1S/synthetic/new"
    idx.add(new_key, "f_00.sdf", 999_999)
    third = idx.save_sharded(root, n_shards=8)
    assert third["written"] == 1 and third["skipped"] == 7
    qs = IndexStore.open(root)
    assert len(qs) == 4001
    assert qs.lookup(new_key) == ("f_00.sdf", 999_999)
    # different params -> full rewrite (no stale-skip across layouts)
    assert idx.save_sharded(root, n_shards=4)["written"] == 4
    # ...and the old layout's extra shard files are cleaned up, so the
    # reported storage footprint reflects the live layout only
    leftover = {p.name for p in root.glob("shard_*.npy")
                if not p.name.startswith(tuple(f"shard_000{s}" for s in range(4)))}
    assert not leftover, leftover
    # a Bloom-sizing change alone must also rewrite (the content hash only
    # covers data columns; a skipped shard would pair the old bitmap with
    # the new bloom_k -> false negatives)
    resized = idx.save_sharded(root, n_shards=4, bloom_bits_per_key=4)
    assert resized["written"] == 4 and resized["skipped"] == 0
    qs2 = IndexStore.open(root)
    keys = [f"InChI=1S/synthetic/{i}" for i in range(0, 4000, 97)]
    assert qs2.lookup_batch(keys)[2].all()


def test_republish_preserves_live_mmap_readers(tmp_path):
    """Shard rewrites go through temp-file + rename, so a reader holding a
    shard mmap'd keeps its old inode — never a torn/truncated column."""
    idx = synth_index(1000)
    root = tmp_path / "s"
    idx.save_sharded(root, n_shards=2)
    qs = IndexStore.open(root)
    keys = [f"InChI=1S/synthetic/{i}" for i in range(0, 1000, 3)]
    assert qs.lookup_batch(keys)[2].all()  # fault both shards in (mmap'd)
    before = [np.asarray(qs._shard(s).digests).copy() for s in range(2)]
    for i in range(200):
        idx.add(f"InChI=1S/more/{i}", "g.sdf", i)
    assert idx.save_sharded(root, n_shards=2)["written"] == 2
    for s in range(2):  # the live mapping still sees the old bytes, intact
        np.testing.assert_array_equal(np.asarray(qs._shard(s).digests), before[s])
    assert qs.locate_batch(keys) == [idx.lookup(k) for k in keys]
    # a fresh open serves the republished content
    assert IndexStore.open(root).lookup("InChI=1S/more/7") == ("g.sdf", 7)


def test_device_probe_parity(tmp_path):
    idx = synth_index(1500)
    idx.save_sharded(tmp_path / "s", n_shards=4)
    keys = [f"InChI=1S/synthetic/{i}" for i in range(0, 1500, 7)]
    keys += [f"InChI=1S/absent/{i}" for i in range(60)]
    host = IndexStore.open(tmp_path / "s")
    dev = IndexStore.open(tmp_path / "s")
    fh, oh, hh = host.lookup_batch(keys, probe="host")
    fd, od, hd = dev.lookup_batch(keys, probe="device")
    np.testing.assert_array_equal(hh, hd)
    np.testing.assert_array_equal(fh, fd)
    np.testing.assert_array_equal(oh, od)
    with pytest.raises(ValueError):
        host.lookup_batch(keys[:1], probe="quantum")


@settings(max_examples=15)
@given(picks=st.lists(st.integers(min_value=0, max_value=2999), min_size=1,
                      max_size=60))
def test_lookup_batch_parity_hypothesis(tmp_path_factory, picks):
    global _HYP_STORE
    try:
        idx, qs = _HYP_STORE
    except NameError:
        idx = synth_index(3000)
        root = tmp_path_factory.mktemp("hyp") / "s"
        idx.save_sharded(root, n_shards=8, digest_bits=20)
        qs = IndexStore.open(root)
        _HYP_STORE = (idx, qs)
    keys = [f"InChI=1S/synthetic/{i}" for i in picks]
    keys += [f"InChI=1S/absent/{i}" for i in picks[:10]]
    assert qs.locate_batch(keys) == [idx.lookup(k) for k in keys]


def test_lookup_batch_parity_100k(tmp_path):
    """Acceptance-scale parity: ≥100k keys, digests narrowed to 24 bits so
    the corpus contains hundreds of seeded digest collisions."""
    n = 100_000
    idx = synth_index(n, n_files=31)
    digest_bits = 24
    d = digest_u64([f"InChI=1S/synthetic/{i}" for i in range(n)],
                   bits=digest_bits)
    n_colliding = int(n - len(np.unique(d)))
    assert n_colliding > 50, "collision seeding failed"
    idx.save_sharded(tmp_path / "s", n_shards=16, digest_bits=digest_bits)
    qs = IndexStore.open(tmp_path / "s")
    keys = [f"InChI=1S/synthetic/{i}" for i in range(n)]
    misses = [f"InChI=1S/absent/{i}" for i in range(2000)]
    locs = qs.locate_batch(keys + misses)
    for k, loc in zip(keys + misses, locs):
        assert loc == idx.lookup(k), k
    assert qs.stats.verify_collisions > 0


# ---------------------------------------------------------------------------
# consumers on top of the store
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    spec = CorpusSpec(n_files=2, records_per_file=150)
    root = tmp_path_factory.mktemp("corpus") / "c"
    generate_corpus(root, spec)
    return RecordStore(root), spec


def test_extract_through_index_store(corpus, tmp_path):
    store, spec = corpus
    idx = build_index(store)
    idx.save_sharded(tmp_path / "s", n_shards=4)
    qs = IndexStore.open(tmp_path / "s")
    targets = intersect_host(
        db_id_list(spec, "chembl"), db_id_list(spec, "emolecules")
    ).ids
    res_dict = extract(store, idx, targets)
    res_store = extract(store, qs, targets)
    assert res_store.records == res_dict.records
    assert res_store.missing == res_dict.missing
    assert not res_store.mismatches


def test_indexed_dataset_on_index_store(corpus, tmp_path):
    from repro.data.pipeline import IndexedDataset
    from repro.data.sampler import GlobalSampler

    store, spec = corpus
    idx = build_index(store)
    idx.save_sharded(tmp_path / "s", n_shards=4)
    qs = IndexStore.open(tmp_path / "s")
    ds_dict = IndexedDataset(store, idx, seq_len=64)
    ds_store = IndexedDataset(store, qs, seq_len=64)
    assert ds_store.keys == ds_dict.keys  # same deterministic ordering
    sampler = GlobalSampler(n_examples=len(ds_store), global_batch=4, seed=0)
    a = ds_dict.batch_for(sampler, step=3, dp_rank=0, n_dp=1)
    b = ds_store.batch_for(sampler, step=3, dp_rank=0, n_dp=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["loss_mask"], b["loss_mask"])


# ---------------------------------------------------------------------------
# intersect: shared helpers + intra-table collision-run fix
# ---------------------------------------------------------------------------

def test_candidate_runs_cover_equal_digest_spans():
    table = np.array([1, 3, 3, 3, 7], dtype=np.uint64)
    starts, stops = candidate_runs(table, np.array([0, 3, 7, 9], dtype=np.uint64))
    assert list(starts) == [0, 1, 4, 5]
    assert list(stops) == [0, 4, 5, 5]


def test_intersect_sorted_survives_intra_table_collisions():
    """At 8 digest bits distinct ids collide constantly inside the running
    table; side='left' alone verified only the first of each equal-digest
    run and dropped true members behind it."""
    a = [f"InChI=1S/x/{i}" for i in range(400)]
    b = [f"InChI=1S/x/{i}" for i in range(0, 400, 2)]
    c = [f"InChI=1S/x/{i}" for i in range(0, 400, 3)]
    want = intersect_host(a, b, c).ids
    got = intersect_sorted(a, b, c, digest_bits=8)
    assert got.ids == want
    # default width unchanged and still exact
    assert intersect_sorted(a, b, c).ids == want

# ---------------------------------------------------------------------------
# concurrency: cold-store thread safety + the pinned serving plane
# ---------------------------------------------------------------------------

def test_cold_store_survives_concurrent_first_touch(tmp_path):
    """Many threads hammering a COLD store race the lazy shard/Bloom
    np.load (the scatter-gather workers' access pattern); every thread
    must see correct results and no partially-initialized shard."""
    import threading

    idx = synth_index(6000, n_files=5)
    idx.save_sharded(tmp_path / "s", n_shards=16)
    qs = IndexStore.open(tmp_path / "s")  # cold: nothing loaded yet
    keys = list(idx.entries.keys())
    absent = [f"InChI=1S/absent/{i}" for i in range(200)]
    want = {k: idx.lookup(k) for k in keys}
    errors = []

    def hammer(seed: int) -> None:
        try:
            mine = keys[seed::12] + absent[seed::12]
            locs = qs.locate_batch(mine)
            for k, loc in zip(mine, locs):
                assert loc == want.get(k), (k, loc)
        except Exception as e:  # pragma: no cover - the regression signal
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(12)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert qs.shards_loaded == 16
    assert qs.stats.queries == len(keys) + len(absent)  # no lost updates


def test_serving_plane_parity_with_collisions(tmp_path):
    """The pinned digest/file/offset plane must return exactly what the
    per-shard probe returns — including collision runs at truncated
    digest widths — with identical stats."""
    idx = synth_index(9000, n_files=5)
    idx.save_sharded(tmp_path / "s", n_shards=8, digest_bits=16)
    plain = IndexStore.open(tmp_path / "s")
    plane = IndexStore.open(tmp_path / "s")
    planes = plane.preload_digest_plane()
    keys = list(idx.entries.keys())[::2] + [
        f"InChI=1S/absent/{i}" for i in range(500)
    ]
    want = plain.lookup_batch(keys)
    got = plane.lookup_batch(keys)
    for w, g in zip(want, got):
        assert (w == g).all()
    assert plain.stats.verify_collisions == plane.stats.verify_collisions
    assert plain.stats.verify_collisions > 0  # 16-bit digests do collide
    assert plain.stats.bloom_rejects == plane.stats.bloom_rejects
    assert plain.stats.hits == plane.stats.hits
    assert plain.stats.shards_touched == plane.stats.shards_touched
    # adopt_planes shares the (read-only) planes across replicas
    third = IndexStore.open(tmp_path / "s")
    third.adopt_planes(planes)
    got3 = third.lookup_batch(keys)
    for w, g in zip(want, got3):
        assert (w == g).all()
