"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates its REDUCED same-family config and
runs one forward/train step on CPU, asserting output shapes and no NaNs;
plus decode-vs-forward consistency on representative families.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.configs.base import SHAPES, shape_by_name
from repro.models.common import unembed_logits
from repro.models.registry import build_model


def _batch_for(cfg, B=2, S=64, key=jax.random.PRNGKey(1)):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.enc_frames, cfg.d_model)
        )
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_img_tokens, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_loss_no_nans(arch):
    cfg = get_config(arch).smoke()
    api = build_model(cfg)
    params, specs = api.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss, metrics = jax.jit(api.loss)(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch} produced NaN loss"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step_updates_params(arch):
    from repro.train.loop import make_train_state, make_train_step
    from repro.train.optimizer import AdamWConfig

    cfg = get_config(arch).smoke()
    api = build_model(cfg)
    state = make_train_state(api, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(api, AdamWConfig(warmup_steps=1)))
    batch = _batch_for(cfg)
    new_state, m = step(state, batch)
    assert not bool(jnp.isnan(m["loss"]))
    assert int(new_state["step"]) == 1
    # at least one parameter moved
    moved = jax.tree_util.tree_map(
        lambda a, b: bool(jnp.any(a != b)), state["params"], new_state["params"]
    )
    assert any(jax.tree_util.tree_leaves(moved)), f"{arch}: no param moved"


@pytest.mark.parametrize(
    "arch", ["yi-6b", "gemma3-12b", "mamba2-1.3b", "jamba-1.5-large-398b",
             "whisper-small", "qwen3-moe-235b-a22b"]
)
def test_smoke_decode_matches_forward(arch):
    cfg = get_config(arch).smoke()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no train drops
    api = build_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    B, S = 2, 64
    batch = _batch_for(cfg, B, S)
    nimg = cfg.n_img_tokens or 0
    logits_pre, cache = jax.jit(
        lambda p, b: api.prefill(p, b, max_len=S + nimg + 4)
    )(params, batch)
    tok = jnp.argmax(logits_pre, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B,), S + nimg, jnp.int32)
    logits_dec, _ = jax.jit(api.decode_step)(params, tok, pos, cache)

    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    ext["loss_mask"] = jnp.ones_like(ext["tokens"], jnp.float32)
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models.transformer import lm_forward

        hid, _ = lm_forward(params, cfg, ext["tokens"], ext.get("patch_embeds"))
    elif cfg.family == "hybrid":
        from repro.models.hybrid import hybrid_forward

        hid, _ = hybrid_forward(params, cfg, ext["tokens"])
    elif cfg.family == "ssm":
        from repro.models.ssm import ssm_forward

        hid, _ = ssm_forward(params, cfg, ext["tokens"])
    else:
        from repro.models.encdec import encdec_forward

        hid = encdec_forward(params, cfg, ext["frames"], ext["tokens"])
    truth = unembed_logits(params["embed"], cfg, hid[:, -1:, :])[:, 0]
    err = float(jnp.max(jnp.abs(
        logits_dec.astype(jnp.float32) - truth.astype(jnp.float32)
    )))
    assert err < 0.06, f"{arch}: decode/forward divergence {err}"


def test_full_configs_match_assignment():
    """The published numbers, verbatim (guards accidental edits)."""
    rows = {
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "mamba2-1.3b": (48, 2048, 1, 1, 0, 50280),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
    }
    for arch, (L, D, H, KV, F, V) in rows.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, D, H, KV, F, V), arch
    # MoE / structural details
    assert get_config("qwen3-moe-235b-a22b").n_experts == 128
    assert get_config("qwen3-moe-235b-a22b").experts_per_token == 8
    assert get_config("moonshot-v1-16b-a3b").n_experts == 64
    assert get_config("moonshot-v1-16b-a3b").experts_per_token == 6
    assert get_config("jamba-1.5-large-398b").n_experts == 16
    assert get_config("jamba-1.5-large-398b").hybrid_block == 8
    assert get_config("gemma3-12b").local_block == 6
    assert get_config("mamba2-1.3b").ssm_state == 128
    assert get_config("qwen2-72b").qkv_bias


def test_full_param_counts_in_published_ballpark():
    """Abstract init (no allocation) → param totals ≈ the model names."""
    import sys
    sys.path.insert(0, "src")
    from repro.launch.dryrun import abstract_init, param_stats

    expect = {
        "qwen2-72b": (65e9, 85e9),
        "yi-6b": (5.5e9, 7e9),
        "gemma3-12b": (10e9, 15e9),
        "qwen1.5-110b": (100e9, 125e9),
        "jamba-1.5-large-398b": (350e9, 440e9),
        "qwen3-moe-235b-a22b": (210e9, 260e9),
        # the ASSIGNED config (48L × 64e × d_ff 1408) arithmetically implies
        # ~28B total; the real Moonlight-16B has 27 layers — we implement
        # the assignment as specified
        "moonshot-v1-16b-a3b": (24e9, 32e9),
        "mamba2-1.3b": (1.0e9, 1.7e9),
        "whisper-small": (0.2e9, 0.5e9),
        "internvl2-76b": (66e9, 86e9),
    }
    for arch, (lo, hi) in expect.items():
        api = build_model(get_config(arch))
        ps, specs = abstract_init(api)
        n = param_stats(ps, specs)["total"]
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_shape_cells_runnable_map():
    from repro.configs import cell_is_runnable, runnable_cells

    cells = runnable_cells()
    assert len(cells) == 33  # 10×4 minus 7 long_500k skips
    assert ("mamba2-1.3b", "long_500k") in cells
    assert ("jamba-1.5-large-398b", "long_500k") in cells
    assert ("gemma3-12b", "long_500k") in cells
    assert ("qwen2-72b", "long_500k") not in cells
