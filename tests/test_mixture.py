"""Multi-corpus mixture sampler: determinism, elasticity, weight fidelity."""

import collections

import pytest
from hypothesis import given, settings, strategies as st

from repro.data.mixture import MixtureSampler


def test_mixture_is_deterministic_and_elastic():
    smp = MixtureSampler(sizes=(100, 50, 200), weights=(0.5, 0.2, 0.3),
                         global_batch=8, seed=3)
    full = smp.batch_examples(step=4, dp_rank=0, n_dp=1)
    parts = []
    for r in range(4):
        parts += smp.batch_examples(step=4, dp_rank=r, n_dp=4)
    assert parts == full


def test_mixture_weights_respected():
    smp = MixtureSampler(sizes=(1000, 1000), weights=(0.8, 0.2),
                         global_batch=16, seed=0)
    counts = collections.Counter()
    for step in range(80):
        for c, _ in smp.batch_examples(step, 0, 1):
            counts[c] += 1
    frac0 = counts[0] / (counts[0] + counts[1])
    assert 0.74 <= frac0 <= 0.86  # 0.8 ± sampling noise at n=1280


def test_mixture_per_corpus_stream_is_epoch_exact():
    """Within one epoch of a corpus's stream: no repeats, full coverage."""
    smp = MixtureSampler(sizes=(13, 7), weights=(1.0, 1.0),
                         global_batch=4, seed=1)
    seen = collections.defaultdict(list)
    for step in range(40):
        for c, i in smp.batch_examples(step, 0, 1):
            seen[c].append(i)
    for c, n in ((0, 13), (1, 7)):
        first_epoch = seen[c][:n]
        assert sorted(first_epoch) == list(range(n)), (c, sorted(first_epoch))


@settings(max_examples=20, deadline=None)
@given(
    n0=st.integers(5, 200), n1=st.integers(5, 200),
    w0=st.floats(0.05, 1.0), seed=st.integers(0, 1000),
)
def test_mixture_examples_always_in_range(n0, n1, w0, seed):
    smp = MixtureSampler(sizes=(n0, n1), weights=(w0, 1 - w0 if w0 < 1 else 0.5),
                         global_batch=4, seed=seed)
    for step in range(6):
        for c, i in smp.batch_examples(step, 0, 1):
            assert 0 <= i < (n0, n1)[c]


def test_mixture_rejects_bad_inputs():
    with pytest.raises(ValueError):
        MixtureSampler(sizes=(10,), weights=(1.0, 1.0), global_batch=4)
    with pytest.raises(ValueError):
        MixtureSampler(sizes=(10, 10), weights=(0.0, 0.0), global_batch=4)
    smp = MixtureSampler(sizes=(10, 10), weights=(1.0, 1.0), global_batch=5)
    with pytest.raises(ValueError):
        smp.batch_slots(0, 0, 2)
