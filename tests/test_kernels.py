"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle.

Every kernel sweeps shapes/dtypes and asserts allclose (bit-exact for the
integer kernels) against its ref.py oracle, plus hypothesis property tests
on the kernels' semantic invariants.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels.hash_mix.kernel import hash_mix_pallas
from repro.kernels.hash_mix.ref import hash_mix_ref
from repro.kernels.sorted_probe.ops import sorted_probe_pallas
from repro.kernels.sorted_probe.ref import sorted_probe_ref, sort_pairs
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import (
    flash_attention_chunked,
    flash_attention_ref,
)
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref


# ---------------------------------------------------------------------------
# hash_mix
# ---------------------------------------------------------------------------

HASH_SHAPES = [(1, 8), (37, 16), (256, 8), (1000, 24), (4096, 64), (513, 8), (8, 40)]


@pytest.mark.parametrize("n,w", HASH_SHAPES)
def test_hash_mix_matches_ref(n, w):
    rng = np.random.default_rng(n * 1000 + w)
    x = jnp.asarray(rng.integers(0, 2**32, size=(n, w), dtype=np.uint32))
    ref = hash_mix_ref(x)
    pal = hash_mix_pallas(x, interpret=True)
    assert ref.shape == (n, 4) and ref.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


@pytest.mark.parametrize("block_rows", [8, 64, 1024])
def test_hash_mix_block_size_invariance(block_rows):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(0, 2**32, size=(300, 16), dtype=np.uint32))
    ref = hash_mix_ref(x)
    pal = hash_mix_pallas(x, block_rows=block_rows, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


def test_hash_mix_seed_changes_digest():
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.integers(0, 2**32, size=(64, 8), dtype=np.uint32))
    a = np.asarray(hash_mix_ref(x, seed=0))
    b = np.asarray(hash_mix_ref(x, seed=1))
    assert not np.array_equal(a, b)


def test_hash_mix_avalanche():
    """Single input-bit flip flips ~half the output bits."""
    rng = np.random.default_rng(9)
    x = rng.integers(0, 2**32, size=(2000, 16), dtype=np.uint32)
    y = x.copy()
    y[:, 5] ^= 1 << 17
    hx = np.asarray(hash_mix_ref(jnp.asarray(x))).view(np.uint8)
    hy = np.asarray(hash_mix_ref(jnp.asarray(y))).view(np.uint8)
    rate = np.unpackbits(hx ^ hy, axis=1).mean()
    assert 0.47 < rate < 0.53


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 200),
    w=st.sampled_from([8, 16, 24]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hash_mix_property_kernel_eq_ref(n, w, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 2**32, size=(n, w), dtype=np.uint32))
    np.testing.assert_array_equal(
        np.asarray(hash_mix_ref(x)),
        np.asarray(hash_mix_pallas(x, block_rows=64, interpret=True)),
    )


def test_hash_mix_row_locality():
    """Digest of a row is independent of its neighbours (padding safety)."""
    rng = np.random.default_rng(10)
    x = rng.integers(0, 2**32, size=(50, 8), dtype=np.uint32)
    full = np.asarray(hash_mix_ref(jnp.asarray(x)))
    one = np.asarray(hash_mix_ref(jnp.asarray(x[20:21])))
    np.testing.assert_array_equal(full[20:21], one)


# ---------------------------------------------------------------------------
# sorted_probe
# ---------------------------------------------------------------------------

def _mk_table_queries(rng, m, q, hit_frac=0.5):
    t = rng.integers(0, 2**32, size=(m, 2), dtype=np.uint32)
    t = np.unique(
        t.view([("hi", np.uint32), ("lo", np.uint32)])
    ).view(np.uint32).reshape(-1, 2)
    nhit = int(q * hit_frac)
    qs = np.vstack(
        [
            t[rng.integers(0, len(t), nhit)],
            rng.integers(0, 2**32, size=(q - nhit, 2), dtype=np.uint32),
        ]
    )
    rng.shuffle(qs)
    return jnp.asarray(qs), jnp.asarray(t)


def _numpy_truth(qs, t):
    tn, qn = np.asarray(t), np.asarray(qs)
    tv = tn[:, 0].astype(np.uint64) << np.uint64(32) | tn[:, 1].astype(np.uint64)
    qv = qn[:, 0].astype(np.uint64) << np.uint64(32) | qn[:, 1].astype(np.uint64)
    pos = np.searchsorted(tv, qv, side="left")
    found = (pos < len(tv)) & (tv[np.minimum(pos, len(tv) - 1)] == qv)
    return found, pos.astype(np.int32)


PROBE_CASES = [
    (100, 50, 512, None),
    (5000, 1000, 512, None),
    (10000, 4096, 2048, None),
    (300, 7, 128, None),
    (65536, 4096, 2048, None),
    (2000, 1024, 2048, 8),     # forces overflow fallback
    (2000, 512, 256, 16),
]


@pytest.mark.parametrize("m,q,bt,qmax", PROBE_CASES)
def test_sorted_probe_matches_numpy(m, q, bt, qmax):
    rng = np.random.default_rng(m + q)
    qs, t = _mk_table_queries(rng, m, q)
    found_np, pos_np = _numpy_truth(qs, t)
    f_ref, p_ref = sorted_probe_ref(qs, t)
    np.testing.assert_array_equal(np.asarray(f_ref), found_np)
    np.testing.assert_array_equal(np.asarray(p_ref), pos_np)
    f_pal, p_pal = sorted_probe_pallas(qs, t, table_block=bt, qmax=qmax, interpret=True)
    np.testing.assert_array_equal(np.asarray(f_pal), found_np)
    np.testing.assert_array_equal(np.asarray(p_pal), pos_np)


def test_sorted_probe_all_hits_and_all_misses():
    rng = np.random.default_rng(11)
    qs, t = _mk_table_queries(rng, 4096, 512, hit_frac=1.0)
    f, _ = sorted_probe_pallas(qs, t, table_block=512, interpret=True)
    assert bool(jnp.all(f))
    qs2 = jnp.asarray(np.asarray(qs) ^ np.uint32(0x80000001))  # near-certain misses
    f2, _ = sorted_probe_ref(qs2, t)
    f2_np, _ = _numpy_truth(qs2, t)
    np.testing.assert_array_equal(np.asarray(f2), f2_np)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(2, 400),
    q=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_sorted_probe_property(m, q, seed):
    rng = np.random.default_rng(seed)
    qs, t = _mk_table_queries(rng, m, q, hit_frac=0.7)
    found_np, pos_np = _numpy_truth(qs, t)
    f, p = sorted_probe_pallas(qs, t, table_block=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(f), found_np)
    np.testing.assert_array_equal(np.asarray(p), pos_np)


def test_sort_pairs_is_lexicographic():
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.integers(0, 2**32, size=(500, 2), dtype=np.uint32))
    s, order = sort_pairs(x)
    sn = np.asarray(s)
    v = sn[:, 0].astype(np.uint64) << np.uint64(32) | sn[:, 1].astype(np.uint64)
    assert np.all(v[1:] >= v[:-1])
    # permutation property
    assert sorted(np.asarray(order).tolist()) == list(range(500))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

FA_CASES = [
    # (B, Hq, Hkv, Sq, Skv, D, causal, window)
    (1, 2, 2, 256, 256, 64, True, None),
    (2, 4, 2, 256, 256, 64, True, None),
    (1, 2, 1, 128, 384, 32, True, None),
    (1, 2, 2, 256, 256, 64, True, 128),
    (1, 4, 4, 256, 256, 128, False, None),
    (1, 8, 1, 128, 128, 64, True, None),   # MQA
]


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,window", FA_CASES)
def test_flash_attention_matches_ref_f32(b, hq, hkv, sq, skv, d, causal, window):
    rng = np.random.default_rng(b * 100 + hq)
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, hkv, skv, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, hkv, skv, d)).astype(np.float32))
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    pal = flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=128, block_k=128, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_attention_dtypes(dtype):
    rng = np.random.default_rng(13)
    q = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), dtype=dtype)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), dtype=dtype)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), dtype=dtype)
    ref = flash_attention_ref(q, k, v)
    pal = flash_attention_pallas(q, k, v, block_q=128, block_k=128, interpret=True)
    assert pal.dtype == dtype
    atol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(ref, dtype=np.float32), np.asarray(pal, dtype=np.float32), atol=atol
    )


def test_flash_attention_block_size_invariance():
    rng = np.random.default_rng(14)
    q = jnp.asarray(rng.standard_normal((1, 2, 512, 64)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 2, 512, 64)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 2, 512, 64)).astype(np.float32))
    a = flash_attention_pallas(q, k, v, block_q=128, block_k=256, interpret=True)
    b = flash_attention_pallas(q, k, v, block_q=256, block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


def test_flash_attention_causality_property():
    """Perturbing future keys must not change past outputs."""
    rng = np.random.default_rng(15)
    q = jnp.asarray(rng.standard_normal((1, 2, 256, 32)).astype(np.float32))
    k = np.asarray(rng.standard_normal((1, 2, 256, 32)).astype(np.float32))
    v = np.asarray(rng.standard_normal((1, 2, 256, 32)).astype(np.float32))
    out1 = flash_attention_pallas(
        q, jnp.asarray(k), jnp.asarray(v), block_q=128, block_k=128, interpret=True
    )
    k2, v2 = k.copy(), v.copy()
    k2[:, :, 200:], v2[:, :, 200:] = 99.0, -99.0
    out2 = flash_attention_pallas(
        q, jnp.asarray(k2), jnp.asarray(v2), block_q=128, block_k=128, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(out1)[:, :, :200], np.asarray(out2)[:, :, :200], atol=1e-6
    )


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,window,chunk", [
    (1, 2, 2, 256, 256, 64, True, None, 128),
    (2, 4, 2, 256, 256, 64, True, None, 96),    # chunk not dividing skv
    (1, 2, 1, 128, 384, 32, True, None, 128),   # Sq < Skv
    (1, 2, 2, 256, 256, 64, True, 128, 64),     # sliding window
    (1, 4, 4, 192, 192, 48, False, None, 128),
])
def test_flash_attention_chunked_matches_ref(b, hq, hkv, sq, skv, d,
                                             causal, window, chunk):
    """The XLA online-softmax path (the §Perf default) vs the oracle."""
    rng = np.random.default_rng(b * 31 + sq)
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, hkv, skv, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, hkv, skv, d)).astype(np.float32))
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    chk = flash_attention_chunked(
        q, k, v, causal=causal, window=window, chunk=chunk
    )
    np.testing.assert_allclose(np.asarray(ref), np.asarray(chk),
                               atol=3e-5, rtol=3e-5)


@settings(max_examples=15, deadline=None)
@given(
    sq=st.sampled_from([64, 128]),
    skv=st.sampled_from([128, 192]),
    chunk=st.sampled_from([32, 64, 96]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_chunked_property(sq, skv, chunk, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, 2, sq, 32)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 2, skv, 32)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 2, skv, 32)).astype(np.float32))
    ref = flash_attention_ref(q, k, v)
    chk = flash_attention_chunked(q, k, v, chunk=chunk)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(chk),
                               atol=3e-5, rtol=3e-5)


def test_flash_attention_window_equals_full_when_window_ge_seq():
    rng = np.random.default_rng(16)
    q = jnp.asarray(rng.standard_normal((1, 2, 256, 32)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 32)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 32)).astype(np.float32))
    full = flash_attention_pallas(q, k, v, block_q=128, block_k=128, interpret=True)
    win = flash_attention_pallas(
        q, k, v, window=256, block_q=128, block_k=128, interpret=True
    )
    np.testing.assert_allclose(np.asarray(full), np.asarray(win), atol=1e-6)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

SSD_CASES = [(2, 4, 8, 16), (6, 16, 64, 128), (1, 1, 4, 4), (3, 32, 16, 32)]


@pytest.mark.parametrize("bh,c,p,n", SSD_CASES)
def test_ssd_scan_matches_ref(bh, c, p, n):
    rng = np.random.default_rng(bh * 10 + c)
    states = jnp.asarray(rng.standard_normal((bh, c, p, n)).astype(np.float32))
    decay = jnp.asarray(rng.uniform(0.2, 0.99, (bh, c)).astype(np.float32))
    ref = ssd_scan_ref(states, decay)
    pal = ssd_scan_pallas(states, decay, interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal), atol=1e-6)


def test_ssd_scan_prefix_semantics():
    """prefix[0] == 0 and prefix[c+1] == decay[c]*prefix[c] + states[c]."""
    rng = np.random.default_rng(17)
    states = jnp.asarray(rng.standard_normal((2, 5, 4, 4)).astype(np.float32))
    decay = jnp.asarray(rng.uniform(0.5, 0.9, (2, 5)).astype(np.float32))
    pre = np.asarray(ssd_scan_pallas(states, decay, interpret=True))
    s, d = np.asarray(states), np.asarray(decay)
    np.testing.assert_allclose(pre[:, 0], 0.0)
    for c in range(4):
        np.testing.assert_allclose(
            pre[:, c + 1],
            d[:, c][:, None, None] * pre[:, c] + s[:, c],
            atol=1e-6,
        )


@settings(max_examples=20, deadline=None)
@given(
    bh=st.integers(1, 4),
    c=st.integers(1, 12),
    p=st.sampled_from([4, 8]),
    n=st.sampled_from([4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ssd_scan_property(bh, c, p, n, seed):
    rng = np.random.default_rng(seed)
    states = jnp.asarray(rng.standard_normal((bh, c, p, n)).astype(np.float32))
    decay = jnp.asarray(rng.uniform(0.0, 1.0, (bh, c)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(ssd_scan_ref(states, decay)),
        np.asarray(ssd_scan_pallas(states, decay, interpret=True)),
        atol=1e-6,
    )
