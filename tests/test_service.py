"""Query-service tests: scheduler flush ordering and cancellation, router
scatter-gather merges, and QueryService fetch parity (byte-identical vs
the direct serial ``extract``) on a collision-seeded corpus.
"""

import tempfile
import threading
import time
from concurrent.futures import CancelledError
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    ByteOffsetIndex,
    IndexStore,
    RecordStore,
    build_index,
    extract,
    intersect_host,
)
from repro.core.sdfgen import CorpusSpec, db_id_list, generate_corpus
from repro.data.pipeline import IndexedDataset
from repro.service import (
    MicroBatcher,
    QueryService,
    ServiceConfig,
    ShardRouter,
    run_closed_loop,
)

KEY_BITS = 16  # collision-prone at corpus scale: mismatch path exercised


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def corpus():
    spec = CorpusSpec(n_files=3, records_per_file=500, key_bits=KEY_BITS)
    root = Path(tempfile.mkdtemp()) / "corpus"
    generate_corpus(root, spec)
    return RecordStore(root), spec


@pytest.fixture(scope="module")
def targets(corpus):
    _, spec = corpus
    return intersect_host(
        db_id_list(spec, "chembl", extra_outside=15),
        db_id_list(spec, "emolecules", extra_outside=15),
    ).ids


@pytest.fixture(scope="module")
def hashed_store_dir(corpus):
    """Collision-seeded hashed-key index published as a sharded store."""
    store, _ = corpus
    idx = build_index(store, key_mode="hashed_key", key_bits=KEY_BITS)
    assert idx.stats.n_duplicate_keys > 0
    sdir = Path(tempfile.mkdtemp()) / "istore_hashed"
    idx.save_sharded(sdir, n_shards=8)
    return sdir


@pytest.fixture(scope="module")
def full_store_dir(corpus):
    store, _ = corpus
    idx = build_index(store, key_mode="full_id")
    sdir = Path(tempfile.mkdtemp()) / "istore_full"
    idx.save_sharded(sdir, n_shards=8)
    return sdir


def _fake_probe(keys):
    """Deterministic fake backend: encodes each key's int suffix."""
    vals = np.array([int(k.rsplit("/", 1)[1]) for k in keys], dtype=np.int64)
    return vals.astype(np.int32), vals * 10, np.ones(len(keys), dtype=bool)


# ---------------------------------------------------------------------------
# MicroBatcher: flush ordering, mapping, cancellation, shutdown
# ---------------------------------------------------------------------------

def _blocked_batcher(max_batch=8, max_wait_ms=10_000.0):
    """Batcher whose first probe blocks until ``release`` is set — lets a
    test pile requests into the admission queue deterministically."""
    release = threading.Event()
    probing = threading.Event()
    calls = []

    def probe(keys):
        calls.append(list(keys))
        if len(calls) == 1:
            probing.set()
            assert release.wait(10)
        return _fake_probe(keys)

    return MicroBatcher(probe, max_batch=max_batch, max_wait_ms=max_wait_ms), \
        release, probing, calls


def test_full_batch_flush_and_result_mapping():
    """Requests queued behind a slow probe merge into one full-batch flush,
    and every future gets exactly its own rows."""
    mb, release, probing, calls = _blocked_batcher(max_batch=8)
    t = threading.Thread(target=lambda: mb.lookup(["k/0"]))
    t.start()
    assert probing.wait(10)  # leader is stuck inside probe #1
    futs = [mb.submit([f"k/{i}", f"k/{100 + i}"]) for i in range(1, 5)]
    release.set()
    t.join(10)
    for i, fut in enumerate(futs, start=1):
        fid, off, hit = fut.result(timeout=10)
        assert fid.tolist() == [i, 100 + i]
        assert off.tolist() == [i * 10, (100 + i) * 10]
        assert hit.all()
    mb.close()
    # probe #1 carried the solo leader; the queued 4 requests (8 keys)
    # flushed as ONE full batch, in submission order
    assert calls[0] == ["k/0"]
    assert calls[1] == [f"k/{i}" if j == 0 else f"k/{100 + i}"
                       for i in range(1, 5) for j in (0, 1)]
    assert mb.stats.full_flushes == 1
    assert mb.stats.coalesced_batches == 1
    assert mb.stats.coalesced_requests == 4
    assert mb.stats.batch_keys_max == 8


def test_max_batch_splits_queued_requests():
    """More queued keys than max_batch: whole requests split across
    consecutive flushes, never mid-request."""
    mb, release, probing, calls = _blocked_batcher(max_batch=4)
    t = threading.Thread(target=lambda: mb.lookup(["k/0"]))
    t.start()
    assert probing.wait(10)
    futs = [mb.submit([f"k/{i}", f"k/{100 + i}"]) for i in range(1, 5)]
    release.set()
    for fut in futs:
        fut.result(timeout=10)
    t.join(10)
    mb.close()
    assert [len(c) for c in calls] == [1, 4, 4]  # 2+2 keys per flush
    assert mb.stats.full_flushes >= 1


def test_deadline_flush_fires_without_new_arrivals():
    """A lone request below the armed cohort target is flushed by the
    watchdog at the max_wait deadline, not stuck forever."""
    mb, release, probing, _ = _blocked_batcher(max_batch=64, max_wait_ms=25.0)
    # phase 1: force a coalesced batch so the batcher enters cohort mode
    t = threading.Thread(target=lambda: mb.lookup(["k/0"]))
    t.start()
    assert probing.wait(10)
    f1, f2 = mb.submit(["k/1"]), mb.submit(["k/2"])
    release.set()
    f1.result(10), f2.result(10)
    t.join(10)
    assert mb.stats.coalesced_batches == 1
    assert mb._coalescing
    # phase 2: one below-target request arms and must deadline-flush
    t0 = time.monotonic()
    fid, _off, hit = mb.lookup(["k/7"], timeout=10)
    dt = time.monotonic() - t0
    assert fid.tolist() == [7] and hit.all()
    assert mb.stats.deadline_flushes >= 1
    assert dt >= 0.015  # it actually waited toward the deadline
    mb.close()


def test_cohort_flush_fires_on_target_arrival():
    """Concurrent closed-loop clients trigger cohort flushes (the armed
    target re-forms) and the latency window fills."""
    mb = MicroBatcher(_fake_probe, max_batch=64, max_wait_ms=50.0)
    keys = [f"k/{i}" for i in range(64)]
    rep = run_closed_loop(
        lambda ks: mb.lookup(ks), keys, clients=6, duration_s=0.4
    )
    assert rep.errors == 0
    assert mb.stats.coalesced_batches > 0
    assert mb.stats.cohort_flushes > 0
    assert mb.stats.mean_batch_keys > 1.0
    lat = mb.latency_ms()
    assert lat["p50"] > 0 and lat["p99"] >= lat["p50"]
    mb.close()


def test_shutdown_cancels_queued_futures():
    mb, release, probing, calls = _blocked_batcher()
    t = threading.Thread(target=lambda: mb.lookup(["k/0"]))
    t.start()
    assert probing.wait(10)
    queued = mb.submit(["k/9"])
    closer = threading.Thread(target=mb.close)  # drain=False: cancel
    closer.start()
    release.set()
    t.join(10)
    closer.join(10)
    assert queued.cancelled()
    with pytest.raises(CancelledError):
        queued.result(timeout=1)
    assert mb.stats.cancelled >= 1
    assert all("k/9" not in c for c in calls)  # never probed
    with pytest.raises(RuntimeError):
        mb.submit(["k/10"])  # closed


def test_close_drain_probes_queued_requests():
    mb, release, probing, _ = _blocked_batcher()
    t = threading.Thread(target=lambda: mb.lookup(["k/0"]))
    t.start()
    assert probing.wait(10)
    queued = mb.submit(["k/9"])
    closer = threading.Thread(target=lambda: mb.close(drain=True))
    closer.start()
    release.set()
    t.join(10)
    closer.join(10)
    fid, _off, hit = queued.result(timeout=1)
    assert fid.tolist() == [9] and hit.all()


def test_cancelled_future_withdraws_request():
    mb, release, probing, calls = _blocked_batcher()
    t = threading.Thread(target=lambda: mb.lookup(["k/0"]))
    t.start()
    assert probing.wait(10)
    doomed = mb.submit(["k/5"])
    kept = mb.submit(["k/6"])
    assert doomed.cancel()
    release.set()
    t.join(10)
    assert kept.result(10)[0].tolist() == [6]
    mb.close()
    assert all("k/5" not in c for c in calls)
    assert mb.stats.cancelled >= 1


def test_probe_exception_propagates_to_every_future():
    def bad_probe(keys):
        raise RuntimeError("shard on fire")

    mb = MicroBatcher(bad_probe, max_wait_ms=5.0)
    with pytest.raises(RuntimeError, match="shard on fire"):
        mb.lookup(["k/0"], timeout=5)
    mb.close()


def test_batcher_validates_knobs():
    with pytest.raises(ValueError):
        MicroBatcher(_fake_probe, max_batch=0)
    with pytest.raises(ValueError):
        MicroBatcher(_fake_probe, max_wait_ms=-1)


# ---------------------------------------------------------------------------
# ShardRouter: scatter-gather merge parity + stats
# ---------------------------------------------------------------------------

def test_router_matches_direct_store(full_store_dir, corpus):
    store, _ = corpus
    direct = IndexStore.open(full_store_dir)
    keys = sorted(direct.iter_keys())
    probe_keys = keys[::3] + [f"InChI=1S/absent/{i}" for i in range(40)]
    want = direct.lookup_batch(probe_keys)
    # min_scatter_keys=1 forces the scatter path; replicas checkout works
    with ShardRouter(full_store_dir, replicas=3, min_scatter_keys=1) as router:
        got = router.lookup_batch(probe_keys)
        for w, g in zip(want, got):
            assert (w == g).all()
        assert router.stats.scattered >= 1
        assert router.stats.shard_probes > 1
        assert sum(router.stats.keys_per_shard.values()) == len(probe_keys)
        qs = router.query_stats()
        assert qs.queries == len(probe_keys)
        assert qs.hits == int(want[2].sum())
        # locate surface mirrors the store's
        assert router.locate_batch(probe_keys[:5]) == direct.locate_batch(
            probe_keys[:5]
        )
        assert router.lookup(probe_keys[0]) == direct.lookup(probe_keys[0])
    with pytest.raises(RuntimeError):
        router.lookup_batch(probe_keys[:2])  # closed


def test_router_inline_path_small_batches(full_store_dir):
    direct = IndexStore.open(full_store_dir)
    keys = sorted(direct.iter_keys())[:10]
    router = ShardRouter(full_store_dir, replicas=2, min_scatter_keys=1024)
    got = router.lookup_batch(keys)
    want = direct.lookup_batch(keys)
    for w, g in zip(want, got):
        assert (w == g).all()
    assert router.stats.inline == 1 and router.stats.scattered == 0
    empty = router.lookup_batch([])
    assert all(len(a) == 0 for a in empty)
    router.close()


def test_router_rejects_bad_replicas(full_store_dir):
    with pytest.raises(ValueError):
        ShardRouter(full_store_dir, replicas=0)


# ---------------------------------------------------------------------------
# QueryService: byte parity vs the serial reference (the stats-parity gate)
# ---------------------------------------------------------------------------

def test_service_fetch_parity_on_collision_seeded_corpus(
    corpus, targets, hashed_store_dir
):
    """Service-path fetch must reproduce the serial loop byte-for-byte:
    records (content AND order), missing, and the collision mismatches."""
    store, _ = corpus
    idx = build_index(store, key_mode="hashed_key", key_bits=KEY_BITS)
    serial = extract(store, idx, targets, key_bits=KEY_BITS, workers=0)
    assert serial.mismatches and serial.missing  # both paths exercised
    with QueryService(store, hashed_store_dir, ServiceConfig(replicas=2)) as svc:
        res = svc.fetch(targets, key_bits=KEY_BITS)
        assert list(res.records.items()) == list(serial.records.items())
        assert res.missing == serial.missing
        assert res.mismatches == serial.mismatches
        # warm pass: served from the shared cache, still byte-identical
        res2 = svc.fetch(targets, key_bits=KEY_BITS)
        assert list(res2.records.items()) == list(serial.records.items())
        assert res2.cache_hits == res2.seeks
        assert res2.spans_read == 0


def test_service_concurrent_fetches_stay_identical(
    corpus, targets, hashed_store_dir
):
    store, _ = corpus
    idx = build_index(store, key_mode="hashed_key", key_bits=KEY_BITS)
    serial = extract(store, idx, targets, key_bits=KEY_BITS, workers=0)
    with QueryService(store, hashed_store_dir, ServiceConfig(replicas=2)) as svc:
        outs = {}

        def worker(i):
            outs[i] = svc.fetch(targets, key_bits=KEY_BITS)

        ths = [threading.Thread(target=worker, args=(i,)) for i in range(6)]
        for t in ths:
            t.start()
        for t in ths:
            t.join()
        for res in outs.values():
            assert list(res.records.items()) == list(serial.records.items())
            assert res.missing == serial.missing
            assert res.mismatches == serial.mismatches


def test_service_fetch_stream_and_lookup(corpus, targets, full_store_dir):
    store, _ = corpus
    idx = build_index(store, key_mode="full_id")
    serial = extract(store, idx, targets, workers=0)
    with QueryService(store, full_store_dir) as svc:
        got = dict(svc.fetch_stream(targets))
        assert got == serial.records
        # lookup surface: present and absent keys
        present = list(serial.records.keys())[:5]
        locs = svc.lookup(present + ["InChI=1S/absent/0"])
        assert all(loc is not None for loc in locs[:5])
        assert locs[-1] is None
        assert locs[:5] == [idx.lookup(k) for k in present]
        assert present[0] in svc and "InChI=1S/absent/0" not in svc
        assert len(svc) == len(idx)


def test_service_stats_counters(corpus, targets, full_store_dir):
    store, _ = corpus
    with QueryService(store, full_store_dir, ServiceConfig(replicas=2)) as svc:
        svc.fetch(targets)
        lk = sorted(svc.router.iter_keys())[:300]

        def looker(i):
            for j in range(i, len(lk), 6):
                svc.lookup_batch(lk[j:j + 3])

        ths = [threading.Thread(target=looker, args=(i,)) for i in range(6)]
        for t in ths:
            t.start()
        for t in ths:
            t.join()
        s = svc.stats()
        assert s["scheduler"]["requests"] > 0
        assert s["scheduler"]["coalesced_batches"] > 0
        assert s["scheduler"]["mean_batch_keys"] > 1.0
        assert s["store"]["queries"] == s["router"]["keys"]
        assert s["cache"]["entries"] > 0
        assert s["read"]["records"] > 0
        assert s["scheduler"]["latency_ms"]["p99"] >= \
            s["scheduler"]["latency_ms"]["p50"]


def test_indexed_dataset_rides_the_service(corpus, full_store_dir):
    store, _ = corpus
    idx = build_index(store, key_mode="full_id")
    direct = IndexedDataset(store, idx, seq_len=64, cache_records=512)
    with QueryService(store, full_store_dir) as svc:
        ds = IndexedDataset(store, None, seq_len=64, service=svc)
        assert ds.keys == direct.keys
        sample = ds.keys[:40]
        assert ds.fetch_many(list(sample)) == direct.fetch_many(list(sample))
        assert ds.fetch_record(sample[0]) == direct.fetch_record(sample[0])
        with pytest.raises(KeyError):
            ds.fetch_many(["InChI=1S/absent/0"])
    with pytest.raises(ValueError):
        IndexedDataset(store, None, seq_len=64)  # no index, no service


# ---------------------------------------------------------------------------
# load generator
# ---------------------------------------------------------------------------

def test_run_closed_loop_accounting():
    calls = []

    def fn(ks):
        calls.append(len(ks))

    rep = run_closed_loop(fn, ["a", "b", "c"], clients=3, duration_s=0.2,
                          keys_per_request=2)
    assert rep.requests == len(calls)
    assert rep.keys == 2 * rep.requests
    assert rep.lookups_per_sec > 0
    assert rep.p99_ms >= rep.p50_ms >= 0
    assert set(calls) == {2}
    with pytest.raises(ValueError):
        run_closed_loop(fn, [], clients=1)
    with pytest.raises(ValueError):
        run_closed_loop(fn, ["a"], clients=0)
