"""Pipelined extraction engine tests: serial/parallel parity, span
coalescing, span-boundary records, the record cache, and the streaming
``extract_iter`` API.
"""

import tempfile
from pathlib import Path

import pytest

from repro.core import (
    ExtractionResult,
    RecordCache,
    RecordStore,
    build_index,
    coalesce_spans,
    compare_ids_batch,
    extract,
    extract_iter,
    intersect_host,
)
from repro.core.reader import DEFAULT_SPAN_GUESS
from repro.core.sdfgen import CorpusSpec, db_id_list, generate_corpus

# Collision-seeded: 1500 records hashed into a 16-bit key space gives
# E[collisions] ≈ 1500² / 2^17 ≈ 17, so the mismatch path is exercised.
KEY_BITS = 16


@pytest.fixture(scope="module")
def corpus():
    spec = CorpusSpec(n_files=3, records_per_file=500, key_bits=KEY_BITS)
    root = Path(tempfile.mkdtemp()) / "corpus"
    generate_corpus(root, spec)
    return RecordStore(root), spec


@pytest.fixture(scope="module")
def targets(corpus):
    _, spec = corpus
    # extra_outside seeds the missing path (ids absent from the corpus)
    return intersect_host(
        db_id_list(spec, "chembl", extra_outside=15),
        db_id_list(spec, "emolecules", extra_outside=15),
    ).ids


def _assert_identical(a: ExtractionResult, b: ExtractionResult):
    """Byte-identical output: records (content AND order), missing, mismatches."""
    assert list(a.records.items()) == list(b.records.items())
    assert a.missing == b.missing
    assert a.mismatches == b.mismatches


# ---------------------------------------------------------------------------
# serial vs pipelined parity
# ---------------------------------------------------------------------------

def test_parity_full_id_index(corpus, targets):
    store, _ = corpus
    idx = build_index(store, key_mode="full_id")
    serial = extract(store, idx, targets, workers=0)
    piped = extract(store, idx, targets, workers=4)
    assert serial.found > 0 and len(serial.missing) > 0  # both paths exercised
    _assert_identical(serial, piped)
    assert piped.spans_read > 0
    assert piped.seeks == serial.seeks


def test_parity_collision_seeded_hashed_index(corpus, targets):
    """Mismatch + missing paths: hashed collisions fetch structurally
    different molecules; both read paths must report them identically."""
    store, _ = corpus
    idx = build_index(store, key_mode="hashed_key", key_bits=KEY_BITS)
    assert idx.stats.n_duplicate_keys > 0  # collisions actually seeded
    serial = extract(store, idx, targets, key_bits=KEY_BITS, workers=0)
    piped = extract(store, idx, targets, key_bits=KEY_BITS, workers=4)
    # the deterministic corpus seeds real mismatches AND real misses here
    assert len(serial.mismatches) > 0 and len(serial.missing) > 0
    _assert_identical(serial, piped)


def test_parity_with_cache_and_warm_rerun(corpus, targets):
    store, _ = corpus
    idx = build_index(store, key_mode="full_id")
    serial = extract(store, idx, targets, workers=0)
    cache = RecordCache(capacity=4096)
    cold = extract(store, idx, targets, workers=4, cache=cache)
    warm = extract(store, idx, targets, workers=4, cache=cache)
    _assert_identical(serial, cold)
    _assert_identical(serial, warm)
    assert cold.cache_hits == 0
    assert warm.cache_hits == warm.seeks      # fully warm
    assert warm.spans_read == 0               # no I/O at all
    assert warm.files_opened == 0


def test_parity_single_worker_and_unsorted(corpus, targets):
    store, _ = corpus
    idx = build_index(store, key_mode="full_id")
    serial = extract(store, idx, targets, workers=0)
    one = extract(store, idx, targets, workers=1)
    # sort_offsets=False is an access-pattern ablation: it must take the
    # serial loop (the engine has no unsorted mode) and still agree
    unsorted_ = extract(store, idx, targets, workers=4, sort_offsets=False)
    _assert_identical(serial, one)
    _assert_identical(serial, unsorted_)
    assert unsorted_.spans_read == 0  # engine did not run


def test_verify_backends_agree(corpus, targets):
    store, _ = corpus
    idx = build_index(store, key_mode="hashed_key", key_bits=KEY_BITS)
    s = extract(store, idx, targets, key_bits=KEY_BITS, workers=2,
                verify_backend="string")
    d = extract(store, idx, targets, key_bits=KEY_BITS, workers=2,
                verify_backend="digest")
    _assert_identical(s, d)


def test_compare_ids_batch_digest_fallback():
    exp = ["InChI=1S/a", "InChI=1S/b", "InChI=1S/c"]
    rec = ["InChI=1S/a", "InChI=1S/DIFFERENT", "InChI=1S/c"]
    assert compare_ids_batch(exp, rec, backend="digest") == [True, False, True]
    assert compare_ids_batch(exp, rec, backend="string") == [True, False, True]
    assert compare_ids_batch([], [], backend="digest") == []
    with pytest.raises(ValueError):
        compare_ids_batch(exp, rec, backend="nope")


# ---------------------------------------------------------------------------
# phase-timing split
# ---------------------------------------------------------------------------

def test_seconds_split_into_plan_and_read(corpus, targets):
    store, _ = corpus
    idx = build_index(store, key_mode="full_id")
    res = extract(store, idx, targets)
    assert res.plan_seconds > 0 and res.read_seconds > 0
    assert res.seconds == res.plan_seconds + res.read_seconds


# ---------------------------------------------------------------------------
# span coalescing
# ---------------------------------------------------------------------------

def test_coalesce_merges_within_gap_threshold():
    guess, gap = 100, 50
    # second offset exactly at provisional_end + gap: still merges (<=)
    spans = coalesce_spans([(0, 0), (1, guess + gap)], gap=gap, guess=guess)
    assert len(spans) == 1
    assert spans[0].start == 0 and spans[0].end == guess + gap + guess
    # one byte past the threshold: splits
    spans = coalesce_spans([(0, 0), (1, guess + gap + 1)], gap=gap, guess=guess)
    assert len(spans) == 2
    assert [s.start for s in spans] == [0, guess + gap + 1]


def test_coalesce_max_span_bounds_merged_reads():
    """Dense targets within the gap still split once the merged span would
    exceed max_span — bounds per-worker pread buffers on huge files."""
    offsets = [(i, i * 100) for i in range(100)]
    merged = coalesce_spans(offsets, gap=1 << 30, guess=100, max_span=1 << 30)
    assert len(merged) == 1
    capped = coalesce_spans(offsets, gap=1 << 30, guess=100, max_span=1000)
    assert len(capped) > 1
    assert all(s.end - s.start <= 1000 for s in capped)
    assert sorted(m[0] for s in capped for m in s.members) == list(range(100))
    with pytest.raises(ValueError):
        coalesce_spans(offsets, max_span=0)


def test_coalesce_sorts_clamps_and_validates():
    spans = coalesce_spans([(1, 500), (0, 0)], gap=10_000, guess=100,
                           file_size=550)
    assert len(spans) == 1
    assert spans[0].end == 550                       # clamped to file size
    assert [m[0] for m in spans[0].members] == [0, 1]  # offset order
    with pytest.raises(ValueError):
        coalesce_spans([(0, 0)], gap=-1)
    with pytest.raises(ValueError):
        coalesce_spans([(0, 0)], guess=0)


def test_gap_knob_controls_spans_read(corpus):
    """gap=0 keeps sparse targets in separate preads; a huge gap merges a
    file's whole target set into one span."""
    store, spec = corpus
    idx = build_index(store, key_mode="full_id")
    targets = db_id_list(spec, "chembl")  # every 7th record: sparse-ish
    tight = extract(store, idx, targets, workers=1, coalesce_gap=0,
                    span_guess=64)
    merged = extract(store, idx, targets, workers=1,
                     coalesce_gap=1 << 30, span_guess=64)
    _assert_identical(tight, merged)
    assert merged.files_opened == len(store)
    # fully merged: one initial span per file (+ tail extensions)
    assert merged.spans_read < tight.spans_read
    assert tight.spans_read >= len(targets)  # one span (or more) per record


# ---------------------------------------------------------------------------
# records spanning span boundaries (tail-extension path)
# ---------------------------------------------------------------------------

def test_records_spanning_span_boundaries(corpus, targets):
    """A span guess far smaller than a record forces repeated tail
    extensions; the split must still be byte-identical to the serial scan."""
    store, _ = corpus
    idx = build_index(store, key_mode="full_id")
    serial = extract(store, idx, targets, workers=0)
    for guess in (1, 7, 64):
        tiny = extract(store, idx, targets, workers=2, span_guess=guess)
        _assert_identical(serial, tiny)
        assert tiny.spans_read > serial.found  # extensions actually happened


def test_delimiter_straddling_and_tail_record(tmp_path):
    """Delimiter split across pread boundaries, $$$$ inside record data, and
    an unterminated final record all match the serial reader."""
    from repro.core.records import read_record_at

    path = tmp_path / "t.sdf"
    rec_a = "line one\ndata $$$$ not a terminator\nlast\n"
    rec_b = "short\n"
    rec_c = "unterminated tail record\n"
    raw = rec_a + "$$$$\n" + rec_b + "$$$$\n" + rec_c
    path.write_text(raw, encoding="utf-8")
    offs = [0, len(rec_a) + 5, len(rec_a) + 5 + len(rec_b) + 5]

    from repro.core.reader import ReadStats, stream_plan

    class _OneFileStore:
        def path_of(self, name):
            return path

    for guess in range(1, 9):  # every tiny guess slides the pread boundary
        plan = {"t.sdf": [(f"id{i}", f"id{i}", off) for i, off in enumerate(offs)]}
        stats = ReadStats()
        events = list(stream_plan(_OneFileStore(), plan, verify=False,
                                  workers=1, span_guess=guess,
                                  coalesce_gap=0, stats=stats))
        texts = {ev.offset: ev.text for ev in events}
        for off in offs:
            assert texts[off] == read_record_at(path, off), (guess, off)
    assert texts[offs[0]] == rec_a and texts[offs[2]] == rec_c


def test_offset_past_eof_degrades_like_serial(corpus):
    """A bogus offset beyond EOF must produce the serial path's outcome
    (empty read -> unparseable mismatch), not a crash."""
    store, _ = corpus
    from repro.core import ByteOffsetIndex

    fname = store.file_names()[0]
    idx = ByteOffsetIndex(key_mode="full_id")
    idx.add("InChI=1S/ghost", fname, 10**9)
    serial = extract(store, idx, ["InChI=1S/ghost"], workers=0)
    piped = extract(store, idx, ["InChI=1S/ghost"], workers=2)
    _assert_identical(serial, piped)
    assert len(piped.mismatches) == 1
    assert piped.mismatches[0].found_id == "<unparseable>"


def test_bulk_scanner_matches_line_reference(tmp_path):
    """iter_records/iter_record_offsets (bulk bytes.find scan) must be
    byte-exact vs the per-line reference on delimiter edge cases, at every
    chunk boundary."""
    import random

    import repro.core.records as R
    from repro.core.records import RECORD_DELIM, iter_record_offsets, iter_records

    def ref_records(path):
        with open(path, "rb") as f:
            offset = 0
            start = 0
            buf = []
            for line in f:
                if line.rstrip(b"\n\r") == RECORD_DELIM:
                    yield start, b"".join(buf).decode("utf-8", "replace")
                    offset += len(line)
                    start = offset
                    buf = []
                else:
                    buf.append(line)
                    offset += len(line)
            if buf and any(ln.strip() for ln in buf):
                yield start, b"".join(buf).decode("utf-8", "replace")

    pieces = [b"", b"\n", b"$$$$\n", b"$$$$", b"$$$$\r\n", b"$$$$\r\r\n",
              b"x$$$$\n", b"$$$$x\n", b"$$$$$\n", b"abc\n", b"  \n", b"\r\n",
              b"data $$$$ mid\n", b"$$$$$$$$\n", b"tail-no-newline"]
    rng = random.Random(7)
    old_chunk = R._READ_CHUNK
    try:
        for chunk in (4, 7, old_chunk):  # tiny chunks slide every boundary
            R._READ_CHUNK = chunk
            for trial in range(60):
                body = b"".join(
                    rng.choice(pieces) for _ in range(rng.randint(0, 10))
                )
                p = tmp_path / f"t_{chunk}_{trial}.sdf"
                p.write_bytes(body)
                want = list(ref_records(p))
                assert list(iter_records(p)) == want, (chunk, body)
                assert list(iter_record_offsets(p)) == [
                    s for s, t in want if t.strip()
                ], (chunk, body)
    finally:
        R._READ_CHUNK = old_chunk


# ---------------------------------------------------------------------------
# record cache
# ---------------------------------------------------------------------------

def test_cache_hit_miss_eviction_counters():
    c = RecordCache(capacity=2)
    assert c.get("f", 0) is None
    assert c.stats.misses == 1
    c.put("f", 0, "aaa")
    c.put("f", 1, "bbb", recomputed_id="id-b")
    assert c.get("f", 0) == ("aaa", None)
    assert c.get("f", 1) == ("bbb", "id-b")
    assert c.stats.hits == 2
    c.put("f", 2, "ccc")                 # evicts LRU
    assert c.stats.evictions == 1
    assert len(c) == 2
    # offset 0 was most-recently-used before the insert of 2 evicted... the
    # LRU order after the two gets is [0, 1]; inserting 2 evicts 0
    assert c.get("f", 0) is None
    assert c.get("f", 1) is not None and c.get("f", 2) is not None
    assert 0 < c.hit_rate < 1


def test_cache_refresh_keeps_verified_id_and_bounds_bytes():
    c = RecordCache(capacity=10, max_bytes=10)
    c.put("f", 0, "abcde", recomputed_id="id-a")
    c.put("f", 0, "abcde")               # refresh without id: id preserved
    assert c.get("f", 0) == ("abcde", "id-a")
    c.put("f", 1, "fghij")
    c.put("f", 2, "klmno")               # 15 bytes total > 10: evicts
    assert c.cached_bytes <= 10
    assert c.stats.evictions >= 1
    c.clear()
    assert len(c) == 0 and c.cached_bytes == 0
    with pytest.raises(ValueError):
        RecordCache(capacity=0)


def test_cache_skips_reparse_on_warm_verify(corpus, targets):
    """A warm verified hit is served without recompute: corrupting the file
    under a warm cache goes unnoticed (the documented staleness trade-off),
    proving no re-read/re-parse happened."""
    store, _ = corpus
    idx = build_index(store, key_mode="full_id")
    cache = RecordCache(capacity=4096)
    extract(store, idx, targets, workers=2, cache=cache)
    victim = store.files()[0]
    backup = victim.read_bytes()
    victim.write_bytes(b"GARBAGE " * 100)
    try:
        warm = extract(store, idx, targets, workers=2, cache=cache)
        assert not warm.mismatches and warm.spans_read == 0
    finally:
        victim.write_bytes(backup)


def test_cache_scan_resistance_slru():
    """One bulk sweep of one-touch inserts must not evict the protected
    working set (segmented-LRU admission: new entries ride probation)."""
    c = RecordCache(capacity=100)
    working = [("hot", i) for i in range(40)]
    for f, o in working:
        c.put(f, o, f"rec{o}")
    for f, o in working:
        assert c.get(f, o) is not None  # second touch: promoted
    assert c.stats.promotions == 40
    assert c.stats.probation_hits == 40
    assert c.protected_len == 40
    # the sweep: 1000 records touched exactly once
    for i in range(1000):
        c.put("sweep", i, "x" * 20)
    assert len(c) <= 100
    assert c.stats.evictions >= 900
    # the working set survived the sweep untouched
    for f, o in working:
        assert c.get(f, o) is not None, (f, o)
    assert c.protected_len == 40


def test_cache_protected_cap_demotes_not_evicts():
    c = RecordCache(capacity=10, protected_frac=0.5)  # protected cap 5
    for i in range(8):
        c.put("f", i, f"r{i}")
    for i in range(8):
        c.get("f", i)  # promote all 8 -> 3 demotions back to probation
    assert c.protected_len == 5
    assert c.probation_len == 3
    assert c.stats.demotions == 3
    assert len(c) == 8  # demotion never evicts
    # demoted entries are still hits (and re-promote)
    assert c.get("f", 0) is not None


def test_cache_validates_protected_frac():
    with pytest.raises(ValueError):
        RecordCache(capacity=10, protected_frac=0.0)
    with pytest.raises(ValueError):
        RecordCache(capacity=10, protected_frac=1.5)
    # protected can never fill the whole cache: one admission slot stays
    assert RecordCache(capacity=10, protected_frac=1.0).protected_capacity == 9


def test_cache_never_starves_admission():
    """A fully-promoted working set must not fossilize the cache: new
    entries stay admittable (and can earn promotion) afterwards."""
    c = RecordCache(capacity=4, protected_frac=1.0)
    for i in range(4):
        c.put("f", i, f"r{i}")
        c.get("f", i)  # promote
    c.put("f", 99, "new")
    assert c.get("f", 99) is not None  # admitted, not evicted on arrival
    # capacity=1 degenerates to a plain LRU of one, still admitting
    tiny = RecordCache(capacity=1)
    tiny.put("f", 0, "a")
    assert tiny.get("f", 0) is not None
    assert tiny.get("f", 0) is not None  # degenerate re-hit stays cached
    tiny.put("f", 1, "b")
    assert tiny.get("f", 1) is not None
    assert tiny.get("f", 0) is None
    assert tiny.stats.promotions == 0  # no protected segment to earn
    # byte budget: a promoted set filling max_bytes must give way when
    # the working set shifts (evict protected LRU, admit the newcomer)
    cb = RecordCache(capacity=100, max_bytes=400)
    for i in range(10):
        cb.put("f", i, "x" * 40)
        cb.get("f", i)  # promote; protected bytes == max_bytes
    for i in range(50):
        cb.put("g", i, "y" * 40)
    assert cb.get("g", 49) is not None  # newcomers are admitted
    assert cb.cached_bytes <= 400


# ---------------------------------------------------------------------------
# streaming API
# ---------------------------------------------------------------------------

def test_extract_iter_streams_verified_records(corpus, targets):
    store, _ = corpus
    idx = build_index(store, key_mode="hashed_key", key_bits=KEY_BITS)
    ref = extract(store, idx, targets, key_bits=KEY_BITS, workers=0)
    res = ExtractionResult()
    got = {}
    for full_id, text in extract_iter(store, idx, targets,
                                      key_bits=KEY_BITS, workers=3,
                                      result=res):
        got[full_id] = text
    assert got == ref.records
    assert res.missing == ref.missing
    assert res.mismatches == ref.mismatches
    assert res.seeks == ref.seeks
    assert res.records == {}  # the stream is the record channel


def test_extract_iter_abandoned_early_does_not_block(corpus, targets):
    """Breaking out of the stream must not stall on in-flight file workers
    (the pool drops queued files instead of joining everything)."""
    import time

    store, _ = corpus
    idx = build_index(store, key_mode="full_id")
    it = extract_iter(store, idx, targets, workers=4)
    first = next(it)
    t0 = time.perf_counter()
    it.close()
    assert time.perf_counter() - t0 < 5.0
    assert isinstance(first, tuple) and len(first) == 2
    # the engine stays fully usable afterwards
    ref = extract(store, idx, targets, workers=0)
    again = dict(extract_iter(store, idx, targets, workers=4))
    assert again == ref.records
