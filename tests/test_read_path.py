"""Zero-copy async read path: span backends, views, env knobs, fetch_async.

Complements test_extract_engine.py (engine parity/coalescing/cache) with
the backend-abstraction surface the async read path added: per-backend
byte parity on a collision-seeded corpus, the zero-copy RecordView
lifecycle (lazy decode, buffer release at the API boundary), fd hygiene
when a streaming consumer abandons early, the REPRO_READER_* env knobs,
verify-mode agreement, and the service's end-to-end async fetch.
"""

import gc
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import pytest

from repro.core import (
    RecordStore,
    build_index,
    extract,
    extract_iter,
    intersect_host,
    resolve_backend,
    uring_available,
)
from repro.core.extract import ExtractionResult, plan_extraction
from repro.core.iobackend import RecordView
from repro.core.reader import ReadStats, stream_plan
from repro.core.sdfgen import CorpusSpec, db_id_list, generate_corpus
from repro.core.verify import VerifyBatcher

KEY_BITS = 16  # collision-seeded: mismatch path is part of every parity run

BACKENDS = ["thread", "mmap"] + (["uring"] if uring_available() else [])


@pytest.fixture(scope="module")
def corpus():
    spec = CorpusSpec(n_files=3, records_per_file=500, key_bits=KEY_BITS)
    root = Path(tempfile.mkdtemp()) / "corpus"
    generate_corpus(root, spec)
    return RecordStore(root), spec


@pytest.fixture(scope="module")
def targets(corpus):
    _, spec = corpus
    return intersect_host(
        db_id_list(spec, "chembl", extra_outside=15),
        db_id_list(spec, "emolecules", extra_outside=15),
    ).ids


@pytest.fixture(scope="module")
def hashed_index(corpus):
    store, _ = corpus
    return build_index(store, key_mode="hashed_key", key_bits=KEY_BITS)


@pytest.fixture(scope="module")
def serial_ref(corpus, targets, hashed_index):
    store, _ = corpus
    res = extract(store, hashed_index, targets, key_bits=KEY_BITS, workers=0)
    assert res.mismatches, "corpus no longer seeds collisions"
    return res


def _assert_identical(a: ExtractionResult, b: ExtractionResult):
    assert list(a.records.items()) == list(b.records.items())
    assert a.missing == b.missing
    assert a.mismatches == b.mismatches


# ---------------------------------------------------------------------------
# per-backend parity + stats surface
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_parity_collision_seeded(corpus, targets, hashed_index,
                                         serial_ref, backend):
    store, _ = corpus
    res = extract(store, hashed_index, targets, key_bits=KEY_BITS,
                  workers=3, backend=backend)
    _assert_identical(serial_ref, res)
    assert res.read_backend == backend
    assert res.inflight_peak >= 1
    assert res.verify_records >= res.found


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_parity_extract_iter(corpus, targets, hashed_index,
                                     serial_ref, backend):
    store, _ = corpus
    seen = dict(extract_iter(store, hashed_index, targets,
                             key_bits=KEY_BITS, workers=2, backend=backend))
    assert seen == serial_ref.records


def test_depth_caps_inflight_spans(corpus, targets, hashed_index):
    if "uring" not in BACKENDS:
        pytest.skip("no io_uring on this kernel")
    store, _ = corpus
    res = extract(store, hashed_index, targets, key_bits=KEY_BITS,
                  workers=1, backend="uring", depth=3)
    assert 1 <= res.inflight_peak <= 3


# ---------------------------------------------------------------------------
# env knobs (repro.flags)
# ---------------------------------------------------------------------------

def test_reader_backend_env_steers_auto(corpus, targets, hashed_index,
                                        serial_ref, monkeypatch):
    store, _ = corpus
    monkeypatch.setenv("REPRO_READER_BACKEND", "thread")
    res = extract(store, hashed_index, targets, key_bits=KEY_BITS, workers=2)
    assert res.read_backend == "thread"
    _assert_identical(serial_ref, res)


def test_reader_depth_env(corpus, targets, hashed_index, monkeypatch):
    if "uring" not in BACKENDS:
        pytest.skip("no io_uring on this kernel")
    store, _ = corpus
    monkeypatch.setenv("REPRO_READER_DEPTH", "2")
    res = extract(store, hashed_index, targets, key_bits=KEY_BITS,
                  workers=1, backend="uring")
    assert res.inflight_peak <= 2


def test_verify_backend_env_steers_auto(corpus, targets, hashed_index,
                                        serial_ref, monkeypatch):
    store, _ = corpus
    monkeypatch.setenv("REPRO_VERIFY_BACKEND", "string")
    res = extract(store, hashed_index, targets, key_bits=KEY_BITS, workers=2)
    _assert_identical(serial_ref, res)


def test_resolve_backend_names():
    be = resolve_backend(None)
    try:
        assert be.name == ("uring" if uring_available() else "thread")
    finally:
        be.close()
    for name in ("thread", "mmap"):
        be = resolve_backend(name)
        try:
            assert be.name == name
        finally:
            be.close()
    with pytest.raises(ValueError):
        resolve_backend("not-a-backend")


# ---------------------------------------------------------------------------
# zero-copy invariant
# ---------------------------------------------------------------------------

def test_record_views_are_zero_copy_until_decode(corpus, targets,
                                                 hashed_index):
    store, _ = corpus
    plan, _missing = plan_extraction(hashed_index, targets, KEY_BITS)
    stats = ReadStats()
    events = list(stream_plan(store, plan, verify=True, workers=1,
                              stats=stats, backend="thread"))
    assert events
    views = [ev.payload for ev in events if ev.ok]
    assert views and all(isinstance(v, RecordView) for v in views)
    for v in views:
        assert not v.decoded
        rr = v.raw_range()
        assert rr is not None  # still pinned to its span buffer
        raw, lo, hi = rr
        assert bytes(memoryview(raw)[lo:hi]).decode("utf-8") == v.text
        # decode boundary: the view no longer pins the buffer...
        assert v.decoded and v.raw_range() is None and v.mem() is None
        # ...but the memoized text survives
        assert v.text.endswith("$$$$\n") or "$$$$" not in v.text


def test_span_buffer_shared_within_coalesced_span(corpus, hashed_index):
    """Records coalesced into one span must carve views of ONE buffer."""
    store, _ = corpus
    # dense targets: consecutive records of one db => spans merge
    _, spec = corpus
    dense = db_id_list(spec, "chembl")[:40]
    plan, _ = plan_extraction(hashed_index, dense, KEY_BITS)
    events = [ev for ev in stream_plan(
        store, plan, verify=False, workers=1,
        coalesce_gap=1 << 20, stats=ReadStats(), backend="thread",
    ) if ev.ok and isinstance(ev.payload, RecordView)]
    bufs = {id(ev.payload._buf) for ev in events}
    assert len(bufs) < len(events), "no span sharing happened"


# ---------------------------------------------------------------------------
# abandoned consumers leak nothing
# ---------------------------------------------------------------------------

def _corpus_fds(root: Path) -> int:
    """Open fds (or mmaps via their /proc symlink targets) into ``root``.

    Counting *corpus* fds instead of the process total keeps the test
    immune to unrelated fd churn from background threads earlier test
    modules leave behind (executors, JAX runtime, fork pools).
    """
    n = 0
    prefix = str(root)
    for fd in os.listdir("/proc/self/fd"):
        try:
            if os.readlink(f"/proc/self/fd/{fd}").startswith(prefix):
                n += 1
        except OSError:
            continue
    return n


@pytest.mark.parametrize("backend", BACKENDS)
def test_abandoned_extract_iter_leaks_no_fds(corpus, targets, hashed_index,
                                             backend):
    store, _ = corpus
    for _ in range(3):
        it = extract_iter(store, hashed_index, targets, key_bits=KEY_BITS,
                          workers=2, backend=backend)
        for _ev, _ in zip(range(3), it):
            pass
        it.close()
    # close() drops queued files but deliberately does NOT join in-flight
    # file workers (abandon must not stall) — poll until they drain.  A
    # real leak never reaches zero.
    deadline = time.monotonic() + 10.0
    while _corpus_fds(store.root) and time.monotonic() < deadline:
        gc.collect()
        time.sleep(0.05)
    assert _corpus_fds(store.root) == 0


# ---------------------------------------------------------------------------
# verify modes agree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["string", "vector", "process"])
def test_verify_modes_agree_with_reference(corpus, targets, hashed_index,
                                           serial_ref, mode):
    store, _ = corpus
    res = extract(store, hashed_index, targets, key_bits=KEY_BITS,
                  workers=2, verify_backend=mode)
    _assert_identical(serial_ref, res)


def test_verify_batcher_counts_batches():
    vb = VerifyBatcher("vector")
    stats = ReadStats()
    recs = [
        "junk\n  repro    junk\n    0.0000    0.0000    0.0000 C   0\n",
    ]
    ok, ids = vb.verify(["InChI=1S/nope"], recs, None, stats)
    assert ok == [False] and len(ids) == 1
    assert stats.verify_records == 1 and stats.verify_batches >= 1


# ---------------------------------------------------------------------------
# service: async end-to-end fetch
# ---------------------------------------------------------------------------

def test_fetch_async_parity_and_read_stats(corpus, targets, hashed_index):
    from repro.service import QueryService, ServiceConfig

    store, _ = corpus
    sdir = Path(tempfile.mkdtemp()) / "istore"
    hashed_index.save_sharded(sdir, n_shards=4)
    with QueryService(store, sdir, ServiceConfig(replicas=1)) as svc:
        sync = svc.fetch(targets, key_bits=KEY_BITS)
        fut = svc.fetch_async(targets, key_bits=KEY_BITS)
        res = fut.result(timeout=60)
        _assert_identical(sync, res)
        s = svc.stats()["read"]
        for key in ("backend", "spans_read", "bytes_read", "records",
                    "inflight_peak", "verify_batches", "verify_records",
                    "verify_batch_max"):
            assert key in s, key
        assert s["backend"] in ("uring", "thread", "mmap", "serial")
        assert s["records"] > 0 and s["verify_records"] > 0


# ---------------------------------------------------------------------------
# scaled benchmark corpus (the --scale knob)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_bench_scale_flag_multiplies_corpus(tmp_path):
    """`benchmarks.run --scale N` multiplies records-per-file and the
    scaled engine bench still reports parity."""
    extract_json = tmp_path / "BENCH_extract.json"
    env = dict(os.environ)
    env.update(
        PYTHONPATH=str(Path(__file__).resolve().parents[1] / "src"),
        REPRO_BENCH_FILES="2",
        REPRO_BENCH_RPF="120",
        REPRO_BENCH_CACHE=str(tmp_path / "bench_cache"),
        REPRO_BENCH_EXTRACT_OUT=str(extract_json),
        REPRO_BENCH_SERVICE_OUT=str(tmp_path / "BENCH_service.json"),
        REPRO_BENCH_SERVICE_SECONDS="0.4",
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--scale", "3"],
        capture_output=True, text=True, env=env, timeout=560,
        cwd=Path(__file__).resolve().parents[1],
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    m = json.loads(extract_json.read_text())
    assert m["corpus"]["records_per_file"] == 360  # 120 x 3
    assert m["parity"] is True
    assert m["backends"], "per-backend cold rows missing"
