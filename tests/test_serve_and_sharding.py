"""Serving engine + sharding-rule tests (incl. an 8-device subprocess)."""

import dataclasses
import json
import os
import subprocess
import sys
import tempfile
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist.logical import DEFAULT_RULES, divisible_spec
from repro.models.registry import build_model
from repro.serve.engine import Engine, ServeConfig


def _tiny_cfg(**kw):
    base = dataclasses.replace(
        get_config("yi-6b"),
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=300,
    )
    return dataclasses.replace(base, **kw)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def test_engine_greedy_deterministic_and_eos():
    cfg = _tiny_cfg()
    api = build_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=8, max_len=64))
    r1 = eng.generate(["InChI=1S/C4", "InChI=1S/C4"])
    assert r1[0].token_ids == r1[1].token_ids  # batch determinism
    r2 = eng.generate(["InChI=1S/C4"])
    assert r2[0].token_ids == r1[0].token_ids  # batch-size invariance
    assert all(len(r.token_ids) <= 8 for r in r1)


def test_engine_respects_prompt_lengths():
    cfg = _tiny_cfg()
    api = build_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=4, max_len=64))
    rs = eng.generate(["ab", "abcdef"])
    assert rs[0].prompt_len == 3 and rs[1].prompt_len == 7  # +BOS


# ---------------------------------------------------------------------------
# logical sharding rules
# ---------------------------------------------------------------------------

def test_rules_drop_missing_mesh_axes():
    # single-pod mesh has no "pod" axis: batch rule must degrade to data-only
    assert DEFAULT_RULES.mesh_axes("batch", ("data", "model")) == "data"
    assert DEFAULT_RULES.mesh_axes("batch", ("pod", "data", "model")) == (
        "pod", "data",
    )
    assert DEFAULT_RULES.mesh_axes("nonexistent", ("data", "model")) is None


def test_rules_no_duplicate_mesh_axis_in_spec():
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        axis_names = ("data", "model")

    spec = DEFAULT_RULES.spec(("d_ff", "vocab"), FakeMesh())  # both → model
    flat = [s for s in spec if s is not None]
    assert flat == ["model"] or flat == [("model",)] or len(flat) == 1


def test_divisible_spec_drops_uneven_axes():
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    # 12 heads over model=16 → dropped; 32-dim over data=16 → kept
    out = divisible_spec(P("data", "model"), (32, 12), FakeMesh())
    assert tuple(out) == ("data", None)


# ---------------------------------------------------------------------------
# multi-device (8 fake CPU devices in a subprocess)
# ---------------------------------------------------------------------------

SUBPROCESS_PROG = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models.registry import build_model
    from repro.launch.mesh import make_mesh
    from repro.launch.sharding import batch_shardings, shardings_from_specs

    cfg = dataclasses.replace(
        get_config("qwen3-moe-235b-a22b").smoke(),
        n_layers=2, capacity_factor=8.0,
    )
    api = build_model(cfg)
    params, specs = api.init(jax.random.PRNGKey(0))
    B, S = 4, 32
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab_size),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    # single-device reference
    loss_ref, _ = jax.jit(api.loss)(params, batch)

    mesh = make_mesh((2, 4), ("data", "model"))
    with mesh:
        psh = shardings_from_specs(mesh, specs, params)
        bsh = batch_shardings(mesh, {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                                     for k, v in batch.items()})
        params_s = jax.device_put(params, psh)
        batch_s = jax.device_put(batch, bsh)
        loss_sharded, _ = jax.jit(api.loss)(params_s, batch_s)
    out = {
        "ref": float(loss_ref),
        "sharded": float(loss_sharded),
        "n_dev": jax.device_count(),
    }
    print("RESULT:" + json.dumps(out))
    """
)


def test_moe_sharded_equals_single_device():
    """shard_map MoE on a 2×4 mesh reproduces the single-device loss."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_PROG],
        capture_output=True, text=True, env=env, timeout=500,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["n_dev"] == 8
    assert abs(out["ref"] - out["sharded"]) < 0.03, out
