"""Data pipeline + checkpoint + fault-tolerance + compression tests."""

import dataclasses
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import (
    CheckpointManager,
    load_catalog,
    read_tensor,
    restore_pytree,
    save_pytree,
)
from repro.configs import get_config
from repro.core import RecordStore, build_index
from repro.core.sdfgen import CorpusSpec, generate_corpus
from repro.data.pipeline import BatchLoader, IndexedDataset
from repro.data.sampler import FeistelShuffle, GlobalSampler
from repro.dist.compress import (
    ErrorFeedbackCompressor,
    dequantize_int8,
    quantize_int8,
)
from repro.runtime.fault import ElasticPlan, FailureDetector, Heartbeat, run_with_failures
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def data():
    spec = CorpusSpec(n_files=2, records_per_file=400)
    root = Path(tempfile.mkdtemp()) / "c"
    generate_corpus(root, spec)
    store = RecordStore(root)
    idx = build_index(store)
    return IndexedDataset(store, idx, seq_len=96), spec


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 3000), seed=st.integers(0, 2**30))
def test_feistel_is_permutation(n, seed):
    f = FeistelShuffle(n, seed)
    step = max(1, n // 97)
    seen = [f(i) for i in range(0, n, step)]
    assert all(0 <= x < n for x in seen)
    if n <= 512:
        full = [f(i) for i in range(n)]
        assert sorted(full) == list(range(n))


@pytest.mark.parametrize("n_dp", [1, 2, 4, 8])
def test_sampler_elastic_equivalence(n_dp, data):
    ds, _ = data
    smp = GlobalSampler(len(ds), global_batch=8)
    want = smp.all_ids(step=5)
    got = []
    for r in range(n_dp):
        got += smp.example_ids(5, r, n_dp)
    assert got == want


def test_sampler_covers_epoch_without_repeats(data):
    ds, _ = data
    smp = GlobalSampler(100, global_batch=10)
    seen = []
    for step in range(10):
        seen += smp.all_ids(step)
    assert sorted(seen) == list(range(100))  # one full epoch, no dup/miss


# ---------------------------------------------------------------------------
# pipeline
# ---------------------------------------------------------------------------

def test_batch_shapes_and_masks(data):
    ds, _ = data
    smp = GlobalSampler(len(ds), global_batch=4)
    b = ds.batch_for(smp, 0, 0, 1)
    assert b["tokens"].shape == (4, 96) and b["tokens"].dtype == np.int32
    assert b["loss_mask"].shape == (4, 96)
    assert (b["loss_mask"].sum(1) > 0).all()


def test_loader_prefetch_and_straggler(data):
    ds, _ = data
    smp = GlobalSampler(len(ds), global_batch=4)
    calls = {"n": 0}

    def flaky(step):
        calls["n"] += 1
        if step == 1 and calls["n"] < 3:
            time.sleep(0.4)
        return ds.batch_for(smp, step, 0, 1)

    bl = BatchLoader(ds, smp, deadline_s=0.05, fetch_fn=flaky)
    bl.start()
    steps = [bl.get(timeout=30)[0] for _ in range(3)]
    bl.stop()
    assert steps == [0, 1, 2]
    assert bl.stats.deadline_misses >= 1 and bl.stats.retries >= 1


def test_fetch_verification_detects_corruption(data):
    ds, _ = data
    key = ds.keys[3]
    fname, off = ds.index.lookup(key)
    path = ds.store.path_of(fname)
    raw = bytearray(path.read_bytes())
    # corrupt one structural byte of that record's atom block: flip the
    # first carbon's element symbol (changes the canonical id)
    probe = raw[off : off + 2000].find(b" C  ")
    assert probe > 0
    raw[off + probe + 1] = ord("N")
    backup = path.read_bytes()
    path.write_bytes(bytes(raw))
    try:
        before = ds.stats.verify_failures
        out = ds.fetch_many([key])
        assert key not in out
        assert ds.stats.verify_failures == before + 1
    finally:
        path.write_bytes(backup)


# ---------------------------------------------------------------------------
# checkpoint catalog
# ---------------------------------------------------------------------------

def test_catalog_partial_restore_and_offsets(tmp_path):
    tree = {
        "w": np.arange(12, dtype=np.float32).reshape(3, 4),
        "nested": {"b": np.ones((5,), np.int64)},
    }
    d = tmp_path / "ck"
    save_pytree(tree, d, meta={"step": 9})
    cat = load_catalog(d)
    assert set(cat) == {"w", "nested/b"}
    # O(1) partial restore of one tensor via its byte offset
    w = read_tensor(d, cat["w"])
    np.testing.assert_array_equal(w, tree["w"])
    # offsets are disjoint and ordered
    spans = sorted((e.byte_offset, e.byte_offset + e.nbytes) for e in cat.values())
    for (a0, a1), (b0, _) in zip(spans, spans[1:]):
        assert a1 <= b0


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    tree = {"x": np.zeros((4,), np.float32)}
    for s in (1, 2, 3, 4):
        tree["x"] = tree["x"] + 1
        mgr.save(s, tree)
    assert mgr.steps() == [3, 4]
    step, back = mgr.restore({"x": np.zeros((4,), np.float32)})
    assert step == 4 and back["x"][0] == 4


def test_manager_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=3)
    tree = {"x": np.random.default_rng(0).normal(size=(256, 256)).astype(np.float32)}
    mgr.save(7, tree, blocking=False)
    mgr.wait()
    _, back = mgr.restore(tree)
    np.testing.assert_array_equal(back["x"], tree["x"])


# ---------------------------------------------------------------------------
# trainer: crash + elastic recovery
# ---------------------------------------------------------------------------

def _tiny_cfg():
    return dataclasses.replace(
        get_config("yi-6b"),
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=300,
    )


def test_trainer_crash_restore_continues_exactly(data, tmp_path):
    ds, _ = data
    tcfg = TrainerConfig(seq_len=96, global_batch=4, steps=9, ckpt_every=3,
                         opt=AdamWConfig(warmup_steps=2, total_steps=9))
    # uninterrupted reference run
    tr_ref = Trainer(_tiny_cfg(), tcfg, ds, tmp_path / "ref")
    _, _, hist_ref = tr_ref.run()
    # crashed + resumed run
    tr_a = Trainer(_tiny_cfg(), tcfg, ds, tmp_path / "crash")
    reached, _, hist_a = tr_a.run(die_at_step=5)
    assert reached == 5 and tr_a.ckpt.latest_step() == 3
    tr_b = Trainer(_tiny_cfg(), tcfg, ds, tmp_path / "crash")
    _, _, hist_b = tr_b.run()
    assert hist_b[0]["step"] == 3
    # loss trajectory after resume matches the uninterrupted run bitwise-ish
    ref = {h["step"]: h["loss"] for h in hist_ref}
    for h in hist_b:
        assert abs(h["loss"] - ref[h["step"]]) < 1e-4, (h["step"], h["loss"], ref[h["step"]])


def test_run_with_failures_elastic_plan(tmp_path, data):
    ds, _ = data
    log_steps = []

    def chunk(start, until, n_dp):
        log_steps.append((start, until, n_dp))
        return until, {}

    log = run_with_failures(12, chunk, fail_at={4: 1, 8: 1}, initial_dp=4)
    kinds = [e["kind"] for e in log.events]
    assert kinds.count("failure") == 2
    assert log_steps == [(0, 4, 4), (4, 8, 3), (8, 12, 2)]
    assert ElasticPlan.for_survivors(3, 16).n_dp == 3


def test_heartbeat_detector(tmp_path):
    hb = Heartbeat(tmp_path, 0)
    hb.beat(5)
    det = FailureDetector(tmp_path, n_workers=2, timeout=10.0)
    assert det.alive() == [0] and det.dead() == [1]


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_quantization_bounds():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32)) * 3.0
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-6


def test_error_feedback_preserves_signal_over_steps():
    """Σ compressed grads ≈ Σ true grads (error feedback drains residual)."""
    comp = ErrorFeedbackCompressor()
    params = {"w": jnp.zeros((32,), jnp.float32)}
    state = {"ef_residual": comp.init(params)}
    rng = np.random.default_rng(1)
    total_true = np.zeros((32,), np.float32)
    total_comp = np.zeros((32,), np.float32)
    for _ in range(50):
        g = {"w": jnp.asarray(rng.normal(size=(32,)).astype(np.float32) * 1e-3)}
        cg, state = comp.apply(g, state)
        total_true += np.asarray(g["w"])
        total_comp += np.asarray(cg["w"])
    resid = np.asarray(state["ef_residual"]["w"])
    np.testing.assert_allclose(total_comp + resid, total_true, atol=1e-5)


def test_trainer_with_compression_trains(data, tmp_path):
    ds, _ = data
    tcfg = TrainerConfig(seq_len=96, global_batch=4, steps=6, ckpt_every=6,
                         compress_grads=True,
                         opt=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=6))
    tr = Trainer(_tiny_cfg(), tcfg, ds, tmp_path / "comp")
    _, state, hist = tr.run()
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert "ef_residual" in state
