"""End-to-end behaviour tests for the paper's system.

The full integration pipeline (corpus → index → intersect → extract →
validated dataset → LM training on it) with exact ground-truth counts,
plus the §VI collision-discovery/migration narrative as an invariant.
"""

import dataclasses
import tempfile
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    RecordStore,
    build_index,
    extract,
    intersect_host,
    intersect_sorted,
    scan_corpus,
)
from repro.core.records import extract_property
from repro.core.sdfgen import (
    PROP_XLOGP,
    CorpusSpec,
    db_id_list,
    generate_corpus,
    ground_truth_final_dataset,
    ground_truth_intersection,
)
from repro.data.pipeline import IndexedDataset
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def corpus():
    spec = CorpusSpec(n_files=4, records_per_file=600, key_bits=20)
    root = Path(tempfile.mkdtemp()) / "c"
    generate_corpus(root, spec)
    return RecordStore(root), spec


def test_full_integration_funnel(corpus):
    """Fig. 1: universe → B∩C → ∩pubchem → property-complete, all exact."""
    store, spec = corpus
    idx = build_index(store, workers=2)
    chembl = db_id_list(spec, "chembl", extra_outside=15)
    emol = db_id_list(spec, "emolecules", extra_outside=15)
    inter = intersect_host(chembl, emol)
    assert intersect_sorted(chembl, emol).ids == inter.ids

    res = extract(store, idx, inter.ids)
    gt = ground_truth_intersection(spec)
    assert res.found == len(gt)
    assert len(res.missing) == 15
    assert not res.mismatches

    with_prop = sum(
        1 for r in res.records.values()
        if extract_property(r, PROP_XLOGP) is not None
    )
    assert with_prop == len(ground_truth_final_dataset(spec))


def test_collision_discovery_and_migration_invariant(corpus):
    """hashed-key pipeline loses ≥0 records to collisions; the full-id
    migration recovers every one of them with zero mismatches."""
    store, spec = corpus
    targets = db_id_list(spec, "chembl")
    idx_h = build_index(store, key_mode="hashed_key", key_bits=18,
                        recompute_keys=True)
    res_h = extract(store, idx_h, targets, key_bits=18)
    rep = scan_corpus(store, key_bits=18)
    # at 18 bits over 2400 records, collisions are near-certain (E≈11)
    assert rep.n_colliding_keys > 0
    assert len(res_h.mismatches) + idx_h.stats.n_duplicate_keys > 0

    idx_f = build_index(store, key_mode="full_id")
    res_f = extract(store, idx_f, targets)
    assert not res_f.mismatches
    assert res_f.found == len(targets)
    assert res_f.found >= res_h.found


def test_training_on_validated_dataset(corpus, tmp_path):
    """The extracted dataset trains an LM end to end (loss decreases)."""
    store, spec = corpus
    idx = build_index(store)
    ds = IndexedDataset(store, idx, seq_len=96)
    cfg = dataclasses.replace(
        get_config("yi-6b"),
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=300,
    )
    tcfg = TrainerConfig(seq_len=96, global_batch=4, steps=8, ckpt_every=4,
                         opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=8))
    tr = Trainer(cfg, tcfg, ds, tmp_path)
    _, _, hist = tr.run()
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert tr.ckpt.latest_step() == 8
    assert ds.stats.verify_failures == 0
