"""Similarity-search tests: fingerprint folding, Tanimoto backends
(oracle / blocked host / interpreted Pallas kernel) byte-parity, the
store's fingerprint sidecars, deterministic cross-shard tie-breaking,
and the service-level batched ``similar`` path (+ the asyncio fetch).
"""

import asyncio
import tempfile
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    ByteOffsetIndex,
    IndexStore,
    RecordStore,
    build_index,
    extract,
    intersect_host,
)
from repro.core.fingerprint import (
    DEFAULT_FP_BITS,
    _POP_LUT,
    fingerprint_batch,
    fold_fingerprint,
    popcount_u32,
    words_for,
)
from repro.core.sdfgen import CorpusSpec, db_id_list, generate_corpus
from repro.core.store import merge_similar_topk
from repro.kernels.tanimoto.ops import (
    tanimoto_topk,
    tanimoto_topk_host,
    tanimoto_topk_pallas,
)
from repro.kernels.tanimoto.ref import (
    PAD_INDEX,
    PAD_SCORE,
    tanimoto_topk_naive,
    tanimoto_topk_ref,
)
from repro.service import QueryService, ServiceConfig, ShardRouter


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

# repetitions of "ABC" share one trigram *set* {ABC, BCA, CAB}: distinct
# keys, byte-identical folded fingerprints — a seeded tie flood
TIE_KEYS = ["ABC" * r for r in range(2, 12)]


@pytest.fixture(scope="module")
def tie_store_dir():
    """Sharded store seeding equal-fingerprint keys across shards/files."""
    idx = ByteOffsetIndex(key_mode="full_id")
    for i, key in enumerate(TIE_KEYS):
        idx.add(key, f"f_{i % 4:02d}.sdf", 1000 + i * 64)
    for i in range(300):
        idx.add(f"FILLER/{i:05d}", f"f_{i % 4:02d}.sdf", 50_000 + i * 64)
    sdir = Path(tempfile.mkdtemp()) / "tie_store"
    idx.save_sharded(sdir, n_shards=8)
    return sdir, idx


@pytest.fixture(scope="module")
def corpus():
    spec = CorpusSpec(n_files=3, records_per_file=400, key_bits=16)
    root = Path(tempfile.mkdtemp()) / "corpus"
    generate_corpus(root, spec)
    return RecordStore(root), spec


@pytest.fixture(scope="module")
def corpus_store_dir(corpus):
    store, _ = corpus
    idx = build_index(store, key_mode="full_id")
    sdir = Path(tempfile.mkdtemp()) / "istore"
    idx.save_sharded(sdir, n_shards=8)
    return sdir, sorted(idx.entries.keys())


# ---------------------------------------------------------------------------
# fingerprint folding
# ---------------------------------------------------------------------------

def test_fold_deterministic_and_batch_consistent():
    texts = ["InChI=1S/C2H6O/c1-2-3/h3H,2H2,1H3", "xyz", "ab", ""]
    fps, counts = fingerprint_batch(texts)
    assert fps.shape == (4, words_for(DEFAULT_FP_BITS))
    for i, t in enumerate(texts):
        assert np.array_equal(fps[i], fold_fingerprint(t))
        assert counts[i] == popcount_u32(fps[i]).sum()
    again, _ = fingerprint_batch(texts)
    assert np.array_equal(fps, again)
    assert (counts[:2] > 0).all()


def test_equal_trigram_sets_collide():
    base = fold_fingerprint("ABCABC")
    for key in TIE_KEYS:
        assert np.array_equal(fold_fingerprint(key), base)
    assert not np.array_equal(fold_fingerprint("ABX"), base)


def test_words_for_validation():
    assert words_for(1024) == 32
    assert words_for(32) == 1
    for bad in (0, 16, 48, 96, -32):
        with pytest.raises(ValueError):
            words_for(bad)


def test_popcount_lut_matches_bitwise_count():
    rng = np.random.default_rng(3)
    a = rng.integers(0, 2**32, size=(37, 5), dtype=np.uint32)
    via_lut = _POP_LUT[np.ascontiguousarray(a).view(np.uint8)].reshape(
        *a.shape, 4
    ).sum(axis=-1, dtype=np.int32)
    assert np.array_equal(popcount_u32(a), via_lut)
    assert popcount_u32(np.uint32([0, 0xFFFFFFFF])).tolist() == [0, 32]


# ---------------------------------------------------------------------------
# backend byte-parity: oracle vs blocked host vs interpreted Pallas
# ---------------------------------------------------------------------------

def _rand_plane(rng, n, w):
    return rng.integers(0, 2**32, size=(n, w), dtype=np.uint32)


@pytest.mark.parametrize("qn,n,k", [(1, 1, 4), (7, 255, 8), (5, 3, 8)])
def test_host_backend_matches_oracle(qn, n, k):
    rng = np.random.default_rng(11)
    q, db = _rand_plane(rng, qn, 32), _rand_plane(rng, n, 32)
    if n >= 3:
        db[2] = db[0]  # duplicated rows: exact score ties
    ref = tanimoto_topk_ref(q, db, k)
    for kw in ({}, {"db_chunk": 100, "tile": 64}, {"tile": 7}):
        got = tanimoto_topk_host(q, db, k, **kw)
        assert np.array_equal(ref[0], got[0]) and np.array_equal(ref[1], got[1])


def test_host_backend_odd_width_and_empty():
    rng = np.random.default_rng(12)
    q, db = _rand_plane(rng, 3, 1), _rand_plane(rng, 40, 1)  # no uint64 view
    ref = tanimoto_topk_ref(q, db, 5)
    got = tanimoto_topk_host(q, db, 5)
    assert np.array_equal(ref[0], got[0]) and np.array_equal(ref[1], got[1])
    s, i = tanimoto_topk_host(np.zeros((2, 2), np.uint32),
                              np.zeros((0, 2), np.uint32), 3)
    assert (s == PAD_SCORE).all() and (i == PAD_INDEX).all()


def test_kernel_interpret_matches_oracle_with_ties():
    texts = ["ABCABC"] * 9 + [f"U{i:03d}" for i in range(30)]
    db, _ = fingerprint_batch(texts)
    q, _ = fingerprint_batch(["ABCABCABC", "U005"])
    ref = tanimoto_topk_ref(q, db, 6)
    kern = tanimoto_topk(q, db, 6, interpret=True)
    assert np.array_equal(ref[0], kern[0])
    assert np.array_equal(ref[1], kern[1])
    # the 9 identical rows tie at 1.0 and must surface lowest-row-first
    assert kern[1][0].tolist() == [0, 1, 2, 3, 4, 5]
    # k > n_db pads with the oracle sentinel
    s, i = tanimoto_topk(q[:1], db[:2], 5, interpret=True)
    assert (s[0, 2:] == PAD_SCORE).all() and (i[0, 2:] == PAD_INDEX).all()


def test_naive_loop_matches_batched():
    rng = np.random.default_rng(13)
    q, db = _rand_plane(rng, 6, 32), _rand_plane(rng, 90, 32)
    ref = tanimoto_topk_ref(q, db, 7)
    naive = tanimoto_topk_naive(q, db, 7)
    assert np.array_equal(ref[0], naive[0]) and np.array_equal(ref[1], naive[1])


def test_dispatcher_host_path_is_blocked_backend():
    rng = np.random.default_rng(14)
    q, db = _rand_plane(rng, 4, 32), _rand_plane(rng, 64, 32)
    auto = tanimoto_topk(q, db, 5, use_pallas=False)
    host = tanimoto_topk_host(q, db, 5)
    assert np.array_equal(auto[0], host[0]) and np.array_equal(auto[1], host[1])


# ---------------------------------------------------------------------------
# store sidecars + similar_batch
# ---------------------------------------------------------------------------

def test_fingerprint_sidecars_roundtrip_and_incremental(tie_store_dir):
    sdir, idx = tie_store_dir
    st = IndexStore.open(sdir)
    assert st.fingerprint_bits == DEFAULT_FP_BITS
    assert all((sdir / f"shard_{s:04d}.fps.npy").exists()
               for s in range(st.n_shards)
               if int(st.manifest["shards"][s]["count"]) > 0)
    # unchanged republish skips every shard (fingerprints are a pure
    # function of the keys the content hash already covers)
    assert idx.save_sharded(sdir, n_shards=8)["written"] == 0
    # a width change invalidates the plane and forces a rewrite
    summary = idx.save_sharded(sdir, n_shards=8, fingerprint_bits=512)
    assert summary["written"] > 0
    assert IndexStore.open(sdir).fingerprint_bits == 512
    # disabling the plane cleans the sidecars up and similarity errors
    idx.save_sharded(sdir, n_shards=8, fingerprint_bits=None)
    st = IndexStore.open(sdir)
    assert st.fingerprint_bits is None
    assert not list(sdir.glob("*.fps.npy"))
    with pytest.raises(ValueError, match="no fingerprint plane"):
        st.similar_batch(np.zeros((1, 32), np.uint32), 4)
    # exact-key lookup is untouched by the plane's absence
    assert st.lookup_batch(TIE_KEYS[:3])[2].all()
    idx.save_sharded(sdir, n_shards=8)  # restore for later tests


def test_store_similar_matches_bruteforce_oracle(corpus_store_dir):
    sdir, keys = corpus_store_dir
    st = IndexStore.open(sdir)
    q, _ = fingerprint_batch(keys[::150][:8])
    scores, fids, offs = st.similar_batch(q, 5, probe="host")
    # brute force: score the whole corpus per shard, merge on the
    # two-level contract (score desc, file_id asc, offset asc)
    parts = []
    for s in range(st.n_shards):
        if int(st.manifest["shards"][s]["count"]) == 0:
            continue
        parts.append(st.similar_shard(s, q, 5, probe="host"))
    want = merge_similar_topk(parts, 5)
    assert np.array_equal(scores, want[0])
    assert np.array_equal(fids, want[1])
    assert np.array_equal(offs, want[2])
    # every query is a corpus key: rank-0 must be its own location, 1.0
    assert (scores[:, 0] == np.float32(1.0)).all()
    locs = st.locate_batch(keys[::150][:8])
    for i, loc in enumerate(locs):
        assert loc == (st.file_names[fids[i, 0]], int(offs[i, 0]))


def test_cross_shard_ties_break_by_file_then_offset(tie_store_dir):
    sdir, idx = tie_store_dir
    st = IndexStore.open(sdir)
    # the tie keys land on multiple shards (that's the point of the test)
    q = fold_fingerprint("ABCABC")[None, :]
    k = 4
    scores, fids, offs = st.similar_batch(q, k, probe="host")
    assert (scores[0] == np.float32(1.0)).all()
    # expected: all equal-score candidates ordered (file_id, offset)
    fmap = {name: i for i, name in enumerate(st.file_names)}
    cands = sorted(
        (fmap[f], o) for f, o in (idx.lookup(key) for key in TIE_KEYS)
    )
    assert [(int(f), int(o)) for f, o in zip(fids[0], offs[0])] == cands[:k]
    # shards were actually spanned, not one lucky bucket
    shard_span = {
        s for s in range(st.n_shards)
        for key in TIE_KEYS
        if st.lookup_batch([key])[2][0]
    }
    from repro.core.store import digest_u64, shard_of
    sids = shard_of(digest_u64(TIE_KEYS), st.n_shards, st.digest_bits)
    assert len(set(sids.tolist())) > 1


def test_merge_similar_topk_pads_and_ties():
    a = (
        np.array([[1.0, 0.5, 0.5]], np.float32),
        np.array([[2, 0, 3]], np.int32),
        np.array([[10, 99, 4]], np.int64),
    )
    b = (
        np.array([[1.0, -1.0, -1.0]], np.float32),
        np.array([[1, -1, -1]], np.int32),
        np.array([[7, -1, -1]], np.int64),
    )
    s, f, o = merge_similar_topk([a, b], 3)
    assert s[0].tolist() == [1.0, 1.0, 0.5]
    assert f[0].tolist() == [1, 2, 0]      # equal scores: file_id asc
    assert o[0].tolist() == [7, 10, 99]
    s, f, o = merge_similar_topk([b], 3)   # pads sort last, stay -1
    assert f[0].tolist() == [1, -1, -1] and s[0, 1] == PAD_SCORE


# ---------------------------------------------------------------------------
# router + service
# ---------------------------------------------------------------------------

def test_router_scatter_matches_inline(corpus_store_dir):
    sdir, keys = corpus_store_dir
    q, _ = fingerprint_batch(keys[::97][:6])
    with ShardRouter(sdir, replicas=2, probe="host") as rt:
        scattered = rt.similar_batch(q, 4)
        assert rt.stats.similar_scattered == 1
    with ShardRouter(sdir, replicas=1, probe="host") as rt:
        inline = rt.similar_batch(q, 4)
        assert rt.stats.similar_inline == 1
    for got, want in zip(scattered, inline):
        assert np.array_equal(got, want)


def test_service_similar_coalesces_and_slices(corpus, corpus_store_dir):
    store, _ = corpus
    sdir, keys = corpus_store_dir
    q, _ = fingerprint_batch(keys[::50][:8])
    with QueryService(store, sdir, ServiceConfig(replicas=2)) as svc:
        import threading
        outs = {}
        def client(i):
            outs[i] = svc.similar(q[i : i + 2], 3)
        ths = [threading.Thread(target=client, args=(i,)) for i in range(6)]
        for t in ths: t.start()
        for t in ths: t.join()
        st = IndexStore.open(sdir)
        for i, (s, f, o, deg) in outs.items():
            assert not deg.any()
            ws, wf, wo = st.similar_batch(q[i : i + 2], 3, probe="host")
            assert np.array_equal(s, ws) and np.array_equal(f, wf)
            assert np.array_equal(o, wo)
        sim = svc.stats()["similarity"]
        assert sim["scheduler"]["requests"] == 6
        # a 1-D query row is accepted; k above the probe width bypasses
        # the batcher but returns the same contract
        s1, f1, o1, _ = svc.similar(q[0], 2)
        assert s1.shape == (1, 2)
        big = svc.similar(q[:2], svc.config.similar_top_k + 8)
        assert big[0].shape == (2, svc.config.similar_top_k + 8)
        with pytest.raises(ValueError):
            svc.similar(q[:1], 0)


def test_service_similar_async_event_loop(corpus, corpus_store_dir):
    store, _ = corpus
    sdir, keys = corpus_store_dir
    q, _ = fingerprint_batch(keys[:4])
    with QueryService(store, sdir, ServiceConfig(replicas=1)) as svc:
        async def go():
            futs = [svc.similar_async(q[i : i + 1], 3) for i in range(4)]
            return [await asyncio.wrap_future(f) for f in futs]
        outs = asyncio.run(go())
        st = IndexStore.open(sdir)
        for i, (s, f, o, _) in enumerate(outs):
            ws, wf, wo = st.similar_batch(q[i : i + 1], 3, probe="host")
            assert np.array_equal(s, ws) and np.array_equal(f, wf)
            assert np.array_equal(o, wo)


def test_fetch_aio_matches_fetch(corpus):
    """satellite: the asyncio fetch path is byte-identical to fetch()."""
    store, spec = corpus
    targets = intersect_host(
        db_id_list(spec, "chembl", extra_outside=10),
        db_id_list(spec, "emolecules", extra_outside=10),
    ).ids
    idx = build_index(store, key_mode="hashed_key", key_bits=16)
    sdir = Path(tempfile.mkdtemp()) / "istore_aio"
    idx.save_sharded(sdir, n_shards=8)
    serial = extract(store, idx, targets, key_bits=16, workers=0)
    with QueryService(store, sdir, ServiceConfig(replicas=2)) as svc:
        sync = svc.fetch(targets, key_bits=16)

        async def go():
            return await svc.fetch_aio(targets, key_bits=16)

        aio = asyncio.run(go())
    for res in (sync, aio):
        assert list(res.records.items()) == list(serial.records.items())
        assert res.missing == serial.missing
        assert res.mismatches == serial.mismatches
