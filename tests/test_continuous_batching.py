"""Paged KV cache + continuous-batching scheduler tests.

The load-bearing claims, each pinned bitwise where possible:

* block allocator invariants (no double-free, deterministic reuse,
  exhaustion is backpressure — not corruption);
* the paged decode path is byte-identical to the dense-cache path;
* a block table rebuilt from freed-and-reused blocks decodes byte-
  identically to a fresh pool (eviction can't leak state);
* the continuous engine matches the static engine on uniform batches
  and per-prompt serial generation on ragged mixes;
* the static engine's ragged batches match per-prompt serial generation
  (the pad-logits regression: prefill must gather each sequence's true
  last-position logits, not the pad row's).
"""

import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import build_model
from repro.serve.engine import Engine, ServeConfig
from repro.serve.kvcache import (
    TRASH_BLOCK, BlockManager, PagedCacheSpec, blocks_for,
)
from repro.serve.scheduler import ContinuousEngine


def _tiny_cfg(**kw):
    base = dataclasses.replace(
        get_config("yi-6b"),
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=300,
    )
    return dataclasses.replace(base, **kw)


MAX_LEN, BS = 64, 8


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    params, _ = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def ref_engine(tiny):
    cfg, params = tiny
    # max_len must equal the paged view (blocks x block_size) for byte
    # parity; 20 tokens covers every per-request budget the tests use
    return Engine(cfg, params, ServeConfig(max_new_tokens=20, max_len=MAX_LEN))


def _spec(**kw):
    base = dict(n_blocks=33, block_size=BS, max_slots=3,
                max_blocks_per_seq=MAX_LEN // BS)
    base.update(kw)
    return PagedCacheSpec(**base)


# ---------------------------------------------------------------------------
# block allocator
# ---------------------------------------------------------------------------

def test_blocks_for():
    assert blocks_for(0, 8) == 0
    assert blocks_for(1, 8) == 1
    assert blocks_for(8, 8) == 1
    assert blocks_for(9, 8) == 2


def test_alloc_free_roundtrip_and_double_free():
    mgr = BlockManager(_spec(n_blocks=6, max_slots=2, max_blocks_per_seq=4))
    blocks = mgr.alloc(3)
    assert blocks is not None and len(set(blocks)) == 3
    assert TRASH_BLOCK not in blocks
    mgr.check()
    mgr.free(blocks)
    mgr.check()
    with pytest.raises(ValueError, match="double free"):
        mgr.free(blocks)
    with pytest.raises(ValueError, match="trash"):
        mgr.free([TRASH_BLOCK])


def test_alloc_exhaustion_counts_failures():
    mgr = BlockManager(_spec(n_blocks=4, max_slots=2, max_blocks_per_seq=4))
    assert mgr.alloc(4) is None          # only 3 usable (trash reserved)
    assert mgr.alloc_failures == 1
    got = mgr.alloc(3)
    assert got is not None and mgr.n_free == 0
    assert mgr.alloc(1) is None
    mgr.check()


def test_deterministic_reuse_after_free():
    # LIFO free list: freeing and re-allocating yields the same blocks in
    # the same order — the byte-parity-after-eviction tests rely on this
    mgr = BlockManager(_spec())
    a = mgr.alloc(4)
    mgr.free(a)
    b = mgr.alloc(4)
    assert b == list(reversed(a))
    mgr.free(b)
    assert mgr.alloc(4) == list(reversed(b))


def test_admit_release_tables():
    spec = _spec(n_blocks=9, max_slots=2, max_blocks_per_seq=4)
    mgr = BlockManager(spec)
    assert mgr.admit(0, 17)              # 3 blocks of 8
    assert mgr.admit(1, 25)              # 4 blocks
    assert not mgr.can_admit(9)          # 1 free < 2 needed
    with pytest.raises(ValueError, match="already admitted"):
        mgr.admit(0, 8)
    row = mgr.tables[0]
    assert (row[:3] != TRASH_BLOCK).all() and row[3] == TRASH_BLOCK
    mgr.check()
    mgr.release(0)
    assert (mgr.tables[0] == TRASH_BLOCK).all()
    with pytest.raises(ValueError, match="not admitted"):
        mgr.release(0)
    with pytest.raises(ValueError, match="table width"):
        mgr.admit(0, spec.max_len + 1)
    mgr.check()


def test_admit_whole_or_nothing():
    mgr = BlockManager(_spec(n_blocks=4, max_slots=2, max_blocks_per_seq=4))
    assert not mgr.admit(0, 32)          # needs 4, pool has 3
    assert mgr.n_free == 3 and mgr.n_in_use == 0   # state untouched
    assert mgr.alloc_failures == 1
    assert mgr.admit(0, 24)
    mgr.check()


# ---------------------------------------------------------------------------
# paged decode path (model level)
# ---------------------------------------------------------------------------

def test_paged_decode_bitwise_vs_dense(tiny):
    cfg, params = tiny
    api = build_model(cfg)
    assert api.supports_paged
    spec = _spec()
    mgr = BlockManager(spec)
    toks = jnp.asarray([[256] + list(b"InChI=1S/C4")], jnp.int32)
    L = toks.shape[1]
    batch = {"tokens": toks, "lengths": jnp.asarray([L], jnp.int32)}

    logits, dense = api.prefill(params, batch, max_len=MAX_LEN)
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = jnp.asarray([L], jnp.int32)
    cache = dense
    ref = []
    for _ in range(5):
        lg, cache = api.decode_step(params, cur, pos, cache)
        ref.append(np.asarray(lg))
        cur = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        pos = pos + 1

    # same sequence through the paged path, in slot 1 of a 3-slot batch
    # (slots 0/2 inactive: all-trash tables, pos 0 — their lanes must not
    # perturb slot 1's bytes)
    paged, _ = api.paged_cache_init(spec.n_blocks, BS)
    assert mgr.admit(1, L + 6)
    logits2, dense2 = api.prefill(params, batch, max_len=MAX_LEN)
    paged = api.paged_prefill_write(
        paged, dense2, jnp.asarray(mgr.tables[1]), BS
    )
    cur = jnp.zeros((3, 1), jnp.int32)
    cur = cur.at[1, 0].set(jnp.argmax(logits2[0]).astype(jnp.int32))
    pos = jnp.asarray([0, L, 0], jnp.int32)
    tables = jnp.asarray(mgr.tables)
    for step in range(5):
        lg, paged = api.decode_step_paged(params, cur, pos, tables, paged, BS)
        assert np.array_equal(np.asarray(lg[1:2]), ref[step])
        cur = cur.at[1, 0].set(jnp.argmax(lg[1]).astype(jnp.int32))
        pos = pos.at[1].add(1)


def test_reused_blocks_decode_identically_to_fresh(tiny):
    # evict a sequence, admit another into the recycled blocks, and the
    # recycled pool must produce the same bytes as a brand-new engine
    cfg, params = tiny
    spec = _spec()
    scfg = ServeConfig(max_new_tokens=10, max_len=MAX_LEN)
    churned = ContinuousEngine(cfg, params, spec, scfg)
    churned.generate(["InChI=1S/C4H10", "xylene", "C6H6"])  # churn + evict
    assert churned._mgr.stats()["frees"] > 0
    fresh = ContinuousEngine(cfg, params, spec, scfg)
    probe = ["InChI=1S/C8H9NO2/", "ab"]
    got = [r.token_ids for r in churned.generate(probe)]
    want = [r.token_ids for r in fresh.generate(probe)]
    assert got == want
    churned._mgr.check()
    churned.close()
    fresh.close()


# ---------------------------------------------------------------------------
# continuous engine vs static engine
# ---------------------------------------------------------------------------

def test_uniform_batch_matches_static_engine(tiny, ref_engine):
    cfg, params = tiny
    cont = ContinuousEngine(
        cfg, params, _spec(),
        ServeConfig(max_new_tokens=20, max_len=MAX_LEN),
    )
    texts = ["InChI=1S/", "C6H12O6/c", "smiles:CC"]
    want = [r.token_ids for r in ref_engine.generate(texts)]
    got = [r.token_ids for r in cont.generate(texts)]
    assert got == want
    cont.close()


def test_ragged_budgets_match_serial(tiny, ref_engine):
    cfg, params = tiny
    cont = ContinuousEngine(
        cfg, params, _spec(),
        ServeConfig(max_new_tokens=20, max_len=MAX_LEN),
    )
    ragged = ["ab", "InChI=1S/C4H10/c1-3-4-2", "xy", "C1=CC=CC=C1O"]
    budgets = [3, 20, 5, 9]
    futs = [cont.submit(t, b, lead=False) for t, b in zip(ragged, budgets)]
    cont._maybe_lead()
    got = [f.result(timeout=300).token_ids for f in futs]
    for t, b, g in zip(ragged, budgets, got):
        assert g == ref_engine.generate([t])[0].token_ids[:b]
    # after drain the only blocks still resident are the prefix index's
    # published prompt blocks; clearing it must empty the pool exactly
    cont.check()
    st = cont._mgr.stats()
    held = sum(1 for _ in cont._index.block_refs()) if cont._index else 0
    assert st["in_use"] == held
    if cont._index is not None:
        cont._index.clear()
    st = cont._mgr.stats()
    assert st["in_use"] == 0 and st["allocs"] == st["frees"]
    cont._mgr.check()
    cont.close()


def test_pool_exhaustion_is_admission_backpressure(tiny, ref_engine):
    cfg, params = tiny
    # pool fits ONE long sequence at a time: 5 usable blocks, each
    # request needs 4 — the second must queue, then run after eviction
    cont = ContinuousEngine(
        cfg, params,
        _spec(n_blocks=6, max_slots=2, max_blocks_per_seq=4),
        ServeConfig(max_new_tokens=20, max_len=32),
    )
    texts = ["InChI=1S/C4", "C1=CC=CC=C1"]
    futs = [cont.submit(t, 20, lead=False) for t in texts]
    cont._maybe_lead()
    got = [f.result(timeout=300).token_ids for f in futs]
    assert cont.stats.admission_stalls > 0, "requests never contended"
    assert cont.stats.peak_active == 1
    # backpressure must not change bytes: compare against serial
    ref32 = Engine(cfg, params, ServeConfig(max_new_tokens=20, max_len=32))
    for t, g in zip(texts, got):
        assert g == ref32.generate([t])[0].token_ids
    cont._mgr.check()
    cont.close()


def test_oversized_request_fails_cleanly(tiny):
    cfg, params = tiny
    cont = ContinuousEngine(
        cfg, params,
        _spec(n_blocks=4, max_slots=2, max_blocks_per_seq=4),
        ServeConfig(max_new_tokens=8, max_len=32),
    )
    # needs 4 blocks but only 3 usable exist: fails, doesn't hang/spin
    fut = cont.submit("InChI=1S/C8H9NO2/x", 13)
    with pytest.raises(RuntimeError, match="usable"):
        fut.result(timeout=60)
    # over the table width: rejected at submit
    fut2 = cont.submit("x" * 30, 8)
    with pytest.raises(ValueError, match="max_len"):
        fut2.result(timeout=60)
    # the engine still serves admissible requests afterwards
    r = cont.submit("ab", 4).result(timeout=300)
    assert len(r.token_ids) <= 4
    cont.close()


def test_concurrent_submits_and_slo(tiny, ref_engine):
    cfg, params = tiny
    cont = ContinuousEngine(
        cfg, params, _spec(),
        ServeConfig(max_new_tokens=20, max_len=MAX_LEN),
    )
    texts = ["InChI=1S/", "C6H12O6/c", "smiles:CC"] * 3
    outs = {}

    def worker(i, t):
        outs[i] = cont.submit(t, 8).result(timeout=300)

    ths = [threading.Thread(target=worker, args=(i, t))
           for i, t in enumerate(texts)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    for i, t in enumerate(texts):
        assert outs[i].token_ids == ref_engine.generate([t])[0].token_ids[:8]
    slo = cont.slo_ms()
    assert slo["ttft_p50_ms"] > 0 and slo["itl_p50_ms"] > 0
    assert cont.stats.completed == len(texts)
    c = cont.counters()
    assert c["tokens_out"] >= len(texts)  # counters are flat floats
    cont.close()
    with pytest.raises(RuntimeError, match="closed"):
        cont.submit("ab")


def test_unsupported_family_rejected(tiny):
    ssm_cfg = dataclasses.replace(
        get_config("mamba2-1.3b"),
        n_layers=2, d_model=64, vocab_size=300,
    )
    ssm_params, _ = build_model(ssm_cfg).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="paged"):
        ContinuousEngine(ssm_cfg, ssm_params, _spec())


# ---------------------------------------------------------------------------
# static engine regression: ragged prompts
# ---------------------------------------------------------------------------

def test_static_engine_ragged_matches_serial(tiny, ref_engine):
    # the pad-logits regression: a ragged right-padded batch must start
    # every continuation from its OWN last prompt token, so batch output
    # equals per-prompt serial output
    texts = ["ab", "abcdefgh", "xyz", "InChI=1S/C8H9NO2/"]
    batched = ref_engine.generate(texts)
    for t, r in zip(texts, batched):
        assert r.token_ids == ref_engine.generate([t])[0].token_ids
