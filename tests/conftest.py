"""Test-suite bootstrap: offline fallback shim for ``hypothesis``.

The property tests use a small slice of the hypothesis API (``given`` /
``settings`` / a handful of strategies).  On machines without network
access the package may be missing — rather than losing 5 test modules at
collection, this conftest installs a minimal deterministic stand-in into
``sys.modules`` *before* the test modules import.

The shim is NOT hypothesis: no shrinking, no example database, no
coverage-guided search.  It draws ``max_examples`` pseudo-random examples
from a fixed seed (plus min/max boundary examples for integer ranges), so
a property failure is reproducible but less thoroughly hunted.  When real
hypothesis is importable it is used untouched.
"""

from __future__ import annotations

import inspect
import random
import sys
import types
import zlib


def _install_hypothesis_shim() -> None:
    class Strategy:
        """Base: a deterministic ``example(rng, i)`` drawer."""

        def example(self, rng: random.Random, i: int):  # pragma: no cover
            raise NotImplementedError

    class Integers(Strategy):
        def __init__(self, min_value, max_value):
            self.lo, self.hi = int(min_value), int(max_value)

        def example(self, rng, i):
            # first two draws hit the boundaries — cheap edge coverage
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return rng.randint(self.lo, self.hi)

    class Floats(Strategy):
        def __init__(self, min_value, max_value):
            self.lo, self.hi = float(min_value), float(max_value)

        def example(self, rng, i):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return rng.uniform(self.lo, self.hi)

    class SampledFrom(Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def example(self, rng, i):
            if i < len(self.elements):
                return self.elements[i]
            return rng.choice(self.elements)

    class Characters(Strategy):
        def __init__(self, min_codepoint=32, max_codepoint=126, **_):
            self.lo, self.hi = int(min_codepoint), int(max_codepoint)

        def example(self, rng, i):
            return chr(rng.randint(self.lo, self.hi))

    class Text(Strategy):
        def __init__(self, alphabet=None, min_size=0, max_size=None):
            self.alphabet = alphabet
            self.min_size = int(min_size)
            self.max_size = int(max_size) if max_size is not None else self.min_size + 20

        def example(self, rng, i):
            n = rng.randint(self.min_size, self.max_size)
            out = []
            for _ in range(n):
                if self.alphabet is None:
                    out.append(chr(rng.randint(32, 126)))
                elif isinstance(self.alphabet, Strategy):
                    out.append(self.alphabet.example(rng, 2))
                else:
                    out.append(rng.choice(list(self.alphabet)))
            return "".join(out)

    class Lists(Strategy):
        def __init__(self, elements, min_size=0, max_size=None):
            self.elements = elements
            self.min_size = int(min_size)
            self.max_size = int(max_size) if max_size is not None else self.min_size + 20

        def example(self, rng, i):
            n = rng.randint(self.min_size, self.max_size)
            return [self.elements.example(rng, 2) for _ in range(n)]

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = lambda min_value=0, max_value=2**31 - 1: Integers(
        min_value, max_value
    )
    strategies.floats = lambda min_value=0.0, max_value=1.0: Floats(
        min_value, max_value
    )
    strategies.sampled_from = SampledFrom
    strategies.characters = Characters
    strategies.text = Text
    strategies.lists = Lists

    _DEFAULT_MAX_EXAMPLES = 20

    def given(*args, **strategy_kwargs):
        if args:
            raise TypeError("hypothesis shim supports keyword strategies only")

        def decorate(fn):
            sig = inspect.signature(fn)
            passthrough = [
                p for name, p in sig.parameters.items()
                if name not in strategy_kwargs
            ]

            def wrapper(*wargs, **wkwargs):
                n = getattr(wrapper, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
                # crc32, not hash(): str hashes are salted per process and
                # would make drawn examples unreproducible across runs
                rng = random.Random(
                    0xC0FFEE ^ zlib.crc32(fn.__qualname__.encode())
                )
                for i in range(n):
                    drawn = {
                        k: s.example(rng, i) for k, s in strategy_kwargs.items()
                    }
                    fn(*wargs, **wkwargs, **drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            # hide strategy params from pytest's fixture resolution
            wrapper.__signature__ = sig.replace(parameters=passthrough)
            return wrapper

        return decorate

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def decorate(fn):
            fn._shim_max_examples = int(max_examples)
            return fn

        return decorate

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strategies
    hyp.__shim__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies


try:  # pragma: no cover - exercised only where hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()
