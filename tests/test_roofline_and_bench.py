"""Roofline-extraction unit tests + benchmark-harness smoke test."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.launch.roofline import (
    HW,
    RooflineTerms,
    collective_bytes_from_hlo,
    model_flops,
)

SYNTH_HLO = """
HloModule jit_step
fused_computation {
  p0 = f32[128,256]{1,0} parameter(0)
  ROOT r = f32[128,256]{1,0} add(p0, p0)
}
ENTRY main {
  %x = bf16[1024,512]{1,0} parameter(0)
  %ag = bf16[1024,8192]{1,0} all-gather(bf16[1024,512]{1,0} %x), replica_groups={}
  %ar = f32[256,256]{1,0} all-reduce(f32[256,256]{1,0} %y), to_apply=add
  %rs = f32[64,256]{1,0} reduce-scatter(f32[1024,256]{1,0} %z), dimensions={0}
  %a2a = bf16[32,32]{1,0} all-to-all(bf16[32,32]{1,0} %w), dimensions={0}
  %cp = s32[16]{0} collective-permute(s32[16]{0} %v), source_target_pairs={{0,1}}
  %ags = bf16[8,8] all-gather-start(bf16[8,4] %q), replica_groups={}
  %agd = bf16[8,8] all-gather-done(bf16[8,8] %ags)
  ROOT %out = f32[2] tuple()
}
"""


def test_collective_parser_counts_operands_once():
    got = collective_bytes_from_hlo(SYNTH_HLO)
    assert got["all-gather"] == 1024 * 512 * 2 + 8 * 4 * 2  # + async start
    assert got["all-reduce"] == 256 * 256 * 4
    assert got["reduce-scatter"] == 1024 * 256 * 4
    assert got["all-to-all"] == 32 * 32 * 2
    assert got["collective-permute"] == 16 * 4
    # -done lines must not double count: total all-gather above is exact


def test_roofline_terms_arithmetic():
    t = RooflineTerms(
        flops_per_device=197e12,          # exactly 1s of compute
        bytes_per_device=819e9 * 2,       # 2s of memory
        collective_bytes=50e9 * 0.5,      # 0.5s of collective
        collective_breakdown={},
        peak_memory_bytes=0,
    )
    assert abs(t.t_compute - 1.0) < 1e-9
    assert abs(t.t_memory - 2.0) < 1e-9
    assert abs(t.t_collective - 0.5) < 1e-9
    assert t.bottleneck == "memory"
    assert t.step_time_lb == t.t_memory


def test_model_flops_formulas():
    assert model_flops(1e9, 1000, "train") == 6e12
    assert model_flops(1e9, 1000, "inference") == 2e12


def test_probe_cfg_scales_stacks():
    # import without triggering the XLA_FLAGS side effect in this process:
    # dryrun sets env at import; harmless here (jax already initialized)
    from repro.launch.dryrun import _probe_cfg, _scan_unit
    from repro.configs import get_config

    jamba = get_config("jamba-1.5-large-398b")
    assert _scan_unit(jamba) == 8
    assert _probe_cfg(jamba, 2).n_layers == 16
    gemma = get_config("gemma3-12b")
    assert _probe_cfg(gemma, 1).n_layers == 6
    whisper = get_config("whisper-small")
    p = _probe_cfg(whisper, 1)
    assert p.n_layers == 1 and p.n_enc_layers == 1


@pytest.mark.slow
def test_benchmark_harness_smoke(tmp_path):
    """benchmarks.run completes on a tiny corpus, emits CSV rows, and
    writes the machine-readable BENCH_extract.json metrics."""
    import json

    extract_json = tmp_path / "BENCH_extract.json"
    env = dict(os.environ)
    env.update(
        PYTHONPATH=str(Path(__file__).resolve().parents[1] / "src"),
        REPRO_BENCH_FILES="2",
        REPRO_BENCH_RPF="250",
        REPRO_BENCH_CACHE=str(Path(__file__).resolve().parents[1] / ".bench_cache_test"),
        REPRO_BENCH_EXTRACT_OUT=str(extract_json),
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run"],
        capture_output=True, text=True, env=env, timeout=500,
        cwd=Path(__file__).resolve().parents[1],
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    lines = proc.stdout.strip().splitlines()
    assert lines[0] == "name,us_per_call,derived"
    names = {l.split(",")[0] for l in lines[1:]}
    for expected in ("table1.mean", "table2.measured_speedup",
                     "table2.serial_read_ablation",
                     "table3.disk_io_volume", "table4.full_id",
                     "eq45.migration_full_id", "fig2.crossover",
                     "extract.pipelined_warm", "kernels.hash_mix"):
        assert expected in names, f"missing {expected}"
    assert not any(".ERROR" in n for n in names)
    metrics = json.loads(extract_json.read_text())
    assert metrics["parity"] is True
    assert metrics["pipelined_warm"]["cache_hit_rate"] > 0
    assert metrics["speedup_warm"] > 0
