"""repro.dist unit tests: logical rules, divisibility fallback, compression."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.compress import (
    ErrorFeedbackCompressor,
    dequantize_int8,
    make_compressor,
    quantize_int8,
    topk_mask,
)
from repro.dist.logical import (
    DEFAULT_RULES,
    _current_mesh,
    axis_rules,
    constrain,
    current_rules,
    divisible_spec,
)


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


# ---------------------------------------------------------------------------
# logical rules
# ---------------------------------------------------------------------------

def test_divisible_spec_replicates_non_divisible_dims():
    mesh = FakeMesh({"data": 16, "model": 16})
    out = divisible_spec(P("data", "model"), (32, 12), mesh)
    assert tuple(out) == ("data", None)
    # every dim uneven → fully replicated
    out = divisible_spec(P("data", "model"), (3, 5), mesh)
    assert tuple(out) == (None, None)


def test_divisible_spec_shrinks_axis_groups():
    # ("pod","data") = 2*16: 32 divides → whole group kept; 2 only fits "pod"
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    out = divisible_spec(P(("pod", "data"), None), (32, 7), mesh)
    assert tuple(out)[0] == ("pod", "data")
    out = divisible_spec(P(("pod", "data"), None), (2, 7), mesh)
    assert tuple(out)[0] == "pod"


def test_spec_consumes_each_mesh_axis_once():
    mesh = FakeMesh({"data": 2, "model": 4})
    spec = DEFAULT_RULES.spec(("batch", "heads", "kv_heads"), mesh)
    # heads takes "model"; kv_heads finds it consumed → replicated
    assert tuple(spec) == ("data", "model", None)


def test_axis_rules_override_and_restore():
    assert current_rules() is DEFAULT_RULES
    with axis_rules({"seq_sp": None, "custom": "model"}) as rules:
        assert current_rules() is rules
        assert rules.mesh_axes("seq_sp", ("data", "model")) is None
        assert rules.mesh_axes("custom", ("data", "model")) == "model"
        # untouched rules inherited from the default table
        assert rules.mesh_axes("heads", ("data", "model")) == "model"
    assert current_rules() is DEFAULT_RULES


def test_constrain_is_identity_without_mesh():
    assert _current_mesh() is None
    x = jnp.arange(12.0).reshape(3, 4)
    y = constrain(x, "batch", "d_ff")
    assert y is x  # literally a no-op, not a copy


def test_constrain_applies_under_mesh_and_preserves_values():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jnp.arange(8.0).reshape(2, 4)
    with mesh:
        assert _current_mesh() is not None
        y = constrain(x, "batch", "d_ff")
        # jit path (how the models hit it)
        z = jax.jit(lambda a: constrain(a, "batch", "d_ff") * 2.0)(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(z), np.asarray(x) * 2.0)


def test_moe_honours_axis_rule_override():
    """axis_rules({'experts': None}) routes MoE through the local path."""
    from repro.configs import get_config
    from repro.models import moe

    cfg = dataclasses.replace(
        get_config("qwen3-moe-235b-a22b").smoke(),
        n_layers=1, capacity_factor=8.0,
    )
    from repro.models.common import compute_dtype

    params, _ = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(
        jax.random.PRNGKey(1), (2, 8, cfg.d_model), compute_dtype(cfg)
    )
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        y_sharded, aux_sharded = moe.moe_apply(params, cfg, x)
        with axis_rules({"experts": None}):  # expert axis disabled → local
            y_local, aux_local = moe.moe_apply(params, cfg, x)
    np.testing.assert_allclose(
        np.asarray(y_sharded), np.asarray(y_local), atol=1e-5
    )
    np.testing.assert_allclose(
        float(aux_sharded), float(aux_local), atol=1e-6
    )


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_round_trip_error_bound():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(64, 33)).astype(np.float32)) * 5.0
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    assert q.dtype == jnp.int8 and back.dtype == jnp.float32
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-6


def test_int8_zero_leaf_is_stable():
    q, s = quantize_int8(jnp.zeros((16,), jnp.float32))
    back = dequantize_int8(q, s)
    assert not bool(jnp.any(jnp.isnan(back)))
    np.testing.assert_array_equal(np.asarray(back), 0.0)


def test_int8_per_channel_beats_per_tensor_on_wide_variance():
    """Axis-0 scales bound each row's error by its OWN amax: on a leaf whose
    row magnitudes span 6 orders, per-tensor quantization flushes the small
    rows to zero while per-channel round-trips them."""
    rng = np.random.default_rng(11)
    rows = [rng.normal(size=48).astype(np.float32) * 10.0 ** (p - 4)
            for p in range(8)]
    x = jnp.asarray(np.stack(rows))
    q_pt, s_pt = quantize_int8(x)
    q_pc, s_pc = quantize_int8(x, per_channel=True)
    assert s_pc.shape == (8, 1)
    back_pt = dequantize_int8(q_pt, s_pt)
    back_pc = dequantize_int8(q_pc, s_pc)
    # per-channel error respects each row's own bound...
    row_err = jnp.max(jnp.abs(back_pc - x), axis=1)
    assert bool(jnp.all(row_err <= s_pc[:, 0] * 0.5 + 1e-9))
    # ...and is strictly better than per-tensor on the small rows
    small = jnp.abs(x[0])
    assert float(jnp.max(jnp.abs(back_pt[0] - x[0]))) >= float(jnp.max(small)) * 0.99
    assert float(jnp.max(jnp.abs(back_pc[0] - x[0]))) < float(jnp.max(small)) * 0.01
    assert float(jnp.sum(jnp.abs(back_pc - x))) < float(jnp.sum(jnp.abs(back_pt - x)))


def test_int8_per_channel_falls_back_on_vectors():
    x = jnp.asarray(np.linspace(-2, 2, 9, dtype=np.float32))
    q, s = quantize_int8(x, per_channel=True)
    assert s.ndim == 0  # per-tensor scalar scale for <2-dim leaves
    np.testing.assert_allclose(
        np.asarray(dequantize_int8(q, s)), np.asarray(x), atol=float(s) * 0.5 + 1e-6
    )


def test_topk_mask_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05], jnp.float32)
    out = np.asarray(topk_mask(x, 0.4))  # k = 2
    np.testing.assert_array_equal(out, [0.0, -5.0, 0.0, 3.0, 0.0])


@pytest.mark.parametrize(
    "method,per_channel", [("int8", False), ("int8", True), ("topk", False)]
)
def test_error_feedback_telescopes_to_true_gradient_sum(method, per_channel):
    comp = ErrorFeedbackCompressor(
        method=method, topk_frac=0.25, per_channel=per_channel
    )
    params = {"a": jnp.zeros((17,), jnp.float32), "n": {"b": jnp.zeros((4, 3))}}
    state = {"ef_residual": comp.init(params)}
    rng = np.random.default_rng(3)
    tot_true = {"a": np.zeros(17, np.float32), "b": np.zeros((4, 3), np.float32)}
    tot_comp = {"a": np.zeros(17, np.float32), "b": np.zeros((4, 3), np.float32)}
    for _ in range(40):
        g = {
            "a": jnp.asarray(rng.normal(size=17).astype(np.float32) * 1e-3),
            "n": {"b": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))},
        }
        cg, state = comp.apply(g, state)
        tot_true["a"] += np.asarray(g["a"])
        tot_true["b"] += np.asarray(g["n"]["b"])
        tot_comp["a"] += np.asarray(cg["a"])
        tot_comp["b"] += np.asarray(cg["n"]["b"])
    res = state["ef_residual"]
    np.testing.assert_allclose(
        tot_comp["a"] + np.asarray(res["a"]), tot_true["a"], atol=1e-5
    )
    np.testing.assert_allclose(
        tot_comp["b"] + np.asarray(res["n"]["b"]), tot_true["b"], atol=1e-4
    )


def test_error_feedback_is_jit_compatible():
    comp = ErrorFeedbackCompressor()
    params = {"w": jnp.ones((8,), jnp.float32)}
    state = {"ef_residual": comp.init(params)}
    g = {"w": jnp.full((8,), 0.5, jnp.float32)}
    cg, new_state = jax.jit(comp.apply)(g, state)
    assert cg["w"].shape == (8,)
    assert "ef_residual" in new_state


def test_make_compressor_registry():
    assert make_compressor(None) is None
    assert make_compressor("none") is None
    assert make_compressor("int8_ef").method == "int8"
    pc = make_compressor("int8_pc_ef")
    assert pc.method == "int8" and pc.per_channel
    assert not make_compressor("int8_ef").per_channel
    tk = make_compressor("topk_ef", topk_frac=0.5)
    assert tk.method == "topk" and tk.topk_frac == 0.5
    with pytest.raises(ValueError):
        make_compressor("gzip")


def test_trainer_config_builds_compressor():
    from repro.train.trainer import TrainerConfig

    assert TrainerConfig().make_compressor() is None
    c = TrainerConfig(compress_grads=True, compressor="topk_ef", topk_frac=0.2)
    comp = c.make_compressor()
    assert comp.method == "topk" and comp.topk_frac == 0.2


# ---------------------------------------------------------------------------
# sharded serving (1×1 mesh on the CPU container: exercises the mesh path)
# ---------------------------------------------------------------------------

def test_engine_with_mesh_matches_unsharded():
    from repro.configs import get_config
    from repro.models.registry import build_model
    from repro.serve.engine import Engine, ServeConfig

    cfg = dataclasses.replace(
        get_config("yi-6b"),
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=300,
    )
    api = build_model(cfg)
    params, specs = api.init(jax.random.PRNGKey(0))
    scfg = ServeConfig(max_new_tokens=6, max_len=64)
    ref = Engine(cfg, params, scfg).generate(["InChI=1S/C4"])
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    got = Engine(
        cfg, params, scfg, mesh=mesh, param_specs=specs
    ).generate(["InChI=1S/C4"])
    assert got[0].token_ids == ref[0].token_ids
