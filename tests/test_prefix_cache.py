"""Prefix-sharing paged KV cache tests.

The load-bearing claims:

* refcounted allocator invariants: double-unref rejection, free-of-shared
  rejection, COW ``fork`` giving a slot a private copy before a write,
  ``check()`` catching a hand-corrupted refcount exactly (slot holds +
  index holds == refcount);
* :class:`PrefixIndex` behavior: longest-block match with exact-token
  verification (a fabricated hash collision is a miss, never a wrong
  adoption), LRU touch ordering, eviction never freeing a block another
  holder still references;
* the chunked suffix-prefill path produces logits bit-identical to full
  prefill (the basis of prefix-on vs prefix-off byte parity);
* the continuous engine end to end: hits counted, prefill tokens saved,
  outputs byte-identical with sharing on vs off, pool pressure reclaims
  index blocks instead of stalling forever;
* ``close(drain=False)`` fails queued-but-unadmitted futures with
  :class:`EngineClosed`; ``close(drain=True)`` loses nothing;
* sampling in continuous mode: per-request keys make outputs independent
  of lane composition; greedy stays the default.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import build_model
from repro.serve.engine import ServeConfig
from repro.serve.kvcache import (
    BlockManager,
    PagedCacheSpec,
    PrefixIndex,
    rolling_block_hashes,
)
from repro.serve.scheduler import ContinuousEngine, EngineClosed


def _tiny_cfg(**kw):
    base = dataclasses.replace(
        get_config("yi-6b"),
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=300,
    )
    return dataclasses.replace(base, **kw)


MAX_LEN, BS = 64, 8


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    params, _ = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _spec(**kw):
    base = dict(n_blocks=33, block_size=BS, max_slots=3,
                max_blocks_per_seq=MAX_LEN // BS)
    base.update(kw)
    return PagedCacheSpec(**base)


# ---------------------------------------------------------------------------
# refcounted allocator
# ---------------------------------------------------------------------------

def test_ref_unref_lifecycle_and_double_unref():
    mgr = BlockManager(_spec(n_blocks=9, max_slots=2, max_blocks_per_seq=4))
    blocks = mgr.alloc(2)
    assert all(mgr.refcount(b) == 1 for b in blocks)
    mgr.ref(blocks)
    assert all(mgr.refcount(b) == 2 for b in blocks)
    assert mgr.unref(blocks) == 0          # still held once: nothing freed
    assert mgr.unref(blocks) == 2          # last holder: back to free list
    assert mgr.n_in_use == 0
    with pytest.raises(ValueError, match="no holders"):
        mgr.unref(blocks)
    with pytest.raises(ValueError, match="trash"):
        mgr.ref([0])
    mgr.check({})


def test_free_of_shared_block_rejected():
    mgr = BlockManager(_spec(n_blocks=9, max_slots=2, max_blocks_per_seq=4))
    blocks = mgr.alloc(1)
    mgr.ref(blocks)
    with pytest.raises(ValueError, match="shared"):
        mgr.free(blocks)
    mgr.unref(blocks)
    mgr.free(blocks)                        # exclusive again: fine
    assert mgr.n_in_use == 0


def test_release_unrefs_instead_of_freeing():
    mgr = BlockManager(_spec(n_blocks=9, max_slots=2, max_blocks_per_seq=4))
    assert mgr.admit(0, 17)                # 3 blocks
    shared = mgr.slot_blocks(0)[:2]
    assert mgr.admit(1, 17, prefix_blocks=shared)
    assert all(mgr.refcount(b) == 2 for b in shared)
    mgr.release(0)
    # slot 1 still addresses the shared blocks: they must stay resident
    assert all(mgr.refcount(b) == 1 for b in shared)
    assert set(shared) <= set(mgr.slot_blocks(1))
    mgr.check({})
    mgr.release(1)
    assert mgr.n_in_use == 0


def test_check_catches_corrupted_refcount():
    mgr = BlockManager(_spec(n_blocks=9, max_slots=2, max_blocks_per_seq=4))
    assert mgr.admit(0, 17)
    b = mgr.slot_blocks(0)[0]
    mgr.check({})
    mgr._refcounts[b] = 5                  # corrupt: nothing holds 4 extra
    with pytest.raises(AssertionError, match="refcount"):
        mgr.check({})
    mgr._refcounts[b] = 1
    mgr.check({})
    # refcount entry for a free block is out of sync too
    free_b = mgr._free[-1]
    mgr._refcounts[free_b] = 1
    with pytest.raises(AssertionError, match="out of sync"):
        mgr.check({})


def test_fork_cow_gives_private_copy_on_write():
    """Sharing slot writes must never be visible through the other table."""
    spec = _spec(n_blocks=9, max_slots=2, max_blocks_per_seq=4)
    mgr = BlockManager(spec)
    # stand-in KV pool: one row-vector per pool row, addressed like the
    # real per-layer pools (block i owns rows [i*bs, (i+1)*bs))
    k = jnp.zeros((spec.n_blocks * BS, 4))

    assert mgr.admit(0, 17)
    shared = mgr.slot_blocks(0)
    assert mgr.admit(1, 17, prefix_blocks=shared[:2])
    b = shared[0]
    marker = jnp.ones((BS, 4))
    k = k.at[b * BS: (b + 1) * BS].set(marker)

    # exclusive block: fork is a no-op
    old, new = mgr.fork(1, 2)
    assert old == new

    # shared block: fork swaps in a fresh block; caller copies rows
    old, new = mgr.fork(1, 0)
    assert old == b and new != b
    assert mgr.tables[1][0] == new and mgr.tables[0][0] == b
    assert mgr.refcount(b) == 1 and mgr.refcount(new) == 1
    k = k.at[new * BS: (new + 1) * BS].set(k[old * BS: (old + 1) * BS])
    # slot 1 writes through its (now private) table entry
    k = k.at[new * BS].set(7.0)
    # slot 0's view of the original block is untouched
    assert np.array_equal(
        np.asarray(k[b * BS: (b + 1) * BS]), np.asarray(marker)
    )
    assert float(k[new * BS, 0]) == 7.0
    mgr.check({})


def test_fork_pool_exhausted_returns_none():
    mgr = BlockManager(_spec(n_blocks=4, max_slots=2, max_blocks_per_seq=3))
    assert mgr.admit(0, 17)                # all 3 usable blocks
    assert mgr.admit(1, 17, prefix_blocks=mgr.slot_blocks(0))
    assert mgr.fork(1, 0) is None          # nothing left to copy into
    mgr.check({})


# ---------------------------------------------------------------------------
# prefix index
# ---------------------------------------------------------------------------

def _mgr_idx(**kw):
    mgr = BlockManager(_spec(**kw))
    return mgr, PrefixIndex(mgr)


def test_index_publish_match_and_exact_verification():
    mgr, idx = _mgr_idx()
    prompt = list(range(1, 21))            # 20 tokens: 2 full blocks of 8
    assert mgr.admit(0, 24)
    blocks = mgr.slot_blocks(0)
    assert idx.publish(prompt, blocks, len(prompt)) == 2
    mgr.check(idx.block_refs())

    got, n = idx.match(prompt)
    assert n == 16 and got == blocks[:2]
    # extending prompt with a different tail still matches the stem
    got, n = idx.match(prompt[:16] + [99, 98, 97])
    assert n == 16 and got == blocks[:2]
    # shorter prompt matches fewer blocks (adoption leaves >= 1 token)
    got, n = idx.match(prompt[:9])
    assert n == 8 and got == blocks[:1]
    # a full-block-aligned prompt never adopts ALL its blocks
    got, n = idx.match(prompt[:16])
    assert n == 8
    # different tokens, same length: miss
    got, n = idx.match([7] * 20)
    assert n == 0 and got == []


def test_index_hash_collision_is_a_miss():
    mgr, idx = _mgr_idx()
    prompt = list(range(1, 17))
    assert mgr.admit(0, 24)
    idx.publish(prompt, mgr.slot_blocks(0), len(prompt))
    # fabricate a collision: same rolling hash key, different stored tokens
    key = rolling_block_hashes(prompt, BS, 1)[0]
    tokens, chain = idx._entries[key]
    idx._entries[key] = ((999,) * len(tokens), chain)
    got, n = idx.match(prompt[:9])
    assert n == 0 and got == []
    assert idx.hash_collisions >= 1


def test_index_eviction_lru_and_never_frees_shared():
    mgr, idx = _mgr_idx(n_blocks=17, max_slots=3)
    pa = [1] * 9                            # 1 full block
    pb = [2] * 9
    assert mgr.admit(0, 9)
    idx.publish(pa, mgr.slot_blocks(0), 9)
    assert mgr.admit(1, 9)
    idx.publish(pb, mgr.slot_blocks(1), 9)
    a_blk = mgr.slot_blocks(0)[0]
    b_blk = mgr.slot_blocks(1)[0]
    # slot 0 finishes; slot 1 stays active.  a_blk is index-only (rc 1),
    # b_blk is index+slot (rc 2).
    mgr.release(0)
    assert mgr.refcount(a_blk) == 1 and mgr.refcount(b_blk) == 2
    # touch pa making pb's entry the LRU — but pb's block is shared, so
    # eviction must skip it and take pa's entry instead
    idx.match(pa + [3])
    freed = idx.evict_for(1)
    assert freed == 1
    assert mgr.refcount(b_blk) == 2        # untouched: slot 1 still holds it
    assert a_blk in mgr._free
    mgr.check(idx.block_refs())
    # nothing else is reclaimable while slot 1 lives
    assert idx.evict_for(1) == 0
    mgr.release(1)
    assert idx.evict_for(1) == 1           # now pb's entry can go
    assert mgr.n_in_use == 0


def test_index_lru_order_evicts_oldest_first():
    mgr, idx = _mgr_idx(n_blocks=17, max_slots=3)
    pa, pb = [1] * 9, [2] * 9
    assert mgr.admit(0, 9)
    idx.publish(pa, mgr.slot_blocks(0), 9)
    a_blk = mgr.slot_blocks(0)[0]
    mgr.release(0)
    assert mgr.admit(1, 9)
    idx.publish(pb, mgr.slot_blocks(1), 9)
    b_blk = mgr.slot_blocks(1)[0]
    mgr.release(1)
    # pa older than pb: one eviction takes pa's block
    assert idx.evict_for(1) == 1
    assert a_blk in mgr._free and mgr.refcount(b_blk) == 1


# ---------------------------------------------------------------------------
# chunked suffix prefill (model level)
# ---------------------------------------------------------------------------

def test_suffix_prefill_logits_bitwise_vs_full(tiny):
    cfg, params = tiny
    api = build_model(cfg)
    spec = _spec()
    cache, _ = api.paged_cache_init(spec.n_blocks, BS)

    prompt = [256] + list(b"InChI=1S/C8H9NO2/c1-6(")  # 24 tokens: 3 blocks
    L = len(prompt)
    bucket = ((L + BS - 1) // BS) * BS
    toks = np.full((1, bucket), 258, np.int32)
    toks[0, :L] = prompt
    full_logits, dense = api.prefill(
        params, {"tokens": jnp.asarray(toks), "lengths": jnp.asarray([L])},
        max_len=MAX_LEN,
    )
    # publisher wrote blocks [1, 2, 3]
    row_pub = np.zeros(MAX_LEN // BS, np.int32)
    row_pub[:3] = [1, 2, 3]
    cache = api.paged_prefill_write(cache, dense, jnp.asarray(row_pub), BS)

    for start in (8, 16):                   # adopt 1 then 2 blocks
        n_adopt = start // BS
        row = np.zeros(MAX_LEN // BS, np.int32)
        row[:3] = row_pub[:3]
        row[n_adopt:3] = [4, 5][: 3 - n_adopt]  # fresh suffix blocks
        suf = toks[:, start:]
        suf_logits, cache = api.prefill_suffix(
            params, jnp.asarray(suf), start, jnp.asarray(row), cache, BS,
            lengths=jnp.asarray([L - start]),
        )
        assert np.array_equal(np.asarray(full_logits), np.asarray(suf_logits)), (
            f"suffix prefill logits differ from full prefill at start={start}"
        )


# ---------------------------------------------------------------------------
# continuous engine end to end
# ---------------------------------------------------------------------------

STEM = "InChI=1S/C8H9NO2/c1-6(10)9-7-2-4-8(11)5-3-7;"
SHARED = [STEM + tail for tail in ("a1", "b22", "c333", "a1")]


def test_engine_prefix_hits_and_byte_parity(tiny):
    cfg, params = tiny
    spec = _spec(n_blocks=65, max_slots=3, max_blocks_per_seq=8)
    scfg = ServeConfig(max_new_tokens=8, max_len=MAX_LEN)
    on = ContinuousEngine(cfg, params, spec, scfg, prefix_cache=True)
    off = ContinuousEngine(cfg, params, spec, scfg, prefix_cache=False)
    try:
        want = [r.token_ids for r in off.generate(SHARED)]
        got = [r.token_ids for r in on.generate(SHARED)]
        assert got == want, "prefix sharing changed emitted bytes"
        assert on.stats.prefix_hits >= len(SHARED) - 1
        assert on.stats.prefill_tokens_saved >= 32 * (len(SHARED) - 1)
        c = on.counters()
        assert c["prefix_hit_rate"] > 0 and c["pfx_entries"] > 0
        assert off.stats.prefix_hits == 0 and off.counters()["prefix_hit_rate"] == 0
        on.check()
        off.check()
    finally:
        on.close()
        off.close()


def test_engine_pool_pressure_reclaims_index_blocks(tiny):
    cfg, params = tiny
    # pool sized so resident index entries MUST be evicted to admit the
    # later distinct prompts: 10 usable blocks, each request reserves 4,
    # and every distinct prompt keeps 3 resident after finishing
    spec = _spec(n_blocks=11, max_slots=2, max_blocks_per_seq=5)
    scfg = ServeConfig(max_new_tokens=6, max_len=40)
    on = ContinuousEngine(cfg, params, spec, scfg, prefix_cache=True)
    off = ContinuousEngine(cfg, params, spec, scfg, prefix_cache=False)
    try:
        prompts = [
            "InChI=1S/C4H10/c1-3-4-2;x",
            "C1=CC=CC=C1O.C1=CC=CC=C1",
            "InChI=1S/C4H10/c1-3-4-2;y",   # stem shared with #1 if resident
            "benzene+toluene+xylene!!",
            "InChI=1S/C4H10/c1-3-4-2;z",
        ]
        futs = [on.submit(p, lead=False) for p in prompts]
        on._maybe_lead()
        got = [f.result(timeout=300).token_ids for f in futs]
        want = [off.generate([p])[0].token_ids for p in prompts]
        assert got == want
        assert on.counters()["pfx_evictions"] > 0, "pressure never reclaimed"
        on.check()
    finally:
        on.close()
        off.close()


def test_close_fails_queued_with_engine_closed(tiny):
    cfg, params = tiny
    eng = ContinuousEngine(
        cfg, params, _spec(), ServeConfig(max_new_tokens=4, max_len=MAX_LEN)
    )
    futs = [eng.submit(t, lead=False) for t in ("ab", "cd", "ef")]
    eng.close()                             # no drain: nobody ever led
    for f in futs:
        with pytest.raises(EngineClosed, match="never admitted"):
            f.result(timeout=60)
    assert eng.stats.cancelled == 3
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit("xy")


def test_close_drain_serves_everything(tiny):
    cfg, params = tiny
    eng = ContinuousEngine(
        cfg, params, _spec(), ServeConfig(max_new_tokens=4, max_len=MAX_LEN)
    )
    futs = [eng.submit(t, lead=False) for t in ("ab", "cd", "ef")]
    eng.close(drain=True)
    for f in futs:
        assert len(f.result(timeout=60).token_ids) >= 1
    assert eng.stats.completed == 3 and eng.stats.cancelled == 0


# ---------------------------------------------------------------------------
# sampling in continuous mode
# ---------------------------------------------------------------------------

def test_sampling_independent_of_lane_composition(tiny):
    cfg, params = tiny
    scfg = ServeConfig(
        max_new_tokens=10, max_len=MAX_LEN, greedy=False,
        temperature=0.9, top_k=20,
    )
    solo = ContinuousEngine(cfg, params, _spec(), scfg)
    packed = ContinuousEngine(cfg, params, _spec(), scfg)
    try:
        want = solo.submit("InChI=1S/C4", seed=7).result(timeout=300).token_ids
        # same request sharing the batch with different co-residents (and
        # a different admission order) must reproduce exactly
        futs = [
            packed.submit("benzene", seed=1, lead=False),
            packed.submit("InChI=1S/C4", seed=7, lead=False),
            packed.submit("xylene!", seed=2, lead=False),
        ]
        packed._maybe_lead()
        got = futs[1].result(timeout=300).token_ids
        assert got == want, "sampled tokens depend on lane composition"
        # distinct seeds on the same prompt diverge (overwhelmingly)
        other = packed.submit("InChI=1S/C4", seed=8).result(timeout=300)
        assert isinstance(other.token_ids, list)
    finally:
        solo.close()
        packed.close()


def test_sampling_seed_reproducible_and_greedy_default(tiny):
    cfg, params = tiny
    scfg = ServeConfig(
        max_new_tokens=8, max_len=MAX_LEN, greedy=False, temperature=1.2,
    )
    eng = ContinuousEngine(cfg, params, _spec(), scfg)
    try:
        a = eng.submit("smiles:CC", seed=3).result(timeout=300).token_ids
        b = eng.submit("smiles:CC", seed=3).result(timeout=300).token_ids
        assert a == b, "same (prompt, seed) must reproduce"
    finally:
        eng.close()
    # greedy stays the default and ignores sampling knobs
    assert ServeConfig().greedy and ServeConfig().top_k == 0
