"""Fault-tolerance tests: heartbeat publish, failure-detector edges,
micro-batcher leader-death containment, fault-injecting transports,
health tracking, and the router's failover / hedged / degraded serving.

The acceptance gate lives here too: a seeded chaos run (one dead shard +
one slow shard) must produce byte-identical degraded results across two
runs, fire hedged requests, and return byte-identical clean results
after revival.
"""

import json
import os
import tempfile
import threading
import time
from pathlib import Path

import numpy as np
import pytest

import repro.runtime.fault as fault_mod
from repro.core import RecordStore, build_index
from repro.core.sdfgen import CorpusSpec, generate_corpus
from repro.core.store import IndexStore, digest_u64, merge_similar_topk, shard_of
from repro.runtime.fault import (
    BackoffPolicy,
    ElasticPlan,
    FailureDetector,
    Heartbeat,
    run_with_failures,
)
from repro.service import (
    DEAD,
    DEGRADED,
    UP,
    FaultInjectingTransport,
    FlakyError,
    HealthTracker,
    LocalTransport,
    MicroBatcher,
    ProbeTimeoutError,
    QueryService,
    ServiceConfig,
    ShardDownError,
    ShardRouter,
    run_closed_loop,
)
from repro.service.transport import error_kind


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def corpus():
    spec = CorpusSpec(n_files=2, records_per_file=300)
    root = Path(tempfile.mkdtemp()) / "corpus"
    generate_corpus(root, spec)
    return RecordStore(root), spec


@pytest.fixture(scope="module")
def store_dir(corpus):
    store, _ = corpus
    idx = build_index(store, key_mode="full_id")
    sdir = Path(tempfile.mkdtemp()) / "istore"
    idx.save_sharded(sdir, n_shards=8, fingerprint_bits=256)
    return sdir


@pytest.fixture(scope="module")
def probe_keys(store_dir):
    st = IndexStore.open(store_dir)
    return sorted(st.iter_keys())[:240]


def _chaos_router(store_dir, seed=42, **kw):
    """Router over fault-injecting transports; returns (router, injectors)."""
    injectors = []

    def factory(st, i):
        tr = FaultInjectingTransport(
            LocalTransport(st, name=f"r{i}"), seed=seed + i
        )
        injectors.append(tr)
        return tr

    kw.setdefault("replicas", 2)
    kw.setdefault("min_scatter_keys", 1)
    kw.setdefault("probe_timeout_ms", 250.0)
    kw.setdefault("fail_threshold", 1)
    kw.setdefault("health_backoff", BackoffPolicy(base_s=0.1, cap_s=0.5))
    rt = ShardRouter(store_dir, transport_factory=factory, **kw)
    return rt, injectors


# ---------------------------------------------------------------------------
# satellite: Heartbeat tmp-file publish
# ---------------------------------------------------------------------------

def test_heartbeat_tmp_name_survives_dots_and_carries_pid(tmp_path):
    """Regression: ``with_suffix`` rewrites everything after the last dot
    of the final component, so a dotted heartbeat file name collapsed to
    a shared ``hb.tmp`` — racing ranks then interleaved publishes."""
    hb = Heartbeat(tmp_path, 3)
    hb.path = tmp_path / "hb.v2_00003"  # dotted name: the mangling case
    hb.beat(step=7)
    assert json.loads(hb.path.read_text())["step"] == 7
    # nothing else left behind, and the tmp path never clobbered a sibling
    assert sorted(p.name for p in tmp_path.iterdir()) == ["hb.v2_00003"]
    # the tmp naming is per-pid and per-thread, so neither sibling
    # processes nor pool threads beating one rank can interleave writes
    # into a single tmp file
    tmp = hb.path.with_name(
        f"{hb.path.name}.{os.getpid()}.{threading.get_ident()}.tmp"
    )
    assert str(os.getpid()) in tmp.name


def test_heartbeat_concurrent_beats_stay_atomic(tmp_path):
    hb = Heartbeat(tmp_path, 0)
    stop = threading.Event()
    errors = []

    def beater(base):
        i = 0
        while not stop.is_set():
            try:
                hb.beat(step=base + i)
            except Exception as e:  # pragma: no cover
                errors.append(e)
            i += 1

    threads = [
        threading.Thread(target=beater, args=(t * 10_000,))
        for t in range(4)
    ]
    for t in threads:
        t.start()
    deadline = time.monotonic() + 0.5
    while time.monotonic() < deadline:
        got = hb.read()  # every observed publish is complete JSON
        assert got is None or "step" in got
    stop.set()
    for t in threads:
        t.join(5)
    assert not errors
    assert hb.read() is not None
    assert [p.name for p in tmp_path.glob("*.tmp")] == []


def test_heartbeat_cleans_tmp_on_write_failure(tmp_path, monkeypatch):
    hb = Heartbeat(tmp_path, 1)
    real_replace = os.replace

    def boom(src, dst):
        raise OSError("disk full")

    monkeypatch.setattr(fault_mod.os, "replace", boom)
    with pytest.raises(OSError):
        hb.beat(step=1)
    monkeypatch.setattr(fault_mod.os, "replace", real_replace)
    assert [p.name for p in tmp_path.glob("*.tmp")] == []


# ---------------------------------------------------------------------------
# satellite: FailureDetector / ElasticPlan / run_with_failures edges
# ---------------------------------------------------------------------------

def test_failure_detector_boundary_and_clock_skew(tmp_path, monkeypatch):
    det = FailureDetector(tmp_path, n_workers=3, timeout=5.0)
    now = 1_000_000.0
    monkeypatch.setattr(fault_mod.time, "time", lambda: now)
    # rank 0: exactly at the timeout boundary — still alive (<=)
    (tmp_path / "hb_00000").write_text(json.dumps({"step": 1, "t": now - 5.0}))
    # rank 1: a hair past the deadline — dead
    (tmp_path / "hb_00001").write_text(
        json.dumps({"step": 1, "t": now - 5.0001})
    )
    # rank 2: heartbeat from the future (clock skew) — alive, not dead
    (tmp_path / "hb_00002").write_text(json.dumps({"step": 1, "t": now + 60}))
    assert det.alive() == [0, 2]
    assert det.dead() == [1]


def test_elastic_plan_zero_survivors_raises():
    with pytest.raises(RuntimeError, match="no survivors"):
        ElasticPlan.for_survivors(0, n_model=2)
    assert ElasticPlan.for_survivors(3, n_model=2).n_dp == 3


def test_run_with_failures_failure_at_step_zero():
    """A failure scheduled before any training ran must shrink dp BEFORE
    the first chunk launches (regression: it was silently ignored)."""
    seen = []

    def chunk(start, until, n_dp):
        seen.append((start, until, n_dp))
        return until, {}

    log = run_with_failures(
        total_steps=8, train_chunk=chunk, fail_at={0: 2}, initial_dp=4
    )
    assert seen == [(0, 8, 2)]
    kinds = [e["kind"] for e in log.events]
    assert kinds == ["failure", "chunk"]
    assert log.events[0]["new_dp"] == 2


# ---------------------------------------------------------------------------
# satellite: MicroBatcher leader-death containment
# ---------------------------------------------------------------------------

def test_batcher_systemexit_delivered_and_followers_rescued():
    """A probe raising SystemExit kills its leader (client) thread, but
    the batch's futures get the exception and later requests are rescued
    by the watchdog sweep instead of waiting forever."""
    calls = []

    def probe(keys):
        calls.append(list(keys))
        if len(calls) == 1:
            raise SystemExit("poisoned probe")
        v = np.arange(len(keys))
        return v.astype(np.int32), v.astype(np.int64) * 10, np.ones(
            len(keys), dtype=bool
        )

    mb = MicroBatcher(probe, max_batch=8, max_wait_ms=5.0)
    first_exc = []

    def doomed_client():
        try:
            mb.lookup(["k/1"])
        except BaseException as e:  # noqa: BLE001
            first_exc.append(e)

    t = threading.Thread(target=doomed_client)
    t.start()
    t.join(5)
    assert not t.is_alive()
    assert isinstance(first_exc[0], SystemExit)
    # no live leader now; the watchdog's periodic sweep must lead this
    out = mb.submit(["k/2"]).result(timeout=5)
    assert len(out) == 3 and bool(out[2][0])
    mb.close()


def test_batcher_close_bounded_by_grace_when_leader_wedged():
    """close(drain=False) must not block forever behind a probe that
    never returns: pending requests cancel, close returns within the
    grace window, the wedged cohort's futures stay pending."""
    wedge = threading.Event()

    def probe(keys):
        wedge.wait(30)
        v = np.arange(len(keys))
        return v.astype(np.int32), v.astype(np.int64), np.ones(
            len(keys), dtype=bool
        )

    mb = MicroBatcher(probe, close_grace_s=0.2)
    inflight_res = []
    th = threading.Thread(
        target=lambda: inflight_res.append(mb.submit(["k/1"]).result(35)),
        daemon=True,
    )
    th.start()
    time.sleep(0.15)  # let the leader enter the wedged probe
    queued = mb.submit(["k/2"])
    t0 = time.monotonic()
    mb.close(drain=False)
    assert time.monotonic() - t0 < 2.0
    assert queued.cancelled()
    assert mb.stats.cancelled >= 1
    wedge.set()  # un-wedge: the alive leader still resolves its cohort
    th.join(5)
    assert inflight_res and len(inflight_res[0]) == 3


def test_batcher_close_recovers_cohort_of_dead_leader():
    """White-box: a leader thread that died without unwinding (no Python
    exception reached _execute's handler) leaves its cohort unresolved —
    close() must deliver a RuntimeError rather than hang the callers."""
    mb = MicroBatcher(lambda keys: None, close_grace_s=0.1)
    from repro.service.scheduler import _Request

    req = _Request(["k/1"])
    assert req.future.set_running_or_notify_cancel()
    dead = threading.Thread(target=lambda: None)
    dead.start()
    dead.join()
    with mb._lock:
        mb._inflight = [req]
        mb._leader_thread = dead
    assert mb._leader.acquire(blocking=False)  # simulate a held flush
    try:
        mb.close(drain=False)
    finally:
        mb._leader.release()
    with pytest.raises(RuntimeError, match="leader died mid-flush"):
        req.future.result(timeout=1)
    assert mb.stats.leader_deaths == 1


def test_batcher_slices_extra_columns_and_preserves_type():
    from repro.service import LookupBatchResult

    def probe(keys):
        n = len(keys)
        return LookupBatchResult(
            np.arange(n, dtype=np.int32),
            np.arange(n, dtype=np.int64) * 10,
            np.ones(n, dtype=bool),
            np.array([k.endswith("dead") for k in keys]),
        )

    mb = MicroBatcher(probe)
    out = mb.lookup(["a", "b/dead"])
    assert isinstance(out, LookupBatchResult)
    assert out.degraded.tolist() == [False, True]
    mb.close()


# ---------------------------------------------------------------------------
# FaultInjectingTransport
# ---------------------------------------------------------------------------

def test_transport_kill_revive_and_taxonomy(store_dir, probe_keys):
    st = IndexStore.open(store_dir)
    tr = FaultInjectingTransport(LocalTransport(st), seed=1)
    keys = probe_keys[:20]
    dg = digest_u64(keys)
    shards = np.unique(shard_of(dg, st.n_shards, st.digest_bits)).tolist()
    s = shards[0]
    tr.kill(shard=s)
    with pytest.raises(ShardDownError) as ei:
        tr.lookup_shard(s, keys, dg)
    assert error_kind(ei.value) == "down" and ei.value.shard == s
    assert tr.injected["down"] == 1
    # whole-batch probes inherit the worst state of the shards they touch
    with pytest.raises(ShardDownError):
        tr.lookup_all(keys, dg)
    tr.revive(shard=s)
    fid, off, hit = tr.lookup_all(keys, dg)
    assert hit.all()

    tr.set_latency(50.0, shard=s)  # delay >= deadline -> timeout error
    with pytest.raises(ProbeTimeoutError) as ei:
        tr.lookup_shard(s, keys, dg, timeout_s=0.02)
    assert error_kind(ei.value) == "timeout"
    tr.clear()

    tr.set_error_rate(1.0, shard=s)
    with pytest.raises(FlakyError) as ei:
        tr.lookup_shard(s, keys, dg)
    assert error_kind(ei.value) == "error"
    tr.clear()
    assert tr.lookup_shard(s, keys, dg)[2].all()


def test_transport_fault_sequence_is_seed_deterministic(store_dir, probe_keys):
    """Same seed + same probe sequence => same injected fault sequence,
    regardless of wall clock (per-shard RNG streams)."""
    st = IndexStore.open(store_dir)
    keys = probe_keys[:30]
    dg = digest_u64(keys)
    s = int(shard_of(dg, st.n_shards, st.digest_bits)[0])

    def run_seq(seed):
        tr = FaultInjectingTransport(LocalTransport(st), seed=seed)
        tr.set_error_rate(0.5, shard=s)
        outcomes = []
        for _ in range(24):
            try:
                tr.lookup_shard(s, keys[:4], dg[:4])
                outcomes.append("ok")
            except FlakyError:
                outcomes.append("flaky")
        return outcomes

    a, b, c = run_seq(7), run_seq(7), run_seq(8)
    assert a == b
    assert "flaky" in a and "ok" in a
    assert a != c  # different seed, different stream (overwhelmingly)


# ---------------------------------------------------------------------------
# HealthTracker
# ---------------------------------------------------------------------------

def test_health_state_machine_and_probation_pacing():
    t = [0.0]
    h = HealthTracker(
        n_replicas=2, fail_threshold=2,
        backoff=BackoffPolicy(base_s=1.0, multiplier=2.0, cap_s=8.0),
        clock=lambda: t[0],
    )
    assert h.state(0, 3) == UP and not h.has_unhealthy()
    h.on_failure(0, 3, "down")
    assert h.state(0, 3) == DEGRADED and h.has_unhealthy()
    h.on_failure(0, 3, "down")
    assert h.state(0, 3) == DEAD
    # dead replica excluded while inside the backoff window
    assert h.candidates(3) == [1]
    t[0] = 1.5  # past base_s: exactly one probation probe handed out
    assert h.candidates(3) == [1, 0]
    assert h.candidates(3) == [1]  # window advanced: no stampede
    # failed probation widens the window exponentially
    h.on_failure(0, 3, "down")
    t[0] = 3.0
    assert h.candidates(3) == [1]          # 1.5 + 2.0 = 3.5 not reached
    t[0] = 4.0
    assert h.candidates(3) == [1, 0]
    # successful probation revives and records the recovery time
    h.on_success(0, 3, latency_s=0.01)
    assert h.state(0, 3) == UP
    snap = h.snapshot()
    assert snap["revivals"] == 1
    assert snap["last_recovery_s"] == pytest.approx(4.0 - 0.0)
    assert snap["failures"]["down"] == 3


def test_health_p95_and_snapshot_taxonomy():
    h = HealthTracker(n_replicas=1)
    assert h.p95_s(0, 0) is None
    for ms in range(1, 101):
        h.on_success(0, 0, latency_s=ms / 1e3)
    assert h.p95_s(0, 0) == pytest.approx(0.095, abs=0.005)
    h.on_failure(0, 1, "timeout")
    snap = h.snapshot()
    assert snap["replica_state"] == [DEGRADED]
    assert snap["failures"] == {"timeout": 1}


def test_health_heartbeats_feed_failure_detector(tmp_path):
    h = HealthTracker(n_replicas=2, rundir=tmp_path, heartbeat_interval_s=0.0)
    h.on_success(0, 0, 0.001)
    h.on_success(1, 0, 0.001)
    snap = h.snapshot()
    assert snap["heartbeat_alive"] == [0, 1]
    assert sorted(p.name for p in tmp_path.glob("hb_*")) == [
        "hb_00000", "hb_00001"
    ]


# ---------------------------------------------------------------------------
# router failover / hedging / degraded mode
# ---------------------------------------------------------------------------

def test_router_fails_over_to_sibling_replica(store_dir, probe_keys):
    with ShardRouter(store_dir, replicas=2, min_scatter_keys=1) as clean:
        want = clean.lookup_batch(probe_keys)
    rt, inj = _chaos_router(store_dir)
    try:
        dead_shard = 2
        inj[0].kill(shard=dead_shard)  # one replica only: siblings cover
        res = rt.lookup_batch_ex(probe_keys)
        assert not res.degraded.any()
        for got, ref in zip((res.file_ids, res.offsets, res.hit), want):
            assert np.array_equal(got, ref)
        assert rt.stats.retries >= 1
        assert rt.stats.errors_per_shard[dead_shard]["down"] >= 1
        assert rt.health.state(0, dead_shard) == DEAD
        assert rt.health.state(1, dead_shard) == UP
    finally:
        rt.close()


def test_router_degraded_mask_matches_dead_shard(store_dir, probe_keys):
    rt, inj = _chaos_router(store_dir)
    try:
        dead_shard = 1
        for tr in inj:
            tr.kill(shard=dead_shard)
        res = rt.lookup_batch_ex(probe_keys)
        sid = shard_of(
            digest_u64(probe_keys, bits=rt.digest_bits),
            rt.n_shards, rt.digest_bits,
        )
        want_degraded = sid == dead_shard
        assert want_degraded.any()  # the fixture must exercise the mask
        assert np.array_equal(res.degraded, want_degraded)
        # degraded keys read as misses with -1 sentinels ...
        assert not res.hit[want_degraded].any()
        assert (res.file_ids[want_degraded] == -1).all()
        assert (res.offsets[want_degraded] == -1).all()
        # ... while every healthy shard still answers
        assert res.hit[~want_degraded].all()
        assert rt.stats.degraded_keys == int(want_degraded.sum())
        assert rt.stats.degraded_batches == 1
        # legacy 3-tuple callers see plain misses, no exception
        fid, off, hit = rt.lookup_batch(probe_keys)
        assert np.array_equal(hit, res.hit)
    finally:
        rt.close()


def test_router_similarity_degrades_to_surviving_shards(store_dir, probe_keys):
    from repro.core.fingerprint import fingerprint_batch

    fps, _ = fingerprint_batch(probe_keys[:5], 256)
    st = IndexStore.open(store_dir)
    dead_shard = 3
    live = [
        s for s in range(st.n_shards)
        if s != dead_shard and int(st.manifest["shards"][s]["count"]) > 0
    ]
    want = merge_similar_topk(
        [st.similar_shard(s, fps, 4) for s in live], 4
    )
    rt, inj = _chaos_router(store_dir)
    try:
        for tr in inj:
            tr.kill(shard=dead_shard)
        res = rt.similar_batch_ex(fps, 4)
        assert res.degraded.all()  # a lost shard taints every query
        for got, ref in zip((res.scores, res.file_ids, res.offsets), want):
            assert np.array_equal(got, ref)
        assert rt.stats.degraded_similar == 1
    finally:
        rt.close()


def test_router_all_dead_fails_fast_within_backoff(store_dir, probe_keys):
    rt, inj = _chaos_router(store_dir, fail_threshold=1)
    try:
        for tr in inj:
            tr.kill()  # whole endpoint down, every shard
        r1 = rt.lookup_batch_ex(probe_keys[:40])
        assert r1.degraded.all()
        # inside the backoff window candidates() is empty: the next batch
        # degrades without probing (fail-fast taxonomy "dead")
        r2 = rt.lookup_batch_ex(probe_keys[:40])
        assert r2.degraded.all()
        kinds = set()
        for errs in rt.stats.errors_per_shard.values():
            kinds.update(errs)
        assert "dead" in kinds
    finally:
        rt.close()


def test_chaos_acceptance_deterministic_degraded_and_recovery(
    store_dir, probe_keys
):
    """Acceptance: seeded chaos (1 dead shard + 1 slow shard) produces
    byte-identical degraded results across two runs, fires hedges, and
    returns byte-identical clean results after revival."""
    with ShardRouter(store_dir, replicas=2, min_scatter_keys=1) as clean:
        baseline = clean.lookup_batch(probe_keys)

    dead_shard, slow_shard = 2, 5

    def chaos_run():
        rt, inj = _chaos_router(
            store_dir, seed=42, probe_timeout_ms=400.0,
            hedge_floor_ms=5.0,
        )
        try:
            for tr in inj:
                tr.kill(shard=dead_shard)
                tr.set_latency(30.0, jitter_ms=10.0, shard=slow_shard)
            out = [rt.lookup_batch_ex(probe_keys) for _ in range(3)]
            stats = rt.stats
            # revive and wait out the probation backoff
            for tr in inj:
                tr.revive(shard=dead_shard)
                tr.clear()
            deadline = time.monotonic() + 10.0
            post = rt.lookup_batch_ex(probe_keys)
            while post.degraded.any() and time.monotonic() < deadline:
                time.sleep(0.1)
                post = rt.lookup_batch_ex(probe_keys)
            return out, post, stats, rt.health.snapshot()
        finally:
            rt.close()

    runs_a, post_a, stats_a, snap_a = chaos_run()
    runs_b, post_b, stats_b, snap_b = chaos_run()

    # the degraded results are deterministic: byte-identical across runs
    for ra, rb in zip(runs_a, runs_b):
        for col_a, col_b in zip(ra, rb):
            assert np.array_equal(col_a, col_b)
    # the slow shard pushed probes past the hedge point
    assert stats_a.hedges_fired > 0
    # degraded masks cover exactly the dead shard's key range
    sid = shard_of(
        digest_u64(probe_keys), 8, 64
    )
    assert np.array_equal(runs_a[0].degraded, sid == dead_shard)
    # post-revival: byte-identical to the no-fault baseline
    assert not post_a.degraded.any()
    for got, ref in zip(
        (post_a.file_ids, post_a.offsets, post_a.hit), baseline
    ):
        assert np.array_equal(got, ref)
    for got, ref in zip(
        (post_b.file_ids, post_b.offsets, post_b.hit), baseline
    ):
        assert np.array_equal(got, ref)
    assert snap_a["revivals"] >= 1
    assert snap_a["last_recovery_s"] > 0


# ---------------------------------------------------------------------------
# QueryService + loadgen under chaos
# ---------------------------------------------------------------------------

def test_service_threads_degraded_mask_through_batcher(corpus, store_dir):
    rstore, _ = corpus
    rt, inj = _chaos_router(store_dir)
    dead_shard = 4
    for tr in inj:
        tr.kill(shard=dead_shard)
    with QueryService(rstore, rt, ServiceConfig(replicas=2)) as svc:
        st = IndexStore.open(store_dir)
        keys = sorted(st.iter_keys())[:120]
        sid = shard_of(digest_u64(keys), st.n_shards, st.digest_bits)
        outs = {}

        def client(i):
            outs[i] = svc.lookup_batch(keys[i * 20:(i + 1) * 20])

        ths = [
            threading.Thread(target=client, args=(i,)) for i in range(6)
        ]
        for t in ths:
            t.start()
        for t in ths:
            t.join(10)
        for i, res in outs.items():
            want = (sid[i * 20:(i + 1) * 20] == dead_shard)
            assert np.array_equal(res.degraded, want)
            assert res.hit[~want].all()
        s = svc.stats()
        assert s["fault"]["degraded_keys"] == int(
            (sid[:120] == dead_shard).sum()
        )
        assert s["health"]["dead_domains"]
    rt.close()


def test_service_fetch_survives_dead_shard(corpus, store_dir):
    """fetch through a dead shard range: affected targets land in
    ``missing`` (the degraded contract), nothing raises, and every other
    record still round-trips byte-identically."""
    rstore, _ = corpus
    st = IndexStore.open(store_dir)
    keys = sorted(st.iter_keys())[:100]
    sid = shard_of(digest_u64(keys), st.n_shards, st.digest_bits)
    dead_shard = int(sid[0])  # guarantee at least one affected target
    rt, inj = _chaos_router(store_dir)
    for tr in inj:
        tr.kill(shard=dead_shard)
    with QueryService(rstore, rt, ServiceConfig(replicas=2)) as svc:
        res = svc.fetch(keys, verify=True)
        behind_dead = {k for k, s in zip(keys, sid) if s == dead_shard}
        assert behind_dead
        assert behind_dead <= set(res.missing)
        assert set(res.records) == set(keys) - set(res.missing)
        assert not res.mismatches
    rt.close()


def test_loadgen_separates_failed_degraded_and_counters():
    calls = [0]

    class FakeResult:
        def __init__(self, degraded):
            self.degraded = np.array([degraded])

    def request_fn(keys):
        calls[0] += 1
        if calls[0] % 5 == 0:
            raise RuntimeError("injected request failure")
        return FakeResult(degraded=(calls[0] % 3 == 0))

    hedges = [0]

    def counters():
        hedges[0] += 1
        return {"hedges_fired": hedges[0] * 2}

    rep = run_closed_loop(
        request_fn, ["k1", "k2"], clients=2, duration_s=0.3,
        classify=lambda r: bool(r.degraded.any()),
        counters_fn=counters,
    )
    assert rep.errors > 0 and rep.failed == rep.errors
    assert rep.degraded > 0
    assert rep.requests > 0
    assert rep.counters["hedges_fired"] == 2  # delta of the two snapshots
    assert "failed" in rep.summary() and "hedges" in rep.summary()
