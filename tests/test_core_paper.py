"""Core paper-system tests: identifiers, records, index, extraction,
collisions, intersection — unit + hypothesis property tests.
"""

import tempfile
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    ByteOffsetIndex,
    RecordStore,
    birthday_expectation,
    build_index,
    canonical_id,
    canonical_id_from_structure,
    collisions_from_pairs,
    extract,
    hashed_key,
    intersect_host,
    intersect_sorted,
    iter_record_offsets,
    iter_records,
    molecule_from_cid,
    naive_scan,
    pack_ids,
    read_record_at,
    scan_corpus,
    scan_pairs_sorted,
    unpack_ids,
)
from repro.core.records import extract_property, record_properties
from repro.core.sdfgen import (
    PROP_ID,
    PROP_KEY,
    CorpusSpec,
    db_id_list,
    generate_corpus,
    ground_truth_final_dataset,
    ground_truth_intersection,
    record_text_for_cid,
)


@pytest.fixture(scope="module")
def corpus():
    spec = CorpusSpec(n_files=3, records_per_file=500, key_bits=24)
    root = Path(tempfile.mkdtemp()) / "corpus"
    generate_corpus(root, spec)
    return RecordStore(root), spec


# ---------------------------------------------------------------------------
# identifiers
# ---------------------------------------------------------------------------

def test_canonical_id_deterministic_and_injective():
    ids = [canonical_id(molecule_from_cid(c)) for c in range(3000)]
    assert len(set(ids)) == 3000
    assert ids[7] == canonical_id(molecule_from_cid(7))


def test_hashed_key_format_and_truncation():
    k = hashed_key("InChI=1S/C2H6O/c1-2-3/h3H,2H2,1H3")
    assert len(k) == 27 and k[14] == "-" and k.endswith("SA-N")
    k8 = {hashed_key(f"id{i}", bits=8) for i in range(1000)}
    assert len(k8) <= 256  # 8-bit space cannot exceed 256 keys


def test_recompute_from_structure_roundtrip():
    for cid in range(0, 200, 17):
        spec = CorpusSpec()
        text = record_text_for_cid(cid, spec)
        assert canonical_id_from_structure(text) == extract_property(text, PROP_ID)


@settings(max_examples=40, deadline=None)
@given(cid=st.integers(0, 4**15 - 1))
def test_molecule_structural_validity(cid):
    mol = molecule_from_cid(cid)
    n = mol.natoms
    for a, b, order, stereo in mol.bonds:
        assert 0 <= a < b < n
        assert order in (1, 2)
    assert all(h >= 0 for h in mol.hcount)
    # connected: union-find over bonds
    parent = list(range(n))
    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x
    for a, b, _, _ in mol.bonds:
        parent[find(a)] = find(b)
    assert len({find(i) for i in range(n)}) == 1


@settings(max_examples=30, deadline=None)
@given(ids=st.lists(st.text(
    alphabet=st.characters(min_codepoint=33, max_codepoint=126), min_size=1,
    max_size=60), min_size=1, max_size=40))
def test_packing_roundtrip(ids):
    assert unpack_ids(pack_ids(ids)) == ids


# ---------------------------------------------------------------------------
# records / index
# ---------------------------------------------------------------------------

def test_record_iteration_and_seek(corpus):
    store, spec = corpus
    path = store.files()[0]
    records = list(iter_records(path))
    assert len(records) == spec.records_per_file
    # every recorded offset seeks back to the identical record
    for off, text in records[:: max(1, len(records) // 23)]:
        assert read_record_at(path, off) == text
    offs = list(iter_record_offsets(path))
    assert offs == [o for o, _ in records]


def test_index_build_serial_parallel_equal(corpus):
    store, spec = corpus
    i1 = build_index(store, workers=1)
    i2 = build_index(store, workers=2)
    assert i1.entries == i2.entries
    assert len(i1) == spec.n_records
    assert i1.stats.n_duplicate_keys == 0  # full ids are injective


def test_index_csv_roundtrip(corpus, tmp_path):
    store, _ = corpus
    idx = build_index(store)
    size = idx.save_csv(tmp_path / "ix.csv")
    assert size > 0
    back = ByteOffsetIndex.load_csv(tmp_path / "ix.csv")
    assert back.entries == idx.entries


def test_index_lookup_matches_linear_scan(corpus):
    store, _ = corpus
    idx = build_index(store)
    path = store.files()[1]
    for off, text in list(iter_records(path))[:40]:
        key = extract_property(text, PROP_ID)
        assert idx.lookup(key) == (path.name, off)


# ---------------------------------------------------------------------------
# extraction (Algorithm 3) + baseline (Algorithm 1)
# ---------------------------------------------------------------------------

def test_extraction_funnel_exact(corpus):
    store, spec = corpus
    idx = build_index(store)
    targets = intersect_host(
        db_id_list(spec, "chembl", extra_outside=10),
        db_id_list(spec, "emolecules", extra_outside=10),
    ).ids
    res = extract(store, idx, targets)
    assert res.found == len(ground_truth_intersection(spec))
    assert len(res.missing) == 10  # the outside-universe ids
    assert not res.mismatches
    # grouped: opens ≤ files, seeks == found
    assert res.files_opened <= len(store)
    assert res.seeks == res.found


def test_extraction_sorted_offsets_are_forward(corpus):
    store, spec = corpus
    idx = build_index(store)
    from repro.core.extract import plan_extraction

    targets = db_id_list(spec, "chembl")[:100]
    plan, _ = plan_extraction(idx, targets)
    for fname, items in plan.items():
        offs = [o for _, _, o in items]
        assert offs == sorted(offs)


def test_baseline_agrees_with_extraction(corpus):
    store, spec = corpus
    idx = build_index(store)
    targets = db_id_list(spec, "chembl")[:25]
    res_naive = naive_scan(store, targets, membership="set")
    res_idx = extract(store, idx, targets)
    assert set(res_naive.records) == set(res_idx.records)
    for k in res_naive.records:
        assert res_naive.records[k].strip() == res_idx.records[k].strip()


def test_ungrouped_extraction_equivalent(corpus):
    store, spec = corpus
    idx = build_index(store)
    targets = db_id_list(spec, "emolecules")[:30]
    a = extract(store, idx, targets, group_by_file=True)
    b = extract(store, idx, targets, group_by_file=False)
    assert a.records == b.records
    assert b.files_opened >= a.files_opened


# ---------------------------------------------------------------------------
# collisions (§VI)
# ---------------------------------------------------------------------------

def test_collision_scan_matches_dict_and_sorted_paths(corpus):
    store, _ = corpus
    rep = scan_corpus(store, key_bits=16)
    # independent sorted-path implementation agrees
    pairs = []
    for p in store.files():
        for _off, text in iter_records(p):
            fid = extract_property(text, PROP_ID)
            pairs.append((hashed_key(fid, 16), fid))
    sorted_path = scan_pairs_sorted([k for k, _ in pairs], [v for _, v in pairs])
    assert rep.colliding == sorted_path
    # birthday-bound order of magnitude (n=1500 at 16 bits => E≈17)
    e = birthday_expectation(rep.n_records, 16)
    assert 0.2 * e <= rep.n_colliding_keys <= 5 * e


def test_hashed_pipeline_mismatches_full_pipeline_clean(corpus):
    store, spec = corpus
    targets = db_id_list(spec, "chembl")
    idx_h = build_index(store, key_mode="hashed_key", key_bits=16, recompute_keys=True)
    res_h = extract(store, idx_h, targets, key_bits=16)
    idx_f = build_index(store, key_mode="full_id")
    res_f = extract(store, idx_f, targets)
    assert not res_f.mismatches
    assert res_f.found >= res_h.found
    # at 16 bits over 1500 records, shadowing must have occurred
    assert idx_h.stats.n_duplicate_keys > 0


def test_collisions_from_pairs_distinctness():
    pairs = [("K1", "a"), ("K1", "a"), ("K2", "a"), ("K2", "b"), ("K3", "c")]
    got = collisions_from_pairs(pairs)
    assert got == {"K2": ["a", "b"]}  # duplicates of same id are NOT collisions


# ---------------------------------------------------------------------------
# intersection (Eq. 1)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    a=st.lists(st.integers(0, 400), max_size=120),
    b=st.lists(st.integers(0, 400), max_size=120),
    c=st.lists(st.integers(0, 400), max_size=120),
)
def test_intersection_paths_agree_with_sets(a, b, c):
    la = [f"id{x}" for x in a]
    lb = [f"id{x}" for x in b]
    lc = [f"id{x}" for x in c]
    want = sorted(set(la) & set(lb) & set(lc))
    assert intersect_host(la, lb, lc).ids == want
    assert intersect_sorted(la, lb, lc).ids == want


def test_funnel_counts_reproduce_paper_shape(corpus):
    """db_final ⊂ extracted ⊂ targets ⊂ universe, all counts exact."""
    store, spec = corpus
    gt = ground_truth_intersection(spec)
    gtf = ground_truth_final_dataset(spec)
    assert len(gtf) <= len(gt) <= spec.n_records
    idx = build_index(store)
    targets = intersect_host(
        db_id_list(spec, "chembl"), db_id_list(spec, "emolecules")
    ).ids
    res = extract(store, idx, targets)
    assert res.found == len(gt)
