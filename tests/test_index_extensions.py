"""Beyond-paper index extensions: incremental updates + binary sidecar.

Both are the paper's own §VIII future-work items, implemented and tested.
"""

import tempfile
from pathlib import Path

import pytest

from repro.core import RecordStore, build_index, extract
from repro.core.index import BinaryIndex, file_fingerprints, update_index
from repro.core.records import extract_property, read_record_at
from repro.core.sdfgen import PROP_ID, CorpusSpec, generate_corpus, record_text_for_cid


@pytest.fixture()
def corpus(tmp_path):
    spec = CorpusSpec(n_files=3, records_per_file=200)
    root = tmp_path / "c"
    generate_corpus(root, spec)
    return RecordStore(root), spec


def test_incremental_update_only_rescans_changed(corpus):
    store, spec = corpus
    idx = build_index(store)
    fp = file_fingerprints(store)
    n0 = len(idx)

    # append two records to one file (database growth)
    target = store.files()[1]
    with open(target, "a", encoding="utf-8", newline="\n") as f:
        for cid in (spec.n_records + 1000, spec.n_records + 1001):
            f.write(record_text_for_cid(cid, spec))
            f.write("$$$$\n")

    fp2, summary = update_index(idx, store, fp)
    assert summary["rescanned"] == 1           # only the appended file
    assert len(idx) == n0 + 2
    # new record is addressable
    txt = record_text_for_cid(spec.n_records + 1000, spec)
    from repro.core.records import extract_property
    from repro.core.sdfgen import PROP_ID

    key = extract_property(txt, PROP_ID)
    loc = idx.lookup(key)
    assert loc is not None and loc[0] == target.name

    # no-op second update
    _, summary2 = update_index(idx, store, fp2)
    assert summary2 == {"rescanned": 0, "dropped": 0, "added": 0}


def test_incremental_update_handles_removed_file(corpus):
    store, spec = corpus
    idx = build_index(store)
    fp = file_fingerprints(store)
    victim = store.files()[2]
    victim.unlink()
    _, summary = update_index(idx, store, fp)
    assert summary["dropped"] == spec.records_per_file
    assert len(idx) == spec.n_records - spec.records_per_file
    # index remains extraction-consistent
    res = extract(store, idx, list(idx.entries.keys())[:20])
    assert res.found == 20 and not res.mismatches


def test_binary_sidecar_lookup_matches_dict(corpus, tmp_path):
    store, _ = corpus
    idx = build_index(store)
    path = tmp_path / "ix.npz"
    written, size = idx.save_binary(path)
    assert written == path and written.exists()
    assert size == written.stat().st_size
    bx = BinaryIndex(path)
    assert len(bx) == len(idx)
    for key in list(idx.entries.keys())[::37]:
        assert bx.lookup(key) == idx.lookup(key)
    assert bx.lookup("InChI=1S/NOT_A_REAL_ID") is None


def test_binary_sidecar_persists_key_mode(corpus, tmp_path):
    """A hashed-key sidecar must extract like its builder: key_mode travels
    with the file, so plan_extraction hashes the targets before lookup."""
    store, _ = corpus
    idx = build_index(store, key_mode="hashed_key")
    written, _ = idx.save_binary(tmp_path / "hx.npz")
    bx = BinaryIndex(written)
    assert bx.key_mode == "hashed_key"
    targets = [
        extract_property(read_record_at(store.files()[0], 0), PROP_ID)
    ]
    res = extract(store, bx, targets)
    assert res.found == 1 and not res.missing and not res.mismatches


def test_binary_sidecar_normalizes_suffix(corpus, tmp_path):
    """save_binary reports the file actually written (suffix added up front)."""
    store, _ = corpus
    idx = build_index(store)
    written, size = idx.save_binary(tmp_path / "ix")  # no .npz given
    assert written.name == "ix.npz" and written.exists()
    assert size == written.stat().st_size
    assert len(BinaryIndex(tmp_path / "ix")) == len(idx)
