"""Launcher entry-point smoke tests (subprocess, real CLI surface)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(args, timeout=500):
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", *args],
        capture_output=True, text=True, env=env, timeout=timeout, cwd=ROOT,
    )


def test_train_launcher_smoke(tmp_path):
    proc = _run([
        "repro.launch.train", "--arch", "yi-6b", "--steps", "6",
        "--seq-len", "64", "--global-batch", "4",
        "--corpus-records", "400", "--ckpt-every", "3",
        "--workdir", str(tmp_path / "run"),
    ])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "done: 6 steps" in proc.stdout
    assert (tmp_path / "run" / "ckpt").exists()


def test_serve_launcher_smoke():
    proc = _run([
        "repro.launch.serve", "--arch", "yi-6b",
        "--max-new-tokens", "4", "--max-len", "64",
        "--prompts", "InChI=1S/C4",
    ])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "tok/s" in proc.stdout


def test_dryrun_launcher_single_cell(tmp_path):
    out = tmp_path / "cell.jsonl"
    proc = _run([
        "repro.launch.dryrun", "--arch", "whisper-small",
        "--shape", "train_4k", "--out", str(out),
    ], timeout=560)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
    import json

    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["status"] == "ok"
    assert rec["roofline"]["flops_per_device"] > 0
    assert rec["mesh"] == "16x16"


def test_dryrun_skipped_cell(tmp_path):
    out = tmp_path / "skip.jsonl"
    proc = _run([
        "repro.launch.dryrun", "--arch", "qwen2-72b",
        "--shape", "long_500k", "--out", str(out),
    ])
    assert proc.returncode == 0
    import json

    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["status"] == "skipped"
