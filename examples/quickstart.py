"""Quickstart: the paper's pipeline end-to-end in one minute.

Generates a scale-model corpus (PubChem role) plus two overlapping id
lists (ChEMBL / eMolecules roles), builds the byte-offset index, runs the
three-way intersection, extracts the validated records with defensive
verification, and prints the integration funnel (paper Fig. 1).

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile
import time
from pathlib import Path

from repro.core import (
    RecordStore,
    build_index,
    extract,
    intersect_host,
    intersect_sorted,
)
from repro.core.sdfgen import (
    CorpusSpec,
    db_id_list,
    generate_corpus,
    ground_truth_final_dataset,
    ground_truth_intersection,
)
from repro.core.records import extract_property
from repro.core.sdfgen import PROP_XLOGP


def main():
    t0 = time.time()
    spec = CorpusSpec(n_files=4, records_per_file=2_000)
    root = Path(tempfile.mkdtemp()) / "corpus"
    print(f"① generating corpus: {spec.n_files} files × "
          f"{spec.records_per_file} records (PubChem role)…")
    manifest = generate_corpus(root, spec)
    store = RecordStore(root)
    print(f"   {manifest.total_bytes/1e6:.1f} MB on disk")

    print("② building byte-offset index (Algorithm 2)…")
    idx = build_index(store, key_mode="full_id", workers=2)
    print(f"   {len(idx)} entries in {idx.stats.build_seconds:.2f}s")

    print("③ three-way intersection (Eq. 1)…")
    chembl = db_id_list(spec, "chembl", extra_outside=30)
    emol = db_id_list(spec, "emolecules", extra_outside=30)
    inter = intersect_host(chembl, emol)
    inter2 = intersect_sorted(chembl, emol)
    assert inter.ids == inter2.ids, "host and sorted-merge paths disagree"
    print(f"   ChEMBL∩eMolecules = {inter.count} "
          f"(paper: 477,123)")

    print("④ index-based extraction with verification (Algorithm 3)…")
    res = extract(store, idx, inter.ids)
    print(f"   found {res.found}, not-in-pubchem {len(res.missing)}, "
          f"verify-mismatches {len(res.mismatches)}; "
          f"{res.files_opened} file opens for {res.seeks} seeks")

    with_prop = sum(
        1 for r in res.records.values()
        if extract_property(r, PROP_XLOGP) is not None
    )
    gt = ground_truth_intersection(spec)
    gt_final = ground_truth_final_dataset(spec)
    print("\n=== integration funnel (paper Fig. 1) ===")
    print(f"  pubchem universe        {spec.n_records:>8}   (paper 176,929,690)")
    print(f"  chembl ∩ emolecules     {inter.count:>8}   (paper 477,123)")
    print(f"  ∩ pubchem (extracted)   {res.found:>8}   (paper 435,413)")
    print(f"  with computed property  {with_prop:>8}   (paper 426,850)")
    assert res.found == len(gt), "extraction disagrees with ground truth!"
    assert with_prop == len(gt_final), "property filter disagrees!"
    print(f"\nground truth reproduced exactly — done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
