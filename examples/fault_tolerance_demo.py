"""Fault-tolerance demo: crash, restart, and elastic rescale mid-run.

Scenario driven by the coordinator logic in :mod:`repro.runtime.fault`:

  1. train with dp=4 (simulated shards on one host);
  2. hard-kill at step 12 (no final checkpoint — like a SIGKILL);
  3. detector sees the dead worker, survivors re-carve to dp=2;
  4. training resumes from the last catalog checkpoint with dp=2 —
     the deterministic sampler re-partitions the SAME global example
     order, so the token stream is bit-identical to an uninterrupted run.

The final assert proves the invariant the index-backed data plane buys:
elastic restarts do not change what the model trains on.

    PYTHONPATH=src python examples/fault_tolerance_demo.py
"""

import dataclasses
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.configs import get_config
from repro.core import RecordStore, build_index
from repro.core.sdfgen import CorpusSpec, generate_corpus
from repro.data.pipeline import IndexedDataset
from repro.data.sampler import GlobalSampler
from repro.runtime.fault import ElasticPlan, FailureDetector, Heartbeat
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = dataclasses.replace(
        get_config("yi-6b"),
        n_layers=2, d_model=96, n_heads=4, n_kv_heads=2, head_dim=24,
        d_ff=192, vocab_size=512,
    )
    root = Path(tempfile.mkdtemp()) / "c"
    generate_corpus(root, CorpusSpec(n_files=2, records_per_file=600))
    store = RecordStore(root)
    ds = IndexedDataset(store, build_index(store), seq_len=64)
    wd = Path(tempfile.mkdtemp())
    tcfg = TrainerConfig(seq_len=64, global_batch=8, steps=24, ckpt_every=5,
                         opt=AdamWConfig(lr=5e-4, warmup_steps=4, total_steps=24))

    print("— phase 1: dp=4, crash injected at step 12 —")
    tr = Trainer(cfg, tcfg, ds, wd, n_dp=1)  # host runs the fused dp=4 batch
    for r in range(4):
        Heartbeat(wd, r).beat(0)
    reached, _, hist1 = tr.run(die_at_step=12)
    print(f"  crashed at step {reached}; last checkpoint: "
          f"{tr.ckpt.latest_step()}")

    print("— phase 2: failure detection + elastic plan —")
    time.sleep(0.2)
    det = FailureDetector(wd, n_workers=4, timeout=0.1)  # all heartbeats stale
    dead = det.dead()
    plan = ElasticPlan.for_survivors(n_survivors=4 - len(dead[:2]), n_model=1)
    print(f"  stale/dead workers: {dead} → re-carve to dp={plan.n_dp}")

    print("— phase 3: resume from checkpoint with the elastic plan —")
    tr2 = Trainer(cfg, tcfg, ds, wd, n_dp=1)
    final, _, hist2 = tr2.run()
    print(f"  resumed at {hist2[0]['step']}, finished at {final}")

    # invariant: the token stream equals the uninterrupted run's
    smp = GlobalSampler(len(ds), tcfg.global_batch, seed=tcfg.seed)
    for step in (10, 15, 20):
        full = ds.batch_for(smp, step, 0, 1)["tokens"]
        parts = np.concatenate(
            [ds.batch_for(smp, step, r, plan.n_dp)["tokens"]
             for r in range(plan.n_dp)]
        )
        assert np.array_equal(full, parts), f"token stream diverged at {step}"
    print("  token-stream invariance across dp re-carve verified ✓")
    losses = [h["loss"] for h in hist1] + [h["loss"] for h in hist2]
    print(f"  loss trajectory: {losses[0]:.3f} → {losses[-1]:.3f} "
          f"across crash + restart")


if __name__ == "__main__":
    main()
