"""End-to-end training driver: byte-offset-indexed corpus → LM training.

The data plane is the paper's architecture verbatim: records are fetched
per step through the index with grouped, offset-sorted seeks; addressing
is stateless so the checkpoint stores one integer of pipeline state.
Training runs with catalog checkpoints and demonstrates restart.

Defaults are sized for the 1-core CPU container (a ~3M-param model, 80
steps).  ``--preset 100m --steps 300`` is the full-size configuration for
real hardware; the dry-run proves the same code lowers at 72B+.

    PYTHONPATH=src python examples/train_indexed_lm.py [--steps 80]
"""

import argparse
import dataclasses
import tempfile
from pathlib import Path

from repro.configs import get_config
from repro.core import RecordStore, build_index
from repro.core.sdfgen import CorpusSpec, generate_corpus
from repro.data.pipeline import IndexedDataset
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    # name: (layers, d_model, heads, kv, d_ff, seq, batch) — vocab fixed 512
    "tiny": (2, 128, 4, 2, 256, 128, 8),
    "20m": (6, 384, 6, 2, 1024, 256, 8),
    "100m": (12, 768, 12, 4, 2048, 512, 16),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--records", type=int, default=8_000)
    ap.add_argument("--workdir", type=str, default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    L, D, H, KV, F, S, B = PRESETS[args.preset]
    cfg = dataclasses.replace(
        get_config("yi-6b"),
        n_layers=L, d_model=D, n_heads=H, n_kv_heads=KV,
        head_dim=D // H, d_ff=F, vocab_size=512,
    )

    root = Path(tempfile.mkdtemp()) / "corpus" if not args.workdir else (
        Path(args.workdir) / "corpus"
    )
    spec = CorpusSpec(n_files=4, records_per_file=args.records // 4)
    generate_corpus(root, spec)
    store = RecordStore(root)
    idx = build_index(store)
    ds = IndexedDataset(store, idx, seq_len=S)
    print(f"indexed dataset: {len(ds)} records "
          f"({ds.stats.verify_failures} verify failures)")

    workdir = Path(args.workdir) if args.workdir else Path(tempfile.mkdtemp())
    tcfg = TrainerConfig(
        seq_len=S, global_batch=B, steps=args.steps, ckpt_every=20,
        compress_grads=args.compress_grads,
        opt=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
    )
    tr = Trainer(cfg, tcfg, ds, workdir)
    n_params = None

    def log(step, rec):
        if step % 10 == 0 or step == args.steps - 1:
            print(f"  step {step:4d}  loss {rec['loss']:.4f}  "
                  f"gnorm {rec['grad_norm']:.2f}  lr {rec['lr']:.2e}  "
                  f"{rec['dt']*1e3:.0f} ms")

    print(f"training {args.preset} preset for {args.steps} steps "
          f"(ckpt every {tcfg.ckpt_every} into {workdir})")
    final, state, hist = tr.run(on_step=log)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss {first:.4f} → {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'}); "
          f"latest checkpoint step {tr.ckpt.latest_step()}")
    assert last < first, "training failed to reduce loss"
    # fetch-pattern stats: the paper's access optimization at work
    print(f"data plane: {ds.stats.fetches} record fetches, "
          f"{ds.stats.retries} straggler retries, "
          f"{ds.stats.verify_failures} verification failures")


if __name__ == "__main__":
    main()
