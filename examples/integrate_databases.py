"""Full paper narrative: hashed-key pipeline → collision discovery →
migration to collision-free full ids (§VI).

Runs the integration twice: first keyed by the 27-char hashed key at a
collision-prone effective width (so the hundred-million-scale phenomenon
is observable at demo scale), watching Algorithm 3's defensive
verification catch the collisions; then migrated to full canonical ids,
verifying zero mismatches, with the Eq. 4/5 birthday-bound analysis.
Finally the migrated index is published as the sharded mmap-backed
``IndexStore`` and the whole target list is served through one batched
``lookup_batch`` call — the serving-grade query path — the read phase
itself is re-run through the pipelined extraction engine (coalesced
preads, parallel file workers, record cache) to show the serial loop and
the engine produce identical output at very different speeds, and the
whole stack is stood up as the async ``QueryService`` with concurrent
clients coalescing through the continuous-batching scheduler.

    PYTHONPATH=src python examples/integrate_databases.py [--records 24000]
"""

import argparse
import tempfile
import threading
import time
from pathlib import Path

from repro.core import (
    IndexStore,
    RecordCache,
    RecordStore,
    birthday_expectation,
    build_index,
    extract,
    extract_iter,
    intersect_host,
    scan_corpus,
)
from repro.core.sdfgen import CorpusSpec, db_id_list, generate_corpus
from repro.service import QueryService, ServiceConfig

KEY_BITS = 22  # collision-prone at demo scale (E[collisions] = n²/2^23)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=24_000)
    ap.add_argument("--files", type=int, default=6)
    args = ap.parse_args()

    spec = CorpusSpec(
        n_files=args.files,
        records_per_file=args.records // args.files,
        key_bits=KEY_BITS,
    )
    root = Path(tempfile.mkdtemp()) / "corpus"
    print(f"corpus: {spec.n_records} records, hashed keys truncated to "
          f"{KEY_BITS} bits (models the paper's 1e15 space at 177M records)")
    generate_corpus(root, spec)
    store = RecordStore(root)

    targets = intersect_host(
        db_id_list(spec, "chembl"), db_id_list(spec, "emolecules")
    ).ids
    print(f"targets (ChEMBL∩eMolecules role): {len(targets)}")

    # ---- phase 1: hashed-key pipeline (pre-§VI.C) --------------------------
    print("\n— phase 1: index keyed by hashed 27-char key —")
    idx_h = build_index(store, key_mode="hashed_key", key_bits=KEY_BITS)
    print(f"  index entries {len(idx_h)}, shadowed duplicate keys "
          f"{idx_h.stats.n_duplicate_keys} (collisions silently shadow records!)")
    res_h = extract(store, idx_h, targets, key_bits=KEY_BITS)
    print(f"  extraction: found {res_h.found}, verification MISMATCHES "
          f"{len(res_h.mismatches)}  ← the §VI.A discovery moment")
    for m in res_h.mismatches[:3]:
        print(f"    key {m.lookup_key} fetched a structurally different "
              f"molecule at {m.file}:{m.offset}")

    # ---- phase 2: systematic collision scan (§VI.B) ------------------------
    print("\n— phase 2: systematic full-corpus collision scan —")
    rep = scan_corpus(store, key_bits=KEY_BITS)
    e = birthday_expectation(rep.n_records, KEY_BITS)
    print(f"  {rep.n_colliding_keys} colliding keys affecting "
          f"{rep.n_affected_records} records; birthday bound E={e:.1f} "
          f"(paper: 163 observed vs E=15.7 at their scale)")
    print(f"  empirical rate {rep.empirical_rate:.2e} (paper Eq.4: 1.84e-6)")

    # ---- phase 3: migration to full ids (§VI.C) ----------------------------
    print("\n— phase 3: migrated pipeline (full canonical ids) —")
    idx_f = build_index(store, key_mode="full_id")
    res_f = extract(store, idx_f, targets)
    print(f"  extraction: found {res_f.found}, mismatches "
          f"{len(res_f.mismatches)} (deterministic uniqueness)")
    assert len(res_f.mismatches) == 0
    assert res_f.found >= res_h.found
    print("\nmigration recovered every record the hashed pipeline lost — "
          "the paper's conclusion, reproduced")

    # ---- phase 4: publish as the sharded query service (beyond-paper) ------
    print("\n— phase 4: sharded mmap-backed IndexStore (query-service layer) —")
    store_dir = root.parent / "index_store"
    summary = idx_f.save_sharded(store_dir, n_shards=8)
    qs = IndexStore.open(store_dir)
    print(f"  published {summary['n_entries']} entries into "
          f"{summary['written']} shards ({qs.total_bytes()/1e6:.2f} MB on disk)")
    file_ids, offsets, hit = qs.lookup_batch(targets)
    print(f"  one lookup_batch over {len(targets)} targets: "
          f"{int(hit.sum())} hits, {qs.stats.bloom_rejects} bloom rejects, "
          f"{qs.stats.verify_collisions} digest collisions verified away, "
          f"{qs.shards_loaded}/{qs.n_shards} shards faulted in")
    assert int(hit.sum()) == len(targets) - len(res_f.missing)
    # the store is a drop-in read backend for Algorithm 3
    res_s = extract(store, qs, targets)
    assert res_s.found == res_f.found and not res_s.mismatches
    print(f"  extraction through the store matches the dict index "
          f"({res_s.found} records) — same truth, O(touched shards) memory")

    # ---- phase 5: pipelined read engine + record cache (beyond-paper) ------
    print("\n— phase 5: pipelined extraction engine (coalesced preads + cache) —")
    t0 = time.perf_counter()
    res_serial = extract(store, qs, targets, workers=0)
    t_serial = time.perf_counter() - t0
    cache = RecordCache(capacity=2 * len(targets))
    t0 = time.perf_counter()
    res_p = extract(store, qs, targets, workers=4, coalesce_gap=64 * 1024,
                    cache=cache)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_w = extract(store, qs, targets, workers=4, coalesce_gap=64 * 1024,
                    cache=cache)
    t_warm = time.perf_counter() - t0
    assert list(res_p.records.items()) == list(res_serial.records.items())
    assert list(res_w.records.items()) == list(res_serial.records.items())
    print(f"  serial workers=0: {t_serial*1e3:.0f} ms; pipelined cold: "
          f"{t_cold*1e3:.0f} ms ({res_p.spans_read} pread spans for "
          f"{res_p.seeks} records); warm: {t_warm*1e3:.0f} ms "
          f"({res_w.cache_hits}/{res_w.seeks} cache hits)")
    print(f"  byte-identical output on all three paths; warm speedup "
          f"{t_serial/max(t_warm, 1e-9):.1f}x")
    # streaming consumption: records arrive as their file worker verifies
    n_stream = sum(1 for _ in extract_iter(store, qs, targets, cache=cache))
    print(f"  extract_iter streamed {n_stream} verified records "
          f"(plan/probe amortized through the same lookup_batch)")

    # ---- phase 6: the async query service (scatter-gather + micro-batching) -
    print("\n— phase 6: QueryService (router → scheduler → reader → cache) —")
    with QueryService(store, store_dir,
                      ServiceConfig(replicas=2, max_batch=512)) as svc:
        res_svc = svc.fetch(targets)
        assert list(res_svc.records.items()) == list(res_serial.records.items())
        print(f"  svc.fetch parity vs serial extract: {res_svc.found} records "
              f"byte-identical")
        # many concurrent clients, each asking for a handful of records:
        # the scheduler re-coalesces them into the big batched probes the
        # store is built for
        n_clients, reqs_per_client, kpr = 8, 40, 4
        done = [0] * n_clients

        def client(ci: int) -> None:
            for r in range(reqs_per_client):
                i = (ci * 131 + r * kpr) % max(1, len(targets) - kpr)
                locs = svc.lookup(targets[i:i + kpr])
                done[ci] += sum(1 for l in locs if l is not None)

        t0 = time.perf_counter()
        ths = [threading.Thread(target=client, args=(i,))
               for i in range(n_clients)]
        for t in ths:
            t.start()
        for t in ths:
            t.join()
        dt = time.perf_counter() - t0
        s = svc.stats()
        sch = s["scheduler"]
        print(f"  {n_clients} clients x {reqs_per_client} requests x {kpr} keys "
              f"in {dt*1e3:.0f} ms ({sum(done)/dt:,.0f} lookups/s)")
        print(f"  scheduler: {sch['batches']} probes for {sch['requests']} "
              f"requests (mean batch {sch['mean_batch_keys']:.1f} keys, "
              f"{sch['coalesced_batches']} coalesced), p50 "
              f"{sch['latency_ms']['p50']:.2f} ms")
        print(f"  cache: {s['cache']['hit_rate']:.0%} hit rate "
              f"({s['cache']['protected']} protected / "
              f"{s['cache']['probation']} probation)")


if __name__ == "__main__":
    main()
