"""Serving example: batched requests through prefill + decode.

Loads (or quickly trains) a small LM on the indexed corpus, then serves a
batch of molecular-id prompts through the Engine — prefill once, decode
with per-sequence positions, EOS stopping.  The decode inner loop is the
same ``serve_step`` the multi-pod dry-run lowers at 32k/500k context.

    PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses
import tempfile
from pathlib import Path

import jax

from repro.configs import get_config
from repro.core import RecordStore, build_index
from repro.core.sdfgen import CorpusSpec, generate_corpus
from repro.data.pipeline import IndexedDataset
from repro.serve.engine import Engine, ServeConfig
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = dataclasses.replace(
        get_config("yi-6b"),
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512,
    )
    root = Path(tempfile.mkdtemp()) / "c"
    spec = CorpusSpec(n_files=2, records_per_file=1_000)
    generate_corpus(root, spec)
    store = RecordStore(root)
    ds = IndexedDataset(store, build_index(store), seq_len=96)

    print("fitting a small LM on the indexed corpus (30 steps)…")
    tr = Trainer(
        cfg,
        TrainerConfig(seq_len=96, global_batch=8, steps=30, ckpt_every=30,
                      opt=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30)),
        ds,
        Path(tempfile.mkdtemp()),
    )
    _, state, hist = tr.run()
    print(f"  loss {hist[0]['loss']:.3f} → {hist[-1]['loss']:.3f}")

    engine = Engine(cfg, state["params"],
                    ServeConfig(max_new_tokens=24, max_len=160))
    prompts = [
        "InChI=1S/C12H22O2/",
        "InChI=1S/C8H9NO2/",
        "InChI=1S/C12H22O2/",   # duplicate: batched decode must agree
    ]
    print(f"serving batch of {len(prompts)} requests…")
    results = engine.generate(prompts)
    for i, r in enumerate(results):
        print(f"  [{i}] prompt_len={r.prompt_len} steps={r.steps} "
              f"prefill={r.prefill_s*1e3:.0f}ms "
              f"decode={r.tokens_per_s:.0f} tok/s")
        print(f"      → {r.text[:60]!r}")
    # batched decode determinism: identical prompts, identical continuations
    assert results[0].token_ids == results[2].token_ids, \
        "identical prompts diverged in one batch!"
    print("batched decode determinism verified")


if __name__ == "__main__":
    main()
