"""Deterministic synthetic SDF corpus generation.

The container has no 3.2 TB PubChem mirror, so the paper's corpus is
reproduced as a *scale model*: ``n_files`` SDF files × ``records_per_file``
records (the paper: 354 × 500,000), with the same structural features the
paper's system depends on:

* variable-length records delimited by ``$$$$``;
* an embedded full canonical id (``PUBCHEM_IUPAC_INCHI`` role) and a
  hashed key (``REPRO_ID_KEY``, InChIKey role) per record;
* a structure block from which the id is *recomputable* (Algorithm 3's
  defensive verification);
* occasional missing computed properties (the paper's 8,563 exclusions);
* three overlapping "databases" (pubchem/chembl/emolecules roles) with a
  known ground-truth intersection, so the integration funnel (Fig. 1) is
  exactly checkable.

Everything is a pure function of integer compound ids (cids), so corpora
are reproducible and any worker can regenerate any record independently —
the property that the data-plane fault-tolerance story relies on.
"""

from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass, asdict
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .identifiers import (
    DEFAULT_KEY_BITS,
    Molecule,
    canonical_id,
    hashed_key,
    molecule_from_cid,
    structure_block,
    _rng_stream,
)

__all__ = [
    "CorpusSpec",
    "CorpusManifest",
    "generate_corpus",
    "load_manifest",
    "record_text_for_cid",
    "db_membership",
    "ground_truth_intersection",
    "PROP_ID",
    "PROP_KEY",
    "PROP_CID",
    "PROP_XLOGP",
]

PROP_CID = "PUBCHEM_COMPOUND_CID"
PROP_ID = "PUBCHEM_IUPAC_INCHI"          # full canonical id (collision-free)
PROP_KEY = "REPRO_ID_KEY"                # hashed 27-char key (collision-prone)
PROP_XLOGP = "PUBCHEM_XLOGP3"            # the ML target property

# Database membership rules (pure functions of cid => ground truth known):
#   pubchem    : all cids in [0, n_records)
#   chembl     : cid % CHEMBL_MOD == 0
#   emolecules : cid % EMOL_MOD == 0
# Intersection of all three: cid % lcm(CHEMBL_MOD, EMOL_MOD) == 0.
CHEMBL_MOD = 7
EMOL_MOD = 11


@dataclass(frozen=True)
class CorpusSpec:
    n_files: int = 8
    records_per_file: int = 2_000
    key_bits: int = DEFAULT_KEY_BITS
    salt: str = "repro-corpus-v1"
    # Probability (per mille) that a record lacks the computed property —
    # reproduces the paper's final-phase exclusions (8,563 / 435,413 ≈ 2%).
    missing_prop_per_mille: int = 20

    @property
    def n_records(self) -> int:
        return self.n_files * self.records_per_file


@dataclass
class CorpusManifest:
    spec: CorpusSpec
    root: str
    files: List[str]
    total_bytes: int

    def save(self) -> None:
        p = Path(self.root) / "manifest.json"
        payload = {
            "spec": asdict(self.spec),
            "root": self.root,
            "files": self.files,
            "total_bytes": self.total_bytes,
        }
        p.write_text(json.dumps(payload, indent=1))


def load_manifest(root: Path) -> CorpusManifest:
    payload = json.loads((Path(root) / "manifest.json").read_text())
    return CorpusManifest(
        spec=CorpusSpec(**payload["spec"]),
        root=payload["root"],
        files=payload["files"],
        total_bytes=payload["total_bytes"],
    )


def _has_xlogp(cid: int, spec: CorpusSpec) -> bool:
    rng = _rng_stream(cid, spec.salt + ":prop")
    return not rng.chance(spec.missing_prop_per_mille, 1000)


def _xlogp_value(cid: int, spec: CorpusSpec) -> float:
    rng = _rng_stream(cid, spec.salt + ":xlogp")
    return round(-3.0 + 10.0 * rng.u16() / 65535.0, 2)


def record_text_for_cid(cid: int, spec: CorpusSpec) -> str:
    """Render one SDF record (without the ``$$$$`` terminator line)."""
    mol = molecule_from_cid(cid, spec.salt)
    full_id = canonical_id(mol)
    key = hashed_key(full_id, spec.key_bits)
    lines = [
        f"CID-{cid:09d}",
        "  repro-sdfgen",
        "",
        structure_block(mol),
        f"> <{PROP_CID}>",
        str(cid),
        "",
        f"> <{PROP_ID}>",
        full_id,
        "",
        f"> <{PROP_KEY}>",
        key,
        "",
    ]
    if _has_xlogp(cid, spec):
        lines += [f"> <{PROP_XLOGP}>", f"{_xlogp_value(cid, spec):.2f}", ""]
    return "\n".join(lines) + "\n"


def _file_cid_range(file_idx: int, spec: CorpusSpec) -> range:
    s = spec.records_per_file
    return range(file_idx * s, (file_idx + 1) * s)


def generate_corpus(root: Path, spec: CorpusSpec, force: bool = False) -> CorpusManifest:
    """Write the corpus to ``root`` (idempotent unless ``force``).

    File ``compound_{i:05d}.sdf`` holds cids ``[i*S, (i+1)*S)`` — mirroring
    PubChem's fixed 500k-compounds-per-file layout.
    """
    root = Path(root)
    manifest_path = root / "manifest.json"
    if manifest_path.exists() and not force:
        m = load_manifest(root)
        if m.spec == spec:
            return m
    root.mkdir(parents=True, exist_ok=True)
    files: List[str] = []
    total = 0
    for i in range(spec.n_files):
        name = f"compound_{i:05d}.sdf"
        path = root / name
        with open(path, "w", encoding="utf-8", newline="\n") as f:
            for cid in _file_cid_range(i, spec):
                f.write(record_text_for_cid(cid, spec))
                f.write("$$$$\n")
        files.append(name)
        total += path.stat().st_size
    m = CorpusManifest(spec=spec, root=str(root), files=files, total_bytes=total)
    m.save()
    return m


# ---------------------------------------------------------------------------
# The three "databases" and their ground-truth intersection.
# ---------------------------------------------------------------------------

def db_membership(cid: int, db: str) -> bool:
    if db == "pubchem":
        return True
    if db == "chembl":
        return cid % CHEMBL_MOD == 0
    if db == "emolecules":
        return cid % EMOL_MOD == 0
    raise ValueError(f"unknown db {db!r}")


def db_id_list(spec: CorpusSpec, db: str, extra_outside: int = 0) -> List[str]:
    """Full canonical ids of the ``db`` subset of the universe.

    ``extra_outside`` appends ids of molecules *not* in the pubchem corpus
    (cids beyond the universe) — reproducing the paper's funnel where
    477,123 ChEMBL∩eMolecules compounds shrink to 435,413 found in PubChem.
    """
    ids = [
        canonical_id(molecule_from_cid(cid, spec.salt))
        for cid in range(spec.n_records)
        if db_membership(cid, db)
    ]
    for k in range(extra_outside):
        cid = spec.n_records + k
        ids.append(canonical_id(molecule_from_cid(cid, spec.salt)))
    return ids


def ground_truth_intersection(spec: CorpusSpec) -> List[int]:
    """cids present in all three databases (pure arithmetic ground truth)."""
    step = CHEMBL_MOD * EMOL_MOD  # lcm(7, 11)
    return list(range(0, spec.n_records, step))


def ground_truth_final_dataset(spec: CorpusSpec) -> List[int]:
    """Intersection cids that also carry the computed property (XLOGP role).

    The paper's final analytical dataset: 435,413 intersection molecules
    minus 8,563 lacking computed properties → 426,850.
    """
    return [c for c in ground_truth_intersection(spec) if _has_xlogp(c, spec)]
