"""Bloom-filter sidecars: cheap membership prefilter for index shards.

A shard's Bloom filter answers "could this digest be in the shard?" from a
few cache-line-sized bit probes — misses (the overwhelmingly common case in
a sharded deployment where most keys route elsewhere or don't exist) are
rejected without touching the shard's mmap'd columns at all.  False
positives cost one wasted sorted-digest probe, never a wrong answer: the
digest search and the full-key verify behind it stay authoritative
(Algorithm 3 discipline).  This is the standard cheap-prefilter for
membership-heavy chemical workloads (Medina & White 2023).

Everything is vectorized numpy over ``uint64`` digest arrays so the filter
slots directly into the batched ``IndexStore.lookup_batch`` path.  Probe
positions come from double hashing (Kirsch & Mitzenmacher): ``h1`` is the
digest itself (already uniform — blake2b output), ``h2`` a splitmix64 remix
forced odd, position ``i`` is ``(h1 + i*h2) mod m`` with ``m`` a power of
two so the mod is a mask.
"""

from __future__ import annotations

import math
from typing import Optional

import numpy as np

__all__ = ["BloomFilter"]

# splitmix64 finalizer constants (public-domain mixing function).
_SM_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_SM_MUL1 = np.uint64(0xBF58476D1CE4E5B9)
_SM_MUL2 = np.uint64(0x94D049BB133111EB)

_MAX_K = 16
_MIN_BITS = 64  # floor so empty/tiny shards still get a valid bitmap


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer, vectorized over uint64 (wrapping arithmetic)."""
    z = x + _SM_GAMMA
    z = (z ^ (z >> np.uint64(30))) * _SM_MUL1
    z = (z ^ (z >> np.uint64(27))) * _SM_MUL2
    return z ^ (z >> np.uint64(31))


class BloomFilter:
    """Fixed-size Bloom filter over uint64 digests.

    ``bits`` is the packed bitmap (uint8, little-endian bit order within a
    byte); ``k`` the number of probe positions per digest.  Construction
    picks ``m`` as the next power of two ≥ ``n * bits_per_key`` and
    ``k ≈ (m/n) ln 2`` (the FPR-optimal count), so the default 12 bits/key
    lands near a 0.5 % false-positive rate.
    """

    __slots__ = ("bits", "k", "m")

    def __init__(self, bits: np.ndarray, k: int):
        if bits.dtype != np.uint8:
            raise ValueError(f"bitmap must be uint8, got {bits.dtype}")
        self.bits = bits
        self.k = int(k)
        self.m = int(bits.shape[0]) * 8

    # -- construction -------------------------------------------------------

    @staticmethod
    def plan(n: int, bits_per_key: int = 12) -> tuple:
        """The ``(m, k)`` :meth:`build` would choose for ``n`` keys.

        Deterministic in ``(n, bits_per_key)``, so callers can record the
        probe count of an existing sidecar without materializing a bitmap
        (incremental republish skips unchanged shards entirely).
        """
        m = 1 << max(
            _MIN_BITS.bit_length() - 1, (max(1, n) * bits_per_key - 1).bit_length()
        )
        k = int(min(_MAX_K, max(1, round(math.log(2) * m / max(1, n)))))
        return m, k

    @classmethod
    def build(
        cls,
        digests: np.ndarray,
        bits_per_key: int = 12,
        k: Optional[int] = None,
    ) -> "BloomFilter":
        n = int(len(digests))
        m, k_auto = cls.plan(n, bits_per_key)
        k = k_auto if k is None else int(min(k, _MAX_K))
        bits = np.zeros(m // 8, dtype=np.uint8)
        bf = cls(bits, k)
        if n:
            bf.add(digests)
        return bf

    def add(self, digests: np.ndarray) -> None:
        d = np.asarray(digests, dtype=np.uint64)
        h2 = _mix64(d) | np.uint64(1)
        mask = np.uint64(self.m - 1)
        for i in range(self.k):
            pos = (d + np.uint64(i) * h2) & mask
            byte_idx = (pos >> np.uint64(3)).astype(np.int64)
            bit = np.left_shift(
                np.uint8(1), (pos & np.uint64(7)).astype(np.uint8)
            )
            np.bitwise_or.at(self.bits, byte_idx, bit)

    # -- queries ------------------------------------------------------------

    def contains(self, digests: np.ndarray) -> np.ndarray:
        """Vectorized membership: bool mask, no false negatives.

        All ``k`` probe positions are materialized as one ``(k, n)`` grid and
        tested in a single numpy pass: for the small batches a query service
        coalesces (a handful of keys per shard), ``k`` sequential
        length-``n`` passes were dominated by per-op dispatch overhead, not
        by the probes themselves.
        """
        d = np.asarray(digests, dtype=np.uint64)
        if d.shape[0] == 0:
            return np.ones(0, dtype=bool)
        h2 = _mix64(d) | np.uint64(1)
        i = np.arange(self.k, dtype=np.uint64)[:, None]
        pos = (d[None, :] + i * h2[None, :]) & np.uint64(self.m - 1)
        byte = self.bits[(pos >> np.uint64(3)).astype(np.int64)]
        bit = (byte >> (pos & np.uint64(7)).astype(np.uint8)) & np.uint8(1)
        return bit.all(axis=0)

    # -- diagnostics --------------------------------------------------------

    def expected_fpp(self, n: int) -> float:
        """Theoretical false-positive probability after inserting ``n`` keys."""
        if n <= 0:
            return 0.0
        return (1.0 - math.exp(-self.k * n / self.m)) ** self.k

    @property
    def nbytes(self) -> int:
        return int(self.bits.nbytes)
