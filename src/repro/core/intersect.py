"""Eq. 1 — multi-source intersection: D_final = D_A ∩ D_B ∩ D_C.

Two implementations, cross-validated:

* ``intersect_host``   — Python set intersection (the paper's "standard set
  operations on identifier lists", 2.5 h at their scale).
* ``intersect_sorted`` — packed-digest sort-merge on NumPy arrays, the
  TPU-idiomatic path whose inner membership step is what the
  ``sorted_probe`` Pallas kernel accelerates on device.  Digest hits are
  verified on the full string id over the *whole* equal-digest run
  (collision-safe by construction — the same run-scan discipline the
  sharded :class:`repro.core.store.IndexStore` applies), using the shared
  :func:`repro.core.store.digest_u64` / :func:`candidate_runs` helpers
  rather than a private copy.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from .store import candidate_runs, digest_u64

__all__ = ["IntersectionResult", "intersect_host", "intersect_sorted", "digest_u64"]


@dataclass
class IntersectionResult:
    ids: List[str]
    seconds: float
    method: str

    @property
    def count(self) -> int:
        return len(self.ids)


def intersect_host(*id_lists: Sequence[str]) -> IntersectionResult:
    t0 = time.perf_counter()
    if not id_lists:
        return IntersectionResult([], 0.0, "host")
    acc = set(id_lists[0])
    for ids in id_lists[1:]:
        acc &= set(ids)
    out = sorted(acc)
    return IntersectionResult(out, time.perf_counter() - t0, "host")


def intersect_sorted(
    *id_lists: Sequence[str], digest_bits: int = 64
) -> IntersectionResult:
    """Sort-merge intersection over packed digests, string-verified.

    The device-friendly formulation: digests of list k+1 are probed against
    the sorted digest table of the running intersection via binary search
    (``np.searchsorted`` here; ``kernels/sorted_probe`` on TPU).  Each probe
    inspects the full ``[left, right)`` equal-digest run — a ``side="left"``
    position alone would only verify the first of several colliding table
    digests and silently drop true members behind it.

    ``digest_bits < 64`` narrows the digest space (collision studies and
    tests; mirrors ``hashed_key``'s width knob) — results stay exact because
    of string verification, only the collision rate changes.
    """
    t0 = time.perf_counter()
    if not id_lists:
        return IntersectionResult([], 0.0, "sorted")
    cur_ids: List[str] = list(dict.fromkeys(id_lists[0]))  # dedupe, keep order
    cur_dig = digest_u64(cur_ids, bits=digest_bits)
    order = np.argsort(cur_dig, kind="stable")
    cur_ids = [cur_ids[i] for i in order]
    cur_dig = cur_dig[order]

    for ids in id_lists[1:]:
        probe_ids = list(dict.fromkeys(ids))
        probe_dig = digest_u64(probe_ids, bits=digest_bits)
        starts, stops = candidate_runs(cur_dig, probe_dig)
        keep_ids: List[str] = []
        keep_dig: List[np.uint64] = []
        for i in np.nonzero(stops > starts)[0]:
            # digest hit -> verify on the full string id, scanning the whole
            # equal-digest run (collision-safe)
            for t in range(int(starts[i]), int(stops[i])):
                if cur_ids[t] == probe_ids[i]:
                    keep_ids.append(probe_ids[i])
                    keep_dig.append(probe_dig[i])
                    break
        kd = np.array(keep_dig, dtype=np.uint64)
        order = np.argsort(kd, kind="stable")
        cur_ids = [keep_ids[i] for i in order]
        cur_dig = kd[order]

    out = sorted(cur_ids)
    return IntersectionResult(out, time.perf_counter() - t0, "sorted")
