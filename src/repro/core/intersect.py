"""Eq. 1 — multi-source intersection: D_final = D_A ∩ D_B ∩ D_C.

Two implementations, cross-validated:

* ``intersect_host``   — Python set intersection (the paper's "standard set
  operations on identifier lists", 2.5 h at their scale).
* ``intersect_sorted`` — packed-digest sort-merge on NumPy arrays, the
  TPU-idiomatic path whose inner membership step is what the
  ``sorted_probe`` Pallas kernel accelerates on device.  Digest hits are
  verified on the full string id (collision-safe by construction).
"""

from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = ["IntersectionResult", "intersect_host", "intersect_sorted", "digest_u64"]


@dataclass
class IntersectionResult:
    ids: List[str]
    seconds: float
    method: str

    @property
    def count(self) -> int:
        return len(self.ids)


def intersect_host(*id_lists: Sequence[str]) -> IntersectionResult:
    t0 = time.perf_counter()
    if not id_lists:
        return IntersectionResult([], 0.0, "host")
    acc = set(id_lists[0])
    for ids in id_lists[1:]:
        acc &= set(ids)
    out = sorted(acc)
    return IntersectionResult(out, time.perf_counter() - t0, "host")


def digest_u64(ids: Sequence[str]) -> np.ndarray:
    """blake2b-64 digests of string ids as a uint64 vector."""
    return np.fromiter(
        (
            int.from_bytes(hashlib.blake2b(s.encode(), digest_size=8).digest(), "big")
            for s in ids
        ),
        dtype=np.uint64,
        count=len(ids),
    )


def intersect_sorted(*id_lists: Sequence[str]) -> IntersectionResult:
    """Sort-merge intersection over packed digests, string-verified.

    The device-friendly formulation: digests of list k+1 are probed against
    the sorted digest table of the running intersection via binary search
    (``np.searchsorted`` here; ``kernels/sorted_probe`` on TPU).
    """
    t0 = time.perf_counter()
    if not id_lists:
        return IntersectionResult([], 0.0, "sorted")
    cur_ids: List[str] = list(dict.fromkeys(id_lists[0]))  # dedupe, keep order
    cur_dig = digest_u64(cur_ids)
    order = np.argsort(cur_dig, kind="stable")
    cur_ids = [cur_ids[i] for i in order]
    cur_dig = cur_dig[order]

    for ids in id_lists[1:]:
        probe_ids = list(dict.fromkeys(ids))
        probe_dig = digest_u64(probe_ids)
        pos = np.searchsorted(cur_dig, probe_dig, side="left")
        pos = np.minimum(pos, len(cur_dig) - 1) if len(cur_dig) else pos
        hit = len(cur_dig) > 0
        keep_ids: List[str] = []
        keep_dig: List[np.uint64] = []
        if hit:
            match = cur_dig[pos] == probe_dig
            for i in np.nonzero(match)[0]:
                # digest hit -> verify on the full string id (collision-safe)
                if cur_ids[pos[i]] == probe_ids[i]:
                    keep_ids.append(probe_ids[i])
                    keep_dig.append(probe_dig[i])
        kd = np.array(keep_dig, dtype=np.uint64)
        order = np.argsort(kd, kind="stable")
        cur_ids = [keep_ids[i] for i in order]
        cur_dig = kd[order]

    out = sorted(cur_ids)
    return IntersectionResult(out, time.perf_counter() - t0, "sorted")
