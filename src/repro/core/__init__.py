"""The paper's primary contribution: the byte-offset indexing architecture.

Phase 1 (index construction, Algorithm 2)  → :mod:`repro.core.index`
Phase 2 (targeted extraction, Algorithm 3) → :mod:`repro.core.extract`
Async span read engine (coalesced spans)   → :mod:`repro.core.reader`
Span I/O backends (uring/thread/mmap)      → :mod:`repro.core.iobackend`
Batched verification (vectorized ids)      → :mod:`repro.core.verify`
Record-content LRU cache                   → :mod:`repro.core.cache`
Baseline (naïve scan, Algorithm 1)         → :mod:`repro.core.baseline`
Identifier layer (InChI/InChIKey roles)    → :mod:`repro.core.identifiers`
Collision discovery (§VI, Eq. 4/5)         → :mod:`repro.core.collisions`
Multi-source intersection (Eq. 1)          → :mod:`repro.core.intersect`
Record substrate (SDF dialect)             → :mod:`repro.core.records`
Synthetic corpus (scale model of PubChem)  → :mod:`repro.core.sdfgen`
TPU packing layer (ids → uint32 lanes)     → :mod:`repro.core.packing`
Sharded query service (mmap + Bloom)       → :mod:`repro.core.store`
Bloom-filter prefilter sidecars            → :mod:`repro.core.bloom`
Fingerprint bit-planes (similarity)        → :mod:`repro.core.fingerprint`
"""

from .baseline import BaselineResult, estimate_runtime, measure_scan_throughput, naive_scan
from .collisions import (
    CollisionReport,
    birthday_expectation,
    collisions_from_pairs,
    scan_corpus,
    scan_pairs_sorted,
)
from .cache import CacheStats, RecordCache
from .extract import ExtractionResult, Mismatch, extract, extract_iter, plan_extraction
from .iobackend import RecordView, SpanBackend, resolve_backend, uring_available
from .reader import ReadStats, coalesce_spans, stream_plan
from .verify import VerifyBatcher, compare_ids_batch, recompute_ids_batch
from .identifiers import (
    DEFAULT_KEY_BITS,
    PAPER_KEY_BITS,
    Molecule,
    canonical_id,
    canonical_id_from_structure,
    hashed_key,
    molecule_from_cid,
)
from .index import (
    BinaryIndex,
    ByteOffsetIndex,
    IndexStats,
    build_index,
    file_fingerprints,
    update_index,
)
from .bloom import BloomFilter
from .fingerprint import (
    DEFAULT_FP_BITS,
    fingerprint_batch,
    fold_fingerprint,
    popcount_u32,
)
from .intersect import IntersectionResult, intersect_host, intersect_sorted
from .packing import lanes_for, pack_ids, unpack_ids
from .store import (
    IndexStore,
    QueryStats,
    candidate_runs,
    digest_u64,
    merge_similar_topk,
    save_sharded,
    shard_of,
)
from .records import (
    RECORD_DELIM,
    RecordStore,
    extract_property,
    iter_record_offsets,
    iter_records,
    read_record_at,
    record_properties,
)
from .sdfgen import (
    CorpusManifest,
    CorpusSpec,
    db_id_list,
    db_membership,
    generate_corpus,
    ground_truth_final_dataset,
    ground_truth_intersection,
    load_manifest,
    record_text_for_cid,
)
