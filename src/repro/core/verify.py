"""Batched record verification — recompute-and-compare off the per-record path.

Algorithm 3's defensive verification recomputes every fetched record's
canonical id from its structural bytes and compares it against the id
the index promised.  Done record-at-a-time in Python
(:func:`repro.core.identifiers.canonical_id_from_structure`) that costs
~50 µs/record — at bench scale it IS the cold read path (the I/O is a
few µs/record once spans coalesce).  This module batches it:

:func:`recompute_ids_batch`
    Cross-record *vectorized* recompute: every record's ctab block is
    located with C-speed byte scans, the atom/bond blocks of the whole
    batch are stacked into two numpy matrices (rows are the fixed-width
    38-/13-byte lines), counts, hydrogen totals, bond tuples and layout
    validity all come out of vectorized column arithmetic, and the
    canonical-id strings are assembled per record from precomputed
    fragment tables.  Any record that fails the strict layout validation
    (non-ASCII counts line, misaligned rows, non-digit fields, truncated
    block …) falls back to the reference parser for that record, so the
    output is *always* identical to per-record
    ``canonical_id_from_structure`` — including the ``<unparseable>``
    cases — just ~2x cheaper for well-formed corpora.

:class:`VerifyBatcher`
    Leader-combining verification across *all* engine workers: workers
    enqueue their (expected, payload) chunks, one leader drains the
    queue and runs a single combined recompute + compare — one
    vectorized pass (and, on an accelerator, ONE ``hash_mix`` digest
    batch) instead of per-worker compares holding the GIL.  Backends:

    - ``vector``  — combined vectorized recompute, string compare;
    - ``process`` — combined recompute chunked over a process pool
      (off-GIL on multi-core hosts; record bytes are pickled to the
      children, which is the one copy this mode pays);
    - ``string``/``digest`` — the per-record reference recompute with a
      string / ``hash_mix``-digest compare (the legacy paths, kept for
      ablations and tests);
    - ``auto``    — ``vector`` recompute, with the compare riding the
      ``hash_mix`` device batch when JAX is already live on TPU (the
      store's probe discipline), else the C-speed string compare.
"""

from __future__ import annotations

import atexit
import os
import struct
import sys
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .identifiers import canonical_id_from_structure
from .iobackend import RecordView

__all__ = [
    "VerifyBatcher",
    "compare_ids_batch",
    "recompute_ids_batch",
]

_UNPARSEABLE = "<unparseable>"

# structure_block's atom-line prefix: three fixed 0.0000 coords + space.
_ATOM_PREFIX = b"    0.0000    0.0000    0.0000 "
_PREFIX_ARR = np.frombuffer(_ATOM_PREFIX, np.uint8)
_ATOM_W = 38   # 37-char atom line + \n
_BOND_W = 13   # 12-char bond line + \n

def _recompute(text: str) -> str:
    """The reference per-record recompute (kept as ground truth)."""
    try:
        return canonical_id_from_structure(text)
    except ValueError:
        return _UNPARSEABLE


def _payload_text(p) -> str:
    if isinstance(p, str):
        return p
    if isinstance(p, RecordView):
        return p.text
    return bytes(p).decode("utf-8", "replace")


def _payload_ctx(p):
    """``(raw, lo, hi, mem_slicer)`` for byte-level parsing, or ``None``
    when only decoded text is available (cached strings, detached views)."""
    if isinstance(p, RecordView):
        rr = p.raw_range()
        if rr is None:
            return None
        raw, lo, hi = rr
        return raw, lo, hi, p.slice_mem
    if isinstance(p, (bytes, bytearray)):
        mv = memoryview(p)
        return p, 0, len(p), lambda a, b: mv[a:b]
    return None


def _scan_ctab(raw, lo: int, hi: int):
    """Locate + strictly validate the counts line of a record's ctab.

    Returns ``(natoms, nbonds, atom_block_start, bond_block_start)`` or
    ``None`` to send the record to the reference parser.  The fast path
    only accepts the FIRST ``V2000`` byte occurrence, on an all-ASCII
    line with nothing but whitespace after the tag — exactly the cases
    where byte-line splitting provably agrees with the reference's
    ``str.splitlines`` view (ASCII lines admit no hidden unicode line
    breaks).  Everything else falls back.
    """
    j = raw.find(b"V2000", lo, hi)
    if j < 0:
        return None
    nl = raw.rfind(b"\n", lo, j)
    ls = lo if nl < 0 else nl + 1
    le = raw.find(b"\n", j + 5, hi)
    if le < 0:
        le = hi
    line = bytes(raw[ls:le])
    if not line.isascii() or line[j + 5 - ls:].strip():
        return None
    # str.splitlines also breaks on \r \v \f \x1c-\x1e — a counts line
    # containing any of them reads differently to the reference parser
    if len(line.translate(None, b"\r\x0b\x0c\x1c\x1d\x1e")) != len(line):
        return None
    try:
        natoms = int(line[0:3])
        nbonds = int(line[3:6])
    except ValueError:
        return None
    if natoms < 0 or nbonds < 0:
        return None
    a0 = le + 1
    b0 = a0 + _ATOM_W * natoms
    if b0 + _BOND_W * nbonds > hi:
        return None  # truncated block: the reference's slicing semantics apply
    return natoms, nbonds, a0, b0


def recompute_ids_batch(payloads: Sequence) -> List[str]:
    """Canonical ids for a batch of records, vectorized across records.

    ``payloads`` may be :class:`~repro.core.iobackend.RecordView`\\ s,
    raw ``bytes``, or decoded ``str`` (strings always take the reference
    parser).  Output is element-for-element identical to
    ``[_recompute(text) for text in batch]``.
    """
    n = len(payloads)
    ids: List[Optional[str]] = [None] * n
    metas: List[Tuple[int, int, int]] = []   # (slot, natoms, nbonds)
    atom_parts: List = []
    bond_parts: List = []
    fallback: List[int] = []

    for i, p in enumerate(payloads):
        ctx = _payload_ctx(p)
        if ctx is None:
            fallback.append(i)
            continue
        raw, lo, hi, mem = ctx
        m = _scan_ctab(raw, lo, hi)
        if m is None:
            fallback.append(i)
            continue
        natoms, nbonds, a0, b0 = m
        metas.append((i, natoms, nbonds))
        atom_parts.append(mem(a0, b0))
        bond_parts.append(mem(b0, b0 + _BOND_W * nbonds))

    if metas:
        _vector_ids(metas, atom_parts, bond_parts, ids, fallback)

    for i in fallback:
        ids[i] = _recompute(_payload_text(payloads[i]))
    return ids  # type: ignore[return-value]


def _bounds(widths, rows) -> List[int]:
    """Per-record byte boundaries into a globally space-stripped stream:
    cumulative nonspace widths, sampled at the record row offsets."""
    pos = np.zeros(len(widths) + 1, np.int64)
    np.cumsum(widths, out=pos[1:])
    return pos[rows].tolist()


def _vector_ids(metas, atom_parts, bond_parts, ids, fallback) -> None:
    nrec = len(metas)
    # One contiguous copy of just the ctab blocks — the batch's only
    # byte materialization (memoryview sources, so no per-record bytes).
    A = np.frombuffer(b"".join(atom_parts), np.uint8).reshape(-1, _ATOM_W)
    B = np.frombuffer(b"".join(bond_parts), np.uint8).reshape(-1, _BOND_W)
    na = np.fromiter((m[1] for m in metas), np.int64, nrec)
    nb = np.fromiter((m[2] for m in metas), np.int64, nrec)
    arow = np.zeros(nrec + 1, np.int64)
    np.cumsum(na, out=arow[1:])
    brow = np.zeros(nrec + 1, np.int64)
    np.cumsum(nb, out=brow[1:])
    seg_a = np.repeat(np.arange(nrec), na)
    seg_b = np.repeat(np.arange(nrec), nb)
    bad = np.zeros(nrec, bool)

    def isd(c):
        return (c >= 48) & (c <= 57)

    # ---- atom rows: layout validation + h totals + element codes ----------
    # Validation encodes "str(int(field)) == field.strip() and the field is
    # one whitespace-delimited token": digits only, no leading zeros, spaces
    # strictly leading.  Anything else (including 3-char element symbols,
    # which no supported element uses) sends the record to the reference
    # parser — the fast path only keeps rows whose byte layout provably
    # round-trips through the reference's split()/int() semantics.
    if len(A):
        ok = (A[:, :31] == _PREFIX_ARR).all(axis=1)
        ok &= (A[:, 34] == 32) & (A[:, 37] == 10)
        e0, e1, e2 = A[:, 31], A[:, 32], A[:, 33]
        nz = lambda c: (c > 32) & (c < 127)  # printable non-space: one token
        ok &= nz(e0) & (nz(e1) | (e1 == 32)) & (e2 == 32)
        h0, h1 = A[:, 35], A[:, 36]
        ok &= isd(h1) & ((isd(h0) & (h0 != 48)) | (h0 == 32))
        if not ok.all():
            bad[seg_a[~ok]] = True
        hval = (np.where(h0 == 32, 0, (h0 - 48).astype(np.int16) * 10)
                + (h1 - 48))
        ecode = (e0.astype(np.int16) << 8) | e1
        htot = np.bincount(seg_a, weights=hval, minlength=nrec).astype(np.int64)
        # element layer: strip spaces ONCE globally; per-record boundaries
        # come from the cumulative nonspace widths (exact even on invalid
        # rows, which only ever reach fallback records)
        EL = A[:, 31:33]
        el_s = EL.tobytes().replace(b" ", b"")
        el_b = _bounds((EL != 32).sum(axis=1), arow)
        # h layer: "d," / "dd," fragments, same global-strip trick
        HS = np.empty((len(A), 3), np.uint8)
        HS[:, 0] = h0
        HS[:, 1] = h1
        HS[:, 2] = 44  # ','
        hs_s = HS.tobytes().replace(b" ", b"")
        hs_b = _bounds((HS != 32).sum(axis=1), arow)
    else:
        ecode = np.zeros(0, np.int16)
        htot = np.zeros(nrec, np.int64)
        el_s = hs_s = b""
        el_b = hs_b = [0] * (nrec + 1)

    # ---- bond rows: validation + conn/stereo fragment slots ---------------
    if len(B):
        okb = B[:, 12] == 10
        Fw = B[:, :12].reshape(-1, 4, 3).astype(np.int16)
        c0, c1, c2 = Fw[..., 0], Fw[..., 1], Fw[..., 2]
        s0, s1 = c0 == 32, c1 == 32
        d0, d1 = isd(c0), isd(c1)
        okf = isd(c2) & (d1 | s1) & (d0 | s0) & ~(d0 & s1)
        okf &= ~(d0 & (c0 == 48)) & ~(s0 & d1 & (c1 == 48))  # leading zeros
        okb &= okf.all(axis=1)
        if not okb.all():
            bad[seg_b[~okb]] = True
        b_o = (np.where(d0[:, 2], c0[:, 2] - 48, 0) * 100
               + np.where(d1[:, 2], c1[:, 2] - 48, 0) * 10 + (c2[:, 2] - 48))
        b_st = (np.where(d0[:, 3], c0[:, 3] - 48, 0) * 100
                + np.where(d1[:, 3], c1[:, 3] - 48, 0) * 10 + (c2[:, 3] - 48))
        # conn fragments: fixed 12-byte slots "aaa-bbb[*ooo]," built from
        # the raw field bytes; the leading-zero rule above makes the
        # space-stripped slot equal the reference's f"{a}-{b}[*{o}]"
        CS = np.full((len(B), 12), 32, np.uint8)
        CS[:, 0:3] = B[:, 0:3]
        CS[:, 3] = 45   # '-'
        CS[:, 4:7] = B[:, 3:6]
        star = b_o != 1
        CS[star, 7] = 42  # '*'
        CS[star, 8:11] = B[star, 6:9]
        CS[:, 11] = 44  # ','
        conn_s = CS.tobytes().replace(b" ", b"")
        conn_b = _bounds((CS != 32).sum(axis=1), brow)
        # stereo layer: 4-byte "%3d," slots holding the 1-based bond
        # ordinal for st != 0 rows, all-spaces otherwise
        stnz = b_st != 0
        st_any = np.zeros(nrec, bool)
        if stnz.any():
            st_any[seg_b[stnz]] = True
            ordl = np.arange(len(B), dtype=np.int64) - np.repeat(brow[:-1], nb) + 1
            TS = np.full((len(B), 4), 32, np.uint8)
            o_ = ordl[stnz]
            hh, tt, uu = o_ // 100, (o_ // 10) % 10, o_ % 10
            TS[stnz, 0] = np.where(hh > 0, 48 + hh, 32)
            TS[stnz, 1] = np.where((hh > 0) | (tt > 0), 48 + tt, 32)
            TS[stnz, 2] = 48 + uu
            TS[stnz, 3] = 44  # ','
            st_s = TS.tobytes().replace(b" ", b"")
            st_b = _bounds((TS != 32).sum(axis=1), brow)
        else:
            st_s = b""
            st_b = [0] * (nrec + 1)
    else:
        st_any = np.zeros(nrec, bool)
        conn_s = st_s = b""
        conn_b = st_b = [0] * (nrec + 1)

    # ---- formula layer: per-record element counts, one bincount -----------
    K = 0
    names: List[str] = []
    if len(ecode):
        uniq, inv = np.unique(ecode, return_inverse=True)
        K = len(uniq)
        counts = np.bincount(seg_a * K + inv, minlength=nrec * K).reshape(nrec, K)
        names = [
            (chr((int(u) >> 8) & 0xFF) + chr(int(u) & 0xFF)).replace(" ", "")
            for u in uniq
        ]
    order = sorted(range(K), key=names.__getitem__)
    # Resolve carbon by its exact ("C", " ") code, not by name: invalid
    # rows of fallback-bound records can inject codes (e.g. (" ", "C"))
    # whose stripped NAME collides — good records never count those
    # columns (their rows are all valid, and name↔code is bijective over
    # valid codes), but an index-by-name could land on one.
    c_code = np.int16((ord("C") << 8) | 32)
    c_col = int(np.searchsorted(uniq, c_code)) if K else -1
    if c_col >= K or (K and uniq[c_col] != c_code):
        c_col = -1
    order_no_c = [k for k in order if k != c_col]
    # formula keys: the packed (counts..., htot) row — repeated formulas
    # (common in narrow corpora) memoize, the rest unpack via one Struct
    fkey_arr = np.empty((nrec, K + 1), np.uint32)
    if K:
        fkey_arr[:, :K] = counts
    fkey_arr[:, K] = htot
    fkey_bytes = fkey_arr.tobytes()
    FW = 4 * (K + 1)
    funpack = struct.Struct(f"<{K + 1}I").unpack
    fcache: Dict[bytes, str] = {}

    def build_formula(fk: bytes) -> str:
        vals = funpack(fk)
        h = vals[K]
        nc = vals[c_col] if c_col >= 0 else 0
        if nc:
            parts = [f"C{nc}"]
            if h:
                parts.append(f"H{h}")
            for k in order_no_c:
                v = vals[k]
                if v:
                    parts.append(f"{names[k]}{v}")
        else:
            # Hill order without carbon: H merges into the alphabetical
            # element list (and, as in the reference, *overwrites* any
            # atom-line "H" count).
            d = {names[k]: vals[k] for k in order if vals[k]}
            if h:
                d["H"] = h
            parts = [f"{el}{d[el]}" for el in sorted(d)]
        return "".join(parts)

    # ---- assembly: plain-python loop over pre-stripped byte slices --------
    bad_l, st_l = bad.tolist(), st_any.tolist()
    fget = fcache.get
    for r in range(nrec):
        if bad_l[r]:
            fallback.append(metas[r][0])
            continue
        fk = fkey_bytes[r * FW:(r + 1) * FW]
        formula = fget(fk)
        if formula is None:
            formula = fcache[fk] = build_formula(fk)
        # the -1s drop each layer's trailing comma (empty layers guarded)
        c0_, c1_ = conn_b[r], conn_b[r + 1]
        h0_, h1_ = hs_b[r], hs_b[r + 1]
        sid = (
            "InChI=1S/" + formula
            + "/e" + el_s[el_b[r]:el_b[r + 1]].decode()
            + "/c" + (conn_s[c0_:c1_ - 1].decode() if c1_ > c0_ else "")
            + "/h" + (hs_s[h0_:h1_ - 1].decode() if h1_ > h0_ else "")
        )
        if st_l[r]:
            sid += "/t" + st_s[st_b[r]:st_b[r + 1] - 1].decode()
        ids[metas[r][0]] = sid


# ---------------------------------------------------------------------------
# Digest compare (the hash_mix device batch)
# ---------------------------------------------------------------------------

def _tpu_backend_active() -> bool:
    """True only when JAX is ALREADY imported and its backend is TPU
    (never imports jax — same discipline as the store's probe selection)."""
    jax = sys.modules.get("jax")
    if jax is None:
        return False
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - defensive
        return False


def _bucket(n: int, lo: int = 32) -> int:
    b = lo
    while b < n:
        b <<= 1
    return b


def compare_ids_batch(
    expected: Sequence[str],
    recomputed: Sequence[str],
    backend: str = "auto",
) -> List[bool]:
    """Per-record verification compare, vectorized.

    ``backend="digest"`` packs both id columns into uint32 lanes and runs
    ONE :func:`repro.kernels.hash_mix.ops.hash_mix` batch over them
    (shapes are bucketed so the jit cache stays small), accepting records
    whose 128-bit digests agree and falling back to a full-string compare
    only on digest disagreement — digest inequality already proves string
    inequality, so the fallback can only confirm the mismatch.
    ``backend="string"`` compares strings directly.  ``"auto"`` follows the
    store's probe discipline: the digest path only when JAX is already
    imported AND running on TPU — a host-side extraction never pays the
    framework import, and on CPU the C-speed string compare beats the jnp
    reference kernel anyway.
    """
    if backend == "auto":
        backend = "digest" if _tpu_backend_active() else "string"
    if backend == "string":
        return [e == r for e, r in zip(expected, recomputed)]
    if backend != "digest":
        raise ValueError(f"unknown verify backend {backend!r}")
    n = len(expected)
    if n == 0:
        return []
    import jax.numpy as jnp

    from repro.core.packing import lanes_for, pack_ids
    from repro.kernels.hash_mix.ops import hash_mix

    ids = list(expected) + list(recomputed)
    lanes = _bucket(lanes_for(ids), lo=32)
    m = _bucket(2 * n, lo=64)
    ids += [""] * (m - 2 * n)
    digests = np.asarray(hash_mix(jnp.asarray(pack_ids(ids, lanes))))
    same = (digests[:n] == digests[n : 2 * n]).all(axis=1)
    # Digest-equal => verified (a 128-bit expected/recomputed collision is
    # negligible); digest-unequal => full-string compare, which documents
    # the mismatch the digests already proved.
    return [bool(s) or expected[i] == recomputed[i] for i, s in enumerate(same)]


# ---------------------------------------------------------------------------
# Cross-worker batching
# ---------------------------------------------------------------------------

_PROC_POOL = None
_PROC_LOCK = threading.Lock()


def _recompute_chunk(items: List) -> List[str]:
    """Process-pool unit: vectorized recompute in a child process."""
    return recompute_ids_batch(items)


def _process_pool():
    global _PROC_POOL
    with _PROC_LOCK:
        if _PROC_POOL is None:
            import multiprocessing
            from concurrent.futures import ProcessPoolExecutor

            try:
                ctx = multiprocessing.get_context("fork")
            except ValueError:  # pragma: no cover - non-posix
                ctx = None
            _PROC_POOL = ProcessPoolExecutor(
                max_workers=max(1, (os.cpu_count() or 2) - 1),
                mp_context=ctx,
            )
            atexit.register(_PROC_POOL.shutdown)
    return _PROC_POOL


class _Chunk:
    __slots__ = ("expected", "payloads", "pre", "stats", "event", "ok",
                 "ids", "error")

    def __init__(self, expected, payloads, pre, stats):
        self.expected = expected
        self.payloads = payloads
        self.pre = pre
        self.stats = stats
        self.event = threading.Event()
        self.ok: Optional[List[bool]] = None
        self.ids: Optional[List[str]] = None
        self.error: Optional[BaseException] = None


class VerifyBatcher:
    """Combine verification work across workers into one batched pass.

    Workers call :meth:`verify`; whoever arrives while no leader is
    active becomes the leader and drains the queue — its combined batch
    covers every worker that enqueued meanwhile (continuous batching,
    the same shape as the service's ``MicroBatcher``, but synchronous:
    the caller needs the answer before it can emit events).  A service
    shares ONE batcher across every concurrent ``fetch``, so the device
    digest pass (or the process pool) sees cross-request batches.
    """

    def __init__(self, backend: str = "auto", combine: bool = True):
        if backend not in ("auto", "string", "digest", "vector", "process"):
            raise ValueError(f"unknown verify backend {backend!r}")
        self.backend = backend
        self.combine = combine and backend not in ("string", "digest")
        self._lock = threading.Lock()
        self._queue: List[_Chunk] = []
        self._leading = False

    # -- public --------------------------------------------------------------

    def verify(
        self,
        expected: Sequence[str],
        payloads: Sequence,
        precomputed: Optional[Sequence[Optional[str]]] = None,
        stats=None,
    ) -> Tuple[List[bool], List[str]]:
        """``(ok, recomputed_ids)`` for one worker's records.

        ``precomputed`` carries ids already known (warm cache hits) —
        those records skip the recompute but still ride the combined
        compare, exactly like the legacy per-worker path did.
        """
        n = len(expected)
        if n == 0:
            return [], []
        pre = list(precomputed) if precomputed is not None else [None] * n

        if self.backend in ("string", "digest"):
            # reference per-record recompute (the ablation/legacy path)
            ids = [
                pre[i] if pre[i] is not None
                else _recompute(_payload_text(payloads[i]))
                for i in range(n)
            ]
            ok = compare_ids_batch(expected, ids, self.backend)
            if stats is not None:
                stats.verify_batches += 1
                stats.verify_records += n
                stats.verify_batch_max = max(stats.verify_batch_max, n)
            return ok, ids

        chunk = _Chunk(list(expected), list(payloads), pre, stats)
        if not self.combine:
            self._run_batch([chunk])
            if chunk.error is not None:
                raise chunk.error
            return chunk.ok, chunk.ids

        with self._lock:
            self._queue.append(chunk)
            lead = not self._leading
            if lead:
                self._leading = True
        if not lead:
            chunk.event.wait()
            if chunk.error is not None:
                raise chunk.error
            return chunk.ok, chunk.ids
        try:
            while True:
                with self._lock:
                    batch, self._queue = self._queue, []
                    if not batch:
                        self._leading = False
                        break
                self._run_batch(batch)
        except BaseException:
            with self._lock:  # pragma: no cover - defensive
                self._leading = False
            raise
        if chunk.error is not None:
            raise chunk.error
        return chunk.ok, chunk.ids

    # -- internals -----------------------------------------------------------

    def _run_batch(self, batch: List[_Chunk]) -> None:
        try:
            need = []
            slots = []
            total = 0
            for c in batch:
                total += len(c.expected)
                for k, rid in enumerate(c.pre):
                    if rid is None:
                        need.append(c.payloads[k])
                        slots.append((c, k))
            ids_need = self._recompute_many(need)
            for (c, k), rid in zip(slots, ids_need):
                c.pre[k] = rid
            # one combined compare across every chunk (on TPU this is the
            # single hash_mix digest pass for all workers' records)
            exp_all: List[str] = []
            ids_all: List[str] = []
            for c in batch:
                exp_all.extend(c.expected)
                ids_all.extend(c.pre)  # type: ignore[arg-type]
            ok_all = compare_ids_batch(exp_all, ids_all, "auto")
            pos = 0
            for c in batch:
                m = len(c.expected)
                c.ok = ok_all[pos:pos + m]
                c.ids = c.pre  # type: ignore[assignment]
                pos += m
                if c.stats is not None:
                    c.stats.verify_records += m
                    c.stats.verify_batch_max = max(
                        c.stats.verify_batch_max, total
                    )
            lead_stats = batch[0].stats
            if lead_stats is not None:
                lead_stats.verify_batches += 1  # one physical batch
        except BaseException as e:
            for c in batch:
                c.error = e
        finally:
            for c in batch:
                c.event.set()

    def _recompute_many(self, payloads: List) -> List[str]:
        if not payloads:
            return []
        if self.backend == "process" and len(payloads) >= 2:
            pool = _process_pool()
            workers = pool._max_workers
            # serialize views to bytes for the children (the one copy
            # this mode pays); strings pass through
            items = [
                p if isinstance(p, str)
                else (bytes(p.mem()) if isinstance(p, RecordView)
                      else bytes(p))
                for p in payloads
            ]
            step = max(64, (len(items) + workers - 1) // workers)
            chunks = [items[i:i + step] for i in range(0, len(items), step)]
            out: List[str] = []
            for part in pool.map(_recompute_chunk, chunks):
                out.extend(part)
            return out
        return recompute_ids_batch(payloads)
