"""Async span engine: pluggable I/O backends, zero-copy records, batched verify.

Algorithm 3's read phase, rebuilt for throughput.  The serial reference
path (kept in :func:`repro.core.extract.extract` under ``workers=0`` for
the ablation benchmarks) does one ``seek()`` per record, walks the file
line by line in Python, decodes eagerly, and re-verifies one record at a
time.  This engine batches all four costs:

1. **Span coalescing** — offset-sorted targets within a file are merged
   into read spans whenever the byte gap between the provisional end of
   one record and the start of the next is at most ``coalesce_gap``.
   N nearby records then cost one I/O submission instead of N.
2. **Pluggable span backends** (:mod:`repro.core.iobackend`) — *how*
   spans become bytes is delegated to a :class:`SpanBackend`:
   ``uring`` submits a depth-controlled window of spans to a raw
   io_uring ring and consumes completions in arrival order (one slow
   span never stalls the window); ``thread`` is the portable blocking
   ``preadv`` fallback; ``mmap`` maps whole files and serves spans as
   windows of the page cache.  Select with ``REPRO_READER_BACKEND`` /
   ``REPRO_READER_DEPTH`` (see :mod:`repro.flags`) or per call.
3. **Zero-copy record views** — records are carved out of span buffers
   as :class:`~repro.core.iobackend.RecordView` memoryview windows.  No
   ``bytes`` copy of a record exists anywhere; boundary scans
   (C-speed ``find(b"$$$$")``) run on the retained buffer, tail
   extensions (a record overrunning its provisional span) happen
   *before* views are carved (exported ``bytearray``\\ s cannot resize),
   and the single materialization is the lazy UTF-8 decode at the API
   boundary (``RecordView.text``), which also drops the buffer pin.
4. **Batched verification** (:mod:`repro.core.verify`) — recomputed ids
   come from one vectorized cross-record pass per worker chunk, and a
   shared :class:`~repro.core.verify.VerifyBatcher` leader-combines
   chunks across *all* workers (and, service-wide, across concurrent
   fetches) into single recompute/compare batches — on TPU, one
   ``hash_mix`` digest pass for everything in flight.

Knob guidance: ``coalesce_gap`` trades wasted bytes for fewer
submissions (raise it on storage with expensive round trips; lower it
for very sparse target sets), ``span_guess`` should sit near the p90
record size (too small costs tail-extension reads — watch
``ReadStats.spans_read`` exceed span count; too large reads slack),
``depth`` (uring) bounds in-flight spans per worker — raise it on
high-latency storage, shrink it to bound buffer residency.

A :class:`~repro.core.cache.RecordCache` can sit in front of the reads:
hits skip the I/O entirely, and hits that already carry a recomputed id
skip the structural re-parse too — a warm verified re-extraction touches
no file and parses nothing.
"""

from __future__ import annotations

import os
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

from repro import flags

from .cache import RecordCache
from .iobackend import RecordView, SpanBackend, SpanBuffer, resolve_backend
from .records import find_record_end
from .verify import (
    VerifyBatcher,
    _recompute,
    _tpu_backend_active,
    compare_ids_batch,
)

__all__ = [
    "DEFAULT_COALESCE_GAP",
    "DEFAULT_SPAN_GUESS",
    "DEFAULT_WORKERS",
    "ReadEvent",
    "ReadStats",
    "Span",
    "coalesce_spans",
    "compare_ids_batch",
    "stream_plan",
]

# Provisional bytes fetched per record before its real end is known.  One
# page: records smaller than this cost a single aligned read with bounded
# overshoot; larger records extend by doubling.
DEFAULT_SPAN_GUESS = 4096
# Maximum unread bytes tolerated between two records before the span is
# split.  32 KiB rides out small inter-target gaps (page-cache readahead
# would fault them in anyway) without degenerating into whole-file reads
# for sparse target sets.
DEFAULT_COALESCE_GAP = 32 * 1024
# Hard cap on one coalesced span's read size: bounds per-worker resident
# memory on dense target sets (paper-scale files run to gigabytes; without
# the cap a dense plan would materialize a whole file per worker).  A
# single record larger than this still reads fully via tail extension.
DEFAULT_MAX_SPAN = 8 * 1024 * 1024
# Read workers: I/O-bound (pread releases the GIL), so oversubscribing a
# small host is fine and overlaps read with verify.
DEFAULT_WORKERS = min(8, 2 * (os.cpu_count() or 1))


@dataclass
class ReadStats:
    """I/O + verify accounting for one engine run (merged across workers)."""

    files_opened: int = 0
    spans_read: int = 0      # I/O submissions issued (spans + tail extensions)
    bytes_read: int = 0      # bytes actually read (incl. coalescing overshoot)
    cache_hits: int = 0      # records served without touching the file
    records: int = 0         # records handled (verified + mismatched)
    backend: str = ""        # span backend the run resolved to
    inflight_peak: int = 0   # max spans simultaneously in flight (one worker)
    verify_batches: int = 0  # physical combined verify batches
    verify_records: int = 0  # records that rode a verify batch
    verify_batch_max: int = 0  # largest combined batch observed

    def merge(self, other: "ReadStats") -> None:
        self.files_opened += other.files_opened
        self.spans_read += other.spans_read
        self.bytes_read += other.bytes_read
        self.cache_hits += other.cache_hits
        self.records += other.records
        self.backend = self.backend or other.backend
        self.inflight_peak = max(self.inflight_peak, other.inflight_peak)
        self.verify_batches += other.verify_batches
        self.verify_records += other.verify_records
        self.verify_batch_max = max(self.verify_batch_max, other.verify_batch_max)


class ReadEvent:
    """One record's outcome: ``ok`` (verified or verify=False) or not.

    ``payload`` is the record as read — a zero-copy
    :class:`~repro.core.iobackend.RecordView` (or an already-decoded
    ``str`` off the cache); ``text`` decodes at first access.
    ``found_id`` is the recomputed canonical id when verification ran
    (``None`` under ``verify=False``); for a mismatch it is the id of the
    structurally different molecule the bytes actually held.
    """

    __slots__ = ("ok", "full_id", "key", "file", "offset", "payload",
                 "found_id")

    def __init__(self, ok, full_id, key, file, offset, payload, found_id):
        self.ok = ok
        self.full_id = full_id
        self.key = key
        self.file = file
        self.offset = offset
        self.payload = payload
        self.found_id = found_id

    @property
    def text(self) -> str:
        p = self.payload
        return p if isinstance(p, str) else p.text


@dataclass
class Span:
    """A merged read range covering one or more record starts."""

    start: int
    end: int                                    # provisional, exclusive
    members: List[Tuple[int, int]] = field(default_factory=list)  # (slot, off)


def coalesce_spans(
    offsets: Sequence[Tuple[int, int]],
    gap: int = DEFAULT_COALESCE_GAP,
    guess: int = DEFAULT_SPAN_GUESS,
    file_size: Optional[int] = None,
    max_span: int = DEFAULT_MAX_SPAN,
) -> List[Span]:
    """Merge ``(slot, offset)`` targets into read spans.

    Each record provisionally extends ``guess`` bytes past its start; a
    target joins the current span when its offset is at most ``gap`` bytes
    past the span's provisional end (``<=`` — a gap of exactly ``gap``
    bytes still merges) AND the merged span stays within ``max_span``
    bytes (memory bound per span buffer).  Ends are clamped to
    ``file_size`` when known.
    """
    if guess < 1:
        raise ValueError(f"span guess must be >= 1, got {guess}")
    if gap < 0:
        raise ValueError(f"coalesce gap must be >= 0, got {gap}")
    if max_span < 1:
        raise ValueError(f"max span must be >= 1, got {max_span}")
    ordered = sorted(offsets, key=lambda t: t[1])
    spans: List[Span] = []
    cur: Optional[Span] = None
    for slot, off in ordered:
        end = off + guess
        if file_size is not None:
            end = min(end, file_size)
        end = max(end, off)  # offsets at/past EOF: degenerate empty range
        if (
            cur is not None
            and off <= cur.end + gap
            and max(cur.end, end) - cur.start <= max_span
        ):
            cur.end = max(cur.end, end)
            cur.members.append((slot, off))
        else:
            cur = Span(start=off, end=end, members=[(slot, off)])
            spans.append(cur)
    return spans


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

def _carve_records(
    buf: SpanBuffer,
    members: Sequence[Tuple[int, int]],
    backend: SpanBackend,
    handle,
    guess: int,
    stats: ReadStats,
    payloads: List,
) -> None:
    """Resolve every member record's end in ``buf``, then carve views.

    Two passes on purpose: tail extensions resize the span's
    ``bytearray``, which is illegal once a memoryview is exported — so
    ALL ends are found (extending as needed) before ANY view is carved.
    """
    ends: List[Tuple[int, int, int]] = []
    for slot, off in members:
        rel = off - buf.base
        while True:
            end, _nxt, definite = find_record_end(buf.raw, rel, buf.at_eof)
            if definite:
                break
            if not backend.extend(handle, buf, guess, stats):
                # file exhausted (or unextendable backend): buffer end is EOF
                end, _nxt, _ = find_record_end(buf.raw, rel, True)
                break
        ends.append((slot, rel, max(end, rel)))
    for slot, rel, end in ends:
        payloads[slot] = RecordView(buf, rel, end)
    if ends:
        # Freeze the buffer NOW: with the shared memoryview exported, an
        # mmap close under live views raises (and is tolerated) instead
        # of silently invalidating them before their records decode.
        buf.view()


def _process_file(
    path,
    fname: str,
    items: Sequence[Tuple[str, str, int]],
    verify: bool,
    gap: int,
    guess: int,
    cache: Optional[RecordCache],
    verifier: VerifyBatcher,
    max_span: int,
    backend: SpanBackend,
    depth: int,
) -> Tuple[List[ReadEvent], ReadStats]:
    """One worker's unit: read, carve, and verify every target in a file."""
    stats = ReadStats()
    n = len(items)
    payloads: List = [None] * n          # RecordView | str (cache hits)
    rids: List[Optional[str]] = [None] * n

    to_read: List[int] = []
    if cache is not None:
        for i, (_fid, _key, off) in enumerate(items):
            hit = cache.get(fname, off)
            if hit is not None:
                payloads[i], rids[i] = hit
                stats.cache_hits += 1
            else:
                to_read.append(i)
    else:
        to_read = list(range(n))

    if to_read:
        handle = backend.open(path)
        stats.files_opened += 1
        try:
            fsize = backend.size(handle)
            spans = coalesce_spans(
                [(i, items[i][2]) for i in to_read], gap, guess, fsize, max_span
            )
            for span, buf in backend.read_spans(handle, spans, stats, depth):
                _carve_records(
                    buf, span.members, backend, handle, guess, stats, payloads
                )
        finally:
            backend.close_handle(handle)

    if verify:
        # records needing a cache (re-)insert: fresh reads, plus hits
        # cached without an id (a verify=False run) now being upgraded
        to_put = [i for i in range(n) if rids[i] is None] if cache is not None else ()
        ok, rids = verifier.verify(
            [it[0] for it in items], payloads, rids, stats
        )
        if cache is not None:
            for i in to_put:
                cache.put(fname, items[i][2], payloads[i], rids[i])
    else:
        ok = [True] * n
        if cache is not None:
            for i in to_read:
                cache.put(fname, items[i][2], payloads[i])

    events = [
        ReadEvent(
            ok=ok[i],
            full_id=full_id,
            key=key,
            file=fname,
            offset=off,
            payload=payloads[i],
            found_id=rids[i] if verify else None,
        )
        for i, (full_id, key, off) in enumerate(items)
    ]
    stats.records += n
    return events, stats


def stream_plan(
    store,
    plan: Dict[str, List[Tuple[str, str, int]]],
    *,
    verify: bool = True,
    workers: int = DEFAULT_WORKERS,
    coalesce_gap: int = DEFAULT_COALESCE_GAP,
    span_guess: int = DEFAULT_SPAN_GUESS,
    cache: Optional[RecordCache] = None,
    verify_backend: str = "auto",
    stats: Optional[ReadStats] = None,
    max_span: int = DEFAULT_MAX_SPAN,
    executor: Optional[ThreadPoolExecutor] = None,
    backend: Union[SpanBackend, str, None] = None,
    depth: Optional[int] = None,
    verifier: Optional[VerifyBatcher] = None,
) -> Iterator[ReadEvent]:
    """Stream :class:`ReadEvent`s for an extraction plan.

    ``plan`` is :func:`repro.core.extract.plan_extraction` output
    (``{file_name: [(full_id, lookup_key, offset), ...]}``).  Files are
    fanned out over ``workers`` threads (``workers <= 1`` runs inline, in
    plan order); events for a file are emitted as soon as that file's
    records are verified, so downstream consumers overlap with reads still
    in flight.  Event order across files is completion order — callers
    needing determinism must reorder (``extract`` does).

    ``backend`` selects the span I/O backend: a
    :class:`~repro.core.iobackend.SpanBackend` instance (borrowed — never
    closed here; how a service shares its rings across fetches), a name
    (``"uring"``/``"thread"``/``"mmap"``/``"auto"``), or ``None`` for the
    ``REPRO_READER_BACKEND`` env default.  ``depth`` bounds in-flight
    spans per worker (``None`` → ``REPRO_READER_DEPTH``).  ``verifier``
    lends a shared :class:`~repro.core.verify.VerifyBatcher` (cross-call
    verify combining); by default one is built from ``verify_backend``.

    At most ``2 * workers`` files are in flight at once (backpressure: a
    slow consumer of a huge plan never forces every file's records to sit
    in memory), and abandoning the generator early drops queued files
    instead of joining the whole extraction — in-flight io_uring spans
    are drained before their buffers are released.

    ``executor`` lends a long-lived pool (it is never shut down here) so
    hot-path callers — the training loader fetches every step — skip
    per-call pool construction.  ``stats`` (optional) accumulates merged
    I/O counters; per-file merges happen on the consuming thread, so
    reading it mid-iteration is safe.
    """
    if stats is None:
        stats = ReadStats()
    owned_backend: Optional[SpanBackend] = None
    if isinstance(backend, SpanBackend):
        be = backend
    else:
        be = owned_backend = resolve_backend(backend)
    stats.backend = stats.backend or be.name
    if depth is None:
        depth = flags.reader_depth()
    if verify_backend == "auto":
        verify_backend = flags.verify_backend()
    vf = verifier if verifier is not None else VerifyBatcher(verify_backend)
    args = dict(
        verify=verify,
        gap=coalesce_gap,
        guess=span_guess,
        cache=cache,
        verifier=vf,
        max_span=max_span,
        backend=be,
        depth=depth,
    )
    files = list(plan.items())
    try:
        if executor is None and (workers <= 1 or len(files) <= 1):
            for fname, items in files:
                events, fstats = _process_file(
                    store.path_of(fname), fname, items, **args
                )
                stats.merge(fstats)
                yield from events
            return

        owned = executor is None
        pool = executor if executor is not None else ThreadPoolExecutor(
            max_workers=workers
        )
        pending: set = set()
        todo = iter(files)
        max_inflight = max(2 * workers, 2)
        try:
            while True:
                for fname, items in todo:
                    pending.add(pool.submit(
                        _process_file, store.path_of(fname), fname, items, **args
                    ))
                    if len(pending) >= max_inflight:
                        break
                if not pending:
                    return
                done, pending = wait(pending, return_when=FIRST_COMPLETED)
                for fut in done:
                    events, fstats = fut.result()
                    stats.merge(fstats)
                    yield from events
        finally:
            # An abandoned generator (consumer broke out of extract_iter)
            # must not stall until every in-flight file finishes: drop
            # queued files and return without joining the running ones.
            if owned:
                pool.shutdown(wait=False, cancel_futures=True)
            else:
                for fut in pending:
                    fut.cancel()
    finally:
        if owned_backend is not None:
            owned_backend.close()
