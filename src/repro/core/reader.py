"""Pipelined extraction engine: coalesced preads + parallel file workers.

Algorithm 3's read phase, rebuilt for throughput.  The serial reference
path (kept in :func:`repro.core.extract.extract` under ``workers=0`` for
the ablation benchmarks) does one ``seek()`` per record and then walks the
file line by line in Python until the ``$$$$`` terminator.  This engine
replaces all three per-record costs with batched equivalents:

1. **Span coalescing** — offset-sorted targets within a file are merged
   into ``os.pread`` spans whenever the byte gap between the provisional
   end of one record and the start of the next is at most ``coalesce_gap``
   (the knob).  N nearby records then cost one syscall instead of N, and
   the access pattern the paper could only *approximate* with forward
   seeks becomes genuinely sequential.
2. **Bulk boundary splitting** — record ends are found with C-speed
   ``bytes.find(b"$$$$")`` scans over the coalesced buffer (with a
   line-start + rest-of-line check so ``$$$$`` inside record data never
   terminates early), not a per-line Python loop.  Records longer than the
   provisional span are handled by doubling tail reads until the delimiter
   (or EOF) appears.
3. **Parallel file workers + batched verify** — files fan out across a
   ``ThreadPoolExecutor`` (``pread`` releases the GIL, so reads overlap),
   each worker verifying its own records: canonical ids are recomputed
   once per record, then compared against the expected ids in one
   vectorized ``hash_mix`` digest batch, falling back to a full-string
   compare only where digests disagree (digest inequality *proves* string
   inequality, so the fallback exists to document the mismatch, not to
   decide it).

A :class:`~repro.core.cache.RecordCache` can sit in front of the reads:
hits skip the pread entirely, and hits that already carry a recomputed id
skip the structural re-parse too — a warm verified re-extraction touches
no file and parses nothing.
"""

from __future__ import annotations

import os
import sys
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from .cache import RecordCache
from .identifiers import canonical_id_from_structure
from .records import find_record_end

__all__ = [
    "DEFAULT_COALESCE_GAP",
    "DEFAULT_SPAN_GUESS",
    "DEFAULT_WORKERS",
    "ReadEvent",
    "ReadStats",
    "Span",
    "coalesce_spans",
    "compare_ids_batch",
    "stream_plan",
]

# Provisional bytes fetched per record before its real end is known.  One
# page: records smaller than this cost a single aligned read with bounded
# overshoot; larger records extend by doubling.
DEFAULT_SPAN_GUESS = 4096
# Maximum unread bytes tolerated between two records before the span is
# split.  32 KiB rides out small inter-target gaps (page-cache readahead
# would fault them in anyway) without degenerating into whole-file reads
# for sparse target sets.
DEFAULT_COALESCE_GAP = 32 * 1024
# Hard cap on one coalesced span's pread size: bounds per-worker resident
# memory on dense target sets (paper-scale files run to gigabytes; without
# the cap a dense plan would materialize a whole file per worker).  A
# single record larger than this still reads fully via tail extension.
DEFAULT_MAX_SPAN = 8 * 1024 * 1024
# Read workers: I/O-bound (pread releases the GIL), so oversubscribing a
# small host is fine and overlaps read with verify.
DEFAULT_WORKERS = min(8, 2 * (os.cpu_count() or 1))

_MAX_EXTEND = 1 << 20  # tail-extension reads cap at 1 MiB per pread
_UNPARSEABLE = "<unparseable>"


def _tpu_backend_active() -> bool:
    """True only when JAX is ALREADY imported and its backend is TPU
    (never imports jax — same discipline as the store's probe selection)."""
    jax = sys.modules.get("jax")
    if jax is None:
        return False
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - defensive
        return False


@dataclass
class ReadStats:
    """I/O accounting for one engine run (merged across file workers)."""

    files_opened: int = 0
    spans_read: int = 0      # pread calls issued (coalesced spans + extensions)
    bytes_read: int = 0      # bytes actually pread (incl. coalescing overshoot)
    cache_hits: int = 0      # records served without touching the file
    records: int = 0         # records handled (verified + mismatched)

    def merge(self, other: "ReadStats") -> None:
        self.files_opened += other.files_opened
        self.spans_read += other.spans_read
        self.bytes_read += other.bytes_read
        self.cache_hits += other.cache_hits
        self.records += other.records


@dataclass(frozen=True)
class ReadEvent:
    """One record's outcome: ``ok`` (verified or verify=False) or not.

    ``found_id`` is the recomputed canonical id when verification ran
    (``None`` under ``verify=False``); for a mismatch it is the id of the
    structurally different molecule the bytes actually held.
    """

    ok: bool
    full_id: str
    key: str
    file: str
    offset: int
    text: str
    found_id: Optional[str]


@dataclass
class Span:
    """A merged pread range covering one or more record starts."""

    start: int
    end: int                                    # provisional, exclusive
    members: List[Tuple[int, int]] = field(default_factory=list)  # (slot, off)


def coalesce_spans(
    offsets: Sequence[Tuple[int, int]],
    gap: int = DEFAULT_COALESCE_GAP,
    guess: int = DEFAULT_SPAN_GUESS,
    file_size: Optional[int] = None,
    max_span: int = DEFAULT_MAX_SPAN,
) -> List[Span]:
    """Merge ``(slot, offset)`` targets into pread spans.

    Each record provisionally extends ``guess`` bytes past its start; a
    target joins the current span when its offset is at most ``gap`` bytes
    past the span's provisional end (``<=`` — a gap of exactly ``gap``
    bytes still merges) AND the merged span stays within ``max_span``
    bytes (memory bound per pread buffer).  Ends are clamped to
    ``file_size`` when known.
    """
    if guess < 1:
        raise ValueError(f"span guess must be >= 1, got {guess}")
    if gap < 0:
        raise ValueError(f"coalesce gap must be >= 0, got {gap}")
    if max_span < 1:
        raise ValueError(f"max span must be >= 1, got {max_span}")
    ordered = sorted(offsets, key=lambda t: t[1])
    spans: List[Span] = []
    cur: Optional[Span] = None
    for slot, off in ordered:
        end = off + guess
        if file_size is not None:
            end = min(end, file_size)
        end = max(end, off)  # offsets at/past EOF: degenerate empty range
        if (
            cur is not None
            and off <= cur.end + gap
            and max(cur.end, end) - cur.start <= max_span
        ):
            cur.end = max(cur.end, end)
            cur.members.append((slot, off))
        else:
            cur = Span(start=off, end=end, members=[(slot, off)])
            spans.append(cur)
    return spans


class _SpanReader:
    """Reads one coalesced span, extending the tail until records close."""

    __slots__ = ("fd", "span", "fsize", "stats", "buf", "guess")

    def __init__(self, fd: int, span: Span, fsize: int, guess: int, stats: ReadStats):
        self.fd = fd
        self.span = span
        self.fsize = fsize
        self.guess = guess
        self.stats = stats
        length = max(0, span.end - span.start)
        self.buf = os.pread(fd, length, span.start)
        stats.spans_read += 1
        stats.bytes_read += len(self.buf)

    def _at_eof(self) -> bool:
        return self.span.start + len(self.buf) >= self.fsize

    def _extend(self) -> bool:
        """Grow the buffer tail; False when the file is exhausted."""
        step = min(max(self.guess, len(self.buf)), _MAX_EXTEND)
        extra = os.pread(self.fd, step, self.span.start + len(self.buf))
        if not extra:
            return False
        self.stats.spans_read += 1
        self.stats.bytes_read += len(extra)
        self.buf += extra
        return True

    def record_at(self, off: int) -> str:
        """The record text starting at absolute offset ``off``.

        Byte-identical to the serial ``read_record_at``: everything from
        the record start up to (not including) its terminator line, decoded
        utf-8 with replacement.
        """
        rel = off - self.span.start
        while True:
            end, _nxt, definite = find_record_end(self.buf, rel, self._at_eof())
            if definite:
                return self.buf[rel:end].decode("utf-8", "replace")
            if not self._extend():
                # file shrank under us vs fstat: treat buffer end as EOF
                end, _nxt, _ = find_record_end(self.buf, rel, True)
                return self.buf[rel:end].decode("utf-8", "replace")


# ---------------------------------------------------------------------------
# Vectorized verification
# ---------------------------------------------------------------------------

def _recompute(text: str) -> str:
    try:
        return canonical_id_from_structure(text)
    except ValueError:
        return _UNPARSEABLE


def _bucket(n: int, lo: int = 32) -> int:
    b = lo
    while b < n:
        b <<= 1
    return b


def compare_ids_batch(
    expected: Sequence[str],
    recomputed: Sequence[str],
    backend: str = "auto",
) -> List[bool]:
    """Per-record verification compare, vectorized.

    ``backend="digest"`` packs both id columns into uint32 lanes and runs
    ONE :func:`repro.kernels.hash_mix.ops.hash_mix` batch over them
    (shapes are bucketed so the jit cache stays small), accepting records
    whose 128-bit digests agree and falling back to a full-string compare
    only on digest disagreement — digest inequality already proves string
    inequality, so the fallback can only confirm the mismatch.
    ``backend="string"`` compares strings directly.  ``"auto"`` follows the
    store's probe discipline: the digest path only when JAX is already
    imported AND running on TPU — a host-side extraction never pays the
    framework import, and on CPU the C-speed string compare beats the jnp
    reference kernel anyway.
    """
    if backend == "auto":
        backend = "digest" if _tpu_backend_active() else "string"
    if backend == "string":
        return [e == r for e, r in zip(expected, recomputed)]
    if backend != "digest":
        raise ValueError(f"unknown verify backend {backend!r}")
    n = len(expected)
    if n == 0:
        return []
    import jax.numpy as jnp
    import numpy as np

    from repro.core.packing import lanes_for, pack_ids
    from repro.kernels.hash_mix.ops import hash_mix

    ids = list(expected) + list(recomputed)
    lanes = _bucket(lanes_for(ids), lo=32)
    m = _bucket(2 * n, lo=64)
    ids += [""] * (m - 2 * n)
    digests = np.asarray(hash_mix(jnp.asarray(pack_ids(ids, lanes))))
    same = (digests[:n] == digests[n : 2 * n]).all(axis=1)
    # Digest-equal => verified (a 128-bit expected/recomputed collision is
    # negligible); digest-unequal => full-string compare, which documents
    # the mismatch the digests already proved.
    return [bool(s) or expected[i] == recomputed[i] for i, s in enumerate(same)]


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

def _process_file(
    path,
    fname: str,
    items: Sequence[Tuple[str, str, int]],
    verify: bool,
    gap: int,
    guess: int,
    cache: Optional[RecordCache],
    verify_backend: str,
    max_span: int,
) -> Tuple[List[ReadEvent], ReadStats]:
    """One worker's unit: read, split, and verify every target in a file."""
    stats = ReadStats()
    n = len(items)
    texts: List[Optional[str]] = [None] * n
    rids: List[Optional[str]] = [None] * n

    to_read: List[int] = []
    if cache is not None:
        for i, (_fid, _key, off) in enumerate(items):
            hit = cache.get(fname, off)
            if hit is not None:
                texts[i], rids[i] = hit
                stats.cache_hits += 1
            else:
                to_read.append(i)
    else:
        to_read = list(range(n))

    if to_read:
        fd = os.open(path, os.O_RDONLY)
        stats.files_opened += 1
        try:
            fsize = os.fstat(fd).st_size
            for span in coalesce_spans(
                [(i, items[i][2]) for i in to_read], gap, guess, fsize, max_span
            ):
                reader = _SpanReader(fd, span, fsize, guess, stats)
                for slot, off in span.members:
                    texts[slot] = reader.record_at(off)
                # one cache insert per record: freshly-read text goes in with
                # its recomputed id below when verifying (avoids double puts)
                if cache is not None and not verify:
                    for slot, off in span.members:
                        cache.put(fname, off, texts[slot])
        finally:
            os.close(fd)

    events: List[ReadEvent] = []
    if verify:
        for i in range(n):
            if rids[i] is None:
                rids[i] = _recompute(texts[i])  # type: ignore[arg-type]
                if cache is not None:
                    cache.put(fname, items[i][2], texts[i], rids[i])
        ok = compare_ids_batch([it[0] for it in items], rids, verify_backend)
    else:
        ok = [True] * n
    for i, (full_id, key, off) in enumerate(items):
        events.append(
            ReadEvent(
                ok=ok[i],
                full_id=full_id,
                key=key,
                file=fname,
                offset=off,
                text=texts[i],  # type: ignore[arg-type]
                found_id=rids[i] if verify else None,
            )
        )
    stats.records += n
    return events, stats


def stream_plan(
    store,
    plan: Dict[str, List[Tuple[str, str, int]]],
    *,
    verify: bool = True,
    workers: int = DEFAULT_WORKERS,
    coalesce_gap: int = DEFAULT_COALESCE_GAP,
    span_guess: int = DEFAULT_SPAN_GUESS,
    cache: Optional[RecordCache] = None,
    verify_backend: str = "auto",
    stats: Optional[ReadStats] = None,
    max_span: int = DEFAULT_MAX_SPAN,
    executor: Optional[ThreadPoolExecutor] = None,
) -> Iterator[ReadEvent]:
    """Stream :class:`ReadEvent`s for an extraction plan.

    ``plan`` is :func:`repro.core.extract.plan_extraction` output
    (``{file_name: [(full_id, lookup_key, offset), ...]}``).  Files are
    fanned out over ``workers`` threads (``workers <= 1`` runs inline, in
    plan order); events for a file are emitted as soon as that file's
    records are verified, so downstream consumers overlap with reads still
    in flight.  Event order across files is completion order — callers
    needing determinism must reorder (``extract`` does).

    At most ``2 * workers`` files are in flight at once (backpressure: a
    slow consumer of a huge plan never forces every file's records to sit
    decoded in memory), and abandoning the generator early drops queued
    files instead of joining the whole extraction.

    ``executor`` lends a long-lived pool (it is never shut down here) so
    hot-path callers — the training loader fetches every step — skip
    per-call pool construction.  ``stats`` (optional) accumulates merged
    I/O counters; per-file merges happen on the consuming thread, so
    reading it mid-iteration is safe.
    """
    if stats is None:
        stats = ReadStats()
    args = dict(
        verify=verify,
        gap=coalesce_gap,
        guess=span_guess,
        cache=cache,
        verify_backend=verify_backend,
        max_span=max_span,
    )
    files = list(plan.items())
    if executor is None and (workers <= 1 or len(files) <= 1):
        for fname, items in files:
            events, fstats = _process_file(store.path_of(fname), fname, items, **args)
            stats.merge(fstats)
            yield from events
        return

    owned = executor is None
    pool = executor if executor is not None else ThreadPoolExecutor(max_workers=workers)
    pending: set = set()
    todo = iter(files)
    max_inflight = max(2 * workers, 2)
    try:
        while True:
            for fname, items in todo:
                pending.add(pool.submit(
                    _process_file, store.path_of(fname), fname, items, **args
                ))
                if len(pending) >= max_inflight:
                    break
            if not pending:
                return
            done, pending = wait(pending, return_when=FIRST_COMPLETED)
            for fut in done:
                events, fstats = fut.result()
                stats.merge(fstats)
                yield from events
    finally:
        # An abandoned generator (consumer broke out of extract_iter) must
        # not stall until every in-flight file finishes: drop queued files
        # and return without joining the running ones.
        if owned:
            pool.shutdown(wait=False, cancel_futures=True)
        else:
            for fut in pending:
                fut.cancel()
