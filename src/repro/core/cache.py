"""Bounded LRU record-content cache — the layer in front of the store.

Extraction re-runs (the paper's "re-extraction with modified criteria, no
index rebuild", Table II) and the training loader's epoch loops fetch the
same records over and over.  The byte-offset index makes each fetch O(1)
in *seeks*, but every fetch still pays a ``pread`` plus — far more
expensive at our record sizes — a full structural re-parse for defensive
verification.  This cache remembers both: the raw record text *and* the
canonical id recomputed from it, keyed by the record's physical location
``(file_id, offset)``.

Location keys (not identifier keys) make the cache correct under every
key_mode: hashed-key collisions map two different lookup keys to one
location, and the cache serves both from a single entry while the
verification compare still runs against each caller's expected id.

Entries are LRU-evicted by record count and optionally by total cached
bytes.  All operations are thread-safe (the extraction engine's file
workers share one cache), and hit/miss/eviction counters are kept for the
benchmarks' cache-hit-rate row.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional, Tuple

__all__ = ["CacheStats", "RecordCache"]


@dataclass
class CacheStats:
    """Cumulative counters across the cache's lifetime."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    inserts: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class RecordCache:
    """LRU cache of ``(file_id, offset) -> (record_text, recomputed_id)``.

    ``recomputed_id`` is the canonical id re-derived from the record's
    structural data (``canonical_id_from_structure``), or ``None`` when the
    entry was inserted without verification.  Caching the recomputed id is
    what makes a warm cache fast: a verified re-fetch becomes one dict
    lookup plus one id compare — no I/O, no parse.

    ``capacity`` bounds the entry count; ``max_bytes`` (optional)
    additionally bounds the total cached record text, so one pathological
    corpus of huge records cannot blow the memory budget.
    """

    def __init__(self, capacity: int = 4096, max_bytes: Optional[int] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if max_bytes is not None and max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        self.capacity = capacity
        self.max_bytes = max_bytes
        self.stats = CacheStats()
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Tuple[str, int], Tuple[str, Optional[str]]]" = (
            OrderedDict()
        )
        self._bytes = 0

    # -- core operations ----------------------------------------------------

    def get(self, file_id: str, offset: int) -> Optional[Tuple[str, Optional[str]]]:
        """``(text, recomputed_id)`` for a cached location, else ``None``."""
        key = (file_id, offset)
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.stats.misses += 1
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return entry

    def put(
        self,
        file_id: str,
        offset: int,
        text: str,
        recomputed_id: Optional[str] = None,
    ) -> None:
        """Insert or refresh an entry (refresh also promotes to MRU).

        Refreshing never *forgets* a recomputed id: an insert with
        ``recomputed_id=None`` over an already-verified entry keeps the
        verified id (recomputation is deterministic, so the stored id stays
        correct for the unchanged text).
        """
        key = (file_id, offset)
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self._bytes -= len(old[0])
                if recomputed_id is None:
                    recomputed_id = old[1]
            else:
                self.stats.inserts += 1
            self._entries[key] = (text, recomputed_id)
            self._bytes += len(text)
            while len(self._entries) > self.capacity or (
                self.max_bytes is not None
                and self._bytes > self.max_bytes
                and len(self._entries) > 1
            ):
                _, (etext, _) = self._entries.popitem(last=False)
                self._bytes -= len(etext)
                self.stats.evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes = 0

    # -- introspection ------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: Tuple[str, int]) -> bool:
        with self._lock:
            return key in self._entries

    @property
    def cached_bytes(self) -> int:
        with self._lock:
            return self._bytes

    @property
    def hit_rate(self) -> float:
        return self.stats.hit_rate
