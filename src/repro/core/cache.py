"""Scan-resistant record-content cache — the layer in front of the store.

Extraction re-runs (the paper's "re-extraction with modified criteria, no
index rebuild", Table II) and the training loader's epoch loops fetch the
same records over and over.  The byte-offset index makes each fetch O(1)
in *seeks*, but every fetch still pays a ``pread`` plus — far more
expensive at our record sizes — a full structural re-parse for defensive
verification.  This cache remembers both: the raw record text *and* the
canonical id recomputed from it, keyed by the record's physical location
``(file_id, offset)``.

Payloads may be decoded strings or zero-copy
:class:`~repro.core.iobackend.RecordView` windows — the cache is
agnostic (byte accounting uses ``len()``, identical for both).  Caching
the *view* keeps the read path copy-free end to end: the entry pins its
span buffer only until some consumer decodes it (``RecordView.text``
memoizes the string and drops the buffer reference in place, so the
cached entry itself stops pinning at the first delivery).

Location keys (not identifier keys) make the cache correct under every
key_mode: hashed-key collisions map two different lookup keys to one
location, and the cache serves both from a single entry while the
verification compare still runs against each caller's expected id.

Admission is **segmented LRU** (SLRU): a new entry enters a probationary
segment and is only *promoted* to the protected segment when it is hit
again.  Eviction always drains probation first, so one bulk extraction
sweep — millions of records touched exactly once — churns through
probation without evicting the serving working set that earned its place
in protected.  A plain LRU would flush everything on every sweep; with
the query service sharing one cache between bulk extraction and
high-concurrency serving, that failure mode is the default workload.

Entries are evicted by record count and optionally by total cached
bytes.  All operations are thread-safe (the extraction engine's file
workers and the service's reader share one cache), and
hit/miss/eviction/promotion counters are kept for the benchmarks'
cache-hit-rate rows.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional, Tuple

__all__ = ["CacheStats", "RecordCache"]

# Fraction of ``capacity`` the protected segment may hold.  Promotion past
# this demotes the protected LRU back to probation (second-chance), never
# evicts it outright.
DEFAULT_PROTECTED_FRAC = 0.8


@dataclass
class CacheStats:
    """Cumulative counters across the cache's lifetime."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    inserts: int = 0
    probation_hits: int = 0  # hits that found the entry still on probation
    demotions: int = 0       # protected LRU pushed back to probation

    @property
    def promotions(self) -> int:
        """Probation -> protected moves.  Promotion happens exactly on a
        probation hit, so this is derived, not separately counted — one
        fact, one counter."""
        return self.probation_hits

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class RecordCache:
    """SLRU cache of ``(file_id, offset) -> (record_payload, recomputed_id)``.

    ``record_payload`` is the record text as a ``str`` or an undecoded
    :class:`~repro.core.iobackend.RecordView` (the engine caches views;
    they decode lazily at the API boundary).

    ``recomputed_id`` is the canonical id re-derived from the record's
    structural data (``canonical_id_from_structure``), or ``None`` when the
    entry was inserted without verification.  Caching the recomputed id is
    what makes a warm cache fast: a verified re-fetch becomes one dict
    lookup plus one id compare — no I/O, no parse.

    ``capacity`` bounds the total entry count across both segments;
    ``max_bytes`` (optional) additionally bounds the total cached record
    text, so one pathological corpus of huge records cannot blow the
    memory budget.  ``protected_frac`` caps the protected segment's share
    of ``capacity`` (the rest is guaranteed probation room, so admission
    never starves).
    """

    def __init__(
        self,
        capacity: int = 4096,
        max_bytes: Optional[int] = None,
        protected_frac: float = DEFAULT_PROTECTED_FRAC,
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if max_bytes is not None and max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        if not 0.0 < protected_frac <= 1.0:
            raise ValueError(
                f"protected_frac must be in (0, 1], got {protected_frac}"
            )
        self.capacity = capacity
        self.max_bytes = max_bytes
        # Protected may never fill the whole cache: probation-first
        # eviction would then evict every NEW entry on arrival and the
        # cache could fossilize around a pinned protected set.  Capping at
        # capacity-1 keeps at least one admission slot; at capacity=1 the
        # cap is 0 and the cache degrades to a plain LRU of one (no
        # promotion).
        self.protected_capacity = min(
            capacity - 1, max(1, int(capacity * protected_frac))
        ) if capacity > 1 else 0
        self.stats = CacheStats()
        self._lock = threading.Lock()
        # Two LRU segments; an entry lives in exactly one at a time.
        self._probation: "OrderedDict[Tuple[str, int], Tuple[str, Optional[str]]]" = (
            OrderedDict()
        )
        self._protected: "OrderedDict[Tuple[str, int], Tuple[str, Optional[str]]]" = (
            OrderedDict()
        )
        self._bytes = 0

    # -- core operations ----------------------------------------------------

    def get(self, file_id: str, offset: int) -> Optional[Tuple[str, Optional[str]]]:
        """``(text, recomputed_id)`` for a cached location, else ``None``."""
        key = (file_id, offset)
        with self._lock:
            entry = self._protected.get(key)
            if entry is not None:
                self._protected.move_to_end(key)
                self.stats.hits += 1
                return entry
            entry = self._probation.get(key)
            if entry is None:
                self.stats.misses += 1
                return None
            self.stats.hits += 1
            if self.protected_capacity == 0:
                self._probation.move_to_end(key)  # plain LRU degenerate
                return entry
            # second reference: the entry earned protection
            self.stats.probation_hits += 1
            del self._probation[key]
            self._protected[key] = entry
            while len(self._protected) > self.protected_capacity:
                dkey, dval = self._protected.popitem(last=False)
                self._probation[dkey] = dval  # demote, don't evict
                self.stats.demotions += 1
            return entry

    def put(
        self,
        file_id: str,
        offset: int,
        text,  # str | RecordView
        recomputed_id: Optional[str] = None,
    ) -> None:
        """Insert or refresh an entry (refresh promotes to its segment's MRU).

        A *new* entry always enters probation — one reference is no claim
        on the working set; promotion happens on the next :meth:`get`.  A
        refresh stays in whichever segment the entry already occupies.
        Refreshing never *forgets* a recomputed id: an insert with
        ``recomputed_id=None`` over an already-verified entry keeps the
        verified id (recomputation is deterministic, so the stored id stays
        correct for the unchanged text).
        """
        key = (file_id, offset)
        with self._lock:
            seg = None
            old = self._protected.pop(key, None)
            if old is not None:
                seg = self._protected
            else:
                old = self._probation.pop(key, None)
                if old is not None:
                    seg = self._probation
            if old is not None:
                self._bytes -= len(old[0])
                if recomputed_id is None:
                    recomputed_id = old[1]
            else:
                seg = self._probation
                self.stats.inserts += 1
            seg[key] = (text, recomputed_id)
            self._bytes += len(text)
            self._evict_over_budget()

    def _evict_over_budget(self) -> None:
        """Evict probation-first until count and byte budgets hold.

        "Probation-first" must not mean "newcomer-first": when probation
        holds only the entry being admitted (the byte budget can reach
        this state — promotions move entries without freeing bytes), the
        victim comes from protected instead, or the cache would fossilize
        around the old protected set and never admit again.
        """
        while len(self._probation) + len(self._protected) > self.capacity or (
            self.max_bytes is not None
            and self._bytes > self.max_bytes
            and len(self._probation) + len(self._protected) > 1
        ):
            if len(self._probation) > 1 or not self._protected:
                victim_seg = self._probation
            else:
                victim_seg = self._protected
            _, (etext, _) = victim_seg.popitem(last=False)
            self._bytes -= len(etext)
            self.stats.evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._probation.clear()
            self._protected.clear()
            self._bytes = 0

    # -- introspection ------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._probation) + len(self._protected)

    def __contains__(self, key: Tuple[str, int]) -> bool:
        with self._lock:
            return key in self._probation or key in self._protected

    @property
    def probation_len(self) -> int:
        with self._lock:
            return len(self._probation)

    @property
    def protected_len(self) -> int:
        with self._lock:
            return len(self._protected)

    @property
    def cached_bytes(self) -> int:
        with self._lock:
            return self._bytes

    @property
    def hit_rate(self) -> float:
        return self.stats.hit_rate
