"""§VI — systematic hashed-key collision discovery and analysis.

The paper's most striking empirical result: 163 InChIKey values in PubChem
map to multiple distinct full InChI strings (326 records), ~10× the
birthday-bound expectation of n²/2h ≈ 15.7.  This module reproduces the
*methodology*:

* ``scan_corpus``      — full-corpus scan collecting (hashed_key, full_id)
  pairs and grouping them (the paper's "systematic scanning of the entire
  PubChem index").  Host-dict implementation (exact).
* ``scan_pairs_sorted``— the TPU-idiomatic equivalent: hash → sort →
  adjacent-compare on packed digests (NumPy/JAX arrays; the Pallas
  ``hash_mix`` kernel feeds this path at scale).  Cross-validated against
  the dict path in tests.
* ``birthday_expectation`` — Eq. 5: E[collisions] ≈ n²/(2h).

With the key width set to the paper's h ≈ 1e15 our container-scale corpora
produce ~0 collisions (as theory says they should at n ≤ 1e6); the
benchmarks therefore sweep the key width downward and verify measured
collision counts track n²/2h — the same validation logic, scale-adjusted.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from .identifiers import hashed_key
from .records import RecordStore, extract_property, iter_records
from .sdfgen import PROP_ID

__all__ = [
    "CollisionReport",
    "scan_corpus",
    "collisions_from_pairs",
    "scan_pairs_sorted",
    "birthday_expectation",
]


@dataclass
class CollisionReport:
    n_records: int = 0
    key_bits: int = 0
    # key -> list of distinct full ids sharing it (only keys with >= 2)
    colliding: Dict[str, List[str]] = field(default_factory=dict)
    seconds: float = 0.0

    @property
    def n_colliding_keys(self) -> int:
        return len(self.colliding)

    @property
    def n_affected_records(self) -> int:
        return sum(len(v) for v in self.colliding.values())

    @property
    def empirical_rate(self) -> float:
        """Eq. 4: affected records / total records."""
        return self.n_affected_records / self.n_records if self.n_records else 0.0


def birthday_expectation(n_records: int, key_bits: int) -> float:
    """Eq. 5: E[collisions] ≈ n² / (2h) with h = 2**key_bits."""
    return (float(n_records) ** 2) / (2.0 * float(2 ** key_bits))


def collisions_from_pairs(
    pairs: Iterable[Tuple[str, str]]
) -> Dict[str, List[str]]:
    """Group (key, full_id) pairs; return keys with >= 2 *distinct* ids.

    Distinctness matters: the same molecule indexed twice is a duplicate,
    not a collision (the paper's count is of distinct-structure pairs).
    """
    groups: Dict[str, set] = {}
    for key, full_id in pairs:
        groups.setdefault(key, set()).add(full_id)
    return {k: sorted(v) for k, v in groups.items() if len(v) >= 2}


def scan_corpus(
    store: RecordStore, key_bits: int
) -> CollisionReport:
    """Full-corpus collision scan (host-exact reference path)."""
    t0 = time.perf_counter()
    pairs: List[Tuple[str, str]] = []
    n = 0
    for path in store.files():
        for _off, text in iter_records(path):
            full_id = extract_property(text, PROP_ID)
            if full_id is None:
                continue
            n += 1
            pairs.append((hashed_key(full_id, key_bits), full_id))
    rep = CollisionReport(
        n_records=n,
        key_bits=key_bits,
        colliding=collisions_from_pairs(pairs),
        seconds=time.perf_counter() - t0,
    )
    return rep


def scan_pairs_sorted(
    keys: Sequence[str], full_ids: Sequence[str]
) -> Dict[str, List[str]]:
    """Sort-based collision detection (TPU-idiomatic substitution).

    Hash-map "group by key" does not map to TPU; sort + adjacent-compare
    does.  Keys are mapped to uint64 digests, argsorted, and runs of equal
    digests are checked for distinct full ids.  Digest equality is then
    confirmed on the *string* key (guards against digest aliasing, mirroring
    Algorithm 3's verify-at-the-end discipline).
    """
    if len(keys) != len(full_ids):
        raise ValueError("length mismatch")
    n = len(keys)
    if n == 0:
        return {}
    import hashlib

    dig = np.fromiter(
        (
            int.from_bytes(hashlib.blake2b(k.encode(), digest_size=8).digest(), "big")
            for k in keys
        ),
        dtype=np.uint64,
        count=n,
    )
    order = np.argsort(dig, kind="stable")
    ds = dig[order]
    # run boundaries: ds[i] == ds[i+1]
    eq = ds[1:] == ds[:-1]
    out: Dict[str, set] = {}
    i = 0
    while i < n:
        j = i
        while j + 1 < n and eq[j]:
            j += 1
        if j > i:
            # candidate run [i, j]; confirm on string key then group ids
            for a in range(i, j + 1):
                ka = keys[order[a]]
                out.setdefault(ka, set()).add(full_ids[order[a]])
        i = j + 1
    return {k: sorted(v) for k, v in out.items() if len(v) >= 2}
