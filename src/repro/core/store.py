"""Sharded, mmap-backed query service over the byte-offset index.

The dict inside :class:`~repro.core.index.ByteOffsetIndex` is the paper's
§IV.A in-memory index — fine for one host building the index, a non-starter
for serving it at the paper's 176M-compound scale.  This module is the
serving-grade face of the same contract: the index partitioned by digest
range into ``S`` shards, each persisted as packed sorted-digest columns
(the :meth:`ByteOffsetIndex.save_binary` sidecar format, split per column
so every column is ``np.load(..., mmap_mode="r")``-able) plus a Bloom
bitmap, under one JSON manifest:

    store_dir/
      manifest.json              # params, file_names, per-shard meta
      shard_0003.digests.npy     # uint64, sorted ascending within shard
      shard_0003.file_ids.npy    # int32 into manifest["file_names"]
      shard_0003.offsets.npy     # int64 byte offsets
      shard_0003.keys.npy        # |S<w> full keys (the verify column)
      shard_0003.bloom.npy       # packed Bloom bitmap (uint8)
      shard_0003.fps.npy         # (N, W) uint32 fingerprint bit-plane
      shard_0003.fpcounts.npy    # int32 per-row popcounts (union term)

Query model (batch-first — ``lookup_batch(keys)``):

1. **digest** every key once (vectorized blake2b-64, ``digest_u64``);
2. **route** by digest range (``shard_of``: top bits of the digest);
3. **Bloom prefilter** per shard — misses are rejected from a few bit
   probes without ever faulting the shard's data columns in;
4. **probe** survivors against the shard's sorted digest column — host
   ``np.searchsorted`` or the ``sorted_probe`` Pallas kernel on device;
5. **verify** every digest hit against the full key, scanning forward over
   the equal-digest run (Algorithm 3 discipline: a digest collision costs
   an extra compare, never a wrong record).

Shards load lazily and stay mmap'd, so resident memory is O(touched
shards), and an untouched store costs only its manifest.  ``ByteOffsetIndex``
remains the builder: :func:`save_sharded` skips rewriting shards whose
content hash is unchanged, so incremental index updates republish only the
shards they touched.

Beyond exact-key lookup, each shard carries a **fingerprint plane**
(``fps``/``fpcounts`` sidecars, see :mod:`repro.core.fingerprint`): packed
``(N, W)`` uint32 bit-rows in the same digest-sorted row order as the data
columns, enabling the second query modality — :meth:`IndexStore.similar_batch`
screens a batch of query fingerprints against every shard's plane with the
batched Tanimoto top-k kernel and merges the per-shard winners into global
``(scores, file_ids, offsets)``.
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

import numpy as np

from .bloom import BloomFilter
from .fingerprint import (
    DEFAULT_FP_BITS,
    fingerprint_batch,
    popcount_u32,
    words_for,
)

__all__ = [
    "IndexStore",
    "QueryStats",
    "candidate_runs",
    "digest_u64",
    "merge_similar_topk",
    "save_sharded",
    "shard_of",
]

MANIFEST_NAME = "manifest.json"
FORMAT_VERSION = 1

_COLUMNS = ("digests", "file_ids", "offsets", "keys")


# ---------------------------------------------------------------------------
# Shared digest / probe helpers (also used by core.intersect)
# ---------------------------------------------------------------------------

def digest_u64(ids: Sequence[str], bits: int = 64) -> np.ndarray:
    """blake2b-64 digests of string ids as a uint64 vector.

    ``bits < 64`` truncates to the low ``bits`` bits — the same
    width-narrowing device :func:`repro.core.identifiers.hashed_key` uses to
    make hundred-million-scale collision phenomenology observable (and
    testable) at container-scale corpora.
    """
    if not 1 <= bits <= 64:
        raise ValueError(f"bits must be in [1, 64], got {bits}")
    out = np.fromiter(
        (
            int.from_bytes(hashlib.blake2b(s.encode(), digest_size=8).digest(), "big")
            for s in ids
        ),
        dtype=np.uint64,
        count=len(ids),
    )
    if bits < 64:
        out &= np.uint64((1 << bits) - 1)
    return out


def candidate_runs(
    sorted_digests: np.ndarray, query_digests: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-query ``[start, stop)`` bounds of the equal-digest run.

    ``side="left"`` alone only reaches the *first* of several equal digests;
    pairing it with ``side="right"`` exposes the whole run so callers can
    verify every colliding candidate — the discipline
    :meth:`BinaryIndex.lookup` applies per key, vectorized.
    """
    starts = np.searchsorted(sorted_digests, query_digests, side="left")
    stops = np.searchsorted(sorted_digests, query_digests, side="right")
    return starts.astype(np.int64), stops.astype(np.int64)


def shard_of(digests: np.ndarray, n_shards: int, digest_bits: int = 64) -> np.ndarray:
    """Shard id per digest: the top ``log2(n_shards)`` bits of the digest.

    Digest-range partitioning keeps each shard's digest column sorted and
    contiguous in key space, so per-shard binary search stays valid and
    range ownership is a shift, not a table.
    """
    shard_bits = (n_shards - 1).bit_length()
    if n_shards < 1 or n_shards != 1 << shard_bits and n_shards != 1:
        raise ValueError(f"n_shards must be a power of two, got {n_shards}")
    if n_shards == 1:
        return np.zeros(len(digests), dtype=np.int64)
    if shard_bits > digest_bits:
        raise ValueError(
            f"n_shards={n_shards} needs {shard_bits} bits but digests have "
            f"only {digest_bits}"
        )
    return (digests >> np.uint64(digest_bits - shard_bits)).astype(np.int64)


def _u64_to_pairs(d: np.ndarray) -> np.ndarray:
    """uint64 → (N, 2) uint32 ``(hi, lo)`` pairs (lex order == u64 order)."""
    hi = (d >> np.uint64(32)).astype(np.uint32)
    lo = (d & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return np.stack([hi, lo], axis=1)


def merge_similar_topk(
    parts: Sequence[Tuple[np.ndarray, np.ndarray, np.ndarray]], k: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Merge per-shard ``(scores, file_ids, offsets)`` top-k candidates.

    The cross-shard tie contract: global order is ``(score desc, file_id
    asc, offset asc)`` — shard-local row order is digest order, meaningless
    across shards, so equal Tanimoto scores from different shards must
    break on the *location* the caller actually receives or the merged
    ranking would depend on shard layout.  Implemented as three stable
    argsorts (offset, then file_id, then ``-score``) == one lexsort with
    score majorizing.  Pad slots (score ``-1``) sort last under ``-score``
    regardless of their location columns.  Used by both
    :meth:`IndexStore.similar_batch` (merging shards) and the router
    (merging replica scatter results) so the two paths cannot drift.
    """
    scores = np.concatenate([p[0] for p in parts], axis=1)
    fids = np.concatenate([p[1] for p in parts], axis=1)
    offs = np.concatenate([p[2] for p in parts], axis=1)

    def take(order):
        return (
            np.take_along_axis(scores, order, axis=1),
            np.take_along_axis(fids, order, axis=1),
            np.take_along_axis(offs, order, axis=1),
        )

    scores, fids, offs = take(np.argsort(offs, axis=1, kind="stable"))
    scores, fids, offs = take(np.argsort(fids, axis=1, kind="stable"))
    scores, fids, offs = take(
        np.argsort(-scores, axis=1, kind="stable")[:, :k]
    )
    pad = scores < 0.0
    return (
        np.where(pad, np.float32(-1.0), scores).astype(np.float32, copy=False),
        np.where(pad, np.int32(-1), fids).astype(np.int32, copy=False),
        np.where(pad, np.int64(-1), offs).astype(np.int64, copy=False),
    )


# ---------------------------------------------------------------------------
# Persistence: ByteOffsetIndex -> sharded store directory
# ---------------------------------------------------------------------------

def _shard_stem(s: int) -> str:
    return f"shard_{s:04d}"


def _atomic_save(path: Path, arr: np.ndarray) -> None:
    """np.save via temp file + rename: a live reader mmap-ing ``path`` keeps
    its old inode intact instead of seeing a truncated/torn rewrite."""
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        np.save(f, arr)
    os.replace(tmp, path)


def save_sharded(
    index,
    root: Path,
    n_shards: int = 16,
    digest_bits: int = 64,
    bloom_bits_per_key: int = 12,
    fingerprint_bits: Optional[int] = DEFAULT_FP_BITS,
) -> Dict[str, object]:
    """Partition ``index.entries`` into digest-range shards under ``root``.

    Each shard gets sorted-digest data columns, a Bloom sidecar, a packed
    fingerprint plane (``fingerprint_bits`` wide; ``None`` disables the
    similarity modality), and a content hash in the manifest.  When ``root``
    already holds a store built with the same parameters, shards whose
    content hash is unchanged are *not* rewritten — an incremental
    :func:`repro.core.index.update_index` followed by ``save_sharded``
    republishes only the shards it touched.  Fingerprints are a pure
    function of the key text, so an unchanged content hash (which covers
    the keys column) implies an unchanged fingerprint plane.

    Only primary entries are written (shadowed duplicate-key locations stay
    in the CSV truth, exactly like ``save_binary``).  Returns a summary:
    ``{"written", "skipped", "n_entries", "path"}``.
    """
    if n_shards < 1 or (n_shards & (n_shards - 1)):
        raise ValueError(f"n_shards must be a power of two, got {n_shards}")
    if fingerprint_bits is not None:
        words_for(fingerprint_bits)  # validate width up front
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)

    keys: List[str] = list(index.entries.keys())
    locs = [index.entries[k] for k in keys]
    file_names = sorted({f for f, _ in locs})
    file_id_of = {n: i for i, n in enumerate(file_names)}

    digests = digest_u64(keys, bits=digest_bits)
    sid = shard_of(digests, n_shards, digest_bits)

    # previous manifest (same params) enables the skip-unchanged fast path
    old_shards: Optional[List[dict]] = None
    mpath = root / MANIFEST_NAME
    if mpath.exists():
        try:
            old = json.loads(mpath.read_text())
        except (OSError, json.JSONDecodeError):
            old = None
        if (
            old
            and old.get("version") == FORMAT_VERSION
            and old.get("n_shards") == n_shards
            and old.get("digest_bits") == digest_bits
            # the shard content hash covers only the data columns, so the
            # Bloom sizing must match too or a skipped shard would keep its
            # old bitmap under a new manifest bloom_k (false negatives)
            and old.get("bloom_bits_per_key") == bloom_bits_per_key
            # the fingerprint plane is derived from the hashed keys column,
            # so hash-equality extends to it only at the same bit width
            and old.get("fingerprint_bits") == fingerprint_bits
            and old.get("file_names") == file_names
            and len(old.get("shards", ())) == n_shards
        ):
            old_shards = old["shards"]

    shards_meta: List[dict] = []
    written = skipped = 0
    for s in range(n_shards):
        members = np.nonzero(sid == s)[0]
        d = digests[members]
        order = np.argsort(d, kind="stable")
        members = members[order]
        d = d[order]
        fid = np.array([file_id_of[locs[i][0]] for i in members], dtype=np.int32)
        off = np.array([locs[i][1] for i in members], dtype=np.int64)
        if len(members):
            kb = np.array([keys[i].encode() for i in members], dtype=np.bytes_)
        else:
            kb = np.array([], dtype="S1")

        h = hashlib.blake2b(digest_size=16)
        for col in (d, fid, off, kb):
            h.update(col.tobytes())
        content = h.hexdigest()
        # bloom_k is deterministic in (count, bits_per_key): record it
        # without building a bitmap so skipped shards cost nothing
        _, bloom_k = BloomFilter.plan(len(d), bloom_bits_per_key)
        meta = {"count": int(len(d)), "hash": content, "bloom_k": bloom_k}

        stem = _shard_stem(s)
        paths = {c: root / f"{stem}.{c}.npy" for c in _COLUMNS}
        bloom_path = root / f"{stem}.bloom.npy"
        fp_paths = (root / f"{stem}.fps.npy", root / f"{stem}.fpcounts.npy")
        unchanged = (
            old_shards is not None
            and old_shards[s].get("hash") == content
            and all(p.exists() for p in paths.values())
            and bloom_path.exists()
            and (
                fingerprint_bits is None
                or all(p.exists() for p in fp_paths)
            )
        )
        if unchanged:
            skipped += 1
        else:
            _atomic_save(paths["digests"], d)
            _atomic_save(paths["file_ids"], fid)
            _atomic_save(paths["offsets"], off)
            _atomic_save(paths["keys"], kb)
            _atomic_save(
                bloom_path,
                BloomFilter.build(d, bits_per_key=bloom_bits_per_key).bits,
            )
            if fingerprint_bits is not None:
                fps, fpc = fingerprint_batch(
                    [keys[i] for i in members], fingerprint_bits
                )
                _atomic_save(fp_paths[0], fps)
                _atomic_save(fp_paths[1], fpc)
            written += 1
        shards_meta.append(meta)

    manifest = {
        "version": FORMAT_VERSION,
        "key_mode": getattr(index, "key_mode", "full_id"),
        "n_shards": n_shards,
        "digest_bits": digest_bits,
        "bloom_bits_per_key": bloom_bits_per_key,
        "fingerprint_bits": fingerprint_bits,
        "n_entries": len(keys),
        "file_names": file_names,
        "shards": shards_meta,
    }
    tmp = mpath.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(manifest, indent=1))
    os.replace(tmp, mpath)  # atomic publish
    # drop shard files a previous layout left behind (republish with fewer
    # shards, crashed temp files) — unreachable through the new manifest
    # but they would inflate the on-disk footprint forever
    sidecars = (*_COLUMNS, "bloom") + (
        ("fps", "fpcounts") if fingerprint_bits is not None else ()
    )
    expected = {
        f"{_shard_stem(s)}.{c}.npy"
        for s in range(n_shards)
        for c in sidecars
    }
    for p in root.glob("shard_*"):
        if p.name not in expected:
            p.unlink()
    return {
        "written": written,
        "skipped": skipped,
        "n_entries": len(keys),
        "path": str(root),
    }


# ---------------------------------------------------------------------------
# The query service
# ---------------------------------------------------------------------------

@dataclass
class QueryStats:
    """Cumulative counters across ``lookup_batch`` calls."""

    queries: int = 0
    hits: int = 0
    bloom_rejects: int = 0          # dropped before touching any data column
    bloom_false_positives: int = 0  # passed the filter, no digest in shard
    digest_probes: int = 0          # candidates probed against a digest column
    verify_collisions: int = 0      # equal digest, different key (scanned past)
    similar_queries: int = 0        # fingerprint rows submitted to similar_batch
    fp_rows_scanned: int = 0        # query x database row pairs Tanimoto-scored
    shards_touched: Set[int] = field(default_factory=set)

    def merge(self, other: "QueryStats") -> None:
        """Fold ``other`` in (router replica aggregation, stats flushes)."""
        self.queries += other.queries
        self.hits += other.hits
        self.bloom_rejects += other.bloom_rejects
        self.bloom_false_positives += other.bloom_false_positives
        self.digest_probes += other.digest_probes
        self.verify_collisions += other.verify_collisions
        self.similar_queries += other.similar_queries
        self.fp_rows_scanned += other.fp_rows_scanned
        self.shards_touched |= other.shards_touched


class _Shard:
    __slots__ = ("digests", "file_ids", "offsets", "keys")

    def __init__(self, digests, file_ids, offsets, keys):
        self.digests = digests
        self.file_ids = file_ids
        self.offsets = offsets
        self.keys = keys

    @property
    def nbytes(self) -> int:
        return sum(
            int(a.nbytes) for a in (self.digests, self.file_ids, self.offsets, self.keys)
        )


class IndexStore:
    """mmap-backed sharded index with Bloom prefilter and batched lookups.

    Drop-in for the read side of :class:`ByteOffsetIndex` (``lookup`` /
    ``locate_batch`` / ``key_mode`` / ``__contains__``), so
    :func:`repro.core.extract.extract` and the training data pipeline run
    unchanged on top of it — but the core API is :meth:`lookup_batch`, which
    amortizes digesting, routing, filtering, and probing across the whole
    batch.
    """

    def __init__(self, root: Path, manifest: dict, mmap: bool = True):
        self.root = Path(root)
        self.manifest = manifest
        self.key_mode: str = manifest["key_mode"]
        self.n_shards: int = int(manifest["n_shards"])
        self.digest_bits: int = int(manifest["digest_bits"])
        self.file_names: List[str] = list(manifest["file_names"])
        self._mmap = bool(mmap)
        # None on stores published before the similarity modality (or with
        # fingerprints disabled): similar_batch raises a clear error then
        fp_bits = manifest.get("fingerprint_bits")
        self.fingerprint_bits: Optional[int] = (
            int(fp_bits) if fp_bits is not None else None
        )
        self._shards: Dict[int, _Shard] = {}
        self._blooms: Dict[int, BloomFilter] = {}
        self._fp_shards: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self.stats = QueryStats()
        # Concurrent lookup_batch callers (the service's scatter-gather
        # workers) race the lazy first-touch np.load of a shard and the
        # shared stats counters; both are serialized here.  Loads hold the
        # lock only around the miss path, so warm probes stay lock-free on
        # the dict read (GIL-atomic) and pay one uncontended acquire per
        # stats flush.
        self._load_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        # Cross-shard Bloom plane (lazy): every shard's bitmap concatenated
        # so a multi-shard batch runs ONE vectorized filter pass instead of
        # a per-shard pass whose fixed numpy dispatch cost dominates
        # micro-batches.  Bitmaps are the small always-cheap part of the
        # store (~bits_per_key/8 bytes per entry), so pinning them all is
        # the designed serving posture; data columns stay mmap-lazy.
        self._bloom_plane: Optional[Tuple[np.ndarray, ...]] = None
        # Serving plane (opt-in via preload_digest_plane): digest, file_id
        # and offset columns concatenated in shard order — digest-range
        # partitioning makes the digest concatenation one globally sorted
        # array, so a whole batch probes with ONE searchsorted and gathers
        # its hit locations with vectorized fancy-indexing instead of a
        # per-shard loop of scalar mmap reads.  Costs 20 resident
        # bytes/entry (the fat keys column stays mmap-lazy), which is why
        # it is the serving posture (the ShardRouter turns it on), not the
        # default.
        self._digest_plane: Optional[Tuple[np.ndarray, ...]] = None

    @classmethod
    def open(cls, root: Path, mmap: bool = True) -> "IndexStore":
        root = Path(root)
        manifest = json.loads((root / MANIFEST_NAME).read_text())
        if manifest.get("version") != FORMAT_VERSION:
            raise ValueError(
                f"unsupported store version {manifest.get('version')!r} "
                f"(expected {FORMAT_VERSION})"
            )
        return cls(root, manifest, mmap=mmap)

    # -- lazy shard access ---------------------------------------------------

    def _load_column(self, stem: str, col: str, count: int) -> np.ndarray:
        path = self.root / f"{stem}.{col}.npy"
        if count == 0:
            # np.memmap refuses zero-length maps; synthesize the empty column
            empty_dtype = {"digests": np.uint64, "file_ids": np.int32,
                           "offsets": np.int64, "keys": "S1"}[col]
            return np.array([], dtype=empty_dtype)
        return np.load(path, mmap_mode="r" if self._mmap else None)

    def _shard(self, s: int) -> _Shard:
        shard = self._shards.get(s)
        if shard is None:
            with self._load_lock:  # double-checked: losers reuse the winner's
                shard = self._shards.get(s)
                if shard is None:
                    stem = _shard_stem(s)
                    count = int(self.manifest["shards"][s]["count"])
                    shard = _Shard(
                        *(self._load_column(stem, c, count) for c in _COLUMNS)
                    )
                    self._shards[s] = shard
        return shard

    def _bloom(self, s: int) -> BloomFilter:
        bloom = self._blooms.get(s)
        if bloom is None:
            with self._load_lock:
                bloom = self._blooms.get(s)
                if bloom is None:
                    bits = np.load(self.root / f"{_shard_stem(s)}.bloom.npy")
                    bloom = BloomFilter(np.asarray(bits, dtype=np.uint8),
                                        int(self.manifest["shards"][s]["bloom_k"]))
                    self._blooms[s] = bloom
        return bloom

    def _fp_shard(self, s: int) -> Tuple[np.ndarray, np.ndarray]:
        """Lazy mmap of shard ``s``'s ``(fps, fpcounts)`` fingerprint plane."""
        pair = self._fp_shards.get(s)
        if pair is None:
            if self.fingerprint_bits is None:
                raise ValueError(
                    "store has no fingerprint plane (published with "
                    "fingerprint_bits=None or by a pre-similarity builder); "
                    "re-run save_sharded with fingerprint_bits set"
                )
            with self._load_lock:
                pair = self._fp_shards.get(s)
                if pair is None:
                    count = int(self.manifest["shards"][s]["count"])
                    w = words_for(self.fingerprint_bits)
                    if count == 0:
                        pair = (
                            np.zeros((0, w), dtype=np.uint32),
                            np.zeros(0, dtype=np.int32),
                        )
                    else:
                        stem = _shard_stem(s)
                        mode = "r" if self._mmap else None
                        pair = (
                            np.load(self.root / f"{stem}.fps.npy",
                                    mmap_mode=mode),
                            np.load(self.root / f"{stem}.fpcounts.npy",
                                    mmap_mode=mode),
                        )
                    self._fp_shards[s] = pair
        return pair

    def _bloom_filter_plane(self) -> Tuple[np.ndarray, ...]:
        """``(bits_concat, byte_off, m_mask, k)`` across all shards."""
        plane = self._bloom_plane
        if plane is None:
            with self._load_lock:
                plane = self._bloom_plane
            if plane is not None:
                return plane
            blooms = [self._bloom(s) for s in range(self.n_shards)]
            bits = np.concatenate([b.bits for b in blooms])
            off = np.zeros(self.n_shards, dtype=np.int64)
            np.cumsum([b.bits.shape[0] for b in blooms[:-1]], out=off[1:])
            m_mask = np.array([b.m - 1 for b in blooms], dtype=np.uint64)
            k = np.array([b.k for b in blooms], dtype=np.int64)
            plane = (bits, off, m_mask, k)
            with self._load_lock:
                self._bloom_plane = plane
        return plane

    def preload_digest_plane(self) -> Tuple[Tuple[np.ndarray, ...], ...]:
        """Pin the serving plane + Bloom plane (serving mode).

        The serving plane is ``(digests, row_off, file_ids, offsets)``
        concatenated across shards — 20 resident bytes/entry.  The fat
        keys column (the verify column) stays mmap-lazy; only verified
        hits fault its pages in.  Returns ``(serving_plane, bloom_plane)``
        so replicas of the same store can share the (read-only) planes
        instead of re-building.
        """
        if self._digest_plane is None:
            counts = [int(m["count"]) for m in self.manifest["shards"]]
            row_off = np.zeros(self.n_shards + 1, dtype=np.int64)
            np.cumsum(counts, out=row_off[1:])
            shards = [self._shard(s) for s in range(self.n_shards)]

            def concat(arrs, dtype):
                return (
                    np.concatenate([np.asarray(a) for a in arrs])
                    if arrs
                    else np.empty(0, dtype=dtype)
                )

            d_all = concat([sh.digests for sh in shards], np.uint64)
            f_all = concat([sh.file_ids for sh in shards], np.int32)
            o_all = concat([sh.offsets for sh in shards], np.int64)
            with self._load_lock:
                self._digest_plane = (d_all, row_off, f_all, o_all)
        return self._digest_plane, self._bloom_filter_plane()

    def adopt_planes(
        self, planes: Tuple[Tuple[np.ndarray, ...], ...]
    ) -> None:
        """Share another replica's (immutable) preloaded planes."""
        digest_plane, bloom_plane = planes
        with self._load_lock:
            self._digest_plane = digest_plane
            self._bloom_plane = bloom_plane

    def _bloom_pass(self, q: np.ndarray, sid: np.ndarray) -> np.ndarray:
        """One vectorized Bloom probe for a whole (multi-shard) batch.

        Identical accept/reject decisions to probing each shard's filter
        separately — same double-hash positions against the same bitmaps,
        gathered through the concatenated plane — but one numpy pass
        total, so a batch spread thinly over many shards (the continuous
        micro-batching regime) no longer pays per-shard dispatch overhead.
        """
        from .bloom import _mix64

        bits, off, m_mask, k = self._bloom_filter_plane()
        kmax = int(k.max()) if len(k) else 1
        h2 = _mix64(q) | np.uint64(1)
        i = np.arange(kmax, dtype=np.uint64)[:, None]
        pos = (q[None, :] + i * h2[None, :]) & m_mask[sid][None, :]
        byte = bits[(pos >> np.uint64(3)).astype(np.int64) + off[sid][None, :]]
        bit = (byte >> (pos & np.uint64(7)).astype(np.uint8)) & np.uint8(1)
        # rows past a shard's own k are neutral (True) under the AND
        valid = np.arange(kmax, dtype=np.int64)[:, None] < k[sid][None, :]
        return np.where(valid, bit.astype(bool), True).all(axis=0)

    # -- core batched query --------------------------------------------------

    def lookup_batch(
        self,
        keys: Sequence[str],
        probe: Optional[str] = None,
        digests: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Resolve a batch of keys: ``(file_ids, offsets, hit_mask)``.

        ``file_ids`` (int32) index :attr:`file_names`; misses hold ``-1`` in
        both columns and ``False`` in ``hit_mask``.  ``probe`` selects the
        digest-search backend: ``"host"`` (``np.searchsorted``), ``"device"``
        (the ``sorted_probe`` Pallas kernel — jnp reference off-TPU), or
        ``None``/"auto" (device only when JAX is already running on TPU).

        ``digests`` (optional uint64, parallel to ``keys``) skips the
        per-call ``digest_u64`` — the service's router digests a request
        batch ONCE and hands each shard probe its slice.  Thread-safe:
        concurrent callers may share one store (lazy shard loads and stats
        flushes are serialized internally).
        """
        n = len(keys)
        file_ids = np.full(n, -1, dtype=np.int32)
        offsets = np.full(n, -1, dtype=np.int64)
        hit = np.zeros(n, dtype=bool)
        if n == 0:
            return file_ids, offsets, hit
        if probe is None or probe == "auto":
            probe = "device" if _tpu_backend_active() else "host"
        if probe not in ("host", "device"):
            raise ValueError(f"unknown probe backend {probe!r}")

        if digests is None:
            q = digest_u64(keys, bits=self.digest_bits)
        else:
            q = np.asarray(digests, dtype=np.uint64)
            if q.shape != (n,):
                raise ValueError(
                    f"digests shape {q.shape} does not match {n} keys"
                )
        sid = shard_of(q, self.n_shards, self.digest_bits)
        delta = QueryStats(queries=n)

        if self._digest_plane is not None and probe == "host":
            # serving posture: one global probe over the pinned digest plane
            self._lookup_plane(keys, q, sid, file_ids, offsets, hit, delta)
            delta.hits = int(hit.sum())
            with self._stats_lock:
                self.stats.merge(delta)
            return file_ids, offsets, hit

        # one stable argsort groups the batch by shard (contiguous slices);
        # per-shard nonzero scans would cost O(S * n) numpy dispatches
        order = np.argsort(sid, kind="stable")
        uniq, group_starts = np.unique(sid[order], return_index=True)
        # a multi-shard batch takes one cross-shard Bloom pass when the
        # serving posture already pinned the plane (it covers ALL shards,
        # so building it here would force every bitmap resident on a
        # store that promised O(touched shards)); otherwise each touched
        # shard probes its own lazily-loaded filter
        passed_all = (
            self._bloom_pass(q, sid)
            if len(uniq) > 1 and self._bloom_plane is not None
            else None
        )

        for gi in range(len(uniq)):
            s = int(uniq[gi])
            lo = group_starts[gi]
            hi = group_starts[gi + 1] if gi + 1 < len(uniq) else n
            sel = order[lo:hi]
            if passed_all is not None:
                passed = passed_all[sel]
            else:
                passed = self._bloom(s).contains(q[sel])
            delta.bloom_rejects += int(len(sel) - passed.sum())
            sel = sel[passed]
            if not len(sel):
                continue
            shard = self._shard(s)
            delta.shards_touched.add(s)
            qd = q[sel]
            td = shard.digests
            delta.digest_probes += int(len(sel))
            if probe == "device":
                found, starts = _probe_starts_device(td, qd)
            else:
                starts = np.searchsorted(td, qd, side="left")
                inb = starts < len(td)
                found = np.zeros(len(qd), dtype=bool)
                found[inb] = td[starts[inb]] == qd[inb]
            delta.bloom_false_positives += int((~found).sum())
            for j in np.nonzero(found)[0]:
                row = int(sel[j])
                kb = keys[row].encode()
                t = int(starts[j])
                while t < len(td) and td[t] == qd[j]:
                    if shard.keys[t] == kb:
                        file_ids[row] = shard.file_ids[t]
                        offsets[row] = shard.offsets[t]
                        hit[row] = True
                        break
                    delta.verify_collisions += 1  # digest collision
                    t += 1

        delta.hits = int(hit.sum())
        with self._stats_lock:
            self.stats.merge(delta)
        return file_ids, offsets, hit

    def _lookup_plane(
        self,
        keys: Sequence[str],
        q: np.ndarray,
        sid: np.ndarray,
        file_ids: np.ndarray,
        offsets: np.ndarray,
        hit: np.ndarray,
        delta: "QueryStats",
    ) -> None:
        """Batch probe against the pinned serving plane.

        Identical results to the per-shard loop: same Bloom decisions,
        same leftmost-of-run starts (the plane is the shard columns
        concatenated in shard order, globally sorted), same full-key
        verify discipline.  The verify itself is vectorized: candidate
        key bytes gather through ONE fancy-index per touched shard and
        compare in bulk; only candidates that fail that first compare
        (digest collisions — rare by construction) fall back to the
        scalar run scan.  Equal digests share top bits, so a run never
        crosses a shard boundary.
        """
        d_all, row_off, f_all, o_all = self._digest_plane
        passed = self._bloom_pass(q, sid)
        delta.bloom_rejects += int(len(q) - passed.sum())
        sel = np.nonzero(passed)[0]
        if not len(sel):
            return
        delta.digest_probes += int(len(sel))
        # same "touched" accounting as the per-shard loop: every shard
        # with a Bloom-passing key counts, found or not (physically the
        # plane answers non-hits without faulting shard columns, but the
        # stats contract mirrors the loop so the paths stay comparable)
        delta.shards_touched.update(
            int(s) for s in np.unique(sid[sel])
        )
        qd = q[sel]
        starts = np.searchsorted(d_all, qd, side="left")
        inb = starts < len(d_all)
        found = np.zeros(len(sel), dtype=bool)
        found[inb] = d_all[starts[inb]] == qd[inb]
        delta.bloom_false_positives += int((~found).sum())
        fj = np.nonzero(found)[0]
        if not len(fj):
            return
        frow = sel[fj]                  # batch rows with a digest hit
        fpos = starts[fj]               # global plane positions (run heads)
        fshard = (
            np.searchsorted(row_off, fpos, side="right") - 1
        ).astype(np.int64)
        expected = np.array([keys[r].encode() for r in frow], dtype=np.bytes_)
        ok = np.zeros(len(fj), dtype=bool)
        for s in np.unique(fshard):
            s = int(s)
            g = np.nonzero(fshard == s)[0]
            cand = self._shard(s).keys[fpos[g] - row_off[s]]  # one gather
            ok[g] = cand == expected[g]
        hrows = frow[ok]
        file_ids[hrows] = f_all[fpos[ok]]
        offsets[hrows] = o_all[fpos[ok]]
        hit[hrows] = True
        # First candidate mismatched: walk the equal-digest run (the
        # Algorithm 3 collision discipline, scalar because it is rare).
        for j in np.nonzero(~ok)[0]:
            row = int(frow[j])
            s = int(fshard[j])
            shard = self._shard(s)
            base = int(row_off[s])
            end = int(row_off[s + 1])
            kb = expected[j]
            qdj = q[row]
            t = int(fpos[j])
            while t < end and d_all[t] == qdj:
                if shard.keys[t - base] == kb:
                    file_ids[row] = f_all[t]
                    offsets[row] = o_all[t]
                    hit[row] = True
                    break
                delta.verify_collisions += 1  # digest collision
                t += 1

    # -- similarity modality ---------------------------------------------------

    def fp_words(self) -> int:
        """uint32 words per fingerprint row (raises without a plane)."""
        if self.fingerprint_bits is None:
            raise ValueError("store has no fingerprint plane")
        return words_for(self.fingerprint_bits)

    def _check_fps(self, fps: np.ndarray) -> np.ndarray:
        fps = np.ascontiguousarray(fps, dtype=np.uint32)
        if fps.ndim == 1:
            fps = fps[None, :]
        if fps.ndim != 2 or fps.shape[1] != self.fp_words():
            raise ValueError(
                f"query fingerprints must be (Q, {self.fp_words()}) uint32 "
                f"(fingerprint_bits={self.fingerprint_bits}), got {fps.shape}"
            )
        return fps

    @staticmethod
    def _similar_probe(probe: Optional[str]) -> str:
        if probe is None or probe == "auto":
            return "device" if _tpu_backend_active() else "host"
        if probe not in ("host", "device"):
            raise ValueError(f"unknown probe backend {probe!r}")
        return probe

    def _similar_shard(
        self,
        s: int,
        fps: np.ndarray,
        k: int,
        probe: str,
        q_counts: np.ndarray,
        delta: QueryStats,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Top-k of one shard's plane, rows mapped to ``(file_id, offset)``.

        Within a shard ties break by row index (ascending digest order) —
        the kernel/reference contract — which the cross-shard merge then
        re-breaks on ``(file_id, offset)``; see :func:`merge_similar_topk`.
        """
        qn = fps.shape[0]
        count = int(self.manifest["shards"][s]["count"])
        if count == 0:
            return (
                np.full((qn, k), -1.0, dtype=np.float32),
                np.full((qn, k), -1, dtype=np.int32),
                np.full((qn, k), -1, dtype=np.int64),
            )
        db, dc = self._fp_shard(s)
        delta.shards_touched.add(s)
        delta.fp_rows_scanned += count * qn
        if probe == "device":
            from repro.kernels.tanimoto.ops import tanimoto_topk

            scores, rows = tanimoto_topk(
                fps, np.asarray(db), k,
                q_counts=q_counts, db_counts=np.asarray(dc), use_pallas=True,
            )
        else:
            from repro.kernels.tanimoto.ops import tanimoto_topk_host

            scores, rows = tanimoto_topk_host(
                fps, db, k, q_counts=q_counts, db_counts=dc
            )
        shard = self._shard(s)
        valid = rows >= 0
        r = np.where(valid, rows, 0)
        fids = np.where(
            valid, np.asarray(shard.file_ids)[r], np.int32(-1)
        ).astype(np.int32, copy=False)
        offs = np.where(
            valid, np.asarray(shard.offsets)[r], np.int64(-1)
        ).astype(np.int64, copy=False)
        return scores, fids, offs

    def similar_shard(
        self,
        s: int,
        fps: np.ndarray,
        k: int,
        probe: Optional[str] = None,
        q_counts: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """One shard's ``(scores, file_ids, offsets)`` top-k (router scatter)."""
        fps = self._check_fps(fps)
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        if not 0 <= s < self.n_shards:
            raise ValueError(f"shard {s} out of range [0, {self.n_shards})")
        qc = (
            popcount_u32(fps).sum(axis=1, dtype=np.int32)
            if q_counts is None else np.asarray(q_counts, dtype=np.int32)
        )
        delta = QueryStats()
        out = self._similar_shard(
            s, fps, k, self._similar_probe(probe), qc, delta
        )
        with self._stats_lock:
            self.stats.merge(delta)
        return out

    def similar_batch(
        self,
        fps: np.ndarray,
        k: int,
        probe: Optional[str] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched Tanimoto top-k over every shard's fingerprint plane.

        ``fps`` is ``(Q, W)`` uint32 (one packed query fingerprint per
        row, e.g. :func:`repro.core.fingerprint.fold_fingerprint` output);
        returns ``(scores (Q, k) float32, file_ids (Q, k) int32, offsets
        (Q, k) int64)`` ordered by ``(score desc, file_id asc, offset
        asc)``, padded with ``-1`` columns when the corpus holds fewer
        than ``k`` rows.  ``probe`` selects the scoring backend exactly
        like :meth:`lookup_batch`: ``"device"`` (Pallas kernel),
        ``"host"`` (vectorized NumPy reference — byte-identical), or
        ``None``/"auto".  Thread-safe like ``lookup_batch``.
        """
        fps = self._check_fps(fps)
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        probe = self._similar_probe(probe)
        qn = fps.shape[0]
        delta = QueryStats(similar_queries=qn)
        if qn == 0:
            with self._stats_lock:
                self.stats.merge(delta)
            e = np.zeros((0, k))
            return (
                e.astype(np.float32),
                e.astype(np.int32),
                e.astype(np.int64),
            )
        qc = popcount_u32(fps).sum(axis=1, dtype=np.int32)
        parts = [
            self._similar_shard(s, fps, k, probe, qc, delta)
            for s in range(self.n_shards)
            if int(self.manifest["shards"][s]["count"]) > 0
        ]
        if not parts:
            out = (
                np.full((qn, k), -1.0, dtype=np.float32),
                np.full((qn, k), -1, dtype=np.int32),
                np.full((qn, k), -1, dtype=np.int64),
            )
        else:
            out = merge_similar_topk(parts, k)
        with self._stats_lock:
            self.stats.merge(delta)
        return out

    # -- ByteOffsetIndex-compatible read surface -------------------------------

    def locate_batch(
        self, keys: Sequence[str], probe: Optional[str] = None
    ) -> List[Optional[Tuple[str, int]]]:
        """String-level convenience over :meth:`lookup_batch`."""
        fid, off, hit = self.lookup_batch(keys, probe=probe)
        return [
            (self.file_names[fid[i]], int(off[i])) if hit[i] else None
            for i in range(len(keys))
        ]

    def lookup(self, key: str) -> Optional[Tuple[str, int]]:
        return self.locate_batch([key])[0]

    def __contains__(self, key: str) -> bool:
        return self.lookup_batch([key])[2][0]

    def __len__(self) -> int:
        return int(self.manifest["n_entries"])

    def iter_keys(self) -> Iterator[str]:
        """All keys, shard by shard (loads every shard — builder-side use)."""
        for s in range(self.n_shards):
            for kb in self._shard(s).keys:
                yield kb.decode()

    # -- capacity accounting (benchmarks) -------------------------------------

    @property
    def shards_loaded(self) -> int:
        return len(self._shards)

    def total_bytes(self) -> int:
        """Persistent footprint: every store file on disk."""
        return sum(
            p.stat().st_size
            for p in self.root.iterdir()
            if p.name == MANIFEST_NAME or p.name.startswith("shard_")
        )

    def resident_bytes(self) -> int:
        """Bytes of shard columns + Bloom bitmaps actually faulted in.

        With mmap this is an upper bound (pages of touched shards); the
        point of comparison is against the dict index, which is *all*
        resident *always*.
        """
        return (
            sum(sh.nbytes for sh in self._shards.values())
            + sum(bf.nbytes for bf in self._blooms.values())
            + sum(
                int(fp.nbytes) + int(fc.nbytes)
                for fp, fc in self._fp_shards.values()
            )
        )


def _tpu_backend_active() -> bool:
    """True only when JAX is ALREADY imported and its backend is TPU.

    Deliberately never imports jax: a host-side lookup must not pay a
    multi-second framework import just to learn there is no accelerator.
    """
    import sys

    jax = sys.modules.get("jax")
    if jax is None:
        return False
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - defensive
        return False


def _probe_starts_device(
    table_digests: np.ndarray, query_digests: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Device digest probe: ``sorted_probe`` over (hi, lo) uint32 pairs.

    Returns ``(found, starts)`` with ``starts`` the leftmost equal-digest
    position — identical contract to the host ``searchsorted`` path, so the
    equal-run verify loop above is backend-agnostic.
    """
    import jax.numpy as jnp

    from repro.kernels.sorted_probe.ops import sorted_probe

    td = np.ascontiguousarray(table_digests)
    found, pos = sorted_probe(
        jnp.asarray(_u64_to_pairs(query_digests)),
        jnp.asarray(_u64_to_pairs(td)),
    )
    found = np.asarray(found, dtype=bool)
    starts = np.asarray(pos, dtype=np.int64)
    # The Pallas kernel's fence partitioning assumes a unique table; shard
    # digest columns carry collision runs, and a run straddling a table
    # block gives a within-block (not global-leftmost) position.  Rewind to
    # the run head so the forward verify scan sees every candidate.
    for j in np.nonzero(found)[0]:
        t = int(starts[j])
        while t > 0 and td[t - 1] == query_digests[j]:
            t -= 1
        starts[j] = t
    return found, starts
