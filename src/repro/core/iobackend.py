"""Span I/O backends: how coalesced spans become bytes in memory.

The extraction engine (:mod:`repro.core.reader`) plans *what* to read —
coalesced ``[start, end)`` spans per file — and delegates *how* to a
:class:`SpanBackend`.  Three backends ship, selected by
``REPRO_READER_BACKEND`` (see :mod:`repro.flags`) or per call:

``uring``
    Raw ``io_uring`` submission/completion rings driven through
    ``ctypes`` syscalls (no liburing dependency).  Spans are submitted as
    ``IORING_OP_READ`` SQEs in a depth-controlled window
    (``REPRO_READER_DEPTH`` in-flight spans), completions are reaped as
    they land, so one slow span never stalls the rest of the window.
    One ring per worker thread, owned by the backend instance and closed
    with it.  Linux only; availability is probed once per process.

``thread``
    ``os.preadv`` into a freshly allocated ``bytearray`` per span — the
    portable fallback.  Parallelism comes from the engine's file-worker
    fan-out (``pread`` releases the GIL); the submission window within a
    file is effectively 1.

``mmap``
    The whole file is mapped once (``PROT_READ``) and every span is a
    window into the mapping — no read syscalls at all, page faults do
    the I/O.  Fastest on page-cached corpora; record views pin the
    mapping until they are decoded (see below), and a file truncated
    under a live mapping can SIGBUS, so this backend is opt-in rather
    than the ``auto`` default.

``auto`` resolves to ``uring`` where the kernel supports it, else
``thread``.

Zero-copy lifecycle
-------------------
Every backend yields :class:`SpanBuffer`\\ s — a retained ``bytearray``
(or the file mapping) plus its absolute base offset.  The engine carves
records out as :class:`RecordView`\\ s: ``(buffer, start, stop)`` triples
whose bytes are only ever touched through ``memoryview`` slices.  No
``bytes`` copy of a record exists anywhere in the pipeline; the single
materialization is the lazy UTF-8 decode at the API boundary
(:attr:`RecordView.text`), which memoizes the string and *drops the
buffer reference* so verified-and-delivered records stop pinning their
span buffer (and, for ``mmap``, the mapping).

Tail extension (a record overrunning its provisional span) appends to
the span's ``bytearray`` and therefore must finish before any view is
exported — ``bytearray`` forbids resizing with live exports.  The engine
orders its work accordingly; :meth:`SpanBuffer.view` enforces it.
"""

from __future__ import annotations

import ctypes
import mmap
import os
import struct
import sys
import threading
from collections import deque
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

from repro import flags

__all__ = [
    "RecordView",
    "SpanBuffer",
    "SpanBackend",
    "MmapBackend",
    "ThreadBackend",
    "UringBackend",
    "resolve_backend",
    "uring_available",
]

_MAX_EXTEND = 1 << 20  # tail-extension reads cap at 1 MiB per pread


class SpanBuffer:
    """One span's retained bytes: a ``bytearray`` or an ``mmap`` window.

    ``base`` is the absolute file offset of ``raw[0]``; ``fsize`` the
    file size at open, so :attr:`at_eof` tells the record splitter
    whether a missing delimiter is final or the buffer just ended early.
    """

    __slots__ = ("raw", "base", "fsize", "_mv")

    def __init__(self, raw, base: int, fsize: int):
        self.raw = raw
        self.base = base
        self.fsize = fsize
        self._mv: Optional[memoryview] = None

    @property
    def at_eof(self) -> bool:
        return self.base + len(self.raw) >= self.fsize

    def view(self) -> memoryview:
        """The shared memoryview over ``raw`` (created once, lazily).

        First call freezes the buffer: a ``bytearray`` with an exported
        view cannot be resized, so all tail extensions must happen
        before any record view is carved out.
        """
        mv = self._mv
        if mv is None:
            mv = self._mv = memoryview(self.raw)
        return mv

    @property
    def extendable(self) -> bool:
        return self._mv is None and isinstance(self.raw, bytearray)


class RecordView:
    """A record as a zero-copy window ``[start, stop)`` into a span buffer.

    ``text`` decodes lazily (UTF-8, ``replace``) straight from the
    memoryview — no intermediate ``bytes`` — memoizes the result, and
    releases the buffer reference: once a record crosses the API
    boundary it no longer pins its span buffer or file mapping.
    ``raw_range()`` exposes the undecoded bytes to the batched verifier
    (``None`` after the buffer has been released).
    """

    __slots__ = ("_buf", "start", "stop", "_text")

    def __init__(self, buf: SpanBuffer, start: int, stop: int):
        self._buf = buf
        self.start = start
        self.stop = stop
        self._text: Optional[str] = None

    def __len__(self) -> int:
        return self.stop - self.start

    @property
    def decoded(self) -> bool:
        return self._text is not None

    def raw_range(self) -> Optional[Tuple[object, int, int]]:
        """``(buffer_object, start, stop)`` for in-place byte scans
        (``find`` etc. need the buffer object, not a memoryview)."""
        buf = self._buf
        if buf is None:
            return None
        return buf.raw, self.start, self.stop

    def mem(self) -> Optional[memoryview]:
        buf = self._buf
        if buf is None:
            return None
        return buf.view()[self.start:self.stop]

    def slice_mem(self, a: int, b: int) -> memoryview:
        """Zero-copy window at *absolute buffer* coordinates (the batched
        verifier works in ``raw_range()`` coordinates)."""
        return self._buf.view()[a:b]

    @property
    def text(self) -> str:
        t = self._text
        if t is None:
            buf = self._buf
            t = str(buf.view()[self.start:self.stop], "utf-8", "replace")
            self._text = t
            self._buf = None  # decode boundary: stop pinning the buffer
        return t


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

class SpanBackend:
    """How coalesced spans become :class:`SpanBuffer`\\ s.

    Instances are owned: the engine builds one per ``stream_plan`` call
    (or borrows a long-lived one from the service) and ``close()``\\ s it
    when owned.  All methods are thread-safe across file workers.
    """

    name = "?"

    def open(self, path) -> Tuple:
        fd = os.open(path, os.O_RDONLY)
        try:
            fsize = os.fstat(fd).st_size
        except OSError:
            os.close(fd)
            raise
        return (fd, fsize)

    def size(self, handle) -> int:
        return handle[1]

    def close_handle(self, handle) -> None:
        os.close(handle[0])

    def read_spans(self, handle, spans, stats, depth: int
                   ) -> Iterator[Tuple[object, SpanBuffer]]:
        raise NotImplementedError

    def extend(self, handle, buf: SpanBuffer, guess: int, stats) -> bool:
        """Grow ``buf``'s tail; False when the file is exhausted."""
        if not buf.extendable:
            return False
        fd = handle[0]
        step = min(max(guess, len(buf.raw)), _MAX_EXTEND)
        extra = os.pread(fd, step, buf.base + len(buf.raw))
        if not extra:
            return False
        stats.spans_read += 1
        stats.bytes_read += len(extra)
        buf.raw += extra
        return True

    def close(self) -> None:
        pass


class ThreadBackend(SpanBackend):
    """Portable fallback: one blocking ``preadv`` per span into a
    retained ``bytearray``.  Concurrency comes from the engine's file
    fan-out (``preadv`` releases the GIL)."""

    name = "thread"

    def read_spans(self, handle, spans, stats, depth: int):
        fd, fsize = handle
        for span in spans:
            length = max(0, span.end - span.start)
            buf = bytearray(length)
            if length:
                got = os.preadv(fd, [buf], span.start)
                if got < length:
                    del buf[got:]
            stats.spans_read += 1
            stats.bytes_read += len(buf)
            stats.inflight_peak = max(stats.inflight_peak, 1)
            yield span, SpanBuffer(buf, span.start, fsize)


class MmapBackend(SpanBackend):
    """Whole-file ``mmap``: spans are windows, reads are page faults.

    The fd is closed immediately after mapping (the mapping survives);
    the mapping itself is released when the last undecoded
    :class:`RecordView` lets go.  ``spans_read``/``bytes_read`` account
    the coalesced spans *touched*, to stay comparable with the pread
    backends.  Never needs tail extension — the buffer is the file.
    """

    name = "mmap"

    def open(self, path):
        fd = os.open(path, os.O_RDONLY)
        try:
            fsize = os.fstat(fd).st_size
            mm = mmap.mmap(fd, 0, prot=mmap.PROT_READ) if fsize else b""
        finally:
            os.close(fd)
        return (mm, fsize, SpanBuffer(mm, 0, fsize))

    def size(self, handle) -> int:
        return handle[1]

    def close_handle(self, handle) -> None:
        mm = handle[0]
        if isinstance(mm, mmap.mmap):
            try:
                mm.close()
            except BufferError:
                pass  # live record views pin the mapping; GC unmaps later

    def read_spans(self, handle, spans, stats, depth: int):
        mm, fsize, shared = handle
        # page faults are synchronous 4 KiB reads with no readahead on a
        # seeky mapping — keep a depth-deep madvise(WILLNEED) window ahead
        # of the carve so the kernel pulls upcoming spans in the
        # background, same in-flight discipline as the uring queue
        advise = getattr(mm, "madvise", None) if fsize else None
        willneed = getattr(mmap, "MADV_WILLNEED", None)
        ahead = 0
        for i, span in enumerate(spans):
            if advise is not None and willneed is not None:
                while ahead < len(spans) and ahead - i < depth:
                    sp = spans[ahead]
                    lo = (sp.start // mmap.PAGESIZE) * mmap.PAGESIZE
                    hi = min(sp.end, fsize)
                    if hi > lo:
                        try:
                            advise(willneed, lo, hi - lo)
                        except (OSError, ValueError):  # pragma: no cover
                            advise = None
                            break
                    ahead += 1
                stats.inflight_peak = max(stats.inflight_peak, ahead - i)
            else:
                stats.inflight_peak = max(stats.inflight_peak, 1)
            stats.spans_read += 1
            stats.bytes_read += max(0, min(span.end, fsize) - span.start)
            yield span, shared

    def extend(self, handle, buf, guess, stats) -> bool:
        return False  # the buffer already covers the whole file


# -- io_uring (raw syscalls, no liburing) -----------------------------------

_SYS_IO_URING_SETUP = 425
_SYS_IO_URING_ENTER = 426
_IORING_OFF_SQ_RING = 0
_IORING_OFF_SQES = 0x10000000
_IORING_ENTER_GETEVENTS = 1
_IORING_OP_READ = 22
_FEAT_SINGLE_MMAP = 1


class _UringParams(ctypes.Structure):
    # struct io_uring_params: 8 head fields + sq_off (8 u32 + u64) +
    # cq_off (8 u32 + u64), flattened.
    _fields_ = (
        [("sq_entries", ctypes.c_uint32), ("cq_entries", ctypes.c_uint32),
         ("flags", ctypes.c_uint32), ("sq_thread_cpu", ctypes.c_uint32),
         ("sq_thread_idle", ctypes.c_uint32), ("features", ctypes.c_uint32),
         ("wq_fd", ctypes.c_uint32), ("resv", ctypes.c_uint32 * 3)]
        + [(f"sq_{f}", ctypes.c_uint32) for f in
           ("head", "tail", "ring_mask", "ring_entries", "flags_off",
            "dropped", "array", "resv1")]
        + [("sq_user_addr", ctypes.c_uint64)]
        + [(f"cq_{f}", ctypes.c_uint32) for f in
           ("head", "tail", "ring_mask", "ring_entries", "overflow",
            "cqes", "flags_off", "resv1")]
        + [("cq_user_addr", ctypes.c_uint64)]
    )


_libc = None


def _get_libc():
    global _libc
    if _libc is None:
        _libc = ctypes.CDLL(None, use_errno=True)
    return _libc


class _Ring:
    """One io_uring instance: setup, mmap'd rings, submit/reap."""

    def __init__(self, entries: int):
        libc = _get_libc()
        p = _UringParams()
        fd = libc.syscall(_SYS_IO_URING_SETUP, entries, ctypes.byref(p))
        if fd < 0:
            raise OSError(ctypes.get_errno(), "io_uring_setup failed")
        self.fd = fd
        self.p = p
        try:
            # ring sizes follow liburing: the index-array / cqe-array
            # offset plus the actual entry counts (sq_entries/cq_entries
            # are real counts; the p.sq_*/cq_* ring fields are OFFSETS)
            sq_size = p.sq_array + p.sq_entries * 4
            cq_size = p.cq_cqes + p.cq_entries * 16
            if p.features & _FEAT_SINGLE_MMAP:
                sq_size = cq_size = max(sq_size, cq_size)
            self.sq = mmap.mmap(
                fd, sq_size, flags=mmap.MAP_SHARED,
                prot=mmap.PROT_READ | mmap.PROT_WRITE,
                offset=_IORING_OFF_SQ_RING)
            self.cq = self.sq if p.features & _FEAT_SINGLE_MMAP else mmap.mmap(
                fd, cq_size, flags=mmap.MAP_SHARED,
                prot=mmap.PROT_READ | mmap.PROT_WRITE,
                offset=0x8000000)
            self.sqes = mmap.mmap(
                fd, p.sq_entries * 64, flags=mmap.MAP_SHARED,
                prot=mmap.PROT_READ | mmap.PROT_WRITE,
                offset=_IORING_OFF_SQES)
        except Exception:
            os.close(fd)
            raise
        # The params' sq_*/cq_* fields are byte OFFSETS into the ring
        # mmaps; dereference the actual mask values once.
        self.sq_mask, = struct.unpack_from("<I", self.sq, p.sq_ring_mask)
        self.cq_mask, = struct.unpack_from("<I", self.cq, p.cq_ring_mask)
        self._sqe_idx = 0

    def prep_read(self, fd: int, addr: int, length: int, offset: int,
                  user_data: int) -> None:
        p = self.p
        idx = self._sqe_idx & self.sq_mask
        self._sqe_idx += 1
        # io_uring_sqe head: opcode, flags, ioprio, fd, off, addr, len,
        # rw_flags, user_data (rest of the 64 bytes zeroed)
        sqe = struct.pack("<BBHiQQIIQ", _IORING_OP_READ, 0, 0, fd,
                          offset, addr, length, 0, user_data)
        base = idx * 64
        self.sqes[base:base + len(sqe)] = sqe
        self.sqes[base + len(sqe):base + 64] = b"\0" * (64 - len(sqe))
        struct.pack_into("<I", self.sq, p.sq_array + idx * 4, idx)
        tail, = struct.unpack_from("<I", self.sq, p.sq_tail)
        struct.pack_into("<I", self.sq, p.sq_tail, tail + 1)

    def enter(self, to_submit: int, min_complete: int) -> None:
        libc = _get_libc()
        flags_ = _IORING_ENTER_GETEVENTS if min_complete else 0
        r = libc.syscall(_SYS_IO_URING_ENTER, self.fd, to_submit,
                         min_complete, flags_, 0, 0)
        if r < 0:
            err = ctypes.get_errno()
            if err == 4:  # EINTR: retry the wait (submissions consumed)
                return self.enter(0, min_complete)
            raise OSError(err, "io_uring_enter failed")

    def reap(self) -> List[Tuple[int, int]]:
        p = self.p
        head, = struct.unpack_from("<I", self.cq, p.cq_head)
        tail, = struct.unpack_from("<I", self.cq, p.cq_tail)
        out = []
        while head != tail:
            off = p.cq_cqes + (head & self.cq_mask) * 16
            user_data, res = struct.unpack_from("<Qi", self.cq, off)
            out.append((user_data, res))
            head += 1
        struct.pack_into("<I", self.cq, p.cq_head, head)
        return out

    def close(self) -> None:
        if self.fd >= 0:
            for m in {id(self.sq): self.sq, id(self.cq): self.cq,
                      id(self.sqes): self.sqes}.values():
                try:
                    m.close()
                except BufferError:  # pragma: no cover - defensive
                    pass
            os.close(self.fd)
            self.fd = -1


_URING_OK: Optional[bool] = None
_URING_PROBE_LOCK = threading.Lock()


def uring_available() -> bool:
    """Probe (once per process) whether io_uring setup+read works here —
    kernels and seccomp policies that expose the syscalls partially are
    common enough that only a full round trip counts."""
    global _URING_OK
    if _URING_OK is None:
        with _URING_PROBE_LOCK:
            if _URING_OK is None:
                _URING_OK = _probe_uring()
    return _URING_OK


def _probe_uring() -> bool:
    if not sys.platform.startswith("linux"):
        return False
    try:
        ring = _Ring(4)
    except OSError:
        return False
    try:
        buf = bytearray(16)
        cb = (ctypes.c_char * 16).from_buffer(buf)
        fd = os.open("/proc/self/cmdline", os.O_RDONLY)
        try:
            ring.prep_read(fd, ctypes.addressof(cb), 16, 0, 7)
            ring.enter(1, 1)
            done = ring.reap()
        finally:
            os.close(fd)
        del cb
        return len(done) == 1 and done[0][0] == 7 and done[0][1] >= 0
    except OSError:
        return False
    finally:
        ring.close()


class UringBackend(SpanBackend):
    """io_uring span submission with a depth-controlled in-flight window.

    Up to ``depth`` spans per file worker sit in the kernel at once;
    completions yield in arrival order, so the record splitter starts on
    whichever span lands first.  Short reads resubmit the remainder at
    the completed offset.  One ring per worker thread, lazily built and
    owned by this backend instance — ``close()`` (or the owning
    service/engine teardown) closes every ring fd.
    """

    name = "uring"

    def __init__(self):
        self._rings: Dict[int, _Ring] = {}
        self._lock = threading.Lock()
        self._closed = False

    def _ring(self, depth: int) -> _Ring:
        tid = threading.get_ident()
        ring = self._rings.get(tid)
        if ring is None:
            entries = 8
            while entries < depth:
                entries <<= 1
            ring = _Ring(min(entries, 1024))
            with self._lock:
                if self._closed:
                    ring.close()
                    raise RuntimeError("backend closed")
                self._rings[tid] = ring
        return ring

    def read_spans(self, handle, spans, stats, depth: int):
        fd, fsize = handle
        depth = max(1, depth)
        ring = self._ring(depth)
        depth = min(depth, ring.p.sq_entries)
        # user_data -> [span, bytearray, ctypes_export, bytes_got]
        pending: Dict[int, list] = {}
        ready: deque = deque()  # reaped, not yet processed
        it = iter(spans)
        next_ud = 0
        exhausted = False
        try:
            while True:
                submitted = 0
                while not exhausted and len(pending) < depth:
                    span = next(it, None)
                    if span is None:
                        exhausted = True
                        break
                    length = max(0, span.end - span.start)
                    if length == 0:
                        stats.spans_read += 1
                        yield span, SpanBuffer(bytearray(), span.start, fsize)
                        continue
                    buf = bytearray(length)
                    # single-byte export: pins the buffer exactly like a
                    # full-length array would, but skips the per-length
                    # ctypes array-class construction (~6 µs/span)
                    cb = ctypes.c_char.from_buffer(buf)
                    ring.prep_read(fd, ctypes.addressof(cb), length,
                                   span.start, next_ud)
                    pending[next_ud] = [span, buf, cb, 0]
                    # pending[ud] must hold the ONLY export reference: a
                    # lingering local would block the bytearray resizes
                    # below (and the consumer's tail extensions)
                    del cb
                    next_ud += 1
                    submitted += 1
                if not pending and not ready:
                    return
                stats.inflight_peak = max(stats.inflight_peak, len(pending))
                if submitted or not ready:
                    ring.enter(submitted, 0 if ready else 1)
                ready.extend(ring.reap())
                while ready:
                    # popped BEFORE processing: an exception (or an
                    # abandoning consumer) mid-batch must not leave
                    # already-completed uds in pending for the drain
                    ud, res = ready.popleft()
                    ent = pending[ud]
                    if res < 0:
                        del pending[ud]
                        ent[2] = None
                        raise OSError(-res, os.strerror(-res))
                    ent[3] += res
                    span, buf = ent[0], ent[1]
                    got, want = ent[3], len(buf) - ent[3]
                    if res == 0 or want <= 0 or span.start + got >= fsize:
                        del pending[ud]
                        ent[2] = None  # release export before any resize
                        if got < len(buf):
                            del buf[got:]
                        stats.spans_read += 1
                        stats.bytes_read += got
                        yield span, SpanBuffer(buf, span.start, fsize)
                    else:  # short read mid-file: resubmit the remainder
                        cb = ctypes.c_char.from_buffer(buf)
                        ring.prep_read(fd, ctypes.addressof(cb) + got, want,
                                       span.start + got, ud)
                        ent[2] = cb
                        del cb
                        ring.enter(1, 0)
        finally:
            # An abandoned generator must not leave the kernel writing
            # into buffers we are about to free: discard completions
            # already reaped, then drain every span still in flight
            # (regular-file reads complete promptly).
            for ud, _res in ready:
                ent = pending.pop(ud, None)
                if ent is not None:
                    ent[2] = None
            while pending:
                ring.enter(0, 1)
                for ud, _res in ring.reap():
                    ent = pending.pop(ud, None)
                    if ent is not None:
                        ent[2] = None

    def close(self) -> None:
        with self._lock:
            self._closed = True
            rings, self._rings = self._rings, {}
        for ring in rings.values():
            ring.close()


# ---------------------------------------------------------------------------
# Selection
# ---------------------------------------------------------------------------

_BACKENDS = {
    "thread": ThreadBackend,
    "mmap": MmapBackend,
    "uring": UringBackend,
}


def resolve_backend(name: Optional[str] = None) -> SpanBackend:
    """Build a backend instance from a name (or the env default).

    ``None``/``"auto"`` reads ``REPRO_READER_BACKEND`` and falls through
    to ``uring`` where the probe passes, else ``thread``.  The caller
    owns the returned instance (``close()`` it — io_uring rings hold
    fds).
    """
    if name is None or name == "auto":
        name = flags.reader_backend()
    if name == "auto":
        name = "uring" if uring_available() else "thread"
    try:
        cls = _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown reader backend {name!r} "
            f"(choose from auto/{'/'.join(sorted(_BACKENDS))})"
        ) from None
    return cls()
