"""Fingerprint plane: packed bit-matrix sidecars for similarity search.

The byte-offset index answers *exact-key* lookups; the second query
modality the related work points at (Medina & White's molecular Bloom
filters, Vaskin et al.'s substructure prefilters) is *similarity*: screen
millions of fixed-width molecular fingerprints with a bitwise Tanimoto
coefficient and keep the top-k.  This module is the build-time half of
that plane:

* a **deterministic folded fingerprint** per record — character-shingle
  features of the record's canonical identifier text, each hashed with
  the splitmix64 remix the Bloom sidecars already use and folded into a
  fixed ``FP_BITS``-wide bit vector (the classic hashed-fingerprint
  construction: feature multiplicity is discarded, only presence folds
  in).  Pure function of the text, so any worker can regenerate any
  fingerprint and a republished shard's plane is byte-stable;
* the **packed layout** the Pallas kernel consumes: ``(N, W)`` uint32
  words per shard (``W = FP_BITS / 32``), row order identical to the
  shard's digest-sorted data columns, plus a precomputed per-row
  popcount column so the kernel's union term ``|q| + |d| - |q & d|``
  never re-counts the database side.

Fingerprints are *screens*, not identity: equal fingerprints do not mean
equal records (fold collisions are by design), which is exactly why the
serving contract returns scored candidates instead of asserting matches
— the byte-offset columns behind each hit remain the ground truth.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

__all__ = [
    "DEFAULT_FP_BITS",
    "FP_WORD_BITS",
    "fingerprint_batch",
    "fold_fingerprint",
    "popcount_u32",
    "words_for",
]

DEFAULT_FP_BITS = 1024  # 32 uint32 words/row: VMEM-friendly, ~0.5% dense text
FP_WORD_BITS = 32
_SHINGLE = 3            # character trigrams: the text-feature shingle width

# splitmix64 finalizer (same public-domain mixer the Bloom sidecars use);
# duplicated rather than imported so this module stays dependency-free.
_SM_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_SM_MUL1 = np.uint64(0xBF58476D1CE4E5B9)
_SM_MUL2 = np.uint64(0x94D049BB133111EB)

# per-plane salt folded into every shingle hash: bump to rev the format
_FP_SALT = np.uint64(0xF1A9_0B5E_7C3D_2001)

_POP_LUT = np.array([bin(i).count("1") for i in range(256)], dtype=np.uint8)


def _mix64(x: np.ndarray) -> np.ndarray:
    z = x + _SM_GAMMA
    z = (z ^ (z >> np.uint64(30))) * _SM_MUL1
    z = (z ^ (z >> np.uint64(27))) * _SM_MUL2
    return z ^ (z >> np.uint64(31))


def words_for(bits: int) -> int:
    """uint32 words per fingerprint row; ``bits`` must pack evenly."""
    if bits < FP_WORD_BITS or bits % FP_WORD_BITS:
        raise ValueError(
            f"fingerprint bits must be a positive multiple of "
            f"{FP_WORD_BITS}, got {bits}"
        )
    if bits & (bits - 1):
        # power of two keeps the fold a mask (and shard planes uniform)
        raise ValueError(f"fingerprint bits must be a power of two, got {bits}")
    return bits // FP_WORD_BITS


def popcount_u32(a: np.ndarray) -> np.ndarray:
    """Per-element 1-bit count of a uint32 array, as int32.

    ``np.bitwise_count`` (numpy >= 2) when present, else one gather
    through a 256-entry byte LUT — both exact, both vectorized.
    """
    a = np.ascontiguousarray(a, dtype=np.uint32)
    if hasattr(np, "bitwise_count"):
        return np.bitwise_count(a).astype(np.int32)
    b = _POP_LUT[a.view(np.uint8)].reshape(*a.shape, 4)
    return b.sum(axis=-1, dtype=np.int32)


def _shingle_positions(text: str, bits: int) -> np.ndarray:
    """Folded bit positions of every length-3 byte shingle of ``text``."""
    raw = text.encode("utf-8")
    if len(raw) < _SHINGLE:
        raw = raw + b"\x00" * (_SHINGLE - len(raw))
    b = np.frombuffer(raw, dtype=np.uint8).astype(np.uint64)
    codes = (
        (b[:-2] << np.uint64(16)) | (b[1:-1] << np.uint64(8)) | b[2:]
    ) ^ _FP_SALT
    return (_mix64(codes) & np.uint64(bits - 1)).astype(np.int64)


def fold_fingerprint(text: str, bits: int = DEFAULT_FP_BITS) -> np.ndarray:
    """One packed fingerprint row: ``(W,)`` uint32, deterministic in ``text``."""
    w = words_for(bits)
    row = np.zeros(w, dtype=np.uint32)
    pos = _shingle_positions(text, bits)
    np.bitwise_or.at(
        row,
        pos >> np.int64(5),
        np.uint32(1) << (pos & np.int64(31)).astype(np.uint32),
    )
    return row


def fingerprint_batch(
    texts: Sequence[str], bits: int = DEFAULT_FP_BITS
) -> Tuple[np.ndarray, np.ndarray]:
    """Fingerprint a batch: ``(fps (N, W) uint32, popcounts (N,) int32)``.

    One vectorized fold pass over the concatenation of all shingles —
    per-row Python work is a slice bookkeeping loop, not hashing.
    """
    w = words_for(bits)
    n = len(texts)
    fps = np.zeros((n, w), dtype=np.uint32)
    if n:
        per_row: List[np.ndarray] = [_shingle_positions(t, bits) for t in texts]
        pos = np.concatenate(per_row)
        rows = np.repeat(
            np.arange(n, dtype=np.int64),
            np.fromiter((len(p) for p in per_row), np.int64, count=n),
        )
        flat = rows * w + (pos >> np.int64(5))
        np.bitwise_or.at(
            fps.reshape(-1),
            flat,
            np.uint32(1) << (pos & np.int64(31)).astype(np.uint32),
        )
    counts = popcount_u32(fps).sum(axis=1, dtype=np.int32) if n else \
        np.zeros(0, dtype=np.int32)
    return fps, counts
