"""Byte-offset index construction and persistence (Algorithm 2, §IV).

Phase 1 of the paper's architecture: a one-time O(M×S) scan of every record
file builds a persistent ``key → (file, byte_offset)`` map.  The index is
the contract between the data plane and everything above it — extraction
(Algorithm 3), the training data loader, and the checkpoint catalog all
address records through it.

Two key modes reproduce the paper's §VI migration:

* ``key_mode="hashed_key"`` — index keyed by the 27-char digest
  (InChIKey role): smaller and faster, but collision-prone at scale.
* ``key_mode="full_id"``    — index keyed by the full canonical id
  (full-InChI role): deterministic uniqueness, +~27 % storage (Table IV).

Persistence is CSV (paper-faithful: ``identifier,filename,byte_offset``,
human-readable, ~15 % overhead vs binary — §IV.B) plus an optional binary
sidecar (beyond-paper: packed uint64 digests + offsets for O(1) mmap load
into the TPU-friendly sorted-probe path).
"""

from __future__ import annotations

import csv
import hashlib
import multiprocessing as mp
import os
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .identifiers import hashed_key
from .records import RecordStore, extract_property, iter_records
from .sdfgen import PROP_ID, PROP_KEY

__all__ = [
    "ByteOffsetIndex",
    "IndexStats",
    "build_index",
    "scan_file_for_index",
]

_CSV_HEADER = ["identifier", "filename", "byte_offset"]


@dataclass
class IndexStats:
    n_entries: int = 0
    n_files: int = 0
    n_duplicate_keys: int = 0          # same key seen again (collision signal)
    build_seconds: float = 0.0
    bytes_scanned: int = 0


class ByteOffsetIndex:
    """Persistent map ``identifier → (file_name, byte_offset)``.

    Duplicate keys (distinct records hashing to the same key — the paper's
    InChIKey collisions) are *retained*: the primary map keeps the first
    location (matching the paper's index behaviour, where a collision
    silently shadows a record until verification exposes it) and
    ``shadowed`` keeps every additional location so the collision scanner
    can enumerate them without a second corpus pass.
    """

    def __init__(self, key_mode: str = "full_id"):
        if key_mode not in ("full_id", "hashed_key"):
            raise ValueError(f"bad key_mode {key_mode!r}")
        self.key_mode = key_mode
        self.entries: Dict[str, Tuple[str, int]] = {}
        self.shadowed: Dict[str, List[Tuple[str, int]]] = {}
        self.stats = IndexStats()

    # -- construction -----------------------------------------------------

    def add(self, key: str, file_name: str, offset: int) -> None:
        if key in self.entries:
            self.shadowed.setdefault(key, []).append((file_name, offset))
            self.stats.n_duplicate_keys += 1
        else:
            self.entries[key] = (file_name, offset)

    def merge(self, other: "ByteOffsetIndex") -> None:
        """Dictionary-union merge of a worker's partial index (Alg. 2 l.15-17)."""
        for k, loc in other.entries.items():
            self.add(k, *loc)
        for k, locs in other.shadowed.items():
            for loc in locs:
                self.shadowed.setdefault(k, []).append(loc)
                self.stats.n_duplicate_keys += 1

    # -- queries ----------------------------------------------------------

    def lookup(self, key: str) -> Optional[Tuple[str, int]]:
        return self.entries.get(key)

    def locate_batch(
        self, keys: Sequence[str]
    ) -> List[Optional[Tuple[str, int]]]:
        """Batched lookup — the read contract shared with ``IndexStore``.

        Consumers (extraction planning, the data pipeline) call this once
        per batch instead of ``lookup`` per key, so swapping the dict for
        the sharded mmap store changes nothing above the call site.
        """
        return [self.entries.get(k) for k in keys]

    def iter_keys(self) -> Iterable[str]:
        """Key enumeration shared by every index backend."""
        return iter(self.entries.keys())

    def __len__(self) -> int:
        return len(self.entries)

    def __contains__(self, key: str) -> bool:
        return key in self.entries

    # -- persistence (paper-faithful CSV) -----------------------------------

    def save_csv(self, path: Path) -> int:
        """Write ``identifier,filename,byte_offset`` rows; returns file size."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(path.suffix + ".tmp")
        with open(tmp, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(_CSV_HEADER)
            for key, (fname, off) in self.entries.items():
                w.writerow([key, fname, off])
            for key, locs in self.shadowed.items():
                for fname, off in locs:
                    w.writerow([key, fname, off])
        os.replace(tmp, path)  # atomic publish
        return path.stat().st_size

    @classmethod
    def load_csv(cls, path: Path, key_mode: str = "full_id") -> "ByteOffsetIndex":
        idx = cls(key_mode=key_mode)
        with open(path, newline="") as f:
            r = csv.reader(f)
            header = next(r)
            if header != _CSV_HEADER:
                raise ValueError(f"unexpected index header {header!r}")
            for key, fname, off in r:
                idx.add(key, fname, int(off))
        idx.stats.n_entries = len(idx)
        return idx

    # -- incremental updates (paper §VIII future work, implemented) ----------

    def drop_file(self, file_name: str) -> int:
        """Remove every entry that points into ``file_name``."""
        doomed = [k for k, (f, _) in self.entries.items() if f == file_name]
        for k in doomed:
            del self.entries[k]
        for k in list(self.shadowed):
            self.shadowed[k] = [
                loc for loc in self.shadowed[k] if loc[0] != file_name
            ]
            if not self.shadowed[k]:
                del self.shadowed[k]
        # promote shadowed entries whose primary vanished
        for k, locs in list(self.shadowed.items()):
            if k not in self.entries and locs:
                self.entries[k] = locs.pop(0)
                if not locs:
                    del self.shadowed[k]
        return len(doomed)

    # -- persistence (binary sidecar: packed digests for the TPU probe path) --

    def save_binary(self, path: Path) -> Tuple[Path, int]:
        """npz sidecar: uint64 digest of each key + file ids + offsets.

        Digests here are *pointers into the CSV truth*, not identifiers of
        record content — the probe path resolves candidate hits and then
        verifies against the full key, exactly like Algorithm 3's defensive
        validation (a digest collision degrades to an extra verify, never to
        a wrong record).

        The ``.npz`` suffix is normalized up front (``np.savez`` appends it
        when missing), and the written path is returned with its size so
        the reported size always refers to the file actually on disk.
        """
        path = Path(path)
        if path.suffix != ".npz":
            path = path.with_name(path.name + ".npz")
        keys: List[str] = []
        fnames: List[str] = []
        offs: List[int] = []
        for key, (fname, off) in self.entries.items():
            keys.append(key)
            fnames.append(fname)
            offs.append(off)
        file_names = sorted(set(fnames))
        file_ids = {n: i for i, n in enumerate(file_names)}
        digests = np.array(
            [np.uint64(int.from_bytes(hashlib.blake2b(k.encode(), digest_size=8).digest(), "big"))
             for k in keys],
            dtype=np.uint64,
        )
        order = np.argsort(digests, kind="stable")
        np.savez(
            path,
            digests=digests[order],
            file_ids=np.array([file_ids[n] for n in fnames], dtype=np.int32)[order],
            offsets=np.array(offs, dtype=np.int64)[order],
            file_names=np.array(file_names),
            keys=np.array(keys, dtype=object)[order].astype(str),
            key_mode=np.array(self.key_mode),
        )
        return path, path.stat().st_size

    def save_sharded(
        self,
        root: Path,
        n_shards: int = 16,
        digest_bits: int = 64,
        bloom_bits_per_key: int = 12,
        fingerprint_bits: Optional[int] = 1024,
    ) -> Dict[str, object]:
        """Publish the index as a sharded mmap-backed store directory.

        The serving-grade persistence path (:mod:`repro.core.store`):
        digest-range shards of the packed sidecar columns plus per-shard
        Bloom bitmaps plus — unless ``fingerprint_bits=None`` — packed
        ``fingerprint_bits``-wide fingerprint planes for Tanimoto
        similarity search.  Re-publishing after an incremental
        :func:`update_index` rewrites only shards whose content changed.
        """
        from .store import save_sharded  # local import: store builds on index

        return save_sharded(
            self,
            root,
            n_shards=n_shards,
            digest_bits=digest_bits,
            bloom_bits_per_key=bloom_bits_per_key,
            fingerprint_bits=fingerprint_bits,
        )


class BinaryIndex:
    """mmap-fast sorted-digest index (the TPU sorted-probe's host twin).

    Loads the npz sidecar written by :meth:`ByteOffsetIndex.save_binary`;
    lookups are a binary search over the uint64 digest column with a full
    string-key verification on hit (Algorithm 3 discipline: a digest
    collision costs a verify, never a wrong record).
    """

    def __init__(self, path: Path):
        p = str(path)
        if not p.endswith(".npz"):
            p += ".npz"
        z = np.load(p, allow_pickle=False)
        self.digests = z["digests"]        # sorted uint64
        self.file_ids = z["file_ids"]
        self.offsets = z["offsets"]
        self.file_names = [str(x) for x in z["file_names"]]
        self.keys = [str(x) for x in z["keys"]]
        # persisted since PR 2; older sidecars predate hashed_key support
        self.key_mode = (
            str(z["key_mode"]) if "key_mode" in z.files else "full_id"
        )

    def __len__(self) -> int:
        return len(self.digests)

    def lookup(self, key: str) -> Optional[Tuple[str, int]]:
        d = np.uint64(
            int.from_bytes(
                hashlib.blake2b(key.encode(), digest_size=8).digest(), "big"
            )
        )
        i = int(np.searchsorted(self.digests, d))
        while i < len(self.digests) and self.digests[i] == d:
            if self.keys[i] == key:  # verify on the full key
                return self.file_names[self.file_ids[i]], int(self.offsets[i])
            i += 1
        return None

    def locate_batch(
        self, keys: Sequence[str]
    ) -> List[Optional[Tuple[str, int]]]:
        """Batched lookup (same read contract as the dict index / IndexStore)."""
        return [self.lookup(k) for k in keys]

    def iter_keys(self) -> Iterable[str]:
        return iter(self.keys)


def scan_file_for_index(
    args: Tuple[str, str, bool, int]
) -> Tuple[str, List[Tuple[str, int]], int]:
    """Worker: scan one SDF file, return ``(file_name, [(key, offset)], bytes)``.

    ProcessFile() from Algorithm 2 — embarrassingly parallel, no
    inter-worker communication.  Module-level function so it pickles for
    ``multiprocessing.Pool``.
    """
    path_s, key_mode, recompute, key_bits = args
    path = Path(path_s)
    out: List[Tuple[str, int]] = []
    for offset, text in iter_records(path):
        if key_mode == "full_id":
            key = extract_property(text, PROP_ID)
        else:
            key = None if recompute else extract_property(text, PROP_KEY)
            if key is None:
                full = extract_property(text, PROP_ID)
                key = hashed_key(full, key_bits) if full else None
        if key is not None:
            out.append((key, offset))
    return path.name, out, path.stat().st_size


def build_index(
    store: RecordStore,
    key_mode: str = "full_id",
    workers: int = 1,
    key_bits: int = 64,
    recompute_keys: bool = False,
) -> ByteOffsetIndex:
    """Phase 1: full corpus scan → persistent byte-offset index.

    ``workers > 1`` uses a process pool over files (Algorithm 2); the merge
    is a dictionary union, as in the paper.  O(M×S), incurred once.
    ``recompute_keys`` ignores the embedded hashed-key property and
    re-derives it from the full id at ``key_bits`` (key-width studies).
    """
    t0 = time.perf_counter()
    idx = ByteOffsetIndex(key_mode=key_mode)
    files = store.files()
    args = [(str(p), key_mode, recompute_keys, key_bits) for p in files]
    bytes_scanned = 0
    if workers <= 1:
        results = map(scan_file_for_index, args)
        for fname, pairs, nbytes in results:
            bytes_scanned += nbytes
            for key, off in pairs:
                idx.add(key, fname, off)
    else:
        ctx = mp.get_context("fork" if hasattr(os, "fork") else "spawn")
        with ctx.Pool(processes=workers) as pool:
            for fname, pairs, nbytes in pool.imap_unordered(scan_file_for_index, args):
                bytes_scanned += nbytes
                for key, off in pairs:
                    idx.add(key, fname, off)
    idx.stats.n_entries = len(idx)
    idx.stats.n_files = len(files)
    idx.stats.build_seconds = time.perf_counter() - t0
    idx.stats.bytes_scanned = bytes_scanned
    return idx


def file_fingerprints(store: RecordStore) -> Dict[str, Tuple[int, int]]:
    """``name → (size, mtime_ns)`` for change detection.

    This is the change-detection entry point, so it is the one place that
    must see the directory as it is NOW — refresh the store's cached
    listing before fingerprinting.
    """
    return {
        p.name: (p.stat().st_size, p.stat().st_mtime_ns)
        for p in store.refresh().files()
    }


def update_index(
    idx: ByteOffsetIndex,
    store: RecordStore,
    old_fingerprints: Dict[str, Tuple[int, int]],
    key_mode: str = "full_id",
    key_bits: int = 64,
) -> Tuple[Dict[str, Tuple[int, int]], Dict[str, int]]:
    """Incremental index update (the paper's §VIII future work, built).

    Rescans ONLY files that are new or whose (size, mtime) changed, and
    drops entries for files that vanished — O(changed bytes) instead of the
    full O(M×S) rebuild.  Returns (new_fingerprints, change summary).
    """
    new_fp = file_fingerprints(store)
    changed = [
        n for n, fp in new_fp.items() if old_fingerprints.get(n) != fp
    ]
    removed = [n for n in old_fingerprints if n not in new_fp]
    summary = {"rescanned": 0, "dropped": 0, "added": 0}
    for name in removed + changed:
        summary["dropped"] += idx.drop_file(name)
    for name in changed:
        fname, pairs, _ = scan_file_for_index(
            (str(store.path_of(name)), key_mode, False, key_bits)
        )
        for key, off in pairs:
            idx.add(key, fname, off)
            summary["added"] += 1
        summary["rescanned"] += 1
    idx.stats.n_entries = len(idx)
    return new_fp, summary
