"""Algorithm 1 — the paper's naïve nested-scan baseline.

Implemented exactly as published (§III.B): iterate files, scan every
record, test the record's identifier against the remaining-target
collection, stop early when all targets are found.  Two membership
variants are provided:

* ``membership="list"`` — the paper's pseudo-code uses a *list* of targets
  (``M ← T``, ``current_inchi ∈ M``), giving the O(N×M×S) complexity the
  paper analyses and projects to 100+ days.
* ``membership="set"``  — the obvious O(1)-membership fix.  Even with it,
  every (re-)extraction re-reads the entire corpus (the paper's Table III
  I/O argument: 168.9 TB baseline vs 177 MB indexed) — the index still
  wins on I/O volume, which is the paper's deeper point.

``estimate_runtime`` reproduces Eq. 2/3: project full-scale runtime from a
measured throughput sample, which is how the paper justified abandoning
the brute-force path after scanning only 3 representative files.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .records import RecordStore, extract_property, iter_records
from .sdfgen import PROP_ID

__all__ = ["BaselineResult", "naive_scan", "estimate_runtime", "measure_scan_throughput"]


@dataclass
class BaselineResult:
    records: Dict[str, str] = field(default_factory=dict)  # id -> record text
    missing: Set[str] = field(default_factory=set)
    files_scanned: int = 0
    records_scanned: int = 0
    bytes_scanned: int = 0
    seconds: float = 0.0
    comparisons: int = 0  # membership-test operation count (Eq. 2 analogue)


def naive_scan(
    store: RecordStore,
    targets: Sequence[str],
    membership: str = "list",
    max_files: Optional[int] = None,
) -> BaselineResult:
    """Algorithm 1: scan files until every target is found (or files end)."""
    if membership not in ("list", "set"):
        raise ValueError(membership)
    res = BaselineResult()
    remaining_list: List[str] = list(targets)
    remaining_set: Set[str] = set(targets)
    t0 = time.perf_counter()
    files = store.files()
    if max_files is not None:
        files = files[:max_files]
    for path in files:
        if not remaining_set:
            break
        res.files_scanned += 1
        res.bytes_scanned += path.stat().st_size
        for _offset, text in iter_records(path):
            res.records_scanned += 1
            rid = extract_property(text, PROP_ID)
            if rid is None:
                continue
            if membership == "list":
                # Paper-faithful: linear membership over the target list.
                res.comparisons += len(remaining_list)
                hit = rid in remaining_list
            else:
                res.comparisons += 1
                hit = rid in remaining_set
            if hit and rid in remaining_set:
                res.records[rid] = text
                remaining_set.discard(rid)
                if membership == "list":
                    remaining_list.remove(rid)
                if not remaining_set:
                    break
    res.missing = remaining_set
    res.seconds = time.perf_counter() - t0
    return res


@dataclass
class ThroughputSample:
    file: str
    file_bytes: int
    records: int
    seconds: float

    @property
    def records_per_second(self) -> float:
        return self.records / self.seconds if self.seconds > 0 else float("inf")


def measure_scan_throughput(
    store: RecordStore, n_files: int = 3
) -> List[ThroughputSample]:
    """Table I analogue: scan representative files, measure mol/s."""
    files = store.files()
    if not files:
        return []
    # representative spread: smallest, median, largest by size
    by_size = sorted(files, key=lambda p: p.stat().st_size)
    picks: List[Path] = []
    for frac in (0.0, 0.5, 1.0):
        p = by_size[min(int(frac * (len(by_size) - 1)), len(by_size) - 1)]
        if p not in picks:
            picks.append(p)
    samples: List[ThroughputSample] = []
    for path in picks[:n_files]:
        t0 = time.perf_counter()
        n = 0
        for _off, text in iter_records(path):
            extract_property(text, PROP_ID)
            n += 1
        dt = time.perf_counter() - t0
        samples.append(
            ThroughputSample(path.name, path.stat().st_size, n, dt)
        )
    return samples


def estimate_runtime(
    n_targets: int,
    n_files: int,
    records_per_file: int,
    throughput_rps: float,
    membership: str = "list",
) -> Tuple[float, float]:
    """Eq. 2/3: (operation_count, projected_seconds).

    ``membership="list"`` charges one pass over the target list per record
    (the paper's 8.4e13-comparison model with effective comparison rate
    folded into ``throughput_rps`` per the paper's normalization); "set"
    charges a single corpus scan.
    """
    if membership == "list":
        ops = float(n_targets) * n_files * records_per_file
        # paper normalizes by per-molecule scan rate across the whole target
        # list: T = N*M*S / (rate * list_factor); we keep their convention of
        # quoting ops and dividing by measured effective rate.
        seconds = ops / max(throughput_rps, 1e-9)
    else:
        ops = float(n_files) * records_per_file
        seconds = ops / max(throughput_rps, 1e-9)
    return ops, seconds
