"""Delimited-record stores: the paper's SDF file substrate.

Structure Data Format (SDF) files are semi-structured text with
variable-length records terminated by a ``$$$$`` line.  Everything in this
module operates on *byte offsets* (files opened in binary mode), because —
as the paper stresses (§IV.B) — byte addressing is what makes ``seek()``
O(1); line addressing would degrade to O(k) sequential scans.
"""

from __future__ import annotations

import io
import os
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple

__all__ = [
    "RECORD_DELIM",
    "RecordStore",
    "iter_records",
    "iter_record_offsets",
    "read_record_at",
    "extract_property",
    "record_properties",
]

RECORD_DELIM = b"$$$$"
_DELIM_LINE = b"$$$$\n"
_READ_CHUNK = 1 << 20  # 1 MiB buffered reads for sequential scans


@dataclass(frozen=True)
class RecordStore:
    """A directory of delimited record files (the "PubChem distribution").

    The paper's corpus: 354 files × ~500k records.  Files are discovered in
    sorted order so that ``file_id`` (the integer position used by compact
    index encodings) is stable.
    """

    root: Path

    def __post_init__(self):
        object.__setattr__(self, "root", Path(self.root))

    def files(self) -> List[Path]:
        return sorted(self.root.glob("*.sdf"))

    def file_names(self) -> List[str]:
        return [p.name for p in self.files()]

    def path_of(self, name: str) -> Path:
        return self.root / name

    def total_bytes(self) -> int:
        return sum(p.stat().st_size for p in self.files())

    def __len__(self) -> int:
        return len(self.files())


def iter_records(path: Path) -> Iterator[Tuple[int, str]]:
    """Yield ``(byte_offset, record_text)`` for every record in ``path``.

    Sequential full-file scan (the index-construction primitive).  Offsets
    are byte positions of the first byte of each record.  The trailing
    ``$$$$`` line is not included in ``record_text``.
    """
    with open(path, "rb", buffering=_READ_CHUNK) as f:
        offset = 0
        start = 0
        buf: List[bytes] = []
        for line in f:
            if line.rstrip(b"\n\r") == RECORD_DELIM:
                yield start, b"".join(buf).decode("utf-8", "replace")
                offset += len(line)
                start = offset
                buf = []
            else:
                buf.append(line)
                offset += len(line)
        if buf and any(ln.strip() for ln in buf):
            yield start, b"".join(buf).decode("utf-8", "replace")


def iter_record_offsets(path: Path) -> Iterator[int]:
    """Yield the byte offset of every record start (no parsing).

    This is ``ScanLineOffsets`` from Algorithm 2, fused with record
    detection: a single streaming pass that only tracks byte positions.
    """
    with open(path, "rb", buffering=_READ_CHUNK) as f:
        offset = 0
        start = 0
        saw_content = False
        for line in f:
            if line.rstrip(b"\n\r") == RECORD_DELIM:
                if saw_content:
                    yield start
                offset += len(line)
                start = offset
                saw_content = False
            else:
                offset += len(line)
                if line.strip():
                    saw_content = True
        if saw_content:
            yield start


def read_record_at(path_or_handle, offset: int) -> str:
    """O(1) record fetch: ``seek(offset)`` then read until the delimiter.

    Algorithm 3 lines 6–7 (``seek`` + ``ReadUntilDelimiter``).  Accepts an
    open binary handle so that callers extracting many records from one
    file (grouped extraction) amortize the ``open()`` cost, as the paper's
    GroupByFilename optimization requires.
    """
    own = False
    if isinstance(path_or_handle, (str, Path)):
        f = open(path_or_handle, "rb", buffering=_READ_CHUNK)
        own = True
    else:
        f = path_or_handle
    try:
        f.seek(offset)
        buf: List[bytes] = []
        for line in f:
            if line.rstrip(b"\n\r") == RECORD_DELIM:
                break
            buf.append(line)
        return b"".join(buf).decode("utf-8", "replace")
    finally:
        if own:
            f.close()


def extract_property(record_text: str, name: str) -> Optional[str]:
    """Extract an SDF data item ``> <name>`` value (first line) or None."""
    tag = f"> <{name}>"
    lines = record_text.splitlines()
    for i, ln in enumerate(lines):
        if ln.strip() == tag:
            if i + 1 < len(lines):
                v = lines[i + 1].strip()
                return v if v else None
            return None
    return None


def record_properties(record_text: str) -> Dict[str, str]:
    """All SDF data items of a record as a dict (single-line values)."""
    props: Dict[str, str] = {}
    lines = record_text.splitlines()
    i = 0
    while i < len(lines):
        ln = lines[i].strip()
        if ln.startswith("> <") and ln.endswith(">"):
            name = ln[3:-1]
            val = lines[i + 1].strip() if i + 1 < len(lines) else ""
            props[name] = val
            i += 2
        else:
            i += 1
    return props
