"""Delimited-record stores: the paper's SDF file substrate.

Structure Data Format (SDF) files are semi-structured text with
variable-length records terminated by a ``$$$$`` line.  Everything in this
module operates on *byte offsets* (files opened in binary mode), because —
as the paper stresses (§IV.B) — byte addressing is what makes ``seek()``
O(1); line addressing would degrade to O(k) sequential scans.
"""

from __future__ import annotations

import io
import os
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple

__all__ = [
    "RECORD_DELIM",
    "RecordStore",
    "find_record_end",
    "iter_records",
    "iter_record_offsets",
    "read_record_at",
    "extract_property",
    "record_properties",
]

RECORD_DELIM = b"$$$$"
_DELIM_LINE = b"$$$$\n"
_READ_CHUNK = 1 << 20  # 1 MiB buffered reads for sequential scans


@dataclass(frozen=True)
class RecordStore:
    """A directory of delimited record files (the "PubChem distribution").

    The paper's corpus: 354 files × ~500k records.  Files are discovered in
    sorted order so that ``file_id`` (the integer position used by compact
    index encodings) is stable.

    The sorted listing is computed once on first use and reused —
    ``files()``/``file_names()``/``total_bytes()`` sit inside per-file
    extraction and scan loops, and re-globbing the directory for each call
    is pure syscall waste on a corpus that almost never changes.  Callers
    that DO change the directory (incremental index updates) must
    :meth:`refresh` before relisting.
    """

    root: Path

    def __post_init__(self):
        object.__setattr__(self, "root", Path(self.root))
        object.__setattr__(self, "_files_cache", None)

    def files(self) -> List[Path]:
        cached = self._files_cache
        if cached is None:
            cached = sorted(self.root.glob("*.sdf"))
            object.__setattr__(self, "_files_cache", cached)
        return cached

    def refresh(self) -> "RecordStore":
        """Invalidate the cached listing (directory contents changed)."""
        object.__setattr__(self, "_files_cache", None)
        return self

    def file_names(self) -> List[str]:
        return [p.name for p in self.files()]

    def path_of(self, name: str) -> Path:
        return self.root / name

    def total_bytes(self) -> int:
        return sum(p.stat().st_size for p in self.files())

    def __len__(self) -> int:
        return len(self.files())


def find_record_end(buf: bytes, rel: int, at_eof: bool) -> Tuple[int, int, bool]:
    """Locate the ``$$$$`` terminator line of the record starting at ``rel``.

    The single home of the delimiter-line grammar, shared by the bulk
    sequential scanners below and the pipelined extraction engine's span
    splitter (:mod:`repro.core.reader`): a terminator is ``$$$$`` at a line
    start followed only by ``\\r``s before its newline (or before EOF) —
    exactly the per-line path's ``line.rstrip(b"\\n\\r") == b"$$$$"`` test,
    found with C-speed ``bytes.find`` instead of a line loop.  ``rel``
    must be a line start (record starts always are).

    Returns ``(end, next_start, definite)``: ``end`` is where the record's
    bytes stop (the terminator line's first byte, or ``len(buf)`` when no
    terminator exists before EOF); ``next_start`` is the position just past
    the terminator line (``end == next_start`` means no terminator was
    found — an unterminated trailing record).  ``definite=False`` means the
    buffer ended before the answer was certain (no delimiter yet, or a
    candidate whose line might continue past the buffer) — the caller must
    extend the buffer unless ``at_eof``.
    """
    n = len(buf)
    pos = rel
    while True:
        idx = buf.find(RECORD_DELIM, pos)
        if idx == -1:
            return n, n, at_eof
        if idx > 0 and buf[idx - 1] != 0x0A:
            pos = idx + 1  # mid-line "$$$$": record content
            continue
        j = idx + 4
        while j < n and buf[j] == 0x0D:
            j += 1
        if j >= n:
            # "$$$$\r*" flush against the buffer end: at EOF the per-line
            # path's rstrip accepts it; otherwise the line may continue.
            return idx, n, at_eof
        if buf[j] == 0x0A:
            return idx, j + 1, True
        pos = j  # "$$$$junk": record content, keep scanning


def _iter_delimited(path: Path) -> Iterator[Tuple[int, bytes, bool]]:
    """Yield ``(start_offset, raw_record_bytes, terminated)`` per record.

    The shared sequential-scan core: chunked binary reads split with
    :func:`find_record_end` instead of a per-line Python loop.
    ``terminated`` is False only for a trailing record with no closing
    delimiter.
    """
    with open(path, "rb") as f:
        buf = b""
        base = 0          # absolute file offset of buf[0]
        start = 0         # absolute offset of the current record's first byte
        at_eof = False
        while True:
            rel = start - base
            end, nxt, definite = find_record_end(buf, rel, at_eof)
            if definite:
                if nxt > end:  # terminator found
                    yield start, buf[rel:end], True
                    start = base + nxt
                    continue
                tail = buf[rel:]  # EOF with no terminator
                if tail.strip():
                    yield start, tail, False
                return
            # need more bytes: drop the consumed prefix, then refill
            if rel > 0:
                buf = buf[rel:]
                base = start
            chunk = f.read(_READ_CHUNK)
            if chunk:
                buf += chunk
            else:
                at_eof = True


def iter_records(path: Path) -> Iterator[Tuple[int, str]]:
    """Yield ``(byte_offset, record_text)`` for every record in ``path``.

    Sequential full-file scan (the index-construction primitive).  Offsets
    are byte positions of the first byte of each record.  The trailing
    ``$$$$`` line is not included in ``record_text``.
    """
    for start, raw, _terminated in _iter_delimited(path):
        yield start, raw.decode("utf-8", "replace")


def iter_record_offsets(path: Path) -> Iterator[int]:
    """Yield the byte offset of every record start (no parsing).

    This is ``ScanLineOffsets`` from Algorithm 2, fused with record
    detection: a single streaming pass that only tracks byte positions.
    Blank records (nothing but whitespace before the delimiter) carry no
    indexable content and are skipped, as before.
    """
    for start, raw, _terminated in _iter_delimited(path):
        if raw.strip():
            yield start


def read_record_at(path_or_handle, offset: int) -> str:
    """O(1) record fetch: ``seek(offset)`` then read until the delimiter.

    Algorithm 3 lines 6–7 (``seek`` + ``ReadUntilDelimiter``).  Accepts an
    open binary handle so that callers extracting many records from one
    file (grouped extraction) amortize the ``open()`` cost, as the paper's
    GroupByFilename optimization requires.
    """
    own = False
    if isinstance(path_or_handle, (str, Path)):
        f = open(path_or_handle, "rb", buffering=_READ_CHUNK)
        own = True
    else:
        f = path_or_handle
    try:
        f.seek(offset)
        buf: List[bytes] = []
        for line in f:
            if line.rstrip(b"\n\r") == RECORD_DELIM:
                break
            buf.append(line)
        return b"".join(buf).decode("utf-8", "replace")
    finally:
        if own:
            f.close()


def extract_property(record_text: str, name: str) -> Optional[str]:
    """Extract an SDF data item ``> <name>`` value (first line) or None."""
    tag = f"> <{name}>"
    lines = record_text.splitlines()
    for i, ln in enumerate(lines):
        if ln.strip() == tag:
            if i + 1 < len(lines):
                v = lines[i + 1].strip()
                return v if v else None
            return None
    return None


def record_properties(record_text: str) -> Dict[str, str]:
    """All SDF data items of a record as a dict (single-line values)."""
    props: Dict[str, str] = {}
    lines = record_text.splitlines()
    i = 0
    while i < len(lines):
        ln = lines[i].strip()
        if ln.startswith("> <") and ln.endswith(">"):
            name = ln[3:-1]
            val = lines[i + 1].strip() if i + 1 < len(lines) else ""
            props[name] = val
            i += 2
        else:
            i += 1
    return props
