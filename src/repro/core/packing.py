"""String-id ↔ fixed-width tensor packing (TPU adaptation layer).

TPUs stream dense arrays, not heaps of Python strings.  This module packs
variable-length identifier strings into ``(N, W)`` uint32 lane tensors
(zero-padded, 4 chars per lane) — the representation consumed by the
``hash_mix`` Pallas kernel and the sorted-probe membership path.

Width is chosen per corpus (max id length rounded up to a multiple of 8
lanes = 32 bytes) so MXU/VPU alignment holds.  Packing is injective for
ids ≤ W*4 bytes: unpack(pack(x)) == x.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

__all__ = ["pack_ids", "unpack_ids", "lanes_for"]


def lanes_for(ids: Sequence[str], min_lanes: int = 8) -> int:
    """Number of uint32 lanes needed for the longest id (multiple of 8)."""
    max_len = max((len(s.encode()) for s in ids), default=1)
    lanes = (max_len + 3) // 4
    return max(min_lanes, ((lanes + 7) // 8) * 8)


def pack_ids(ids: Sequence[str], lanes: int | None = None) -> np.ndarray:
    """Pack utf-8 id strings into a ``(N, lanes)`` uint32 array.

    Little-endian within each lane; zero padding after the id bytes.  Raises
    if any id exceeds the lane budget (silent truncation would reintroduce
    exactly the aliasing bug the paper warns about).
    """
    if lanes is None:
        lanes = lanes_for(ids)
    width = lanes * 4
    n = len(ids)
    buf = np.zeros((n, width), dtype=np.uint8)
    for i, s in enumerate(ids):
        b = s.encode()
        if len(b) > width:
            raise ValueError(
                f"id of {len(b)} bytes exceeds packing width {width}; "
                "increase lanes (never truncate identifiers)"
            )
        buf[i, : len(b)] = np.frombuffer(b, dtype=np.uint8)
    return buf.reshape(n, lanes, 4).view(np.uint32).reshape(n, lanes)


def unpack_ids(packed: np.ndarray) -> List[str]:
    """Inverse of :func:`pack_ids`."""
    n, lanes = packed.shape
    raw = packed.reshape(n, lanes, 1).view(np.uint8).reshape(n, lanes * 4)
    out: List[str] = []
    for i in range(n):
        row = raw[i]
        nz = np.nonzero(row)[0]
        end = (nz[-1] + 1) if len(nz) else 0
        out.append(bytes(row[:end]).decode("utf-8"))
    return out
