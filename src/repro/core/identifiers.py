"""Molecular identifiers: canonical full ids and hashed keys.

This module is the reproduction of the paper's identifier layer (§II.C,
§VI).  Real chemistry uses InChI (canonical, deterministic, verbose) and
InChIKey (a SHA-256-derived 27-character digest).  We reproduce the exact
*system properties* that matter to the paper:

* ``canonical_id``   — a deterministic, collision-free canonical string
  derived purely from molecular structure (the "full InChI" role).  Two
  structures are identical iff their canonical ids are identical.
* ``hashed_key``     — a 27-character, SHA-256-derived digest of the
  canonical id formatted exactly like an InChIKey
  (``XXXXXXXXXXXXXX-YYYYYYYYSA-N``).  The effective hash width is
  configurable (``bits``) so that the paper's hundred-million-scale
  collision phenomenology (§VI.B, Eq. 4/5) can be observed and measured at
  container-scale corpora: the paper's h ≈ 1e15 (~50 bits) with n = 1.77e8
  records is expectation-equivalent to ~28 bits at n = 1e5 records.
* ``molecule_from_cid`` — a deterministic synthetic molecule generator:
  the structure (and therefore the canonical id) is a pure function of the
  integer compound id, which makes terabyte-scale corpora reproducible
  from a single integer range.

The derivation chain mirrors the paper's: structure → InChI → InChIKey,
with ``canonical_id_from_structure`` playing the role of "recompute the
molecule's InChI from its structural data using RDKit" (Algorithm 3,
lines 8–12).
"""

from __future__ import annotations

import hashlib
import struct
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

__all__ = [
    "Molecule",
    "molecule_from_cid",
    "canonical_id",
    "canonical_id_from_structure",
    "hashed_key",
    "DEFAULT_KEY_BITS",
    "PAPER_KEY_BITS",
]

# The paper (Eq. 5) models InChIKey space as h ~ 1e15 => ~50 bits.
PAPER_KEY_BITS = 50
# Full-strength default for production use (14 base-26 chars ~ 65.8 bits
# of the connectivity block alone; we cap at 64 for packing convenience).
DEFAULT_KEY_BITS = 64

_ELEMENTS = ("C", "N", "O", "S", "P", "F", "Cl", "Br")
# Rough valence budget per element, used to keep generated structures
# internally consistent (H counts are derived, not random).
_VALENCE = {"C": 4, "N": 3, "O": 2, "S": 2, "P": 3, "F": 1, "Cl": 1, "Br": 1}

_B26 = "ABCDEFGHIJKLMNOPQRSTUVWXYZ"


@dataclass(frozen=True)
class Molecule:
    """A synthetic molecule: a connected multigraph with stereo tags.

    ``atoms``  — element symbol per atom (canonical order).
    ``bonds``  — (a, b, order, stereo) with a < b, canonically sorted.
    ``hcount`` — implicit hydrogens per atom (valence - bond order sum).
    """

    atoms: Tuple[str, ...]
    bonds: Tuple[Tuple[int, int, int, int], ...]
    hcount: Tuple[int, ...] = field(default=())

    @property
    def natoms(self) -> int:
        return len(self.atoms)

    @property
    def nbonds(self) -> int:
        return len(self.bonds)


def _rng_stream(cid: int, salt: str) -> "_Sha256Stream":
    return _Sha256Stream(f"{salt}:{cid}".encode())


class _Sha256Stream:
    """Cheap deterministic random stream from iterated SHA-256.

    Independent of Python's global RNG so corpora are reproducible across
    processes and library versions (critical for the multi-worker index
    construction tests).
    """

    __slots__ = ("_buf", "_pos", "_seed", "_ctr")

    def __init__(self, seed: bytes):
        self._seed = seed
        self._ctr = 0
        self._buf = b""
        self._pos = 0

    def _refill(self) -> None:
        self._buf = hashlib.sha256(self._seed + struct.pack("<Q", self._ctr)).digest()
        self._ctr += 1
        self._pos = 0

    def u8(self) -> int:
        if self._pos >= len(self._buf):
            self._refill()
        v = self._buf[self._pos]
        self._pos += 1
        return v

    def u16(self) -> int:
        return self.u8() | (self.u8() << 8)

    def randint(self, lo: int, hi: int) -> int:
        """Uniform integer in [lo, hi] (inclusive); hi-lo < 65536."""
        span = hi - lo + 1
        return lo + self.u16() % span

    def chance(self, num: int, den: int) -> bool:
        return self.u16() % den < num


# cid→structure injectivity: a backbone chain encodes the cid in base 4
# over chainable elements (valence ≥ 2), so two distinct cids can never
# produce identical structures (and therefore never identical canonical
# ids) — PubChem CIDs likewise map 1:1 to structures.
_DIGIT_ELEMENTS = ("C", "N", "O", "S")
_CID_CHAIN_LEN = 15  # 4**15 ≈ 1.07e9 > PubChem scale (1.77e8)


def molecule_from_cid(cid: int, salt: str = "repro-corpus-v1") -> Molecule:
    """Deterministically synthesize a molecule for compound id ``cid``.

    Structure: a cid-encoding backbone chain (injectivity guarantee)
    followed by a random spanning tree of 4..28 extra heavy atoms plus a
    few ring-closure bonds, with bond orders and stereo tags.  The
    construction is canonical by construction (atom indices are the
    canonical numbering), so ``canonical_id`` is well-defined and
    recomputable from the serialized structure alone.
    """
    if not 0 <= cid < 4 ** _CID_CHAIN_LEN:
        raise ValueError(f"cid out of range: {cid}")
    rng = _rng_stream(cid, salt)

    # --- backbone: base-4 digits of cid as a linear chain -----------------
    atoms: List[str] = []
    v = cid
    for _ in range(_CID_CHAIN_LEN):
        atoms.append(_DIGIT_ELEMENTS[v % 4])
        v //= 4
    nc = len(atoms)
    remaining = [_VALENCE[a] for a in atoms]
    bonds: List[Tuple[int, int, int, int]] = []
    for i in range(1, nc):
        bonds.append((i - 1, i, 1, 0))
        remaining[i - 1] -= 1
        remaining[i] -= 1

    # --- random decoration ------------------------------------------------
    n = nc + rng.randint(4, 28)
    for _ in range(n - nc):
        r = rng.u8()
        # Organic-like composition: mostly carbon.
        if r < 160:
            atoms.append("C")
        else:
            atoms.append(_ELEMENTS[1 + rng.u8() % (len(_ELEMENTS) - 1)])
    remaining += [_VALENCE[a] for a in atoms[nc:]]

    # Spanning tree: attach atom i to a previous atom with spare valence.
    for i in range(nc, n):
        # pick parent among previous atoms with remaining valence
        tries = 0
        j = rng.randint(0, i - 1)
        while remaining[j] < 1 and tries < 2 * i:
            j = (j + 1) % i
            tries += 1
        if remaining[j] < 1 or remaining[i] < 1:
            j = 0  # degenerate fallback; still a valid graph
        order = 1
        if remaining[i] >= 2 and remaining[j] >= 2 and rng.chance(1, 5):
            order = 2
        stereo = 1 if (order == 1 and rng.chance(1, 8)) else 0
        a, b = (j, i) if j < i else (i, j)
        bonds.append((a, b, order, stereo))
        remaining[i] -= order
        remaining[j] -= order

    # A few ring closures.
    nrings = rng.randint(0, 2)
    for _ in range(nrings):
        a = rng.randint(0, n - 1)
        b = rng.randint(0, n - 1)
        if a == b:
            continue
        a, b = (a, b) if a < b else (b, a)
        if remaining[a] >= 1 and remaining[b] >= 1 and not any(
            (a, b) == (x, y) for x, y, _, _ in bonds
        ):
            bonds.append((a, b, 1, 0))
            remaining[a] -= 1
            remaining[b] -= 1

    bonds.sort()
    hcount = tuple(max(0, r) for r in remaining)
    return Molecule(atoms=tuple(atoms), bonds=tuple(bonds), hcount=hcount)


def _formula(mol: Molecule) -> str:
    """Hill-order molecular formula (C first, H second, rest alphabetical)."""
    counts: dict = {}
    for a in mol.atoms:
        counts[a] = counts.get(a, 0) + 1
    h = sum(mol.hcount)
    parts: List[str] = []
    if "C" in counts:
        parts.append(f"C{counts.pop('C')}")
        if h:
            parts.append(f"H{h}")
        for el in sorted(counts):
            parts.append(f"{el}{counts[el]}")
    else:
        if h:
            counts["H"] = h
        for el in sorted(counts):
            parts.append(f"{el}{counts[el]}")
    return "".join(parts)


def canonical_id(mol: Molecule) -> str:
    """Canonical full identifier (the "full InChI" role).

    Layered like InChI: formula ``/c`` connectivity ``/h`` hydrogens and an
    optional ``/t`` stereo layer.  Injective over the molecule structures we
    generate: every atom, bond, order, H-count and stereo tag is serialized.
    """
    conn = ",".join(
        f"{a + 1}-{b + 1}" + (f"*{o}" if o != 1 else "")
        for a, b, o, _ in mol.bonds
    )
    hs = ",".join(str(h) for h in mol.hcount)
    elems = "".join(
        a if len(a) == 1 else a for a in mol.atoms
    )  # positional element string disambiguates formula-equal isomers
    s = f"InChI=1S/{_formula(mol)}/e{elems}/c{conn}/h{hs}"
    stereo = [i for i, (_, _, _, st) in enumerate(mol.bonds) if st]
    if stereo:
        s += "/t" + ",".join(str(i + 1) for i in stereo)
    return s


def hashed_key(full_id: str, bits: int = DEFAULT_KEY_BITS) -> str:
    """27-character InChIKey-style digest of a canonical id.

    SHA-256 over the canonical id, truncated to ``bits`` effective bits,
    then base-26 encoded into the standard 14-8 block layout with the
    constant ``SA-N`` suffix (standard InChIKey flag/proton chars).  With
    ``bits`` = 50 this models the paper's h ≈ 1e15 key space (Eq. 5).
    """
    if not 8 <= bits <= 64:
        raise ValueError(f"bits must be in [8, 64], got {bits}")
    digest = hashlib.sha256(full_id.encode()).digest()
    v = int.from_bytes(digest[:8], "big")
    if bits < 64:
        v &= (1 << bits) - 1
    # 22 base-26 chars hold ~103 bits >= 64: encode v into 22 chars.
    chars = []
    for _ in range(22):
        chars.append(_B26[v % 26])
        v //= 26
    block = "".join(reversed(chars))
    return f"{block[:14]}-{block[14:22]}SA-N"


# ---------------------------------------------------------------------------
# Structure serialization (molfile-ish) and re-derivation.
# ---------------------------------------------------------------------------

def structure_block(mol: Molecule) -> str:
    """Serialize a molecule as a V2000-flavoured ctab block.

    Atom lines carry the element and implicit-H count; bond lines carry
    (a, b, order, stereo).  ``canonical_id_from_structure`` re-derives the
    canonical id from exactly this text, which is what makes Algorithm 3's
    defensive verification meaningful (recompute-and-compare).
    """
    lines = [f"{mol.natoms:3d}{mol.nbonds:3d}  0  0  0  0  0  0  0999 V2000"]
    for el, h in zip(mol.atoms, mol.hcount):
        lines.append(f"    0.0000    0.0000    0.0000 {el:<3s} {h:2d}")
    for a, b, o, st in mol.bonds:
        lines.append(f"{a + 1:3d}{b + 1:3d}{o:3d}{st:3d}")
    lines.append("M  END")
    return "\n".join(lines)


def parse_structure_block(text: str) -> Molecule:
    """Inverse of :func:`structure_block` (tolerates surrounding SDF text)."""
    lines = text.splitlines()
    # find the counts line: ends with V2000
    start = None
    for i, ln in enumerate(lines):
        if ln.rstrip().endswith("V2000"):
            start = i
            break
    if start is None:
        raise ValueError("no V2000 counts line found")
    counts = lines[start]
    natoms = int(counts[0:3])
    nbonds = int(counts[3:6])
    atoms: List[str] = []
    hcount: List[int] = []
    for ln in lines[start + 1 : start + 1 + natoms]:
        parts = ln.split()
        atoms.append(parts[3])
        hcount.append(int(parts[4]))
    bonds: List[Tuple[int, int, int, int]] = []
    for ln in lines[start + 1 + natoms : start + 1 + natoms + nbonds]:
        a = int(ln[0:3]) - 1
        b = int(ln[3:6]) - 1
        o = int(ln[6:9])
        st = int(ln[9:12])
        bonds.append((a, b, o, st))
    return Molecule(atoms=tuple(atoms), bonds=tuple(bonds), hcount=tuple(hcount))


def canonical_id_from_structure(record_text: str) -> str:
    """Recompute the canonical id from a record's structural data.

    The reproduction of "recompute the molecule's InChI from its structural
    data using RDKit's canonical InChI generation" — the verification step
    that surfaced the paper's hash collisions.
    """
    return canonical_id(parse_structure_block(record_text))
