"""Algorithm 3 — index-based extraction with grouped, offset-sorted seeks.

Phase 2 of the paper's architecture.  The three published optimizations
are all here and individually switchable (so the benchmarks can ablate
them, Table II / §IV.D):

1. **GroupByFilename** — one ``open()`` per file containing targets
   (477,123 potential opens → 312 in the paper).
2. **Offset-sorted traversal** — targets within a file are visited in
   ascending byte order, converting random seeks into near-sequential
   forward reads (10–100× effective-throughput on spinning disks; still
   measurable on SSD/page-cache via readahead).
3. **Defensive verification** — every extracted record's identifier is
   *recomputed from its structural data* and compared against the expected
   identifier.  This is the step that exposed the paper's InChIKey
   collisions (§VI.A): under ``hashed_key`` indexing, a collision fetches a
   structurally different molecule whose recomputed full id mismatches.

Beyond the paper, the read phase itself is pipelined
(:mod:`repro.core.reader`): targets coalesce into merged spans submitted
through a pluggable I/O backend (io_uring / threaded preadv / mmap),
record boundaries come from bulk ``bytes.find`` scans over zero-copy
span buffers, files fan out over a thread pool, verification runs as
batched vectorized recomputes (:mod:`repro.core.verify`), and a
:class:`~repro.core.cache.RecordCache` can absorb repeat fetches.
``workers=0`` preserves the exact serial reference loop for the ablation
rows; both paths produce byte-identical ``records``/``missing``/
``mismatches``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from .cache import RecordCache
from .identifiers import hashed_key
from .reader import (
    DEFAULT_COALESCE_GAP,
    DEFAULT_SPAN_GUESS,
    DEFAULT_WORKERS,
    ReadStats,
    _recompute,
    stream_plan,
)
from .records import RecordStore, read_record_at

__all__ = [
    "ExtractionResult",
    "Mismatch",
    "assemble_plan",
    "extract",
    "extract_iter",
    "plan_extraction",
]


@dataclass(frozen=True)
class Mismatch:
    """A verification failure: what the index promised vs what the bytes say."""

    expected_id: str
    found_id: str
    file: str
    offset: int
    lookup_key: str


@dataclass
class ExtractionResult:
    records: Dict[str, str] = field(default_factory=dict)   # full_id -> record
    missing: List[str] = field(default_factory=list)        # not in index
    mismatches: List[Mismatch] = field(default_factory=list)
    files_opened: int = 0
    seeks: int = 0            # records fetched (one logical seek per target)
    bytes_read: int = 0       # bytes actually read (incl. coalescing overshoot)
    spans_read: int = 0       # pread spans issued (0 on the serial path)
    cache_hits: int = 0       # records served from the RecordCache
    plan_seconds: float = 0.0  # plan/probe phase (batched index lookups)
    read_seconds: float = 0.0  # read+verify phase (Algorithm 3's loop)
    read_backend: str = ""    # span backend the engine resolved to ("" = serial)
    inflight_peak: int = 0    # max spans in flight at once (engine path)
    verify_batches: int = 0   # physical combined verify batches
    verify_records: int = 0   # records verified through batches
    verify_batch_max: int = 0  # largest combined verify batch

    @property
    def found(self) -> int:
        return len(self.records)

    @property
    def seconds(self) -> float:
        """Total wall time (plan + read), kept for back-compatibility."""
        return self.plan_seconds + self.read_seconds


def plan_extraction(
    index,
    targets: Sequence[str],
    key_bits: int = 64,
    sort_offsets: bool = True,
) -> Tuple[Dict[str, List[Tuple[str, str, int]]], List[str]]:
    """Build the per-file extraction plan through ONE batched lookup.

    Returns ``(plan, missing)`` where ``plan[file] = [(full_id, lookup_key,
    offset), ...]`` sorted by ascending offset (if ``sort_offsets``).

    ``index`` is any read backend exposing the batch contract —
    :class:`ByteOffsetIndex` (dict), :class:`BinaryIndex` (packed sidecar),
    or :class:`repro.core.store.IndexStore` (sharded mmap store, where the
    single ``locate_batch`` call amortizes digesting, Bloom filtering, and
    shard probing over the whole target list).

    Targets are always full canonical ids (the ChEMBL∩eMolecules list is
    known by full id); under ``hashed_key`` indexing the lookup key is the
    digest of the target id — exactly the paper's pipeline before the §VI.C
    migration.
    """
    hashed = getattr(index, "key_mode", "full_id") == "hashed_key"
    keys = [
        hashed_key(t, key_bits) if hashed else t for t in targets
    ]
    locate = getattr(index, "locate_batch", None)
    if locate is not None:
        locs = locate(keys)
    else:  # minimal backends: fall back to per-key lookups
        locs = [index.lookup(k) for k in keys]
    return assemble_plan(targets, keys, locs, sort_offsets)


def assemble_plan(
    targets: Sequence[str],
    keys: Sequence[str],
    locs: Sequence[Optional[Tuple[str, int]]],
    sort_offsets: bool = True,
) -> Tuple[Dict[str, List[Tuple[str, str, int]]], List[str]]:
    """Group resolved locations into the per-file extraction plan.

    Shared by :func:`plan_extraction` (direct index backends) and the
    query service's scheduler-coalesced plan path — one definition of the
    plan shape, two ways of resolving locations.
    """
    plan: Dict[str, List[Tuple[str, str, int]]] = {}
    missing: List[str] = []
    for full_id, key, loc in zip(targets, keys, locs):
        if loc is None:
            missing.append(full_id)
            continue
        fname, off = loc
        plan.setdefault(fname, []).append((full_id, key, off))
    if sort_offsets:
        for fname in plan:
            plan[fname].sort(key=lambda t: t[2])
    return plan, missing


def extract(
    store: RecordStore,
    index,  # ByteOffsetIndex | BinaryIndex | IndexStore (batch read contract)
    targets: Sequence[str],
    verify: bool = True,
    sort_offsets: bool = True,
    group_by_file: bool = True,
    key_bits: int = 64,
    workers: Optional[int] = None,
    coalesce_gap: int = DEFAULT_COALESCE_GAP,
    span_guess: int = DEFAULT_SPAN_GUESS,
    cache: Optional[RecordCache] = None,
    verify_backend: str = "auto",
    backend=None,   # SpanBackend | name | None (REPRO_READER_BACKEND)
    depth: Optional[int] = None,   # in-flight spans per worker (uring)
    verifier=None,  # shared repro.core.verify.VerifyBatcher
    service=None,  # repro.service.QueryService — scheduler-coalesced plan path
) -> ExtractionResult:
    """Algorithm 3: seek-extract every target through the index.

    ``workers`` selects the read path: ``None`` (default) uses the
    pipelined engine with :data:`~repro.core.reader.DEFAULT_WORKERS`
    threads; any ``workers >= 1`` pins the engine's pool size; ``workers=0``
    runs the serial reference loop (one ``seek`` + per-line scan + per-record
    verify) — the ablation baseline the benchmarks compare against.  Both
    paths return byte-identical ``records``/``missing``/``mismatches``.

    ``coalesce_gap``/``span_guess`` tune the engine's pread coalescing and
    ``cache`` (a :class:`~repro.core.cache.RecordCache`) serves repeat
    fetches without re-reading — see :mod:`repro.core.reader`.

    ``service`` (a :class:`repro.service.QueryService`) replaces the
    direct ``index`` probe with the service's scheduler-coalesced lookup
    path — concurrent extractions then share probe batches, the service's
    record cache (unless ``cache`` overrides it), and its long-lived read
    pool; ``index`` may be ``None``.  Output is byte-identical either way.

    The access-pattern ablations always take the serial loop, because the
    engine has no unsorted/ungrouped mode (it coalesces in offset order by
    construction): ``group_by_file=False`` is one open per target, and
    ``sort_offsets=False`` visits each file's targets in lookup order.
    """
    t0 = time.perf_counter()
    res = ExtractionResult()
    executor = None
    if service is not None:
        plan, missing = service.plan(targets, key_bits=key_bits,
                                     sort_offsets=sort_offsets)
        if cache is None:
            cache = service.cache
        executor = service.read_executor
        if workers is None:
            workers = service.config.read_workers
        if backend is None:
            backend = service.read_backend
        if verifier is None:
            verifier = service.verifier
    else:
        plan, missing = plan_extraction(index, targets, key_bits, sort_offsets)
    res.missing = missing
    res.plan_seconds = time.perf_counter() - t0

    t1 = time.perf_counter()
    found: Dict[str, str] = {}

    if workers is None:
        workers = DEFAULT_WORKERS

    if group_by_file and sort_offsets and workers > 0:
        # pipelined engine: coalesced preads, parallel file workers,
        # batched digest verification, optional record cache
        stats = ReadStats()
        for ev in stream_plan(
            store,
            plan,
            verify=verify,
            workers=workers,
            coalesce_gap=coalesce_gap,
            span_guess=span_guess,
            cache=cache,
            verify_backend=verify_backend,
            stats=stats,
            executor=executor,
            backend=backend,
            depth=depth,
            verifier=verifier,
        ):
            res.seeks += 1
            if ev.ok:
                found[ev.full_id] = ev.text
            else:
                res.mismatches.append(
                    Mismatch(ev.full_id, ev.found_id, ev.file, ev.offset, ev.key)
                )
        res.files_opened = stats.files_opened
        res.bytes_read = stats.bytes_read
        res.spans_read = stats.spans_read
        res.cache_hits = stats.cache_hits
        res.read_backend = stats.backend
        res.inflight_peak = stats.inflight_peak
        res.verify_batches = stats.verify_batches
        res.verify_records = stats.verify_records
        res.verify_batch_max = stats.verify_batch_max
    else:
        # serial reference paths (ablations): grouped forward seeks with the
        # per-line scan, or fully ungrouped one-open-per-target access
        def handle_record(full_id: str, key: str, fname: str, off: int, text: str):
            res.seeks += 1
            res.bytes_read += len(text)
            if verify:
                recomputed = _recompute(text)
                if recomputed != full_id:
                    # The paper's "log error" branch — and the collision signal.
                    res.mismatches.append(
                        Mismatch(full_id, recomputed, fname, off, key)
                    )
                    return
            found[full_id] = text

        if group_by_file:
            for fname, items in plan.items():
                path = store.path_of(fname)
                res.files_opened += 1
                with open(path, "rb") as handle:
                    # offsets ascend (sort_offsets) => forward-only seeks,
                    # the paper's near-sequential access pattern.
                    for full_id, key, off in items:
                        text = read_record_at(handle, off)
                        handle_record(full_id, key, fname, off, text)
        else:
            for fname, items in plan.items():
                path = store.path_of(fname)
                for full_id, key, off in items:
                    res.files_opened += 1
                    text = read_record_at(path, off)
                    handle_record(full_id, key, fname, off, text)

    # Deterministic output regardless of worker interleaving: records in
    # target order, mismatches in (file, offset) order — so the serial and
    # pipelined paths compare byte-identical.
    res.records = {t: found[t] for t in targets if t in found}
    res.mismatches.sort(key=lambda m: (m.file, m.offset, m.expected_id))
    res.read_seconds = time.perf_counter() - t1
    return res


def extract_iter(
    store: RecordStore,
    index,
    targets: Sequence[str],
    *,
    verify: bool = True,
    key_bits: int = 64,
    workers: Optional[int] = None,
    coalesce_gap: int = DEFAULT_COALESCE_GAP,
    span_guess: int = DEFAULT_SPAN_GUESS,
    cache: Optional[RecordCache] = None,
    verify_backend: str = "auto",
    backend=None,   # SpanBackend | name | None (REPRO_READER_BACKEND)
    depth: Optional[int] = None,
    verifier=None,  # shared repro.core.verify.VerifyBatcher
    result: Optional[ExtractionResult] = None,
    service=None,  # repro.service.QueryService — scheduler-coalesced plan path
) -> Iterator[Tuple[str, str]]:
    """Streaming Algorithm 3: yield ``(full_id, record)`` as verified.

    Records are emitted as soon as their file worker has read and verified
    them, so consumers (tokenizers, property extractors, network writers)
    overlap with reads still in flight instead of waiting for the whole
    extraction.  Yield order is completion order, not target order.

    Pass ``result`` (an :class:`ExtractionResult`) to also collect
    ``missing``/``mismatches`` and the I/O counters; its ``records`` dict
    stays empty — the stream IS the record channel.  ``workers=0`` is
    coerced to 1 (the engine is the only streaming path; use
    :func:`extract` for the serial ablation, whose access-pattern knobs —
    ``sort_offsets``/``group_by_file`` — do not apply here: the engine
    always reads each file's targets in coalesced offset order).

    ``service`` routes the plan probe through the query service's
    scheduler and defaults ``cache`` to the service's shared record cache,
    exactly as in :func:`extract`; ``index`` may then be ``None``.
    """
    t0 = time.perf_counter()
    executor = None
    if service is not None:
        plan, missing = service.plan(targets, key_bits=key_bits)
        if cache is None:
            cache = service.cache
        executor = service.read_executor
        if workers is None:
            workers = service.config.read_workers
        if backend is None:
            backend = service.read_backend
        if verifier is None:
            verifier = service.verifier
    else:
        plan, missing = plan_extraction(index, targets, key_bits)
    if result is not None:
        result.missing = missing
        result.plan_seconds = time.perf_counter() - t0

    t1 = time.perf_counter()
    stats = ReadStats()
    if workers is None:
        workers = DEFAULT_WORKERS
    try:
        for ev in stream_plan(
            store,
            plan,
            verify=verify,
            workers=max(1, workers),
            coalesce_gap=coalesce_gap,
            span_guess=span_guess,
            cache=cache,
            verify_backend=verify_backend,
            stats=stats,
            executor=executor,
            backend=backend,
            depth=depth,
            verifier=verifier,
        ):
            if result is not None:
                result.seeks += 1
            if ev.ok:
                yield ev.full_id, ev.text
            elif result is not None:
                result.mismatches.append(
                    Mismatch(ev.full_id, ev.found_id, ev.file, ev.offset, ev.key)
                )
    finally:
        if result is not None:
            result.files_opened += stats.files_opened
            result.bytes_read += stats.bytes_read
            result.spans_read += stats.spans_read
            result.cache_hits += stats.cache_hits
            result.read_backend = result.read_backend or stats.backend
            result.inflight_peak = max(result.inflight_peak, stats.inflight_peak)
            result.verify_batches += stats.verify_batches
            result.verify_records += stats.verify_records
            result.verify_batch_max = max(
                result.verify_batch_max, stats.verify_batch_max
            )
            result.mismatches.sort(key=lambda m: (m.file, m.offset, m.expected_id))
            result.read_seconds = time.perf_counter() - t1
