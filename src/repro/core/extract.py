"""Algorithm 3 — index-based extraction with grouped, offset-sorted seeks.

Phase 2 of the paper's architecture.  The three published optimizations are
all here and individually switchable (so the benchmarks can ablate them,
Table II / §IV.D):

1. **GroupByFilename** — one ``open()`` per file containing targets
   (477,123 potential opens → 312 in the paper).
2. **Offset-sorted traversal** — targets within a file are visited in
   ascending byte order, converting random seeks into near-sequential
   forward reads (10–100× effective-throughput on spinning disks; still
   measurable on SSD/page-cache via readahead).
3. **Defensive verification** — every extracted record's identifier is
   *recomputed from its structural data* and compared against the expected
   identifier.  This is the step that exposed the paper's InChIKey
   collisions (§VI.A): under ``hashed_key`` indexing, a collision fetches a
   structurally different molecule whose recomputed full id mismatches.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .identifiers import canonical_id_from_structure, hashed_key
from .records import RecordStore, extract_property, read_record_at
from .sdfgen import PROP_ID

__all__ = ["ExtractionResult", "Mismatch", "plan_extraction", "extract"]


@dataclass(frozen=True)
class Mismatch:
    """A verification failure: what the index promised vs what the bytes say."""

    expected_id: str
    found_id: str
    file: str
    offset: int
    lookup_key: str


@dataclass
class ExtractionResult:
    records: Dict[str, str] = field(default_factory=dict)   # full_id -> record
    missing: List[str] = field(default_factory=list)        # not in index
    mismatches: List[Mismatch] = field(default_factory=list)
    files_opened: int = 0
    seeks: int = 0
    bytes_read: int = 0
    seconds: float = 0.0

    @property
    def found(self) -> int:
        return len(self.records)


def plan_extraction(
    index,
    targets: Sequence[str],
    key_bits: int = 64,
    sort_offsets: bool = True,
) -> Tuple[Dict[str, List[Tuple[str, str, int]]], List[str]]:
    """Build the per-file extraction plan through ONE batched lookup.

    Returns ``(plan, missing)`` where ``plan[file] = [(full_id, lookup_key,
    offset), ...]`` sorted by ascending offset (if ``sort_offsets``).

    ``index`` is any read backend exposing the batch contract —
    :class:`ByteOffsetIndex` (dict), :class:`BinaryIndex` (packed sidecar),
    or :class:`repro.core.store.IndexStore` (sharded mmap store, where the
    single ``locate_batch`` call amortizes digesting, Bloom filtering, and
    shard probing over the whole target list).

    Targets are always full canonical ids (the ChEMBL∩eMolecules list is
    known by full id); under ``hashed_key`` indexing the lookup key is the
    digest of the target id — exactly the paper's pipeline before the §VI.C
    migration.
    """
    plan: Dict[str, List[Tuple[str, str, int]]] = {}
    missing: List[str] = []
    hashed = getattr(index, "key_mode", "full_id") == "hashed_key"
    keys = [
        hashed_key(t, key_bits) if hashed else t for t in targets
    ]
    locate = getattr(index, "locate_batch", None)
    if locate is not None:
        locs = locate(keys)
    else:  # minimal backends: fall back to per-key lookups
        locs = [index.lookup(k) for k in keys]
    for full_id, key, loc in zip(targets, keys, locs):
        if loc is None:
            missing.append(full_id)
            continue
        fname, off = loc
        plan.setdefault(fname, []).append((full_id, key, off))
    if sort_offsets:
        for fname in plan:
            plan[fname].sort(key=lambda t: t[2])
    return plan, missing


def extract(
    store: RecordStore,
    index,  # ByteOffsetIndex | BinaryIndex | IndexStore (batch read contract)
    targets: Sequence[str],
    verify: bool = True,
    sort_offsets: bool = True,
    group_by_file: bool = True,
    key_bits: int = 64,
) -> ExtractionResult:
    """Algorithm 3: seek-extract every target through the index.

    With ``group_by_file=False`` the ungrouped access pattern (one open per
    target) is used — kept for the ablation benchmark only.
    """
    t0 = time.perf_counter()
    res = ExtractionResult()
    plan, missing = plan_extraction(index, targets, key_bits, sort_offsets)
    res.missing = missing

    def handle_record(full_id: str, key: str, fname: str, off: int, text: str):
        res.seeks += 1
        res.bytes_read += len(text)
        if verify:
            try:
                recomputed = canonical_id_from_structure(text)
            except ValueError:
                recomputed = "<unparseable>"
            if recomputed != full_id:
                # The paper's "log error" branch — and the collision signal.
                res.mismatches.append(
                    Mismatch(full_id, recomputed, fname, off, key)
                )
                return
        res.records[full_id] = text

    if group_by_file:
        for fname, items in plan.items():
            path = store.path_of(fname)
            res.files_opened += 1
            with open(path, "rb") as handle:
                # offsets ascend (sort_offsets) => forward-only seeks, the
                # paper's near-sequential access pattern.
                for full_id, key, off in items:
                    text = read_record_at(handle, off)
                    handle_record(full_id, key, fname, off, text)
    else:
        for fname, items in plan.items():
            path = store.path_of(fname)
            for full_id, key, off in items:
                res.files_opened += 1
                text = read_record_at(path, off)
                handle_record(full_id, key, fname, off, text)

    res.seconds = time.perf_counter() - t0
    return res
