"""Checkpointing with a byte-offset catalog — the paper's architecture
reapplied to training state.

On-disk layout per checkpoint::

    <dir>/step_00000042/
        shard_00000.bin     # every tensor's raw bytes, concatenated
        catalog.csv         # name, byte_offset, nbytes, dtype, shape, digest
        meta.json           # step, tree structure, framework versions

Exactly the paper's design points, transplanted:

* **byte-offset catalog** → O(1) ``seek()`` restore of any single tensor
  (partial restores for elastic resharding or tensor surgery never read
  the whole shard file);
* **CSV catalog** for the same reasons the paper chose CSV for its index
  (§IV.B): debuggable, greppable, language-neutral;
* **defensive verification** (Algorithm 3 lines 8–12): every restored
  tensor's blake2b digest is recomputed and compared to the catalog —
  index corruption or torn writes are detected, not propagated;
* **atomic publish**: tmp-dir + ``os.replace`` rename, so a crash mid-save
  never yields a half-checkpoint that restore could pick up.

Saves can run asynchronously (background thread snapshots host copies);
``keep_last`` retention prunes old steps.
"""

from __future__ import annotations

import csv
import hashlib
import json
import os
import shutil
import threading
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax

__all__ = ["CheckpointManager", "CatalogEntry", "save_pytree", "restore_pytree"]

PyTree = Any
_CATALOG_HEADER = ["name", "byte_offset", "nbytes", "dtype", "shape", "digest"]


def _flatten_with_names(tree: PyTree) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, np.asarray(leaf)))
    return out


def _digest(buf: bytes) -> str:
    return hashlib.blake2b(buf, digest_size=16).hexdigest()


@dataclass(frozen=True)
class CatalogEntry:
    name: str
    byte_offset: int
    nbytes: int
    dtype: str
    shape: Tuple[int, ...]
    digest: str


def save_pytree(tree: PyTree, directory: Path, meta: Optional[dict] = None) -> Path:
    """Write one catalog checkpoint (atomic)."""
    directory = Path(directory)
    tmp = directory.with_name(directory.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    entries: List[CatalogEntry] = []
    offset = 0
    with open(tmp / "shard_00000.bin", "wb") as f:
        for name, arr in _flatten_with_names(tree):
            buf = arr.tobytes()
            f.write(buf)
            entries.append(
                CatalogEntry(
                    name, offset, len(buf), str(arr.dtype),
                    tuple(arr.shape), _digest(buf),
                )
            )
            offset += len(buf)
    with open(tmp / "catalog.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(_CATALOG_HEADER)
        for e in entries:
            w.writerow(
                [e.name, e.byte_offset, e.nbytes, e.dtype,
                 json.dumps(list(e.shape)), e.digest]
            )
    (tmp / "meta.json").write_text(json.dumps(meta or {}, indent=1))
    if directory.exists():
        shutil.rmtree(directory)
    os.replace(tmp, directory)  # atomic publish
    return directory


def load_catalog(directory: Path) -> Dict[str, CatalogEntry]:
    out: Dict[str, CatalogEntry] = {}
    with open(Path(directory) / "catalog.csv", newline="") as f:
        r = csv.reader(f)
        header = next(r)
        if header != _CATALOG_HEADER:
            raise ValueError(f"bad catalog header {header}")
        for name, off, nb, dt, shp, dg in r:
            out[name] = CatalogEntry(
                name, int(off), int(nb), dt, tuple(json.loads(shp)), dg
            )
    return out


def read_tensor(directory: Path, entry: CatalogEntry, verify: bool = True) -> np.ndarray:
    """O(1) single-tensor restore: seek to the catalog offset and read."""
    with open(Path(directory) / "shard_00000.bin", "rb") as f:
        f.seek(entry.byte_offset)
        buf = f.read(entry.nbytes)
    if verify and _digest(buf) != entry.digest:
        raise IOError(
            f"checkpoint integrity failure for {entry.name!r} "
            f"(digest mismatch — corrupted shard or stale catalog)"
        )
    return np.frombuffer(buf, dtype=np.dtype(entry.dtype)).reshape(entry.shape)


def restore_pytree(tree_like: PyTree, directory: Path, verify: bool = True) -> PyTree:
    """Restore into the structure of ``tree_like`` (names must match)."""
    catalog = load_catalog(directory)
    names = [n for n, _ in _flatten_with_names(tree_like)]
    missing = [n for n in names if n not in catalog]
    if missing:
        raise KeyError(f"checkpoint missing tensors: {missing[:5]}…")
    # offset-sorted read order: the paper's sequential-access optimization
    order = sorted(names, key=lambda n: catalog[n].byte_offset)
    loaded: Dict[str, np.ndarray] = {}
    with open(Path(directory) / "shard_00000.bin", "rb") as f:
        for n in order:
            e = catalog[n]
            f.seek(e.byte_offset)
            buf = f.read(e.nbytes)
            if verify and _digest(buf) != e.digest:
                raise IOError(f"integrity failure for {n!r}")
            loaded[n] = np.frombuffer(buf, np.dtype(e.dtype)).reshape(e.shape)
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    return jax.tree_util.tree_unflatten(
        treedef, [loaded[n] for n in names]
    )


class CheckpointManager:
    """Async, retained, resumable checkpoints."""

    def __init__(self, root: Path, keep_last: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._pending: Optional[threading.Thread] = None

    def _dir_for(self, step: int) -> Path:
        return self.root / f"step_{step:08d}"

    def steps(self) -> List[int]:
        out = []
        for p in self.root.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp"):
                try:
                    out.append(int(p.name.split("_")[1]))
                except (IndexError, ValueError):
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, tree: PyTree, meta: Optional[dict] = None,
             blocking: bool = True) -> None:
        # snapshot to host memory first (device buffers may be donated next step)
        host = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        meta = dict(meta or {}, step=step, time=time.time())

        def work():
            save_pytree(host, self._dir_for(step), meta)
            self._prune()

        if blocking:
            work()
        else:
            self.wait()
            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore(self, tree_like: PyTree, step: Optional[int] = None) -> Tuple[int, PyTree]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        tree = restore_pytree(tree_like, self._dir_for(step))
        return step, tree

    def restore_tensor(self, step: int, name: str) -> np.ndarray:
        """Partial restore: one tensor via its catalog offset (O(1) seek)."""
        d = self._dir_for(step)
        catalog = load_catalog(d)
        return read_tensor(d, catalog[name])

    def _prune(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self._dir_for(s), ignore_errors=True)
