"""Distribution layer: logical-axis sharding rules + gradient compression.

Two submodules, both mesh-optional (single-device code pays nothing):

* :mod:`repro.dist.logical` — named logical axes ("batch", "heads",
  "embed", …) mapped to mesh axes by a context-managed rule table.
  Models annotate activations with :func:`~repro.dist.logical.constrain`
  and return parameter *specs* (tuples of logical names); the launcher
  turns specs into NamedShardings (:mod:`repro.launch.sharding`).
* :mod:`repro.dist.compress` — int8 / top-k gradient compression with
  error feedback, hooked between grad computation and the optimizer by
  :mod:`repro.train.loop`.
"""

from repro.dist.logical import (
    AxisRules,
    DEFAULT_RULES,
    axis_rules,
    constrain,
    current_rules,
    divisible_spec,
)
from repro.dist.compress import (
    ErrorFeedbackCompressor,
    dequantize_int8,
    make_compressor,
    quantize_int8,
)

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "axis_rules",
    "constrain",
    "current_rules",
    "divisible_spec",
    "ErrorFeedbackCompressor",
    "dequantize_int8",
    "make_compressor",
    "quantize_int8",
]
