"""Named logical-axis sharding: rule table, spec derivation, ``constrain``.

The models never mention mesh axes.  They speak in *logical* axis names —
"batch", "seq", "heads", "embed", "d_ff", … — both for parameter specs
(tuples returned next to params by every ``init_*``) and for activation
annotations (:func:`constrain` calls at layer boundaries).  This module
owns the translation:

* :class:`AxisRules` maps each logical name to the mesh axes it shards
  over (a name, a tuple of names for multi-axis groups like FSDP over
  ``("pod", "data")``, or ``None`` for replicated).
* :data:`DEFAULT_RULES` encodes the production layout: batch and the
  parameters' d_model dim over the data-parallel axes (FSDP/ZeRO-3),
  heads / d_ff / vocab / experts over "model" (tensor parallel), and the
  sequence-parallel residual layout ("seq_sp" → "model").
* :func:`axis_rules` is a context manager that swaps the active table —
  experiments override individual rules without touching model code.
* :func:`constrain` applies ``jax.lax.with_sharding_constraint`` with the
  spec the active rules produce **iff a mesh is active**; with no mesh it
  is the identity, so single-device smoke tests and the CPU container pay
  nothing.  Non-divisible dims degrade to replication (never an error).
* :func:`divisible_spec` is that degradation as a standalone helper — the
  launcher uses it when turning param/cache specs into NamedShardings.

Rules consult only ``mesh.axis_names`` / ``mesh.shape``, so a 1-device
smoke mesh, the 16×16 production pod and the 2×16×16 multi-pod mesh all
resolve from one table (absent axes drop out per rule).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "axis_rules",
    "constrain",
    "current_rules",
    "divisible_spec",
]

# A rule's right-hand side: replicated, one mesh axis, or an ordered group
# of mesh axes (major → minor, e.g. FSDP over ("pod", "data")).
MeshAxes = Union[None, str, Tuple[str, ...]]


def _current_mesh() -> Optional[Mesh]:
    """The mesh installed by ``with mesh:``, or None outside any context."""
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover - jax internals moved
        try:
            from jax.interpreters import pxla

            m = pxla.thread_resources.env.physical_mesh
        except Exception:
            return None
    return None if m is None or m.empty else m


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Immutable logical-name → mesh-axes table.

    The table is total over the names the models use; unknown names
    resolve to replicated (None) so adding a new logical axis in a model
    degrades gracefully until a rule is written for it.
    """

    table: Mapping[str, MeshAxes]

    def mesh_axes(self, logical: str, axis_names: Sequence[str]) -> MeshAxes:
        """Resolve one logical name against the axes a mesh actually has.

        Group rules keep only present axes — ("pod", "data") degrades to
        "data" on a single-pod mesh — and a rule with no surviving axis
        (or an unknown name) resolves to None (replicated).
        """
        want = self.table.get(logical)
        if want is None:
            return None
        if isinstance(want, str):
            want = (want,)
        present = tuple(a for a in want if a in tuple(axis_names))
        if not present:
            return None
        return present[0] if len(present) == 1 else present

    def spec(self, logical_axes: Sequence[Optional[str]], mesh: Any) -> P:
        """PartitionSpec for a tuple of logical names on ``mesh``.

        A mesh axis is consumed at most once per spec (GSPMD rejects
        duplicates): when two dims map to the same axis — ("d_ff",
        "vocab") both → "model" — the first dim keeps it and later dims
        drop it (replicated), matching the "first dim wins" convention of
        t5x/flax logical partitioning.
        """
        names = tuple(getattr(mesh, "axis_names", ()) or ())
        used: set = set()
        parts = []
        for logical in logical_axes:
            if logical is None:
                parts.append(None)
                continue
            axes = self.mesh_axes(logical, names)
            if axes is None:
                parts.append(None)
                continue
            group = (axes,) if isinstance(axes, str) else axes
            group = tuple(a for a in group if a not in used)
            if not group:
                parts.append(None)
                continue
            used.update(group)
            parts.append(group[0] if len(group) == 1 else group)
        return P(*parts)

    def extend(self, **overrides: MeshAxes) -> "AxisRules":
        """A new table with ``overrides`` replacing / adding rules."""
        merged = dict(self.table)
        merged.update(overrides)
        return AxisRules(table=merged)


# Production layout (DESIGN rationale in the module docstring):
#   dp / FSDP group  — batch and parameter d_model over ("pod", "data")
#   tensor parallel  — head-, ff-, vocab- and expert-sharded dims → "model"
#   sequence parallel— the residual's seq dim → "model" between TP regions
#   replicated       — per-layer stack dims, norm weights, tiny vectors
DEFAULT_RULES = AxisRules(
    table={
        # data-parallel / FSDP group
        "batch": ("pod", "data"),
        "embed": ("pod", "data"),
        # tensor-parallel dims
        "heads": "model",
        "kv_heads": "model",
        "d_ff": "model",
        "vocab": "model",
        "experts": "model",
        "conv_dim": "model",
        "ssm_heads": "model",
        # sequence-parallel residual layout (Megatron SP)
        "seq_sp": "model",
        # replicated
        "seq": None,
        "embed_act": None,
        "expert_ff": None,
        "layers": None,
        "block_pos": None,
        "frames": None,
    }
)


class _RuleStack(threading.local):
    def __init__(self):
        self.stack: list = []


_STACK = _RuleStack()


def current_rules() -> AxisRules:
    """The innermost :func:`axis_rules` table, or :data:`DEFAULT_RULES`."""
    return _STACK.stack[-1] if _STACK.stack else DEFAULT_RULES


@contextlib.contextmanager
def axis_rules(rules: Union[AxisRules, Mapping[str, MeshAxes]]):
    """Install a rule table for the dynamic extent of the block.

    Accepts a full :class:`AxisRules` or a mapping of overrides applied
    on top of the currently active table::

        with axis_rules({"seq_sp": None}):   # disable sequence parallelism
            loss = jax.jit(api.loss)(params, batch)
    """
    if not isinstance(rules, AxisRules):
        rules = current_rules().extend(**dict(rules))
    _STACK.stack.append(rules)
    try:
        yield rules
    finally:
        _STACK.stack.pop()


def _entry_divisible(entry: MeshAxes, dim: int, sizes: Mapping[str, int]) -> MeshAxes:
    """Shrink one spec entry until its axis-size product divides ``dim``.

    Group entries drop minor axes first (keep the longest divisible major
    prefix); a single axis either fits or is dropped entirely.
    """
    if entry is None:
        return None
    group = (entry,) if isinstance(entry, str) else tuple(entry)
    while group:
        n = 1
        for a in group:
            n *= int(sizes.get(a, 1))
        if n > 0 and dim % n == 0 and dim >= n:
            break
        group = group[:-1]
    if not group:
        return None
    return group[0] if len(group) == 1 else group


def divisible_spec(spec: Union[P, Sequence[Any]], shape: Sequence[int], mesh: Any) -> P:
    """Replication fallback: drop spec entries that don't divide the shape.

    ``spec`` entries are mesh-axis names (or axis groups) positionally
    matched with ``shape``; any dim whose assigned axes' total extent does
    not divide it falls back to None.  GSPMD would otherwise either pad or
    reject the sharding — for the tiny smoke configs that hit this path
    (12 heads on a model=16 mesh) replication is the correct degradation.
    """
    dims = tuple(shape)
    sizes = dict(getattr(mesh, "shape", {}) or {})
    entries = tuple(spec)[: len(dims)]
    parts = [
        _entry_divisible(entry, dims[i], sizes) for i, entry in enumerate(entries)
    ]
    return P(*parts)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate ``x`` with the sharding the active rules give these axes.

    Identity when no mesh is active (single-device paths trace exactly the
    same jaxpr they always did).  Under a mesh, resolves the logical names
    through :func:`current_rules`, degrades non-divisible dims to
    replication, and applies ``with_sharding_constraint``.  Fewer names
    than ``x.ndim`` leaves trailing dims unconstrained.
    """
    mesh = _current_mesh()
    if mesh is None:
        return x
    spec = current_rules().spec(logical_axes, mesh)
    spec = divisible_spec(spec, x.shape, mesh)
    if all(entry is None for entry in tuple(spec)):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
