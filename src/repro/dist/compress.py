"""Gradient compression with error feedback for bandwidth-bound training.

At pod scale the all-reduce of fp32 gradients is the dominant wire cost of
a data-parallel step.  This module provides the standard remedy pair:

* **Lossy per-leaf compression** — :func:`quantize_int8` (symmetric int8,
  one fp32 scale per leaf: 4× fewer bytes on the wire) and a magnitude
  top-k sparsifier.  Both are pure jnp and jit-compatible, so the
  compressor runs *inside* the jitted train step.
* **Error feedback** (Seide et al. 2014, Karimireddy et al. 2019) —
  :class:`ErrorFeedbackCompressor` keeps a per-leaf fp32 residual of what
  compression discarded and adds it back before compressing the next
  step.  The telescoping sum ``Σ compressed + residual == Σ true`` holds
  exactly, so the optimizer sees an unbiased gradient stream over time
  and convergence matches uncompressed training to first order.

The trainer hooks a compressor between grad computation and the AdamW
update (:mod:`repro.train.loop`); which one — if any — is chosen by
``TrainerConfig`` through :func:`make_compressor`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "topk_mask",
    "ErrorFeedbackCompressor",
    "make_compressor",
]

PyTree = Any

# Guards the scale against an all-zero leaf (0/0 → NaN grads downstream).
_MIN_SCALE = 1e-12


def quantize_int8(
    x: jax.Array, per_channel: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization, per-leaf or per-channel.

    Returns ``(q, scale)`` with ``q = round(x / scale)`` in [-127, 127]
    and ``scale = max|x| / 127``, so the round-trip error is bounded by
    ``scale / 2`` elementwise.

    ``per_channel=True`` computes one scale per axis-0 slice (shape
    ``(d0, 1, ..., 1)`` — broadcastable) instead of a single fp32 scalar.
    For wide-variance leaves — embedding tables, gate matrices where row
    magnitudes span orders of magnitude — a per-tensor scale collapses
    small-magnitude rows to zero; per-channel scales bound each row's
    error by ITS OWN amax/254, at d0×4 bytes of extra wire cost.  Leaves
    with fewer than 2 dims fall back to the per-tensor scale (a vector
    leaf's "channels" are single elements — scales would outweigh data).
    """
    xf = x.astype(jnp.float32)
    if per_channel and xf.ndim >= 2:
        amax = jnp.max(jnp.abs(xf), axis=tuple(range(1, xf.ndim)), keepdims=True)
    else:
        amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax / 127.0, _MIN_SCALE)
    q = jnp.clip(jnp.round(xf / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_int8` (fp32 output; scale broadcasts, so
    scalar and per-channel scales dequantize identically)."""
    return q.astype(jnp.float32) * scale


def topk_mask(x: jax.Array, frac: float) -> jax.Array:
    """Keep the ``frac`` largest-|x| entries of a leaf, zero the rest.

    Threshold via a full sort of |x| — leaves are weight-shaped (≤ a few
    M elements), and the sort happens once per leaf per step inside an
    already-compiled train step.
    """
    xf = x.astype(jnp.float32)
    flat = jnp.abs(xf.reshape(-1))
    k = max(1, int(flat.shape[0] * frac))
    thresh = jnp.sort(flat)[flat.shape[0] - k]
    return jnp.where(jnp.abs(xf) >= thresh, xf, 0.0)


@dataclasses.dataclass
class ErrorFeedbackCompressor:
    """Per-leaf lossy compression + error-feedback residual.

    The residual pytree lives in the train state under :attr:`state_key`
    (the trainer inits it via :meth:`init` and the checkpoint manager
    persists it like any other state leaf, so crash recovery preserves
    the accumulated error).  :meth:`apply` is pure and jit-compatible:

        grads, state = compressor.apply(grads, state)

    ``method`` selects the lossy step: "int8" (default) or "topk"
    (magnitude sparsification at :attr:`topk_frac`); :attr:`per_channel`
    switches int8 to axis-0 per-channel scales (wide-variance leaves).
    """

    method: str = "int8"
    topk_frac: float = 0.1
    per_channel: bool = False
    state_key: str = "ef_residual"

    def __post_init__(self):
        if self.method not in ("int8", "topk"):
            raise ValueError(f"unknown compression method {self.method!r}")

    def init(self, params: PyTree) -> PyTree:
        """Zero fp32 residual, one leaf per parameter."""
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

    def _compress_leaf(self, g: jax.Array) -> jax.Array:
        if self.method == "topk":
            return topk_mask(g, self.topk_frac)
        q, s = quantize_int8(g, per_channel=self.per_channel)
        return dequantize_int8(q, s)

    def apply(
        self, grads: PyTree, state: Dict[str, Any]
    ) -> Tuple[PyTree, Dict[str, Any]]:
        """Compress ``grads`` (+ carried residual), update the residual.

        ``state`` is any dict holding the residual under :attr:`state_key`
        — the full train state in the trainer, a bare one-key dict in
        tests.  Returns the decompressed (wire-equivalent) grads and the
        state with the new residual.
        """
        residual = state[self.state_key]
        total = jax.tree_util.tree_map(
            lambda g, r: g.astype(jnp.float32) + r, grads, residual
        )
        compressed = jax.tree_util.tree_map(self._compress_leaf, total)
        new_residual = jax.tree_util.tree_map(
            lambda t, c: t - c, total, compressed
        )
        new_state = dict(state)
        new_state[self.state_key] = new_residual
        return compressed, new_state


# name → constructor kwargs; the names are what TrainerConfig / the train
# launcher accept, so adding a scheme here surfaces it everywhere at once.
_COMPRESSORS: Dict[str, Dict[str, Any]] = {
    "int8_ef": {"method": "int8"},
    "int8_pc_ef": {"method": "int8", "per_channel": True},
    "topk_ef": {"method": "topk"},
}


def make_compressor(
    name: Optional[str], **overrides: Any
) -> Optional[ErrorFeedbackCompressor]:
    """Build a compressor by name ("int8_ef", "int8_pc_ef", "topk_ef");
    None/"none" → None."""
    if name is None or name == "none":
        return None
    try:
        kwargs = dict(_COMPRESSORS[name])
    except KeyError:
        raise ValueError(
            f"unknown compressor {name!r}; choose from "
            f"{sorted(_COMPRESSORS)} or 'none'"
        ) from None
    kwargs.update(overrides)
    return ErrorFeedbackCompressor(**kwargs)
