"""Pure-jnp oracle for ``ssd_scan``: Mamba2 inter-chunk state recurrence.

The SSD (state-space duality) chunked form splits the sequence into chunks;
intra-chunk terms are dense matmuls (MXU-friendly, left in XLA), while the
inter-chunk term is the sequential recurrence this kernel owns:

    h[0]     = 0
    h[c + 1] = decay[c] * h[c] + states[c]

with per-(batch·head) state matrices ``states (BH, C, P, N)`` and scalar
chunk decays ``decay (BH, C)``.  Output is the *prefix* state entering each
chunk: ``prefix[c] = h[c]``.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["ssd_scan_ref"]


def ssd_scan_ref(states: jax.Array, decay: jax.Array) -> jax.Array:
    """``states (BH, C, P, N) f32, decay (BH, C) f32 → prefix (BH, C, P, N)``."""
    if states.ndim != 4 or decay.ndim != 2:
        raise ValueError(f"bad shapes {states.shape} {decay.shape}")
    bh, c, p, n = states.shape

    def step(h, xs):
        s_c, d_c = xs
        out = h
        h = d_c[:, None, None] * h + s_c
        return h, out

    h0 = jnp.zeros((bh, p, n), states.dtype)
    # scan over the chunk axis
    _, prefix = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(decay, 1, 0)),
    )
    return jnp.moveaxis(prefix, 0, 1)
