"""Pallas TPU kernel for the SSD inter-chunk state scan.

Grid ``(BH, C)`` with the chunk axis innermost (sequential); the running
state ``h (P, N)`` lives in f32 VMEM scratch across chunk steps.  Each step
emits the prefix state then updates the carry — a single fused
multiply-add over a (P, N) tile (VPU), with the (BH) axis grid-parallel.

VMEM per step (P=64, N=128): state tile 64×128×4 B = 32 KiB ×3 ≈ 96 KiB ✓
The win vs XLA's unrolled scan: the carry never round-trips to HBM between
chunks — only ``states``/``prefix`` stream through, making the op purely
bandwidth-bound on the chunk tensors.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_scan_pallas"]


def _ssd_kernel(states_ref, decay_ref, prefix_ref, h_ref):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    h = h_ref[...]
    prefix_ref[0, 0] = h.astype(prefix_ref.dtype)
    d = decay_ref[0, 0]
    h_ref[...] = d * h + states_ref[0, 0].astype(jnp.float32)


def ssd_scan_pallas(
    states: jax.Array,  # (BH, C, P, N)
    decay: jax.Array,   # (BH, C)
    interpret: bool = False,
) -> jax.Array:
    bh, c, p, n = states.shape
    if decay.shape != (bh, c):
        raise ValueError(f"decay {decay.shape} != {(bh, c)}")
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        _ssd_kernel,
        grid=(bh, c),
        in_specs=[
            pl.BlockSpec((1, 1, p, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, p, n), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, c, p, n), states.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(states, decay)
