"""Public jit'd entry point for the SSD inter-chunk scan."""

from __future__ import annotations

import functools

import jax

from .kernel import ssd_scan_pallas
from .ref import ssd_scan_ref

__all__ = ["ssd_scan"]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ssd_scan(
    states: jax.Array,
    decay: jax.Array,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return ssd_scan_pallas(states, decay, interpret=interpret)
    return ssd_scan_ref(states, decay)
