"""Pure-jnp oracle for ``sorted_probe``: membership of 64-bit keys in a
sorted table.

Keys are ``(hi, lo)`` uint32 pairs (TPU-friendly — no uint64 lanes).  The
reference is a branch-free vectorized binary search over the full table:
``log2(M)`` rounds of midpoint gathers.  Returns, per query:

* ``found`` — whether the key is present,
* ``pos``   — the lower-bound insertion index (== match index when found).

This is the paper's Phase-2 "consult the in-memory index" operation
(Algorithm 3 line 5) recast for TPU: a sorted dense array + binary search
replaces the CPU hash map (§IV.A's O(1) dict), trading O(1) expected for
O(log M) worst-case but gaining fully dense, pointer-free memory traffic.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["sorted_probe_ref", "pair_less", "pair_eq", "sort_pairs"]


def pair_less(a_hi, a_lo, b_hi, b_lo):
    """(a_hi,a_lo) < (b_hi,b_lo) lexicographically, branch-free."""
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo))


def pair_eq(a_hi, a_lo, b_hi, b_lo):
    return (a_hi == b_hi) & (a_lo == b_lo)


def sort_pairs(keys: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Sort ``(N, 2)`` uint32 pairs lexicographically; returns (sorted, order).

    Two stable argsort passes (LSD radix over the two lanes).
    """
    lo = keys[:, 1]
    hi = keys[:, 0]
    o1 = jnp.argsort(lo, stable=True)
    o2 = jnp.argsort(hi[o1], stable=True)
    order = o1[o2]
    return keys[order], order


def sorted_probe_ref(
    queries: jax.Array, table: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """``queries (Q,2) uint32`` against ``table (M,2) uint32`` (sorted asc).

    Returns ``(found (Q,) bool, pos (Q,) int32)`` with ``pos`` the lower
    bound (first index with table[idx] >= query).
    """
    if queries.ndim != 2 or queries.shape[1] != 2:
        raise ValueError(f"queries must be (Q, 2), got {queries.shape}")
    if table.ndim != 2 or table.shape[1] != 2:
        raise ValueError(f"table must be (M, 2), got {table.shape}")
    q = queries.shape[0]
    m = table.shape[0]
    if m == 0:
        return jnp.zeros((q,), bool), jnp.zeros((q,), jnp.int32)
    q_hi, q_lo = queries[:, 0], queries[:, 1]
    t_hi, t_lo = table[:, 0], table[:, 1]

    lo_b = jnp.zeros((q,), jnp.int32)
    hi_b = jnp.full((q,), m, jnp.int32)
    # fixed-step branch-free search; `active` makes the converged state a
    # fixed point (extra steps must not walk past the answer)
    steps = max(1, m.bit_length())
    for _ in range(steps):
        active = lo_b < hi_b
        mid = (lo_b + hi_b) // 2
        mh = jnp.take(t_hi, mid, mode="clip")
        ml = jnp.take(t_lo, mid, mode="clip")
        go_right = pair_less(mh, ml, q_hi, q_lo)  # table[mid] < query
        lo_b = jnp.where(active & go_right, mid + 1, lo_b)
        hi_b = jnp.where(active & ~go_right, mid, hi_b)
    pos = lo_b
    ph = jnp.take(t_hi, jnp.minimum(pos, m - 1))
    pl_ = jnp.take(t_lo, jnp.minimum(pos, m - 1))
    found = (pos < m) & pair_eq(ph, pl_, q_hi, q_lo)
    return found, pos.astype(jnp.int32)
