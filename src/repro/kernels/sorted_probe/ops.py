"""Public jit'd entry point for ``sorted_probe`` (stages A + B + C).

``sorted_probe(queries, table)`` — membership of (Q,2) uint32 keys in a
sorted unique (M,2) uint32 table.  Dispatches stage B to the Pallas kernel
on TPU (or when forced), otherwise runs the pure-jnp reference.

Exactness guarantee: bucket overflow (more than QMAX queries routed to one
table block — possible only under adversarial key clustering; digests are
uniform) is detected and those queries are resolved through the reference
binary search, so results are always exact.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_TABLE_BLOCK, SENTINEL, probe_blocks_pallas
from .ref import pair_eq, pair_less, sort_pairs, sorted_probe_ref

__all__ = ["sorted_probe", "sorted_probe_pallas"]


def _fence_assign(sorted_q: jax.Array, fences: jax.Array) -> jax.Array:
    """Block id per query: rightmost fence <= q (branch-free bin search)."""
    nb = fences.shape[0]
    q_hi, q_lo = sorted_q[:, 0], sorted_q[:, 1]
    f_hi, f_lo = fences[:, 0], fences[:, 1]
    lo_b = jnp.zeros((sorted_q.shape[0],), jnp.int32)
    hi_b = jnp.full((sorted_q.shape[0],), nb, jnp.int32)
    # fixed-step search with convergence guard (see ref.sorted_probe_ref)
    steps = max(1, nb.bit_length())
    for _ in range(steps):
        active = lo_b < hi_b
        mid = (lo_b + hi_b) // 2
        mh = jnp.take(f_hi, mid, mode="clip")
        ml = jnp.take(f_lo, mid, mode="clip")
        le = ~pair_less(q_hi, q_lo, mh, ml)  # fence[mid] <= q
        lo_b = jnp.where(active & le, mid + 1, lo_b)
        hi_b = jnp.where(active & ~le, mid, hi_b)
    return jnp.maximum(lo_b - 1, 0)  # rightmost fence <= q (clamped)


@functools.partial(
    jax.jit, static_argnames=("table_block", "qmax", "interpret")
)
def sorted_probe_pallas(
    queries: jax.Array,
    table: jax.Array,
    table_block: int = DEFAULT_TABLE_BLOCK,
    qmax: int | None = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Fence-partitioned Pallas probe; exact (overflow falls back to ref)."""
    q_n = queries.shape[0]
    m = table.shape[0]
    if m == 0 or q_n == 0:
        return jnp.zeros((q_n,), bool), jnp.zeros((q_n,), jnp.int32)

    bt = min(table_block, max(128, m))
    nblocks = (m + bt - 1) // bt
    m_pad = nblocks * bt
    pad = jnp.full((m_pad - m, 2), SENTINEL, jnp.uint32)
    t_pad = jnp.concatenate([table, pad], axis=0) if m_pad != m else table
    fences = t_pad[::bt]  # (nblocks, 2)

    # --- stage A: sort queries, assign blocks, bucket ----------------------
    sorted_q, order = sort_pairs(queries)
    bid = _fence_assign(sorted_q, fences)  # (Q,) block per sorted query
    # rank within block: queries sorted => equal bids contiguous
    first = jnp.searchsorted(bid, jnp.arange(nblocks, dtype=bid.dtype))
    rank = jnp.arange(q_n, dtype=jnp.int32) - jnp.take(first, bid).astype(jnp.int32)
    if qmax is None:
        avg = (q_n + nblocks - 1) // nblocks
        qmax = max(64, min(q_n, 4 * avg))
        qmax = (qmax + 7) // 8 * 8
    overflow = rank >= qmax
    # overflow queries scatter into a discard slot (index qmax) so they can
    # never clobber a legitimate bucket entry
    rank_c = jnp.minimum(rank, qmax)
    buckets = jnp.full((nblocks, qmax + 1, 2), SENTINEL, jnp.uint32)
    buckets = buckets.at[bid, rank_c].set(sorted_q)[:, :qmax]

    # --- stage B: Pallas blocked probe -------------------------------------
    found_b, pos_b = probe_blocks_pallas(
        t_pad, buckets, table_block=bt, interpret=interpret
    )

    # --- stage C: gather back + overflow fallback --------------------------
    found_s = found_b[bid, rank_c].astype(bool)
    pos_s = pos_b[bid, rank_c]
    any_ovf = jnp.any(overflow)

    def _with_fallback():
        f_ref, p_ref = sorted_probe_ref(sorted_q, table)
        return (
            jnp.where(overflow, f_ref, found_s),
            jnp.where(overflow, p_ref, pos_s),
        )

    def _no_fallback():
        return found_s, pos_s

    found_s, pos_s = jax.lax.cond(any_ovf, _with_fallback, _no_fallback)
    # mask sentinel-padding hits beyond the real table
    found_s = found_s & (pos_s < m)
    # unsort
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(q_n, dtype=order.dtype))
    return found_s[inv], pos_s[inv]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def sorted_probe(
    queries: jax.Array,
    table: jax.Array,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Membership probe; kernel on TPU, pure-jnp reference elsewhere."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return sorted_probe_pallas(queries, table, interpret=interpret)
    return sorted_probe_ref(queries, table)
