"""Pallas TPU kernel for ``sorted_probe``: fence-partitioned membership.

The TPU adaptation of the paper's index lookup (DESIGN.md §2): a CPU hash
map is pointer-chasing and does not vectorize; a *sorted dense table* +
*fence-partitioned broadcast compare* does:

  stage A (jnp, ops.py) — sort queries, assign each to a table block via a
    fence search (fence = every B_T-th table key), bucket queries per block;
  stage B (this kernel)  — grid over table blocks: each step holds one
    ``(B_T, 2)`` table block and its ``(QMAX, 2)`` query bucket in VMEM and
    resolves membership with a dense ``(B_T × QMAX)`` lexicographic compare
    (VPU-regular, branch-free — the TPU-idiomatic substitute for per-query
    binary search, whose dynamic lane gathers are the expensive thing on
    this hardware);
  stage C (jnp, ops.py) — scatter results back to original query order.

VMEM per grid step (B_T=2048, QMAX=512):
  table 2048×2×4 B = 16 KiB, queries 512×2×4 B = 4 KiB,
  compare matrices 2×2048×512 bool ≈ 2 MiB  « 16 MiB VMEM ✓
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["probe_blocks_pallas", "DEFAULT_TABLE_BLOCK", "SENTINEL"]

DEFAULT_TABLE_BLOCK = 2048
SENTINEL = 0xFFFFFFFF  # bucket padding key (never a valid query by masking)


def _probe_kernel(t_ref, q_ref, found_ref, pos_ref, *, table_block: int):
    t = t_ref[...]   # (B_T, 2) uint32, sorted ascending
    q = q_ref[0]     # (QMAX, 2) uint32 bucket (sentinel-padded)
    t_hi, t_lo = t[:, 0], t[:, 1]
    q_hi, q_lo = q[:, 0], q[:, 1]
    # dense lexicographic compare: (B_T, QMAX)
    lt = (t_hi[:, None] < q_hi[None, :]) | (
        (t_hi[:, None] == q_hi[None, :]) & (t_lo[:, None] < q_lo[None, :])
    )
    eq = (t_hi[:, None] == q_hi[None, :]) & (t_lo[:, None] == q_lo[None, :])
    count = jnp.sum(lt.astype(jnp.int32), axis=0)  # lower bound within block
    found = jnp.any(eq, axis=0)
    base = pl.program_id(0) * table_block
    found_ref[0, :] = found.astype(jnp.int32)
    pos_ref[0, :] = base + count


def probe_blocks_pallas(
    table_padded: jax.Array,   # (nblocks * B_T, 2) uint32, sorted + sentinel pad
    buckets: jax.Array,        # (nblocks, QMAX, 2) uint32 bucketed queries
    table_block: int = DEFAULT_TABLE_BLOCK,
    interpret: bool = False,
):
    """Stage B: per-block membership. Returns (found, pos) of shape
    ``(nblocks, QMAX)``; ``pos`` is the global lower-bound index assuming the
    query was routed to the correct block (stage A's fence invariant)."""
    nblocks, qmax, _ = buckets.shape
    if table_padded.shape[0] != nblocks * table_block:
        raise ValueError(
            f"table rows {table_padded.shape[0]} != nblocks*B_T "
            f"{nblocks}*{table_block}"
        )
    kernel = functools.partial(_probe_kernel, table_block=table_block)
    found, pos = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((table_block, 2), lambda i: (i, 0)),
            pl.BlockSpec((1, qmax, 2), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, qmax), lambda i: (i, 0)),
            pl.BlockSpec((1, qmax), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, qmax), jnp.int32),
            jax.ShapeDtypeStruct((nblocks, qmax), jnp.int32),
        ],
        interpret=interpret,
    )(table_padded, buckets)
    return found, pos
