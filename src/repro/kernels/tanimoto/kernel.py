"""Pallas TPU kernel for batched Tanimoto top-k over packed fingerprints.

Similarity screening is the first workload in this repo where the Pallas
kernel is the *throughput* lever rather than a probe: every query must
touch every database row (no digest routing to hide behind), so the job
is a dense streaming scan — exactly what the VPU's 8x128 lanes want.

Layout (mirrors ``sorted_probe``'s staged shape):

  grid over database blocks: step ``i`` holds one ``(B_D, W)`` uint32
  fingerprint block + its ``(1, B_D)`` precomputed popcounts in VMEM,
  with the full ``(Q, W)`` query plane resident across steps;

  per step — intersection popcounts via a SWAR bit-trick popcount over
  uint32 words (branch-free adds/shifts/masks, no lookup tables to
  gather through), one ``(Q, B_D)`` lane matrix per word, statically
  unrolled over the ``W`` words; union from the precomputed row
  popcounts (``|q| + |d| - c``); score ``c / u`` in float32;

  a running per-query top-k lives in the *output* refs (constant index
  map → the block stays in VMEM across all grid steps): each step merges
  its ``(Q, B_D)`` candidate scores into the ``(Q, K)`` running heap by
  K rounds of masked max-extraction — first-occurrence ties, which (run
  entries sorted, block indices ascending, run indices always below the
  current block's) is exactly the oracle's ``(score desc, index asc)``
  order.

VMEM per grid step (Q=256, B_D=256, W=32, K=32):
  queries 256x32x4 B = 32 KiB, block 32 KiB, score/intersection
  matrices ~4x256x256x4 B = 1 MiB, running top-k 2x256x32x4 B = 64 KiB
  « 16 MiB ✓
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["tanimoto_blocks_pallas", "DEFAULT_DB_BLOCK", "PAD_IDX_SENTINEL"]

DEFAULT_DB_BLOCK = 256
# running-heap slots start at this index with score -1; any real row
# (score >= 0) displaces them, and survivors are mapped to -1 on the host
PAD_IDX_SENTINEL = 2**31 - 1

_M1 = np.uint32(0x55555555)
_M2 = np.uint32(0x33333333)
_M4 = np.uint32(0x0F0F0F0F)


def _popcount_u32(x: jax.Array) -> jax.Array:
    """SWAR popcount of a uint32 array (exact, branch-free, no gathers)."""
    x = x - ((x >> np.uint32(1)) & _M1)
    x = (x & _M2) + ((x >> np.uint32(2)) & _M2)
    x = (x + (x >> np.uint32(4))) & _M4
    x = x + (x >> np.uint32(8))
    x = (x + (x >> np.uint32(16))) & np.uint32(0x3F)
    return x.astype(jnp.int32)


def _tanimoto_kernel(
    db_ref,      # (B_D, W) uint32 — this step's database block
    dbc_ref,     # (1, B_D) int32  — its precomputed row popcounts
    q_ref,       # (Q, W) uint32   — the full query plane (every step)
    qc_ref,      # (1, Q) int32    — query popcounts
    scores_ref,  # (Q, K) f32      — running top-k scores (accumulator)
    idx_ref,     # (Q, K) int32    — running top-k global row indices
    *,
    block_d: int,
    k_pad: int,
    n_db: int,
    n_words: int,
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        scores_ref[...] = jnp.full(scores_ref.shape, -1.0, jnp.float32)
        idx_ref[...] = jnp.full(idx_ref.shape, PAD_IDX_SENTINEL, jnp.int32)

    d = db_ref[...]
    q = q_ref[...]
    dc = dbc_ref[0]
    qc = qc_ref[0]
    qn = q.shape[0]

    # intersection popcount, one (Q, B_D) lane matrix per word (static
    # unroll — W is a compile-time constant, no dynamic lane slicing)
    inter = jnp.zeros((qn, block_d), jnp.int32)
    for w in range(n_words):
        inter += _popcount_u32(q[:, w, None] & d[None, :, w])
    union = qc[:, None] + dc[None, :] - inter
    score = jnp.where(
        union > 0,
        inter.astype(jnp.float32) / union.astype(jnp.float32),
        0.0,
    )
    rows = step * block_d + jax.lax.broadcasted_iota(
        jnp.int32, (qn, block_d), 1
    )
    valid = rows < n_db  # sentinel-padded tail rows never place
    score = jnp.where(valid, score, -1.0)
    rows = jnp.where(valid, rows, PAD_IDX_SENTINEL)

    # merge into the running top-k: K rounds of masked max-extraction.
    # First-occurrence tie-break == (score desc, index asc): running
    # entries (always from earlier blocks, i.e. smaller indices) come
    # first in the concat, and both halves are ascending-index within
    # equal scores.
    all_s = jnp.concatenate([scores_ref[...], score], axis=1)
    all_i = jnp.concatenate([idx_ref[...], rows], axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, all_s.shape, 1)
    top_s, top_i = [], []
    for _ in range(k_pad):
        m = jnp.max(all_s, axis=1)
        at_max = all_s == m[:, None]
        first = jnp.min(
            jnp.where(at_max, cols, PAD_IDX_SENTINEL), axis=1
        )
        sel = cols == first[:, None]
        top_s.append(m)
        top_i.append(jnp.sum(jnp.where(sel, all_i, 0), axis=1))
        all_s = jnp.where(sel, -2.0, all_s)  # below any pad: never re-picked
    scores_ref[...] = jnp.stack(top_s, axis=1)
    idx_ref[...] = jnp.stack(top_i, axis=1)


@functools.partial(
    jax.jit, static_argnames=("block_d", "k_pad", "n_db", "interpret")
)
def tanimoto_blocks_pallas(
    db_padded: jax.Array,   # (nblocks * B_D, W) uint32, zero-padded tail
    dbc_padded: jax.Array,  # (nblocks, B_D) int32 row popcounts
    queries: jax.Array,     # (Q, W) uint32
    q_counts: jax.Array,    # (1, Q) int32
    block_d: int = DEFAULT_DB_BLOCK,
    k_pad: int = 8,
    n_db: int = 0,
    interpret: bool = False,
):
    """Streamed top-k: returns ``(scores (Q, k_pad) f32, idx (Q, k_pad) i32)``.

    ``idx`` holds global database row indices; slots that never filled
    (fewer than ``k_pad`` real rows) carry ``score -1`` and the pad
    sentinel index — the ops wrapper maps them to the oracle's ``-1``.
    """
    nblocks = db_padded.shape[0] // block_d
    if db_padded.shape[0] != nblocks * block_d or nblocks == 0:
        raise ValueError(
            f"database rows {db_padded.shape[0]} not a positive multiple "
            f"of block_d {block_d}"
        )
    qn, n_words = queries.shape
    kernel = functools.partial(
        _tanimoto_kernel,
        block_d=block_d,
        k_pad=k_pad,
        n_db=n_db,
        n_words=n_words,
    )
    return pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_d, n_words), lambda i: (i, 0)),
            pl.BlockSpec((1, block_d), lambda i: (i, 0)),
            pl.BlockSpec((qn, n_words), lambda i: (0, 0)),
            pl.BlockSpec((1, qn), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((qn, k_pad), lambda i: (0, 0)),
            pl.BlockSpec((qn, k_pad), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, k_pad), jnp.float32),
            jax.ShapeDtypeStruct((qn, k_pad), jnp.int32),
        ],
        interpret=interpret,
    )(db_padded, dbc_padded, queries, q_counts)
