"""Public entry point for batched Tanimoto top-k.

``tanimoto_topk(q_fps, db_fps, k)`` — host numpy in, host numpy out
(the fingerprint planes live in mmap'd sidecars and the results feed
straight into byte-offset column gathers, so unlike ``sorted_probe``
the natural boundary here is numpy, not jax arrays).  Dispatches to the
Pallas kernel on TPU (or when forced / interpreted), otherwise to the
cache-blocked host backend — every backend produces byte-identical
``(scores, indices)`` under the contract documented in ``ref.py``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .kernel import DEFAULT_DB_BLOCK, tanimoto_blocks_pallas
from .ref import (
    PAD_INDEX,
    PAD_SCORE,
    _check_plane,
    _merge_running,
    tanimoto_topk_ref,
)

__all__ = ["tanimoto_topk", "tanimoto_topk_host", "tanimoto_topk_pallas"]

# database rows per inner scoring tile on the host path: the (Q, tile)
# uint64/int32 working set stays L2-resident instead of streaming a
# (Q, N) intermediate through main memory per fingerprint word
_HOST_TILE = 1024
# rows per outer top-k merge block (bounds peak memory to (Q, chunk) f32
# at million-row shards, same role as the reference's _DB_CHUNK)
_HOST_CHUNK = 65_536


def _chunk_topk(blk: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Exact ``(score desc, column asc)`` top-k of one ``(Q, M)`` block.

    ``argpartition`` (introselect, O(M)) finds the k-th score per row;
    the reference's full stable mergesort over the block is
    data-dependent and several times slower on realistic score
    distributions.  Partitioning alone breaks boundary ties arbitrarily,
    so the selection is completed exactly: every column strictly above
    the threshold is in, and the remaining slots fill with the *lowest*
    columns at the threshold — the same first-seen-winner order the
    oracle's stable sort produces.
    """
    qn, m = blk.shape
    if m <= k:
        order = np.argsort(-blk, axis=1, kind="stable")
        return (
            np.take_along_axis(blk, order, axis=1),
            order.astype(np.int32),
        )
    part = np.argpartition(-blk, k - 1, axis=1)[:, :k]
    thr = np.take_along_axis(blk, part, axis=1).min(axis=1)
    out_s = np.empty((qn, k), dtype=np.float32)
    out_i = np.empty((qn, k), dtype=np.int32)
    for r in range(qn):
        row = blk[r]
        above = np.nonzero(row > thr[r])[0]
        at = np.nonzero(row == thr[r])[0][: k - above.size]
        cols = np.concatenate([above, at]).astype(np.int32)
        scores = row[cols]
        # k elements: the stable sort keeps ascending columns per score
        order = np.argsort(-scores, kind="stable")
        out_s[r] = scores[order]
        out_i[r] = cols[order]
    return out_s, out_i


def tanimoto_topk_host(
    q_fps: np.ndarray,
    db_fps: np.ndarray,
    k: int,
    q_counts: Optional[np.ndarray] = None,
    db_counts: Optional[np.ndarray] = None,
    db_chunk: int = _HOST_CHUNK,
    tile: int = _HOST_TILE,
) -> Tuple[np.ndarray, np.ndarray]:
    """Cache-blocked host backend; byte-identical to ``tanimoto_topk_ref``.

    Same streaming merge as the reference, but each chunk's score matrix
    comes from an L2-tiled scorer: fingerprint words are viewed two at a
    time as uint64 (halving the word loop), each ``(Q, tile)`` popcount
    accumulation reuses preallocated buffers instead of allocating per
    word, and the float32 division lands tile-wise into the chunk block.
    Chunk top-k selection goes through :func:`_chunk_topk` (partition +
    exact tie completion) instead of the oracle's full stable sort.  The
    intersection counts are the same int32 values, the division is the
    same float32-cast-then-divide, and the tie discipline is the same
    ``(score desc, row asc)``, so results agree with the reference
    byte-for-byte.
    """
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    q_fps = _check_plane(q_fps, "q_fps")
    db_fps = _check_plane(db_fps, "db_fps")
    if q_fps.shape[1] != db_fps.shape[1]:
        raise ValueError(
            f"word width mismatch: queries {q_fps.shape[1]} vs "
            f"database {db_fps.shape[1]}"
        )
    qn, n_words = q_fps.shape
    n_db = db_fps.shape[0]
    if qn == 0 or n_db == 0:
        return (
            np.full((qn, k), PAD_SCORE, dtype=np.float32),
            np.full((qn, k), PAD_INDEX, dtype=np.int32),
        )
    if n_words % 2:
        # 32-bit planes (W odd) have no uint64 view; the chunked
        # reference is already dispatch-bound there anyway
        return tanimoto_topk_ref(
            q_fps, db_fps, k,
            q_counts=q_counts, db_counts=db_counts, db_chunk=db_chunk,
        )
    from repro.core.fingerprint import popcount_u32

    qc = (
        popcount_u32(q_fps).sum(axis=1, dtype=np.int32)
        if q_counts is None else np.asarray(q_counts, dtype=np.int32)
    )
    dc = (
        popcount_u32(db_fps).sum(axis=1, dtype=np.int32)
        if db_counts is None else np.asarray(db_counts, dtype=np.int32)
    )
    q64 = q_fps.view(np.uint64)
    db64 = db_fps.view(np.uint64)
    w64 = q64.shape[1]
    run_s = np.full((qn, k), PAD_SCORE, dtype=np.float32)
    run_i = np.full((qn, k), np.iinfo(np.int32).max, dtype=np.int32)
    anded = np.empty((qn, tile), dtype=np.uint64)
    counts = np.empty((qn, tile), dtype=np.uint8)
    inter = np.empty((qn, tile), dtype=np.int32)
    for lo in range(0, n_db, db_chunk):
        hi = min(lo + db_chunk, n_db)
        blk = np.zeros((qn, hi - lo), dtype=np.float32)
        for tlo in range(lo, hi, tile):
            thi = min(tlo + tile, hi)
            m = thi - tlo
            t = anded[:, :m]
            c = counts[:, :m]
            x = inter[:, :m]
            np.bitwise_and(q64[:, 0, None], db64[None, tlo:thi, 0], out=t)
            np.bitwise_count(t, out=c)
            x[:] = c
            for w in range(1, w64):
                np.bitwise_and(q64[:, w, None], db64[None, tlo:thi, w], out=t)
                np.bitwise_count(t, out=c)
                x += c
            union = qc[:, None] + dc[None, tlo:thi] - x
            np.divide(
                x.astype(np.float32),
                union.astype(np.float32),
                out=blk[:, tlo - lo : thi - lo],
                where=union > 0,
            )
        blk_s, blk_i = _chunk_topk(blk, k)
        run_s, run_i = _merge_running(run_s, run_i, blk_s, blk_i + lo)
    run_i = np.where(run_s < 0.0, PAD_INDEX, run_i)
    run_s = np.where(run_s < 0.0, PAD_SCORE, run_s)
    return run_s, run_i


def _ceil_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def tanimoto_topk_pallas(
    q_fps: np.ndarray,
    db_fps: np.ndarray,
    k: int,
    q_counts: Optional[np.ndarray] = None,
    db_counts: Optional[np.ndarray] = None,
    block_d: int = DEFAULT_DB_BLOCK,
    interpret: bool = False,
) -> Tuple[np.ndarray, np.ndarray]:
    """Pad to kernel tiles, run the Pallas scan, strip back to ``(Q, k)``."""
    from repro.core.fingerprint import popcount_u32

    q_fps = np.ascontiguousarray(q_fps, dtype=np.uint32)
    db_fps = np.ascontiguousarray(db_fps, dtype=np.uint32)
    qn, n_words = q_fps.shape
    n_db = db_fps.shape[0]
    if db_fps.shape[1] != n_words:
        raise ValueError(
            f"word width mismatch: queries {n_words} vs database "
            f"{db_fps.shape[1]}"
        )
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if qn == 0 or n_db == 0:
        return (
            np.full((qn, k), PAD_SCORE, dtype=np.float32),
            np.full((qn, k), PAD_INDEX, dtype=np.int32),
        )

    qc = (
        popcount_u32(q_fps).sum(axis=1, dtype=np.int32)
        if q_counts is None else np.asarray(q_counts, dtype=np.int32)
    )
    dc = (
        popcount_u32(db_fps).sum(axis=1, dtype=np.int32)
        if db_counts is None else np.asarray(db_counts, dtype=np.int32)
    )

    # tile the database into (nblocks, bd) with zero rows (count 0) in the
    # tail — the kernel masks them via n_db before they can place
    bd = min(block_d, _ceil_to(n_db, 8))
    nblocks = -(-n_db // bd)
    d_pad = nblocks * bd
    db_p = np.zeros((d_pad, n_words), dtype=np.uint32)
    db_p[:n_db] = db_fps
    dc_p = np.zeros(d_pad, dtype=np.int32)
    dc_p[:n_db] = dc
    # queries pad to a sublane multiple; zero-fp rows are sliced back off
    q_pad = _ceil_to(qn, 8)
    q_p = np.zeros((q_pad, n_words), dtype=np.uint32)
    q_p[:qn] = q_fps
    qc_p = np.zeros((1, q_pad), dtype=np.int32)
    qc_p[0, :qn] = qc
    k_pad = _ceil_to(k, 8)

    scores, idx = tanimoto_blocks_pallas(
        db_p,
        dc_p.reshape(nblocks, bd),
        q_p,
        qc_p,
        block_d=bd,
        k_pad=k_pad,
        n_db=n_db,
        interpret=interpret,
    )
    scores = np.asarray(scores)[:qn, :k]
    idx = np.asarray(idx)[:qn, :k]
    # unfilled heap slots carry the in-kernel sentinel; map to the oracle pad
    empty = scores < 0.0
    return (
        np.where(empty, PAD_SCORE, scores).astype(np.float32, copy=False),
        np.where(empty, PAD_INDEX, idx).astype(np.int32, copy=False),
    )


def tanimoto_topk(
    q_fps: np.ndarray,
    db_fps: np.ndarray,
    k: int,
    q_counts: Optional[np.ndarray] = None,
    db_counts: Optional[np.ndarray] = None,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
) -> Tuple[np.ndarray, np.ndarray]:
    """Batched Tanimoto top-k; kernel on TPU, blocked host path elsewhere.

    ``interpret=True`` forces the Pallas path in interpreter mode (the
    CPU-side parity check); ``use_pallas`` overrides auto-detection.
    """
    if use_pallas is None:
        if interpret:
            use_pallas = True
        else:
            import jax

            use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return tanimoto_topk_pallas(
            q_fps, db_fps, k,
            q_counts=q_counts, db_counts=db_counts, interpret=interpret,
        )
    return tanimoto_topk_host(
        q_fps, db_fps, k, q_counts=q_counts, db_counts=db_counts
    )
