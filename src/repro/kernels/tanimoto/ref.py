"""NumPy oracle for batched Tanimoto top-k over packed fingerprints.

The scoring contract every backend must reproduce **exactly** (the
kernel's top-k indices and scores are asserted byte-identical to this):

* fingerprints are ``(·, W)`` uint32 bit-planes, ``W`` words per row;
* ``c = popcount(q & d)`` (intersection), ``u = |q| + |d| - c`` (union);
* ``score = float32(c) / float32(u)`` — both operands are small exact
  integers, so the IEEE-754 single division is uniquely determined —
  and ``score = 0.0`` when the union is empty (two all-zero rows);
* top-k selection orders by ``(score desc, row index asc)``: equal
  scores break toward the *earlier database row*, so selection is
  deterministic and blockwise-mergeable (a streaming kernel that scans
  rows in order and keeps first-seen winners agrees with the oracle);
* when fewer than ``k`` rows exist, the tail is padded with
  ``score = -1.0, index = -1`` (valid scores are always >= 0).

The matrix path (:func:`tanimoto_topk_ref`) is the deployable host
backend — one vectorized pass per fingerprint word over a bounded
database chunk — while :func:`tanimoto_topk_naive` is the pre-batching
baseline (one independent scoring call per query) that the similarity
benchmark measures the batched paths against.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.fingerprint import popcount_u32

__all__ = [
    "PAD_INDEX",
    "PAD_SCORE",
    "tanimoto_scores_ref",
    "tanimoto_topk_naive",
    "tanimoto_topk_ref",
]

PAD_SCORE = np.float32(-1.0)
PAD_INDEX = np.int32(-1)

# database rows scored per chunk in the matrix path: bounds the (Q, N)
# intermediate to ~Q * 64k * 4 B while keeping per-word numpy dispatch
# overhead amortized over wide rows
_DB_CHUNK = 65_536


def _check_plane(fps: np.ndarray, name: str) -> np.ndarray:
    fps = np.ascontiguousarray(fps, dtype=np.uint32)
    if fps.ndim != 2:
        raise ValueError(f"{name} must be (N, W) uint32, got {fps.shape}")
    return fps


def tanimoto_scores_ref(
    q_fps: np.ndarray,
    db_fps: np.ndarray,
    q_counts: Optional[np.ndarray] = None,
    db_counts: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Dense ``(Q, N)`` float32 Tanimoto matrix (one pass per word)."""
    q_fps = _check_plane(q_fps, "q_fps")
    db_fps = _check_plane(db_fps, "db_fps")
    if q_fps.shape[1] != db_fps.shape[1]:
        raise ValueError(
            f"word width mismatch: queries {q_fps.shape[1]} vs "
            f"database {db_fps.shape[1]}"
        )
    qc = (
        popcount_u32(q_fps).sum(axis=1, dtype=np.int32)
        if q_counts is None else np.asarray(q_counts, dtype=np.int32)
    )
    dc = (
        popcount_u32(db_fps).sum(axis=1, dtype=np.int32)
        if db_counts is None else np.asarray(db_counts, dtype=np.int32)
    )
    inter = np.zeros((q_fps.shape[0], db_fps.shape[0]), dtype=np.int32)
    for w in range(q_fps.shape[1]):
        inter += popcount_u32(q_fps[:, w, None] & db_fps[None, :, w])
    union = qc[:, None] + dc[None, :] - inter
    out = np.zeros(inter.shape, dtype=np.float32)
    np.divide(
        inter.astype(np.float32),
        union.astype(np.float32),
        out=out,
        where=union > 0,
    )
    return out


def _merge_running(
    run_s: np.ndarray,
    run_i: np.ndarray,
    blk_s: np.ndarray,
    blk_i: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """Fold a score block into the running ``(Q, k)`` top-k.

    ``(score desc, index asc)`` via one vectorized argsort per merge:
    ``-score`` majorizes, index minorizes, and numpy's stable mergesort
    on the composite keeps the deterministic tie order.
    """
    k = run_s.shape[1]
    all_s = np.concatenate([run_s, blk_s], axis=1)
    all_i = np.concatenate([run_i, blk_i], axis=1)
    # lexicographic (-score, index): indices are < 2**31, scores f32 —
    # sort by index first (stable), then by -score (stable) == lexsort
    order = np.argsort(all_i, axis=1, kind="stable")
    all_s = np.take_along_axis(all_s, order, axis=1)
    all_i = np.take_along_axis(all_i, order, axis=1)
    order = np.argsort(-all_s, axis=1, kind="stable")[:, :k]
    return (
        np.take_along_axis(all_s, order, axis=1),
        np.take_along_axis(all_i, order, axis=1),
    )


def tanimoto_topk_ref(
    q_fps: np.ndarray,
    db_fps: np.ndarray,
    k: int,
    q_counts: Optional[np.ndarray] = None,
    db_counts: Optional[np.ndarray] = None,
    db_chunk: int = _DB_CHUNK,
) -> Tuple[np.ndarray, np.ndarray]:
    """Batched top-k: ``(scores (Q, k) f32, indices (Q, k) int32)``.

    Streams the database in ``db_chunk``-row blocks (bounded memory at
    million-row shards) and merges each block into the running top-k —
    the same scan order and tie discipline as the Pallas kernel, which
    is what makes exact agreement between the two checkable.
    """
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    q_fps = _check_plane(q_fps, "q_fps")
    db_fps = _check_plane(db_fps, "db_fps")
    qn = q_fps.shape[0]
    qc = (
        popcount_u32(q_fps).sum(axis=1, dtype=np.int32)
        if q_counts is None else np.asarray(q_counts, dtype=np.int32)
    )
    dc = (
        popcount_u32(db_fps).sum(axis=1, dtype=np.int32)
        if db_counts is None else np.asarray(db_counts, dtype=np.int32)
    )
    run_s = np.full((qn, k), PAD_SCORE, dtype=np.float32)
    run_i = np.full((qn, k), np.iinfo(np.int32).max, dtype=np.int32)
    for lo in range(0, db_fps.shape[0], db_chunk):
        hi = min(lo + db_chunk, db_fps.shape[0])
        blk_s = tanimoto_scores_ref(
            q_fps, db_fps[lo:hi], q_counts=qc, db_counts=dc[lo:hi]
        )
        blk_i = np.broadcast_to(
            np.arange(lo, hi, dtype=np.int32)[None, :], blk_s.shape
        )
        run_s, run_i = _merge_running(run_s, run_i, blk_s, blk_i)
    run_i = np.where(run_s < 0.0, PAD_INDEX, run_i)
    run_s = np.where(run_s < 0.0, PAD_SCORE, run_s)
    return run_s, run_i


def tanimoto_topk_naive(
    q_fps: np.ndarray, db_fps: np.ndarray, k: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-query loop baseline: one independent scoring pass per query.

    The pre-batching serving contract (each request scored on its own,
    popcounts recomputed every call) — identical results to
    :func:`tanimoto_topk_ref`, measured by the benchmark as the floor
    the batched kernel path must beat.
    """
    outs = [
        tanimoto_topk_ref(q_fps[i : i + 1], db_fps, k)
        for i in range(q_fps.shape[0])
    ]
    if not outs:
        w = np.zeros((0, k), dtype=np.float32)
        return w, w.astype(np.int32)
    return (
        np.concatenate([s for s, _ in outs], axis=0),
        np.concatenate([i for _, i in outs], axis=0),
    )
