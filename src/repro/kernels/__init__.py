"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships as ``kernel.py`` (pl.pallas_call + BlockSpec tiling),
``ops.py`` (jit'd dispatching wrapper) and ``ref.py`` (pure-jnp oracle).
On this CPU container kernels execute only under ``interpret=True`` (Mosaic
lowering is TPU-only); the model code paths default to the reference
implementations off-TPU.

* ``hash_mix``        — 128-bit mixing digest of packed identifiers
                        (the InChIKey role for on-device analytics).
* ``sorted_probe``    — fence-partitioned membership probe against a sorted
                        digest table (the paper's index lookup, TPU-native).
* ``tanimoto``        — batched Tanimoto top-k over packed fingerprint
                        bit-planes (the similarity query modality).
* ``flash_attention`` — causal/sliding-window GQA flash attention.
* ``ssd_scan``        — Mamba2 SSD inter-chunk state recurrence.
"""

from .hash_mix.ops import hash_mix, hash_mix_u64
from .sorted_probe.ops import sorted_probe
from .tanimoto.ops import tanimoto_topk
from .flash_attention.ops import flash_attention
from .ssd_scan.ops import ssd_scan
