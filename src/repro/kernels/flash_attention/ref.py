"""Pure-jnp oracle for causal/windowed GQA flash attention.

Unblocked reference: materializes the full (Sq, Skv) score matrix in f32.
Semantics shared with the kernel:

* queries are the **last** ``Sq`` positions of the key sequence (so
  prefill Sq == Skv and decode Sq == 1 both work with one offset rule);
* ``causal``: key position must be <= query position;
* ``window``: if set, key position must be > query position - window
  (sliding-window attention — Gemma3 local layers, window=1024);
* GQA: Hq queries share Hkv key/value heads (Hq % Hkv == 0).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["flash_attention_ref", "flash_attention_chunked"]

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,  # (B, Hkv, Skv, D)
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads for GQA
    kf = jnp.repeat(kf, g, axis=1)
    vf = jnp.repeat(vf, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)  # query abs position
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return out.astype(q.dtype)


def flash_attention_chunked(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,  # (B, Hkv, Skv, D)
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention in pure XLA (flash attention without Pallas).

    Beyond-paper §Perf optimization (EXPERIMENTS.md): scans over KV chunks
    carrying (m, l, acc), so peak score memory is (B, H, Sq, chunk) instead
    of (B, H, Sq, Skv) — the S×S materialization that made every train/
    prefill cell memory-bound in the baseline dry-run disappears.  GQA is
    computed in grouped form (no repeated K/V materialization).  Matmuls
    run in the input dtype with f32 accumulation (MXU-native).

    The chunk loop is a ``lax.scan`` honoring ``flags.scan_unroll()`` so the
    dry-run's roofline probes count every chunk (see launch/dryrun.py).
    """
    from repro import flags
    from repro.dist.logical import constrain

    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    c = min(chunk, skv)
    pad = (c - skv % c) % c
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nkc = (skv + pad) // c
    off = skv - sq  # queries are the last sq positions
    # FULL-HEAD layout: hq stays a shardable TP dim (heads→model).  K/V are
    # repeated to hq heads PER CHUNK inside the scan body (chunk-sized, so
    # the repeat costs ~nothing) — the grouped (B,Hkv,G,…) form would make
    # both head dims indivisible by the model axis and silently replicate
    # the whole attention computation (measured: +3× bytes on qwen3-moe).
    qf = q * jnp.asarray(scale, q.dtype)
    qf = constrain(qf, "batch", "heads", None, None)
    q_pos = jnp.arange(sq) + off

    kc = k.reshape(b, hkv, nkc, c, d).transpose(2, 0, 1, 3, 4)  # (n,B,Hkv,c,D)
    vc = v.reshape(b, hkv, nkc, c, d).transpose(2, 0, 1, 3, 4)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, ci = xs
        kb = jnp.repeat(kb, g, axis=1)      # (B, Hq, c, D) chunk-local
        vb = jnp.repeat(vb, g, axis=1)
        kb = constrain(kb, "batch", "heads", None, None)
        vb = constrain(vb, "batch", "heads", None, None)
        s = jnp.einsum(
            "bhqd,bhcd->bhqc", qf, kb,
            preferred_element_type=jnp.float32,
        )  # (B, Hq, Sq, c)
        k_pos = ci * c + jnp.arange(c)
        mask = (k_pos[None, :] < skv)
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = p * mask[None, None]
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqc,bhcd->bhqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    a0 = constrain(
        jnp.zeros((b, hq, sq, d), jnp.float32), "batch", "heads", None, None
    )
    (m_f, l_f, acc), _ = lax.scan(
        step, (m0, l0, a0),
        (kc, vc, jnp.arange(nkc)),
        unroll=flags.scan_unroll(),
    )
    l_safe = jnp.where(l_f > 0, l_f, 1.0)
    out = acc / l_safe[..., None]
    return out.astype(q.dtype)
