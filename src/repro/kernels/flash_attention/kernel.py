"""Pallas TPU flash attention (causal / sliding-window, GQA).

Online-softmax blocked attention:

  grid = (B*Hq, Sq/BQ, Skv/BK)   — kv block index innermost (sequential);
  VMEM blocks: q (BQ, D), k (BK, D), v (BK, D), out (BQ, D);
  f32 scratch carried across kv steps: acc (BQ, D), m (BQ,), l (BQ,).

MXU alignment: BQ, BK multiples of 128; D is the head dim (128/256-class).
VMEM per step (BQ=BK=512, D=128, bf16 in / f32 scratch):
  q/k/v/out ≈ 4 × 512×128×2 B = 512 KiB, scratch ≈ 512×128×4 + 2×512×4
  ≈ 260 KiB  « 16 MiB ✓

Fully-masked kv blocks (beyond the causal frontier or the sliding window)
are skipped with ``pl.when`` — with a window the skip fraction approaches
1 - window/Skv, which is where the kernel's sub-quadratic win comes from.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: Optional[int],
    bq: int, bk: int, sq: int, skv: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions (queries are the last sq positions of the stream)
    off = skv - sq
    q_lo = qi * bq + off          # first query abs position in this block
    q_hi = q_lo + bq - 1
    k_lo = ki * bk

    # block-level visibility: any (q, k) pair in this tile unmasked?
    visible = True
    if causal:
        visible = jnp.logical_and(visible, k_lo <= q_hi)
    if window is not None:
        visible = jnp.logical_and(visible, k_lo + bk - 1 > q_lo - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale   # (BQ, D)
        k = k_ref[0].astype(jnp.float32)           # (BK, D)
        v = v_ref[0].astype(jnp.float32)           # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                           # (BQ, BK)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None]) * mask.astype(jnp.float32)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe_l = jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,   # (B, Hq, Sq, D)
    k: jax.Array,   # (B, Hkv, Skv, D)
    v: jax.Array,   # (B, Hkv, Skv, D)
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    if sq % bq or skv % bk:
        raise ValueError(f"seq lens ({sq},{skv}) not divisible by blocks ({bq},{bk})")
    g = hq // hkv

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)
    grid = (b * hq, sq // bq, skv // bk)

    def kv_index(bh, qi, ki):
        # map flattened q-head index -> flattened kv-head index (GQA)
        return ((bh // hq) * hkv + (bh % hq) // g, ki, 0)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, sq=sq, skv=skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            _vmem((bq, d)),   # acc
            _vmem((bq,)),     # m (running max)
            _vmem((bq,)),     # l (running denom)
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
