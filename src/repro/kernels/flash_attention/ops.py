"""Public jit'd entry point for flash attention.

TPU → Pallas kernel; elsewhere → pure-jnp reference (XLA fuses it well
enough for CPU tests, and the dry-run rooflines measure the XLA path).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro import flags

from .kernel import flash_attention_pallas
from .ref import flash_attention_chunked, flash_attention_ref

__all__ = ["flash_attention"]


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    """TPU → Pallas kernel; XLA path → chunked online-softmax (default)
    or the unblocked reference (REPRO_ATTN_IMPL=ref, §Perf baseline)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale,
            interpret=interpret,
        )
    if flags.ATTN_IMPL == "chunked":
        return flash_attention_chunked(
            q, k, v, causal=causal, window=window, scale=scale,
            chunk=flags.ATTN_CHUNK,
        )
    return flash_attention_ref(q, k, v, causal=causal, window=window, scale=scale)
