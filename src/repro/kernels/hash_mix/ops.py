"""Public jit'd entry points for ``hash_mix``.

``hash_mix(x)`` dispatches to the Pallas kernel on TPU and to the pure-jnp
reference elsewhere (CPU containers run the kernel only under
``interpret=True`` in tests — Mosaic lowering is TPU-only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import hash_mix_pallas
from .ref import hash_mix_ref

__all__ = ["hash_mix", "hash_mix_u64", "digest_ids"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("seed", "use_pallas", "interpret"))
def hash_mix(
    x: jax.Array,
    seed: int = 0,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    """``(N, W) uint32 → (N, 4) uint32`` digest (see kernel/ref)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return hash_mix_pallas(x, seed=seed, interpret=interpret)
    return hash_mix_ref(x, seed=seed)


def hash_mix_u64(x: jax.Array, seed: int = 0) -> jax.Array:
    """First 64 digest bits as ``(N, 2) uint32`` (hi, lo) pairs.

    The sorted-probe membership path keys on 64-bit digests; collisions at
    that width degrade to an extra full-id verify, never to wrong results.
    """
    d = hash_mix(x, seed=seed)
    return d[:, :2]


def digest_ids(ids, seed: int = 0) -> np.ndarray:
    """Host convenience: list[str] → (N, 2) uint32 digests via packing."""
    from repro.core.packing import pack_ids

    packed = jnp.asarray(pack_ids(list(ids)))
    return np.asarray(hash_mix_u64(packed, seed=seed))
