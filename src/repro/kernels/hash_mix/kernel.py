"""Pallas TPU kernel for ``hash_mix``: blocked 128-bit mixing digest.

VMEM tiling: the ``(N, W)`` uint32 input is processed in ``(BN, W)``
row blocks (whole rows — the mix is sequential over lanes, parallel over
rows).  Pure VPU integer arithmetic; no MXU involvement.  Block rows are
grid-parallel; the lane loop is unrolled at trace time (W is static and
small: identifiers pack into ≤ 64 lanes).

VMEM budget per grid step (BN=1024, W=64):
  in  1024 × 64 × 4 B  = 256 KiB
  out 1024 × 4 × 4 B   =  16 KiB          « 16 MiB VMEM ✓
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PRIME1, PRIME2, PRIME3, PRIME4

__all__ = ["hash_mix_pallas", "DEFAULT_BLOCK_ROWS"]

DEFAULT_BLOCK_ROWS = 1024


def _rotl(x, r: int):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def _avalanche(h):
    h = h ^ (h >> jnp.uint32(15))
    h = h * PRIME2
    h = h ^ (h >> jnp.uint32(13))
    h = h * PRIME3
    h = h ^ (h >> jnp.uint32(16))
    return h


def _hash_mix_kernel(x_ref, out_ref, *, w: int, seed: int):
    x = x_ref[...]  # (BN, W) uint32 in VMEM
    bn = x.shape[0]
    s = jnp.uint32(seed)
    h0 = jnp.full((bn,), PRIME1 + s, dtype=jnp.uint32)
    h1 = jnp.full((bn,), PRIME2 ^ s, dtype=jnp.uint32)
    h2 = jnp.full((bn,), PRIME3 + (s * PRIME1), dtype=jnp.uint32)
    h3 = jnp.full((bn,), PRIME4 ^ (s * PRIME2), dtype=jnp.uint32)
    for i in range(w):  # static unroll over lanes
        k = x[:, i]
        lane = jnp.uint32(i + 1)
        h0 = _rotl(h0 + k * PRIME2, 13) * PRIME1
        h1 = _rotl(h1 ^ (k + lane) * PRIME3, 17) * PRIME2
        h2 = _rotl(h2 + (k ^ lane * PRIME1) * PRIME4, 11) * PRIME3
        h3 = _rotl(h3 ^ k * PRIME1, 19) * PRIME4
    ln = jnp.uint32(w)
    h0 = _avalanche(h0 ^ (ln * PRIME1) ^ _rotl(h1, 7))
    h1 = _avalanche(h1 ^ (ln * PRIME2) ^ _rotl(h2, 12))
    h2 = _avalanche(h2 ^ (ln * PRIME3) ^ _rotl(h3, 18))
    h3 = _avalanche(h3 ^ (ln * PRIME4) ^ _rotl(h0, 23))
    out_ref[...] = jnp.stack([h0, h1, h2, h3], axis=1)


def hash_mix_pallas(
    x: jax.Array,
    seed: int = 0,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Blocked Pallas digest; bit-exact vs :func:`..ref.hash_mix_ref`.

    ``N`` is padded up to a multiple of ``block_rows`` (padded rows hash
    garbage zeros and are sliced off — digests are row-local so padding
    cannot contaminate real rows).
    """
    if x.dtype != jnp.uint32 or x.ndim != 2:
        raise TypeError(f"expected (N, W) uint32, got {x.shape} {x.dtype}")
    n, w = x.shape
    bn = min(block_rows, max(8, n))
    n_pad = (n + bn - 1) // bn * bn
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0))) if n_pad != n else x
    grid = (n_pad // bn,)
    out = pl.pallas_call(
        functools.partial(_hash_mix_kernel, w=w, seed=seed),
        grid=grid,
        in_specs=[pl.BlockSpec((bn, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 4), jnp.uint32),
        interpret=interpret,
    )(xp)
    return out[:n]
