"""Pure-jnp oracle for the ``hash_mix`` kernel.

128-bit mixing hash (xxhash/murmur-flavoured avalanche) over packed
``(N, W)`` uint32 identifier tensors, emitted as ``(N, 4)`` uint32 lanes.
This is the digest the TPU data plane uses in place of the paper's
SHA-256-derived InChIKey for *in-memory* analytics (dedup, membership,
collision grouping) — cryptographic strength is not required there
because every digest hit is verified against the full identifier
(Algorithm 3 discipline); what matters is avalanche quality and speed.

The reference is the unblocked formulation; the Pallas kernel must match
it bit-exactly for every shape/dtype in the sweep.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["hash_mix_ref", "PRIME1", "PRIME2", "PRIME3", "PRIME4"]

# xxhash32 primes (odd, high-entropy) — standard public constants.
# numpy scalars (not jnp arrays) so Pallas kernels see them as literals.
PRIME1 = np.uint32(0x9E3779B1)
PRIME2 = np.uint32(0x85EBCA77)
PRIME3 = np.uint32(0xC2B2AE3D)
PRIME4 = np.uint32(0x27D4EB2F)


def _rotl(x: jax.Array, r: int) -> jax.Array:
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def _avalanche(h: jax.Array) -> jax.Array:
    h = h ^ (h >> jnp.uint32(15))
    h = h * PRIME2
    h = h ^ (h >> jnp.uint32(13))
    h = h * PRIME3
    h = h ^ (h >> jnp.uint32(16))
    return h


def hash_mix_ref(x: jax.Array, seed: int = 0) -> jax.Array:
    """``(N, W) uint32 → (N, 4) uint32`` 128-bit mixing digest.

    Four decorrelated accumulator lanes absorb every input lane with
    distinct rotation/prime schedules, then avalanche + cross-mix.
    """
    if x.dtype != jnp.uint32:
        raise TypeError(f"hash_mix expects uint32, got {x.dtype}")
    if x.ndim != 2:
        raise ValueError(f"hash_mix expects (N, W), got {x.shape}")
    n, w = x.shape
    s = jnp.uint32(seed)
    h0 = jnp.full((n,), PRIME1 + s, dtype=jnp.uint32)
    h1 = jnp.full((n,), PRIME2 ^ s, dtype=jnp.uint32)
    h2 = jnp.full((n,), PRIME3 + (s * PRIME1), dtype=jnp.uint32)
    h3 = jnp.full((n,), PRIME4 ^ (s * PRIME2), dtype=jnp.uint32)
    for i in range(w):
        k = x[:, i]
        lane = jnp.uint32(i + 1)
        h0 = _rotl(h0 + k * PRIME2, 13) * PRIME1
        h1 = _rotl(h1 ^ (k + lane) * PRIME3, 17) * PRIME2
        h2 = _rotl(h2 + (k ^ lane * PRIME1) * PRIME4, 11) * PRIME3
        h3 = _rotl(h3 ^ k * PRIME1, 19) * PRIME4
    # length injection + cross-lane mix + final avalanche
    ln = jnp.uint32(w)
    h0 = _avalanche(h0 ^ (ln * PRIME1) ^ _rotl(h1, 7))
    h1 = _avalanche(h1 ^ (ln * PRIME2) ^ _rotl(h2, 12))
    h2 = _avalanche(h2 ^ (ln * PRIME3) ^ _rotl(h3, 18))
    h3 = _avalanche(h3 ^ (ln * PRIME4) ^ _rotl(h0, 23))
    return jnp.stack([h0, h1, h2, h3], axis=1)
