"""Deterministic, elastic example addressing.

``(step, dp_rank)`` → global example ids → record keys → byte offsets is a
*pure function*: no iterator state exists anywhere.  Consequences, which
are the data-plane half of the fault-tolerance story (DESIGN.md §2):

* checkpointing the data pipeline = saving one integer (the step);
* any worker can compute any other worker's shard (failure hand-off);
* changing the dp extent (elastic rescale) re-partitions the SAME global
  example order — tokens-seen semantics are preserved exactly, because
  example ids are global and only their assignment to ranks changes.

Shuffling is a stateless Feistel permutation over [0, N): pseudo-random,
invertible, O(1) per index, no materialized permutation array (N can be
billions of records at production scale).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import List, Sequence

__all__ = ["FeistelShuffle", "GlobalSampler"]


class FeistelShuffle:
    """Stateless permutation of [0, n) via a 4-round Feistel network.

    Works over the smallest balanced bit-domain ≥ n with cycle-walking to
    stay inside [0, n).
    """

    def __init__(self, n: int, seed: int, rounds: int = 4):
        if n <= 0:
            raise ValueError("n must be positive")
        self.n = n
        self.seed = seed
        self.rounds = rounds
        bits = max(2, (n - 1).bit_length())
        self.half = (bits + 1) // 2
        self.mask = (1 << self.half) - 1
        self.domain = 1 << (2 * self.half)

    def _round_key(self, r: int) -> int:
        h = hashlib.blake2b(
            f"{self.seed}:{r}".encode(), digest_size=8
        ).digest()
        return int.from_bytes(h, "big")

    def _feistel(self, x: int) -> int:
        l = x >> self.half
        r = x & self.mask
        for i in range(self.rounds):
            k = self._round_key(i)
            f = hashlib.blake2b(
                (r ^ (k & self.mask)).to_bytes(8, "big"), digest_size=8
            ).digest()
            l, r = r, l ^ (int.from_bytes(f, "big") & self.mask)
        return (l << self.half) | r

    def __call__(self, i: int) -> int:
        if not 0 <= i < self.n:
            raise IndexError(i)
        x = i
        while True:  # cycle-walk until inside [0, n)
            x = self._feistel(x)
            if x < self.n:
                return x


@dataclass(frozen=True)
class GlobalSampler:
    """Maps (step, dp_rank) → the global example indices of that shard."""

    n_examples: int
    global_batch: int
    seed: int = 0

    def _shuffle(self, epoch: int) -> FeistelShuffle:
        return FeistelShuffle(self.n_examples, self.seed * 1000003 + epoch)

    def example_ids(self, step: int, dp_rank: int, n_dp: int) -> List[int]:
        """Record indices for one dp shard at one step (epoch-wrapped)."""
        if self.global_batch % n_dp:
            raise ValueError(
                f"global_batch {self.global_batch} not divisible by dp={n_dp}"
            )
        per = self.global_batch // n_dp
        base = step * self.global_batch + dp_rank * per
        out = []
        for i in range(per):
            g = base + i
            epoch, idx = divmod(g, self.n_examples)
            out.append(self._shuffle(epoch)(idx))
        return out

    def all_ids(self, step: int) -> List[int]:
        return self.example_ids(step, 0, 1)
