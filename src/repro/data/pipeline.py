"""Index-backed training data pipeline.

The byte-offset index IS the dataset: examples are addressed
``example idx → record key → (file, byte_offset) → seek``.  Per step each
dp shard's fetches are **grouped by file and sorted by ascending offset**
— Algorithm 3's access-pattern optimization reapplied verbatim to the
training loader (DESIGN.md §2).

Production concerns implemented here:

* deterministic addressing (see :mod:`repro.data.sampler`) — checkpoint =
  one integer, elastic re-shard for free;
* host-side prefetch thread (double buffering, overlap with device step);
* straggler mitigation: per-fetch deadline + speculative retry through a
  pluggable ``fetch_fn`` (any record is re-fetchable by any host because
  addressing is stateless — in a multi-host deployment the retry can go to
  a replica filesystem path);
* integrity: extracted records are id-verified (the paper's defensive
  validation) before tokenization; verification failures are surfaced,
  never silently dropped.
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.cache import RecordCache
from repro.core.extract import plan_extraction
from repro.core.identifiers import canonical_id_from_structure
from repro.core.iobackend import resolve_backend
from repro.core.reader import (
    DEFAULT_COALESCE_GAP,
    DEFAULT_SPAN_GUESS,
    ReadStats,
    stream_plan,
)
from repro.core.records import RecordStore, read_record_at
from repro.data.sampler import GlobalSampler
from repro.data.tokenizer import ByteTokenizer, render_example

__all__ = ["IndexedDataset", "BatchLoader", "StragglerStats"]


@dataclass
class StragglerStats:
    fetches: int = 0
    retries: int = 0
    deadline_misses: int = 0
    verify_failures: int = 0


class IndexedDataset:
    """Record-level access through the byte-offset index.

    Fetches ride the pipelined extraction engine
    (:mod:`repro.core.reader`): a step's record set coalesces into merged
    preads per file, files fan out over ``workers`` threads, and an
    optional :class:`~repro.core.cache.RecordCache` (``cache=`` or
    ``cache_records > 0``) serves epoch-loop repeats without re-reading or
    re-verifying.  Caching is opt-in because a cached record is served
    as-verified — a corpus mutated underneath the loader would go
    unnoticed until eviction.  ``workers=0`` falls back to the serial
    per-record loop.  ``reader_backend``/``reader_depth`` select and
    window the span I/O backend (uring/thread/mmap — see
    :mod:`repro.core.iobackend`); the backend handle is owned by the
    dataset, opened lazily on the first engine fetch, and released by
    :meth:`close`.

    ``service`` (a :class:`repro.service.QueryService`) rides the shared
    query service instead of a private index handle: step fetches then
    coalesce with every other service caller (serving traffic, concurrent
    loaders) through the continuous-batching scheduler, and the service's
    scan-resistant record cache absorbs epoch repeats.  ``index`` may be
    ``None`` in that case; the dataset's own ``cache``/``workers`` knobs
    defer to the service's.
    """

    def __init__(
        self,
        store: RecordStore,
        index,  # ByteOffsetIndex | IndexStore (batch read contract) | None
        seq_len: int,
        verify: bool = True,
        workers: int = 2,
        cache: Optional[RecordCache] = None,
        cache_records: int = 0,
        coalesce_gap: int = DEFAULT_COALESCE_GAP,
        span_guess: int = DEFAULT_SPAN_GUESS,
        service=None,  # repro.service.QueryService
        reader_backend: Optional[str] = None,
        reader_depth: Optional[int] = None,
    ):
        if index is None and service is None:
            raise ValueError("need an index or a QueryService")
        self.store = store
        self.index = index
        self.service = service
        self.seq_len = seq_len
        self.verify = verify
        self.workers = workers
        self.coalesce_gap = coalesce_gap
        self.span_guess = span_guess
        self.reader_backend = reader_backend
        self.reader_depth = reader_depth
        # span I/O backend is resolved lazily on the first engine fetch so
        # datasets that only ride the service (or only fetch_record) never
        # open a uring / spin up read state they won't use
        self._backend = None
        if service is not None:
            self.cache = service.cache
        else:
            self.cache = cache if cache is not None else (
                RecordCache(capacity=cache_records) if cache_records > 0 else None
            )
        self.tok = ByteTokenizer()
        # dataset order = sorted index keys (deterministic across hosts;
        # iter_keys is the enumeration every index backend shares)
        enum = index if index is not None else service.router
        self.keys: List[str] = sorted(enum.iter_keys())
        self.stats = StragglerStats()
        self.read_stats = ReadStats()
        # long-lived worker pool: fetch_many runs every training step, so
        # per-call pool construction would be pure hot-path overhead
        self._pool: Optional[ThreadPoolExecutor] = None

    def __len__(self) -> int:
        return len(self.keys)

    def fetch_record(self, key: str) -> str:
        if self.service is not None:
            loc = self.service.lookup([key])[0]
        else:
            loc = self.index.lookup(key)
        if loc is None:
            raise KeyError(key)
        fname, off = loc
        if self.cache is not None:
            hit = self.cache.get(fname, off)
            if hit is not None:
                p = hit[0]
                # the engine caches zero-copy RecordViews; decode at the
                # dataset's API boundary — callers get str, always
                return p if isinstance(p, str) else p.text
        text = read_record_at(self.store.path_of(fname), off)
        if self.cache is not None:
            self.cache.put(fname, off, text)
        return text

    def fetch_many(self, keys: List[str]) -> Dict[str, str]:
        """Grouped + offset-sorted fetch (Algorithm 3 access pattern).

        Planning goes through ONE batched index lookup (``plan_extraction``
        → ``locate_batch``), so a step's whole fetch set is digested,
        Bloom-filtered, and probed together when the index is a sharded
        ``IndexStore``; the read phase then streams through the pipelined
        engine (coalesced preads, parallel file workers, cached records).
        On the service path the same probe additionally coalesces with
        concurrent service callers before it reaches the router.
        """
        if self.service is not None:
            res = self.service.fetch(keys, verify=self.verify)
            if res.missing:
                raise KeyError(f"{len(res.missing)} keys missing from index")
            self.stats.fetches += res.seeks
            self.stats.verify_failures += len(res.mismatches)
            return res.records
        plan, missing = plan_extraction(self.index, keys)
        if missing:
            raise KeyError(f"{len(missing)} keys missing from index")
        out: Dict[str, str] = {}
        if self.workers > 0:
            if self.workers > 1 and self._pool is None:
                self._pool = ThreadPoolExecutor(max_workers=self.workers)
            if self._backend is None:
                self._backend = resolve_backend(self.reader_backend)
            for ev in stream_plan(
                self.store,
                plan,
                verify=self.verify,
                workers=self.workers,
                coalesce_gap=self.coalesce_gap,
                span_guess=self.span_guess,
                cache=self.cache,
                stats=self.read_stats,
                executor=self._pool,
                backend=self._backend,
                depth=self.reader_depth,
            ):
                self.stats.fetches += 1
                if ev.ok:
                    out[ev.full_id] = ev.text
                else:
                    self.stats.verify_failures += 1
            return out
        for fname, items in plan.items():
            path = self.store.path_of(fname)
            with open(path, "rb") as fh:
                for full_id, _key, off in items:
                    text = read_record_at(fh, off)
                    self.stats.fetches += 1
                    if self.verify:
                        try:
                            rid = canonical_id_from_structure(text)
                        except ValueError:
                            rid = "<unparseable>"
                        if rid != full_id:
                            self.stats.verify_failures += 1
                            continue
                    out[full_id] = text
        return out

    def close(self) -> None:
        """Release the worker pool and the owned span I/O backend."""
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None
        if self._backend is not None:
            self._backend.close()
            self._backend = None

    def example(self, idx: int) -> Tuple[np.ndarray, np.ndarray]:
        key = self.keys[idx % len(self.keys)]
        text = render_example(self.fetch_record(key))
        if text is None:
            # property-less record: substitute the id-only rendering
            text = key
        ids = self.tok.encode(text)
        return self.tok.pad_to(ids, self.seq_len)

    def batch_for(
        self, sampler: GlobalSampler, step: int, dp_rank: int, n_dp: int
    ) -> Dict[str, np.ndarray]:
        idxs = sampler.example_ids(step, dp_rank, n_dp)
        keys = [self.keys[i % len(self.keys)] for i in idxs]
        records = self.fetch_many(keys)
        toks, masks = [], []
        for k in keys:
            text = render_example(records[k]) if k in records else k
            if text is None:
                text = k
            t, m = self.tok.pad_to(self.tok.encode(text), self.seq_len)
            toks.append(t)
            masks.append(m)
        return {
            "tokens": np.stack(toks),
            "loss_mask": np.stack(masks),
        }


class BatchLoader:
    """Prefetching loader with deadline-based speculative retry.

    ``fetch_fn(step) -> batch`` defaults to the dataset's grouped fetch;
    tests inject slow/flaky fetchers to exercise the straggler path.
    """

    def __init__(
        self,
        dataset: IndexedDataset,
        sampler: GlobalSampler,
        dp_rank: int = 0,
        n_dp: int = 1,
        prefetch: int = 2,
        deadline_s: float = 30.0,
        fetch_fn: Optional[Callable[[int], Dict[str, np.ndarray]]] = None,
    ):
        self.dataset = dataset
        self.sampler = sampler
        self.dp_rank = dp_rank
        self.n_dp = n_dp
        self.deadline_s = deadline_s
        self.fetch_fn = fetch_fn or (
            lambda step: dataset.batch_for(sampler, step, dp_rank, n_dp)
        )
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._next_step = 0
        self.stats = dataset.stats

    # -- prefetch thread ----------------------------------------------------

    def start(self, from_step: int = 0) -> None:
        self._next_step = from_step
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _fetch_with_deadline(self, step: int) -> Dict[str, np.ndarray]:
        """One fetch; on deadline miss, speculatively re-issue (stateless
        addressing makes the retry identical and side-effect free)."""
        result: Dict[str, object] = {}
        done = threading.Event()

        def run():
            try:
                result["batch"] = self.fetch_fn(step)
            except Exception as e:  # pragma: no cover
                result["err"] = e
            finally:
                done.set()

        t = threading.Thread(target=run, daemon=True)
        t.start()
        if not done.wait(self.deadline_s):
            self.stats.deadline_misses += 1
            self.stats.retries += 1
            # speculative retry; first finisher wins
            t2 = threading.Thread(target=run, daemon=True)
            t2.start()
            done.wait()
        if "err" in result:
            raise result["err"]  # type: ignore[misc]
        return result["batch"]  # type: ignore[return-value]

    def _worker(self) -> None:
        while not self._stop.is_set():
            step = self._next_step
            batch = self._fetch_with_deadline(step)
            self._next_step = step + 1
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.25)
                    break
                except queue.Full:
                    continue

    def get(self, timeout: float = 60.0) -> Tuple[int, Dict[str, np.ndarray]]:
        return self._q.get(timeout=timeout)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)

    # -- synchronous convenience --------------------------------------------

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        return self._fetch_with_deadline(step)
