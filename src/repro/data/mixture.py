"""Deterministic multi-corpus mixture sampling.

Production pretraining draws from several corpora with domain weights
(e.g. validated-intersection data upweighted vs raw single-source data —
exactly the quality tiers the paper's integration funnel produces).  This
sampler keeps the data-plane invariants of :mod:`repro.data.sampler`:

* ``(step, slot)`` → (corpus, example) is a **pure function** — the
  checkpoint is still one integer, elastic re-shard still exact;
* corpus choice per global slot uses a stateless hash (no RNG state),
  so any worker can recompute any other worker's draw;
* within a corpus, examples follow that corpus's own Feistel shuffle
  epoch-by-epoch (no example skipped or repeated within an epoch of the
  per-corpus stream).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from repro.data.sampler import FeistelShuffle

__all__ = ["MixtureSampler"]


def _hash01(seed: int, x: int) -> float:
    h = hashlib.blake2b(f"{seed}:{x}".encode(), digest_size=8).digest()
    return int.from_bytes(h, "big") / 2**64


@dataclass(frozen=True)
class MixtureSampler:
    """Weighted mixture over K corpora with stateless addressing."""

    sizes: Tuple[int, ...]            # examples per corpus
    weights: Tuple[float, ...]        # sampling weights (normalized)
    global_batch: int
    seed: int = 0

    def __post_init__(self):
        if len(self.sizes) != len(self.weights):
            raise ValueError("sizes/weights length mismatch")
        if any(w < 0 for w in self.weights) or sum(self.weights) <= 0:
            raise ValueError("weights must be non-negative, sum > 0")

    def _corpus_for(self, g: int) -> int:
        """Corpus of global slot ``g`` (stateless categorical draw)."""
        u = _hash01(self.seed * 7919 + 1, g)
        total = sum(self.weights)
        acc = 0.0
        for i, w in enumerate(self.weights):
            acc += w / total
            if u < acc:
                return i
        return len(self.weights) - 1

    def _rank_within_corpus(self, g: int, corpus: int) -> int:
        """How many slots before ``g`` chose ``corpus`` (pure in (seed, g)).

        Exact counting, memoized monotonically per (sampler-identity,
        corpus): amortized O(1) per sequential slot, O(g) worst case on a
        cold jump — still a pure function of the inputs, so determinism
        and elasticity are preserved.
        """
        key = (self.seed, self.sizes, self.weights, corpus)
        cache = _rank_cache.setdefault(key, {0: 0})  # rank before slot 0
        if g in cache:
            return cache[g]
        gmax = max(k for k in cache if k <= g)
        rank = cache[gmax]
        for x in range(gmax, g):
            if self._corpus_for(x) == corpus:
                rank += 1
        cache[g] = rank
        return rank

    def example_for_slot(self, g: int) -> Tuple[int, int]:
        """global slot → (corpus index, example index within corpus)."""
        c = self._corpus_for(g)
        r = self._rank_within_corpus(g, c)
        n = self.sizes[c]
        epoch, idx = divmod(r, n)
        shuf = FeistelShuffle(n, self.seed * 1000003 + 31 * c + epoch)
        return c, shuf(idx)

    def batch_slots(self, step: int, dp_rank: int, n_dp: int) -> List[int]:
        if self.global_batch % n_dp:
            raise ValueError("global_batch not divisible by dp")
        per = self.global_batch // n_dp
        base = step * self.global_batch + dp_rank * per
        return list(range(base, base + per))

    def batch_examples(
        self, step: int, dp_rank: int, n_dp: int
    ) -> List[Tuple[int, int]]:
        return [self.example_for_slot(g) for g in self.batch_slots(step, dp_rank, n_dp)]


_rank_cache: Dict[tuple, Dict[int, int]] = {}
