"""Byte-level tokenizer for indexed-corpus LM training.

Vocabulary: 256 raw bytes + BOS/EOS/PAD specials.  Deterministic, needs no
training artifacts, and any vocabulary size ≥ 259 in the assigned configs
embeds it trivially (ids above 258 are simply never produced — the
embedding rows exist, which is what the shape cells exercise).

``render_example`` turns one SDF record into the training text: the
canonical id plus its computed property ("XLOGP3=…"), i.e. the
logP-prediction formulation the paper's final dataset targets.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.records import extract_property
from repro.core.sdfgen import PROP_ID, PROP_XLOGP

__all__ = ["ByteTokenizer", "render_example"]

BOS = 256
EOS = 257
PAD = 258
VOCAB = 259


class ByteTokenizer:
    bos_id = BOS
    eos_id = EOS
    pad_id = PAD
    vocab_size = VOCAB

    def encode(self, text: str, add_bos: bool = True, add_eos: bool = True) -> List[int]:
        ids = list(text.encode("utf-8"))
        if add_bos:
            ids = [BOS] + ids
        if add_eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids) -> str:
        return bytes(i for i in ids if 0 <= i < 256).decode("utf-8", "replace")

    def pad_to(self, ids: List[int], length: int) -> Tuple[np.ndarray, np.ndarray]:
        """(tokens, loss_mask) both (length,); mask 0 on padding."""
        ids = ids[:length]
        out = np.full((length,), PAD, np.int32)
        out[: len(ids)] = ids
        mask = np.zeros((length,), np.float32)
        mask[: len(ids)] = 1.0
        return out, mask


def render_example(record_text: str) -> Optional[str]:
    """SDF record → training text (canonical id → property)."""
    full_id = extract_property(record_text, PROP_ID)
    if full_id is None:
        return None
    xlogp = extract_property(record_text, PROP_XLOGP)
    if xlogp is None:
        return None  # the paper's final-phase exclusion (missing property)
    return f"{full_id}\nXLOGP3={xlogp}"
