"""Fault-tolerant run coordination: heartbeats, failure detection, elastic
restart.

The container has one host, so multi-node failure handling is exercised
through the same mechanism a TPU-pod deployment uses in miniature:

* every worker (simulated or real) renews a **heartbeat file**
  (``hb_<rank>``) under the run directory;
* the coordinator scans heartbeats; a worker whose heartbeat is older
  than ``timeout`` is declared dead;
* recovery = restart from the latest **catalog checkpoint** with the
  surviving worker count: the deterministic sampler re-partitions the
  global example order over the new dp extent (no data loss / no
  duplication — DESIGN.md §2), and the mesh is re-carved via
  ``make_mesh`` with the surviving shape.

``run_with_failures`` drives a train function through injected failures
and asserts the recovery invariants — used by the integration tests and
the fault-tolerance example.
"""

from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "BackoffPolicy",
    "ElasticPlan",
    "FailureDetector",
    "Heartbeat",
    "run_with_failures",
]


class Heartbeat:
    def __init__(self, rundir: Path, rank: int):
        self.path = Path(rundir) / f"hb_{rank:05d}"
        self.rank = rank

    def beat(self, step: int) -> None:
        # with_name, not with_suffix: suffix replacement rewrites anything
        # after the last dot of the final component, so a dotted file name
        # would lose part of its rank; and the tmp name carries the pid AND
        # thread ident so neither two processes nor two pool threads beating
        # the same rank ever interleave writes into one tmp file
        # (os.replace keeps the publish itself atomic).
        tmp = self.path.with_name(
            f"{self.path.name}.{os.getpid()}.{threading.get_ident()}.tmp"
        )
        try:
            tmp.write_text(json.dumps({"step": step, "t": time.time()}))
            os.replace(tmp, self.path)
        except BaseException:
            tmp.unlink(missing_ok=True)
            raise

    def read(self) -> Optional[dict]:
        try:
            return json.loads(self.path.read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            return None


class FailureDetector:
    """Coordinator-side: who is alive, who missed their deadline."""

    def __init__(self, rundir: Path, n_workers: int, timeout: float = 5.0):
        self.rundir = Path(rundir)
        self.n_workers = n_workers
        self.timeout = timeout

    def alive(self) -> List[int]:
        now = time.time()
        out = []
        for r in range(self.n_workers):
            hb = Heartbeat(self.rundir, r).read()
            if hb is not None and now - hb["t"] <= self.timeout:
                out.append(r)
        return out

    def dead(self) -> List[int]:
        a = set(self.alive())
        return [r for r in range(self.n_workers) if r not in a]


@dataclass(frozen=True)
class BackoffPolicy:
    """Exponential backoff schedule (dead-replica re-probe, retry waits).

    ``delay(attempt)`` is ``base_s * multiplier**attempt`` capped at
    ``cap_s`` — attempt 0 is the first wait after the failure that opened
    the backoff window.  Shared by the serving tier's
    :class:`~repro.service.health.HealthTracker` (how long a dead replica
    stays unprobed) and any coordinator that wants paced re-admission.
    """

    base_s: float = 0.2
    multiplier: float = 2.0
    cap_s: float = 5.0

    def delay(self, attempt: int) -> float:
        if attempt < 0:
            raise ValueError(f"attempt must be >= 0, got {attempt}")
        return float(min(self.cap_s, self.base_s * self.multiplier ** attempt))


@dataclass(frozen=True)
class ElasticPlan:
    """Mesh + sampler re-carve after a failure."""

    n_dp: int
    n_model: int

    @staticmethod
    def for_survivors(n_survivors: int, n_model: int) -> "ElasticPlan":
        """Largest dp extent that the survivors can host (model size fixed:
        TP groups must stay whole — a lost chip kills its whole TP group)."""
        if n_survivors < 1:
            raise RuntimeError("no survivors")
        return ElasticPlan(n_dp=max(1, n_survivors), n_model=n_model)


@dataclass
class FailureLog:
    events: List[dict] = field(default_factory=list)

    def record(self, **kw) -> None:
        self.events.append(dict(kw, t=time.time()))


def run_with_failures(
    total_steps: int,
    train_chunk: Callable[[int, int, int], Tuple[int, dict]],
    fail_at: Dict[int, int],
    initial_dp: int = 4,
) -> FailureLog:
    """Drive training through injected failures.

    ``train_chunk(start_step, until_step, n_dp) -> (reached_step, info)``
    runs training (checkpointing inside) and returns where it stopped.
    ``fail_at`` maps step → number of dp shards lost at that step.
    The loop restarts each time from the last checkpoint with the reduced
    dp extent, exactly as the coordinator would.
    """
    log = FailureLog()
    n_dp = initial_dp
    step = 0
    pending = dict(fail_at)
    while step < total_steps:
        # a failure scheduled at the current step (including step 0, before
        # any training has run) applies before the next chunk launches —
        # the chunk must already see the reduced dp extent
        if step in pending:
            lost = pending.pop(step)
            n_dp = max(1, n_dp - lost)
            log.record(kind="failure", at=step, lost=lost, new_dp=n_dp)
        # next failure boundary in this chunk (if any)
        upcoming = sorted(s for s in pending if s > step)
        until = min([total_steps] + upcoming)
        reached, info = train_chunk(step, until, n_dp)
        log.record(kind="chunk", start=step, until=until, reached=reached,
                   n_dp=n_dp, **info)
        step = reached
    return log
