"""Uniform model API over all assigned architecture families.

``build_model(cfg)`` returns a :class:`ModelApi` with the same five entry
points regardless of family — the trainer, serving engine, dry-run and
benchmarks program against this interface only:

  init(key)                       → (params, param_specs)
  loss(params, batch)             → (scalar, metrics)       [train_step core]
  prefill(params, batch, max_len) → (last_logits, cache)
  decode_step(params, token, pos, cache) → (logits, cache)  [serve_step core]
  cache_init(batch, max_len)      → (cache, cache_specs)
  input_specs(shape)              → dict of ShapeDtypeStructs (dry-run)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

from . import encdec, hybrid, ssm, transformer

__all__ = ["ModelApi", "build_model"]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    cache_init: Callable

    def input_specs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of this cell.

        No device allocation — the same pattern the dry-run uses for full
        production configs (weak-type-correct, shardable).
        """
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        f32 = jnp.float32
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            specs: Dict[str, jax.ShapeDtypeStruct] = {}
            s_txt = s - (cfg.n_img_tokens or 0)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s_txt), i32)
            if shape.kind == "train":
                specs["loss_mask"] = jax.ShapeDtypeStruct((b, s_txt), f32)
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.enc_frames, cfg.d_model), f32
                )
            if cfg.family == "vlm":
                specs["patch_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_img_tokens, cfg.d_model), f32
                )
            return specs
        # decode: one new token against a cache of seq_len
        return {
            "token": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((b,), i32),
        }


def _transformer_api(cfg: ModelConfig) -> ModelApi:
    def loss(params, batch):
        return transformer.lm_loss(
            params,
            cfg,
            batch["tokens"],
            loss_mask=batch.get("loss_mask"),
            extra_embeds=batch.get("patch_embeds"),
        )

    def prefill(params, batch, max_len=None):
        return transformer.lm_prefill(
            params,
            cfg,
            batch["tokens"],
            extra_embeds=batch.get("patch_embeds"),
            max_len=max_len,
        )

    return ModelApi(
        cfg=cfg,
        init=lambda key: transformer.init_lm(cfg, key),
        loss=loss,
        prefill=prefill,
        decode_step=lambda p, t, pos, c: transformer.lm_decode_step(p, cfg, t, pos, c),
        cache_init=lambda b, m: transformer.lm_cache_init(cfg, b, m),
    )


def _hybrid_api(cfg: ModelConfig) -> ModelApi:
    return ModelApi(
        cfg=cfg,
        init=lambda key: hybrid.init_hybrid(cfg, key),
        loss=lambda p, batch: hybrid.hybrid_loss(
            p, cfg, batch["tokens"], loss_mask=batch.get("loss_mask")
        ),
        prefill=lambda p, batch, max_len=None: hybrid.hybrid_prefill(
            p, cfg, batch["tokens"], max_len=max_len
        ),
        decode_step=lambda p, t, pos, c: hybrid.hybrid_decode_step(p, cfg, t, pos, c),
        cache_init=lambda b, m: hybrid.hybrid_cache_init(cfg, b, m),
    )


def _ssm_api(cfg: ModelConfig) -> ModelApi:
    return ModelApi(
        cfg=cfg,
        init=lambda key: ssm.init_ssm(cfg, key),
        loss=lambda p, batch: ssm.ssm_loss(
            p, cfg, batch["tokens"], loss_mask=batch.get("loss_mask")
        ),
        prefill=lambda p, batch, max_len=None: ssm.ssm_prefill(
            p, cfg, batch["tokens"], max_len=max_len
        ),
        decode_step=lambda p, t, pos, c: ssm.ssm_decode_step(p, cfg, t, pos, c),
        cache_init=lambda b, m: ssm.ssm_cache_init(cfg, b, m),
    )


def _encdec_api(cfg: ModelConfig) -> ModelApi:
    return ModelApi(
        cfg=cfg,
        init=lambda key: encdec.init_encdec(cfg, key),
        loss=lambda p, batch: encdec.encdec_loss(
            p, cfg, batch["frames"], batch["tokens"],
            loss_mask=batch.get("loss_mask"),
        ),
        prefill=lambda p, batch, max_len=None: encdec.encdec_prefill(
            p, cfg, batch["frames"], batch["tokens"], max_len=max_len
        ),
        decode_step=lambda p, t, pos, c: encdec.encdec_decode_step(p, cfg, t, pos, c),
        cache_init=lambda b, m: encdec.encdec_cache_init(cfg, b, m),
    )


def build_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family in ("dense", "moe", "vlm"):
        return _transformer_api(cfg)
    if cfg.family == "hybrid":
        return _hybrid_api(cfg)
    if cfg.family == "ssm":
        return _ssm_api(cfg)
    if cfg.family == "encdec":
        return _encdec_api(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
