"""Uniform model API over all assigned architecture families.

``build_model(cfg)`` returns a :class:`ModelApi` with the same five entry
points regardless of family — the trainer, serving engine, dry-run and
benchmarks program against this interface only:

  init(key)                       → (params, param_specs)
  loss(params, batch)             → (scalar, metrics)       [train_step core]
  prefill(params, batch, max_len) → (last_logits, cache)
  decode_step(params, token, pos, cache) → (logits, cache)  [serve_step core]
  cache_init(batch, max_len)      → (cache, cache_specs)
  input_specs(shape)              → dict of ShapeDtypeStructs (dry-run)

``batch["lengths"]`` (B,) in prefill gathers each sequence's true
last-prompt-position logits, so ragged right-padded batches don't start
greedy continuation from a pad row.

Families that support the paged (block) KV cache — the continuous-batching
serving path — additionally expose three optional entry points (``None``
elsewhere; the continuous engine refuses politely):

  paged_cache_init(n_blocks, block_size)           → (cache, cache_specs)
  decode_step_paged(params, token, pos, tables, cache, block_size)
                                                   → (logits, cache)
  paged_prefill_write(cache, prefill_cache, table_row, block_size, start=0)
                                                   → cache
  prefill_suffix(params, tokens, start, table_row, cache, block_size,
                 lengths)                          → (logits, cache)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

from . import encdec, hybrid, ssm, transformer

__all__ = ["ModelApi", "build_model"]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    cache_init: Callable
    # paged-KV serving contract (continuous batching); None where unsupported
    paged_cache_init: Optional[Callable] = None
    decode_step_paged: Optional[Callable] = None
    paged_prefill_write: Optional[Callable] = None
    prefill_suffix: Optional[Callable] = None

    @property
    def supports_paged(self) -> bool:
        return self.decode_step_paged is not None

    def input_specs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of this cell.

        No device allocation — the same pattern the dry-run uses for full
        production configs (weak-type-correct, shardable).
        """
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        f32 = jnp.float32
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            specs: Dict[str, jax.ShapeDtypeStruct] = {}
            s_txt = s - (cfg.n_img_tokens or 0)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s_txt), i32)
            if shape.kind == "train":
                specs["loss_mask"] = jax.ShapeDtypeStruct((b, s_txt), f32)
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.enc_frames, cfg.d_model), f32
                )
            if cfg.family == "vlm":
                specs["patch_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_img_tokens, cfg.d_model), f32
                )
            return specs
        # decode: one new token against a cache of seq_len
        return {
            "token": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((b,), i32),
        }


def _transformer_api(cfg: ModelConfig) -> ModelApi:
    def loss(params, batch):
        return transformer.lm_loss(
            params,
            cfg,
            batch["tokens"],
            loss_mask=batch.get("loss_mask"),
            extra_embeds=batch.get("patch_embeds"),
        )

    def prefill(params, batch, max_len=None):
        return transformer.lm_prefill(
            params,
            cfg,
            batch["tokens"],
            extra_embeds=batch.get("patch_embeds"),
            max_len=max_len,
            lengths=batch.get("lengths"),
        )

    # paged serving covers global-attention stacks only (no sliding-window
    # ring buffers in the block pool yet) — gate here so Engine/scheduler
    # can introspect support instead of tracing into a NotImplementedError
    paged = not any(w is not None for w in transformer.layer_windows(cfg))
    return ModelApi(
        cfg=cfg,
        init=lambda key: transformer.init_lm(cfg, key),
        loss=loss,
        prefill=prefill,
        decode_step=lambda p, t, pos, c: transformer.lm_decode_step(p, cfg, t, pos, c),
        cache_init=lambda b, m: transformer.lm_cache_init(cfg, b, m),
        paged_cache_init=(
            (lambda n, bs: transformer.lm_paged_cache_init(cfg, n, bs))
            if paged else None
        ),
        decode_step_paged=(
            (lambda p, t, pos, tb, c, bs:
             transformer.lm_decode_step_paged(p, cfg, t, pos, tb, c, bs))
            if paged else None
        ),
        paged_prefill_write=(
            (lambda c, pc, row, bs, start=0:
             transformer.lm_paged_prefill_write(cfg, c, pc, row, bs, start=start))
            if paged else None
        ),
        prefill_suffix=(
            (lambda p, t, start, row, c, bs, lengths=None:
             transformer.lm_prefill_suffix(
                 p, cfg, t, start, row, c, bs, lengths=lengths))
            if paged else None
        ),
    )


def _hybrid_api(cfg: ModelConfig) -> ModelApi:
    return ModelApi(
        cfg=cfg,
        init=lambda key: hybrid.init_hybrid(cfg, key),
        loss=lambda p, batch: hybrid.hybrid_loss(
            p, cfg, batch["tokens"], loss_mask=batch.get("loss_mask")
        ),
        prefill=lambda p, batch, max_len=None: hybrid.hybrid_prefill(
            p, cfg, batch["tokens"], max_len=max_len,
            lengths=batch.get("lengths"),
        ),
        decode_step=lambda p, t, pos, c: hybrid.hybrid_decode_step(p, cfg, t, pos, c),
        cache_init=lambda b, m: hybrid.hybrid_cache_init(cfg, b, m),
    )


def _ssm_api(cfg: ModelConfig) -> ModelApi:
    return ModelApi(
        cfg=cfg,
        init=lambda key: ssm.init_ssm(cfg, key),
        loss=lambda p, batch: ssm.ssm_loss(
            p, cfg, batch["tokens"], loss_mask=batch.get("loss_mask")
        ),
        prefill=lambda p, batch, max_len=None: ssm.ssm_prefill(
            p, cfg, batch["tokens"], max_len=max_len,
            lengths=batch.get("lengths"),
        ),
        decode_step=lambda p, t, pos, c: ssm.ssm_decode_step(p, cfg, t, pos, c),
        cache_init=lambda b, m: ssm.ssm_cache_init(cfg, b, m),
    )


def _encdec_api(cfg: ModelConfig) -> ModelApi:
    return ModelApi(
        cfg=cfg,
        init=lambda key: encdec.init_encdec(cfg, key),
        loss=lambda p, batch: encdec.encdec_loss(
            p, cfg, batch["frames"], batch["tokens"],
            loss_mask=batch.get("loss_mask"),
        ),
        prefill=lambda p, batch, max_len=None: encdec.encdec_prefill(
            p, cfg, batch["frames"], batch["tokens"], max_len=max_len,
            lengths=batch.get("lengths"),
        ),
        decode_step=lambda p, t, pos, c: encdec.encdec_decode_step(p, cfg, t, pos, c),
        cache_init=lambda b, m: encdec.encdec_cache_init(cfg, b, m),
    )


def build_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family in ("dense", "moe", "vlm"):
        return _transformer_api(cfg)
    if cfg.family == "hybrid":
        return _hybrid_api(cfg)
    if cfg.family == "ssm":
        return _ssm_api(cfg)
    if cfg.family == "encdec":
        return _encdec_api(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
