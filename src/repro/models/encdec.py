"""Encoder–decoder transformer (Whisper family).

The conv audio frontend is a stub per instructions: the encoder consumes
precomputed (B, frames, d_model) frame embeddings (``input_specs`` supplies
them).  Encoder: bidirectional self-attention stack.  Decoder: causal
self-attention (RoPE — adaptation note: Whisper's learned positional
embeddings cap at 448 tokens; RoPE makes the assigned 32k decode shapes
well-defined) + cross-attention to the encoder output + MLP.

Serving: self-attention uses a contiguous KV cache; cross-attention K/V are
computed once from the encoder output at prefill and are static thereafter.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import flags
from repro.configs.base import ModelConfig
from repro.dist.logical import constrain
from repro.models.common import (
    _qkv,
    apply_rope,
    attention_apply,
    attention_decode,
    attention_init,
    chunked_xent,
    compute_dtype,
    embed_apply,
    embed_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    last_token_logits,
    unembed_logits,
)
from repro.models.transformer import _stack_inits

__all__ = [
    "init_encdec",
    "encode",
    "encdec_loss",
    "encdec_prefill",
    "encdec_decode_step",
    "encdec_cache_init",
]


def _enc_layer_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    p, s = {}, {}
    p["ln1"], s["ln1"] = rmsnorm_init(cfg.d_model)
    p["attn"], s["attn"] = attention_init(ks[0], cfg)
    p["ln2"], s["ln2"] = rmsnorm_init(cfg.d_model)
    p["mlp"], s["mlp"] = mlp_init(ks[1], cfg)
    return p, s


def _dec_layer_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["ln1"], s["ln1"] = rmsnorm_init(cfg.d_model)
    p["self"], s["self"] = attention_init(ks[0], cfg)
    p["ln2"], s["ln2"] = rmsnorm_init(cfg.d_model)
    p["cross"], s["cross"] = attention_init(ks[1], cfg)
    p["ln3"], s["ln3"] = rmsnorm_init(cfg.d_model)
    p["mlp"], s["mlp"] = mlp_init(ks[2], cfg)
    return p, s


def init_encdec(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    params["embed"], specs["embed"] = embed_init(ks[0], cfg)
    params["enc_pos"] = 0.02 * jax.random.normal(
        ks[3], (cfg.enc_frames, cfg.d_model), jnp.float32
    )
    specs["enc_pos"] = ("frames", "embed")
    params["enc_blocks"], specs["enc_blocks"] = _stack_inits(
        lambda k: _enc_layer_init(k, cfg), ks[1], cfg.n_enc_layers
    )
    params["enc_norm"], specs["enc_norm"] = rmsnorm_init(cfg.d_model)
    params["dec_blocks"], specs["dec_blocks"] = _stack_inits(
        lambda k: _dec_layer_init(k, cfg), ks[2], cfg.n_layers
    )
    params["final_norm"], specs["final_norm"] = rmsnorm_init(cfg.d_model)
    return params, specs


def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames (B, F, D) — precomputed embeddings from the stub frontend."""
    cdt = compute_dtype(cfg)
    f = frames.shape[1]
    x = frames.astype(cdt) + params["enc_pos"][:f].astype(cdt)[None]
    positions = jnp.arange(f)[None, :]

    def body(x, blk):
        x = constrain(x, "batch", "seq_sp", None)
        h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
        x = x + attention_apply(
            blk["attn"], cfg, h, positions, causal=False, use_rope=False
        )
        h = rmsnorm(x, blk["ln2"], cfg.norm_eps)
        x = x + mlp_apply(blk["mlp"], cfg, h)
        return x, None

    body = jax.checkpoint(body, policy=flags.remat_policy())
    x, _ = lax.scan(body, x, params["enc_blocks"], unroll=flags.scan_unroll())
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _dec_layer(blk, cfg: ModelConfig, x, positions, enc_out):
    h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
    x = x + attention_apply(blk["self"], cfg, h, positions, causal=True)
    h = rmsnorm(x, blk["ln2"], cfg.norm_eps)
    x = x + attention_apply(blk["cross"], cfg, h, positions, kv_from=enc_out)
    h = rmsnorm(x, blk["ln3"], cfg.norm_eps)
    return x + mlp_apply(blk["mlp"], cfg, h)


def encdec_forward(params, cfg: ModelConfig, frames, tokens):
    enc_out = encode(params, cfg, frames)
    x = embed_apply(params["embed"], cfg, tokens)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]

    def body(x, blk):
        x = constrain(x, "batch", "seq_sp", None)
        return _dec_layer(blk, cfg, x, positions, enc_out), None

    body = jax.checkpoint(body, policy=flags.remat_policy())
    x, _ = lax.scan(body, x, params["dec_blocks"], unroll=flags.scan_unroll())
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return constrain(x, "batch", "seq", None)


def encdec_loss(params, cfg: ModelConfig, frames, tokens, loss_mask=None):
    hidden = encdec_forward(params, cfg, frames, tokens)
    mask = None if loss_mask is None else loss_mask[:, 1:]
    xent = chunked_xent(params["embed"], cfg, hidden[:, :-1], tokens[:, 1:], mask)
    return xent, {"xent": xent, "aux": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def encdec_cache_init(cfg: ModelConfig, batch: int, max_len: int):
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    cdt = compute_dtype(cfg)
    l = cfg.n_layers
    cache = {
        "self": {
            "k": jnp.zeros((l, batch, hkv, max_len, dh), cdt),
            "v": jnp.zeros((l, batch, hkv, max_len, dh), cdt),
        },
        "cross": {
            "k": jnp.zeros((l, batch, hkv, cfg.enc_frames, dh), cdt),
            "v": jnp.zeros((l, batch, hkv, cfg.enc_frames, dh), cdt),
        },
    }
    spec = jax.tree_util.tree_map(
        lambda _: ("layers", "batch", "kv_heads", None, None), cache
    )
    return cache, spec


def encdec_prefill(params, cfg: ModelConfig, frames, tokens, max_len=None,
                   lengths=None):
    """Encode + decoder forward; builds self- and cross-KV caches."""
    cdt = compute_dtype(cfg)
    enc_out = encode(params, cfg, frames)
    x = embed_apply(params["embed"], cfg, tokens)
    b, s, _ = x.shape
    max_len = max(max_len or s, s)
    positions = jnp.arange(s)[None, :]

    def body(x, blk):
        from repro.kernels.flash_attention.ops import flash_attention

        h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
        q, k, v = _qkv(blk["self"], cfg, h)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kc, vc = jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)
        self_kv = {
            "k": jnp.pad(kc, ((0, 0), (0, 0), (0, max_len - s), (0, 0))).astype(cdt),
            "v": jnp.pad(vc, ((0, 0), (0, 0), (0, max_len - s), (0, 0))).astype(cdt),
        }
        att = flash_attention(jnp.swapaxes(q, 1, 2), kc, vc, causal=True)
        att = jnp.swapaxes(att, 1, 2).reshape(b, s, -1)
        x = x + att @ blk["self"]["wo"].astype(cdt)

        h = rmsnorm(x, blk["ln2"], cfg.norm_eps)
        f = enc_out.shape[1]
        hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
        kx = (enc_out @ blk["cross"]["wk"].astype(cdt)).reshape(b, f, hkv, dh)
        vx = (enc_out @ blk["cross"]["wv"].astype(cdt)).reshape(b, f, hkv, dh)
        if cfg.qkv_bias:
            kx = kx + blk["cross"]["bk"].astype(cdt).reshape(hkv, dh)
            vx = vx + blk["cross"]["bv"].astype(cdt).reshape(hkv, dh)
        cross_kv = {
            "k": jnp.swapaxes(kx, 1, 2).astype(cdt),
            "v": jnp.swapaxes(vx, 1, 2).astype(cdt),
        }
        # reuse the cross K/V just computed (§Perf: attention_apply would
        # re-project enc_out, doubling cross-attention prefill compute)
        hq, dh_ = cfg.n_heads, cfg.resolved_head_dim
        qx = (h @ blk["cross"]["wq"].astype(cdt))
        if cfg.qkv_bias:
            qx = qx + blk["cross"]["bq"].astype(cdt)
        qx = qx.reshape(b, s, hq, dh_)
        att_x = flash_attention(
            jnp.swapaxes(qx, 1, 2), cross_kv["k"], cross_kv["v"],
            causal=False,
        )
        att_x = jnp.swapaxes(att_x, 1, 2).reshape(b, s, -1)
        x = x + att_x @ blk["cross"]["wo"].astype(cdt)
        h = rmsnorm(x, blk["ln3"], cfg.norm_eps)
        x = x + mlp_apply(blk["mlp"], cfg, h)
        return x, {"self": self_kv, "cross": cross_kv}

    x, cache = lax.scan(body, x, params["dec_blocks"], unroll=flags.scan_unroll())
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = last_token_logits(params["embed"], cfg, x, lengths=lengths)
    return logits, cache


def _cross_decode(p, cfg: ModelConfig, x, cross_kv):
    """One-token cross attention against static K/V (all frames valid)."""
    cdt = compute_dtype(cfg)
    b = x.shape[0]
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x[:, 0] @ p["wq"].astype(cdt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdt)
    q = q.reshape(b, h, dh)
    g = h // hkv
    qg = q.reshape(b, hkv, g, dh).astype(cross_kv["k"].dtype)
    scores = jnp.einsum(
        "bkgd,bksd->bkgs", qg, cross_kv["k"],
        preferred_element_type=jnp.float32,
    ) / math.sqrt(dh)
    pr = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum(
        "bkgs,bksd->bkgd", pr.astype(cross_kv["v"].dtype), cross_kv["v"],
        preferred_element_type=jnp.float32,
    )
    ctx = ctx.reshape(b, h * dh).astype(cdt)
    return (ctx @ p["wo"].astype(cdt))[:, None, :]


def encdec_decode_step(params, cfg: ModelConfig, token, pos, cache):
    x = embed_apply(params["embed"], cfg, token)

    def body(x, xs):
        blk, self_kv, cross_kv = xs
        h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
        att, self_new = attention_decode(blk["self"], cfg, h, pos, self_kv)
        x = x + att
        h = rmsnorm(x, blk["ln2"], cfg.norm_eps)
        x = x + _cross_decode(blk["cross"], cfg, h, cross_kv)
        h = rmsnorm(x, blk["ln3"], cfg.norm_eps)
        x = x + mlp_apply(blk["mlp"], cfg, h)
        return x, self_new

    x, self_new = lax.scan(
        body, x, (params["dec_blocks"], cache["self"], cache["cross"]),
        unroll=flags.scan_unroll(),
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed_logits(params["embed"], cfg, x)[:, 0]
    return logits, {"self": self_new, "cross": cache["cross"]}
