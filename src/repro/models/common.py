"""Shared model substrate: norms, RoPE, GQA attention, SwiGLU MLP,
embeddings and chunked cross-entropy.

Conventions
-----------
* Functional params: nested dicts of jnp arrays.  Every ``init_*`` returns
  ``(params, specs)`` where ``specs`` is a parallel pytree of logical axis
  name tuples (see :mod:`repro.dist.logical`) — the launcher turns specs
  into NamedShardings for pjit.
* Master params are fp32; ``apply`` casts to the compute dtype (bf16).
* Activations are annotated with ``constrain`` at layer boundaries; the
  rule table decides what that means on the current mesh.
* Attention supports three modes: full sequence (train/prefill), one-token
  decode against a contiguous KV cache, and one-token decode against a
  ring-buffer windowed cache (sliding-window layers at long context).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist.logical import constrain
from repro.kernels.flash_attention.ops import flash_attention

__all__ = [
    "Dtypes",
    "dense_init",
    "rmsnorm_init",
    "rmsnorm",
    "rope_freqs",
    "apply_rope",
    "attention_init",
    "attention_apply",
    "attention_decode",
    "attention_decode_paged",
    "paged_view",
    "paged_write_rows",
    "mlp_init",
    "mlp_apply",
    "embed_init",
    "embed_apply",
    "unembed_logits",
    "last_token_logits",
    "chunked_xent",
    "param_count",
]

PyTree = Any


def compute_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, spec, scale: Optional[float] = None):
    """He-style init; returns (param, spec)."""
    if scale is None:
        scale = 1.0 / math.sqrt(shape[0])
    p = scale * jax.random.normal(key, shape, dtype=jnp.float32)
    return p, spec


def rmsnorm_init(d: int):
    return jnp.ones((d,), jnp.float32), ("embed_act",)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, Dh), positions broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig) -> Tuple[PyTree, PyTree]:
    d, h, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    params["wq"], specs["wq"] = dense_init(ks[0], (d, h * dh), ("embed", "heads"))
    params["wk"], specs["wk"] = dense_init(ks[1], (d, hkv * dh), ("embed", "heads"))
    params["wv"], specs["wv"] = dense_init(ks[2], (d, hkv * dh), ("embed", "heads"))
    params["wo"], specs["wo"] = dense_init(ks[3], (h * dh, d), ("heads", "embed"))
    if cfg.qkv_bias:
        params["bq"] = jnp.zeros((h * dh,), jnp.float32)
        params["bk"] = jnp.zeros((hkv * dh,), jnp.float32)
        params["bv"] = jnp.zeros((hkv * dh,), jnp.float32)
        specs["bq"] = ("heads",)
        specs["bk"] = ("heads",)
        specs["bv"] = ("heads",)
    return params, specs


def _qkv(params, cfg: ModelConfig, x: jax.Array):
    """x (B, S, D) -> q (B,S,H,Dh), k/v (B,S,Hkv,Dh) in compute dtype."""
    cdt = compute_dtype(cfg)
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ params["wq"].astype(cdt)
    k = x @ params["wk"].astype(cdt)
    v = x @ params["wv"].astype(cdt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(cdt)
        k = k + params["bk"].astype(cdt)
        v = v + params["bv"].astype(cdt)
    return (
        q.reshape(b, s, h, dh),
        k.reshape(b, s, hkv, dh),
        v.reshape(b, s, hkv, dh),
    )


def attention_apply(
    params,
    cfg: ModelConfig,
    x: jax.Array,                      # (B, S, D)
    positions: jax.Array,              # (S,) or (B, S)
    causal: bool = True,
    window: Optional[int] = None,
    use_rope: bool = True,
    kv_from: Optional[jax.Array] = None,  # cross-attention source (B, F, D)
) -> jax.Array:
    """Full-sequence attention (train / prefill / cross)."""
    cdt = compute_dtype(cfg)
    b, s, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    if kv_from is None:
        q, k, v = _qkv(params, cfg, x)
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    else:
        # cross attention: q from x, k/v from encoder output (no RoPE)
        f = kv_from.shape[1]
        q = (x @ params["wq"].astype(cdt)).reshape(b, s, h, dh)
        k = (kv_from @ params["wk"].astype(cdt)).reshape(b, f, hkv, dh)
        v = (kv_from @ params["wv"].astype(cdt)).reshape(b, f, hkv, dh)
        causal = False
        window = None
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "heads", None)
    v = constrain(v, "batch", "seq", "heads", None)
    out = flash_attention(
        jnp.swapaxes(q, 1, 2),
        jnp.swapaxes(k, 1, 2),
        jnp.swapaxes(v, 1, 2),
        causal=causal,
        window=window,
    )                                              # (B, H, S, Dh)
    out = jnp.swapaxes(out, 1, 2).reshape(b, s, h * dh)
    out = out @ params["wo"].astype(cdt)
    from repro import flags as _flags
    from jax.ad_checkpoint import checkpoint_name

    out = constrain(out, *_flags.residual_axes())
    return checkpoint_name(out, "attn_out")


def _gqa_decode_scores(q, k_cache, valid, cdt):
    """q (B,H,Dh), k_cache (B,Hkv,S,Dh), valid (B,S) -> ctx weights (B,H,S).

    §Perf note: the matmul runs in the cache dtype with f32 accumulation
    (preferred_element_type) — casting the whole cache to f32 doubled the
    decode cells' HBM traffic in the baseline dry-run.
    """
    b, h, dh = q.shape
    hkv = k_cache.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, dh).astype(k_cache.dtype)
    s = jnp.einsum(
        "bkgd,bksd->bkgs", qg, k_cache, preferred_element_type=jnp.float32
    )
    s = s / math.sqrt(dh)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return p  # (B, Hkv, G, S)


def decode_attention_chunked(
    q,          # (B, H, Dh)
    k_cache,    # (B, Hkv, S, Dh)
    v_cache,    # (B, Hkv, S, Dh)
    valid,      # (B, S) bool
    chunk: int = 2048,
):
    """One-token GQA attention over a cache, online-softmax over chunks.

    §Perf iteration 2 for the decode cells: the unchunked path materializes
    (B, H, S) f32 score/softmax tensors ~20× larger than the cache slice it
    reads; scanning KV chunks with an (m, l, acc) carry caps the live
    intermediate at (B, H, chunk) — the decode analogue of flash attention,
    in pure XLA.  Chunk loop honours flags.scan_unroll() (roofline probes).
    """
    from repro import flags as _flags

    b, h, dh = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    c = min(chunk, s)
    pad = (c - s % c) % c
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    nch = (s + pad) // c
    qg = (q / math.sqrt(dh)).reshape(b, hkv, g, dh).astype(k_cache.dtype)
    kc = k_cache.reshape(b, hkv, nch, c, dh).transpose(2, 0, 1, 3, 4)
    vc = v_cache.reshape(b, hkv, nch, c, dh).transpose(2, 0, 1, 3, 4)
    valc = valid.reshape(b, nch, c).transpose(1, 0, 2)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, vm = xs
        sc = jnp.einsum(
            "bkgd,bkcd->bkgc", qg, kb, preferred_element_type=jnp.float32
        )
        sc = jnp.where(vm[:, None, None, :], sc, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None]) * vm[:, None, None, :]
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgc,bkcd->bkgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, dh), jnp.float32)
    (_, l_f, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, valc), unroll=_flags.scan_unroll()
    )
    l_safe = jnp.where(l_f > 0, l_f, 1.0)
    return (acc / l_safe[..., None]).reshape(b, h, dh)  # f32


def attention_decode(
    params,
    cfg: ModelConfig,
    x: jax.Array,                 # (B, 1, D)
    pos: jax.Array,               # (B,) absolute position of the new token
    cache: Dict[str, jax.Array],  # {"k","v"}: (B, Hkv, S_slots, Dh)
    window: Optional[int] = None,
    use_rope: bool = True,
    update_cache: bool = True,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode.  Contiguous cache when ``window is None`` (slot =
    absolute position); ring-buffer cache otherwise (slot = pos % window)."""
    cdt = compute_dtype(cfg)
    b, _, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _qkv(params, cfg, x)            # (B,1,H,Dh)/(B,1,Hkv,Dh)
    if use_rope:
        p1 = pos[:, None]
        q = apply_rope(q, p1, cfg.rope_theta)
        k = apply_rope(k, p1, cfg.rope_theta)
    q = q[:, 0]                                # (B, H, Dh)
    k_new = jnp.swapaxes(k, 1, 2)              # (B, Hkv, 1, Dh)
    v_new = jnp.swapaxes(v, 1, 2)

    slots = cache["k"].shape[2]
    slot = pos % window if window is not None else pos

    if update_cache:
        def upd(c, n, s_):
            return lax.dynamic_update_slice(c, n.astype(c.dtype), (0, s_, 0))

        k_cache = jax.vmap(upd)(cache["k"], k_new, slot)
        v_cache = jax.vmap(upd)(cache["v"], v_new, slot)
    else:
        k_cache, v_cache = cache["k"], cache["v"]

    idx = jnp.arange(slots)[None, :]           # (1, S_slots)
    if window is None:
        valid = idx <= pos[:, None]
    else:
        # ring buffer: slot s holds token t = pos - ((pos - s) mod W)
        t = pos[:, None] - (pos[:, None] - idx) % window
        valid = t >= 0
    from repro import flags as _flags

    # §Perf note (EXPERIMENTS.md, decode iteration 2 — REFUTED): chunking
    # the decode cache breaks its (batch, seq→model) sharding: the
    # reshape/transpose reshards ~5 GB of cache per layer (collective term
    # 0→3.4 s).  The unchunked einsum+softmax is already GSPMD's
    # flash-decoding pattern (per-shard partial softmax + scalar combines),
    # so it stays the default; REPRO_DECODE_CHUNKED=1 exists for
    # single-device serving experiments.
    if _flags.DECODE_CHUNKED:
        ctx = decode_attention_chunked(q, k_cache, v_cache, valid)
    else:
        p = _gqa_decode_scores(q, k_cache, valid, cdt)  # (B,Hkv,G,S) f32
        ctx = jnp.einsum(
            "bkgs,bksd->bkgd", p.astype(v_cache.dtype), v_cache,
            preferred_element_type=jnp.float32,
        )
    ctx = ctx.reshape(b, h * dh).astype(cdt)
    out = (ctx @ params["wo"].astype(cdt))[:, None, :]  # (B,1,D)
    return out, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# paged (block) KV cache
# ---------------------------------------------------------------------------
#
# The paged cache replaces the per-sequence contiguous (B, Hkv, S, Dh)
# cache with ONE preallocated pool of fixed-size blocks shared by every
# batch slot: pool (Hkv, P, Dh) where P = n_blocks * block_size and block
# i owns rows [i*bs, (i+1)*bs).  A per-slot block table (B, M) of block
# ids maps logical token position t to pool row
# ``table[b, t // bs] * bs + t % bs``.  All shapes are static (fixed pool,
# fixed table width), so decode traces once and slot admission/eviction
# never retraces — the whole point for continuous batching.  Block id 0
# is reserved as a trash block: unallocated table entries point at it, so
# writes from inactive slots land somewhere harmless and reads from it
# are always masked by the position-validity mask.


def paged_view(pool: jax.Array, tables: jax.Array, block_size: int) -> jax.Array:
    """Gather per-slot contiguous KV views out of the block pool.

    pool (Hkv, P, Dh), tables (B, M) int32 → (B, Hkv, M*bs, Dh).  The
    gather is jit-stable: output shape depends only on the static table
    width, never on how many blocks a slot actually owns.
    """
    b, m = tables.shape
    flat = (
        tables[:, :, None] * block_size
        + jnp.arange(block_size, dtype=tables.dtype)[None, None, :]
    ).reshape(b, m * block_size)
    return jnp.swapaxes(pool[:, flat], 0, 1)  # (B, Hkv, L, Dh)


def paged_write_rows(
    pool: jax.Array,        # (Hkv, P, Dh)
    rows: jax.Array,        # (Hkv, S, Dh) values for logical positions start..start+S-1
    table_row: jax.Array,   # (M,) int32 block table of the target slot
    block_size: int,
    start: int = 0,
) -> jax.Array:
    """Scatter S contiguous logical positions of one slot into the pool
    (prefill → paged cache hand-off).  ``start`` offsets the logical
    positions — suffix prefill writes rows start..start+S-1 after adopted
    prefix blocks, leaving those untouched.  Positions past the slot's
    allocated blocks resolve to the trash block."""
    s = rows.shape[1]
    t = start + jnp.arange(s)
    flat = table_row[t // block_size] * block_size + t % block_size
    return pool.at[:, flat, :].set(rows.astype(pool.dtype))


def attention_decode_paged(
    params,
    cfg: ModelConfig,
    x: jax.Array,                 # (B, 1, D)
    pos: jax.Array,               # (B,) absolute position of the new token
    cache: Dict[str, jax.Array],  # {"k","v"}: (Hkv, P, Dh) block pools
    tables: jax.Array,            # (B, M) int32 block tables
    block_size: int,
    use_rope: bool = True,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode against the paged pool.

    Write-then-gather: the new token's K/V goes to its slot's block at
    ``pos``, then the slot's blocks are gathered into a contiguous
    (B, Hkv, L, Dh) view and the math is exactly
    :func:`attention_decode`'s — same einsums, same masking constant — so
    greedy decode is byte-identical to the contiguous cache whenever the
    view length L matches the contiguous slot count (masked rows
    contribute exact zeros either way).
    """
    cdt = compute_dtype(cfg)
    b, _, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _qkv(params, cfg, x)            # (B,1,H,Dh)/(B,1,Hkv,Dh)
    if use_rope:
        p1 = pos[:, None]
        q = apply_rope(q, p1, cfg.rope_theta)
        k = apply_rope(k, p1, cfg.rope_theta)
    q = q[:, 0]                                # (B, H, Dh)
    k_new = jnp.swapaxes(k, 1, 2)[:, :, 0]     # (B, Hkv, Dh)
    v_new = jnp.swapaxes(v, 1, 2)[:, :, 0]

    flat_w = (
        tables[jnp.arange(b), pos // block_size] * block_size
        + pos % block_size
    )                                          # (B,)
    k_pool = cache["k"].at[:, flat_w, :].set(
        jnp.swapaxes(k_new, 0, 1).astype(cache["k"].dtype)
    )
    v_pool = cache["v"].at[:, flat_w, :].set(
        jnp.swapaxes(v_new, 0, 1).astype(cache["v"].dtype)
    )

    k_cache = paged_view(k_pool, tables, block_size)   # (B, Hkv, L, Dh)
    v_cache = paged_view(v_pool, tables, block_size)
    slots = k_cache.shape[2]
    valid = jnp.arange(slots)[None, :] <= pos[:, None]
    from repro import flags as _flags

    if _flags.DECODE_CHUNKED:
        ctx = decode_attention_chunked(q, k_cache, v_cache, valid)
    else:
        p = _gqa_decode_scores(q, k_cache, valid, cdt)  # (B,Hkv,G,S) f32
        ctx = jnp.einsum(
            "bkgs,bksd->bkgd", p.astype(v_cache.dtype), v_cache,
            preferred_element_type=jnp.float32,
        )
    ctx = ctx.reshape(b, h * dh).astype(cdt)
    out = (ctx @ params["wo"].astype(cdt))[:, None, :]  # (B,1,D)
    return out, {"k": k_pool, "v": v_pool}


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    params = {}
    specs = {}
    params["wg"], specs["wg"] = dense_init(ks[0], (d, f), ("embed", "d_ff"))
    params["wu"], specs["wu"] = dense_init(ks[1], (d, f), ("embed", "d_ff"))
    params["wd"], specs["wd"] = dense_init(ks[2], (f, d), ("d_ff", "embed"))
    return params, specs


def mlp_apply(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    cdt = compute_dtype(cfg)
    g = jax.nn.silu(x @ params["wg"].astype(cdt))
    u = x @ params["wu"].astype(cdt)
    h = constrain(g * u, "batch", "seq", "d_ff")
    out = h @ params["wd"].astype(cdt)
    from repro import flags as _flags
    from jax.ad_checkpoint import checkpoint_name

    out = constrain(out, *_flags.residual_axes())
    return checkpoint_name(out, "ffn_out")


# ---------------------------------------------------------------------------
# embedding / unembedding / loss
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig):
    v, d = cfg.vocab_size, cfg.d_model
    ks = jax.random.split(key, 2)
    params = {"table": 0.02 * jax.random.normal(ks[0], (v, d), jnp.float32)}
    specs = {"table": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        params["unembed"], specs["unembed"] = dense_init(
            ks[1], (d, v), ("embed", "vocab"), scale=0.02
        )
    return params, specs


def embed_apply(params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    cdt = compute_dtype(cfg)
    # Relayout the table for the lookup: vocab-replicated, d_model sharded
    # over the FSDP axes.  Gathering straight from the (vocab→model,
    # d→fsdp) training layout makes SPMD "involuntarily fully rematerialize"
    # the gathered activations (XLA b/433785288); one explicit all-gather of
    # the (small) table shard is strictly cheaper.  §Perf iteration.
    table = constrain(params["table"].astype(cdt), None, "embed")
    x = table[tokens]
    return constrain(x, "batch", "seq", None)


def unembed_logits(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    cdt = compute_dtype(cfg)
    w = (
        params["table"].T if cfg.tie_embeddings else params["unembed"]
    ).astype(cdt)
    logits = x @ w
    return constrain(logits, "batch", "seq", "vocab")


def last_token_logits(
    params,
    cfg: ModelConfig,
    hidden: jax.Array,                    # (B, S, D) final hidden states
    lengths: Optional[jax.Array] = None,  # (B,) true prompt lengths
    offset: int = 0,                      # prepended non-text positions (VLM)
) -> jax.Array:
    """Logits at each sequence's TRUE last prompt position.

    Right-padded ragged batches must not read their "last logits" from a
    pad row — gather hidden at ``offset + lengths - 1`` per sequence.
    ``lengths=None`` keeps the uniform-batch fast path (last row).
    """
    if lengths is None:
        last = hidden[:, -1:, :]
    else:
        idx = (lengths.astype(jnp.int32) + offset - 1)[:, None, None]
        last = jnp.take_along_axis(hidden, idx, axis=1)
    return unembed_logits(params, cfg, last)[:, 0]


def chunked_xent(
    params,
    cfg: ModelConfig,
    hidden: jax.Array,     # (B, S, D) final hidden states
    targets: jax.Array,    # (B, S) next-token ids
    mask: Optional[jax.Array] = None,   # (B, S) 1 = contributes to loss
    chunk: int = 512,
) -> jax.Array:
    """Cross-entropy without materializing (B, S, V) logits at once.

    lax.map over sequence chunks: each step computes a (B, chunk, V) logits
    slab (vocab-sharded over "model"), its logsumexp, and the target logit.
    Peak logits memory drops S/chunk-fold — required at 262k vocab.
    """
    b, s, d = hidden.shape
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    mask = mask.astype(jnp.float32)
    c = min(chunk, s)
    n_chunks = (s + c - 1) // c
    pad = n_chunks * c - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hs = hidden.reshape(b, n_chunks, c, d).swapaxes(0, 1)   # (n, B, c, D)
    ts = targets.reshape(b, n_chunks, c).swapaxes(0, 1)
    ms = mask.reshape(b, n_chunks, c).swapaxes(0, 1)

    def one(args):
        hx, tx, mx = args
        logits = unembed_logits(params, cfg, hx).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)             # (B, c)
        tgt = jnp.take_along_axis(logits, tx[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * mx
        return jnp.sum(nll)

    from repro import flags

    if flags.unrolling():
        # dry-run roofline probes: XLA cost_analysis counts loop bodies
        # once, so unroll the chunk loop at trace time
        total = jnp.zeros((), jnp.float32)
        for i in range(n_chunks):
            total = total + one((hs[i], ts[i], ms[i]))
        losses = total
    else:
        losses = jnp.sum(lax.map(one, (hs, ts, ms)))
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return losses / denom


def param_count(params: PyTree) -> int:
    return int(
        sum(x.size for x in jax.tree_util.tree_leaves(params))
    )
