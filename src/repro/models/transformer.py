"""Decoder-only LM: dense / local-global (gemma3) / MoE / VLM families.

Layer stacks are ``lax.scan`` over stacked weights (compile-time constant
HLO regardless of depth — essential for the 66-cell dry-run).  Uniform
archs scan over single layers; gemma3 scans over blocks of
``local_block`` layers (5 sliding-window + 1 global).  Remat wraps the
scanned body (nothing saved inside a layer); the carried residual stream
is sequence-sharded over "model" (logical axis ``seq_sp``) so the saved
activations per chip stay small (DESIGN.md §3).

Entry points: ``init_lm``, ``lm_loss`` (train), ``lm_prefill`` (forward +
KV cache build), ``lm_decode_step`` (one-token serve), ``lm_cache_init``.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import flags
from repro.configs.base import ModelConfig
from repro.dist.logical import constrain
from repro.models import moe as moe_mod
from repro.models.common import (
    attention_apply,
    attention_decode,
    attention_decode_paged,
    attention_init,
    chunked_xent,
    compute_dtype,
    embed_apply,
    embed_init,
    last_token_logits,
    mlp_apply,
    mlp_init,
    paged_write_rows,
    rmsnorm,
    rmsnorm_init,
    unembed_logits,
    _qkv,
    apply_rope,
)

__all__ = [
    "init_lm",
    "lm_forward",
    "lm_loss",
    "lm_prefill",
    "lm_decode_step",
    "lm_cache_init",
    "lm_paged_cache_init",
    "lm_decode_step_paged",
    "lm_paged_prefill_write",
    "lm_prefill_suffix",
    "layer_windows",
]

PyTree = Any


def _n_scan(cfg: ModelConfig) -> Tuple[int, int]:
    """(number of scan steps, layers per step)."""
    if cfg.local_block:
        assert cfg.n_layers % cfg.local_block == 0
        return cfg.n_layers // cfg.local_block, cfg.local_block
    return cfg.n_layers, 1


def layer_windows(cfg: ModelConfig):
    """Window (or None) per sub-layer position within one scan step."""
    _, per = _n_scan(cfg)
    if cfg.local_block:
        # gemma3: positions 0..per-2 local (sliding window), last one global
        return [cfg.window] * (per - 1) + [None]
    return [cfg.window] * per


def _is_moe_layer(cfg: ModelConfig) -> bool:
    return cfg.n_experts > 0 and cfg.family in ("moe",)


def _sublayer_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    p["ln1"], s["ln1"] = rmsnorm_init(cfg.d_model)
    p["attn"], s["attn"] = attention_init(ks[0], cfg)
    p["ln2"], s["ln2"] = rmsnorm_init(cfg.d_model)
    if _is_moe_layer(cfg):
        p["moe"], s["moe"] = moe_mod.moe_init(ks[1], cfg)
    else:
        p["mlp"], s["mlp"] = mlp_init(ks[1], cfg)
    return p, s


def _stack_inits(init_fn, key, n: int):
    """vmap an init over n keys; returns stacked params + per-layer specs."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, specs = init_fn(key)  # structure only
    specs = jax.tree_util.tree_map(
        lambda sp: ("layers",) + tuple(sp),
        specs,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    return params, specs


def init_lm(cfg: ModelConfig, key) -> Tuple[PyTree, PyTree]:
    n_steps, per = _n_scan(cfg)
    ks = jax.random.split(key, 3)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    params["embed"], specs["embed"] = embed_init(ks[0], cfg)

    if per == 1:
        blk_p, blk_s = _stack_inits(lambda k: _sublayer_init(k, cfg), ks[1], n_steps)
    else:
        def block_init(k):
            kk = jax.random.split(k, per)
            ps, ss = zip(*[_sublayer_init(kk[i], cfg) for i in range(per)])
            stacked = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ps)
            return stacked, jax.tree_util.tree_map(
                lambda sp: ("block_pos",) + tuple(sp),
                ss[0],
                is_leaf=lambda x: isinstance(x, tuple),
            )
        blk_p, blk_s = _stack_inits(block_init, ks[1], n_steps)
    params["blocks"] = blk_p
    specs["blocks"] = blk_s
    params["final_norm"], specs["final_norm"] = rmsnorm_init(cfg.d_model)
    return params, specs


def _apply_sublayer(p, cfg: ModelConfig, x, positions, window):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + attention_apply(p["attn"], cfg, h, positions, causal=True, window=window)
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, aux = moe_mod.moe_apply(p["moe"], cfg, h)
    else:
        y, aux = mlp_apply(p["mlp"], cfg, h), jnp.zeros((), jnp.float32)
    return x + y, aux


def lm_forward(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,                      # (B, S_txt)
    extra_embeds: Optional[jax.Array] = None,  # (B, I, D) VLM patch embeds
) -> Tuple[jax.Array, jax.Array]:
    """→ (hidden (B, S, D), aux_loss scalar)."""
    cdt = compute_dtype(cfg)
    x = embed_apply(params["embed"], cfg, tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cdt), x], axis=1)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]
    windows = layer_windows(cfg)
    per = len(windows)

    def body(carry, blk):
        x, aux = carry
        x = constrain(x, "batch", "seq_sp", None)
        if per == 1:
            x, a = _apply_sublayer(blk, cfg, x, positions, windows[0])
            aux = aux + a
        else:
            for i in range(per):
                sub = jax.tree_util.tree_map(lambda v: v[i], blk)
                x, a = _apply_sublayer(sub, cfg, x, positions, windows[i])
                aux = aux + a
        x = constrain(x, "batch", "seq_sp", None)
        return (x, aux), None

    body = jax.checkpoint(body, policy=flags.remat_policy())
    (x, aux), _ = lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["blocks"],
        unroll=flags.scan_unroll(),
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return constrain(x, "batch", "seq", None), aux


def lm_loss(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,                       # (B, S_txt)
    loss_mask: Optional[jax.Array] = None,   # (B, S_txt)
    extra_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy (+ router aux loss)."""
    hidden, aux = lm_forward(params, cfg, tokens, extra_embeds)
    n_img = 0 if extra_embeds is None else extra_embeds.shape[1]
    t = tokens.shape[1]
    if n_img:
        # hidden[I-1 .. I+T-2] predicts tokens[0 .. T-1]
        pred = lax.dynamic_slice_in_dim(hidden, n_img - 1, t, axis=1)
        targets = tokens
        mask = loss_mask
    else:
        pred = hidden[:, :-1]
        targets = tokens[:, 1:]
        mask = None if loss_mask is None else loss_mask[:, 1:]
    xent = chunked_xent(params["embed"], cfg, pred, targets, mask)
    loss = xent + cfg.router_aux_coef * aux
    return loss, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def lm_cache_init(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked per-scan-step KV caches (+ logical specs)."""
    n_steps, per = _n_scan(cfg)
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    cdt = compute_dtype(cfg)
    windows = layer_windows(cfg)

    def slot_count(w):
        return min(w, max_len) if w is not None else max_len

    caches = []
    for i in range(per):
        sl = slot_count(windows[i])
        kv = {
            "k": jnp.zeros((n_steps, batch, hkv, sl, dh), cdt),
            "v": jnp.zeros((n_steps, batch, hkv, sl, dh), cdt),
        }
        caches.append(kv)
    cache = {f"pos{i}": c for i, c in enumerate(caches)}
    spec = jax.tree_util.tree_map(
        lambda _: ("layers", "batch", "kv_heads", None, None), cache
    )
    return cache, spec


def lm_prefill(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,
    extra_embeds: Optional[jax.Array] = None,
    max_len: Optional[int] = None,
    lengths: Optional[jax.Array] = None,
) -> Tuple[jax.Array, PyTree]:
    """Full-sequence forward that also materializes the KV cache.

    Returns (last-token logits (B, V), cache).  Window layers keep only the
    trailing ``window`` keys (ring-buffer layout, slot = pos % window).
    ``lengths`` (B,) gathers each sequence's true last-prompt-position
    logits so right-padded ragged batches don't read a pad row.
    """
    cdt = compute_dtype(cfg)
    x = embed_apply(params["embed"], cfg, tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cdt), x], axis=1)
    b, s, _ = x.shape
    max_len = max(max_len or s, s)
    positions = jnp.arange(s)[None, :]
    windows = layer_windows(cfg)
    per = len(windows)

    def sub_with_cache(p, x, window):
        from repro.kernels.flash_attention.ops import flash_attention

        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        q, k, v = _qkv(p["attn"], cfg, h)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kc = jnp.swapaxes(k, 1, 2)                   # (B, Hkv, S, Dh)
        vc = jnp.swapaxes(v, 1, 2)
        # cache layout (k/v computed once, reused for attention below)
        if window is not None and s >= window:
            # ring layout: slot = pos % window over the last `window` tokens
            start = s - window
            roll = s % window
            kv = {
                "k": jnp.roll(kc[:, :, start:], shift=roll, axis=2).astype(cdt),
                "v": jnp.roll(vc[:, :, start:], shift=roll, axis=2).astype(cdt),
            }
        else:
            pad = max_len if window is None else min(window, max_len)
            kv = {
                "k": jnp.pad(kc, ((0, 0), (0, 0), (0, pad - s), (0, 0))).astype(cdt),
                "v": jnp.pad(vc, ((0, 0), (0, 0), (0, pad - s), (0, 0))).astype(cdt),
            }
        attn = flash_attention(
            jnp.swapaxes(q, 1, 2), kc, vc, causal=True, window=window
        )
        attn = jnp.swapaxes(attn, 1, 2).reshape(x.shape[0], s, -1)
        x = x + constrain(
            attn @ p["attn"]["wo"].astype(cdt), *flags.residual_axes()
        )
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            y, _ = moe_mod.moe_apply(p["moe"], cfg, h2)
        else:
            y = mlp_apply(p["mlp"], cfg, h2)
        return x + y, kv

    def body(carry, blk):
        x = carry
        x = constrain(x, "batch", "seq_sp", None)
        kvs = {}
        if per == 1:
            x, kv = sub_with_cache(blk, x, windows[0])
            kvs["pos0"] = kv
        else:
            for i in range(per):
                sub = jax.tree_util.tree_map(lambda v: v[i], blk)
                x, kv = sub_with_cache(sub, x, windows[i])
                kvs[f"pos{i}"] = kv
        return x, kvs

    x, cache = lax.scan(body, x, params["blocks"], unroll=flags.scan_unroll())
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    offset = extra_embeds.shape[1] if extra_embeds is not None else 0
    logits = last_token_logits(
        params["embed"], cfg, x, lengths=lengths, offset=offset
    )
    return logits, cache


def lm_decode_step(
    params,
    cfg: ModelConfig,
    token: jax.Array,        # (B, 1) int32
    pos: jax.Array,          # (B,) absolute position of `token`
    cache: PyTree,
) -> Tuple[jax.Array, PyTree]:
    """One-token decode through the scanned stack.  → (logits (B,V), cache)."""
    x = embed_apply(params["embed"], cfg, token)
    windows = layer_windows(cfg)
    per = len(windows)

    def sub_decode(p, x, kv, window):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        attn, kv = attention_decode(p["attn"], cfg, h, pos, kv, window=window)
        x = x + attn
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            y, _ = moe_mod.moe_apply(p["moe"], cfg, h, no_drop=True)
        else:
            y = mlp_apply(p["mlp"], cfg, h)
        return x + y, kv

    def body(x, xs):
        blk, kvs = xs
        new_kvs = {}
        if per == 1:
            x, kv = sub_decode(blk, x, kvs["pos0"], windows[0])
            new_kvs["pos0"] = kv
        else:
            for i in range(per):
                sub = jax.tree_util.tree_map(lambda v: v[i], blk)
                x, kv = sub_decode(sub, x, kvs[f"pos{i}"], windows[i])
                new_kvs[f"pos{i}"] = kv
        return x, new_kvs

    x, new_cache = lax.scan(
        body, x, (params["blocks"], cache), unroll=flags.scan_unroll()
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed_logits(params["embed"], cfg, x)[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# serving: paged (block) KV cache
# ---------------------------------------------------------------------------

def _require_no_windows(cfg: ModelConfig) -> None:
    if any(w is not None for w in layer_windows(cfg)):
        raise NotImplementedError(
            "paged KV cache covers global-attention layers only; "
            f"{cfg.name} has sliding-window layers (window={cfg.window}, "
            f"local_block={cfg.local_block}) — serve it with the static "
            "engine, or page only the global layers (open follow-up)"
        )


def lm_paged_cache_init(cfg: ModelConfig, n_blocks: int, block_size: int):
    """One shared block pool per scan position (+ logical specs).

    Pool layout (n_steps, Hkv, n_blocks * block_size, Dh): block i owns
    rows [i*bs, (i+1)*bs); block 0 is the trash block (see
    :mod:`repro.serve.kvcache`).  Unlike ``lm_cache_init`` there is no
    batch dimension — slots share the pool through their block tables, so
    HBM is sized to the workload's live tokens, not slots × max_len.
    """
    _require_no_windows(cfg)
    n_steps, per = _n_scan(cfg)
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    cdt = compute_dtype(cfg)
    cache = {
        f"pos{i}": {
            "k": jnp.zeros((n_steps, hkv, n_blocks * block_size, dh), cdt),
            "v": jnp.zeros((n_steps, hkv, n_blocks * block_size, dh), cdt),
        }
        for i in range(per)
    }
    spec = jax.tree_util.tree_map(
        lambda _: ("layers", "kv_heads", None, None), cache
    )
    return cache, spec


def lm_decode_step_paged(
    params,
    cfg: ModelConfig,
    token: jax.Array,        # (B, 1) int32
    pos: jax.Array,          # (B,) absolute position of `token`
    tables: jax.Array,       # (B, M) int32 per-slot block tables
    cache: PyTree,           # lm_paged_cache_init layout
    block_size: int,
) -> Tuple[jax.Array, PyTree]:
    """One-token decode against the shared block pool.  → (logits, cache)."""
    _require_no_windows(cfg)
    x = embed_apply(params["embed"], cfg, token)
    _, per = _n_scan(cfg)

    def sub_decode(p, x, kv):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        attn, kv = attention_decode_paged(
            p["attn"], cfg, h, pos, kv, tables, block_size
        )
        x = x + attn
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            y, _ = moe_mod.moe_apply(p["moe"], cfg, h, no_drop=True)
        else:
            y = mlp_apply(p["mlp"], cfg, h)
        return x + y, kv

    def body(x, xs):
        blk, kvs = xs
        new_kvs = {}
        if per == 1:
            x, kv = sub_decode(blk, x, kvs["pos0"])
            new_kvs["pos0"] = kv
        else:
            for i in range(per):
                sub = jax.tree_util.tree_map(lambda v: v[i], blk)
                x, kv = sub_decode(sub, x, kvs[f"pos{i}"])
                new_kvs[f"pos{i}"] = kv
        return x, new_kvs

    x, new_cache = lax.scan(
        body, x, (params["blocks"], cache), unroll=flags.scan_unroll()
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed_logits(params["embed"], cfg, x)[:, 0]
    return logits, new_cache


def lm_paged_prefill_write(
    cfg: ModelConfig,
    cache: PyTree,           # lm_paged_cache_init layout
    prefill_cache: PyTree,   # lm_cache_init layout, batch dim of 1
    table_row: jax.Array,    # (M,) int32 block table of the admitted slot
    block_size: int,
    start: int = 0,
) -> PyTree:
    """Scatter one prefilled sequence's dense KV rows into the pool.

    ``prefill_cache`` is what ``lm_prefill(..., max_len=bucket)`` built for
    a batch of one; its ``bucket`` rows land at the slot's block-table
    positions from logical position ``start`` on (rows past the allocated
    blocks resolve to the trash block, and pad rows inside them are masked
    until decode overwrites).  A non-zero ``start`` leaves the adopted
    prefix blocks untouched (prefix-cache suffix hand-off).
    """
    _require_no_windows(cfg)

    def write(pool, dense):
        # pool (n_steps, Hkv, P, Dh); dense (n_steps, 1, Hkv, S, Dh)
        return jax.vmap(
            lambda pl, dn: paged_write_rows(
                pl, dn, table_row, block_size, start=start
            )
        )(pool, dense[:, 0])

    return jax.tree_util.tree_map(write, cache, prefill_cache)


def lm_prefill_suffix(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,       # (1, S_suf) suffix tokens, padded to a block multiple
    start: int,              # static: adopted prefix length, multiple of block_size
    table_row: jax.Array,    # (M,) int32 block table of the admitted slot
    cache: PyTree,           # lm_paged_cache_init layout
    block_size: int,
    lengths: Optional[jax.Array] = None,  # (1,) true suffix length
) -> Tuple[jax.Array, PyTree]:
    """Prefill only a prompt's suffix against adopted prefix blocks.

    The slot's first ``start`` logical positions already hold the prefix
    KV (adopted, refcounted, from a :class:`repro.serve.kvcache.PrefixIndex`
    hit); this pass embeds just the suffix at positions
    ``start..start+S-1``, writes its K/V into the pool per layer, and runs
    flash attention with the gathered ``start + S`` keys — so suffix
    queries attend to the adopted blocks exactly as full prefill's rows
    ``start..`` attend to its recomputed prefix.

    Bitwise parity with :func:`lm_prefill` holds because the key-axis
    length matches (full bucket ``blocks_for(L)*bs == start + S`` when
    ``start ≡ 0 (mod bs)``), the same flash kernel sees the same per-row
    causal masks, masked positions contribute exact zeros, and the pool
    round-trip is dtype-identity (KV is computed in the cache dtype).
    Asserted by tests, and the basis of the engine's prefix-on vs
    prefix-off byte parity.
    """
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.models.common import paged_view

    _require_no_windows(cfg)
    s = tokens.shape[1]
    if start % block_size != 0:
        raise ValueError(f"start {start} not a multiple of block_size {block_size}")
    if (start + s) % block_size != 0:
        raise ValueError(
            f"suffix length {s} must pad start {start} to a block multiple"
        )
    n_view = (start + s) // block_size
    cdt = compute_dtype(cfg)
    x = embed_apply(params["embed"], cfg, tokens)
    positions = start + jnp.arange(s)[None, :]
    _, per = _n_scan(cfg)

    def sub_suffix(p, x, kv):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        q, k, v = _qkv(p["attn"], cfg, h)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kc = jnp.swapaxes(k, 1, 2)                   # (1, Hkv, S, Dh)
        vc = jnp.swapaxes(v, 1, 2)
        k_pool = paged_write_rows(kv["k"], kc[0], table_row, block_size, start=start)
        v_pool = paged_write_rows(kv["v"], vc[0], table_row, block_size, start=start)
        view_tbl = table_row[None, :n_view]          # (1, n_view)
        k_view = paged_view(k_pool, view_tbl, block_size)  # (1, Hkv, start+S, Dh)
        v_view = paged_view(v_pool, view_tbl, block_size)
        # flash convention: queries are the LAST Sq positions of the key
        # sequence — with Skv = start + S that is exactly start..start+S-1
        attn = flash_attention(
            jnp.swapaxes(q, 1, 2), k_view, v_view, causal=True
        )
        attn = jnp.swapaxes(attn, 1, 2).reshape(x.shape[0], s, -1)
        x = x + attn @ p["attn"]["wo"].astype(cdt)
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            y, _ = moe_mod.moe_apply(p["moe"], cfg, h2)
        else:
            y = mlp_apply(p["mlp"], cfg, h2)
        return x + y, {"k": k_pool, "v": v_pool}

    def body(x, xs):
        blk, kvs = xs
        new_kvs = {}
        if per == 1:
            x, kv = sub_suffix(blk, x, kvs["pos0"])
            new_kvs["pos0"] = kv
        else:
            for i in range(per):
                sub = jax.tree_util.tree_map(lambda v: v[i], blk)
                x, kv = sub_suffix(sub, x, kvs[f"pos{i}"])
                new_kvs[f"pos{i}"] = kv
        return x, new_kvs

    x, new_cache = lax.scan(
        body, x, (params["blocks"], cache), unroll=flags.scan_unroll()
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = last_token_logits(params["embed"], cfg, x, lengths=lengths)
    return logits, new_cache
