"""Mixture-of-Experts layer: expert parallelism via shard_map.

Pattern (DESIGN.md §3): activations enter the MoE layer replicated over the
"model" mesh axis (batch sharded over dp); expert weights are sharded
``experts → model`` (+ ``d_model → data`` FSDP).  Because every model shard
sees all (local-batch) tokens, dispatch needs **no all-to-all** — each
shard locally gathers the tokens routed to *its* experts (capacity-bounded
sort-free ranking), runs dense per-expert SwiGLU matmuls, and the combine
is a single ``psum`` over "model" — the same collective a Megatron TP MLP
pays.  FSDP all-gather of expert weights happens inside the shard_map
(gradient becomes psum_scatter under autodiff, i.e. ZeRO semantics).

Capacity: ``C = ceil(T_local · k / E · capacity_factor)`` tokens per
expert; overflow tokens are dropped (switch-style), counted, and exposed
for monitoring.  Aux load-balance loss: ``E · Σ_e f_e · P_e`` (Switch
Transformer) computed on the local shard and psum-averaged over dp.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.logical import current_rules, _current_mesh
from repro.models.common import compute_dtype, dense_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg: ModelConfig) -> Tuple[Any, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    params = {
        "router": 0.02 * jax.random.normal(ks[0], (d, e), jnp.float32),
        "wg": s * jax.random.normal(ks[1], (e, d, f), jnp.float32),
        "wu": s * jax.random.normal(ks[2], (e, d, f), jnp.float32),
        "wd": (1.0 / math.sqrt(f)) * jax.random.normal(ks[3], (e, f, d), jnp.float32),
    }
    specs = {
        "router": (None, None),  # replicated: read by every shard every layer
        "wg": ("experts", "embed", "expert_ff"),
        "wu": ("experts", "embed", "expert_ff"),
        "wd": ("experts", "expert_ff", "embed"),
    }
    return params, specs


def _local_moe(
    x_l: jax.Array,        # (B_l, S, D) tokens local to this dp shard
    router: jax.Array,     # (D, E) replicated
    wg: jax.Array,         # (E_l, D, F) local experts (already gathered on D)
    wu: jax.Array,
    wd: jax.Array,
    *,
    cfg: ModelConfig,
    e0,                    # first expert id owned by this shard
    capacity: int,
):
    """Dispatch → per-expert SwiGLU → combine, on one model shard."""
    cdt = compute_dtype(cfg)
    bl, s, d = x_l.shape
    e = cfg.n_experts
    el = wg.shape[0]
    k = cfg.experts_per_token
    t = bl * s
    xf = x_l.reshape(t, d)

    logits = (xf.astype(jnp.float32)) @ router.astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = lax.top_k(probs, k)                               # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)           # renorm

    # --- capacity-bounded ranking (sort-free within expert) --------------
    flat_i = top_i.reshape(-1)                                        # (T*k,)
    flat_w = top_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(flat_i, stable=True)
    sorted_i = flat_i[order]
    first = jnp.searchsorted(sorted_i, jnp.arange(e, dtype=sorted_i.dtype))
    rank = jnp.arange(t * k, dtype=jnp.int32) - first[sorted_i].astype(jnp.int32)

    local_e = sorted_i - e0
    keep = (local_e >= 0) & (local_e < el) & (rank < capacity)
    slot_e = jnp.where(keep, local_e, el)            # el = discard row
    slot_c = jnp.where(keep, rank, 0)
    tok_sorted = flat_tok[order]
    w_sorted = flat_w[order]

    tok_buf = jnp.full((el + 1, capacity), t, jnp.int32)             # t = pad row
    tok_buf = tok_buf.at[slot_e, slot_c].set(jnp.where(keep, tok_sorted, t))
    w_buf = jnp.zeros((el + 1, capacity), jnp.float32)
    w_buf = w_buf.at[slot_e, slot_c].set(jnp.where(keep, w_sorted, 0.0))
    tok_buf, w_buf = tok_buf[:el], w_buf[:el]

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xe = xpad[tok_buf]                                # (E_l, C, D) gather

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg.astype(cdt)))
    u = jnp.einsum("ecd,edf->ecf", xe, wu.astype(cdt))
    ye = jnp.einsum("ecf,efd->ecd", g * u, wd.astype(cdt))
    ye = ye * w_buf[..., None].astype(cdt)

    y = jnp.zeros((t + 1, d), cdt).at[tok_buf.reshape(-1)].add(
        ye.reshape(-1, d)
    )[:t]

    # --- aux telemetry -----------------------------------------------------
    # Switch load-balance loss on the local token shard (identical on every
    # model shard; dp-mean happens in the caller's loss aggregation).
    counts = jnp.zeros((e,), jnp.float32).at[flat_i].add(1.0)
    dispatch_frac = counts / (t * k)                  # f_e (scatter, no one-hot)
    prob_frac = jnp.mean(probs, axis=0)               # P_e
    aux = e * jnp.sum(dispatch_frac * prob_frac)
    dropped = jnp.sum((~keep) & (local_e >= 0) & (local_e < el))
    return y.reshape(bl, s, d), aux, dropped


def moe_apply(
    params, cfg: ModelConfig, x: jax.Array, no_drop: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, D) → (y (B, S, D), aux_loss scalar).

    ``no_drop=True`` sizes capacity so no token can overflow (worst case:
    every token routes one assignment to the same expert ⇒ C = T).  Used by
    the decode path, where dropping would corrupt generation.
    """
    mesh = _current_mesh()
    b, s, _ = x.shape
    k, e = cfg.experts_per_token, cfg.n_experts

    def cap_for(t_tokens: int) -> int:
        if no_drop:
            return t_tokens
        return max(1, int(cfg.capacity_factor * t_tokens * k / e))

    # Axis resolution comes from the active rule table: "experts" names the
    # expert-parallel axis, "batch"/"embed" the dp/FSDP groups — so
    # `axis_rules` overrides steer the shard_map path like any constrain.
    rules = current_rules()
    names = mesh.axis_names if mesh is not None else ()
    mdl = rules.mesh_axes("experts", names)

    if mesh is None or not isinstance(mdl, str):
        # single-device / no-expert-axis path: all experts local
        t = b * s
        y, aux, _ = _local_moe(
            x, params["router"], params["wg"], params["wu"], params["wd"],
            cfg=cfg, e0=0, capacity=cap_for(t),
        )
        return y, aux

    def _axes(logical):
        got = rules.mesh_axes(logical, names)
        got = () if got is None else ((got,) if isinstance(got, str) else got)
        return tuple(a for a in got if a != mdl)

    dp = _axes("batch")
    n_model = mesh.shape[mdl]
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if e % n_model:
        raise ValueError(f"{e} experts not divisible by model={n_model}")
    el = e // n_model
    if b % n_dp:
        # batch not divisible over dp (e.g. batch=1 long-context decode):
        # keep tokens replicated across dp inside the shard_map
        dp = ()
        n_dp = 1
    t_local = (b // n_dp) * s
    cap = cap_for(t_local)

    # FSDP axes for expert weights (the "embed" rule: pod+data by default)
    fsdp = _axes("embed")
    fsdp_entry = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)

    def shard_fn(x_l, router, wg_l, wu_l, wd_l):
        # FSDP gather of expert weights over the pod+data axes (ZeRO-3):
        if fsdp:
            wg_f = lax.all_gather(wg_l, fsdp, axis=1, tiled=True)
            wu_f = lax.all_gather(wu_l, fsdp, axis=1, tiled=True)
            wd_f = lax.all_gather(wd_l, fsdp, axis=2, tiled=True)
        else:
            wg_f, wu_f, wd_f = wg_l, wu_l, wd_l
        e0 = lax.axis_index(mdl) * el
        y, aux, dropped = _local_moe(
            x_l, router, wg_f, wu_f, wd_f, cfg=cfg, e0=e0, capacity=cap
        )
        # combine expert contributions across model shards
        y = lax.psum(y, mdl)
        # aux identical across model shards; mean over dp shards
        if dp:
            aux = lax.pmean(aux, dp)
        return y, aux

    batch_axes = dp if dp else None
    in_specs = (
        P(batch_axes, None, None),                # x
        P(None, None),                            # router (replicated)
        P(mdl, fsdp_entry, None),                 # wg (E→model, D→pod+data)
        P(mdl, fsdp_entry, None),                 # wu
        P(mdl, None, fsdp_entry),                 # wd (E→model, F, D→pod+data)
    )
    out_specs = (P(batch_axes, None, None), P())
    if hasattr(jax, "shard_map"):  # jax >= 0.6 (check_vma replaced check_rep)
        smap = partial(
            jax.shard_map, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    else:
        from jax.experimental.shard_map import shard_map as _shard_map

        smap = partial(
            _shard_map, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )
    y, aux = smap(shard_fn)(
        x, params["router"], params["wg"], params["wu"], params["wd"]
    )
    return y, aux
