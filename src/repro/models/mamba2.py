"""Mamba2 block (SSD — state-space duality), TPU-adapted.

Training/prefill uses the chunked SSD form: within a chunk, outputs are
dense ``(Q × Q)`` masked matmuls (MXU work, like a tiny attention); across
chunks a compact ``(H, P, N)`` state is propagated by the sequential
recurrence owned by the ``ssd_scan`` Pallas kernel.  Decode is the O(1)
recurrent update.

Sharding: SSD heads are independent → ``ssm_heads → model`` (TP); the
depthwise conv and all projections follow the same split.  The state never
crosses shards.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist.logical import constrain
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.models.common import compute_dtype, rmsnorm

__all__ = ["mamba_init", "mamba_apply", "mamba_decode", "mamba_state_init"]


def _dims(cfg: ModelConfig):
    d_inner = cfg.d_inner
    h = cfg.ssm_heads
    p = cfg.ssm_head_dim
    n = cfg.ssm_state
    conv_dim = d_inner + 2 * n            # x, B, C share the conv (n_groups=1)
    return d_inner, h, p, n, conv_dim


def mamba_init(key, cfg: ModelConfig) -> Tuple[Any, Any]:
    d = cfg.d_model
    d_inner, h, p, n, conv_dim = _dims(cfg)
    # in_proj emits [z (d_inner), x (d_inner), B (n), C (n), dt (h)]
    d_proj = 2 * d_inner + 2 * n + h
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    params = {
        "in_proj": s * jax.random.normal(ks[0], (d, d_proj), jnp.float32),
        "conv_w": 0.1 * jax.random.normal(ks[1], (conv_dim, cfg.ssm_conv), jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 1e-2, jnp.float32))),
        "norm_w": jnp.ones((d_inner,), jnp.float32),
        "out_proj": (1.0 / math.sqrt(d_inner))
        * jax.random.normal(ks[2], (d_inner, d), jnp.float32),
    }
    specs = {
        "in_proj": ("embed", "conv_dim"),
        "conv_w": ("conv_dim", None),
        "conv_b": ("conv_dim",),
        "a_log": ("ssm_heads",),
        "d_skip": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm_w": ("conv_dim",),
        "out_proj": ("conv_dim", "embed"),
    }
    return params, specs


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_inner, h, p, n, _ = _dims(cfg)
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner : 2 * d_inner + 2 * n]
    dt = proj[..., 2 * d_inner + 2 * n :]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along S: xbc (B, S, C), w (C, K)."""
    k = w.shape[1]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):  # K is tiny (4): static unroll beats conv_general here
        out = out + pad[:, i : i + xbc.shape[1], :].astype(jnp.float32) * w[:, i]
    return jax.nn.silu(out + b).astype(xbc.dtype)


def _segsum_chunk(da: jax.Array):
    """da (B, C, Q, H) → cumulative sums used by the SSD chunk form."""
    cum = jnp.cumsum(da, axis=2)                  # inclusive cumsum over Q
    return cum


def mamba_apply(
    params, cfg: ModelConfig, x: jax.Array, return_state: bool = False
):
    """Full-sequence SSD (train / prefill).  x (B, S, D) → (B, S, D).

    With ``return_state`` also returns the recurrent state after the last
    token ({"ssm", "conv"}) so decode can continue from a prefill."""
    cdt = compute_dtype(cfg)
    b, s_true, d = x.shape
    d_inner, h, p, n, conv_dim = _dims(cfg)
    q = min(cfg.ssm_chunk, s_true)
    pad = (q - s_true % q) % q
    if pad:
        # pad to a chunk multiple; padded steps get dt=0 below, which makes
        # them exact no-ops on the state (decay=e^0=1, contribution=0)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    s = s_true + pad
    nc = s // q

    proj = x @ params["in_proj"].astype(cdt)
    z, xbc_pre, dt_raw = _split_proj(cfg, proj)
    xbc_pre = constrain(xbc_pre, "batch", "seq", "conv_dim")
    xbc = _causal_conv(xbc_pre, params["conv_w"], params["conv_b"])
    xs = xbc[..., :d_inner].reshape(b, s, h, p)
    bmat = xbc[..., d_inner : d_inner + n]            # (B, S, N)
    cmat = xbc[..., d_inner + n :]                    # (B, S, N)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"]
    )                                                  # (B, S, H)
    if pad:
        valid = (jnp.arange(s) < s_true)[None, :, None]
        dt = dt * valid  # padded steps: exact state no-ops
    a = -jnp.exp(params["a_log"])                      # (H,) negative
    da = dt * a                                        # (B, S, H) ≤ 0

    # chunk reshape
    xs_c = xs.reshape(b, nc, q, h, p).astype(jnp.float32)
    b_c = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    c_c = cmat.reshape(b, nc, q, n).astype(jnp.float32)
    dt_c = dt.reshape(b, nc, q, h)
    da_c = da.reshape(b, nc, q, h)
    cum = _segsum_chunk(da_c)                          # (B, C, Q, H)

    # intra-chunk (dense, MXU): scores[q_, k_] = C_q·B_k · exp(cum_q - cum_k) · dt_k
    scores = jnp.einsum("bcqn,bckn->bcqk", c_c, b_c)[:, :, None]   # (B,C,1,Q,Q)
    # decay (B, C, H, Q, Q) = exp(cum[q] - cum[k]), causal-masked
    cum_h = jnp.moveaxis(cum, 3, 2)                    # (B, C, H, Q)
    dmat = jnp.exp(cum_h[..., :, None] - cum_h[..., None, :])
    causal = jnp.tril(jnp.ones((q, q), bool))
    dmat = jnp.where(causal, dmat, 0.0)
    dt_h = jnp.moveaxis(dt_c, 3, 2)                    # (B, C, H, Q)
    w = scores * dmat * dt_h[..., None, :]             # (B, C, H, Q, Q)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", w, xs_c)

    # chunk states: state_c = Σ_k exp(cum_last - cum_k) · dt_k · B_k ⊗ X_k
    last = cum_h[..., -1:]                             # (B, C, H, 1)
    sdecay = jnp.exp(last - cum_h)                     # (B, C, H, Q)
    sw = sdecay * dt_h                                 # (B, C, H, Q)
    states = jnp.einsum("bchk,bckn,bckhp->bchpn", sw, b_c, xs_c)

    # inter-chunk recurrence (Pallas ssd_scan kernel on TPU)
    chunk_decay = jnp.exp(last[..., 0])                # (B, C, H)
    states_bh = (
        states.transpose(0, 2, 1, 3, 4).reshape(b * h, nc, p, n)
    )
    decay_bh = chunk_decay.transpose(0, 2, 1).reshape(b * h, nc)
    prefix = ssd_scan(states_bh, decay_bh)             # (B*H, C, P, N)
    prefix = prefix.reshape(b, h, nc, p, n).transpose(0, 2, 1, 3, 4)

    # inter-chunk output: y_q += (C_q · prefix) * exp(cum_q)
    edecay = jnp.exp(cum_h)                            # (B, C, H, Q)
    y_inter = jnp.einsum(
        "bcqn,bchpn->bcqhp", c_c, prefix
    ) * jnp.moveaxis(edecay, 2, 3)[..., None]
    y = y_intra + y_inter + params["d_skip"][None, None, None, :, None] * xs_c
    y = y.reshape(b, s, d_inner).astype(cdt)

    # gated RMSNorm then out projection
    y = rmsnorm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(cdt)
    if pad:
        out = out[:, :s_true]
    from repro import flags as _flags
    from jax.ad_checkpoint import checkpoint_name

    out = checkpoint_name(constrain(out, *_flags.residual_axes()), "mixer_out")
    if not return_state:
        return out
    # final recurrent state = decay_last * prefix_last + states_last
    # (exact even with padding: padded steps were dt=0 no-ops)
    final = (
        chunk_decay[:, -1][..., None, None] * prefix[:, -1].reshape(b, h, p, n)
        + states[:, -1]
    )
    conv_tail = xbc_pre[:, s_true - (cfg.ssm_conv - 1): s_true, :]
    return out, {"ssm": final, "conv": conv_tail}


def mamba_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_inner, h, p, n, conv_dim = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def mamba_decode(
    params, cfg: ModelConfig, x: jax.Array, state: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token recurrent step.  x (B, 1, D) → (B, 1, D)."""
    cdt = compute_dtype(cfg)
    b = x.shape[0]
    d_inner, h, p, n, conv_dim = _dims(cfg)
    proj = x[:, 0] @ params["in_proj"].astype(cdt)     # (B, d_proj)
    z, xbc_new, dt_raw = _split_proj(cfg, proj)

    # conv ring: state["conv"] (B, K-1, conv_dim) holds the last K-1 inputs
    conv_in = jnp.concatenate(
        [state["conv"], xbc_new[:, None, :]], axis=1
    )                                                   # (B, K, conv_dim)
    w = params["conv_w"]                                # (conv_dim, K)
    xbc = jnp.einsum("bkc,ck->bc", conv_in.astype(jnp.float32), w)
    xbc = jax.nn.silu(xbc + params["conv_b"]).astype(cdt)
    new_conv = conv_in[:, 1:]

    xs = xbc[:, :d_inner].reshape(b, h, p).astype(jnp.float32)
    bvec = xbc[:, d_inner : d_inner + n].astype(jnp.float32)   # (B, N)
    cvec = xbc[:, d_inner + n :].astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B, H)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a)                             # (B, H)

    ssm = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, bvec, xs
    )
    y = jnp.einsum("bn,bhpn->bhp", cvec, ssm) + params["d_skip"][None, :, None] * xs
    y = y.reshape(b, d_inner).astype(cdt)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    out = (y @ params["out_proj"].astype(cdt))[:, None, :]
    return out, {"ssm": ssm, "conv": new_conv}
