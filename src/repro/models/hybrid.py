"""Hybrid SSM + attention model (Jamba family).

Super-block of ``hybrid_block`` layers scanned ``n_layers/hybrid_block``
times: position ``attn_index`` is GQA attention, the rest are Mamba2 SSD
mixers; the FFN alternates dense MLP (even positions) and MoE (odd
positions), reproducing Jamba's every-other-layer MoE placement.

Decode cost: only one attention layer per 8 carries a growing KV cache —
the reason this arch runs the long_500k cell.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import flags
from repro.configs.base import ModelConfig
from repro.dist.logical import constrain
from repro.models import moe as moe_mod
from repro.models.common import (
    attention_decode,
    attention_init,
    chunked_xent,
    compute_dtype,
    embed_apply,
    embed_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    last_token_logits,
    unembed_logits,
)
from repro.models.mamba2 import (
    mamba_apply,
    mamba_decode,
    mamba_init,
    mamba_state_init,
)
from repro.models.transformer import _stack_inits

__all__ = [
    "init_hybrid",
    "hybrid_forward",
    "hybrid_loss",
    "hybrid_prefill",
    "hybrid_decode_step",
    "hybrid_cache_init",
]

PyTree = Any


def _layout(cfg: ModelConfig):
    per = cfg.hybrid_block
    n_blocks = cfg.n_layers // per
    assert cfg.n_layers % per == 0
    mamba_pos = [j for j in range(per) if j != cfg.attn_index]
    moe_pos = [j for j in range(per) if j % cfg.moe_every == cfg.moe_every - 1]
    mlp_pos = [j for j in range(per) if j not in moe_pos]
    return n_blocks, per, mamba_pos, moe_pos, mlp_pos


def _block_init(key, cfg: ModelConfig):
    n_blocks, per, mamba_pos, moe_pos, mlp_pos = _layout(cfg)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}

    def stack(fn, k, n):
        kk = jax.random.split(k, n)
        ps, ss = zip(*[fn(kk[i]) for i in range(n)])
        return (
            jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ps),
            jax.tree_util.tree_map(
                lambda sp: ("block_pos",) + tuple(sp),
                ss[0],
                is_leaf=lambda x: isinstance(x, tuple),
            ),
        )

    p["mamba"], s["mamba"] = stack(lambda k: mamba_init(k, cfg), ks[0], len(mamba_pos))
    p["attn"], s["attn"] = attention_init(ks[1], cfg)
    if moe_pos:
        p["moe"], s["moe"] = stack(lambda k: moe_mod.moe_init(k, cfg), ks[2], len(moe_pos))
    if mlp_pos:
        p["mlp"], s["mlp"] = stack(lambda k: mlp_init(k, cfg), ks[3], len(mlp_pos))
    p["ln_mix"] = jnp.ones((per, cfg.d_model), jnp.float32)
    p["ln_ffn"] = jnp.ones((per, cfg.d_model), jnp.float32)
    s["ln_mix"] = ("block_pos", "embed_act")
    s["ln_ffn"] = ("block_pos", "embed_act")
    return p, s


def init_hybrid(cfg: ModelConfig, key) -> Tuple[PyTree, PyTree]:
    n_blocks, *_ = _layout(cfg)
    ks = jax.random.split(key, 2)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    params["embed"], specs["embed"] = embed_init(ks[0], cfg)
    params["blocks"], specs["blocks"] = _stack_inits(
        lambda k: _block_init(k, cfg), ks[1], n_blocks
    )
    params["final_norm"], specs["final_norm"] = rmsnorm_init(cfg.d_model)
    return params, specs


def _apply_block(blk, cfg: ModelConfig, x, positions, no_drop=False):
    """One super-block (full sequence).  Returns (x, aux)."""
    _, per, mamba_pos, moe_pos, mlp_pos = _layout(cfg)
    aux = jnp.zeros((), jnp.float32)
    mi = ai = fi_moe = fi_mlp = 0
    for j in range(per):
        h = rmsnorm(x, blk["ln_mix"][j], cfg.norm_eps)
        if j == cfg.attn_index:
            from repro.models.common import attention_apply

            x = x + attention_apply(blk["attn"], cfg, h, positions, causal=True)
        else:
            mp = jax.tree_util.tree_map(lambda v: v[mi], blk["mamba"])
            x = x + mamba_apply(mp, cfg, h)
            mi += 1
        h = rmsnorm(x, blk["ln_ffn"][j], cfg.norm_eps)
        if j in moe_pos:
            ep = jax.tree_util.tree_map(lambda v: v[fi_moe], blk["moe"])
            y, a = moe_mod.moe_apply(ep, cfg, h, no_drop=no_drop)
            aux = aux + a
            fi_moe += 1
        else:
            lp = jax.tree_util.tree_map(lambda v: v[fi_mlp], blk["mlp"])
            y = mlp_apply(lp, cfg, h)
            fi_mlp += 1
        x = x + y
    return x, aux


def hybrid_forward(params, cfg: ModelConfig, tokens: jax.Array):
    x = embed_apply(params["embed"], cfg, tokens)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]

    def body(carry, blk):
        x, aux = carry
        x = constrain(x, "batch", "seq_sp", None)
        x, a = _apply_block(blk, cfg, x, positions)
        return (x, aux + a), None

    body = jax.checkpoint(body, policy=flags.remat_policy())
    (x, aux), _ = lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["blocks"],
        unroll=flags.scan_unroll(),
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return constrain(x, "batch", "seq", None), aux


def hybrid_loss(params, cfg: ModelConfig, tokens, loss_mask=None):
    hidden, aux = hybrid_forward(params, cfg, tokens)
    mask = None if loss_mask is None else loss_mask[:, 1:]
    xent = chunked_xent(params["embed"], cfg, hidden[:, :-1], tokens[:, 1:], mask)
    return xent + cfg.router_aux_coef * aux, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def hybrid_cache_init(cfg: ModelConfig, batch: int, max_len: int):
    n_blocks, per, mamba_pos, *_ = _layout(cfg)
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    cdt = compute_dtype(cfg)
    one_state = mamba_state_init(cfg, batch, cdt)
    cache = {
        "attn": {
            "k": jnp.zeros((n_blocks, batch, hkv, max_len, dh), cdt),
            "v": jnp.zeros((n_blocks, batch, hkv, max_len, dh), cdt),
        },
        "mamba": jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(
                a[None, None], (n_blocks, len(mamba_pos)) + a.shape
            ),
            one_state,
        ),
    }
    spec = {
        "attn": {
            "k": ("layers", "batch", "kv_heads", None, None),
            "v": ("layers", "batch", "kv_heads", None, None),
        },
        # ssm (nb, nm, B, H, P, N); conv (nb, nm, B, K-1, conv_dim)
        "mamba": {
            "ssm": ("layers", "block_pos", "batch", "ssm_heads", None, None),
            "conv": ("layers", "block_pos", "batch", None, "conv_dim"),
        },
    }
    return cache, spec


def hybrid_prefill(params, cfg: ModelConfig, tokens, max_len: Optional[int] = None,
                   lengths=None):
    """Forward + cache build.  Attention KV padded to ``max_len``."""
    cdt = compute_dtype(cfg)
    x = embed_apply(params["embed"], cfg, tokens)
    b, s, _ = x.shape
    max_len = max(max_len or s, s)
    positions = jnp.arange(s)[None, :]
    _, per, mamba_pos, moe_pos, mlp_pos = _layout(cfg)

    def body(x, blk):
        from repro.models.common import _qkv, apply_rope
        from repro.kernels.flash_attention.ops import flash_attention

        aux = jnp.zeros((), jnp.float32)
        mi = fi_moe = fi_mlp = 0
        kv_out = None
        mamba_states = []
        for j in range(per):
            h = rmsnorm(x, blk["ln_mix"][j], cfg.norm_eps)
            if j == cfg.attn_index:
                q, k, v = _qkv(blk["attn"], cfg, h)
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
                kc, vc = jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)
                kv_out = {
                    "k": jnp.pad(kc, ((0, 0), (0, 0), (0, max_len - s), (0, 0))).astype(cdt),
                    "v": jnp.pad(vc, ((0, 0), (0, 0), (0, max_len - s), (0, 0))).astype(cdt),
                }
                att = flash_attention(jnp.swapaxes(q, 1, 2), kc, vc, causal=True)
                att = jnp.swapaxes(att, 1, 2).reshape(b, s, -1)
                x = x + constrain(
                    att @ blk["attn"]["wo"].astype(cdt), *flags.residual_axes()
                )
            else:
                mp = jax.tree_util.tree_map(lambda v: v[mi], blk["mamba"])
                y, st = mamba_apply(mp, cfg, h, return_state=True)
                x = x + y
                mamba_states.append(st)
                mi += 1
            h = rmsnorm(x, blk["ln_ffn"][j], cfg.norm_eps)
            if j in moe_pos:
                ep = jax.tree_util.tree_map(lambda v: v[fi_moe], blk["moe"])
                y, _ = moe_mod.moe_apply(ep, cfg, h)
                fi_moe += 1
            else:
                lp = jax.tree_util.tree_map(lambda v: v[fi_mlp], blk["mlp"])
                y = mlp_apply(lp, cfg, h)
                fi_mlp += 1
            x = x + y
        stacked_states = jax.tree_util.tree_map(
            lambda *a: jnp.stack(a), *mamba_states
        )
        return x, {"attn": kv_out, "mamba": stacked_states}

    x, cache = lax.scan(body, x, params["blocks"], unroll=flags.scan_unroll())
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = last_token_logits(params["embed"], cfg, x, lengths=lengths)
    return logits, cache


def hybrid_decode_step(params, cfg: ModelConfig, token, pos, cache):
    """One-token decode.  token (B,1), pos (B,)."""
    x = embed_apply(params["embed"], cfg, token)
    _, per, mamba_pos, moe_pos, mlp_pos = _layout(cfg)

    def body(x, xs):
        blk, kv, mstates = xs
        mi = fi_moe = fi_mlp = 0
        new_m = []
        for j in range(per):
            h = rmsnorm(x, blk["ln_mix"][j], cfg.norm_eps)
            if j == cfg.attn_index:
                att, kv_new = attention_decode(blk["attn"], cfg, h, pos, kv)
                x = x + att
            else:
                mp = jax.tree_util.tree_map(lambda v: v[mi], blk["mamba"])
                st = jax.tree_util.tree_map(lambda v: v[mi], mstates)
                y, st_new = mamba_decode(mp, cfg, h, st)
                x = x + y
                new_m.append(st_new)
                mi += 1
            h = rmsnorm(x, blk["ln_ffn"][j], cfg.norm_eps)
            if j in moe_pos:
                ep = jax.tree_util.tree_map(lambda v: v[fi_moe], blk["moe"])
                y, _ = moe_mod.moe_apply(ep, cfg, h, no_drop=True)
                fi_moe += 1
            else:
                lp = jax.tree_util.tree_map(lambda v: v[fi_mlp], blk["mlp"])
                y = mlp_apply(lp, cfg, h)
                fi_mlp += 1
            x = x + y
        new_mamba = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *new_m)
        return x, (kv_new, new_mamba)

    x, (kv_new, m_new) = lax.scan(
        body, x, (params["blocks"], cache["attn"], cache["mamba"]),
        unroll=flags.scan_unroll(),
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed_logits(params["embed"], cfg, x)[:, 0]
    return logits, {"attn": kv_new, "mamba": m_new}
