"""Pure SSM LM (mamba2 family): uniform scan of Mamba2 SSD blocks.

Block = RMSNorm → Mamba2 mixer → residual (no separate MLP, per the
published architecture).  O(1)-state decode is what makes the long_500k
cell runnable for this family.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import flags
from repro.configs.base import ModelConfig
from repro.dist.logical import constrain
from repro.models.common import (
    chunked_xent,
    compute_dtype,
    embed_apply,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    last_token_logits,
    unembed_logits,
)
from repro.models.mamba2 import (
    mamba_apply,
    mamba_decode,
    mamba_init,
    mamba_state_init,
)
from repro.models.transformer import _stack_inits

__all__ = [
    "init_ssm",
    "ssm_forward",
    "ssm_loss",
    "ssm_prefill",
    "ssm_decode_step",
    "ssm_cache_init",
]


def _layer_init(key, cfg: ModelConfig):
    p, s = {}, {}
    p["ln"], s["ln"] = rmsnorm_init(cfg.d_model)
    p["mamba"], s["mamba"] = mamba_init(key, cfg)
    return p, s


def init_ssm(cfg: ModelConfig, key):
    ks = jax.random.split(key, 2)
    params, specs = {}, {}
    params["embed"], specs["embed"] = embed_init(ks[0], cfg)
    params["blocks"], specs["blocks"] = _stack_inits(
        lambda k: _layer_init(k, cfg), ks[1], cfg.n_layers
    )
    params["final_norm"], specs["final_norm"] = rmsnorm_init(cfg.d_model)
    return params, specs


def ssm_forward(params, cfg: ModelConfig, tokens: jax.Array):
    x = embed_apply(params["embed"], cfg, tokens)

    def body(x, blk):
        x = constrain(x, "batch", "seq_sp", None)
        h = rmsnorm(x, blk["ln"], cfg.norm_eps)
        x = x + mamba_apply(blk["mamba"], cfg, h)
        return x, None

    body = jax.checkpoint(body, policy=flags.remat_policy())
    x, _ = lax.scan(body, x, params["blocks"], unroll=flags.scan_unroll())
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return constrain(x, "batch", "seq", None), jnp.zeros((), jnp.float32)


def ssm_loss(params, cfg: ModelConfig, tokens, loss_mask=None):
    hidden, _ = ssm_forward(params, cfg, tokens)
    mask = None if loss_mask is None else loss_mask[:, 1:]
    xent = chunked_xent(params["embed"], cfg, hidden[:, :-1], tokens[:, 1:], mask)
    return xent, {"xent": xent, "aux": jnp.zeros((), jnp.float32)}


def ssm_cache_init(cfg: ModelConfig, batch: int, max_len: int = 0):
    cdt = compute_dtype(cfg)
    one = mamba_state_init(cfg, batch, cdt)
    cache = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one
    )
    spec = {
        "ssm": ("layers", "batch", "ssm_heads", None, None),
        "conv": ("layers", "batch", None, "conv_dim"),
    }
    return cache, spec


def ssm_prefill(params, cfg: ModelConfig, tokens, max_len: Optional[int] = None,
                lengths=None):
    x = embed_apply(params["embed"], cfg, tokens)

    def body(x, blk):
        h = rmsnorm(x, blk["ln"], cfg.norm_eps)
        y, st = mamba_apply(blk["mamba"], cfg, h, return_state=True)
        return x + y, st

    x, cache = lax.scan(body, x, params["blocks"], unroll=flags.scan_unroll())
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = last_token_logits(params["embed"], cfg, x, lengths=lengths)
    return logits, cache


def ssm_decode_step(params, cfg: ModelConfig, token, pos, cache):
    x = embed_apply(params["embed"], cfg, token)

    def body(x, xs):
        blk, st = xs
        h = rmsnorm(x, blk["ln"], cfg.norm_eps)
        y, st_new = mamba_decode(blk["mamba"], cfg, h, st)
        return x + y, st_new

    x, new_cache = lax.scan(
        body, x, (params["blocks"], cache), unroll=flags.scan_unroll()
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed_logits(params["embed"], cfg, x)[:, 0]
    return logits, new_cache
