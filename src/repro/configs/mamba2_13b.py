"""mamba2-1.3b — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060; unverified]  48L d_model=2048 (attn-free) vocab=50280,
ssm_state=128.  d_inner = 2×d_model = 4096, head_dim 64 → 64 SSD heads.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,               # mamba blocks have no separate MLP
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    notes="pure SSM; long_500k RUNS (O(1) recurrent state decode).",
)
