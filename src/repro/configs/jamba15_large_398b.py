"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave with MoE.

[arXiv:2403.19887; hf]  72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2.  Super-block of 8 layers: 7×Mamba (SSD) +
1×attention (index 3); MoE replaces the MLP in every 2nd layer.

Hardware-adaptation note (DESIGN.md §7): Jamba uses Mamba-1 selective-scan
blocks; we substitute the Mamba2 SSD chunked form (state 128) because its
intra-chunk matmuls map onto the MXU — the published 1:7 interleave, GQA
attention and MoE placement are preserved.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    experts_per_token=2,
    moe_every=2,
    hybrid_block=8,
    attn_index=3,
    ssm_state=128,
    ssm_head_dim=64,
    rope_theta=1e6,
    notes="hybrid SSM+attn; long_500k RUNS (63/72 layers are O(1)-state).",
)
