"""gemma3-12b — dense GQA with 5:1 local:global attention interleave.

[hf:google/gemma-3-1b-pt; unverified]  48L d_model=3840 16H (GQA kv=8)
d_ff=15360 vocab=262144, 128k context.  Local layers use a 1024-token
sliding window; every 6th layer is global — which is why this arch *does*
run long_500k (only 8 of 48 layers hold a full-length KV cache).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,          # gemma family: head_dim independent of d_model
    window=1024,
    local_block=6,         # 5 local + 1 global per block
    rope_theta=1e6,
    notes="5:1 local:global; long_500k RUNS (windowed KV on 40/48 layers).",
)
