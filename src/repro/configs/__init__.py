"""Assigned architecture configs (``--arch <id>``) + the paper pipeline.

Each assigned architecture has its own module with the exact published
config; ``get_config(name)`` resolves the CLI id (dashes) to the module.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from .base import ModelConfig, ShapeConfig, SHAPES, shape_by_name

_ARCH_MODULES = {
    "qwen2-72b": "qwen2_72b",
    "yi-6b": "yi_6b",
    "gemma3-12b": "gemma3_12b",
    "qwen1.5-110b": "qwen15_110b",
    "jamba-1.5-large-398b": "jamba15_large_398b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "mamba2-1.3b": "mamba2_13b",
    "whisper-small": "whisper_small",
    "internvl2-76b": "internvl2_76b",
}

ARCH_NAMES: List[str] = list(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    mod = importlib.import_module(f".{_ARCH_MODULES[name]}", __package__)
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


# Shape-cell skip logic (DESIGN.md §Arch-applicability): long_500k needs
# sub-quadratic sequence handling; decode shapes need a decoder.
def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.family in ("ssm", "hybrid") or bool(cfg.local_block)
    return True


def runnable_cells():
    """All (arch, shape) cells that run, in deterministic order."""
    out = []
    for name in ARCH_NAMES:
        cfg = get_config(name)
        for shape in SHAPES:
            if cell_is_runnable(cfg, shape):
                out.append((name, shape.name))
    return out
