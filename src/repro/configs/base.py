"""Model / run configuration dataclasses.

One ``ModelConfig`` describes every assigned architecture (dense, MoE,
hybrid SSM+attention, pure SSM, encoder–decoder, VLM).  ``ShapeConfig``
describes one input-shape cell (train_4k / prefill_32k / decode_32k /
long_500k).  ``smoke()`` derives the reduced same-family config used by
the CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shape_by_name"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1              # MoE replaces MLP in every k-th layer
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- attention pattern ---
    window: Optional[int] = None    # sliding window width (local layers)
    local_block: int = 0            # gemma3: layers per block (5 local + 1 global)
    # --- hybrid (jamba) ---
    hybrid_block: int = 0           # layers per hybrid super-block
    attn_index: int = -1            # position of the attention layer in block
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_frames: int = 0             # precomputed frame embeddings (stub frontend)
    # --- VLM ---
    n_img_tokens: int = 0           # precomputed patch embeddings (stub frontend)
    # --- misc ---
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        changes = dict(
            n_layers=min(self.n_layers, 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // max(self.n_heads, 1))),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
        )
        if self.n_experts:
            changes.update(n_experts=8, experts_per_token=2)
        if self.local_block:
            changes.update(local_block=2, n_layers=4, window=64)
        elif self.window:
            changes.update(window=64)
        if self.hybrid_block:
            changes.update(hybrid_block=4, attn_index=1, n_layers=4)
        if self.ssm_state:
            changes.update(ssm_state=32, ssm_head_dim=32, ssm_chunk=32)
        if self.n_enc_layers:
            changes.update(n_enc_layers=2, enc_frames=32)
        if self.n_img_tokens:
            changes.update(n_img_tokens=16)
        return replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    def smoke(self) -> "ShapeConfig":
        return replace(
            self,
            seq_len=min(self.seq_len, 128),
            global_batch=min(self.global_batch, 2),
        )


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in SHAPES]}")
