"""internvl2-76b — VLM: InternViT frontend (stub) + InternLM2-76B backbone.

[arXiv:2404.16821; unverified]  80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256.  The vision tower is a STUB per instructions:
``input_specs()`` supplies precomputed (B, 256, d_model) patch embeddings
prepended to the token sequence; the LM backbone is real.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    n_img_tokens=256,
    rope_theta=1e6,
    notes="ViT frontend stubbed; long_500k skipped (pure full attention).",
)
