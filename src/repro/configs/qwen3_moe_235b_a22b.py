"""qwen3-moe-235b-a22b — MoE, 128 experts top-8.

[hf:Qwen/Qwen3-30B-A3B; hf]  94L d_model=4096 64H (GQA kv=4) d_ff=1536
(per expert) vocab=151936, MoE 128e top-8.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    head_dim=128,          # qwen3: head_dim fixed at 128 (64H × 128 > d_model)
    n_experts=128,
    experts_per_token=8,
    moe_every=1,
    rope_theta=1e6,
    notes="128e top-8 MoE; long_500k skipped (pure full attention).",
)
