"""whisper-small — encoder-decoder with conv audio frontend (stub).

[arXiv:2212.04356; unverified]  12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865.  The conv frontend is a STUB per instructions:
``input_specs()`` supplies precomputed (B, 1500, d_model) frame embeddings;
the encoder transformer + decoder (self + cross attention) are real.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,          # decoder layers
    n_enc_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    enc_frames=1500,
    notes=(
        "enc-dec; decode_32k runs (decoder KV + cross cache); "
        "long_500k skipped (full attention, 1500-frame design envelope)."
    ),
)
