"""moonshot-v1-16b-a3b — MoE (moonlight/kimi family), 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (GQA kv=16)
d_ff=1408 (per expert) vocab=163840, MoE 64e top-6.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    n_experts=64,
    experts_per_token=6,
    moe_every=1,
    rope_theta=5e4,
    notes="64e top-6 MoE; long_500k skipped (pure full attention).",
)
