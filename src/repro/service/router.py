"""ShardRouter — fault-tolerant scatter-gather over shard transports.

One :class:`~repro.core.store.IndexStore` already routes a key batch to
its digest-range shards internally, but it does so sequentially on the
calling thread and assumes every shard answers.  The router is the
serving-grade face of the same contract: it owns ``N`` replica endpoints
of one published store directory behind the :class:`ShardTransport`
seam, partitions each incoming key batch by
:func:`~repro.core.store.shard_of`, scatter-gathers the per-shard probes
across worker pools, and — when an endpoint misbehaves — retries,
hedges, and degrades instead of failing the caller:

* **per-probe deadlines** — every transport probe carries
  ``probe_timeout_ms``; a probe that outlives it is abandoned and the
  shard fails over to a sibling replica;
* **bounded retry-with-backoff** — failed probes retry against the next
  healthy sibling (``max_attempts`` total), with a tiny exponential
  pause between attempts;
* **hedged requests** — when a probe exceeds the domain's rolling p95
  (floored at ``hedge_floor_ms``), a second probe fires at the next
  replica and the first result wins (the loser is abandoned, its
  outcome still feeds health);
* **degraded mode** — when every replica of a shard range is dead or
  deadline-expired, the batch *returns* with those keys flagged in a
  per-key ``degraded`` mask (misses, not exceptions) and the failure
  taxonomy recorded per shard in :class:`RouterStats`.

Health state (up / degraded / dead, exponential-backoff probation of
dead replicas) lives in :class:`~repro.service.health.HealthTracker`,
fed by every probe outcome.  Healthy in-process serving keeps the PR 4
fast paths — zero extra thread hops until a transport is chaotic (fault
injection, future RPC stubs) or a failure domain leaves the ``up``
state.

Digesting happens ONCE per batch here (``digest_u64``), and each shard
probe receives its digest slice, so fan-out never re-pays the blake2b
pass.  This is the seam later multi-host serving plugs into: replace
:class:`LocalTransport` with an RPC stub per remote shard-set and the
scatter, gather, merge, health, and hedging logic is unchanged.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import (
    FIRST_COMPLETED,
    ThreadPoolExecutor,
    as_completed,
    wait,
)
from dataclasses import dataclass, field
from pathlib import Path
from typing import (
    Callable,
    Dict,
    List,
    NamedTuple,
    Optional,
    Sequence,
    Tuple,
    Union,
)

import numpy as np

from repro.core.fingerprint import popcount_u32
from repro.core.store import (
    IndexStore,
    QueryStats,
    digest_u64,
    merge_similar_topk,
    shard_of,
)
from repro.runtime.fault import BackoffPolicy

from .health import REPLICA_WIDE, HealthTracker
from .transport import (
    LocalTransport,
    ShardTransport,
    TransportError,
    error_kind,
)

__all__ = [
    "LookupBatchResult",
    "RouterStats",
    "ShardRouter",
    "SimilarResult",
]

DEFAULT_REPLICAS = 2
# Below this many keys a batch probes inline on one replica: task dispatch
# plus pool handoff costs more than the scatter saves (the shard loop
# is GIL-bound numpy; overlap only pays once slices are big enough for
# the release-the-GIL stretches inside searchsorted/bloom to matter).
DEFAULT_MIN_SCATTER_KEYS = 128
DEFAULT_PROBE_TIMEOUT_MS = 1000.0
DEFAULT_MAX_ATTEMPTS = 3
DEFAULT_HEDGE_FLOOR_MS = 10.0
DEFAULT_RETRY_BACKOFF_MS = 1.0


class LookupBatchResult(NamedTuple):
    """``lookup_batch`` rows plus the degraded-mode miss mask.

    ``hit[i]`` is False for keys that are genuinely absent AND for keys
    whose shard could not be probed; ``degraded[i]`` is True only for the
    latter — "we don't know", not "not there".  Callers that ignore the
    mask see plain misses (the pre-fault-tolerance contract).
    """

    file_ids: np.ndarray   # (N,) int32, -1 on miss
    offsets: np.ndarray    # (N,) int64, -1 on miss
    hit: np.ndarray        # (N,) bool
    degraded: np.ndarray   # (N,) bool — shard unreachable, not a real miss


class SimilarResult(NamedTuple):
    """``similar_batch`` top-k planes plus the per-query degraded flag.

    Similarity is a full scan, so a lost shard taints every query in the
    batch equally: ``degraded[i]`` means query ``i``'s top-k was merged
    from the surviving shards only.
    """

    scores: np.ndarray     # (Q, k) float32, -1 pads
    file_ids: np.ndarray   # (Q, k) int32
    offsets: np.ndarray    # (Q, k) int64
    degraded: np.ndarray   # (Q,) bool


@dataclass
class RouterStats:
    """Cumulative routing counters (scatter decisions, shard traffic,
    and the fault-tolerance ledger)."""

    batches: int = 0         # lookup_batch calls served
    keys: int = 0            # keys routed in total
    scattered: int = 0       # batches fanned out across the worker pool
    inline: int = 0          # batches probed inline on one replica
    shard_probes: int = 0    # per-shard probe tasks executed (scattered only)
    # similarity traffic (full-scan modality: every batch touches every
    # shard, so the scatter unit is the shard, not a key partition)
    similar_batches: int = 0
    similar_queries: int = 0        # query fingerprints routed
    similar_scattered: int = 0      # batches fanned out shard-per-task
    similar_inline: int = 0         # batches served whole on one replica
    similar_shard_probes: int = 0   # per-shard similarity tasks executed
    # fault tolerance
    hedges_fired: int = 0    # secondary probes launched past the p95 point
    hedge_wins: int = 0      # hedges that beat their primary
    retries: int = 0         # sibling failovers after a failed/expired probe
    probes_failed: int = 0   # probe attempts that raised a TransportError
    degraded_batches: int = 0   # lookup batches with >= 1 degraded key
    degraded_keys: int = 0      # keys returned behind a dead shard range
    degraded_similar: int = 0   # similarity batches merged from survivors
    # per-shard failure taxonomy: shard (-1 = whole-replica probes) ->
    # {"down"/"timeout"/"error"/"abandoned"/"dead": count}
    errors_per_shard: Dict[int, Dict[str, int]] = field(default_factory=dict)
    # shard traffic of scattered batches (inline batches skip partitioning
    # in the router entirely — the replica routes internally; its
    # QueryStats carry the per-shard truth)
    keys_per_shard: Dict[int, int] = field(default_factory=dict)

    def note_shard_keys(self, sid: np.ndarray) -> None:
        shards, counts = np.unique(sid, return_counts=True)
        for s, c in zip(shards, counts):
            s = int(s)
            self.keys_per_shard[s] = self.keys_per_shard.get(s, 0) + int(c)

    def note_error(self, shard: int, kind: str, n: int = 1) -> None:
        errs = self.errors_per_shard.setdefault(int(shard), {})
        errs[kind] = errs.get(kind, 0) + n


class ShardRouter:
    """Fault-tolerant scatter-gather ``lookup_batch`` over shard transports.

    The router's primary result contract is
    :meth:`IndexStore.lookup_batch` — ``(file_ids, offsets, hit_mask)``
    with misses at ``-1``/``False`` — so everything written against the
    store's batch read surface rides the router unchanged;
    :meth:`lookup_batch_ex` adds the per-key ``degraded`` mask (the
    serving path rides that).  ``stats()`` merges the replicas' per-shard
    :class:`QueryStats` with the router's own scatter + fault accounting,
    and :attr:`health` tracks per-``(replica, shard)`` domain state.

    ``transport_factory(store, idx) -> ShardTransport`` is the
    deployment seam: the default wraps each replica store in a
    :class:`LocalTransport`; chaos runs wrap those in
    :class:`FaultInjectingTransport`; multi-host serving will return RPC
    stubs.
    """

    def __init__(
        self,
        root: Union[str, Path],
        replicas: int = DEFAULT_REPLICAS,
        probe: Optional[str] = None,
        mmap: bool = True,
        min_scatter_keys: int = DEFAULT_MIN_SCATTER_KEYS,
        preload_digests: bool = True,
        transport_factory: Optional[
            Callable[[IndexStore, int], ShardTransport]
        ] = None,
        probe_timeout_ms: float = DEFAULT_PROBE_TIMEOUT_MS,
        max_attempts: int = DEFAULT_MAX_ATTEMPTS,
        hedge: bool = True,
        hedge_floor_ms: float = DEFAULT_HEDGE_FLOOR_MS,
        hedge_factor: float = 1.0,
        retry_backoff_ms: float = DEFAULT_RETRY_BACKOFF_MS,
        fail_threshold: int = 3,
        health_backoff: Optional[BackoffPolicy] = None,
        health_dir: Optional[Union[str, Path]] = None,
    ):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if probe_timeout_ms <= 0:
            raise ValueError(
                f"probe_timeout_ms must be > 0, got {probe_timeout_ms}"
            )
        if max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
        self.root = Path(root)
        self.probe = probe
        self.min_scatter_keys = int(min_scatter_keys)
        self.probe_timeout_ms = float(probe_timeout_ms)
        self.max_attempts = int(max_attempts)
        self.hedge = bool(hedge)
        self.hedge_floor_ms = float(hedge_floor_ms)
        self.hedge_factor = float(hedge_factor)
        self.retry_backoff_ms = float(retry_backoff_ms)
        self._stores: List[IndexStore] = [
            IndexStore.open(self.root, mmap=mmap) for _ in range(replicas)
        ]
        first = self._stores[0]
        if preload_digests:
            # serving posture: pin the global digest + Bloom planes once
            # and share the read-only arrays across replicas
            planes = first.preload_digest_plane()
            for st in self._stores[1:]:
                st.adopt_planes(planes)
        self.key_mode: str = first.key_mode
        self.n_shards: int = first.n_shards
        self.digest_bits: int = first.digest_bits
        self.fingerprint_bits: Optional[int] = first.fingerprint_bits
        self.file_names: List[str] = first.file_names
        if transport_factory is None:
            transport_factory = lambda st, i: LocalTransport(  # noqa: E731
                st, name=f"replica{i}", probe=probe
            )
        self._transports: List[ShardTransport] = [
            transport_factory(st, i) for i, st in enumerate(self._stores)
        ]
        self._chaotic = any(t.chaotic for t in self._transports)
        self.health = HealthTracker(
            n_replicas=len(self._transports),
            fail_threshold=fail_threshold,
            backoff=health_backoff,
            rundir=Path(health_dir) if health_dir is not None else None,
        )
        # gather pool runs per-shard group tasks; probe pool runs the
        # transport probes those tasks race (primary + hedge + retries).
        # Probes never submit to a pool themselves, so the two tiers
        # cannot deadlock on each other.
        self._gather = ThreadPoolExecutor(
            max_workers=min(8, max(4, replicas)),
            thread_name_prefix="shard-gather",
        )
        self._probe_pool = ThreadPoolExecutor(
            max_workers=min(16, max(4, 2 * replicas)),
            thread_name_prefix="shard-probe",
        )
        self._rr = 0
        self._rr_lock = threading.Lock()
        self.stats = RouterStats()
        self._stats_lock = threading.Lock()
        self._closed = False

    @property
    def replicas(self) -> int:
        return len(self._stores)

    @property
    def transports(self) -> List[ShardTransport]:
        return list(self._transports)

    def __len__(self) -> int:
        return len(self._stores[0])

    def iter_keys(self):
        """Enumerate every key (builder-side; loads shards on replica 0)."""
        return self._stores[0].iter_keys()

    # -- transport selection -------------------------------------------------

    def _next_replica(self) -> int:
        with self._rr_lock:
            r = self._rr
            self._rr = (r + 1) % len(self._transports)
        return r

    def _ft_active(self) -> bool:
        """Route through the failure-domain path?  Chaotic transports can
        stall or fail by design; a non-up health domain means a previously
        clean endpoint started failing."""
        return self._chaotic or self.health.has_unhealthy()

    # -- the fault-tolerant probe core ---------------------------------------

    def _timed_call(self, replica: int, hshard: int, call, timeout_s: float):
        """One transport probe; its outcome always reaches the tracker —
        including probes the router already abandoned (late losers)."""
        t0 = time.monotonic()
        try:
            out = call(self._transports[replica], timeout_s)
        except TransportError as e:
            self.health.on_failure(replica, hshard, error_kind(e))
            raise
        except Exception as e:  # noqa: BLE001 — endpoint bug, still a failure
            self.health.on_failure(replica, hshard, "error")
            raise
        self.health.on_success(replica, hshard, time.monotonic() - t0)
        return out

    def _hedge_after_s(self, replica: int, hshard: int) -> float:
        """Fire the hedge once the primary exceeds its domain's rolling
        p95 (scaled by ``hedge_factor``), floored at ``hedge_floor_ms``
        so cold domains still hedge against injected stalls."""
        floor = self.hedge_floor_ms / 1e3
        p95 = self.health.p95_s(replica, hshard)
        if p95 is None:
            return floor
        return max(p95 * self.hedge_factor, floor)

    def _ft_probe(self, shard: Optional[int], call):
        """Probe one failure domain with deadline, hedging, and sibling
        failover.  ``call(transport, timeout_s)`` runs the actual probe.
        Returns the probe result, or ``None`` when the domain is fully
        degraded (every candidate dead, failed, or deadline-expired)."""
        hshard = REPLICA_WIDE if shard is None else int(shard)
        timeout_s = self.probe_timeout_ms / 1e3
        cands = self.health.candidates(hshard)
        if not cands:
            # every replica dead and inside its backoff window: fail fast
            with self._stats_lock:
                self.stats.note_error(hshard, "dead")
            return None
        cands = cands[: self.max_attempts]
        waits: Dict[object, int] = {}
        hedge_futs = set()
        used = 0
        t_stop = 0.0
        hedge_at: Optional[float] = None

        def fire(as_hedge: bool) -> None:
            nonlocal used, t_stop, hedge_at
            r = cands[used]
            used += 1
            f = self._probe_pool.submit(
                self._timed_call, r, hshard, call, timeout_s
            )
            waits[f] = r
            if as_hedge:
                hedge_futs.add(f)
                hedge_at = None
            else:
                now = time.monotonic()
                t_stop = now + timeout_s
                hedge_at = None
                if self.hedge and used < len(cands):
                    ha = self._hedge_after_s(r, hshard)
                    if ha < timeout_s:
                        hedge_at = now + ha

        fire(as_hedge=False)
        while True:
            now = time.monotonic()
            if waits and now < t_stop:
                t_next = t_stop if hedge_at is None else min(hedge_at, t_stop)
                done, _ = wait(
                    set(waits),
                    timeout=max(0.0, t_next - now),
                    return_when=FIRST_COMPLETED,
                )
                winner = None
                for f in done:
                    r = waits.pop(f)
                    exc = f.exception()
                    if exc is None:
                        winner = f
                    elif isinstance(exc, TransportError):
                        with self._stats_lock:
                            self.stats.probes_failed += 1
                            self.stats.note_error(hshard, error_kind(exc))
                    else:
                        raise exc  # endpoint bug: propagate, don't degrade
                if winner is not None:
                    if winner in hedge_futs:
                        with self._stats_lock:
                            self.stats.hedge_wins += 1
                    return winner.result()
                if done:
                    continue  # a probe failed; race whatever is still up
                if hedge_at is not None and time.monotonic() >= hedge_at:
                    hedge_at = None  # one hedge per attempt, never a spin
                    if used < len(cands):
                        with self._stats_lock:
                            self.stats.hedges_fired += 1
                        fire(as_hedge=True)
                continue
            # deadline expired with probes still in flight, or every
            # in-flight probe failed: abandon and fail over to the next
            # sibling (late completions still feed health via _timed_call)
            if waits:
                with self._stats_lock:
                    self.stats.note_error(hshard, "abandoned", len(waits))
                waits.clear()
                hedge_futs.clear()
            if used >= len(cands):
                return None
            with self._stats_lock:
                self.stats.retries += 1
            time.sleep(
                min(0.05, (self.retry_backoff_ms / 1e3) * (2 ** (used - 1)))
            )
            fire(as_hedge=False)

    # -- exact-key lookups ---------------------------------------------------

    def lookup_batch_ex(
        self, keys: Sequence[str], digests: Optional[np.ndarray] = None
    ) -> LookupBatchResult:
        """Resolve a batch: digest once, partition, scatter, merge —
        returning partial results with a per-key ``degraded`` mask
        instead of raising when shard ranges are unreachable."""
        if self._closed:
            raise RuntimeError("router is closed")
        keys = list(keys)
        n = len(keys)
        if n == 0:
            return LookupBatchResult(
                np.empty(0, dtype=np.int32),
                np.empty(0, dtype=np.int64),
                np.empty(0, dtype=bool),
                np.empty(0, dtype=bool),
            )
        q = (
            digest_u64(keys, bits=self.digest_bits)
            if digests is None
            else np.asarray(digests, dtype=np.uint64)
        )
        with self._stats_lock:
            self.stats.batches += 1
            self.stats.keys += n
        if not self._ft_active():
            try:
                return self._healthy_lookup(keys, q)
            except TransportError:
                # an endpoint failed mid-probe: re-route this batch
                # through the per-shard failure-domain path
                pass
        return self._ft_lookup(keys, q)

    def lookup_batch(
        self, keys: Sequence[str], digests: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The legacy 3-tuple contract (degraded keys read as misses)."""
        r = self.lookup_batch_ex(keys, digests)
        return r.file_ids, r.offsets, r.hit

    def _partition(
        self, q: np.ndarray
    ) -> Tuple[List[Tuple[int, np.ndarray]], np.ndarray]:
        """Group a digest batch by shard: ``([(shard, rows), …], sid)``."""
        n = len(q)
        sid = shard_of(q, self.n_shards, self.digest_bits)
        order = np.argsort(sid, kind="stable")
        uniq, starts = np.unique(sid[order], return_index=True)
        bounds = list(starts) + [n]
        return [
            (int(uniq[i]), order[bounds[i]:bounds[i + 1]])
            for i in range(len(uniq))
        ], sid

    def _healthy_lookup(
        self, keys: List[str], q: np.ndarray
    ) -> LookupBatchResult:
        """The PR 4 fast paths: inline micro-batches, pooled scatter for
        big ones — no deadline/hedge machinery in the way."""
        n = len(keys)
        groups = None
        if n >= self.min_scatter_keys and len(self._transports) > 1:
            groups, sid = self._partition(q)
        scatter = groups is not None and len(groups) > 1
        with self._stats_lock:
            if scatter:
                self.stats.note_shard_keys(sid)
                self.stats.scattered += 1
                self.stats.shard_probes += len(groups)
            else:
                self.stats.inline += 1

        no_degrade = np.zeros(n, dtype=bool)
        if not scatter:
            tr = self._transports[self._next_replica()]
            fid, off, hit = tr.lookup_all(keys, q)
            return LookupBatchResult(fid, off, hit, no_degrade)

        def probe_group(shard: int, sel: np.ndarray):
            tr = self._transports[self._next_replica()]
            return tr.lookup_shard(
                shard, [keys[i] for i in sel], q[sel]
            )

        file_ids = np.full(n, -1, dtype=np.int32)
        offsets = np.full(n, -1, dtype=np.int64)
        hit = np.zeros(n, dtype=bool)
        # merge in completion order (same discipline as the span engine's
        # depth window): the gather thread scatters results back the
        # moment any shard lands instead of serializing on the slowest
        futs = {
            self._gather.submit(probe_group, s, sel): sel
            for s, sel in groups
        }
        for fut in as_completed(futs):
            sel = futs[fut]
            gfid, goff, ghit = fut.result()
            file_ids[sel] = gfid
            offsets[sel] = goff
            hit[sel] = ghit
        return LookupBatchResult(file_ids, offsets, hit, no_degrade)

    def _ft_lookup(
        self, keys: List[str], q: np.ndarray
    ) -> LookupBatchResult:
        """Per-shard failure-domain path: every shard group probes with
        deadline + failover + hedging; unreachable groups come back as
        degraded misses instead of exceptions."""
        n = len(keys)
        groups, sid = self._partition(q)
        with self._stats_lock:
            if len(groups) > 1:
                self.stats.note_shard_keys(sid)
                self.stats.scattered += 1
                self.stats.shard_probes += len(groups)
            else:
                self.stats.inline += 1

        file_ids = np.full(n, -1, dtype=np.int32)
        offsets = np.full(n, -1, dtype=np.int64)
        hit = np.zeros(n, dtype=bool)
        degraded = np.zeros(n, dtype=bool)

        def probe_group(shard: int, sel: np.ndarray):
            klist = [keys[i] for i in sel]
            dg = q[sel]
            return self._ft_probe(
                shard,
                lambda tr, to: tr.lookup_shard(shard, klist, dg, to),
            )

        futs = {
            self._gather.submit(probe_group, s, sel): (s, sel)
            for s, sel in groups
        }
        for fut in as_completed(futs):
            _s, sel = futs[fut]
            out = fut.result()
            if out is None:
                degraded[sel] = True
                continue
            gfid, goff, ghit = out
            file_ids[sel] = gfid
            offsets[sel] = goff
            hit[sel] = ghit
        if degraded.any():
            with self._stats_lock:
                self.stats.degraded_batches += 1
                self.stats.degraded_keys += int(degraded.sum())
        return LookupBatchResult(file_ids, offsets, hit, degraded)

    # -- similarity scatter-gather -------------------------------------------

    def similar_batch_ex(self, fps: np.ndarray, k: int) -> SimilarResult:
        """Batched Tanimoto top-k: scatter shards, gather, merge.

        Result contract is :meth:`IndexStore.similar_batch` — ``(scores,
        file_ids, offsets)`` each ``(Q, k)``, ordered ``(score desc,
        file_id asc, offset asc)`` with ``-1`` pads — plus a per-query
        ``degraded`` flag.  Similarity is a full scan of every shard's
        plane, so an unreachable shard taints the whole batch: its rows
        simply do not compete in the merge, and ``degraded`` records
        that the top-k came from the survivors only.
        """
        if self._closed:
            raise RuntimeError("router is closed")
        first = self._stores[0]
        fps = first._check_fps(fps)
        qn = fps.shape[0]
        live = [
            s for s in range(self.n_shards)
            if int(first.manifest["shards"][s]["count"]) > 0
        ]
        with self._stats_lock:
            self.stats.similar_batches += 1
            self.stats.similar_queries += qn
            if qn == 0:
                self.stats.similar_inline += 1
        if qn == 0:
            e = np.zeros((0, k))
            return SimilarResult(
                e.astype(np.float32), e.astype(np.int32),
                e.astype(np.int64), np.zeros(0, dtype=bool),
            )
        if not self._ft_active():
            try:
                return self._healthy_similar(fps, k, live)
            except TransportError:
                pass
        return self._ft_similar(fps, k, live)

    def similar_batch(
        self, fps: np.ndarray, k: int
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The legacy 3-tuple contract (degraded flag dropped)."""
        r = self.similar_batch_ex(fps, k)
        return r.scores, r.file_ids, r.offsets

    def _healthy_similar(
        self, fps: np.ndarray, k: int, live: List[int]
    ) -> SimilarResult:
        qn = fps.shape[0]
        scatter = len(self._transports) > 1 and len(live) > 1
        with self._stats_lock:
            if scatter:
                self.stats.similar_scattered += 1
                self.stats.similar_shard_probes += len(live)
            else:
                self.stats.similar_inline += 1
        no_degrade = np.zeros(qn, dtype=bool)
        if not scatter:
            tr = self._transports[self._next_replica()]
            scores, fids, offs = tr.similar_all(fps, k)
            return SimilarResult(scores, fids, offs, no_degrade)

        qc = popcount_u32(fps).sum(axis=1, dtype=np.int32)  # once per batch

        def probe_shard(s: int):
            tr = self._transports[self._next_replica()]
            return tr.similar_shard(s, fps, k, q_counts=qc)

        futs = [self._gather.submit(probe_shard, s) for s in live]
        # merge_similar_topk is order-insensitive (it re-sorts on the
        # global tie contract), so gather in completion order
        parts = [f.result() for f in as_completed(futs)]
        scores, fids, offs = merge_similar_topk(parts, k)
        return SimilarResult(scores, fids, offs, no_degrade)

    def _ft_similar(
        self, fps: np.ndarray, k: int, live: List[int]
    ) -> SimilarResult:
        qn = fps.shape[0]
        with self._stats_lock:
            if len(live) > 1:
                self.stats.similar_scattered += 1
                self.stats.similar_shard_probes += len(live)
            else:
                self.stats.similar_inline += 1
        qc = popcount_u32(fps).sum(axis=1, dtype=np.int32)

        def probe_shard(s: int):
            return self._ft_probe(
                s,
                lambda tr, to: tr.similar_shard(
                    s, fps, k, q_counts=qc, timeout_s=to
                ),
            )

        futs = {self._gather.submit(probe_shard, s): s for s in live}
        parts = []
        lost = 0
        for f in as_completed(futs):
            out = f.result()
            if out is None:
                lost += 1
            else:
                parts.append(out)
        if parts:
            scores, fids, offs = merge_similar_topk(parts, k)
        else:
            scores = np.full((qn, k), -1.0, dtype=np.float32)
            fids = np.full((qn, k), -1, dtype=np.int32)
            offs = np.full((qn, k), -1, dtype=np.int64)
        degraded = np.full(qn, lost > 0, dtype=bool)
        if lost:
            with self._stats_lock:
                self.stats.degraded_similar += 1
        return SimilarResult(scores, fids, offs, degraded)

    # -- convenience + stats -------------------------------------------------

    def locate_batch(
        self, keys: Sequence[str]
    ) -> List[Optional[Tuple[str, int]]]:
        fid, off, hit = self.lookup_batch(keys)
        return [
            (self.file_names[fid[i]], int(off[i])) if hit[i] else None
            for i in range(len(keys))
        ]

    def lookup(self, key: str) -> Optional[Tuple[str, int]]:
        return self.locate_batch([key])[0]

    def query_stats(self) -> QueryStats:
        """Per-shard probe counters merged across every replica."""
        merged = QueryStats()
        for st in self._stores:
            with st._stats_lock:
                merged.merge(st.stats)
        return merged

    def resident_bytes(self) -> int:
        """Columns faulted in across replicas (mmap pages are shared, so
        this over-counts physical memory by design — it is the per-handle
        view the capacity benchmarks track)."""
        return sum(st.resident_bytes() for st in self._stores)

    def close(self) -> None:
        self._closed = True
        self._gather.shutdown(wait=True, cancel_futures=True)
        self._probe_pool.shutdown(wait=True, cancel_futures=True)
        for tr in self._transports:
            tr.close()

    def __enter__(self) -> "ShardRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
