"""ShardRouter — scatter-gather batched lookups over IndexStore replicas.

One :class:`~repro.core.store.IndexStore` already routes a key batch to
its digest-range shards internally, but it does so sequentially on the
calling thread.  The router is the serving-grade face of the same
contract: it owns ``N`` replica handles of one published store directory
(replicas share pages through the OS page cache — an extra handle costs
file descriptors and a manifest, not resident column memory), partitions
each incoming key batch by :func:`~repro.core.store.shard_of`, and
scatter-gathers the per-shard probes across a bounded worker pool, each
worker checking out its own replica so no two probes contend on one
store's lazy-load or stats state.

Digesting happens ONCE per batch here (``digest_u64``), and each shard
probe receives its digest slice (``IndexStore.lookup_batch(digests=…)``),
so fan-out never re-pays the blake2b pass.  Small batches — the common
case under the micro-batching scheduler — skip the pool entirely
(``min_scatter_keys``): below that size the per-task dispatch overhead
outweighs any overlap, and one replica probes the whole batch inline.

This is the seam later multi-host serving plugs into: replace the
replica checkout with an RPC stub per remote shard-set and the scatter,
gather, and merge logic is unchanged.
"""

from __future__ import annotations

import queue
import threading
from concurrent.futures import ThreadPoolExecutor, as_completed
from contextlib import contextmanager
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.fingerprint import popcount_u32
from repro.core.store import (
    IndexStore,
    QueryStats,
    digest_u64,
    merge_similar_topk,
    shard_of,
)

__all__ = ["RouterStats", "ShardRouter"]

DEFAULT_REPLICAS = 2
# Below this many keys a batch probes inline on one replica: task dispatch
# plus replica checkout costs more than the scatter saves (the shard loop
# is GIL-bound numpy; overlap only pays once slices are big enough for
# the release-the-GIL stretches inside searchsorted/bloom to matter).
DEFAULT_MIN_SCATTER_KEYS = 128


@dataclass
class RouterStats:
    """Cumulative routing counters (scatter decisions + shard traffic)."""

    batches: int = 0         # lookup_batch calls served
    keys: int = 0            # keys routed in total
    scattered: int = 0       # batches fanned out across the worker pool
    inline: int = 0          # batches probed inline on one replica
    shard_probes: int = 0    # per-shard probe tasks executed (scattered only)
    # similarity traffic (full-scan modality: every batch touches every
    # shard, so the scatter unit is the shard, not a key partition)
    similar_batches: int = 0
    similar_queries: int = 0        # query fingerprints routed
    similar_scattered: int = 0      # batches fanned out shard-per-task
    similar_inline: int = 0         # batches served whole on one replica
    similar_shard_probes: int = 0   # per-shard similarity tasks executed
    # shard traffic of scattered batches (inline batches skip partitioning
    # in the router entirely — the replica routes internally; its
    # QueryStats carry the per-shard truth)
    keys_per_shard: Dict[int, int] = field(default_factory=dict)

    def note_shard_keys(self, sid: np.ndarray) -> None:
        shards, counts = np.unique(sid, return_counts=True)
        for s, c in zip(shards, counts):
            s = int(s)
            self.keys_per_shard[s] = self.keys_per_shard.get(s, 0) + int(c)


class ShardRouter:
    """Scatter-gather ``lookup_batch`` over ``replicas`` store handles.

    The router's result contract is exactly :meth:`IndexStore.lookup_batch`
    — ``(file_ids, offsets, hit_mask)`` with misses at ``-1``/``False`` —
    so everything written against the store's batch read surface rides the
    router unchanged.  ``stats()`` merges the replicas' per-shard
    :class:`QueryStats` with the router's own scatter accounting.
    """

    def __init__(
        self,
        root: Union[str, Path],
        replicas: int = DEFAULT_REPLICAS,
        probe: Optional[str] = None,
        mmap: bool = True,
        min_scatter_keys: int = DEFAULT_MIN_SCATTER_KEYS,
        preload_digests: bool = True,
    ):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.root = Path(root)
        self.probe = probe
        self.min_scatter_keys = int(min_scatter_keys)
        self._stores: List[IndexStore] = [
            IndexStore.open(self.root, mmap=mmap) for _ in range(replicas)
        ]
        first = self._stores[0]
        if preload_digests:
            # serving posture: pin the global digest + Bloom planes once
            # and share the read-only arrays across replicas
            planes = first.preload_digest_plane()
            for st in self._stores[1:]:
                st.adopt_planes(planes)
        self.key_mode: str = first.key_mode
        self.n_shards: int = first.n_shards
        self.digest_bits: int = first.digest_bits
        self.fingerprint_bits: Optional[int] = first.fingerprint_bits
        self.file_names: List[str] = first.file_names
        self._free: "queue.SimpleQueue[IndexStore]" = queue.SimpleQueue()
        for st in self._stores:
            self._free.put(st)
        self._pool = ThreadPoolExecutor(
            max_workers=replicas, thread_name_prefix="shard-router"
        )
        self.stats = RouterStats()
        self._stats_lock = threading.Lock()
        self._closed = False

    @property
    def replicas(self) -> int:
        return len(self._stores)

    def __len__(self) -> int:
        return len(self._stores[0])

    def iter_keys(self):
        """Enumerate every key (builder-side; loads shards on replica 0)."""
        return self._stores[0].iter_keys()

    # -- the scatter-gather core --------------------------------------------

    @contextmanager
    def _replica(self):
        """Check out a replica; at most ``replicas`` probes run at once."""
        st = self._free.get()
        try:
            yield st
        finally:
            self._free.put(st)

    def lookup_batch(
        self, keys: Sequence[str], digests: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Resolve a batch: digest once, partition, scatter, merge."""
        if self._closed:
            raise RuntimeError("router is closed")
        keys = list(keys)
        n = len(keys)
        if n == 0:
            return (
                np.empty(0, dtype=np.int32),
                np.empty(0, dtype=np.int64),
                np.empty(0, dtype=bool),
            )
        q = (
            digest_u64(keys, bits=self.digest_bits)
            if digests is None
            else np.asarray(digests, dtype=np.uint64)
        )
        # micro-batches skip partitioning entirely: the replica's own
        # lookup_batch routes internally, and per-call numpy overhead is
        # exactly what the scheduler exists to amortize
        groups = None
        if n >= self.min_scatter_keys and len(self._stores) > 1:
            sid = shard_of(q, self.n_shards, self.digest_bits)
            # one stable argsort, not per-shard nonzero scans (same
            # grouping the store's own batch path uses)
            order = np.argsort(sid, kind="stable")
            uniq, starts = np.unique(sid[order], return_index=True)
            bounds = list(starts) + [n]
            groups = [
                order[bounds[i]:bounds[i + 1]] for i in range(len(uniq))
            ]
        scatter = groups is not None and len(groups) > 1
        with self._stats_lock:
            self.stats.batches += 1
            self.stats.keys += n
            if scatter:
                self.stats.note_shard_keys(sid)
                self.stats.scattered += 1
                self.stats.shard_probes += len(groups)
            else:
                self.stats.inline += 1

        if not scatter:
            with self._replica() as st:
                return st.lookup_batch(keys, probe=self.probe, digests=q)

        def probe_group(sel: np.ndarray):
            with self._replica() as st:
                return st.lookup_batch(
                    [keys[i] for i in sel], probe=self.probe, digests=q[sel]
                )

        file_ids = np.full(n, -1, dtype=np.int32)
        offsets = np.full(n, -1, dtype=np.int64)
        hit = np.zeros(n, dtype=bool)
        # merge in completion order (same discipline as the span engine's
        # depth window): the gather thread scatters results back the
        # moment any shard lands instead of serializing on the slowest
        futs = {self._pool.submit(probe_group, sel): sel for sel in groups}
        for fut in as_completed(futs):
            sel = futs[fut]
            gfid, goff, ghit = fut.result()
            file_ids[sel] = gfid
            offsets[sel] = goff
            hit[sel] = ghit
        return file_ids, offsets, hit

    # -- similarity scatter-gather -------------------------------------------

    def similar_batch(
        self, fps: np.ndarray, k: int
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched Tanimoto top-k: scatter shards, gather, merge.

        Result contract is exactly :meth:`IndexStore.similar_batch` —
        ``(scores, file_ids, offsets)`` each ``(Q, k)``, ordered ``(score
        desc, file_id asc, offset asc)`` with ``-1`` pads.  Similarity is
        a full scan of every shard's plane (no digest routing to narrow
        the fan-out), so with multiple replicas each shard's scan becomes
        one pool task and the per-shard top-k candidates merge through
        the same :func:`merge_similar_topk` the store uses inline —
        identical results by construction, just overlapped.
        """
        if self._closed:
            raise RuntimeError("router is closed")
        first = self._stores[0]
        fps = first._check_fps(fps)
        qn = fps.shape[0]
        live = [
            s for s in range(self.n_shards)
            if int(first.manifest["shards"][s]["count"]) > 0
        ]
        scatter = len(self._stores) > 1 and len(live) > 1 and qn > 0
        with self._stats_lock:
            self.stats.similar_batches += 1
            self.stats.similar_queries += qn
            if scatter:
                self.stats.similar_scattered += 1
                self.stats.similar_shard_probes += len(live)
            else:
                self.stats.similar_inline += 1

        if not scatter:
            with self._replica() as st:
                return st.similar_batch(fps, k, probe=self.probe)

        qc = popcount_u32(fps).sum(axis=1, dtype=np.int32)  # once per batch

        def probe_shard(s: int):
            with self._replica() as st:
                return st.similar_shard(
                    s, fps, k, probe=self.probe, q_counts=qc
                )

        futs = [self._pool.submit(probe_shard, s) for s in live]
        # merge_similar_topk is order-insensitive (it re-sorts on the
        # global tie contract), so gather in completion order
        parts = [f.result() for f in as_completed(futs)]
        return merge_similar_topk(parts, k)

    # -- convenience + stats -------------------------------------------------

    def locate_batch(
        self, keys: Sequence[str]
    ) -> List[Optional[Tuple[str, int]]]:
        fid, off, hit = self.lookup_batch(keys)
        return [
            (self.file_names[fid[i]], int(off[i])) if hit[i] else None
            for i in range(len(keys))
        ]

    def lookup(self, key: str) -> Optional[Tuple[str, int]]:
        return self.locate_batch([key])[0]

    def query_stats(self) -> QueryStats:
        """Per-shard probe counters merged across every replica."""
        merged = QueryStats()
        for st in self._stores:
            with st._stats_lock:
                merged.merge(st.stats)
        return merged

    def resident_bytes(self) -> int:
        """Columns faulted in across replicas (mmap pages are shared, so
        this over-counts physical memory by design — it is the per-handle
        view the capacity benchmarks track)."""
        return sum(st.resident_bytes() for st in self._stores)

    def close(self) -> None:
        self._closed = True
        self._pool.shutdown(wait=True, cancel_futures=True)

    def __enter__(self) -> "ShardRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
