"""QueryService — the typed facade over router → scheduler → reader → cache.

One object owns the whole serving-grade read stack:

    caller ── submit ──► MicroBatcher ── batched probe ──► ShardRouter
                             │                                 │ scatter
                             │                        IndexStore replicas
                             ▼                                 │
                     (file, offset) plan ◄─────── merge ───────┘
                             │
                             ▼
                  reader.stream_plan (coalesced preads, file workers)
                             │         with the shared RecordCache in front
                             ▼
                    verified records / stream

``lookup`` answers "where is this key" through the continuous
micro-batching admission queue, so any number of small concurrent
callers probe as a few big batches.  ``fetch``/``fetch_stream`` carry on
into the async span engine with the service's scan-resistant record
cache in front — the same call a one-off extraction makes, so bulk
integration jobs and high-concurrency serving share one batched read
contract (and one cache, which is why the cache's segmented admission
matters: the bulk sweep must not evict the serving working set).
``fetch_async`` is the fully non-blocking variant: the probe rides the
admission queue, the read phase runs on the service's pools, and the
caller gets a future — end-to-end async through the MicroBatcher.
``fetch_aio`` is the asyncio-native twin (awaitable probe, no parked
thread).  ``similar``/``similar_async`` are the second query modality:
batched Tanimoto top-k over the store's fingerprint planes, coalesced
through their own MicroBatcher so concurrent similarity callers share
shard scans the way lookup callers share probes.

The service owns one long-lived span backend (io_uring rings persist
across fetches; ``ServiceConfig.reader_backend``/``reader_depth``) and
one shared :class:`~repro.core.verify.VerifyBatcher`, so recompute/
digest verification batches combine across every concurrent fetch.

Every layer keeps its own counters; :meth:`stats` merges them into one
dict the launcher and benchmarks report from.
"""

from __future__ import annotations

import asyncio
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.cache import RecordCache
from repro.core.extract import (
    ExtractionResult,
    Mismatch,
    assemble_plan,
    extract,
    extract_iter,
)
from repro.core.identifiers import hashed_key
from repro.core.iobackend import resolve_backend
from repro.core.reader import (
    DEFAULT_COALESCE_GAP,
    DEFAULT_SPAN_GUESS,
    DEFAULT_WORKERS,
    ReadStats,
    stream_plan,
)
from repro.core.records import RecordStore
from repro.core.verify import VerifyBatcher

from repro.runtime.fault import BackoffPolicy

from .router import (
    DEFAULT_HEDGE_FLOOR_MS,
    DEFAULT_MAX_ATTEMPTS,
    DEFAULT_MIN_SCATTER_KEYS,
    DEFAULT_PROBE_TIMEOUT_MS,
    DEFAULT_REPLICAS,
    LookupBatchResult,
    ShardRouter,
    SimilarResult,
)
from .scheduler import (
    DEFAULT_MAX_BATCH,
    DEFAULT_MAX_WAIT_MS,
    MicroBatcher,
)

__all__ = ["QueryService", "ServiceConfig"]


@dataclass
class ServiceConfig:
    """Knobs for the full router → scheduler → reader → cache stack."""

    # router
    replicas: int = DEFAULT_REPLICAS
    probe: Optional[str] = None            # IndexStore probe backend
    min_scatter_keys: int = DEFAULT_MIN_SCATTER_KEYS
    preload_digests: bool = True           # pin the global digest plane
    # scheduler
    max_batch: int = DEFAULT_MAX_BATCH
    max_wait_ms: float = DEFAULT_MAX_WAIT_MS
    # record cache (shared across every fetch path)
    cache_records: int = 8192
    cache_bytes: Optional[int] = None
    # read engine
    read_workers: int = DEFAULT_WORKERS
    coalesce_gap: int = DEFAULT_COALESCE_GAP
    span_guess: int = DEFAULT_SPAN_GUESS
    verify: bool = True
    # span I/O backend: "auto"/"uring"/"thread"/"mmap"; None reads
    # REPRO_READER_BACKEND.  The service owns ONE long-lived backend
    # instance (io_uring rings persist across fetches).
    reader_backend: Optional[str] = None
    # in-flight spans per file worker (None -> REPRO_READER_DEPTH)
    reader_depth: Optional[int] = None
    # verification backend for the shared VerifyBatcher: "auto" (vector
    # recompute + device digest compare when live), "vector", "process",
    # or the legacy per-record "string"/"digest" paths
    verify_backend: str = "auto"
    # similarity: the fixed k every coalesced Tanimoto probe runs at.
    # Per-call k <= this rides the shared batch (the top-k contract is
    # prefix-stable: the top-j of a top-k probe IS the top-j); larger k
    # bypasses the scheduler and probes alone.
    similar_top_k: int = 32
    # fault tolerance (router probe deadlines / failover / hedging —
    # active when transports are chaotic or a failure domain degrades)
    probe_timeout_ms: float = DEFAULT_PROBE_TIMEOUT_MS
    probe_attempts: int = DEFAULT_MAX_ATTEMPTS   # total tries per shard probe
    hedge: bool = True
    hedge_floor_ms: float = DEFAULT_HEDGE_FLOOR_MS
    hedge_factor: float = 1.0
    fail_threshold: int = 3        # consecutive failures before "dead"
    backoff_base_s: float = 0.2    # dead-replica re-probe schedule
    backoff_cap_s: float = 5.0
    health_dir: Optional[str] = None  # heartbeat files for the detector


class QueryService:
    """Async scatter-gather query service over one published index store.

    ``records`` is the SDF corpus (:class:`RecordStore`); ``store`` is the
    ``save_sharded`` directory or an already-built :class:`ShardRouter`.
    The service is thread-safe by construction — that is its point: call
    :meth:`lookup`/:meth:`fetch` from as many threads as you like and the
    scheduler coalesces them.
    """

    def __init__(
        self,
        records: RecordStore,
        store: Union[str, Path, ShardRouter],
        config: Optional[ServiceConfig] = None,
        cache: Optional[RecordCache] = None,
    ):
        self.records = records
        self.config = config or ServiceConfig()
        if isinstance(store, ShardRouter):
            self.router = store
            self._owns_router = False
        else:
            self.router = ShardRouter(
                store,
                replicas=self.config.replicas,
                probe=self.config.probe,
                min_scatter_keys=self.config.min_scatter_keys,
                preload_digests=self.config.preload_digests,
                probe_timeout_ms=self.config.probe_timeout_ms,
                max_attempts=self.config.probe_attempts,
                hedge=self.config.hedge,
                hedge_floor_ms=self.config.hedge_floor_ms,
                hedge_factor=self.config.hedge_factor,
                fail_threshold=self.config.fail_threshold,
                health_backoff=BackoffPolicy(
                    base_s=self.config.backoff_base_s,
                    cap_s=self.config.backoff_cap_s,
                ),
                health_dir=self.config.health_dir,
            )
            self._owns_router = True
        self.cache = cache if cache is not None else RecordCache(
            capacity=self.config.cache_records,
            max_bytes=self.config.cache_bytes,
        )
        # the coalesced probe rides the _ex contract so the per-key
        # degraded mask scatters back with each request's rows
        self.batcher = MicroBatcher(
            self.router.lookup_batch_ex,
            max_batch=self.config.max_batch,
            max_wait_ms=self.config.max_wait_ms,
        )
        # long-lived span-engine pool shared by every fetch (per-call pool
        # construction would dominate small fetches)
        self.read_executor = ThreadPoolExecutor(
            max_workers=max(1, self.config.read_workers),
            thread_name_prefix="svc-reader",
        )
        # One span backend for the service's lifetime (io_uring rings and
        # their fds are per-thread and expensive to rebuild per fetch) and
        # one VerifyBatcher, so verification batches combine across every
        # concurrent fetch — service-wide continuous verify batching.
        self.read_backend = resolve_backend(self.config.reader_backend)
        self.verifier = VerifyBatcher(self.config.verify_backend)
        # tiny pool that runs fetch_async read phases off the scheduler's
        # flush thread (the probe callback must never do blocking I/O)
        self._orchestrator = ThreadPoolExecutor(
            max_workers=2, thread_name_prefix="svc-fetch"
        )
        # similarity admission queue (lazy: a store without a fingerprint
        # plane never pays the second batcher's watchdog thread)
        self._similar_batcher: Optional[MicroBatcher] = None
        self._similar_init_lock = threading.Lock()
        self.read_stats = ReadStats()
        self._read_stats_lock = threading.Lock()
        self._closed = False

    # -- identity ------------------------------------------------------------

    @property
    def key_mode(self) -> str:
        return self.router.key_mode

    def __len__(self) -> int:
        return len(self.router)

    # -- lookup surface (scheduler-coalesced) --------------------------------

    def lookup_async(
        self, keys: Sequence[str]
    ) -> "Future[LookupBatchResult]":
        """Submit a raw lookup; resolves to ``(file_ids, offsets, hit,
        degraded)`` — the fault-tolerant batch contract."""
        return self.batcher.submit(keys)

    def lookup_batch(
        self, keys: Sequence[str], timeout: Optional[float] = None
    ) -> LookupBatchResult:
        """The fault-tolerant batch contract, micro-batched: raw
        ``(file_ids, offsets, hit_mask, degraded_mask)`` with no per-key
        boxing — the hot serving surface (``lookup`` builds name tuples
        on top).  ``degraded[i]`` marks keys whose shard range was
        unreachable: they read as misses, but the truth is unknown."""
        return self.batcher.lookup(keys, timeout=timeout)

    def lookup(
        self, keys: Sequence[str], timeout: Optional[float] = None
    ) -> List[Optional[Tuple[str, int]]]:
        """``[(file_name, offset) | None]`` per key, probe-coalesced."""
        fid, off, hit, _ = self.batcher.lookup(keys, timeout=timeout)
        names = self.router.file_names
        return [
            (names[fid[i]], int(off[i])) if hit[i] else None
            for i in range(len(keys))
        ]

    def __contains__(self, key: str) -> bool:
        return self.lookup([key])[0] is not None

    def plan(
        self,
        targets: Sequence[str],
        key_bits: int = 64,
        sort_offsets: bool = True,
    ):
        """Per-file extraction plan via ONE scheduler-coalesced probe.

        Same contract as :func:`repro.core.extract.plan_extraction`, but
        the location probe goes through the admission queue, so concurrent
        planners share probe batches.
        """
        hashed = self.key_mode == "hashed_key"
        keys = [hashed_key(t, key_bits) if hashed else t for t in targets]
        return assemble_plan(targets, keys, self.lookup(keys), sort_offsets)

    # -- similarity surface (scheduler-coalesced Tanimoto) --------------------

    def _similar_probe_fn(self, rows: Sequence[np.ndarray]):
        """Batched probe for the similarity scheduler: stack the cohort's
        query rows into one plane and scan every shard once for all of
        them at the service-wide ``similar_top_k``.  Returns the
        fault-tolerant quad — the per-query degraded flag is a fourth
        row-aligned column, so it scatters back with each request."""
        fps = np.stack([np.asarray(r, dtype=np.uint32) for r in rows])
        return self.router.similar_batch_ex(fps, self.config.similar_top_k)

    def _similarity_batcher(self) -> MicroBatcher:
        b = self._similar_batcher
        if b is None:
            if self.router.fingerprint_bits is None:
                raise ValueError(
                    "store has no fingerprint plane — republish with "
                    "save_sharded(fingerprint_bits=...) to enable "
                    "similarity queries"
                )
            with self._similar_init_lock:
                b = self._similar_batcher
                if b is None:
                    b = MicroBatcher(
                        self._similar_probe_fn,
                        max_batch=self.config.max_batch,
                        max_wait_ms=self.config.max_wait_ms,
                    )
                    self._similar_batcher = b
        return b

    def similar_async(
        self, fps: np.ndarray, k: Optional[int] = None
    ) -> "Future[SimilarResult]":
        """Submit a similarity batch; resolves like :meth:`similar`.

        The probe rides its own :class:`MicroBatcher` admission queue at
        the fixed ``config.similar_top_k``, so concurrent small batches
        coalesce into one shard scan exactly like lookups do; the
        requested ``k`` is sliced off the shared result (top-k selection
        is prefix-stable under the deterministic tie contract).  ``k``
        larger than ``similar_top_k`` probes alone, uncoalesced.
        """
        k = self.config.similar_top_k if k is None else int(k)
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        fps = np.ascontiguousarray(fps, dtype=np.uint32)
        if fps.ndim == 1:
            fps = fps[None, :]
        if fps.shape[0] == 0:
            out: "Future[SimilarResult]" = Future()
            out.set_result(SimilarResult(
                np.zeros((0, k), dtype=np.float32),
                np.zeros((0, k), dtype=np.int32),
                np.zeros((0, k), dtype=np.int64),
                np.zeros(0, dtype=bool),
            ))
            return out
        if k > self.config.similar_top_k:
            out: "Future[SimilarResult]" = Future()
            if not out.set_running_or_notify_cancel():  # pragma: no cover
                return out
            try:
                out.set_result(self.router.similar_batch_ex(fps, k))
            except BaseException as e:  # noqa: BLE001 — delivered to caller
                out.set_exception(e)
            return out
        probe = self._similarity_batcher().submit(list(fps))
        out = Future()

        def _slice(pf: Future) -> None:
            if not out.set_running_or_notify_cancel():  # pragma: no cover
                return
            try:
                scores, fids, offs, deg = pf.result()
                out.set_result(SimilarResult(
                    scores[:, :k], fids[:, :k], offs[:, :k], deg
                ))
            except BaseException as e:  # noqa: BLE001
                out.set_exception(e)

        probe.add_done_callback(_slice)
        return out

    def similar(
        self,
        fps: np.ndarray,
        k: Optional[int] = None,
        timeout: Optional[float] = None,
    ) -> SimilarResult:
        """Blocking batched Tanimoto top-k through the admission queue.

        ``fps`` is ``(Q, W)`` (or a single ``(W,)`` row) of packed uint32
        query fingerprints (:func:`repro.core.fingerprint.fold_fingerprint`);
        returns ``(scores (Q, k) f32, file_ids (Q, k) i32, offsets (Q, k)
        i64, degraded (Q,) bool)`` ordered by ``(score desc, file_id asc,
        offset asc)`` with ``-1`` pads — the
        :meth:`IndexStore.similar_batch` contract plus the degraded-mode
        flag (True when the top-k was merged from surviving shards only),
        coalesced across concurrent callers.
        """
        return self.similar_async(fps, k).result(timeout=timeout)

    # -- record surface (reader engine + shared cache) -----------------------

    def fetch(
        self,
        targets: Sequence[str],
        verify: Optional[bool] = None,
        key_bits: int = 64,
        workers: Optional[int] = None,
    ) -> ExtractionResult:
        """Algorithm 3 through the service: plan, read, verify, account.

        Byte-identical to a direct serial ``extract`` — records in target
        order, ``missing``/``mismatches`` identical — with the plan probe
        coalesced and the reads riding the shared cache + read pool.
        """
        res = extract(
            self.records,
            None,
            targets,
            verify=self.config.verify if verify is None else verify,
            key_bits=key_bits,
            workers=workers,
            coalesce_gap=self.config.coalesce_gap,
            span_guess=self.config.span_guess,
            depth=self.config.reader_depth,
            service=self,
        )
        self._merge_read(res)
        return res

    def fetch_async(
        self,
        targets: Sequence[str],
        verify: Optional[bool] = None,
        key_bits: int = 64,
        workers: Optional[int] = None,
    ) -> "Future[ExtractionResult]":
        """Non-blocking :meth:`fetch`: async end-to-end through the stack.

        The plan probe is submitted to the :class:`MicroBatcher` admission
        queue without waiting (it coalesces with every other in-flight
        probe); when the batch resolves, the span-engine read phase runs
        on the service's pools and the returned future resolves to the
        same :class:`ExtractionResult` a blocking :meth:`fetch` returns.
        The caller's thread never blocks — submit N fetches, then gather.
        """
        do_verify = self.config.verify if verify is None else verify
        hashed = self.key_mode == "hashed_key"
        targets = list(targets)
        keys = [hashed_key(t, key_bits) if hashed else t for t in targets]
        t0 = time.perf_counter()
        probe = self.batcher.submit(keys)
        out: "Future[ExtractionResult]" = Future()

        def read_phase(pf: Future) -> None:
            if not out.set_running_or_notify_cancel():  # pragma: no cover
                return
            try:
                fids, offs, hit, _deg = pf.result()
                locs = self._locations(fids, offs, hit)
                out.set_result(self._read_plan(
                    targets, keys, locs, do_verify, workers,
                    plan_seconds=time.perf_counter() - t0,
                ))
            except BaseException as e:
                out.set_exception(e)

        # hop off the scheduler's flush thread before doing blocking I/O
        probe.add_done_callback(
            lambda pf: self._orchestrator.submit(read_phase, pf)
        )
        return out

    async def fetch_aio(
        self,
        targets: Sequence[str],
        verify: Optional[bool] = None,
        key_bits: int = 64,
        workers: Optional[int] = None,
    ) -> ExtractionResult:
        """asyncio-native :meth:`fetch` — identical result object.

        Unlike :meth:`fetch_async` (which parks the whole request on the
        orchestrator pool), this coroutine awaits the coalesced probe
        with no thread parked anywhere (``asyncio.wrap_future`` bridges
        the MicroBatcher future to the event loop); only the span-read
        phase — actual blocking syscalls — occupies an executor slot,
        and the coroutine awaits that too, so the event loop stays free
        throughout.  Submit many of these concurrently and the probes
        coalesce into shared batches exactly like ``fetch_async``'s.
        """
        do_verify = self.config.verify if verify is None else verify
        hashed = self.key_mode == "hashed_key"
        targets = list(targets)
        keys = [hashed_key(t, key_bits) if hashed else t for t in targets]
        t0 = time.perf_counter()
        fids, offs, hit, _deg = await asyncio.wrap_future(
            self.batcher.submit(keys)
        )
        locs = self._locations(fids, offs, hit)
        plan_seconds = time.perf_counter() - t0
        loop = asyncio.get_running_loop()
        return await loop.run_in_executor(
            self._orchestrator,
            lambda: self._read_plan(
                targets, keys, locs, do_verify, workers,
                plan_seconds=plan_seconds,
            ),
        )

    def _locations(
        self, fids, offs, hit
    ) -> List[Optional[Tuple[str, int]]]:
        names = self.router.file_names
        return [
            (names[fids[i]], int(offs[i])) if hit[i] else None
            for i in range(len(hit))
        ]

    def _read_plan(
        self,
        targets: List[str],
        keys: List[str],
        locs: List[Optional[Tuple[str, int]]],
        do_verify: bool,
        workers: Optional[int],
        plan_seconds: float,
    ) -> ExtractionResult:
        """The blocking span-read phase shared by fetch_async/fetch_aio."""
        plan, missing = assemble_plan(targets, keys, locs)
        res = ExtractionResult()
        res.missing = missing
        res.plan_seconds = plan_seconds
        t1 = time.perf_counter()
        stats = ReadStats()
        found: Dict[str, str] = {}
        for ev in stream_plan(
            self.records,
            plan,
            verify=do_verify,
            workers=(self.config.read_workers
                     if workers is None else workers),
            coalesce_gap=self.config.coalesce_gap,
            span_guess=self.config.span_guess,
            cache=self.cache,
            stats=stats,
            executor=self.read_executor,
            backend=self.read_backend,
            depth=self.config.reader_depth,
            verifier=self.verifier,
        ):
            res.seeks += 1
            if ev.ok:
                found[ev.full_id] = ev.text
            else:
                res.mismatches.append(Mismatch(
                    ev.full_id, ev.found_id, ev.file, ev.offset, ev.key
                ))
        res.records = {t: found[t] for t in targets if t in found}
        res.mismatches.sort(
            key=lambda m: (m.file, m.offset, m.expected_id)
        )
        res.files_opened = stats.files_opened
        res.bytes_read = stats.bytes_read
        res.spans_read = stats.spans_read
        res.cache_hits = stats.cache_hits
        res.read_backend = stats.backend
        res.inflight_peak = stats.inflight_peak
        res.verify_batches = stats.verify_batches
        res.verify_records = stats.verify_records
        res.verify_batch_max = stats.verify_batch_max
        res.read_seconds = time.perf_counter() - t1
        self._merge_read(res)
        return res

    def fetch_stream(
        self,
        targets: Sequence[str],
        verify: Optional[bool] = None,
        key_bits: int = 64,
        result: Optional[ExtractionResult] = None,
    ) -> Iterator[Tuple[str, str]]:
        """Streaming fetch: yield ``(full_id, record)`` as each verifies."""
        own = result if result is not None else ExtractionResult()
        try:
            yield from extract_iter(
                self.records,
                None,
                targets,
                verify=self.config.verify if verify is None else verify,
                key_bits=key_bits,
                coalesce_gap=self.config.coalesce_gap,
                span_guess=self.config.span_guess,
                depth=self.config.reader_depth,
                result=own,
                service=self,
            )
        finally:
            self._merge_read(own)

    def _merge_read(self, res: ExtractionResult) -> None:
        delta = ReadStats(
            files_opened=res.files_opened,
            spans_read=res.spans_read,
            bytes_read=res.bytes_read,
            cache_hits=res.cache_hits,
            records=res.seeks,
            backend=res.read_backend,
            inflight_peak=res.inflight_peak,
            verify_batches=res.verify_batches,
            verify_records=res.verify_records,
            verify_batch_max=res.verify_batch_max,
        )
        with self._read_stats_lock:
            self.read_stats.merge(delta)

    # -- observability -------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        """One merged view across router, scheduler, reader, and cache."""
        qs = self.router.query_stats()
        rs = self.router.stats
        ss = self.batcher.stats
        cs = self.cache.stats
        lat = self.batcher.latency_ms()
        return {
            "router": {
                "replicas": self.router.replicas,
                "n_shards": self.router.n_shards,
                "batches": rs.batches,
                "keys": rs.keys,
                "scattered": rs.scattered,
                "inline": rs.inline,
                "shard_probes": rs.shard_probes,
                "keys_per_shard": dict(sorted(rs.keys_per_shard.items())),
            },
            "fault": {
                "hedges_fired": rs.hedges_fired,
                "hedge_wins": rs.hedge_wins,
                "retries": rs.retries,
                "probes_failed": rs.probes_failed,
                "degraded_batches": rs.degraded_batches,
                "degraded_keys": rs.degraded_keys,
                "degraded_similar": rs.degraded_similar,
                "errors_per_shard": {
                    s: dict(errs)
                    for s, errs in sorted(rs.errors_per_shard.items())
                },
            },
            "health": self.router.health.snapshot(),
            "store": {
                "queries": qs.queries,
                "hits": qs.hits,
                "bloom_rejects": qs.bloom_rejects,
                "bloom_false_positives": qs.bloom_false_positives,
                "digest_probes": qs.digest_probes,
                "verify_collisions": qs.verify_collisions,
                "shards_touched": len(qs.shards_touched),
            },
            "similarity": {
                "fingerprint_bits": self.router.fingerprint_bits,
                "batches": rs.similar_batches,
                "queries": rs.similar_queries,
                "scattered": rs.similar_scattered,
                "inline": rs.similar_inline,
                "shard_probes": rs.similar_shard_probes,
                "fp_rows_scanned": qs.fp_rows_scanned,
                "scheduler": (
                    {
                        "requests": sim.stats.requests,
                        "batches": sim.stats.batches,
                        "mean_batch_keys": sim.stats.mean_batch_keys,
                        "coalesced_batches": sim.stats.coalesced_batches,
                        "coalesced_requests": sim.stats.coalesced_requests,
                        "latency_ms": sim.latency_ms(),
                    }
                    if (sim := self._similar_batcher) is not None
                    else None
                ),
            },
            "scheduler": {
                "requests": ss.requests,
                "keys": ss.keys,
                "batches": ss.batches,
                "mean_batch_keys": ss.mean_batch_keys,
                "batch_keys_max": ss.batch_keys_max,
                "full_flushes": ss.full_flushes,
                "cohort_flushes": ss.cohort_flushes,
                "deadline_flushes": ss.deadline_flushes,
                "immediate_flushes": ss.immediate_flushes,
                "coalesced_batches": ss.coalesced_batches,
                "coalesced_requests": ss.coalesced_requests,
                "cancelled": ss.cancelled,
                "leader_deaths": ss.leader_deaths,
                "latency_ms": lat,
            },
            "cache": {
                "entries": len(self.cache),
                "probation": self.cache.probation_len,
                "protected": self.cache.protected_len,
                "bytes": self.cache.cached_bytes,
                "hits": cs.hits,
                "misses": cs.misses,
                "hit_rate": cs.hit_rate,
                "evictions": cs.evictions,
                "probation_hits": cs.probation_hits,
                "promotions": cs.promotions,
            },
            "read": {
                "backend": self.read_stats.backend or self.read_backend.name,
                "files_opened": self.read_stats.files_opened,
                "spans_read": self.read_stats.spans_read,
                "bytes_read": self.read_stats.bytes_read,
                "cache_hits": self.read_stats.cache_hits,
                "records": self.read_stats.records,
                "inflight_peak": self.read_stats.inflight_peak,
                "verify_batches": self.read_stats.verify_batches,
                "verify_records": self.read_stats.verify_records,
                "verify_batch_max": self.read_stats.verify_batch_max,
            },
        }

    # -- lifecycle -----------------------------------------------------------

    def close(self, drain: bool = False) -> None:
        """Stop the scheduler (cancelling queued lookups unless ``drain``),
        the read pool, and — if this service built it — the router."""
        if self._closed:
            return
        self._closed = True
        self.batcher.close(drain=drain)
        if self._similar_batcher is not None:
            self._similar_batcher.close(drain=drain)
        self._orchestrator.shutdown(wait=drain, cancel_futures=not drain)
        self.read_executor.shutdown(wait=False, cancel_futures=True)
        self.read_backend.close()
        if self._owns_router:
            self.router.close()

    def __enter__(self) -> "QueryService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
