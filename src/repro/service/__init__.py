"""repro.service — the async scatter-gather query service.

The serving-grade face of the byte-offset index: many small concurrent
lookup/extract requests are re-coalesced into the large batches the
sharded :class:`~repro.core.store.IndexStore` and the pipelined
:mod:`~repro.core.reader` engine are built for.

Scatter-gather shard fan-out      → :mod:`repro.service.router`
Continuous micro-batching queue   → :mod:`repro.service.scheduler`
Typed facade (lookup/fetch/stats) → :mod:`repro.service.api`
Closed-loop load generator        → :mod:`repro.service.loadgen`
"""

from .api import QueryService, ServiceConfig
from .loadgen import LoadReport, run_closed_loop
from .router import RouterStats, ShardRouter
from .scheduler import MicroBatcher, SchedulerStats

__all__ = [
    "LoadReport",
    "MicroBatcher",
    "QueryService",
    "RouterStats",
    "SchedulerStats",
    "ServiceConfig",
    "ShardRouter",
    "run_closed_loop",
]
