"""repro.service — the async scatter-gather query service.

The serving-grade face of the byte-offset index: many small concurrent
lookup/extract requests are re-coalesced into the large batches the
sharded :class:`~repro.core.store.IndexStore` and the pipelined
:mod:`~repro.core.reader` engine are built for — and served
fault-tolerantly: replica endpoints sit behind a transport seam with
health tracking, per-probe deadlines, hedged requests, and degraded-mode
partial results when a shard range is unreachable.

Scatter-gather shard fan-out      → :mod:`repro.service.router`
Replica endpoints + fault inject  → :mod:`repro.service.transport`
Replica/shard health tracking     → :mod:`repro.service.health`
Continuous micro-batching queue   → :mod:`repro.service.scheduler`
Typed facade (lookup/fetch/stats) → :mod:`repro.service.api`
Closed-loop load generator        → :mod:`repro.service.loadgen`
"""

from .api import QueryService, ServiceConfig
from .health import DEAD, DEGRADED, REPLICA_WIDE, UP, HealthTracker
from .loadgen import LoadReport, run_closed_loop
from .router import (
    LookupBatchResult,
    RouterStats,
    ShardRouter,
    SimilarResult,
)
from .scheduler import MicroBatcher, SchedulerStats
from .transport import (
    FaultInjectingTransport,
    FlakyError,
    LocalTransport,
    ProbeTimeoutError,
    ShardDownError,
    ShardTransport,
    TransportError,
)

__all__ = [
    "DEAD",
    "DEGRADED",
    "FaultInjectingTransport",
    "FlakyError",
    "HealthTracker",
    "LoadReport",
    "LocalTransport",
    "LookupBatchResult",
    "MicroBatcher",
    "ProbeTimeoutError",
    "QueryService",
    "REPLICA_WIDE",
    "RouterStats",
    "SchedulerStats",
    "ServiceConfig",
    "ShardDownError",
    "ShardRouter",
    "ShardTransport",
    "SimilarResult",
    "TransportError",
    "UP",
    "run_closed_loop",
]
