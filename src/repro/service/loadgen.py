"""Closed-loop load generator for the query service benchmarks.

``clients`` threads each run a closed loop — pick keys, issue one
request, wait for the result, repeat — against any request function.
Closed-loop is the honest shape for the scheduler comparison: a client
cannot have two requests outstanding, so the service's throughput
advantage must come entirely from *coalescing across clients*, never
from one client secretly batching its own stream.

The same generator drives both sides of the comparison:

* **naive**  — ``request_fn`` calls ``IndexStore.lookup_batch`` directly,
  one per-request probe per call (the pre-service architecture);
* **service** — ``request_fn`` calls ``QueryService.lookup``, which rides
  the continuous micro-batching admission queue.

Under chaos the report separates three outcomes that a bare error count
conflates: **failed** requests raised to the client (the fault-tolerant
service should keep this at zero), **degraded** requests that completed
with partial results (``classify`` inspects each result — e.g. "any key
flagged in the batch's degraded mask"), and served-clean requests.  A
``counters_fn`` snapshot (taken at the barrier and after the last client
exits) attributes service-side fault counters — hedges fired, retries,
degraded keys — to exactly this run's window.

Used by ``benchmarks/service_load.py`` (BENCH_service.json) and the
``repro.launch.serve_index`` launcher's ``--load`` / ``--chaos`` modes.
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

__all__ = ["LoadReport", "run_closed_loop"]


@dataclass
class LoadReport:
    """Merged result of one closed-loop run."""

    clients: int
    seconds: float                 # measured wall window
    requests: int
    keys: int
    errors: int                    # requests that raised to the client
    degraded: int = 0              # requests served with partial results
    # service-side counter deltas over the run window (counters_fn)
    counters: Dict[str, float] = field(default_factory=dict)
    latencies_ms: List[float] = field(repr=False, default_factory=list)

    @property
    def failed(self) -> int:
        """Alias: requests that raised (clients saw an exception)."""
        return self.errors

    @property
    def lookups_per_sec(self) -> float:
        return self.keys / self.seconds if self.seconds > 0 else 0.0

    @property
    def requests_per_sec(self) -> float:
        return self.requests / self.seconds if self.seconds > 0 else 0.0

    def latency_ms(self, p: float) -> float:
        return float(np.percentile(self.latencies_ms, p)) if self.latencies_ms else 0.0

    @property
    def p50_ms(self) -> float:
        return self.latency_ms(50)

    @property
    def p99_ms(self) -> float:
        return self.latency_ms(99)

    def summary(self) -> str:
        out = (
            f"{self.lookups_per_sec:,.0f} lookups/s over {self.clients} "
            f"clients ({self.requests} requests, p50 {self.p50_ms:.2f} ms, "
            f"p99 {self.p99_ms:.2f} ms)"
        )
        if self.errors or self.degraded:
            out += f" [failed {self.errors}, degraded {self.degraded}]"
        hedges = self.counters.get("hedges_fired", 0)
        retries = self.counters.get("retries", 0)
        if hedges or retries:
            out += f" [hedges {hedges}, retries {retries}]"
        return out


def run_closed_loop(
    request_fn: Callable[[List[str]], object],
    key_pool: Sequence[str],
    clients: int = 8,
    duration_s: float = 2.0,
    keys_per_request: int = 1,
    seed: int = 0,
    classify: Optional[Callable[[object], bool]] = None,
    counters_fn: Optional[Callable[[], Dict[str, float]]] = None,
) -> LoadReport:
    """Drive ``request_fn`` from ``clients`` closed-loop threads.

    Each client draws ``keys_per_request`` random keys from ``key_pool``
    per request (seeded per client — runs are reproducible).  All clients
    start together on a barrier; the measured window is the barrier
    release to the last client's exit, so ramp-up isn't credited.

    ``classify(result) -> bool`` (optional) marks a completed request as
    degraded — it still counts toward throughput and latency, since the
    client *was* served, but the report separates it.  ``counters_fn()``
    (optional) returns a cumulative counter dict; the report carries the
    delta across the run window.
    """
    if clients < 1:
        raise ValueError(f"clients must be >= 1, got {clients}")
    if not key_pool:
        raise ValueError("key_pool is empty")
    key_pool = list(key_pool)
    barrier = threading.Barrier(clients + 1)
    stop = threading.Event()
    lats: List[List[float]] = [[] for _ in range(clients)]
    counts = [0] * clients
    errors = [0] * clients
    degraded = [0] * clients

    def client(ci: int) -> None:
        rng = random.Random(seed * 7919 + ci)
        my_lats = lats[ci]
        barrier.wait()
        while not stop.is_set():
            keys = [
                key_pool[rng.randrange(len(key_pool))]
                for _ in range(keys_per_request)
            ]
            t0 = time.perf_counter()
            try:
                result = request_fn(keys)
            except Exception:
                errors[ci] += 1
                continue
            my_lats.append((time.perf_counter() - t0) * 1e3)
            counts[ci] += 1
            if classify is not None and classify(result):
                degraded[ci] += 1

    threads = [
        threading.Thread(target=client, args=(i,), daemon=True)
        for i in range(clients)
    ]
    for t in threads:
        t.start()
    before = dict(counters_fn()) if counters_fn is not None else {}
    barrier.wait()
    t_start = time.perf_counter()
    time.sleep(duration_s)
    stop.set()
    for t in threads:
        t.join(timeout=30)
    elapsed = time.perf_counter() - t_start
    after = dict(counters_fn()) if counters_fn is not None else {}

    merged: List[float] = []
    for ls in lats:
        merged.extend(ls)
    n_req = sum(counts)
    return LoadReport(
        clients=clients,
        seconds=elapsed,
        requests=n_req,
        keys=n_req * keys_per_request,
        errors=sum(errors),
        degraded=sum(degraded),
        counters={k: after[k] - before.get(k, 0) for k in after},
        latencies_ms=merged,
    )
