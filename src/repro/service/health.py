"""HealthTracker — replica/shard health from observed probe outcomes.

The serving tier's failure detector.  Every router probe lands here as a
success (with its latency) or a failure (with its taxonomy kind), keyed
by the **failure domain** ``(replica, shard)`` — one endpoint can lose a
single shard while serving the rest, so health is tracked at the
granularity faults actually occur at (``shard == REPLICA_WIDE`` for
whole-batch probes).

State machine per domain::

    up ──failure──► degraded ──N consecutive failures──► dead
    ▲                   │                                  │
    └────success────────┘          backoff-paced probation probe
                                            │ success
    up ◄────────────────────────────────────┘  (revival recorded)

Dead domains are excluded from the router's candidate lists until their
exponential backoff (:class:`~repro.runtime.fault.BackoffPolicy`) has
elapsed; then exactly one probation probe is handed out per backoff
window — a success revives the domain (recovery time is recorded), a
failure widens the window.  The tracker also keeps a bounded rolling
latency window per domain, whose p95 is what arms the router's hedged
requests.

It rides the :mod:`repro.runtime.fault` machinery two ways: the backoff
schedule is a :class:`BackoffPolicy`, and — given a ``rundir`` — every
replica's probe successes renew a :class:`Heartbeat` file so the
existing coordinator-side :class:`FailureDetector` (the exact code a
multi-host deployment watches) sees the serving tier's liveness;
:meth:`snapshot` reports its verdict alongside the in-process states.
"""

from __future__ import annotations

import threading
import time
from collections import Counter, deque
from pathlib import Path
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.fault import BackoffPolicy, FailureDetector, Heartbeat

__all__ = ["HealthTracker", "REPLICA_WIDE", "UP", "DEGRADED", "DEAD"]

UP = "up"
DEGRADED = "degraded"
DEAD = "dead"

#: pseudo-shard for whole-batch (endpoint-wide) probes
REPLICA_WIDE = -1

_LATENCY_WINDOW = 128
_HEARTBEAT_TIMEOUT_S = 5.0


class _Domain:
    __slots__ = (
        "state", "consec_failures", "latencies", "taxonomy", "successes",
        "dead_since", "next_probe_at", "backoff_attempt", "revivals",
        "last_recovery_s",
    )

    def __init__(self):
        self.state = UP
        self.consec_failures = 0
        self.latencies: Deque[float] = deque(maxlen=_LATENCY_WINDOW)
        self.taxonomy: Counter = Counter()
        self.successes = 0
        self.dead_since = 0.0
        self.next_probe_at = 0.0
        self.backoff_attempt = 0
        self.revivals = 0
        self.last_recovery_s = 0.0


class HealthTracker:
    """Track per-``(replica, shard)`` probe health for the ShardRouter."""

    def __init__(
        self,
        n_replicas: int,
        fail_threshold: int = 3,
        backoff: Optional[BackoffPolicy] = None,
        rundir: Optional[Path] = None,
        heartbeat_interval_s: float = 0.5,
        clock=time.monotonic,
    ):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        if fail_threshold < 1:
            raise ValueError(
                f"fail_threshold must be >= 1, got {fail_threshold}"
            )
        self.n_replicas = n_replicas
        self.fail_threshold = fail_threshold
        self.backoff = backoff or BackoffPolicy()
        self.clock = clock
        self._lock = threading.Lock()
        self._domains: Dict[Tuple[int, int], _Domain] = {}
        # optional on-disk liveness: one Heartbeat file per replica, beat
        # on probe success (throttled), watched by the stock
        # coordinator-side FailureDetector
        self._heartbeats: Optional[List[Heartbeat]] = None
        self._detector: Optional[FailureDetector] = None
        self._last_beat = [0.0] * n_replicas
        self._beat_interval = heartbeat_interval_s
        if rundir is not None:
            rundir = Path(rundir)
            rundir.mkdir(parents=True, exist_ok=True)
            self._heartbeats = [
                Heartbeat(rundir, r) for r in range(n_replicas)
            ]
            self._detector = FailureDetector(
                rundir, n_replicas, timeout=_HEARTBEAT_TIMEOUT_S
            )

    def _domain(self, replica: int, shard: int) -> _Domain:
        d = self._domains.get((replica, shard))
        if d is None:
            d = _Domain()
            self._domains[(replica, shard)] = d
        return d

    # -- outcome ingestion ---------------------------------------------------

    def on_success(
        self, replica: int, shard: int, latency_s: float
    ) -> None:
        now = self.clock()
        with self._lock:
            d = self._domain(replica, shard)
            d.consec_failures = 0
            d.successes += 1
            d.latencies.append(latency_s)
            if d.state == DEAD:
                d.revivals += 1
                d.last_recovery_s = now - d.dead_since
            if d.state != UP:
                d.state = UP
                d.backoff_attempt = 0
                d.next_probe_at = 0.0
        if self._heartbeats is not None:
            wall = time.time()
            if wall - self._last_beat[replica] >= self._beat_interval:
                self._last_beat[replica] = wall
                self._heartbeats[replica].beat(step=0)

    def on_failure(self, replica: int, shard: int, kind: str) -> None:
        now = self.clock()
        with self._lock:
            d = self._domain(replica, shard)
            d.taxonomy[kind] += 1
            d.consec_failures += 1
            if d.state == DEAD:
                # failed probation probe: widen the backoff window
                d.backoff_attempt += 1
                d.next_probe_at = now + self.backoff.delay(d.backoff_attempt)
            elif d.consec_failures >= self.fail_threshold:
                d.state = DEAD
                d.dead_since = now
                d.backoff_attempt = 0
                d.next_probe_at = now + self.backoff.delay(0)
            else:
                d.state = DEGRADED

    # -- router queries ------------------------------------------------------

    def state(self, replica: int, shard: int) -> str:
        with self._lock:
            d = self._domains.get((replica, shard))
            return d.state if d is not None else UP

    def has_unhealthy(self) -> bool:
        """Any domain away from ``up``?  (The router's cheap "should I
        take the failure-domain path" check for non-chaotic transports.)"""
        with self._lock:
            return any(d.state != UP for d in self._domains.values())

    def candidates(self, shard: int) -> List[int]:
        """Replica order for one probe: up, then degraded, then dead
        domains whose backoff has elapsed (at most one probation probe is
        handed out per backoff window — the window is advanced here so a
        burst of concurrent batches can't stampede a reviving replica).
        An empty list means every replica is dead and inside its backoff
        window: fail fast, the caller reports the domain degraded."""
        now = self.clock()
        ups: List[int] = []
        degraded: List[int] = []
        probation: List[int] = []
        with self._lock:
            for r in range(self.n_replicas):
                d = self._domains.get((r, shard))
                if d is None or d.state == UP:
                    ups.append(r)
                elif d.state == DEGRADED:
                    degraded.append(r)
                elif now >= d.next_probe_at:
                    d.next_probe_at = now + self.backoff.delay(
                        d.backoff_attempt
                    )
                    probation.append(r)
        return ups + degraded + probation

    def p95_s(self, replica: int, shard: int) -> Optional[float]:
        """Rolling p95 probe latency of one domain (None until sampled)."""
        with self._lock:
            d = self._domains.get((replica, shard))
            if d is None or not d.latencies:
                return None
            lat = list(d.latencies)
        return float(np.percentile(lat, 95))

    # -- observability -------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            replica_state = []
            taxonomy: Counter = Counter()
            dead_domains = []
            revivals = 0
            last_recovery_s = 0.0
            for r in range(self.n_replicas):
                worst = UP
                for (rr, s), d in self._domains.items():
                    if rr != r:
                        continue
                    if d.state == DEAD:
                        worst = DEAD
                    elif d.state == DEGRADED and worst == UP:
                        worst = DEGRADED
                replica_state.append(worst)
            for (r, s), d in self._domains.items():
                taxonomy.update(d.taxonomy)
                revivals += d.revivals
                last_recovery_s = max(last_recovery_s, d.last_recovery_s)
                if d.state == DEAD:
                    dead_domains.append(
                        {"replica": r,
                         "shard": None if s == REPLICA_WIDE else s}
                    )
        out: Dict[str, object] = {
            "replica_state": replica_state,
            "dead_domains": dead_domains,
            "failures": dict(taxonomy),
            "revivals": revivals,
            "last_recovery_s": last_recovery_s,
        }
        if self._detector is not None:
            out["heartbeat_alive"] = self._detector.alive()
        return out
