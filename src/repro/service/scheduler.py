"""Continuous micro-batching: coalesce concurrent lookups into big probes.

The paper's 740x win comes from turning per-record work into one batched
O(N+M) pass — but a serving deployment receives that work as thousands of
*small concurrent* requests, each a handful of keys.  Paid per request,
the batched machinery degenerates: a single-key ``lookup_batch`` costs
roughly as much as a 64-key one (digest setup, Bloom probe, shard
binary-search are all dominated by fixed per-call overhead), and under
the GIL eight client threads probing independently run *slower* than one
thread probing alone — every tiny numpy call is a potential forced GIL
handoff, and per-request probing maximizes how many of those each key
pays.  The :class:`MicroBatcher` re-coalesces: callers ``submit()`` and
get a future; an admission queue forms batches and ONE thread executes
each batch as a single probe, so the per-call fixed costs (and the GIL
handoffs) amortize across every waiting caller.

**Leader-combining execution.**  There is no flusher thread on the hot
path — at micro-batch scale, waking a parked thread costs hundreds of
microseconds, which is the whole latency budget.  Instead the submitting
thread that finds no flush in progress becomes the *leader*: it drains
the queue, executes the probe, scatters results, and keeps draining
while work remains (arrivals during one probe form the next batch —
continuous batching).  A lone caller therefore pays no coordination
latency at all: it leads immediately, probes its own batch of one, and
leaves.

**Batch formation by leadership transfer.**  Under concurrency the batch
is held open briefly so the cohort that is re-arriving (callers the last
probe just answered, plus new ones) can join — but nobody *waits* for
it.  The leader **arms** an admission target (an EMA of recent batch
size, capped by ``max_batch``) with the oldest request's
``max_wait_ms`` deadline, then simply releases leadership; the submitter
whose request completes the cohort inherits leadership *on its own
thread* and probes immediately — a flush with zero wake latency.  A
watchdog thread enforces only the deadline of a cohort that never
completes (the rare path, so its timed sleeps are off the hot path).

Flush taxonomy (counted in :class:`SchedulerStats`):

* **full** — queued keys reached ``max_batch``;
* **cohort** — the armed admission target re-formed;
* **deadline** — the oldest request hit ``max_wait_ms`` mid-cohort;
* **immediate** — no recent coalescing (single-caller regime): no hold;
* **drain** — flushed by ``close(drain=True)``.

Requests are admitted whole (a request's keys never split across
batches), results scatter back as zero-copy row slices of the batch
arrays, and per-request latency (queue wait + total) is accounted in a
bounded window for the service's p50/p99 rows.

**Leader-death containment.**  Probes run on client threads, so a probe
that raises tears down a *client*, not a service worker — the batcher
must contain that.  A failing probe's exception is delivered to every
future of its batch before the leader unwinds (``SystemExit`` /
``KeyboardInterrupt`` re-raise afterwards — shutdown intent is not
swallowed); requests that queued behind the dying leader are rescued by
the watchdog's periodic sweep (any pending, un-armed queue with no live
leader gets led); and ``close(drain=False)`` waits at most
``close_grace_s`` for a wedged leader instead of forever, delivering a
``RuntimeError`` to the in-flight cohort if its leader thread is found
dead.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Callable, Deque, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["BatchResult", "MicroBatcher", "SchedulerStats"]

DEFAULT_MAX_BATCH = 512
DEFAULT_MAX_WAIT_MS = 1.0
# Admission target: the EMA of recent batch size, rounded.  Firing at the
# full estimate (not a fraction) matters because the firing submitter
# probes IMMEDIATELY — there is no wake latency for stragglers to hide
# in, so an undershot target locks in smaller and smaller batches.
_COHORT_FRACTION = 1.0
_EMA_ALPHA = 0.3
# Bounded latency window (requests) for percentile accounting.
_LATENCY_WINDOW = 8192
# Watchdog sweep period: how long an orphaned cohort (its would-be leader
# died before draining) waits for rescue, worst case.
_SWEEP_INTERVAL_S = 0.1
DEFAULT_CLOSE_GRACE_S = 5.0

# A probe result is any tuple of row-sliceable arrays — the classic
# (file_ids, offsets, hit) triple, or the fault-tolerant quad that adds
# the degraded mask.  The batcher slices every column per request.
BatchResult = Tuple[np.ndarray, ...]


@dataclass
class SchedulerStats:
    """Cumulative admission/flush counters."""

    requests: int = 0
    keys: int = 0
    batches: int = 0            # probe executions
    keys_flushed: int = 0       # keys actually probed (excludes cancelled)
    full_flushes: int = 0       # flushed because keys >= max_batch
    cohort_flushes: int = 0     # flushed because the armed target formed
    deadline_flushes: int = 0   # flushed because the oldest hit max_wait
    immediate_flushes: int = 0  # flushed with no hold (single-caller regime)
    drain_flushes: int = 0      # flushed during close(drain=True)
    coalesced_batches: int = 0  # batches that merged >= 2 requests
    coalesced_requests: int = 0 # requests that shared their batch
    cancelled: int = 0          # requests cancelled before probing
    leader_deaths: int = 0      # in-flight cohorts whose leader thread died
    batch_keys_max: int = 0

    @property
    def mean_batch_keys(self) -> float:
        return self.keys_flushed / self.batches if self.batches else 0.0


class _Request:
    __slots__ = ("keys", "future", "t_submit", "t_flush")

    def __init__(self, keys: List[str]):
        self.keys = keys
        self.future: "Future[BatchResult]" = Future()
        self.t_submit = time.monotonic()
        self.t_flush = 0.0


class MicroBatcher:
    """Admission queue + leader-combining flusher over a batched ``probe_fn``.

    ``probe_fn(keys) -> tuple of row-aligned arrays`` is the batched
    backend — the classic ``(file_ids, offsets, hit_mask)`` triple of a
    store, or a :class:`~repro.service.router.ShardRouter`'s
    fault-tolerant quad with the per-key ``degraded`` mask; the batcher
    slices whatever columns come back, so extra planes ride coalescing
    for free.  Each submitted request resolves to the row slice of the
    merged probe that corresponds to its keys (a NamedTuple result type
    is preserved).  Probes execute on submitting threads (the current
    leader); the only owned thread is the deadline watchdog.
    """

    def __init__(
        self,
        probe_fn: Callable[[List[str]], BatchResult],
        max_batch: int = DEFAULT_MAX_BATCH,
        max_wait_ms: float = DEFAULT_MAX_WAIT_MS,
        close_grace_s: float = DEFAULT_CLOSE_GRACE_S,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got {max_wait_ms}")
        self.probe_fn = probe_fn
        self.max_batch = int(max_batch)
        self.max_wait = max_wait_ms / 1e3
        self.close_grace_s = float(close_grace_s)
        self.stats = SchedulerStats()
        self.wait_seconds: Deque[float] = deque(maxlen=_LATENCY_WINDOW)
        self.total_seconds: Deque[float] = deque(maxlen=_LATENCY_WINDOW)
        self._lock = threading.Lock()    # queue, arming state, counters
        self._leader = threading.Lock()  # at most one probing thread
        self._pending: Deque[_Request] = deque()
        self._pending_keys = 0
        self._armed_target: Optional[int] = None  # cohort keys to admit
        self._armed_deadline = 0.0
        self._armed_evt = threading.Event()       # wakes the watchdog
        self._batch_ema = 1.0                     # recent flushed-keys estimate
        self._coalescing = False                  # last batch merged requests
        self._inflight: Optional[List[_Request]] = None  # leader's cohort
        self._leader_thread: Optional[threading.Thread] = None
        self._stop = False
        self._drain_on_stop = False
        self._watchdog = threading.Thread(
            target=self._watch_deadline, name="micro-batcher-watchdog",
            daemon=True,
        )
        self._watchdog.start()

    # -- client surface ------------------------------------------------------

    def submit(self, keys: Sequence[str]) -> "Future[BatchResult]":
        """Enqueue a request; the future resolves to this request's rows.

        The calling thread may transparently become the leader and execute
        the probe for everything queued.  Cancelling the returned future
        before its batch flushes withdraws the request (its keys are never
        probed).
        """
        req = _Request(list(keys))
        lead = True
        with self._lock:
            if self._stop:
                raise RuntimeError("scheduler is closed")
            self._pending.append(req)
            self._pending_keys += len(req.keys)
            self.stats.requests += 1
            self.stats.keys += len(req.keys)
            if self._armed_target is not None:
                if (
                    self._pending_keys >= self._armed_target
                    or req.t_submit >= self._armed_deadline
                ):
                    self._armed_target = None  # cohort complete: we fire it
                else:
                    lead = False  # batch still forming; don't break it up
        if lead:
            self._maybe_lead()
        return req.future

    def lookup(
        self, keys: Sequence[str], timeout: Optional[float] = None
    ) -> BatchResult:
        """Blocking convenience: ``submit(keys).result(timeout)``."""
        return self.submit(keys).result(timeout)

    # -- leader-combining flusher --------------------------------------------

    def _maybe_lead(self) -> None:
        # Non-blocking: if a leader exists it will see our request; if the
        # batch is armed (forming), the completing submitter leads.  The
        # re-check loop closes the race where the old leader drained to
        # empty and was releasing just as we enqueued.
        while (
            self._pending
            and not self._stop
            and self._armed_target is None
            and self._leader.acquire(blocking=False)
        ):
            try:
                self._drain()
            finally:
                self._leader.release()

    def _take_batch(self) -> List[_Request]:
        """Pop whole requests up to ``max_batch`` keys (caller holds lock)."""
        batch: List[_Request] = []
        taken = 0
        while self._pending:
            if taken and taken + len(self._pending[0].keys) > self.max_batch:
                break
            req = self._pending.popleft()
            self._pending_keys -= len(req.keys)
            # a cancelled future's caller is gone: drop without probing
            if not req.future.set_running_or_notify_cancel():
                self.stats.cancelled += 1
                continue
            batch.append(req)
            taken += len(req.keys)
        return batch

    def _drain(self) -> None:
        while True:
            with self._lock:
                if not self._pending:
                    self._armed_target = None
                    return
                if self._stop and not self._drain_on_stop:
                    return  # close() cancels what we leave behind
                nkeys = self._pending_keys
                if self._stop:
                    reason = "drain"
                elif nkeys >= self.max_batch:
                    reason = "full"
                elif self._coalescing and self.max_wait > 0:
                    target = min(
                        self.max_batch,
                        max(2, round(self._batch_ema * _COHORT_FRACTION)),
                    )
                    now = time.monotonic()
                    deadline = self._pending[0].t_submit + self.max_wait
                    if nkeys < target and now < deadline:
                        # arm and hand leadership to the cohort-completing
                        # submitter (or the watchdog at the deadline)
                        self._armed_target = target
                        self._armed_deadline = deadline
                        self._armed_evt.set()
                        return
                    reason = "cohort" if nkeys >= target else "deadline"
                else:
                    reason = "immediate"
                batch = self._take_batch()
            if batch:
                self._execute(batch, reason)

    def _watch_deadline(self) -> None:
        """Fire armed batches whose cohort never completed, and rescue
        cohorts orphaned by a dead leader (both rare paths)."""
        while True:
            armed = self._armed_evt.wait(timeout=_SWEEP_INTERVAL_S)
            if self._stop:
                return
            if not armed:
                # periodic sweep: pending requests with no armed target
                # normally mean a live leader is about to re-drain them —
                # but if that leader died mid-flush (poisoned probe), the
                # cohort behind it would wait forever.  Leading here is a
                # no-op when a real leader holds the lock.
                with self._lock:
                    orphaned = (
                        bool(self._pending) and self._armed_target is None
                    )
                if orphaned:
                    self._lead_shielded()
                continue
            with self._lock:
                if self._armed_target is None:
                    self._armed_evt.clear()
                    continue
                dt = self._armed_deadline - time.monotonic()
            if dt > 0:
                time.sleep(dt)
                continue  # re-check: the cohort may have fired meanwhile
            with self._lock:
                fire = (
                    self._armed_target is not None
                    and time.monotonic() >= self._armed_deadline
                )
                if fire:
                    self._armed_target = None
            if fire:
                self._lead_shielded()

    def _lead_shielded(self) -> None:
        """Lead from the watchdog: a poisoned probe (``SystemExit``, any
        exception) is already delivered to its futures by ``_execute`` —
        it must not take the rescue thread down with it."""
        try:
            self._maybe_lead()
        except BaseException:  # noqa: BLE001
            pass

    def _execute(self, batch: List[_Request], reason: str) -> None:
        t_flush = time.monotonic()
        if len(batch) == 1:
            all_keys = batch[0].keys
        else:
            all_keys = [k for req in batch for k in req.keys]
        for req in batch:
            req.t_flush = t_flush
        with self._lock:
            self._inflight = batch
            self._leader_thread = threading.current_thread()
        try:
            try:
                cols = self.probe_fn(all_keys)
            except BaseException as e:  # noqa: BLE001 — delivered first
                for req in batch:
                    if not req.future.done():
                        req.future.set_exception(e)
                if isinstance(e, (SystemExit, KeyboardInterrupt)):
                    raise  # shutdown intent: unwind the leader thread too
                return
            t_done = time.monotonic()
            # rebuild each request's rows with the probe's own result type
            # (a NamedTuple like LookupBatchResult survives the slicing)
            remake = getattr(type(cols), "_make", tuple)
            row = 0
            for req in batch:
                stop = row + len(req.keys)
                req.future.set_result(remake(c[row:stop] for c in cols))
                row = stop
        finally:
            with self._lock:
                self._inflight = None
                self._leader_thread = None
        # Batch stats are leader-only writes (serialized by the leader
        # lock); submit-side counters take the queue lock.
        st = self.stats
        st.batches += 1
        st.keys_flushed += len(all_keys)
        st.batch_keys_max = max(st.batch_keys_max, len(all_keys))
        if len(batch) >= 2:
            st.coalesced_batches += 1
            st.coalesced_requests += len(batch)
            # The admission estimate tracks DEMAND, not batch size: keys
            # probed plus keys that queued while we probed.  Tracking the
            # flushed size alone is a self-fulfilling target — the cohort
            # fires at it, so the estimate can never learn that more
            # concurrency was available.
            with self._lock:
                leftover = self._pending_keys
            demand = len(all_keys) + leftover
            self._batch_ema = (
                (1 - _EMA_ALPHA) * self._batch_ema + _EMA_ALPHA * demand
            )
            self._coalescing = True
        else:
            self._batch_ema = max(1.0, 0.9 * self._batch_ema)
            self._coalescing = False
        st_field = {
            "full": "full_flushes",
            "cohort": "cohort_flushes",
            "deadline": "deadline_flushes",
            "immediate": "immediate_flushes",
            "drain": "drain_flushes",
        }[reason]
        setattr(st, st_field, getattr(st, st_field) + 1)
        with self._lock:  # latency_ms snapshots these under the same lock
            for req in batch:
                self.wait_seconds.append(req.t_flush - req.t_submit)
                self.total_seconds.append(t_done - req.t_submit)

    # -- latency accounting --------------------------------------------------

    def latency_ms(self, percentiles: Sequence[float] = (50, 99)) -> dict:
        """Request-latency percentiles over the bounded window."""
        with self._lock:
            total = list(self.total_seconds)
            waits = list(self.wait_seconds)
        if not total:
            return {f"p{int(p)}": 0.0 for p in percentiles} | {"mean_wait": 0.0}
        out = {
            f"p{int(p)}": float(np.percentile(total, p)) * 1e3
            for p in percentiles
        }
        out["mean_wait"] = float(np.mean(waits)) * 1e3
        return out

    # -- shutdown ------------------------------------------------------------

    def close(self, drain: bool = False) -> None:
        """Stop admitting.  ``drain=False`` (default) cancels queued
        requests — their futures report ``cancelled()``; ``drain=True``
        probes what is queued first.  A healthy leader mid-probe finishes
        its current batch either way; a leader that never comes back is
        waited out for at most ``close_grace_s``, and if its thread is
        found dead the in-flight cohort's unresolved futures get a
        ``RuntimeError`` instead of hanging their callers forever."""
        with self._lock:
            if self._stop:
                return
            self._stop = True
            self._drain_on_stop = drain
            self._armed_target = None
            self._armed_evt.set()  # release the watchdog so it can exit
        if drain:
            while self._pending:
                with self._leader:
                    self._drain()
            self._watchdog.join(timeout=10)
            return
        # Cancel queued requests first, under the queue lock — NOT after
        # waiting for the leader.  A live leader popping concurrently
        # skips cancelled futures (set_running_or_notify_cancel), so this
        # cannot race a take; and a wedged or dead leader must not be
        # able to block shutdown while callers pile up behind it.
        with self._lock:
            for req in self._pending:
                if req.future.cancel():
                    self.stats.cancelled += 1
            self._pending.clear()
            self._pending_keys = 0
        if self._leader.acquire(timeout=self.close_grace_s):
            self._leader.release()
        else:
            # grace expired.  A wedged-but-alive probe keeps its futures
            # (they resolve if it ever returns); a dead leader thread
            # can never resolve its cohort — deliver the failure now.
            with self._lock:
                t = self._leader_thread
                batch = self._inflight
                if t is not None and not t.is_alive() and batch:
                    self.stats.leader_deaths += 1
                    err = RuntimeError(
                        "micro-batcher leader died mid-flush"
                    )
                    for req in batch:
                        if not req.future.done():
                            req.future.set_exception(err)
                    self._inflight = None
        self._watchdog.join(timeout=10)

    def __enter__(self) -> "MicroBatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
