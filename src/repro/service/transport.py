"""ShardTransport — the replica-endpoint seam under the ShardRouter.

PR 4 gave the router a checkout pool of in-process :class:`IndexStore`
replicas; multi-host serving needs the same scatter/gather/merge logic
to run against *remote* shard sets, and fault-tolerant serving needs a
place to observe (and, in tests, to inject) endpoint failures.  Both
want the identical seam: everything the router asks of a replica goes
through a :class:`ShardTransport` —

* :class:`LocalTransport` wraps one ``IndexStore`` handle (today's
  in-process deployment; replicas share pages through the OS cache);
* :class:`FaultInjectingTransport` wraps any transport with a seeded,
  deterministic fault plan — per-shard latency distributions, transient
  error rates, and hard "shard down" states, all settable live while
  traffic is flowing (the ``--chaos`` machinery and the chaos tests);
* the multi-host follow-up drops in an RPC stub with the same surface
  and the router, health tracker, hedging, and degraded-mode logic are
  unchanged.

Failure taxonomy is typed: :class:`ShardDownError` (hard down state),
:class:`ProbeTimeoutError` (deadline exceeded), :class:`FlakyError`
(injected transient).  All derive from :class:`TransportError`, which is
what the router's failover path catches — anything else escaping a
transport is a bug and propagates.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from random import Random
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.store import IndexStore, digest_u64, shard_of

__all__ = [
    "FaultInjectingTransport",
    "FlakyError",
    "LocalTransport",
    "ProbeTimeoutError",
    "ShardDownError",
    "ShardTransport",
    "TransportError",
    "error_kind",
]


class TransportError(RuntimeError):
    """Base of every expected (retriable / failover-able) probe failure."""

    def __init__(self, message: str, shard: Optional[int] = None):
        super().__init__(message)
        self.shard = shard


class ShardDownError(TransportError):
    """The endpoint's shard (or the whole endpoint) is hard-down."""


class ProbeTimeoutError(TransportError):
    """The probe exceeded its deadline at the endpoint."""


class FlakyError(TransportError):
    """Injected transient failure (a retry against a sibling should win)."""


def error_kind(exc: BaseException) -> str:
    """Map an exception to the health/stats taxonomy bucket."""
    if isinstance(exc, ShardDownError):
        return "down"
    if isinstance(exc, ProbeTimeoutError):
        return "timeout"
    return "error"


class ShardTransport:
    """One replica endpoint: the full probe surface the router needs.

    ``timeout_s`` on every probe is the caller's per-probe deadline.  An
    in-process transport finishes fast and may ignore it; a transport
    that *can* run long (fault injection today, RPC tomorrow) must raise
    :class:`ProbeTimeoutError` once the deadline is spent rather than
    blocking the router's probe slot indefinitely.
    """

    name: str = "transport"
    #: True when probes through this transport can fail or stall by
    #: design — the router then routes every batch through the per-shard
    #: failure-domain path instead of the whole-batch fast path.
    chaotic: bool = False

    # -- exact-key lookups ---------------------------------------------------

    def lookup_all(
        self,
        keys: Sequence[str],
        digests: np.ndarray,
        timeout_s: Optional[float] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Whole-batch probe (endpoint routes to its shards internally)."""
        raise NotImplementedError

    def lookup_shard(
        self,
        shard: int,
        keys: Sequence[str],
        digests: np.ndarray,
        timeout_s: Optional[float] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Probe one shard's key slice (the scatter unit = failure domain)."""
        raise NotImplementedError

    # -- similarity ----------------------------------------------------------

    def similar_shard(
        self,
        shard: int,
        fps: np.ndarray,
        k: int,
        q_counts: Optional[np.ndarray] = None,
        timeout_s: Optional[float] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        raise NotImplementedError

    def similar_all(
        self,
        fps: np.ndarray,
        k: int,
        timeout_s: Optional[float] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        raise NotImplementedError

    def close(self) -> None:  # endpoints owning sockets/files override
        pass


class LocalTransport(ShardTransport):
    """In-process endpoint over one :class:`IndexStore` replica handle."""

    def __init__(
        self,
        store: IndexStore,
        name: str = "local",
        probe: Optional[str] = None,
    ):
        self.store = store
        self.name = name
        self.probe = probe

    def lookup_all(self, keys, digests, timeout_s=None):
        return self.store.lookup_batch(
            list(keys), probe=self.probe, digests=digests
        )

    def lookup_shard(self, shard, keys, digests, timeout_s=None):
        # the store's batch path routes by digest internally; a
        # shard-partitioned slice touches exactly that shard
        return self.store.lookup_batch(
            list(keys), probe=self.probe, digests=digests
        )

    def similar_shard(self, shard, fps, k, q_counts=None, timeout_s=None):
        return self.store.similar_shard(
            shard, fps, k, probe=self.probe, q_counts=q_counts
        )

    def similar_all(self, fps, k, timeout_s=None):
        return self.store.similar_batch(fps, k, probe=self.probe)


@dataclass
class _ShardFault:
    """Live-settable fault state of one shard at one endpoint."""

    down: bool = False
    latency_s: float = 0.0
    jitter_s: float = 0.0
    error_rate: float = 0.0

    @property
    def clean(self) -> bool:
        return (
            not self.down
            and self.latency_s <= 0.0
            and self.jitter_s <= 0.0
            and self.error_rate <= 0.0
        )


class FaultInjectingTransport(ShardTransport):
    """Deterministic chaos wrapper around any :class:`ShardTransport`.

    Fault state is per shard (``shard=None`` in the setters targets the
    endpoint-wide default) and settable live — the chaos driver kills and
    revives shards while closed-loop clients are mid-flight.  Injection
    is seeded and deterministic *per shard*: each shard owns a
    ``Random(seed, shard)`` stream consumed once per probe of that shard,
    so a fixed probe sequence produces a fixed fault sequence regardless
    of which thread carries it.

    Order of effects per probe: hard-down check, then latency (sleeping
    at most the caller's deadline before raising
    :class:`ProbeTimeoutError`), then the transient-error draw.  A
    whole-batch probe inherits the *worst* state of the shards its keys
    touch — a single down shard fails the whole probe, which is exactly
    what pushes the router onto the per-shard failure-domain path.
    """

    chaotic = True

    def __init__(self, inner: ShardTransport, seed: int = 0):
        if not isinstance(inner, LocalTransport):  # pragma: no cover
            raise TypeError(
                "FaultInjectingTransport needs the wrapped endpoint's "
                "store metadata; wrap a LocalTransport"
            )
        self.inner = inner
        self.name = f"chaos({inner.name})"
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._default = _ShardFault()
        self._faults: Dict[int, _ShardFault] = {}
        self._rngs: Dict[int, Random] = {}
        # injection counters (read by tests and the chaos report)
        self.injected: Dict[str, int] = {
            "down": 0, "timeout": 0, "error": 0, "delayed": 0,
        }

    # -- live fault controls -------------------------------------------------

    def _fault(self, shard: Optional[int]) -> _ShardFault:
        if shard is None:
            return self._default
        f = self._faults.get(shard)
        if f is None:
            d = self._default
            f = _ShardFault(d.down, d.latency_s, d.jitter_s, d.error_rate)
            self._faults[shard] = f
        return f

    def kill(self, shard: Optional[int] = None) -> None:
        """Hard-down a shard (or, with ``None``, the whole endpoint)."""
        with self._lock:
            if shard is None:
                self._default.down = True
                for f in self._faults.values():
                    f.down = True
            else:
                self._fault(shard).down = True

    def revive(self, shard: Optional[int] = None) -> None:
        with self._lock:
            if shard is None:
                self._default.down = False
                for f in self._faults.values():
                    f.down = False
            else:
                self._fault(shard).down = False

    def set_latency(
        self,
        latency_ms: float,
        jitter_ms: float = 0.0,
        shard: Optional[int] = None,
    ) -> None:
        with self._lock:
            f = self._fault(shard)
            f.latency_s = max(0.0, latency_ms) / 1e3
            f.jitter_s = max(0.0, jitter_ms) / 1e3

    def set_error_rate(
        self, rate: float, shard: Optional[int] = None
    ) -> None:
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"error rate must be in [0, 1], got {rate}")
        with self._lock:
            self._fault(shard).error_rate = float(rate)

    def clear(self) -> None:
        """Drop every injected fault (endpoint returns to clean serving)."""
        with self._lock:
            self._default = _ShardFault()
            self._faults.clear()

    # -- injection machinery -------------------------------------------------

    def _rng(self, shard: int) -> Random:
        rng = self._rngs.get(shard)
        if rng is None:
            rng = Random((self.seed << 20) ^ (shard * 0x9E3779B1))
            self._rngs[shard] = rng
        return rng

    def _plan(self, shards: List[int]) -> Tuple[float, bool, Optional[int]]:
        """One locked pass: draw this probe's (delay, flaky, down_shard)."""
        with self._lock:
            delay = 0.0
            flaky = False
            for s in shards:
                f = self._faults.get(s, self._default)
                if f.down:
                    return 0.0, False, s
                if f.clean:
                    continue
                rng = self._rng(s)
                d = f.latency_s + (
                    f.jitter_s * rng.random() if f.jitter_s > 0 else 0.0
                )
                delay = max(delay, d)
                if f.error_rate > 0 and rng.random() < f.error_rate:
                    flaky = True
            return delay, flaky, None

    def _inject(
        self, shards: List[int], timeout_s: Optional[float]
    ) -> None:
        delay, flaky, down = self._plan(shards)
        if down is not None:
            self.injected["down"] += 1
            raise ShardDownError(
                f"{self.name}: shard {down} is down", shard=down
            )
        if delay > 0.0:
            if timeout_s is not None and delay >= timeout_s:
                time.sleep(timeout_s)
                self.injected["timeout"] += 1
                raise ProbeTimeoutError(
                    f"{self.name}: probe exceeded {timeout_s * 1e3:.0f} ms "
                    f"deadline", shard=shards[0] if len(shards) == 1 else None,
                )
            time.sleep(delay)
            self.injected["delayed"] += 1
        if flaky:
            self.injected["error"] += 1
            raise FlakyError(
                f"{self.name}: injected transient failure",
                shard=shards[0] if len(shards) == 1 else None,
            )

    def _touched(self, digests: np.ndarray) -> List[int]:
        st = self.inner.store
        return np.unique(
            shard_of(digests, st.n_shards, st.digest_bits)
        ).tolist()

    # -- probe surface -------------------------------------------------------

    def lookup_all(self, keys, digests, timeout_s=None):
        if digests is None:  # pragma: no cover — router always digests
            digests = digest_u64(list(keys), bits=self.inner.store.digest_bits)
        self._inject(self._touched(np.asarray(digests)), timeout_s)
        return self.inner.lookup_all(keys, digests, timeout_s)

    def lookup_shard(self, shard, keys, digests, timeout_s=None):
        self._inject([int(shard)], timeout_s)
        return self.inner.lookup_shard(shard, keys, digests, timeout_s)

    def similar_shard(self, shard, fps, k, q_counts=None, timeout_s=None):
        self._inject([int(shard)], timeout_s)
        return self.inner.similar_shard(shard, fps, k, q_counts, timeout_s)

    def similar_all(self, fps, k, timeout_s=None):
        st = self.inner.store
        live = [
            s for s in range(st.n_shards)
            if int(st.manifest["shards"][s]["count"]) > 0
        ]
        self._inject(live, timeout_s)
        return self.inner.similar_all(fps, k, timeout_s)

    def close(self) -> None:
        self.inner.close()
