"""Token-level continuous batching over the paged KV cache.

The static :class:`~repro.serve.engine.Engine` serves a batch the way the
dry-run does: pad every prompt to a common length, prefill once, decode
until the LAST sequence finishes.  Real serving traffic is ragged — a
handful of long generations pin the batch while short ones sit finished
in their rows, and newly arrived requests wait for the whole batch to
drain.  This module decouples sequence lifetime from batch lifetime:

* **Paged KV cache.**  Each slot's cache rows live in fixed-size blocks
  of a preallocated pool (:mod:`repro.serve.kvcache`), addressed through
  a per-slot block table.  Admitting or evicting a sequence edits the
  table — never reshapes device state — so the jitted decode step traces
  exactly once for the lifetime of the engine.
* **Slot admission, EOS eviction.**  Between decode steps the leader
  admits queued prefills into free batch slots (reserve-at-admission:
  a request either gets every block it can touch or stays queued — pool
  exhaustion is pure backpressure) and evicts finished sequences, whose
  blocks return to the free list for the next admit.
* **Leader-combining decode loop** (ported from
  :class:`repro.service.scheduler.MicroBatcher`): there is no engine
  thread.  The submitting thread that finds no leader becomes the
  leader and runs admit→decode→evict for *everyone* until no work
  remains; arrivals during a step join at the next step boundary.  A
  lone caller therefore pays zero coordination latency, and leadership
  hands off through the lock-release/re-check dance rather than a
  parked-thread wakeup.
* **Prefix-cache sharing.**  Admission probes a
  :class:`~repro.serve.kvcache.PrefixIndex` keyed by rolling hashes of
  full token blocks: on a hit the slot *adopts* the resident blocks
  (refcount bump, zero prefill compute for those tokens) and prefills
  only the suffix through the chunked
  :func:`~repro.models.transformer.lm_prefill_suffix` path — logits are
  bit-identical to full prefill, so greedy outputs are byte-identical
  with sharing on or off.  Every admitted prompt publishes its full
  blocks back to the index; under pool pressure the index LRU-evicts
  entries whose blocks nothing else holds.  Sharing is bypassed where
  bitwise prefill reproducibility doesn't hold (MoE capacity routing is
  batch-shape-dependent) or positions are offset (VLM image tokens).

Emission is byte-compatible with the static engine's greedy path: the
first token is the argmax of the prefill logits at the true last prompt
position, decode feeds token *k* at position ``len + k - 1``, and a
sequence stops after emitting EOS or ``max_new_tokens`` tokens.  On a
uniform batch the two engines produce identical ``token_ids``
(``tests/test_continuous_batching.py`` pins this bitwise).

Per-request SLO accounting records time-to-first-token (submit → prefill
argmax) and inter-token latency (consecutive decode materializations) in
bounded windows; ``slo_ms()`` reports p50/p99 of both.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import ByteTokenizer
from repro.models.registry import build_model
from repro.serve.engine import GenerationResult, ServeConfig
from repro.serve.kvcache import (
    BlockManager, PagedCacheSpec, PrefixIndex, blocks_for,
)

__all__ = ["ContinuousEngine", "ContinuousStats", "EngineClosed"]


class EngineClosed(RuntimeError):
    """The engine is closed; the request was or will never be admitted."""

# Bounded windows for TTFT / inter-token latency percentiles.
_SLO_WINDOW = 8192


@dataclasses.dataclass
class ContinuousStats:
    """Cumulative scheduler counters (allocator stats live on the manager)."""

    requests: int = 0
    completed: int = 0
    failed: int = 0             # futures resolved with an exception
    cancelled: int = 0          # queued requests cancelled at close()
    prefills: int = 0
    steps: int = 0              # batched decode steps executed
    tokens_out: int = 0         # tokens emitted across all requests
    decode_tokens: int = 0      # tokens emitted by decode steps (excl. first)
    admission_stalls: int = 0   # head-of-queue blocked on slots or blocks
    peak_active: int = 0
    prefix_hits: int = 0        # admissions that adopted indexed blocks
    prefix_misses: int = 0      # prefix-eligible admissions with no match
    prefill_tokens_saved: int = 0  # prompt tokens whose prefill was skipped

    @property
    def tokens_per_step(self) -> float:
        """Mean kept tokens per decode step (≤ max_slots; lane occupancy)."""
        return self.decode_tokens / self.steps if self.steps else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        probes = self.prefix_hits + self.prefix_misses
        return self.prefix_hits / probes if probes else 0.0


class _Seq:
    """Host-side state of one admitted sequence (leader-thread only)."""

    __slots__ = (
        "future", "prompt_len", "budget", "tokens", "t_submit",
        "prefill_s", "t_first", "t_last", "fed",
    )

    def __init__(self, future, prompt_len, budget, t_submit, prefill_s, now):
        self.future: "Future[GenerationResult]" = future
        self.prompt_len = prompt_len
        self.budget = budget
        self.tokens: List[int] = []
        self.t_submit = t_submit
        self.prefill_s = prefill_s
        self.t_first = now
        self.t_last = now
        self.fed = 0            # decode steps this sequence was fed into


class _Request:
    __slots__ = ("prompt", "budget", "future", "t_submit", "seed")

    def __init__(self, prompt: List[int], budget: int, seed: int = 0):
        self.prompt = prompt
        self.budget = budget
        self.seed = seed
        self.future: "Future[GenerationResult]" = Future()
        self.t_submit = time.perf_counter()


class ContinuousEngine:
    """``submit(text) -> Future`` serving over a paged pool of decode slots.

    Greedy by default; with ``greedy=False`` each request samples
    (temperature + top-k) under its own PRNG key derived from a
    per-request seed folded with the token index — never a shared or
    lane-positional key — so sampled outputs are a pure function of
    (prompt, seed), independent of lane composition and eviction order.
    The byte-parity tests against the static engine keep running greedy.
    ``generate(texts)`` is a thin batch wrapper: enqueue all, lead once,
    gather in order.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        spec: PagedCacheSpec,
        scfg: ServeConfig = ServeConfig(),
        prefix_cache: bool = True,
    ):
        self.cfg = cfg
        self.api = build_model(cfg)
        if not self.api.supports_paged:
            raise ValueError(
                f"model family {cfg.family!r} (windows="
                f"{getattr(cfg, 'window', None)}) has no paged-KV decode "
                "path; use the static Engine"
            )
        self.spec = spec
        self.scfg = scfg
        self.params = params
        self.tok = ByteTokenizer()
        self.stats = ContinuousStats()
        self._offset = cfg.n_img_tokens or 0

        self._mgr = BlockManager(spec)
        self._cache, _ = self.api.paged_cache_init(spec.n_blocks, spec.block_size)

        # Prefix sharing needs bitwise-reproducible prefill: MoE capacity
        # routing depends on the prefill batch shape (suffix vs full give
        # different drops), and VLM image tokens offset every position —
        # bypass both so sharing can never change bytes.
        self._prefix_enabled = bool(
            prefix_cache
            and self.api.prefill_suffix is not None
            and self._offset == 0
            and cfg.family != "moe"
        )
        self._index: Optional[PrefixIndex] = (
            PrefixIndex(self._mgr) if self._prefix_enabled else None
        )

        # Fixed-shape batched decode: admission/eviction only edit the
        # block tables and the (S,) token/pos vectors, so this traces once.
        bs = spec.block_size
        temp = float(max(scfg.temperature, 1e-6))
        top_k = int(getattr(scfg, "top_k", 0))

        def sample_rows(logits, seeds, idx):
            # one key per lane from (request seed, token index) ONLY —
            # re-running the same request in any lane mix reproduces it
            lg = logits.astype(jnp.float32) / temp
            if top_k > 0:
                kth = jax.lax.top_k(lg, min(top_k, lg.shape[-1]))[0][..., -1:]
                lg = jnp.where(lg < kth, -jnp.inf, lg)
            keys = jax.vmap(
                lambda s, i: jax.random.fold_in(jax.random.PRNGKey(s), i)
            )(seeds, idx)
            return jax.vmap(jax.random.categorical)(keys, lg).astype(jnp.int32)

        if scfg.greedy:
            def step(p, cur, pos, tables, cache):
                logits, cache = self.api.decode_step_paged(
                    p, cur, pos, tables, cache, bs
                )
                return jnp.argmax(logits, -1).astype(jnp.int32), cache
        else:
            def step(p, cur, pos, tables, cache, seeds, idx):
                logits, cache = self.api.decode_step_paged(
                    p, cur, pos, tables, cache, bs
                )
                return sample_rows(logits, seeds, idx), cache

        self._step = jax.jit(step, donate_argnums=(4,))
        self._sample_first = jax.jit(
            lambda lg, seed: sample_rows(
                lg[None], seed[None], jnp.zeros((1,), jnp.int32)
            )[0]
        )
        self._prefill = jax.jit(
            lambda p, b: self.api.prefill(p, b, max_len=spec.max_len)
        )
        self._write = jax.jit(
            lambda c, pc, row: self.api.paged_prefill_write(c, pc, row, bs),
            donate_argnums=(0,),
        )
        # suffix prefill retraces per (suffix bucket, start) pair — both
        # multiples of block_size and bounded by the table width M, so the
        # trace count is bounded by M² for the engine's lifetime
        self._prefill_suffix = jax.jit(
            lambda p, t, start, row, c, lengths: self.api.prefill_suffix(
                p, t, start, row, c, bs, lengths=lengths
            ),
            static_argnums=(2,),
            donate_argnums=(4,),
        )

        # Leader-only decode state (no lock: exactly one leader at a time).
        self._cur = np.zeros((spec.max_slots, 1), np.int32)
        self._pos = np.zeros((spec.max_slots,), np.int32)
        self._seeds = np.zeros((spec.max_slots,), np.uint32)
        self._idx = np.zeros((spec.max_slots,), np.int32)
        self._active: Dict[int, _Seq] = {}
        self._free_slots: List[int] = list(range(spec.max_slots - 1, -1, -1))
        self._tables_dev = jnp.asarray(self._mgr.tables)
        self._tables_dirty = False

        self._lock = threading.Lock()      # queue, stop flag, SLO windows
        self._leader = threading.Lock()    # at most one decode loop
        self._queue: Deque[_Request] = deque()
        self._stop = False
        self._ttft_ms: Deque[float] = deque(maxlen=_SLO_WINDOW)
        self._itl_ms: Deque[float] = deque(maxlen=_SLO_WINDOW)

    # -- client surface ------------------------------------------------------

    def submit(
        self,
        text: str,
        max_new_tokens: Optional[int] = None,
        lead: bool = True,
        seed: Optional[int] = None,
    ) -> "Future[GenerationResult]":
        """Enqueue one prompt; the future resolves to a GenerationResult.

        The calling thread may transparently become the leader and run
        the decode loop for every queued and active request until no
        work remains (``lead=False`` only enqueues — ``generate`` uses
        it to stage a batch before leading once).

        ``seed`` keys this request's sampling stream (``greedy=False``);
        when omitted it derives from ``scfg.seed`` and the submission
        ordinal — pass it explicitly when replaying a workload across
        threads, where submission order isn't deterministic.
        """
        budget = max_new_tokens or self.scfg.max_new_tokens
        req = _Request(self.tok.encode(text, add_eos=False), budget)
        total = self._offset + len(req.prompt) + budget - 1
        if budget < 1:
            req.future.set_exception(ValueError("max_new_tokens must be >= 1"))
            return req.future
        if total > self.spec.max_len:
            req.future.set_exception(
                ValueError(
                    f"prompt+budget needs {total} cache rows > max_len "
                    f"{self.spec.max_len} "
                    f"({self.spec.max_blocks_per_seq} blocks × "
                    f"{self.spec.block_size})"
                )
            )
            return req.future
        with self._lock:
            if self._stop:
                raise EngineClosed("engine is closed")
            self.stats.requests += 1
            req.seed = seed if seed is not None else (
                self.scfg.seed + self.stats.requests
            )
            self._queue.append(req)
        if lead:
            self._maybe_lead()
        return req.future

    def generate(
        self, texts: List[str], max_new_tokens: Optional[int] = None
    ) -> List[GenerationResult]:
        """Batch wrapper: enqueue everything, lead once, gather in order."""
        futs = [self.submit(t, max_new_tokens, lead=False) for t in texts]
        self._maybe_lead()
        return [f.result() for f in futs]

    # -- leader-combining decode loop ----------------------------------------

    def _maybe_lead(self) -> None:
        # Non-blocking: if a leader exists it will admit our request at
        # its next step boundary.  The re-check loop closes the race
        # where the old leader saw an empty queue and was releasing just
        # as we enqueued.
        while True:
            with self._lock:
                work = bool(self._queue) and not self._stop
            if not work or not self._leader.acquire(blocking=False):
                return
            try:
                self._run_loop()
            finally:
                self._leader.release()

    def _run_loop(self) -> None:
        """Admit → decode one token for every active slot → evict; repeat.

        Runs on the submitting thread that won leadership.  An exception
        (OOM, poisoned weights) is delivered to every *active* future —
        a dying leader must not strand callers — then swallowed so it
        can't tear down an unrelated client thread; queued requests stay
        queued for the next leader.
        """
        try:
            while True:
                self._admit()
                if not self._active:
                    with self._lock:
                        if not self._queue or self._stop:
                            return
                    continue  # backpressure cleared by an eviction race
                self._decode_once()
        except BaseException as e:  # noqa: BLE001 — delivered first
            for slot, seq in list(self._active.items()):
                if not seq.future.done():
                    seq.future.set_exception(e)
                self.stats.failed += 1
                self._mgr.release(slot)
                self._free_slots.append(slot)
            self._active.clear()
            self._tables_dirty = True
            if isinstance(e, (SystemExit, KeyboardInterrupt)):
                raise

    def _probe(self, prompt: List[int]):
        """Longest indexed block-aligned prefix → (blocks, n_tokens)."""
        if self._index is None:
            return [], 0
        return self._index.match(prompt)

    def _admit(self) -> None:
        """Move queued requests into free slots, strictly FIFO.

        Head-of-line blocking is deliberate: skipping a big request to
        admit later small ones would starve it under sustained load, and
        FIFO keeps the backpressure tests deterministic.  Under pool
        pressure the prefix index gives blocks back (LRU entries whose
        blocks nothing else holds) before the head request stalls or
        fails — index residency is a cache, never a reservation.
        """
        while self._free_slots:
            with self._lock:
                if self._stop or not self._queue:
                    return
                req = self._queue[0]
            total = self._offset + len(req.prompt) + req.budget - 1
            # leader-only state below (index, allocator): the lock above
            # only guards the queue — nobody else pops it
            adopt, start = self._probe(req.prompt)
            if not self._mgr.can_admit(total, n_adopted=len(adopt)):
                if self._index is not None:
                    shortfall = (
                        blocks_for(total, self.spec.block_size)
                        - len(adopt) - self._mgr.n_free
                    )
                    if shortfall > 0 and self._index.evict_for(shortfall):
                        # eviction may have dropped the matched entry (or
                        # unlocked a shorter one): probe again
                        adopt, start = self._probe(req.prompt)
                if not self._mgr.can_admit(total, n_adopted=len(adopt)):
                    if self._active:
                        # an eviction will free blocks: wait at the head
                        self.stats.admission_stalls += 1
                        return
                    # leader is the sole allocator and the index has been
                    # drained of reclaimable blocks, so an idle pool is a
                    # FULL pool — this request can never fit; stalling
                    # here would spin the loop forever
                    with self._lock:
                        if self._stop:
                            return  # close() already failed the queue
                        self._queue.popleft()
                        self.stats.failed += 1
                    req.future.set_exception(
                        RuntimeError(
                            f"request needs {blocks_for(total, self.spec.block_size)} "
                            f"blocks but the pool only has "
                            f"{self.spec.usable_blocks} usable"
                        )
                    )
                    continue
            with self._lock:
                if self._stop:
                    return
                self._queue.popleft()
            if not req.future.set_running_or_notify_cancel():
                with self._lock:
                    self.stats.cancelled += 1
                continue
            self._admit_one(req, total, adopt, start)
        # no free slot for the head request: wait for an eviction

    def _admit_one(
        self, req: _Request, total: int, adopt: List[int], start: int
    ) -> None:
        prompt, budget = req.prompt, req.budget
        L = len(prompt)
        # Pad prompts up to a block-size multiple so distinct lengths
        # share prefill traces; the dense cache is always max_len rows
        # (what the paged write scatters), so this is the only retrace
        # axis.  Pad rows beyond ``lengths`` are overwritten by decode
        # before any read can see them — same invariant the static
        # engine's ragged batches rely on.
        bucket = min(
            self.spec.max_len - self._offset,
            blocks_for(L, self.spec.block_size) * self.spec.block_size,
        )
        t0 = time.perf_counter()
        slot: Optional[int] = None
        if start > 0:
            # Prefix hit: the slot and its blocks come first (suffix
            # prefill writes through the block table), then only the
            # unmatched tail runs the model — ``start`` prompt tokens
            # cost zero prefill FLOPs.
            slot = self._free_slots.pop()
            admitted = self._mgr.admit(slot, total, prefix_blocks=adopt)
            assert admitted, "can_admit passed but admit failed (leader is sole allocator)"
            suf = np.full((1, bucket - start), self.tok.pad_id, np.int32)
            suf[0, : L - start] = prompt[start:]
            row = jnp.asarray(self._mgr.tables[slot])
            logits, self._cache = self._prefill_suffix(
                self.params, jnp.asarray(suf), start, row, self._cache,
                jnp.asarray([L - start], jnp.int32),
            )
            dense = None
            self.stats.prefix_hits += 1
            self.stats.prefill_tokens_saved += start
        else:
            if self._prefix_enabled:
                self.stats.prefix_misses += 1
            toks = np.full((1, bucket), self.tok.pad_id, np.int32)
            toks[0, :L] = prompt
            batch: Dict[str, Any] = {
                "tokens": jnp.asarray(toks),
                "lengths": jnp.asarray([L], jnp.int32),
            }
            if self.cfg.family == "vlm":
                batch["patch_embeds"] = jnp.zeros(
                    (1, self.cfg.n_img_tokens, self.cfg.d_model), jnp.float32
                )
            logits, dense = self._prefill(self.params, batch)
        first = self._first_token(logits, req.seed)
        now = time.perf_counter()
        prefill_s = now - t0
        self.stats.prefills += 1
        with self._lock:
            self._ttft_ms.append((now - req.t_submit) * 1e3)
        self.stats.tokens_out += 1

        if first == self.tok.eos_id or budget == 1:
            # Entirely served by prefill: occupies no slot past this
            # point.  A prefix hit already owns blocks — publish the
            # prompt's full blocks (the suffix KV is resident and exact)
            # before dropping the slot's hold, then let go.
            if slot is not None:
                if self._index is not None:
                    self._index.publish(
                        prompt, self._mgr.slot_blocks(slot), L
                    )
                self._mgr.release(slot)
                self._free_slots.append(slot)
                self._tables_dirty = True
            self.stats.completed += 1
            req.future.set_result(
                self._result([first], L, 0, prefill_s, 0.0)
            )
            return

        if slot is None:
            slot = self._free_slots.pop()
            admitted = self._mgr.admit(slot, total)
            assert admitted, "can_admit passed but admit failed (leader is sole allocator)"
            row = jnp.asarray(self._mgr.tables[slot])
            self._cache = self._write(self._cache, dense, row)
        if self._index is not None:
            # publish every full-block prefix: decode writes land in the
            # partial/fresh tail blocks, never in published ones
            self._index.publish(prompt, self._mgr.slot_blocks(slot), L)
        seq = _Seq(req.future, L, budget, req.t_submit, prefill_s, now)
        seq.tokens.append(first)
        self._cur[slot, 0] = first
        self._pos[slot] = self._offset + L
        self._seeds[slot] = req.seed & 0xFFFFFFFF
        self._idx[slot] = 1
        self._active[slot] = seq
        self._tables_dirty = True
        self.stats.peak_active = max(self.stats.peak_active, len(self._active))

    def _first_token(self, logits, seed: int) -> int:
        """First emitted token from the prefill logits (greedy or sampled
        with this request's key at token index 0)."""
        if self.scfg.greedy:
            return int(jnp.argmax(logits[0]))
        return int(
            self._sample_first(
                logits[0], jnp.asarray(seed & 0xFFFFFFFF, jnp.uint32)
            )
        )

    def _decode_once(self) -> None:
        """One batched paged decode step + host-side emit/evict."""
        if self._tables_dirty:
            self._tables_dev = jnp.asarray(self._mgr.tables)
            self._tables_dirty = False
        args = (
            self.params,
            jnp.asarray(self._cur),
            jnp.asarray(self._pos),
            self._tables_dev,
            self._cache,
        )
        if not self.scfg.greedy:
            args = args + (jnp.asarray(self._seeds), jnp.asarray(self._idx))
        nxt, self._cache = self._step(*args)
        nxt = np.asarray(nxt)  # the one host sync per step: (S,) int32
        now = time.perf_counter()
        self.stats.steps += 1
        for slot, seq in list(self._active.items()):
            tok = int(nxt[slot])
            seq.fed += 1
            seq.tokens.append(tok)
            with self._lock:
                self._itl_ms.append((now - seq.t_last) * 1e3)
            seq.t_last = now
            self.stats.tokens_out += 1
            self.stats.decode_tokens += 1
            if tok == self.tok.eos_id or len(seq.tokens) >= seq.budget:
                self._evict(slot, seq, now)
            else:
                self._cur[slot, 0] = tok
                self._pos[slot] += 1
                self._idx[slot] = len(seq.tokens)

    def _evict(self, slot: int, seq: _Seq, now: float) -> None:
        self._mgr.release(slot)
        self._tables_dirty = True
        del self._active[slot]
        self._free_slots.append(slot)
        self._cur[slot, 0] = 0
        self._pos[slot] = 0
        self.stats.completed += 1
        seq.future.set_result(
            self._result(
                seq.tokens, seq.prompt_len, seq.fed, seq.prefill_s,
                now - seq.t_first,
            )
        )

    def _result(self, tokens, prompt_len, steps, prefill_s, decode_s):
        return GenerationResult(
            text=self.tok.decode(tokens),
            token_ids=list(tokens),
            prompt_len=prompt_len,
            steps=steps,
            prefill_s=prefill_s,
            decode_s=decode_s,
        )

    # -- accounting ----------------------------------------------------------

    def slo_ms(self) -> Dict[str, float]:
        """TTFT and inter-token latency percentiles (bounded windows)."""
        with self._lock:
            ttft = list(self._ttft_ms)
            itl = list(self._itl_ms)

        def pct(xs: List[float], p: float) -> float:
            return float(np.percentile(xs, p)) if xs else 0.0

        return {
            "ttft_p50_ms": pct(ttft, 50),
            "ttft_p99_ms": pct(ttft, 99),
            "itl_p50_ms": pct(itl, 50),
            "itl_p99_ms": pct(itl, 99),
            "ttft_mean_ms": float(np.mean(ttft)) if ttft else 0.0,
            "itl_mean_ms": float(np.mean(itl)) if itl else 0.0,
        }

    def reset_slo(self) -> None:
        """Drop the SLO windows (benchmarks: exclude warmup/compile TTFT)."""
        with self._lock:
            self._ttft_ms.clear()
            self._itl_ms.clear()

    def counters(self) -> Dict[str, float]:
        """Flat cumulative counters (loadgen ``counters_fn`` shape)."""
        out = {k: float(v) for k, v in dataclasses.asdict(self.stats).items()}
        out["tokens_per_step"] = self.stats.tokens_per_step
        out["prefix_hit_rate"] = self.stats.prefix_hit_rate
        out.update({f"blk_{k}": float(v) for k, v in self._mgr.stats().items()})
        if self._index is not None:
            out.update(
                {f"pfx_{k}": float(v) for k, v in self._index.stats().items()}
            )
        return out

    def check(self) -> None:
        """Assert allocator + prefix-index consistency (tests + debug):
        every block's refcount must equal its slot holds plus its index
        holds, exactly."""
        self._mgr.check(
            self._index.block_refs() if self._index is not None else None
        )

    # -- shutdown ------------------------------------------------------------

    def close(self, drain: bool = False) -> None:
        """Stop admitting; fail queued requests; wait out the leader.

        Queued-but-unadmitted futures resolve with :class:`EngineClosed`
        — a caller blocked on ``.result()`` gets a clear error instead
        of waiting forever.  Active sequences always finish their decode
        (bounded by the largest remaining budget): the leader keeps
        decoding but admits nothing once the stop flag is up.

        ``drain=True`` first serves everything already queued (leading
        if necessary), so no request submitted before ``close`` is lost.
        """
        if drain:
            while True:
                with self._lock:
                    if self._stop or not self._queue:
                        break
                self._maybe_lead()
                with self._leader:
                    pass  # an existing leader is draining; wait it out
        with self._lock:
            if self._stop:
                return
            self._stop = True
            for req in self._queue:
                if req.future.set_running_or_notify_cancel():
                    req.future.set_exception(
                        EngineClosed(
                            "engine is closed; request was queued but "
                            "never admitted"
                        )
                    )
                self.stats.cancelled += 1
            self._queue.clear()
        with self._leader:
            pass  # leader drains its active set, then we own shutdown

    def __enter__(self) -> "ContinuousEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
