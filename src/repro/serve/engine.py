"""Batched serving engine: prefill + decode over the uniform model API.

Static-batch engine (the dry-run's ``serve_step`` is its inner loop): a
batch of requests is padded to a common prefill length, prefilled once,
then decoded token-by-token with per-sequence positions until EOS or the
token budget.  Per-sequence positions (not a scalar clock) are what real
continuous-batching serving needs — finished sequences keep their cache
rows and are masked out of sampling.

Sharded serving: pass ``mesh`` (and the ``param_specs`` returned by
``api.init``) and the engine device_puts the weights to their logical
shardings, shards the batch over the data-parallel axes, and runs prefill
and every decode step inside the mesh context so the models' ``constrain``
annotations (:mod:`repro.dist.logical`) take effect — batched decode then
shards across devices exactly like the dry-run's serve cells.  Without a
mesh nothing changes: single-device serving traces the identical jaxpr.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import ByteTokenizer
from repro.launch.sharding import batch_shardings, replicated, shardings_from_specs
from repro.models.registry import ModelApi, build_model

__all__ = ["ServeConfig", "Engine", "GenerationResult"]


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    max_len: int = 512
    greedy: bool = True
    temperature: float = 1.0
    top_k: int = 0            # 0 = no top-k truncation (sampling engines)
    seed: int = 0
    # Host-sync cadence of the decode loop: emitted tokens accumulate in a
    # device-side buffer and the all-done flag is polled only every
    # ``sync_every`` steps (1 = poll every step, the old behavior; the
    # token buffer itself transfers ONCE per generate call either way).
    sync_every: int = 8


@dataclasses.dataclass
class GenerationResult:
    text: str
    token_ids: List[int]
    prompt_len: int
    steps: int
    prefill_s: float
    decode_s: float

    @property
    def tokens_per_s(self) -> float:
        return self.steps / self.decode_s if self.decode_s > 0 else float("inf")


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        scfg: ServeConfig = ServeConfig(),
        mesh=None,
        param_specs=None,
    ):
        self.cfg = cfg
        self.api = build_model(cfg)
        self.scfg = scfg
        self.mesh = mesh
        self.tok = ByteTokenizer()
        if mesh is not None:
            sh = (
                shardings_from_specs(mesh, param_specs, params)
                if param_specs is not None
                else replicated(mesh)
            )
            params = jax.device_put(params, sh)
        self.params = params
        self._prefill = jax.jit(
            lambda p, batch: self.api.prefill(p, batch, max_len=scfg.max_len)
        )
        self._decode = jax.jit(self.api.decode_step, donate_argnums=(3,))
        # Fused emit+decode step: token emission, EOS bookkeeping and the
        # decode itself run in ONE jitted call that carries a device-side
        # output buffer — no per-token host transfers (§Perf: the old loop
        # pulled every token with int(cur[i, 0]), B transfers per step).
        eos = self.tok.eos_id
        pad = self.tok.pad_id

        def fused(p, cur, pos, cache, out_buf, n_emit, done, t, key):
            val = jnp.where(done[:, None], pad, cur)
            out_buf = jax.lax.dynamic_update_slice(out_buf, val, (0, t))
            n_emit = n_emit + (~done).astype(jnp.int32)
            done = done | (cur[:, 0] == eos)
            logits, cache = self.api.decode_step(p, cur, pos, cache)
            if self.scfg.greedy:
                nxt = jnp.argmax(logits, -1)
            else:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits / self.scfg.temperature, axis=-1
                )
            cur = nxt[:, None].astype(jnp.int32)
            return cur, pos + 1, cache, out_buf, n_emit, done, key

        self._fused_step = jax.jit(fused, donate_argnums=(3, 4, 5, 6))

    def _mesh_ctx(self):
        """The mesh context (activates the sharding rules) or a no-op."""
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _shard_batch(self, extras: Dict[str, Any]) -> Dict[str, Any]:
        """Spread the request batch over the mesh's data-parallel axes."""
        if self.mesh is None:
            return extras
        sh = batch_shardings(
            self.mesh,
            {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in extras.items()},
        )
        return {k: jax.device_put(v, sh[k]) for k, v in extras.items()}

    def _pad_prompts(self, prompts: List[List[int]]) -> Tuple[np.ndarray, np.ndarray]:
        """Left-align prompts, pad right to the longest (positions differ)."""
        maxlen = max(len(p) for p in prompts)
        toks = np.full((len(prompts), maxlen), self.tok.pad_id, np.int32)
        lens = np.zeros((len(prompts),), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p
            lens[i] = len(p)
        return toks, lens

    def generate(self, texts: List[str]) -> List[GenerationResult]:
        prompts = [self.tok.encode(t, add_eos=False) for t in texts]
        toks, lens = self._pad_prompts(prompts)
        b, s = toks.shape
        extras: Dict[str, Any] = {
            "tokens": jnp.asarray(toks),
            # true prompt lengths: prefill gathers each sequence's OWN
            # last-position logits, so ragged right-padded batches start
            # greedy continuation correctly (not from a pad row)
            "lengths": jnp.asarray(lens, jnp.int32),
        }
        if self.cfg.family == "encdec":
            extras["frames"] = jnp.zeros(
                (b, self.cfg.enc_frames, self.cfg.d_model), jnp.float32
            )
        if self.cfg.family == "vlm":
            extras["patch_embeds"] = jnp.zeros(
                (b, self.cfg.n_img_tokens, self.cfg.d_model), jnp.float32
            )

        extras = self._shard_batch(extras)
        t0 = time.perf_counter()
        with self._mesh_ctx():
            logits, cache = self._prefill(self.params, extras)
        logits.block_until_ready()
        prefill_s = time.perf_counter() - t0

        offset = self.cfg.n_img_tokens or 0
        pos = jnp.asarray(lens + offset, jnp.int32)
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_buf = jnp.full((b, self.scfg.max_new_tokens), self.tok.pad_id,
                           jnp.int32)
        n_emit = jnp.zeros((b,), jnp.int32)
        done = jnp.zeros((b,), bool)
        key = jax.random.PRNGKey(self.scfg.seed)

        # Decode loop: tokens accumulate device-side; the host polls only
        # the all-done flag every ``sync_every`` steps and materializes the
        # token buffer once after the loop.
        t1 = time.perf_counter()
        steps = 0
        sync_every = max(1, self.scfg.sync_every)
        for step in range(self.scfg.max_new_tokens):
            if step % sync_every == 0 and step and bool(jnp.all(done)):
                break
            with self._mesh_ctx():
                cur, pos, cache, out_buf, n_emit, done, key = (
                    self._fused_step(
                        self.params, cur, pos, cache, out_buf, n_emit,
                        done, np.int32(step), key,
                    )
                )
            steps += 1
        out_buf.block_until_ready()
        decode_s = time.perf_counter() - t1

        out_np = np.asarray(out_buf)            # ONE transfer per flush
        emitted = np.asarray(n_emit)
        outs = [out_np[i, : emitted[i]].tolist() for i in range(b)]
        return [
            GenerationResult(
                text=self.tok.decode(outs[i]),
                token_ids=outs[i],
                prompt_len=int(lens[i]),
                steps=steps,
                prefill_s=prefill_s,
                decode_s=decode_s,
            )
            for i in range(b)
        ]
