"""repro.serve — LM serving engines over the uniform model API.

Two architectures share one greedy-token contract (outputs are
byte-identical between them on a given prompt):

Static batched engine (pad → prefill → decode till last finishes)
                                  → :mod:`repro.serve.engine`
Paged KV cache bookkeeping (block pool, free-list, block tables)
                                  → :mod:`repro.serve.kvcache`
Continuous batching (slot admission per decode step, EOS eviction,
TTFT/inter-token SLO accounting)  → :mod:`repro.serve.scheduler`
"""

from .engine import Engine, GenerationResult, ServeConfig
from .kvcache import (
    TRASH_BLOCK, BlockManager, PagedCacheSpec, PrefixIndex, blocks_for,
)
from .scheduler import ContinuousEngine, ContinuousStats, EngineClosed

__all__ = [
    "BlockManager",
    "ContinuousEngine",
    "ContinuousStats",
    "Engine",
    "EngineClosed",
    "GenerationResult",
    "PagedCacheSpec",
    "PrefixIndex",
    "ServeConfig",
    "TRASH_BLOCK",
    "blocks_for",
]
